"""MoE dispatch/combine unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe


def _cfg(E=4, k=2, cf=1.25):
    return ModelConfig(
        name="t", family="moe", num_layers=2, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, head_dim=8,
        moe=MoEConfig(num_experts=E, top_k=k, capacity_factor=cf),
    )


def test_moe_output_finite_and_shaped():
    cfg = _cfg()
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 10, 16))
    y, aux = moe.moe_ffn(p, cfg, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)


def test_moe_matches_dense_single_expert():
    """E=1, k=1, ample capacity == plain FFN with that expert's weights."""
    cfg = _cfg(E=1, k=1, cf=2.0)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, _ = moe.moe_ffn(p, cfg, x)
    h = x @ p["experts_wi"][0]
    g = x @ p["experts_wg"][0]
    ref = (h * jax.nn.silu(g)) @ p["experts_wd"][0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)


def test_moe_capacity_drops_tokens():
    """With capacity factor ~0, (almost) everything is dropped -> ~zero out."""
    cfg = _cfg(E=4, k=1, cf=1e-9)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    y, _ = moe.moe_ffn(p, cfg, x)
    # capacity C = max(int(...), 1) = 1 slot per expert: at most E tokens kept
    nonzero_tokens = int((jnp.abs(y).sum(-1) > 1e-6).sum())
    assert nonzero_tokens <= 2 * 4  # G=2 groups x E experts x 1 slot


def test_moe_grad_flows():
    cfg = _cfg()
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))

    def loss(p):
        y, aux = moe.moe_ffn(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert jnp.isfinite(leaf).all(), path
    # router must receive gradient (through combine weights)
    assert float(jnp.abs(g["router"]).max()) > 0


def test_aux_loss_balanced_routing_is_minimal():
    """Uniform router probs: aux == k (tok_frac sums to k over choices;
    balanced tok_frac_e = k/E, prob_frac_e = 1/E -> aux = E*E*(k/E)*(1/E))."""
    cfg = _cfg(E=4, k=2)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform logits
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    _, aux = moe.moe_ffn(p, cfg, x)
    # ties in top_k concentrate deterministically on the first k experts,
    # which is itself the balanced-load upper-bound k for uniform probs
    assert abs(float(aux) - cfg.moe.top_k) < 0.1
