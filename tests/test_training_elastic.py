"""Elastic plan recovery: mesh resize -> re-race mesh axes -> persist.

The PlanStore mesh gate used to REJECT a store written on a different
topology (restart boots cold, re-autotunes everything).  These tests
pin the recover path: ``repro.training.elastic.recover_plans`` re-keys
each entry's LOCAL winner (block/dtype/fuse axes stay cache hits — zero
local timing runs) and re-races ONLY the mesh-keyed axes (sharding
mode, grad_value reduction), then persists the new winners so the next
restart on the new topology races nothing at all.

Runs under the conftest's 4 virtual CPU devices.
"""
import json

import jax
import pytest

from repro.kernels import plan as pm
from repro.launch import mesh as mesh_lib
from repro.serving.persistence import PlanStore
from repro.training import elastic

_LEVELS = ((8, 8), (4, 4))


def _mesh(dp, tp):
    if len(jax.devices()) < dp * tp:
        pytest.skip(f"needs {dp * tp} devices")
    return mesh_lib.make_mesh_2d(dp, tp)


def _spec(q=16):
    return pm.MsdaSpec(spatial_shapes=_LEVELS, num_heads=2, head_dim=8,
                       num_points=2, num_queries=q, train=True)


@pytest.fixture()
def fresh_caches(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    pm.clear_plans()
    pm.reset_autotune_stats()
    yield tmp_path
    pm.clear_plans()


def test_recover_plans_missing_store_is_cold_boot(tmp_path):
    rep = elastic.recover_plans(str(tmp_path / "nope.json"))
    assert rep.plans == [] and rep.replan_count == 0 and not rep.persisted


def test_recover_plans_matching_mesh_zero_races(fresh_caches):
    """Topology unchanged -> plain seeded restore, no timing runs."""
    tmp_path = fresh_caches
    mesh = _mesh(2, 2)
    plan = pm.msda_plan(_spec(), backend="ref", tune="autotune", mesh=mesh,
                        query_parallel=True)
    store = PlanStore(str(tmp_path / "plans.json"))
    store.save_plans([plan])
    pm.clear_plans()
    pm.reset_autotune_stats()
    rep = elastic.recover_plans(str(tmp_path / "plans.json"), mesh=mesh)
    assert len(rep.plans) == 1 and rep.replan_count == 0
    assert rep.raced == 0 and not rep.persisted
    assert rep.plans[0].sharding_mode == plan.sharding_mode


def test_recover_plans_reraces_mesh_axes_only_and_persists(fresh_caches):
    """Acceptance: a store built on 2x2 restored onto 1x4 re-races
    exactly the mesh-keyed axes (raced_local == 0) while reusing every
    local winner, persists the new winners, and the NEXT 1x4 restore
    does zero timing runs."""
    tmp_path = fresh_caches
    store_path = str(tmp_path / "plans.json")
    m22, m14 = _mesh(2, 2), _mesh(1, 4)
    plan = pm.msda_plan(_spec(), backend="ref", tune="autotune", mesh=m22,
                        query_parallel=True)
    PlanStore(store_path).save_plans([plan])

    # the resized restart
    pm.clear_plans()
    pm.reset_autotune_stats()
    rep = elastic.recover_plans(store_path, mesh=m14)
    assert rep.replan_count == 1 and len(rep.plans) == 1
    assert rep.raced_local == 0, "local axes must come from the seeded winner"
    assert rep.raced_mesh >= 1, "the mesh-keyed axes must actually re-race"
    assert rep.persisted
    assert "data2xmodel2 -> data1xmodel4" in rep.reraced[0]
    assert rep.plans[0].sharding_mode in ("query", "query2d", "batchquery")

    # the store now belongs to the new topology
    with open(store_path) as f:
        data = json.load(f)
    assert data["meta"]["mesh"] == "data1xmodel4"
    assert data["meta"]["elastic_reraced"] == 1

    # second restart on 1x4: zero races of ANY kind
    pm.clear_plans()
    pm.reset_autotune_stats()
    rep2 = elastic.recover_plans(store_path, mesh=m14)
    assert rep2.replan_count == 0 and rep2.raced == 0
    assert len(rep2.plans) == 1
    assert rep2.plans[0].sharding_mode == rep.plans[0].sharding_mode


def test_restore_default_still_rejects_mismatch(fresh_caches):
    """The elastic path is opt-in: restore()'s default mesh gate still
    degrades a mismatched entry to a skip (serving semantics, pinned by
    test_sharding_dist), and the rerace mode must be requested by name."""
    tmp_path = fresh_caches
    store_path = str(tmp_path / "plans.json")
    m22 = _mesh(2, 2)
    plan = pm.msda_plan(_spec(), backend="ref", mesh=m22, sharding="2d")
    store = PlanStore(store_path)
    store.save_plans([plan])
    pm.clear_plans()
    rep = store.restore(mesh=_mesh(1, 4))  # default on_mesh_mismatch="skip"
    assert not rep.plans and len(rep.skipped) == 1
    assert "mismatch" in rep.skipped[0]
    with pytest.raises(ValueError, match="on_mesh_mismatch"):
        store.restore(mesh=m22, on_mesh_mismatch="explode")


def test_corrupt_store_errors_name_the_offender(tmp_path):
    """Store-level corruption names the file; entry-level corruption
    names the entry — never a bare stack trace, never a silent skip."""
    p = tmp_path / "plans.json"
    p.write_text("{ not json")
    rep = PlanStore(str(p)).restore()
    assert not rep.plans and len(rep.skipped) == 1
    assert "corrupt JSON" in rep.skipped[0] and str(p) in rep.skipped[0]

    # valid store, one unreadable entry: the OTHER entries still restore
    good = pm.msda_plan(_spec(), backend="ref")
    store = PlanStore(str(tmp_path / "plans2.json"))
    store.save_plans([good])
    with open(store.path) as f:
        data = json.load(f)
    data["entries"].insert(0, {"backend": "ref", "garbage": True})
    with open(store.path, "w") as f:
        json.dump(data, f)
    pm.clear_plans()
    rep = store.restore()
    assert len(rep.plans) == 1
    assert len(rep.skipped) == 1 and "entry 0" in rep.skipped[0]
    pm.clear_plans()
