"""Per-assigned-architecture smoke tests (reduced configs, CPU).

For every arch: instantiate the same-family reduced config, run one
forward/train step, assert output shapes + no NaNs; for serving archs
additionally assert prefill==decode logits consistency (the strongest
cheap correctness signal for cache machinery).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, list_configs, reduced, supports_shape
from repro.train import state as train_state

LM_ARCHS = [
    "granite-20b", "stablelm-1.6b", "qwen1.5-32b", "llama3-8b",
    "recurrentgemma-2b", "dbrx-132b", "grok-1-314b", "xlstm-350m",
]


def _batch_for(cfg, B=2, S=16):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "audio":
        batch["frames"] = (
            jax.random.normal(ks[1], (B, cfg.encoder.num_frames, cfg.d_model)) * 0.1
        )
    if cfg.family == "vlm":
        from repro.models import vlm

        sv = vlm.pyramid_len(cfg.vision)
        batch["pyramid"] = jax.random.normal(ks[1], (B, sv, cfg.vision.vision_dim)) * 0.1
    if cfg.family == "vision":
        sp = sum(h * w for h, w in cfg.msda.levels)
        batch = {
            "pyramid": jax.random.normal(ks[1], (B, sp, cfg.d_model)) * 0.1,
            "labels": jnp.array([[1, 5, -1], [2, -1, -1]], jnp.int32)[:B],
            "boxes": jax.random.uniform(ks[2], (B, 3, 4)),
        }
    return batch


def test_all_assigned_archs_registered():
    assert set(LM_ARCHS + ["whisper-large-v3", "phi-3-vision-4.2b"]).issubset(
        set(list_configs())
    )


@pytest.mark.parametrize("arch", list_configs())
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    params = train_state.init_model(jax.random.PRNGKey(0), cfg)
    lf = train_state.loss_fn(cfg)
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(lambda p: lf(p, batch, remat=False))(params)
    assert jnp.isfinite(loss), arch
    gnorm = jnp.sqrt(sum(jnp.vdot(g, g) for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm), arch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_consistency(arch):
    from repro.models import lm

    cfg = reduced(get_config(arch))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab_size)
    _, cache = lm.lm_prefill(params, cfg, tokens[:, :8], capacity=64)
    for t in range(8, 12):
        logits_d, cache = lm.lm_decode_step(params, cfg, cache, tokens[:, t])
    logits_full, _ = lm.lm_prefill(params, cfg, tokens, capacity=64)
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_full), atol=3e-4, rtol=2e-3
    )


def test_whisper_prefill_decode_consistency():
    from repro.models import whisper as wh

    cfg = reduced(get_config("whisper-large-v3"))
    params = wh.init_whisper(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.encoder.num_frames, cfg.d_model)) * 0.1
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0, cfg.vocab_size)
    _, cache = wh.whisper_prefill(params, cfg, frames, tokens[:, :6], capacity=16)
    for t in range(6, 10):
        ld, cache = wh.whisper_decode_step(params, cfg, cache, tokens[:, t])
    lp2, _ = wh.whisper_prefill(params, cfg, frames, tokens, capacity=16)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lp2), atol=3e-4, rtol=2e-3)


def test_vlm_prefill_decode_consistency():
    from repro.models import vlm

    cfg = reduced(get_config("phi-3-vision-4.2b"))
    params = vlm.init_vlm(jax.random.PRNGKey(0), cfg)
    sv = vlm.pyramid_len(cfg.vision)
    pyr = jax.random.normal(jax.random.PRNGKey(1), (2, sv, cfg.vision.vision_dim)) * 0.1
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab_size)
    _, cache = vlm.vlm_prefill(params, cfg, pyr, tokens[:, :8], capacity=32)
    for t in range(8, 12):
        ld, cache = vlm.vlm_decode_step(params, cfg, cache, tokens[:, t])
    lp2, _ = vlm.vlm_prefill(params, cfg, pyr, tokens, capacity=32)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lp2), atol=3e-4, rtol=2e-3)


def test_shape_applicability_matrix():
    """40 cells: long_500k only for sub-quadratic archs; others documented."""
    runnable, skipped = 0, 0
    for arch in list_configs():
        cfg = get_config(arch)
        if cfg.family == "vision":
            # paper-native extra cell, not part of the 40
            ok, _ = supports_shape(cfg, SHAPES["detr_1k"])
            assert ok
            continue
        for shape in SHAPES.values():
            if shape.name == "detr_1k":
                ok, reason = supports_shape(cfg, shape)
                assert not ok  # vision-only cell
                continue
            ok, reason = supports_shape(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert shape.name == "long_500k" and reason
    assert runnable + skipped == 40
    assert skipped == 8  # 8 pure-full-attention archs skip long_500k
