"""Hypothesis property tests for the MSDA op's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels import plan as plan_mod
from repro.kernels.ref import msda_ref

SET = dict(max_examples=15, deadline=None)


def _mk(B, Q, H, D, P, levels, seed):
    S = sum(h * w for h, w in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, Q, H, L, P, 2), minval=-0.2, maxval=1.2)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, L, P)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, L, P)
    return value, loc, attn


dims = st.tuples(
    st.integers(1, 2),        # B
    st.integers(1, 17),       # Q
    st.integers(1, 3),        # H
    st.sampled_from([4, 8]),  # D
    st.integers(1, 4),        # P
    st.sampled_from([((5, 7),), ((8, 6), (4, 3))]),
    st.integers(0, 10_000),   # seed
)


@given(dims)
@settings(**SET)
def test_kernel_equals_oracle(args):
    B, Q, H, D, P, levels, seed = args
    value, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    out = ops.msda(value, levels, loc, attn, backend="pallas")
    ref = msda_ref(value, levels, loc, attn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@given(dims, st.floats(-2.0, 2.0), st.floats(-2.0, 2.0))
@settings(**SET)
def test_linearity_in_value(args, alpha, beta):
    """msda(a*v1 + b*v2) == a*msda(v1) + b*msda(v2)."""
    B, Q, H, D, P, levels, seed = args
    v1, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    v2, _, _ = _mk(B, Q, H, D, P, levels, seed + 1)
    lhs = ops.msda(alpha * v1 + beta * v2, levels, loc, attn, backend="pallas")
    rhs = alpha * ops.msda(v1, levels, loc, attn, backend="pallas") + beta * ops.msda(
        v2, levels, loc, attn, backend="pallas"
    )
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=5e-5)


@given(dims)
@settings(**SET)
def test_constant_field_interior(args):
    """Constant value field + interior points -> exactly that constant
    (attention weights sum to 1)."""
    B, Q, H, D, P, levels, seed = args
    _, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    loc = jnp.clip(loc, 0.3, 0.7)  # safely interior
    S = sum(h * w for h, w in levels)
    value = jnp.full((B, S, H, D), 2.5)
    out = ops.msda(value, levels, loc, attn, backend="pallas")
    np.testing.assert_allclose(np.asarray(out), 2.5, atol=1e-4)


@given(dims)
@settings(**SET)
def test_attention_weight_homogeneity(args):
    """Scaling attention weights scales the output (degree-1 homogeneous)."""
    B, Q, H, D, P, levels, seed = args
    value, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    o1 = ops.msda(value, levels, loc, 3.0 * attn, backend="pallas")
    o2 = 3.0 * ops.msda(value, levels, loc, attn, backend="pallas")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=5e-5)


# --------------------------------------------------------------------------
# block planning: the slab-bytes VMEM model's invariants over random specs
# --------------------------------------------------------------------------

_MIB = 2**20

spec_dims = st.tuples(
    st.sampled_from([((5, 7),), ((8, 6), (4, 3)), ((32, 32), (16, 16), (8, 8))]),
    st.integers(1, 8),                       # P
    st.sampled_from([8, 16, 32]),            # D
    st.integers(1, 90_000),                  # Q
    st.sampled_from([2 * _MIB, 16 * _MIB, 32 * _MIB, 64 * _MIB]),  # budget
    st.booleans(),                           # train
    st.sampled_from(["float32", "bfloat16"]),  # slab dtype
)


def _round_up8(x):
    return (x + 7) // 8 * 8


@given(spec_dims)
@settings(**SET)
def test_planned_block_q_respects_vmem_model(args):
    """For random specs — TRAIN ones included — heuristic block_q stays
    sublane(8)-aligned, never exceeds the query extent or the 2048 cap,
    and under the slab-bytes model never exceeds vmem_budget (unless
    already clamped at the 8-row floor / the model's 1 MiB minimum
    working set).  The per-query working set includes the train-mode
    saved-corner output block (block_q x 4P x D in the slab dtype) the
    model used to ignore."""
    levels, P, D, Q, budget, train, slab = args
    spec = plan_mod.MsdaSpec(
        spatial_shapes=levels, num_heads=2, head_dim=D, num_points=P,
        num_queries=Q, train=train, vmem_budget=budget, slab_dtype=slab)
    bqs = plan_mod._heuristic_block_q(spec)
    per_q = ops.per_query_bytes(P, D, train=train,
                                slab_itemsize=spec.slab_itemsize)
    if train:
        assert per_q == ops.per_query_bytes(P, D) + 4 * P * D * spec.slab_itemsize
    for hw, bq in zip(levels, bqs):
        assert bq % 8 == 0 and 8 <= bq <= 2048
        assert bq <= _round_up8(Q)
        resident = ops.slab_rows(hw) * D * spec.slab_itemsize
        if train:
            resident += ops.slab_rows(hw) * D * spec.accum_itemsize
        # the documented model: per-step bytes fit what the budget leaves
        # after the resident slab(s), floored at a 1 MiB working set
        assert bq * per_q <= max(budget - resident, 1 * _MIB) or bq == 8


@given(spec_dims)
@settings(**SET)
def test_bf16_slab_never_narrows_blocks(args):
    """Halving slab residency (bf16 storage) can only widen the planned
    vec-len, never shrink it — the VMEM freed goes to queries."""
    levels, P, D, Q, budget, train, _ = args
    mk = lambda sdt: plan_mod.MsdaSpec(
        spatial_shapes=levels, num_heads=2, head_dim=D, num_points=P,
        num_queries=Q, train=train, vmem_budget=budget, slab_dtype=sdt)
    wide = plan_mod._heuristic_block_q(mk("float32"))
    narrow = plan_mod._heuristic_block_q(mk("bfloat16"))
    assert all(n >= w for n, w in zip(narrow, wide))


@given(spec_dims)
@settings(**SET)
def test_fusion_tier_respects_vmem_fitting_model(args):
    """The fusion tier's 'auto' decision is exactly the documented
    prefix model: ``ops.fusion_prefix`` walks k from L down until the
    packed prefix residency (+ train grad super-slab) plus one minimal
    query step's working set fits the budget — k == L fully fuses,
    2 <= k < L commits a strict prefix, k < 2 falls back to per-level.
    'on'/'off'/'prefix:k' pin the tier regardless."""
    levels, P, D, Q, budget, train, slab = args
    L = len(levels)
    mk = lambda fuse: plan_mod.MsdaSpec(
        spatial_shapes=levels, num_heads=2, head_dim=D, num_points=P,
        num_queries=Q, train=train, vmem_budget=budget, slab_dtype=slab,
        fuse_levels=fuse)
    spec = mk("auto")
    dts = plan_mod._default_slab_dtypes(spec)
    fused, prefix = plan_mod._resolve_fuse_tier(spec, dts, "pallas")
    k_model = ops.fusion_prefix(
        levels, P, D, value_itemsize=plan_mod._slab_itemsizes(dts),
        train=train, vmem_budget=spec.vmem_budget,
        accum_itemsize=spec.accum_itemsize)
    if L >= 2:
        if k_model == L:
            assert (fused, prefix) == (True, 0)  # whole pyramid
        elif k_model >= 2:
            assert (fused, prefix) == (True, k_model)  # strict tier
        else:
            assert (fused, prefix) == (False, 0)  # per-level
        # the k == L rung is the historical whole-pyramid fitting model
        fits = ops.fused_pyramid_fits(
            levels, P, D, value_itemsize=spec.slab_itemsize, train=train,
            vmem_budget=spec.vmem_budget, accum_itemsize=spec.accum_itemsize)
        assert (k_model == L) == fits
        rows = sum(ops.slab_rows(hw) for hw in levels)
        resident = rows * D * spec.slab_itemsize
        if train:
            resident += rows * D * spec.accum_itemsize
        per_q = ops.per_query_bytes(P, D, train=train,
                                    slab_itemsize=spec.slab_itemsize,
                                    levels=L)
        assert fits == (resident + 8 * per_q <= spec.vmem_budget)
        # every committed prefix actually fits its own residency model
        if 0 < k_model:
            kth = ops.fusion_prefix(
                levels[:k_model], P, D,
                value_itemsize=plan_mod._slab_itemsizes(dts[:k_model]),
                train=train, vmem_budget=spec.vmem_budget,
                accum_itemsize=spec.accum_itemsize)
            assert kth == k_model
    else:
        assert (fused, prefix) == (False, 0)  # single level: nothing to fuse
    assert plan_mod._resolve_fuse_tier(mk("on"), dts, "pallas") == (True, 0)
    assert plan_mod._resolve_fuse_tier(mk("off"), dts, "pallas") == (False, 0)
    if L >= 3:
        # a strict pin commits exactly that tier; k >= L degenerates to
        # the whole pyramid (prefix 0 == "all levels")
        assert plan_mod._resolve_fuse_tier(
            mk(f"prefix:{L - 1}"), dts, "pallas") == (True, L - 1)
    assert plan_mod._resolve_fuse_tier(
        mk(f"prefix:{L + 3}"), dts, "pallas") == (True, 0)
    # non-fusable backends never fuse, whatever the policy says
    assert plan_mod._resolve_fuse_tier(mk("on"), dts, "cpu") == (False, 0)


# --------------------------------------------------------------------------
# autotune winner cache: round-trips through XDG_CACHE_HOME, both schemas
# --------------------------------------------------------------------------

cache_entries = st.dictionaries(
    st.text(st.characters(min_codepoint=33, max_codepoint=126), min_size=1, max_size=40),
    st.one_of(
        st.lists(st.integers(8, 2048), min_size=1, max_size=5),  # legacy
        st.fixed_dictionaries(
            {
                "block_q": st.lists(st.integers(8, 2048), min_size=2, max_size=2),
                "slab_dtypes": st.lists(
                    st.sampled_from(["float32", "bfloat16"]), min_size=2, max_size=2),
            },
            # entries grew OPTIONAL fields: "sharding"/"grad_reduce"
            # (mesh-keyed race winners), "fuse_levels" / "fuse_prefix"
            # (fusion-tier race), "onehot_levels" (MXU-routing race) and
            # "sparsity"/"query_order" (pruning/Morton races) — any
            # subset must keep parsing, pre-existing entries included.
            # Keys NO build knows ("future_field"...) must ride through
            # parse -> re-persist untouched (forward compat)
            optional={
                "sharding": st.sampled_from(["1d", "2d"]),
                "fuse_levels": st.booleans(),
                "fuse_prefix": st.integers(1, 4),
                "onehot_levels": st.lists(st.booleans(), min_size=2, max_size=2),
                "grad_reduce": st.sampled_from(["ring", "psum"]),
                "sparsity": st.sampled_from(["dense", "topk"]),
                "query_order": st.sampled_from(["identity", "morton"]),
                "future_field": st.one_of(
                    st.integers(-10, 10), st.text(max_size=8),
                    st.lists(st.integers(-10, 10), max_size=3)),
                "vendor.note": st.text(max_size=8),
            },
        ),
    ),
    max_size=4,
)


@given(cache_entries)
@settings(**SET)
def test_autotune_cache_roundtrips_through_xdg_cache_home(tmp_path_factory, entries):
    """Winner caches (legacy flat lists AND the dtype-aware dict schema
    with every optional raced-axis field) survive a store/load cycle
    rooted at a tmp XDG_CACHE_HOME."""
    import os

    tmp = tmp_path_factory.mktemp("xdg")
    old_env = {k: os.environ.pop(k, None)
               for k in ("XDG_CACHE_HOME", "REPRO_MSDA_AUTOTUNE_CACHE")}
    os.environ["XDG_CACHE_HOME"] = str(tmp)
    try:
        path = plan_mod.autotune_cache_path()
        assert path.startswith(str(tmp))  # respects XDG, not ~/.cache
        plan_mod._store_autotune_cache(entries)
        assert plan_mod._load_autotune_cache() == entries
        spec = plan_mod.MsdaSpec(spatial_shapes=((8, 6), (4, 3)), num_heads=2,
                                 head_dim=8, num_points=2, num_queries=16)
        for hit in entries.values():
            parsed = plan_mod._parse_cache_entry(hit, spec)
            if isinstance(hit, dict):  # current schema always parses
                assert parsed["block_q"] == tuple(hit["block_q"])
                assert parsed["slab_dtypes"] == tuple(hit["slab_dtypes"])
                assert parsed["sharding"] == hit.get("sharding")
                assert parsed["grad_reduce"] == hit.get("grad_reduce")
                assert parsed["fuse_levels"] == hit.get("fuse_levels")
                assert parsed["fuse_prefix"] == hit.get("fuse_prefix")
                oh = hit.get("onehot_levels")
                assert parsed["onehot_levels"] == (
                    tuple(oh) if oh is not None else None)
                assert parsed["sparsity"] == hit.get("sparsity")
                assert parsed["query_order"] == hit.get("query_order")
                assert parsed["extras"] == {
                    k: hit[k] for k in ("future_field", "vendor.note")
                    if k in hit}
                # and the entry shape round-trips through the writer,
                # unknown keys included
                assert plan_mod._parse_cache_entry(
                    plan_mod._winner_entry(parsed), spec) == parsed
            elif len(hit) == spec.num_levels:  # legacy: level count must match
                assert parsed["block_q"] == tuple(hit)
                assert parsed["slab_dtypes"] == ("float32",) * 2
                assert parsed["sharding"] is None
            else:
                assert parsed is None
    finally:
        os.environ.pop("XDG_CACHE_HOME", None)
        for k, v in old_env.items():
            if v is not None:
                os.environ[k] = v


@given(dims)
@settings(**SET)
def test_grad_value_conservation(args):
    """sum over value of grad_value == sum over queries of (attn-weighted
    corner weights) * gout — with gout = ones and all-interior points the
    scatter conserves mass: sum(grad_value) == sum(attn)... == Q*B*H*D-ish.

    Concretely: d/dv sum(msda(v)) applied to constant direction =
    sum(attn * bilinear-partition-of-unity) per (b,h,d); interior points
    have partition-of-unity corners, so total == sum(attn) * D.
    """
    B, Q, H, D, P, levels, seed = args
    value, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    loc = jnp.clip(loc, 0.3, 0.7)

    g = jax.grad(
        lambda v: jnp.sum(ops.msda(v, levels, loc, attn, backend="pallas"))
    )(value)
    np.testing.assert_allclose(
        float(jnp.sum(g)), float(jnp.sum(attn)) * D, rtol=1e-3
    )
