"""Hypothesis property tests for the MSDA op's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import msda_ref

SET = dict(max_examples=15, deadline=None)


def _mk(B, Q, H, D, P, levels, seed):
    S = sum(h * w for h, w in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, Q, H, L, P, 2), minval=-0.2, maxval=1.2)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, L, P)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, L, P)
    return value, loc, attn


dims = st.tuples(
    st.integers(1, 2),        # B
    st.integers(1, 17),       # Q
    st.integers(1, 3),        # H
    st.sampled_from([4, 8]),  # D
    st.integers(1, 4),        # P
    st.sampled_from([((5, 7),), ((8, 6), (4, 3))]),
    st.integers(0, 10_000),   # seed
)


@given(dims)
@settings(**SET)
def test_kernel_equals_oracle(args):
    B, Q, H, D, P, levels, seed = args
    value, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    out = ops.msda(value, levels, loc, attn, backend="pallas")
    ref = msda_ref(value, levels, loc, attn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@given(dims, st.floats(-2.0, 2.0), st.floats(-2.0, 2.0))
@settings(**SET)
def test_linearity_in_value(args, alpha, beta):
    """msda(a*v1 + b*v2) == a*msda(v1) + b*msda(v2)."""
    B, Q, H, D, P, levels, seed = args
    v1, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    v2, _, _ = _mk(B, Q, H, D, P, levels, seed + 1)
    lhs = ops.msda(alpha * v1 + beta * v2, levels, loc, attn, backend="pallas")
    rhs = alpha * ops.msda(v1, levels, loc, attn, backend="pallas") + beta * ops.msda(
        v2, levels, loc, attn, backend="pallas"
    )
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=5e-5)


@given(dims)
@settings(**SET)
def test_constant_field_interior(args):
    """Constant value field + interior points -> exactly that constant
    (attention weights sum to 1)."""
    B, Q, H, D, P, levels, seed = args
    _, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    loc = jnp.clip(loc, 0.3, 0.7)  # safely interior
    S = sum(h * w for h, w in levels)
    value = jnp.full((B, S, H, D), 2.5)
    out = ops.msda(value, levels, loc, attn, backend="pallas")
    np.testing.assert_allclose(np.asarray(out), 2.5, atol=1e-4)


@given(dims)
@settings(**SET)
def test_attention_weight_homogeneity(args):
    """Scaling attention weights scales the output (degree-1 homogeneous)."""
    B, Q, H, D, P, levels, seed = args
    value, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    o1 = ops.msda(value, levels, loc, 3.0 * attn, backend="pallas")
    o2 = 3.0 * ops.msda(value, levels, loc, attn, backend="pallas")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=5e-5)


@given(dims)
@settings(**SET)
def test_grad_value_conservation(args):
    """sum over value of grad_value == sum over queries of (attn-weighted
    corner weights) * gout — with gout = ones and all-interior points the
    scatter conserves mass: sum(grad_value) == sum(attn)... == Q*B*H*D-ish.

    Concretely: d/dv sum(msda(v)) applied to constant direction =
    sum(attn * bilinear-partition-of-unity) per (b,h,d); interior points
    have partition-of-unity corners, so total == sum(attn) * D.
    """
    B, Q, H, D, P, levels, seed = args
    value, loc, attn = _mk(B, Q, H, D, P, levels, seed)
    loc = jnp.clip(loc, 0.3, 0.7)

    g = jax.grad(
        lambda v: jnp.sum(ops.msda(v, levels, loc, attn, backend="pallas"))
    )(value)
    np.testing.assert_allclose(
        float(jnp.sum(g)), float(jnp.sum(attn)) * D, rtol=1e-3
    )
