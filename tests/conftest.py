import os

# Tests run on the single real CPU device (the 512-device override is
# strictly dryrun.py's, per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_trees_close(a, b, atol=1e-5, rtol=1e-5):
    import jax

    for (ka, la), (kb, lb) in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        jax.tree_util.tree_flatten_with_path(b)[0],
    ):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), atol=atol, rtol=rtol,
            err_msg=f"mismatch at {ka}",
        )
