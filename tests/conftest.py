import os

# Tests run on CPU (the 512-device override is strictly dryrun.py's, per
# the assignment).  The host platform is split into 4 virtual devices so
# the distribution tests can build real 2x2 / 1x4 / 4x1 meshes and run
# shard_map + ppermute collectives for the 2D (dp x tp) sharding mode;
# everything else still executes on device 0 as before.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_trees_close(a, b, atol=1e-5, rtol=1e-5):
    import jax

    for (ka, la), (kb, lb) in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        jax.tree_util.tree_flatten_with_path(b)[0],
    ):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), atol=atol, rtol=rtol,
            err_msg=f"mismatch at {ka}",
        )
