"""MSDA Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + grads.

Every Pallas kernel cell runs in interpret mode (the kernel body
executes in Python on CPU) against ``ref.msda_ref``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import msda_grid_sample_baseline, msda_ref

CASES = [
    # (B, Q, H, D, P, levels)
    (1, 8, 1, 8, 1, ((4, 4),)),
    (2, 21, 2, 8, 3, ((10, 6), (5, 3))),
    (1, 40, 4, 16, 4, ((16, 16), (8, 8), (4, 4))),
    (3, 7, 2, 32, 2, ((9, 13),)),
    (1, 100, 8, 8, 4, ((12, 12), (6, 6))),
]


def _inputs(B, Q, H, D, P, levels, dtype=jnp.float32, seed=0):
    S = sum(h * w for h, w in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    value = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    loc = jax.random.uniform(ks[1], (B, Q, H, L, P, 2), minval=-0.3, maxval=1.3)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, L, P)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, L, P)
    gout = jax.random.normal(ks[3], (B, Q, H * D), jnp.float32)
    return value, loc, attn, gout


@pytest.mark.parametrize("case", CASES, ids=[str(c[:5]) for c in CASES])
@pytest.mark.parametrize("fuse", [True, False], ids=["fused", "unfused"])
def test_fwd_matches_oracle(case, fuse):
    B, Q, H, D, P, levels = case
    value, loc, attn, _ = _inputs(B, Q, H, D, P, levels)
    ref = msda_ref(value, levels, loc, attn)
    out = ops.msda(value, levels, loc, attn, backend="pallas",
                   fuse_gather=fuse, fuse_scatter=fuse)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_fwd_dtypes(dtype):
    B, Q, H, D, P, levels = 2, 16, 2, 8, 2, ((8, 8), (4, 4))
    value, loc, attn, _ = _inputs(B, Q, H, D, P, levels, dtype=dtype)
    ref = msda_ref(value, levels, loc, attn)
    out = ops.msda(value, levels, loc, attn, backend="pallas")
    assert out.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("train", [False, True], ids=["regather", "saved"])
@pytest.mark.parametrize("case", CASES[:3], ids=[str(c[:5]) for c in CASES[:3]])
def test_grads_match_oracle(case, train):
    B, Q, H, D, P, levels = case
    value, loc, attn, gout = _inputs(B, Q, H, D, P, levels)

    def loss_ref(v, l, a):
        return jnp.vdot(msda_ref(v, levels, l, a), gout)

    def loss_pal(v, l, a):
        return jnp.vdot(
            ops.msda(v, levels, l, a, backend="pallas", train=train), gout
        )

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(value, loc, attn)
    g_pal = jax.grad(loss_pal, argnums=(0, 1, 2))(value, loc, attn)
    for name, gr, gp in zip(("value", "loc", "attn"), g_ref, g_pal):
        np.testing.assert_allclose(
            np.asarray(gp), np.asarray(gr), atol=5e-4, rtol=5e-4,
            err_msg=f"grad_{name}",
        )


def test_unfused_scatter_matches():
    B, Q, H, D, P, levels = 2, 16, 2, 8, 2, ((8, 8),)
    value, loc, attn, gout = _inputs(B, Q, H, D, P, levels)

    def loss(v, fuse):
        return jnp.vdot(
            ops.msda(v, levels, loc, attn, backend="pallas", fuse_scatter=fuse), gout
        )

    g1 = jax.grad(lambda v: loss(v, True))(value)
    g2 = jax.grad(lambda v: loss(v, False))(value)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_baseline_equals_oracle():
    B, Q, H, D, P, levels = 2, 33, 4, 8, 3, ((14, 9), (7, 5), (3, 3))
    value, loc, attn, _ = _inputs(B, Q, H, D, P, levels)
    a = msda_ref(value, levels, loc, attn)
    b = msda_grid_sample_baseline(value, levels, loc, attn)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_all_oob_is_zero():
    B, Q, H, D, P, levels = 1, 4, 1, 8, 2, ((6, 6),)
    value, _, attn, _ = _inputs(B, Q, H, D, P, levels)
    loc = jnp.full((B, Q, H, 1, P, 2), -3.0)  # far outside
    out = ops.msda(value, levels, loc, attn, backend="pallas")
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_pixel_center_exactness():
    """Sampling exactly at pixel centers returns the pixel values."""
    H_, W_ = 5, 7
    B, Q, Hh, D, P = 1, H_ * W_, 1, 4, 1
    levels = ((H_, W_),)
    value = jax.random.normal(jax.random.PRNGKey(0), (B, H_ * W_, Hh, D))
    ys, xs = jnp.meshgrid(jnp.arange(H_), jnp.arange(W_), indexing="ij")
    loc = jnp.stack([(xs.reshape(-1) + 0.5) / W_, (ys.reshape(-1) + 0.5) / H_], -1)
    loc = loc[None, :, None, None, None, :]
    attn = jnp.ones((B, Q, Hh, 1, P))
    out = ops.msda(value, levels, loc, attn, backend="pallas")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(value[:, :, 0, :]), atol=1e-5
    )


def test_plan_blocks_adaptive():
    """Adaptive block planning: small levels get wide blocks (paper Fig. 7)."""
    shapes = ((256, 256), (16, 16))
    bq = ops.plan_blocks(shapes, 4, 32, 1000)
    assert bq[1] >= bq[0]  # smaller level -> at least as much vec-len headroom
    fixed = ops.plan_blocks(shapes, 4, 32, 1000, adaptive=False)
    assert all(b == 8 for b in fixed)


def test_block_q_invariance():
    """Output must not depend on the block size (pure tiling)."""
    B, Q, H, D, P, levels = 1, 24, 2, 8, 2, ((8, 8), (4, 4))
    value, loc, attn, _ = _inputs(B, Q, H, D, P, levels)
    o1 = ops.msda(value, levels, loc, attn, backend="pallas", block_q=(8, 8))
    o2 = ops.msda(value, levels, loc, attn, backend="pallas", block_q=(24, 16))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@pytest.mark.parametrize("case", CASES[:3], ids=[str(c[:5]) for c in CASES[:3]])
def test_onehot_mxu_path_matches(case):
    """Beyond-paper MXU one-hot gather/scatter == oracle (fwd + grads)."""
    B, Q, H, D, P, levels = case
    value, loc, attn, gout = _inputs(B, Q, H, D, P, levels)
    ref = msda_ref(value, levels, loc, attn)
    out = ops.msda(value, levels, loc, attn, backend="pallas",
                   onehot_small_levels=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    def loss(v):
        return jnp.vdot(
            ops.msda(v, levels, loc, attn, backend="pallas",
                     onehot_small_levels=True), gout)

    def loss_ref(v):
        return jnp.vdot(msda_ref(v, levels, loc, attn), gout)

    g = jax.grad(loss)(value)
    gr = jax.grad(loss_ref)(value)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=5e-4)


def test_onehot_plan_thresholds():
    plan = ops.plan_onehot(((256, 256), (16, 16), (4, 4)))
    assert plan == (False, True, True)  # big levels stay on the VPU gather
