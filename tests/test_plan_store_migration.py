"""PlanStore schema migration: every historical version still restores.

``tests/data/plan_store_v{1..5}.json`` are frozen stores as the v1-v5
schemas wrote them (v1 flat-list winners, v2 per-level slab dtypes, v3
fusion + one-hot routing decisions, v4 heuristic entries, v5 sparsity
axes + a newer-build extra field).  Each must restore on the current
build with ZERO autotune timing runs and re-save as a version-6 store
without dropping any winner decision — the compatibility promise the
version-history comment in ``repro/serving/persistence.py`` makes.
"""
import json
import os

import pytest

from repro.kernels import plan as plan_mod
from repro.serving import persistence

DATA = os.path.join(os.path.dirname(__file__), "data")


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    plan_mod.clear_plans()
    plan_mod.reset_autotune_stats()
    yield
    plan_mod.clear_plans()


def _fixture(version):
    return os.path.join(DATA, f"plan_store_v{version}.json")


def _winner_of(path):
    with open(path) as f:
        data = json.load(f)
    entry = data["entries"][0]
    return entry.get("winner"), entry


@pytest.mark.parametrize("version", [1, 2, 3, 4, 5])
def test_historic_store_restores_with_zero_races(version, tmp_path):
    report = persistence.PlanStore(_fixture(version)).restore()
    assert not report.skipped, report.skipped
    assert len(report.plans) == 1
    assert report.describe_mismatches == []
    assert plan_mod.autotune_stats()["raced"] == 0, \
        f"v{version} restore ran a timing race"
    winner, entry = _winner_of(_fixture(version))
    assert report.seeded_winners == (1 if winner is not None else 0)

    # the restored plan carries the stored decisions, not re-derived ones
    plan = report.plans[0]
    assert plan.backend == entry["backend"]
    if isinstance(winner, list):  # v1 flat block_q list
        assert list(plan.tuning.block_q) == winner
    elif isinstance(winner, dict):
        assert list(plan.tuning.block_q) == winner["block_q"]
        if "slab_dtypes" in winner:
            assert list(plan.tuning.slab_dtypes) == winner["slab_dtypes"]
        if "fuse_levels" in winner:
            assert plan.fused == winner["fuse_levels"]
        if "onehot_levels" in winner:
            assert list(plan.tuning.onehot_levels) == winner["onehot_levels"]
        if "sparsity" in winner:
            assert plan.tuning.sparsity == winner["sparsity"]

    # re-save: the store comes out at the CURRENT version with every
    # winner decision intact (the upgrade path a rolling fleet follows)
    out = persistence.PlanStore(str(tmp_path / "resaved.json"))
    assert out.save_plans(report.plans) == 1
    with open(out.path) as f:
        resaved = json.load(f)
    assert resaved["version"] == persistence.PLAN_STORE_VERSION
    if winner is not None:
        re_winner = resaved["entries"][0]["winner"]
        if isinstance(winner, list):
            assert re_winner["block_q"] == winner
        else:
            for field in ("block_q", "slab_dtypes", "fuse_levels",
                          "onehot_levels", "sparsity"):
                if field in winner:
                    assert re_winner[field] == winner[field], field
        # pre-v6 winners never grow a fuse_prefix: absent keeps meaning
        # "fuse everything fuse_levels says to"
        assert "fuse_prefix" not in re_winner

    # the resaved v6 store round-trips again, still race-free
    plan_mod.clear_plans()
    os.environ["REPRO_MSDA_AUTOTUNE_CACHE"] = str(tmp_path / "autotune2.json")
    plan_mod.reset_autotune_stats()
    again = persistence.PlanStore(out.path).restore()
    assert len(again.plans) == 1 and not again.skipped
    assert plan_mod.autotune_stats()["raced"] == 0
    assert (persistence._norm_describe(again.plans[0].describe())
            == persistence._norm_describe(plan.describe()))


def test_newer_build_extras_survive_the_winner_cache(tmp_path):
    """The v5 fixture's winner carries a field only a newer build knows
    (``fleet_epoch``) — it must ride through seeding and be served back
    by the winner cache untouched, per the extras contract."""
    report = persistence.PlanStore(_fixture(5)).restore()
    assert len(report.plans) == 1
    plan = report.plans[0]
    cached = plan_mod.get_autotune_winner(plan.spec, plan.backend)
    assert cached is not None and cached.get("fleet_epoch") == 3
