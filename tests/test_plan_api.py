"""Plan/execute API: spec -> plan -> execute, registry, caches, shim parity."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, registry
from repro.kernels import plan as plan_mod
from repro.kernels.plan import MsdaSpec, msda_plan
from repro.kernels.ref import msda_ref

LEVELS = ((10, 6), (5, 3))


def _inputs(B=2, Q=21, H=2, D=8, P=3, levels=LEVELS, dtype=jnp.float32, seed=0):
    S = sum(h * w for h, w in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    value = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    loc = jax.random.uniform(ks[1], (B, Q, H, L, P, 2), minval=-0.2, maxval=1.2)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, L, P)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, L, P)
    return value, loc, attn


def _spec(value, loc, **kw):
    B, S, H, D = value.shape
    Q, P = loc.shape[1], loc.shape[4]
    return MsdaSpec(spatial_shapes=LEVELS, num_heads=H, head_dim=D,
                    num_points=P, num_queries=Q, dtype=str(value.dtype), **kw)


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    plan_mod.clear_plans()
    yield
    plan_mod.clear_plans()


# --------------------------------------------------------------------------
# shim vs plan equivalence
# --------------------------------------------------------------------------


def test_shim_bit_identical_to_plan_ref_backend():
    value, loc, attn = _inputs()
    out_shim = ops.msda(value, LEVELS, loc, attn, backend="ref")
    plan = msda_plan(_spec(value, loc), backend="ref")
    out_plan = plan(value, loc, attn)
    assert jnp.array_equal(out_shim, out_plan)  # bit-identical, same path


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_shim_matches_plan_pallas_interpret(dtype):
    value, loc, attn = _inputs(dtype=dtype)
    out_shim = ops.msda(value, LEVELS, loc, attn, backend="pallas")
    plan = msda_plan(_spec(value, loc), backend="pallas")
    out_plan = plan(value, loc, attn)
    assert out_plan.dtype == dtype
    np.testing.assert_array_equal(np.asarray(out_shim), np.asarray(out_plan))
    ref = msda_ref(value, LEVELS, loc, attn)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out_plan, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_plan_q_not_multiple_of_block_q():
    # Q=21 with forced block_q=8: padding path (qpad=24) must be exact
    value, loc, attn = _inputs(Q=21)
    plan = msda_plan(_spec(value, loc), backend="pallas", block_q=(8, 8))
    out = plan(value, loc, attn)
    ref = msda_ref(value, LEVELS, loc, attn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_plan_grads_match_oracle_train_mode():
    value, loc, attn = _inputs()
    plan = msda_plan(_spec(value, loc, train=True), backend="pallas")
    g = jax.grad(lambda v, l, a: jnp.sum(plan(v, l, a) ** 2), argnums=(0, 1, 2))(
        value, loc, attn)
    gr = jax.grad(lambda v, l, a: jnp.sum(msda_ref(v, LEVELS, l, a) ** 2),
                  argnums=(0, 1, 2))(value, loc, attn)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=5e-4)


def test_plan_shape_validation():
    value, loc, attn = _inputs()
    plan = msda_plan(_spec(value, loc), backend="ref")
    with pytest.raises(ValueError, match="does not match plan spec"):
        plan(value[:, :-1], loc, attn)
    with pytest.raises(ValueError, match="!= spec Q"):
        plan(value, loc[:, :-1], attn)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------


def test_registry_builtins_present():
    assert "ref" in registry.list_backends()
    assert "pallas" in registry.list_backends()


def test_registry_unknown_backend_errors():
    with pytest.raises(registry.UnknownBackendError, match="no-such-npu"):
        registry.get_backend("no-such-npu")
    value, loc, attn = _inputs()
    with pytest.raises(ValueError):
        msda_plan(_spec(value, loc), backend="no-such-npu")


def test_registry_register_and_execute_custom_backend():
    calls = []

    def builder(spec, tuning):
        calls.append(spec)

        def run(value, loc, attn):
            from repro.kernels.ref import msda_ref as oracle

            return oracle(value, spec.spatial_shapes, loc, attn)

        return run

    registry.register_backend("test-oracle", builder)
    try:
        value, loc, attn = _inputs()
        plan = msda_plan(_spec(value, loc), backend="test-oracle")
        assert plan.backend == "test-oracle"
        out = plan(value, loc, attn)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(msda_ref(value, LEVELS, loc, attn)), atol=1e-6)
        assert len(calls) == 1  # builder ran exactly once (at plan time)
        plan(value, loc, attn)
        assert len(calls) == 1
    finally:
        registry.unregister_backend("test-oracle")


def test_registry_duplicate_and_reserved_names():
    def builder(spec, tuning):
        return lambda *a: None

    registry.register_backend("dup-backend", builder)
    try:
        with pytest.raises(ValueError, match="already registered"):
            registry.register_backend("dup-backend", builder)
        registry.register_backend("dup-backend", builder, overwrite=True)
    finally:
        registry.unregister_backend("dup-backend")
    with pytest.raises(ValueError, match="reserved"):
        registry.register_backend("auto", builder)


# --------------------------------------------------------------------------
# plan cache behaviour
# --------------------------------------------------------------------------


def test_same_spec_returns_same_plan_object():
    value, loc, attn = _inputs()
    p1 = msda_plan(_spec(value, loc), backend="pallas")
    p2 = msda_plan(_spec(value, loc), backend="pallas")
    assert p1 is p2
    info = plan_mod.plan_cache_info()
    assert info["hits"] >= 1 and info["size"] == 1
    plan_mod.clear_plans()
    p3 = msda_plan(_spec(value, loc), backend="pallas")
    assert p3 is not p1


def test_plan_blocks_not_reinvoked_on_repeat_calls(monkeypatch):
    """Acceptance: repeated identical-spec calls never re-run block planning."""
    value, loc, attn = _inputs()
    counter = {"n": 0}
    real = ops.plan_blocks

    def counting(*a, **kw):
        counter["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(ops, "plan_blocks", counting)
    ops.msda(value, LEVELS, loc, attn, backend="pallas")
    assert counter["n"] == 1  # planned once
    ops.msda(value, LEVELS, loc, attn, backend="pallas")
    ops.msda(value, LEVELS, loc, attn, backend="pallas")
    assert counter["n"] == 1  # cache hits: no re-planning


def test_plan_cache_eviction_bounded():
    value, loc, attn = _inputs()
    old = plan_mod.plan_cache_info()["maxsize"]
    plan_mod.configure_plan_cache(2)
    try:
        for q in (8, 16, 24):
            v, l, a = _inputs(Q=q)
            msda_plan(_spec(v, l), backend="ref")
        assert plan_mod.plan_cache_info()["size"] == 2  # LRU evicted
    finally:
        plan_mod.configure_plan_cache(old)


def test_deprecated_tuning_kwargs_warn():
    value, loc, attn = _inputs()
    ops._WARNED_KWARGS.clear()
    with pytest.warns(DeprecationWarning, match="fuse_gather"):
        ops.msda(value, LEVELS, loc, attn, backend="pallas", fuse_gather=False)


# --------------------------------------------------------------------------
# spec: VMEM budget field (per-device default, overridable)
# --------------------------------------------------------------------------


def test_vmem_budget_defaults_per_device_kind():
    assert plan_mod.default_vmem_budget("TPU v3") == 16 * 2**20
    assert plan_mod.default_vmem_budget("TPU v5p") == 64 * 2**20
    assert plan_mod.default_vmem_budget("cpu") == 32 * 2**20
    spec = MsdaSpec(spatial_shapes=LEVELS, num_heads=2, head_dim=8,
                    num_points=2, num_queries=64)
    assert spec.vmem_budget == plan_mod.default_vmem_budget()


def test_vmem_budget_drives_block_plan():
    big_level = ((64, 64),)
    mk = lambda budget: MsdaSpec(
        spatial_shapes=big_level, num_heads=2, head_dim=32, num_points=4,
        num_queries=4096, vmem_budget=budget)
    small = msda_plan(mk(4 * 2**20), backend="pallas").block_q
    large = msda_plan(mk(256 * 2**20), backend="pallas").block_q
    assert large[0] > small[0]  # more VMEM -> wider blocks (longer vectors)


# --------------------------------------------------------------------------
# inspectability
# --------------------------------------------------------------------------


def test_describe_reports_per_level_decisions():
    value, loc, attn = _inputs()
    plan = msda_plan(_spec(value, loc, onehot_small_levels=True), backend="pallas")
    report = plan.level_report()
    assert len(report) == len(LEVELS)
    assert all(r["gather"] == "mxu-onehot" for r in report)  # tiny levels
    text = plan.describe()
    assert "backend=pallas" in text and "block_q" in text and "vmem" in text
    for r in report:
        assert r["slab_bytes"] > 0 and r["block_q"] >= 8


# --------------------------------------------------------------------------
# dtype policy: the second planned axis (slab dtype + widened accumulator)
# --------------------------------------------------------------------------


def test_autotune_inputs_honor_spec_dtype():
    """Regression: _autotune_inputs used to build fp32 operands regardless
    of spec.dtype, so autotune timed (and cached winners for) a different
    program than real bf16 calls execute."""
    for dt in ("float32", "bfloat16"):
        spec = MsdaSpec(spatial_shapes=LEVELS, num_heads=2, head_dim=8,
                        num_points=3, num_queries=16, dtype=dt)
        value, loc, attn = plan_mod._autotune_inputs(spec)
        assert str(value.dtype) == dt
        assert str(loc.dtype) == dt
        assert str(attn.dtype) == dt
        assert value.shape == (1, spec.total_pixels, 2, 8)
        assert loc.shape == (1, 16, 2, spec.num_levels, 3, 2)


def test_dtype_policy_resolution():
    assert plan_mod.resolve_dtype_policy("follow") == ("", "float32")
    assert plan_mod.resolve_dtype_policy("bfloat16") == ("bfloat16", "float32")
    assert plan_mod.resolve_dtype_policy("auto") == ("auto", "float32")
    with pytest.raises(ValueError, match="dtype policy"):
        plan_mod.resolve_dtype_policy("float8")


def test_bf16_slab_widens_blocks_and_is_reported():
    """bf16 slabs halve residency -> heuristic blocks can only widen; the
    committed variant must show up in describe()/level_report()."""
    big = ((64, 64),)
    mk = lambda sdt: MsdaSpec(spatial_shapes=big, num_heads=2, head_dim=32,
                              num_points=4, num_queries=4096,
                              vmem_budget=4 * 2**20, slab_dtype=sdt)
    p32 = msda_plan(mk("float32"), backend="pallas")
    p16 = msda_plan(mk("bfloat16"), backend="pallas")
    assert p16.block_q[0] >= p32.block_q[0]
    assert p16.level_report()[0]["slab_dtype"] == "bfloat16"
    assert p16.level_report()[0]["slab_bytes"] < p32.level_report()[0]["slab_bytes"]
    assert "bfloat16" in p16.describe() and "accum=float32" in p16.describe()


def test_spec_normalises_policy_dtypes():
    spec = MsdaSpec(spatial_shapes=LEVELS, num_heads=2, head_dim=8,
                    num_points=2, num_queries=16, slab_dtype=jnp.bfloat16,
                    accum_dtype="float32")
    assert spec.slab_dtype == "bfloat16" and spec.accum_dtype == "float32"
    assert spec.resolved_slab_dtype() == "bfloat16"
    auto = MsdaSpec(spatial_shapes=LEVELS, num_heads=2, head_dim=8,
                    num_points=2, num_queries=16, slab_dtype="auto")
    assert auto.resolved_slab_dtype() == "float32"  # heuristic fallback


# --------------------------------------------------------------------------
# autotune (slow: times real candidate executions)
# --------------------------------------------------------------------------


@pytest.mark.slow
def test_autotune_picks_candidate_and_persists(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    value, loc, attn = _inputs(Q=32, levels=((6, 6),))
    spec = MsdaSpec(spatial_shapes=((6, 6),), num_heads=2, head_dim=8,
                    num_points=3, num_queries=32)
    plan = msda_plan(spec, backend="pallas", tune="autotune")
    assert plan.tuning.source == "autotune"
    assert (tmp_path / "tune.json").exists()
    out = plan(value, loc, attn)
    ref = msda_ref(value, ((6, 6),), loc, attn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # a fresh plan cache must hit the on-disk winner (no re-timing)
    plan_mod.clear_plans()
    plan2 = msda_plan(spec, backend="pallas", tune="autotune")
    assert plan2.tuning.source == "autotune-cache"
    assert plan2.block_q == plan.block_q


@pytest.mark.slow
def test_autotune_races_slab_dtypes_and_persists(tmp_path, monkeypatch):
    """Under slab_dtype='auto', autotune races fp32 vs bf16 per level and
    the winner (whichever side) round-trips through the on-disk cache."""
    import json

    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    spec = MsdaSpec(spatial_shapes=((6, 6), (3, 3)), num_heads=2, head_dim=8,
                    num_points=3, num_queries=32, slab_dtype="auto")
    plan = msda_plan(spec, backend="pallas", tune="autotune")
    assert plan.tuning.source == "autotune"
    assert len(plan.tuning.slab_dtypes) == 2
    assert all(d in ("float32", "bfloat16") for d in plan.tuning.slab_dtypes)
    entry = next(iter(json.load(open(tmp_path / "tune.json")).values()))
    assert entry == {"block_q": list(plan.block_q),
                     "slab_dtypes": list(plan.tuning.slab_dtypes),
                     "fuse_levels": plan.fused}
    plan_mod.clear_plans()
    plan2 = msda_plan(spec, backend="pallas", tune="autotune")
    assert plan2.tuning.source == "autotune-cache"
    assert plan2.tuning.slab_dtypes == plan.tuning.slab_dtypes


def test_autotune_ref_backend_falls_back_to_heuristic(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    value, loc, attn = _inputs()
    plan = msda_plan(_spec(value, loc), backend="ref", tune="autotune")
    assert plan.tuning.source == "heuristic"  # no blocks to tune in XLA


def test_unknown_tune_mode_errors():
    value, loc, attn = _inputs()
    with pytest.raises(ValueError, match="tune"):
        msda_plan(_spec(value, loc), tune="genetic")
