"""int8 KV cache: mechanism + end-to-end accuracy on trained weights."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import attention, lm


def test_quantize_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 16)) * 3.0
    q, s = attention._quantize(x)
    err = jnp.abs(q.astype(jnp.float32) * s - x)
    assert q.dtype == jnp.int8
    assert float(err.max()) <= float(s.max()) * 0.51


def test_prefill_write_and_dequant():
    cfg = replace(reduced(get_config("llama3-8b")), kv_quant=True)
    cache = attention.init_kv_cache(cfg, 2, 16, jnp.float32)
    assert cache.k.dtype == jnp.int8
    assert cache.k_scale.shape == (2, 16, cfg.num_kv_heads, 1)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.num_kv_heads, cfg.head_dim))
    c2 = attention._bulk_write(cache, k, k, jnp.full((2,), 8, jnp.int32), at_start=True)
    kk, _ = attention.cache_kv(c2, jnp.float32)
    np.testing.assert_allclose(np.asarray(kk[:, :8]), np.asarray(k), atol=0.03)
    np.testing.assert_allclose(np.asarray(kk[:, 8:]), 0.0)


def test_trained_model_greedy_agreement():
    """On a trained (confident) model, int8-KV greedy decode matches bf16."""
    from repro.data.pipeline import DataConfig, Pipeline
    from repro.train import loop as train_loop, state as train_state

    cfg = reduced(get_config("llama3-8b"))
    pipe = Pipeline(DataConfig(global_batch=8, seq_len=64, vocab_size=cfg.vocab_size))
    step = jax.jit(train_loop.make_train_step(cfg, peak_lr=3e-3, warmup_steps=4,
                                              total_steps=40))
    st = train_state.init_state(jax.random.PRNGKey(0), cfg)
    for s in range(40):
        st, m = step(st, {k: jnp.asarray(v) for k, v in pipe.batch(s).items()})
    assert float(m["loss"]) < 2.5
    params = st.params
    cfgq = replace(cfg, kv_quant=True)
    tokens = jnp.asarray(pipe.batch(100)["tokens"][:2, :12])
    _, cache = lm.lm_prefill(params, cfg, tokens[:, :8], capacity=64)
    _, cacheq = lm.lm_prefill(params, cfgq, tokens[:, :8], capacity=64)
    for t in range(8, 12):
        ld, cache = lm.lm_decode_step(params, cfg, cache, tokens[:, t])
        ldq, cacheq = lm.lm_decode_step(params, cfgq, cacheq, tokens[:, t])
        assert (ld.argmax(-1) == ldq.argmax(-1)).all()
