"""Optimizer, schedule, gradient compression, data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, Pipeline
from repro.optim import adamw, grad_compression as gc, schedule


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw.init_adamw(params)
    target = jnp.array([1.0, 2.0, 3.0])
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw.adamw_update(
            g, state, params, lr=0.05, weight_decay=0.0
        )
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    state = adamw.init_adamw(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, gnorm = adamw.adamw_update(g, state, params, lr=0.1, clip_norm=1.0)
    assert float(gnorm) == 200.0  # pre-clip norm reported


def test_weight_decay_only_matrices():
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = adamw.init_adamw(params)
    g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw.adamw_update(g, state, params, lr=0.1, weight_decay=0.5)
    assert float(p2["w"][0, 0]) < 1.0  # decayed
    assert float(p2["b"][0]) == 1.0  # not decayed


def test_schedule_warmup_cosine():
    lr0 = schedule.warmup_cosine(jnp.asarray(0), peak_lr=1.0, warmup_steps=10, total_steps=100)
    lrp = schedule.warmup_cosine(jnp.asarray(10), peak_lr=1.0, warmup_steps=10, total_steps=100)
    lre = schedule.warmup_cosine(jnp.asarray(100), peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr0) == 0.0 and abs(float(lrp) - 1.0) < 1e-6 and float(lre) <= 0.11


def test_compression_roundtrip_error_feedback():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    q, s, err = gc.compress(x)
    y = gc.decompress(q, s)
    np.testing.assert_allclose(np.asarray(y + err), np.asarray(x), atol=1e-6)
    assert q.dtype == jnp.int8
    # quantization error bounded by scale/2
    assert float(jnp.abs(err).max()) <= float(s) * 0.5 + 1e-7


def test_compression_error_feedback_accumulates_unbiased():
    """With error feedback, the long-run average of decompressed grads
    approaches the true gradient (residual stays bounded)."""
    g = jnp.full((64,), 0.013)
    err = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for _ in range(50):
        q, s, err = gc.compress(g + err)
        total = total + gc.decompress(q, s)
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g), atol=1e-4)


def test_pipeline_determinism_and_restart():
    cfg = DataConfig(global_batch=4, seq_len=16, vocab_size=97, seed=3)
    p1, p2 = Pipeline(cfg), Pipeline(cfg)
    for step in (0, 5, 17):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["targets"], b2["targets"])
    # shifted-by-one relation
    b = p1.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_pipeline_learnable_structure():
    """>=90% of transitions follow the fixed affine rule."""
    cfg = DataConfig(global_batch=8, seq_len=128, vocab_size=101, seed=0)
    b = Pipeline(cfg).batch(0)
    t, tgt = b["tokens"], b["targets"]
    rng = np.random.default_rng(0)
    a = int(rng.integers(1, 97))
    bb = int(rng.integers(0, 101))
    match = ((a * t + bb) % 101 == tgt).mean()
    assert match > 0.9


def test_pipeline_prefetch_iterator():
    cfg = DataConfig(global_batch=2, seq_len=8, vocab_size=31, seed=1)
    it = Pipeline(cfg).iterate(start_step=0)
    batches = [next(it) for _ in range(3)]
    ref = Pipeline(cfg)
    for i, b in enumerate(batches):
        np.testing.assert_array_equal(b["tokens"], ref.batch(i)["tokens"])


def test_tokenizer_roundtrip():
    from repro.data import tokenizer

    s = "hello xMSDA — तपु 123"
    assert tokenizer.decode(tokenizer.encode(s)) == s
