"""Hypothesis property tests for flash attention (GQA-native, chunked)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import flash

SET = dict(max_examples=12, deadline=None)


def _naive(q, k, v, causal, window, q_offset, valid=None):
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q * hd ** -0.5, kk).astype(jnp.float32)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    m = jnp.zeros((Sq, k.shape[1]))
    if causal:
        m = jnp.where(kpos > qpos, -1e30, m)
    if window:
        m = jnp.where(kpos <= qpos - window, -1e30, m)
    s = s + m[None, None]
    if valid is not None:
        s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vv)


cases = st.tuples(
    st.integers(1, 2),            # B
    st.integers(1, 37),           # Sq
    st.integers(1, 41),           # Sk (cross-attention allowed)
    st.sampled_from([(4, 1), (4, 2), (4, 4), (6, 3)]),  # (H, Kv)
    st.sampled_from([4, 8]),      # hd
    st.booleans(),                # causal
    st.sampled_from([0, 3]),      # window
    st.sampled_from([1, 4, 16]),  # kv_chunk
    st.integers(0, 5000),         # seed
)


@given(cases)
@settings(**SET)
def test_flash_equals_naive(args):
    B, Sq, Sk, (H, Kv), hd, causal, window, kv_chunk, seed = args
    if causal or window:
        Sk = Sq  # masks assume aligned positions for self-attention
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, Sk, Kv, hd))
    v = jax.random.normal(ks[2], (B, Sk, Kv, hd))
    out = flash.flash_attend(q, k, v, None, causal, window, 0, kv_chunk)
    ref = _naive(q, k, v, causal, window, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@given(cases)
@settings(**SET)
def test_flash_grads_equal_naive(args):
    B, Sq, Sk, (H, Kv), hd, causal, window, kv_chunk, seed = args
    if causal or window:
        Sk = Sq
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, Sk, Kv, hd))
    v = jax.random.normal(ks[2], (B, Sk, Kv, hd))
    g = jax.random.normal(ks[3], (B, Sq, H, hd))

    def lf(q, k, v):
        return jnp.vdot(flash.flash_attend(q, k, v, None, causal, window, 0, kv_chunk), g)

    def lr(q, k, v):
        return jnp.vdot(_naive(q, k, v, causal, window, 0), g)

    gf = jax.grad(lf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gf, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4, err_msg=f"d{name}"
        )


@given(st.integers(0, 1000), st.sampled_from([1, 7, 16]))
@settings(max_examples=10, deadline=None)
def test_flash_valid_mask_decode(seed, kv_chunk):
    """Per-key validity masks (decode caches) match masked naive attention."""
    B, Sk, H, Kv, hd = 2, 19, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, Sk, Kv, hd))
    v = jax.random.normal(ks[2], (B, Sk, Kv, hd))
    nvalid = jax.random.randint(ks[3], (B,), 1, Sk + 1)
    valid = jnp.arange(Sk)[None, :] < nvalid[:, None]
    out = flash.flash_attend(q, k, v, valid, False, 0, 0, kv_chunk)
    ref = _naive(q, k, v, False, 0, 0, valid=valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@given(st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_flash_quant_decode_tracks_fp(seed):
    """int8-cache decode stays within quantisation error of fp attention."""
    from repro.models import attention

    B, Sk, H, Kv, hd = 2, 23, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, Sk, Kv, hd))
    v = jax.random.normal(ks[2], (B, Sk, Kv, hd))
    kq, ksc = attention._quantize(k)
    vq, vsc = attention._quantize(v)
    out_q = flash.flash_decode_quant(q, kq, vq, ksc, vsc, None, kv_chunk=8)
    ref = _naive(q, k, v, False, 0, 0)
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(ref), atol=0.05)


def test_q_chunk_invariance():
    """Tiling must not change results (q chunked at 2048 internally)."""
    B, S, H, Kv, hd = 1, 2049, 2, 1, 8  # crosses the q-tile boundary
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Kv, hd))
    v = jax.random.normal(ks[2], (B, S, Kv, hd))
    out = flash.flash_attend(q, k, v, None, True, 0, 0, 512)
    ref = _naive(q, k, v, True, 0, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
