"""HLO walker: trip-count multiplication must recover true FLOPs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as ha


def test_scan_flops_multiplied():
    """A scan of N matmuls must count N * flops(one matmul)."""
    N, M = 7, 64
    w = jnp.ones((N, M, M))

    def f(x, w):
        def step(c, wi):
            return c @ wi, None

        y, _ = jax.lax.scan(step, x, w)
        return y

    x = jnp.ones((M, M))
    compiled = jax.jit(f).lower(x, w).compile()
    res = ha.analyze(compiled.as_text())
    expect = N * 2 * M * M * M
    # XLA may rearrange but dot flops should match within 2x
    assert expect * 0.5 <= res["flops"] <= expect * 2.01, (res["flops"], expect)


def test_plain_matmul_flops_exact():
    M, K, Nn = 32, 48, 64
    a = jnp.ones((M, K))
    b = jnp.ones((K, Nn))
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    res = ha.analyze(compiled.as_text())
    assert abs(res["flops"] - 2 * M * K * Nn) / (2 * M * K * Nn) < 0.01


def test_nested_scan_multiplies():
    N1, N2, M = 3, 5, 32

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ jnp.eye(M), None

            c2, _ = jax.lax.scan(inner, c, None, length=N2)
            return c2, None

        y, _ = jax.lax.scan(outer, x, None, length=N1)
        return y

    compiled = jax.jit(f).lower(jnp.ones((M, M))).compile()
    res = ha.analyze(compiled.as_text())
    expect = N1 * N2 * 2 * M ** 3
    assert expect * 0.5 <= res["flops"] <= expect * 2.01


def test_memory_model_nonzero_and_bounded():
    x = jnp.ones((256, 256))
    compiled = jax.jit(lambda x: jnp.tanh(x) + 1.0).lower(x).compile()
    res = ha.analyze(compiled.as_text())
    b = 256 * 256 * 4
    assert b <= res["mem_bytes"] <= 10 * b
