"""Train-step factory: microbatch equivalence + convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.train import loop as train_loop, state as train_state


def test_microbatch_equivalence():
    """num_microbatches=4 must produce the same update as 1 (mean grads)."""
    cfg = reduced(get_config("stablelm-1.6b"))
    state = train_state.init_state(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size),
    }
    batch["targets"] = jnp.roll(batch["tokens"], -1, axis=1)
    s1 = jax.jit(train_loop.make_train_step(cfg, num_microbatches=1, remat=False))
    s4 = jax.jit(train_loop.make_train_step(cfg, num_microbatches=4, remat=False))
    n1, m1 = s1(state, batch)
    n4, m4 = s4(state, batch)
    # losses are means over the same tokens
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for (p1, l1), (p4, l4) in zip(
        jax.tree_util.tree_flatten_with_path(n1.params)[0],
        jax.tree_util.tree_flatten_with_path(n4.params)[0],
    ):
        np.testing.assert_allclose(
            np.asarray(l1), np.asarray(l4), atol=2e-5, err_msg=str(p1)
        )


def test_loss_decreases():
    cfg = reduced(get_config("llama3-8b"))
    pipe = Pipeline(DataConfig(global_batch=4, seq_len=32, vocab_size=cfg.vocab_size))
    step_fn = jax.jit(train_loop.make_train_step(
        cfg, peak_lr=3e-3, warmup_steps=3, total_steps=30, remat=False
    ))
    state = train_state.init_state(jax.random.PRNGKey(0), cfg)
    losses = []
    for s in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 1.0, losses


def test_remat_matches_no_remat():
    cfg = reduced(get_config("stablelm-1.6b"))
    state = train_state.init_state(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    lf = train_state.loss_fn(cfg)
    g1 = jax.grad(lambda p: lf(p, batch, remat=False))(state.params)
    g2 = jax.grad(lambda p: lf(p, batch, remat=True))(state.params)
    for (pa, l1), (_, l2) in zip(
        jax.tree_util.tree_flatten_with_path(g1)[0],
        jax.tree_util.tree_flatten_with_path(g2)[0],
    ):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4,
                                   err_msg=str(pa))
