"""Checkpoint manager + fault-tolerance runtime tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.runtime import elastic, fault_tolerance as ft


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8)), "b": jnp.zeros(8)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    s = _state()
    ckpt.save(s, str(tmp_path), 7)
    r = ckpt.restore(str(tmp_path), s)
    for (pa, la), (pb, lb) in zip(
        jax.tree_util.tree_flatten_with_path(s)[0],
        jax.tree_util.tree_flatten_with_path(r)[0],
    ):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_checkpoint_latest_and_gc(tmp_path):
    s = _state()
    for step in (1, 2, 3, 4, 5):
        ckpt.save(s, str(tmp_path), step, keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir must not be treated as a checkpoint."""
    s = _state()
    ckpt.save(s, str(tmp_path), 3)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_checkpoint_async(tmp_path):
    s = _state()
    t = ckpt.save_async(s, str(tmp_path), 11)
    t.join(timeout=30)
    assert ckpt.latest_step(str(tmp_path)) == 11


def test_elastic_restore_resharded(tmp_path):
    """Restore onto a different (1-device) mesh with NamedSharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    s = _state()
    ckpt.save(s, str(tmp_path), 1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    r = ckpt.restore(str(tmp_path), s, shardings=sh)
    assert r["params"]["w"].sharding.mesh.shape == {"data": 1, "model": 1}


def test_heartbeat_monitor():
    hb = ft.HeartbeatMonitor(["w0", "w1"], timeout_s=0.05)
    hb.beat("w0")
    time.sleep(0.08)
    hb.beat("w1")
    assert hb.dead_workers() == {"w0"}


def test_straggler_detector():
    sd = ft.StragglerDetector([f"w{i}" for i in range(8)], min_steps=3)
    for step in range(5):
        for i in range(8):
            sd.record(f"w{i}", 1.0 + (3.0 if i == 5 else 0.0) + 0.01 * step)
    assert sd.stragglers() == {"w5"}


def test_straggler_no_false_positive():
    sd = ft.StragglerDetector([f"w{i}" for i in range(8)], min_steps=3)
    rng = np.random.default_rng(0)
    for _ in range(10):
        for i in range(8):
            sd.record(f"w{i}", 1.0 + rng.normal() * 0.02)
    assert sd.stragglers() == set()


def test_run_with_restarts_resumes_from_checkpoint(tmp_path):
    """Injected crash at step 20 -> restore from the step-10 checkpoint;
    the trajectory (deterministic data) completes to 50."""
    trained = []
    saved = {"step": 0}

    def train_some(start, n):
        for s in range(start, start + n):
            trained.append(s)
        return start + n

    def save(step):
        saved["step"] = step

    def restore():
        return saved["step"]

    out = ft.run_with_restarts(
        train_some_steps=train_some,
        save_ckpt=save,
        restore_ckpt=restore,
        total_steps=50,
        ckpt_every=10,
        failure_at={20: ft.FailureEvent(step=20, kind="crash", workers={"h3"})},
    )
    assert out["final_step"] == 50
    assert out["restarts"] == 1
    # steps 20..29 were re-trained after restore (deterministic replay)
    assert trained.count(25) == 1 and trained.count(5) == 1


def test_elastic_mesh_proposal():
    shape, axes = elastic.propose_mesh_shape(512, preferred_model=16, want_pod_axis=True)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    shape, axes = elastic.propose_mesh_shape(448, preferred_model=16)  # lost a pod slice
    assert shape == (28, 16)
    shape, axes = elastic.propose_mesh_shape(24, preferred_model=16)
    assert shape[0] * shape[1] == 24  # degrade model axis to keep all chips


def test_end_to_end_restart_with_real_checkpoints(tmp_path):
    """Real train steps + real checkpoints + injected failure."""
    from repro.configs.base import get_config, reduced
    from repro.data.pipeline import DataConfig, Pipeline
    from repro.train import loop as train_loop, state as train_state

    cfg = reduced(get_config("stablelm-1.6b"))
    pipe = Pipeline(DataConfig(global_batch=2, seq_len=16, vocab_size=cfg.vocab_size))
    step_fn = jax.jit(train_loop.make_train_step(cfg, total_steps=12, remat=False))
    box = {"state": train_state.init_state(jax.random.PRNGKey(0), cfg)}

    def train_some(start, n):
        for s in range(start, start + n):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
            box["state"], _ = step_fn(box["state"], batch)
        return start + n

    def save(step):
        ckpt.save(box["state"], str(tmp_path), step)

    def restore():
        box["state"] = ckpt.restore(str(tmp_path), box["state"])
        return int(box["state"].step)

    out = ft.run_with_restarts(
        train_some_steps=train_some, save_ckpt=save, restore_ckpt=restore,
        total_steps=12, ckpt_every=4,
        failure_at={8: ft.FailureEvent(step=8, kind="crash")},
    )
    assert out["final_step"] == 12 and out["restarts"] == 1
    assert int(box["state"].step) == 12
