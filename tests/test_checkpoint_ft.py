"""Checkpoint manager + fault-tolerance runtime tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.runtime import elastic, fault_tolerance as ft


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8)), "b": jnp.zeros(8)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    s = _state()
    ckpt.save(s, str(tmp_path), 7)
    r = ckpt.restore(str(tmp_path), s)
    for (pa, la), (pb, lb) in zip(
        jax.tree_util.tree_flatten_with_path(s)[0],
        jax.tree_util.tree_flatten_with_path(r)[0],
    ):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_checkpoint_latest_and_gc(tmp_path):
    s = _state()
    for step in (1, 2, 3, 4, 5):
        ckpt.save(s, str(tmp_path), step, keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir must not be treated as a checkpoint."""
    s = _state()
    ckpt.save(s, str(tmp_path), 3)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_checkpoint_async(tmp_path):
    s = _state()
    t = ckpt.save_async(s, str(tmp_path), 11)
    t.join(timeout=30)
    assert ckpt.latest_step(str(tmp_path)) == 11


def test_elastic_restore_resharded(tmp_path):
    """Restore onto a different (1-device) mesh with NamedSharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    s = _state()
    ckpt.save(s, str(tmp_path), 1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    r = ckpt.restore(str(tmp_path), s, shardings=sh)
    assert r["params"]["w"].sharding.mesh.shape == {"data": 1, "model": 1}


def test_heartbeat_monitor():
    hb = ft.HeartbeatMonitor(["w0", "w1"], timeout_s=0.05)
    hb.beat("w0")
    time.sleep(0.08)
    hb.beat("w1")
    assert hb.dead_workers() == {"w0"}


def test_straggler_detector():
    sd = ft.StragglerDetector([f"w{i}" for i in range(8)], min_steps=3)
    for step in range(5):
        for i in range(8):
            sd.record(f"w{i}", 1.0 + (3.0 if i == 5 else 0.0) + 0.01 * step)
    assert sd.stragglers() == {"w5"}


def test_straggler_no_false_positive():
    sd = ft.StragglerDetector([f"w{i}" for i in range(8)], min_steps=3)
    rng = np.random.default_rng(0)
    for _ in range(10):
        for i in range(8):
            sd.record(f"w{i}", 1.0 + rng.normal() * 0.02)
    assert sd.stragglers() == set()


def test_run_with_restarts_resumes_from_checkpoint(tmp_path):
    """Injected crash at step 20 -> restore from the step-10 checkpoint;
    the trajectory (deterministic data) completes to 50."""
    trained = []
    saved = {"step": 0}

    def train_some(start, n):
        for s in range(start, start + n):
            trained.append(s)
        return start + n

    def save(step):
        saved["step"] = step

    def restore():
        return saved["step"]

    out = ft.run_with_restarts(
        train_some_steps=train_some,
        save_ckpt=save,
        restore_ckpt=restore,
        total_steps=50,
        ckpt_every=10,
        failure_at={20: ft.FailureEvent(step=20, kind="crash", workers={"h3"})},
    )
    assert out["final_step"] == 50
    assert out["restarts"] == 1
    # steps 20..29 were re-trained after restore (deterministic replay)
    assert trained.count(25) == 1 and trained.count(5) == 1


def test_elastic_mesh_proposal():
    shape, axes = elastic.propose_mesh_shape(512, preferred_model=16, want_pod_axis=True)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    shape, axes = elastic.propose_mesh_shape(448, preferred_model=16)  # lost a pod slice
    assert shape == (28, 16)
    shape, axes = elastic.propose_mesh_shape(24, preferred_model=16)
    assert shape[0] * shape[1] == 24  # degrade model axis to keep all chips


def test_end_to_end_restart_with_real_checkpoints(tmp_path):
    """Real train steps + real checkpoints + injected failure."""
    from repro.configs.base import get_config, reduced
    from repro.data.pipeline import DataConfig, Pipeline
    from repro.train import loop as train_loop, state as train_state

    cfg = reduced(get_config("stablelm-1.6b"))
    pipe = Pipeline(DataConfig(global_batch=2, seq_len=16, vocab_size=cfg.vocab_size))
    step_fn = jax.jit(train_loop.make_train_step(cfg, total_steps=12, remat=False))
    box = {"state": train_state.init_state(jax.random.PRNGKey(0), cfg)}

    def train_some(start, n):
        for s in range(start, start + n):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
            box["state"], _ = step_fn(box["state"], batch)
        return start + n

    def save(step):
        ckpt.save(box["state"], str(tmp_path), step)

    def restore():
        box["state"] = ckpt.restore(str(tmp_path), box["state"])
        return int(box["state"].step)

    out = ft.run_with_restarts(
        train_some_steps=train_some, save_ckpt=save, restore_ckpt=restore,
        total_steps=12, ckpt_every=4,
        failure_at={8: ft.FailureEvent(step=8, kind="crash")},
    )
    assert out["final_step"] == 12 and out["restarts"] == 1
    assert int(box["state"].step) == 12


# --------------------------------------------------------------------------
# Elastic training runtime (repro.training): deterministic fault injection,
# checkpointed recovery with bitwise replay, corrupt-checkpoint fallback
# --------------------------------------------------------------------------

from repro import training


def _toy_harness(ckpt_dir, *, total=12, ckpt_every=3, faults=None,
                 telemetry=None, max_restarts=8):
    """A tiny pure-jnp training problem: fast, deterministic, bitwise."""

    @jax.jit
    def step_fn(state, batch):
        p = state["p"] - 0.1 * jnp.tanh(state["p"] * batch["x"])
        return ({"p": p, "step": state["step"] + 1},
                {"loss": jnp.sum(p * p)})

    def batch_fn(step):
        rng = np.random.default_rng((5, step))
        return {"x": jnp.asarray(rng.standard_normal(4).astype(np.float32))}

    def init_fn():
        return {"p": jnp.ones(4, jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    cfg = training.HarnessConfig(
        total_steps=total, ckpt_every=ckpt_every, ckpt_dir=ckpt_dir,
        max_restarts=max_restarts, async_ckpt=False)
    return training.TrainingHarness(
        step_fn=step_fn, batch_fn=batch_fn, init_fn=init_fn, config=cfg,
        faults=faults, telemetry=telemetry)


def test_restore_latest_valid_skips_corrupt(tmp_path):
    s = _state()
    ckpt.save(s, str(tmp_path), 2)
    ckpt.save(s, str(tmp_path), 4)
    assert training.corrupt_latest_checkpoint(str(tmp_path)) is not None
    state, step, skipped = ckpt.restore_latest_valid(str(tmp_path), s)
    assert step == 2
    assert [st for st, _ in skipped] == [4]
    np.testing.assert_array_equal(np.asarray(state["params"]["b"]),
                                  np.asarray(s["params"]["b"]))


def test_restore_latest_valid_skips_missing_leaf(tmp_path):
    """A torn write that lost a leaf file entirely is also 'corrupt'."""
    s = _state()
    ckpt.save(s, str(tmp_path), 1)
    ckpt.save(s, str(tmp_path), 3)
    os.remove(tmp_path / "step_00000003" / "leaf_00000.npy")
    _, step, skipped = ckpt.restore_latest_valid(str(tmp_path), s)
    assert step == 1 and [st for st, _ in skipped] == [3]


def test_restore_latest_valid_all_corrupt_raises(tmp_path):
    s = _state()
    ckpt.save(s, str(tmp_path), 5)
    training.corrupt_latest_checkpoint(str(tmp_path))
    with pytest.raises(FileNotFoundError) as ei:
        ckpt.restore_latest_valid(str(tmp_path), s)
    assert "5" in str(ei.value)  # names what it skipped


def test_corrupt_latest_checkpoint_empty_dir_returns_none(tmp_path):
    """No checkpoints yet -> nothing to corrupt, and no crash.

    Regression: the chaos harness calls ``corrupt_latest_checkpoint``
    unconditionally at boot; on a fresh run the checkpoint dir is empty
    (or absent) and the injector must report 'no-op', not raise.
    """
    assert training.corrupt_latest_checkpoint(str(tmp_path)) is None
    assert training.corrupt_latest_checkpoint(str(tmp_path / "missing")) is None


def test_corrupt_latest_checkpoint_skips_junk_entries(tmp_path):
    """Non-``step_NNN`` entries (and ``step_final``) must not break the
    latest-step scan — only numeric step dirs are candidates."""
    (tmp_path / "tmp_write").mkdir()
    (tmp_path / "step_final").mkdir()
    (tmp_path / "step_final" / "manifest.json").write_text("{}")
    (tmp_path / "notes.txt").write_text("x")
    # junk only -> still nothing corruptible
    assert training.corrupt_latest_checkpoint(str(tmp_path)) is None
    s = _state()
    ckpt.save(s, str(tmp_path), 7)
    hit = training.corrupt_latest_checkpoint(str(tmp_path))
    assert hit is not None and "step_00000007" in hit


def test_fault_schedule_spec_and_fire_once():
    fs = training.FaultSchedule.from_spec("host_loss@5, corrupt_ckpt@9")
    assert fs.take(4) is None
    ev = fs.take(5)
    assert ev is not None and ev.kind == "host_loss"
    assert fs.take(5) is None  # fires exactly once
    with pytest.raises(ValueError):
        training.FaultSchedule.from_spec("melted@3")
    with pytest.raises(ValueError):
        training.FaultSchedule(
            [training.FaultEvent(2, "preempt"), training.FaultEvent(2, "host_loss")])


def test_fault_schedule_seeded_is_reproducible():
    a = training.FaultSchedule.generate(11, 40, n_faults=3)
    b = training.FaultSchedule.generate(11, 40, n_faults=3)
    assert a.describe() == b.describe()
    assert len(a.events) == 3
    assert all(1 <= s < 40 for s in a.events)
    c = training.FaultSchedule.generate(12, 40, n_faults=3)
    assert c.describe() != a.describe()  # the seed is the schedule


def test_fault_schedule_generate_validates_inputs():
    """Regression: ``generate(kinds=())`` used to reach the rng draw and
    die with ZeroDivisionError; bad inputs must fail up front with a
    ValueError that names the legal kinds."""
    with pytest.raises(ValueError, match="at least one fault kind"):
        training.FaultSchedule.generate(0, 40, n_faults=2, kinds=())
    with pytest.raises(ValueError, match="unknown fault kind"):
        training.FaultSchedule.generate(0, 40, n_faults=2,
                                        kinds=("host_loss", "melted"))
    with pytest.raises(ValueError, match="n_faults"):
        training.FaultSchedule.generate(0, 40, n_faults=-1)
    # a kinds subset is still a legal (and now validated) call
    fs = training.FaultSchedule.generate(3, 40, n_faults=2,
                                         kinds=("preempt",))
    assert all(e.kind == "preempt" for e in fs.events.values())


def test_harness_kill_and_resume_is_bitwise(tmp_path):
    """Stop the loop at step 5; a FRESH harness on the same ckpt dir
    must continue to a loss trajectory bitwise equal to an
    uninterrupted run."""
    ref = _toy_harness(None).run()
    assert ref["final_step"] == 12 and ref["restarts"] == 0

    d = str(tmp_path / "ck")
    half = _toy_harness(d, total=5).run()
    assert half["final_step"] == 5
    resumed = _toy_harness(d).run()  # fresh harness = simulated new process
    assert min(resumed["losses"]) == 5  # resumed at the checkpoint, not 0
    for s in range(5, 12):
        assert resumed["losses"][s] == ref["losses"][s]


def test_harness_preemption_recovers_bitwise(tmp_path):
    ref = _toy_harness(None).run()
    faults = training.FaultSchedule.from_spec("preempt@7")
    out = _toy_harness(str(tmp_path / "ck"), faults=faults).run()
    assert out["restarts"] == 1
    [rec] = out["recovery_log"]
    assert rec["kind"] == "preempt" and rec["failed_step"] == 7
    assert rec["resumed_from"] == 6  # newest ckpt (ckpt_every=3)
    assert out["losses"] == ref["losses"]  # full bitwise continuity


def test_harness_corrupt_ckpt_falls_back_to_previous_step(tmp_path):
    """corrupt_ckpt kills the newest checkpoint with the process: the
    recovery must skip it and resume from the PREVIOUS step."""
    ref = _toy_harness(None).run()
    faults = training.FaultSchedule.from_spec("corrupt_ckpt@7")
    out = _toy_harness(str(tmp_path / "ck"), faults=faults).run()
    assert out["restarts"] == 1
    [rec] = out["recovery_log"]
    assert rec["resumed_from"] == 3  # step-6 ckpt was corrupted -> step 3
    assert rec["ckpt_skipped"] == [6]
    assert out["losses"] == ref["losses"]


def test_harness_identical_recovery_decisions_across_runs(tmp_path):
    """Acceptance: the same seeded schedule reproduces IDENTICAL
    recovery decisions across two runs."""
    outs = []
    for run in ("a", "b"):
        faults = training.FaultSchedule.generate(3, 12, n_faults=2)
        outs.append(_toy_harness(str(tmp_path / run), faults=faults).run())
    assert outs[0]["recovery_log"] == outs[1]["recovery_log"]
    assert outs[0]["restarts"] == outs[1]["restarts"] >= 1
    assert outs[0]["losses"] == outs[1]["losses"]


def test_harness_max_restarts_bounds_the_loop(tmp_path):
    faults = training.FaultSchedule.from_spec("host_loss@2,host_loss@4")
    with pytest.raises(RuntimeError, match="max_restarts"):
        _toy_harness(None, faults=faults, max_restarts=1).run()


def test_harness_telemetry_payload(tmp_path):
    rec = training.StepTimeRecorder(tokens_per_step=128,
                                    config={"arch": "toy"})
    faults = training.FaultSchedule.from_spec("preempt@7")
    _toy_harness(str(tmp_path / "ck"), faults=faults, telemetry=rec).run()
    payload = rec.payload()
    assert payload["bench"] == "train_runtime"
    assert payload["config"] == {"arch": "toy"}
    res = payload["results"]
    # 12 committed steps + 1 replayed (7 computed twice: preempted, redone)
    assert res["steps"] == 13
    assert res["recoveries"] == 1 and len(res["recovery_latency_s"]) == 1
    assert res["tokens_per_sec"] > 0
    assert {r["step"] for r in payload["trajectory"]} == set(range(12))
    [ev] = payload["events"]
    assert ev["kind"] == "recovery" and "preempt@7" in ev["detail"]
    out = rec.write(str(tmp_path / "BENCH_train.json"))
    import json as _json
    with open(out) as f:
        assert _json.load(f)["bench"] == "train_runtime"
