"""Serving resilience: deadlines, shedding, breakers, chaos injection.

The contracts (``docs/serving.md`` §Resilience):

* every ADMITTED request terminates with a typed ``ServeResponse`` —
  sheds and deadline misses included, never a silent drop;
* ``GuardedExecutor`` retries transient failures, opens its breaker
  after K CONSECUTIVE exhausted calls, demotes one rung down its
  (lazily materialised) ladder, probes the primary on the half-open
  schedule and promotes back on success;
* the clean path is free: no fallback rungs built, no extra plan
  builds, no retraces, no breaker transitions;
* chaos is reproducible: equal seeded ``FaultSchedule``s + equal
  injector configs make IDENTICAL fault and recovery decisions.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.kernels import plan as plan_mod
from repro.runtime.faults import (
    SERVING_FAULT_KINDS,
    FaultInjector,
    FaultSchedule,
    InjectedExecutorError,
    corrupt_plan_store,
)
from repro.serving import aot, persistence
from repro.serving.engine import Request, ServeEngine
from repro.serving.resilience import (
    AdmissionController,
    ExecutorFailure,
    GuardedExecutor,
    ResilienceConfig,
    ServeResponse,
    guard_plan,
    ladder_of,
    resilience_snapshot,
)


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    plan_mod.clear_plans()
    plan_mod.reset_autotune_stats()
    aot.reset_stats()
    yield
    plan_mod.clear_plans()


def _lm_engine(slots=2, capacity=32, **kw):
    from repro.configs.base import get_config, reduced
    from repro.models import lm

    cfg = reduced(get_config("llama3-8b"))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params, ServeEngine(cfg, params, slots=slots,
                                    capacity=capacity, **kw)


def _req(rid, n=4, max_new=3, **kw):
    return Request(rid=rid, prompt=np.arange(n, dtype=np.int32) + rid,
                   max_new=max_new, **kw)


# --------------------------------------------------------------------------
# GuardedExecutor: retry, breaker, ladder, half-open probe
# --------------------------------------------------------------------------


class _Flaky:
    """Callable that fails the first ``n_failures`` invocations."""

    def __init__(self, n_failures, result="ok"):
        self.n_failures = n_failures
        self.calls = 0
        self.result = result

    def __call__(self, *a):
        self.calls += 1
        if self.calls <= self.n_failures:
            raise RuntimeError(f"flake #{self.calls}")
        return self.result


def test_retry_recovers_transient_failure():
    pol = ResilienceConfig(max_retries=2)
    flaky = _Flaky(2)
    g = GuardedExecutor("x", flaky, policy=pol)
    assert g.call() == "ok"  # 2 failures absorbed by the retry budget
    assert g.retry_count == 2 and g.state == "closed" and g.rung == 0
    assert g.transitions == []


def test_retry_exhaustion_is_typed_and_counts_toward_breaker():
    pol = ResilienceConfig(max_retries=1, breaker_threshold=3)
    g = GuardedExecutor("x", _Flaky(100), policy=pol)
    with pytest.raises(ExecutorFailure):
        g.call()
    assert g.consecutive_failures == 1 and g.state == "closed"


def test_breaker_demotes_after_k_consecutive_failures_then_recloses():
    pol = ResilienceConfig(max_retries=0, breaker_threshold=2,
                           probe_interval=2)
    primary = _Flaky(3, result="primary")  # heals after 3 failures
    backup = _Flaky(0, result="backup")
    g = GuardedExecutor("x", primary, demote_fn=ladder_of([backup]),
                        policy=pol)
    with pytest.raises(ExecutorFailure):
        g.call()  # failure 1: below threshold -> typed failure
    assert g.call() == "backup"  # failure 2 demotes; SAME call served
    assert g.state == "open" and g.rung == 1
    assert g.call() == "backup"  # calls_since_demote=1
    # 2nd call since demote: half-open probe — the primary's 3rd (and
    # last) flake fails it, so the breaker re-opens and the rung serves
    assert g.call() == "backup"
    assert g.state == "open" and g.rung == 1
    assert g.call() == "backup"  # off the probe schedule
    # next probe finds the healed primary: promote back to rung 0
    assert g.call() == "primary"
    assert g.state == "closed" and g.rung == 0
    assert [t[0] for t in g.transitions] == [
        "open", "half_open", "open", "half_open", "closed"]


def test_half_open_probe_failure_reopens():
    pol = ResilienceConfig(max_retries=0, breaker_threshold=1,
                           probe_interval=1)
    primary = _Flaky(100)
    g = GuardedExecutor("x", primary,
                        demote_fn=ladder_of([_Flaky(0, result="backup")]),
                        policy=pol)
    assert g.call() == "backup"  # immediate demote (threshold 1)
    assert g.call() == "backup"  # probe fails -> re-open -> rung serves
    states = [t[0] for t in g.transitions]
    assert states == ["open", "half_open", "open"]
    assert g.rung == 1


def test_ladder_is_lazy_and_bottoms_out():
    pol = ResilienceConfig(max_retries=0, breaker_threshold=1)
    g = GuardedExecutor("x", _Flaky(100),
                        demote_fn=ladder_of([_Flaky(100), _Flaky(100)]),
                        policy=pol)
    assert g.rung_labels() == ["_Flaky"]  # nothing materialised yet
    with pytest.raises(ExecutorFailure):
        g.call()  # walks every rung, all fail
    assert len(g.rung_labels()) == 3
    assert g.rung == 2  # parked at the bottom


# --------------------------------------------------------------------------
# admission control + typed responses
# --------------------------------------------------------------------------


def test_admission_sheds_past_bound_with_backpressure():
    adm = AdmissionController(2, engine="t")
    assert adm.admit(0) and adm.admit(1)
    assert adm.backpressure(1) == 0.5
    assert not adm.admit(2) and adm.shed_count == 1
    assert adm.backpressure(2) == 1.0


def test_serve_response_statuses_are_validated():
    with pytest.raises(ValueError, match="unknown status"):
        ServeResponse("dropped", 0)
    r = ServeResponse("ok", 1, tokens=(1, 2))
    assert r.ok and r.tokens == (1, 2)


def test_engine_sheds_over_max_queue_with_typed_response():
    _, _, eng = _lm_engine(slots=1, max_queue=2)
    eng.warmup(prompt_lengths=(4,))
    reqs = [_req(i) for i in range(4)]
    resp = [eng.submit(r) for r in reqs]
    assert resp[0] is None and resp[1] is None  # admitted
    assert resp[2].status == "shed" and resp[3].status == "shed"
    eng.run()
    assert all(r.response is not None for r in reqs)
    assert [r.response.status for r in reqs] == ["ok", "ok", "shed", "shed"]
    m = eng.metrics.snapshot()
    assert m["shed"] == 2 and m["submitted"] == 2
    assert eng.resilience_state()["sheds"] == 2


def test_engine_deadline_resolves_queued_request_as_timeout():
    _, _, eng = _lm_engine(slots=1)
    eng.warmup(prompt_lengths=(4,))
    r0 = _req(0, max_new=4)
    r1 = _req(1, max_new=2, deadline_ticks=1)  # will wait behind r0
    eng.submit(r0)
    eng.submit(r1)
    eng.run()
    assert r0.response.ok and len(r0.out) == 4
    assert r1.response.status == "timeout" and "deadline" in r1.response.detail
    assert eng.metrics.snapshot()["deadline_misses"] == 1
    # the per-request tick maps were cleaned up on resolution
    assert not eng.metrics._submit_tick and not eng.metrics._admit_tick


def test_engine_default_deadline_from_config():
    # the engine-wide default applies to queued AND in-flight requests:
    # r0 finishes within its 2 ticks; r1/r2 (queued behind it, then
    # mid-decode) inherit the default and expire
    _, _, eng = _lm_engine(
        slots=1, resilience=ResilienceConfig(deadline_ticks=2))
    eng.warmup(prompt_lengths=(4,))
    reqs = [_req(0, max_new=2)] + [_req(i, max_new=6) for i in (1, 2)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert reqs[0].response.ok
    assert all(r.response.status == "timeout" for r in reqs[1:])


# --------------------------------------------------------------------------
# chaos: injected executor faults through the live engine
# --------------------------------------------------------------------------


def _chaos_engine(spec, **inj_kw):
    inj = FaultInjector(FaultSchedule.from_spec(spec), **inj_kw)
    _, _, eng = _lm_engine(
        slots=1,
        resilience=ResilienceConfig(max_retries=1, breaker_threshold=2,
                                    probe_interval=2),
        faults=inj)
    eng.warmup(prompt_lengths=(4,))
    return eng, inj


def test_injected_decode_raises_drive_breaker_cycle():
    # 4 armed raises = threshold * (retries + 1): one exhausted call
    # (typed error), a second that demotes mid-call, then recovery
    eng, inj = _chaos_engine("exec_raise@1", raise_target="decode",
                             raise_attempts=4)
    reqs = [_req(i, max_new=3) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    while eng._decode_guard.rung > 0:  # drive the half-open probe
        r = _req(100, max_new=2)
        reqs.append(r)
        eng.submit(r)
        eng.run()
    assert inj.pending_raises == 0
    assert all(r.response is not None for r in reqs), "untyped response"
    statuses = {r.response.status for r in reqs}
    assert "error" in statuses and "ok" in statuses
    t = [s for s, _ in eng._decode_guard.transitions]
    assert t[0] == "open" and "half_open" in t and t[-1] == "closed"
    assert eng.metrics.snapshot()["exec_errors"] >= 1


def test_straggler_tick_is_metered():
    eng, _ = _chaos_engine("straggler@1", straggler_s=0.0)
    for i in range(2):
        eng.submit(_req(i))
    eng.run()
    assert eng.metrics.snapshot()["stragglers"] == 1


def test_chaos_run_is_reproducible_same_seed():
    def run(seed):
        sched = FaultSchedule.generate(seed, 6, n_faults=2,
                                       kinds=("exec_raise", "straggler"))
        eng, inj = None, FaultInjector(sched, raise_target="decode",
                                      raise_attempts=2)
        _, _, eng = _lm_engine(
            slots=1,
            resilience=ResilienceConfig(max_retries=0, breaker_threshold=2,
                                        probe_interval=2),
            faults=inj)
        eng.warmup(prompt_lengths=(4,))
        reqs = [_req(i, max_new=3) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return ([r.response.status for r in reqs], list(inj.log),
                list(eng._decode_guard.transitions), sched.describe())

    assert run(13) == run(13)
    # and the schedule itself is seed-sensitive
    assert FaultSchedule.generate(13, 6).describe() != \
        FaultSchedule.generate(14, 6).describe()


def test_serving_kinds_reject_unknown_and_training_kinds_are_ignored():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSchedule.from_spec("gremlin@3")
    inj = FaultInjector(FaultSchedule.from_spec("host_loss@1"))
    ev = inj.begin_tick(1)
    assert ev.kind == "host_loss"
    assert inj.log[-1]["ignored"] is True
    assert inj.pending_raises == 0


# --------------------------------------------------------------------------
# boot-time store corruption
# --------------------------------------------------------------------------


def test_corrupt_store_at_boot_degrades_to_cold_warm_and_repersists(tmp_path):
    store = str(tmp_path / "plans.json")
    from repro.configs.base import get_config, reduced
    from repro.models import vlm

    cfg = reduced(get_config("phi-3-vision-4.2b"))
    params = vlm.init_vlm(jax.random.PRNGKey(0), cfg)
    e1 = ServeEngine(cfg, params, slots=1, capacity=64, store_path=store)
    assert persistence.PlanStore(store).exists()
    e1.shutdown()

    inj = FaultInjector(FaultSchedule.from_spec("corrupt_store@0"))
    plan_mod.clear_plans()
    e2 = ServeEngine(cfg, params, slots=1, capacity=64, store_path=store,
                     faults=inj)
    assert e2.boot_faults == [store]
    assert e2.restore_report is None  # corrupt store -> cold boot
    assert e2.plans, "cold boot warmed no plans"
    assert persistence.PlanStore(store).load() is not None, "not re-persisted"
    assert inj.log[0]["at"] == "boot"
    e2.shutdown()


def test_corrupt_plan_store_missing_path_is_noop(tmp_path):
    assert corrupt_plan_store(str(tmp_path / "absent.json")) is None
    assert corrupt_plan_store("") is None


# --------------------------------------------------------------------------
# plan degradation ladder (unit level; numeric parity in conformance.py)
# --------------------------------------------------------------------------


def test_guard_plan_demotes_down_fallback_ladder():
    from repro.kernels.plan import MsdaSpec, msda_plan

    spec = MsdaSpec(spatial_shapes=((6, 4), (3, 2)), num_heads=2, head_dim=8,
                    num_points=2, num_queries=7, dtype="float32",
                    fuse_levels="on")
    plan = msda_plan(spec, backend="pallas", tune="heuristic")
    assert plan.fused, "primary should be the fused plan"
    inj = FaultInjector(FaultSchedule.from_spec("exec_raise@1"),
                        raise_target="p", raise_attempts=2)
    inj.begin_tick(1)
    pol = ResilienceConfig(max_retries=0, breaker_threshold=2,
                           probe_interval=4)
    g = guard_plan(plan, pol, injector=inj, name="p", engine="t")
    rng = np.random.default_rng(0)
    S = sum(h * w for h, w in spec.spatial_shapes)
    v = rng.standard_normal((1, S, 2, 8)).astype(np.float32)
    loc = rng.uniform(size=(1, 7, 2, 2, 2, 2)).astype(np.float32)
    a = rng.uniform(size=(1, 7, 2, 2, 2)).astype(np.float32)
    with pytest.raises(ExecutorFailure):
        g.call(v, loc, a)  # injected raise, no retries -> failure 1
    out = g.call(v, loc, a)  # failure 2 demotes; per-level rung serves
    assert g.rung == 1 and g.state == "open"
    assert g.rung_labels() == ["pallas/fused", "pallas/per-level"]
    # the demoted rung is race-free and bitwise vs the fused primary
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(plan(v, loc, a)))
    assert plan_mod.autotune_stats()["raced"] == 0
    snap = resilience_snapshot([g])
    assert snap["executors"]["p"]["rung"] == 1


def test_plan_ladder_never_persists_winners(tmp_path, monkeypatch):
    from repro.kernels.plan import MsdaSpec, msda_plan

    cache = tmp_path / "winners.json"
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(cache))
    spec = MsdaSpec(spatial_shapes=((6, 4),), num_heads=2, head_dim=8,
                    num_points=2, num_queries=5, dtype="float32")
    plan = msda_plan(spec, backend="pallas", tune="heuristic")
    for rung in plan.fallback_chain():
        assert rung.tune == "heuristic"
    assert not cache.exists(), "fallback build persisted an autotune winner"


# --------------------------------------------------------------------------
# clean path: resilience must be free
# --------------------------------------------------------------------------


def test_clean_run_builds_no_rungs_and_adds_no_traces():
    _, _, eng = _lm_engine(slots=2)
    eng.warmup(prompt_lengths=(4,))
    tele0 = plan_mod.execution_telemetry()
    reqs = [_req(i) for i in range(3)]
    with aot.probe() as p:
        for r in reqs:
            eng.submit(r)
        eng.run()
    assert all(r.response is not None and r.response.ok for r in reqs)
    assert p.traces == 0 and p.compiles == 0
    state = eng.resilience_state()
    assert state["sheds"] == 0
    for ex in state["executors"].values():
        assert ex["rung"] == 0 and ex["transitions"] == [] \
            and ex["retries"] == 0 and len(ex["rungs_built"]) == 1
    assert plan_mod.execution_telemetry() == tele0, \
        "resilience layer changed plan execution telemetry on a clean run"


def test_resilience_config_validates():
    with pytest.raises(ValueError, match="max_queue"):
        ResilienceConfig(max_queue=0)
    with pytest.raises(ValueError, match="breaker_threshold"):
        ResilienceConfig(breaker_threshold=0)
    with pytest.raises(ValueError, match="probe_interval"):
        ResilienceConfig(probe_interval=0)
    with pytest.raises(ValueError, match="max_retries"):
        ResilienceConfig(max_retries=-1)
    # engine max_queue kwarg overrides the config's bound
    c = dataclasses.replace(ResilienceConfig(), max_queue=7)
    assert c.max_queue == 7


def test_event_window_bounds_metrics_memory():
    from repro.serving.metrics import ServeMetrics

    m = ServeMetrics()
    for rid in range(10_000):
        m.record_submit(rid)
        m.record_admit(rid)
        m.record_tick()
        m.record_retire(rid)
    s = m.snapshot()
    assert s["retired"] == 10_000  # exact counters survive the window
    assert len(m.latency_ticks) <= m.latency_ticks.window
    assert not m._submit_tick and not m._admit_tick
    assert s["latency_ticks"]["max"] >= 0.0


def test_step_recorder_window_keeps_exact_aggregates():
    from repro.training.telemetry import StepTimeRecorder

    rec = StepTimeRecorder(window=8)
    for i in range(100):
        rec.record_step(i, 0.5)
    rec.record_event("recovery", step=50, latency_s=1.0)
    s = rec.summary()
    assert s["steps"] == 100 and s["mean_step_s"] == pytest.approx(0.5)
    assert s["total_step_wall_s"] == pytest.approx(50.0)
    assert s["recoveries"] == 1
    p = rec.payload()
    assert len(p["trajectory"]) == 8  # windowed raw rows
