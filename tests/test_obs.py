"""Observability layer: registry scoping, span JSONL, plan telemetry, gate.

Four contracts:

* the metrics registry is get-or-create with labeled series, and
  ``reset(prefix)`` / ``scope()`` bound what a caller can see or clear;
* nested spans round-trip through the JSONL sink with correct paths,
  depths and attrs, and respect the sink's level threshold;
* plan-cache / winner-cache counters surfaced by
  ``plan.execution_telemetry()`` agree with ``autotune_stats()`` across
  a cold build -> ``PlanStore.restore`` -> warm rebuild cycle;
* ``tools/bench_gate.py`` passes identical trajectories, fails on a
  regression beyond tolerance, and ``--update`` ratchets the baseline
  (old results appended to ``history``).
"""
import importlib.util
import json
import os

import pytest

from repro import obs
from repro.kernels import plan as plan_mod
from repro.kernels.plan import MsdaSpec
from repro.obs import bench as obs_bench
from repro.obs import registry as obs_registry
from repro.serving import persistence


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    """Private winner cache + fresh plan/obs counters per test."""
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    plan_mod.clear_plans()
    plan_mod.reset_autotune_stats()
    obs.reset("msda")
    yield
    plan_mod.clear_plans()
    obs.disable_trace()


# ---------------------------------------------------------------- registry


def test_registry_get_or_create_and_labels():
    r = obs_registry.Registry()
    c = r.counter("req.total")
    assert r.counter("req.total") is c
    c.inc()
    c.inc(2, route="decode")
    assert c.value() == 1.0
    assert c.value(route="decode") == 2.0
    assert c.total() == 3.0
    assert c.values() == {"req.total": 1.0, 'req.total{route="decode"}': 2.0}
    with pytest.raises(TypeError):
        r.gauge("req.total")  # same name, different kind


def test_registry_snapshot_and_reset_scoping():
    r = obs_registry.Registry()
    r.counter("a").inc()
    r.counter("a.b").inc(5)
    r.counter("ab").inc(7)  # shares the prefix string but not the dot scope
    r.gauge("a.g").set(3.0)
    snap = r.snapshot()
    assert snap["counters"] == {"a": 1.0, "a.b": 5.0, "ab": 7.0}
    assert snap["gauges"] == {"a.g": 3.0}

    r.reset("a")
    assert r.counter("a").value() == 0.0
    assert r.counter("a.b").value() == 0.0
    assert r.gauge("a.g").value() == 0.0
    assert r.counter("ab").value() == 7.0, "reset('a') must not touch 'ab'"


def test_registry_scope_sees_only_deltas():
    r = obs_registry.Registry()
    r.counter("x").inc(10)
    with r.scope() as sc:
        r.counter("x").inc(2)
        r.counter("y").inc()
    d = sc.deltas()
    assert d["x"] == 2.0 and d["y"] == 1.0
    assert r.counter("x").value() == 12.0  # scope is a view, not a reset


def test_histogram_summary():
    r = obs_registry.Registry()
    h = r.histogram("lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["sum"] == 10.0
    assert s["min"] == 1.0 and s["max"] == 4.0 and s["mean"] == 2.5
    assert 2.0 <= s["p50"] <= 3.0


# ------------------------------------------------------------------- spans


def test_span_feeds_histogram_without_sink():
    assert obs.trace_path() is None
    with obs.scope() as sc:
        with obs.span("unit.test_hist", level=1):
            pass
    assert sc.hist_deltas().get("span.unit.test_hist") == 1.0


def test_span_nesting_jsonl_round_trip(tmp_path):
    path = tmp_path / "trace.jsonl"
    obs.enable_trace(str(path), level=3)
    with obs.span("outer", level=1, phase="build") as sp:
        sp["n"] = 2
        with obs.span("inner", level=2, idx=0):
            pass
        with obs.span("too_fine", level=4):  # above threshold: not written
            pass
    obs.disable_trace()

    records = [json.loads(line) for line in path.read_text().splitlines()]
    by_name = {rec["name"]: rec for rec in records}
    assert set(by_name) == {"outer", "inner"}
    assert by_name["inner"]["path"] == "outer/inner"
    assert by_name["inner"]["depth"] == 1
    assert by_name["outer"]["path"] == "outer"
    assert by_name["outer"]["depth"] == 0
    assert by_name["outer"]["attrs"] == {"phase": "build", "n": 2}
    assert by_name["outer"]["dur_s"] >= by_name["inner"]["dur_s"]
    # inner closes before outer, so it is written first
    assert records[0]["name"] == "inner"


def test_trace_level_can_be_raised(tmp_path):
    path = tmp_path / "t.jsonl"
    obs.enable_trace(str(path), level=5)
    with obs.span("fine", level=4):
        pass
    obs.disable_trace()
    assert "fine" in path.read_text()


# ------------------------------------------------- plan execution telemetry


def _spec(q=16):
    return MsdaSpec(spatial_shapes=((4, 4), (2, 2)), num_heads=2, head_dim=8,
                    num_points=2, num_queries=q)


def test_plan_cache_counters_match_plan_cache_info():
    plan_mod.msda_plan(_spec(), backend="ref")
    plan_mod.msda_plan(_spec(), backend="ref")  # warm: in-process cache hit
    info = plan_mod.plan_cache_info()
    tele = plan_mod.execution_telemetry()["plan_cache"]
    assert tele["hits"] == info["hits"] == 1
    assert tele["misses"] == info["misses"] == 1
    assert tele["size"] == info["size"] == 1
    assert tele["hit_rate"] == 0.5


def test_winner_cache_counters_cold_store_restore_warm(tmp_path):
    # cpu is blockless, so give autotune a dtype race to actually time
    spec = MsdaSpec(spatial_shapes=((8, 8), (4, 4)), num_heads=2, head_dim=8,
                    num_points=2, num_queries=32, slab_dtype="auto")

    # --- cold: private empty winner cache, autotune really races
    plan = plan_mod.msda_plan(spec, backend="cpu", tune="autotune")
    cold = plan_mod.autotune_stats()
    assert cold["raced"] >= 1
    tele = plan_mod.execution_telemetry()["winner_cache"]
    assert tele["hits"] == cold["cache_hits"] == 0
    assert tele["misses"] >= 1, "cold disk lookup must count as a miss"

    store = persistence.PlanStore(str(tmp_path / "plans.json"))
    assert store.save_plans([plan]) == 1

    # --- restart: plan cache gone, fresh winner cache file, restore seeds it
    plan_mod.clear_plans()
    os.environ["REPRO_MSDA_AUTOTUNE_CACHE"] = str(tmp_path / "autotune2.json")
    plan_mod.reset_autotune_stats()
    report = persistence.PlanStore(store.path).restore()
    assert len(report.plans) == 1
    seeded = plan_mod.autotune_stats()
    assert seeded["raced"] == 0 and seeded["seeded"] >= 1
    tele = plan_mod.execution_telemetry()["winner_cache"]
    assert tele["seeded"] == seeded["seeded"]

    # --- warm: rebuild from scratch against the seeded winner cache
    plan_mod.clear_plans()
    plan_mod.msda_plan(spec, backend="cpu", tune="autotune")
    warm = plan_mod.autotune_stats()
    assert warm["raced"] == 0, "seeded winner cache must preempt the race"
    assert warm["cache_hits"] >= seeded["cache_hits"] + 1
    tele = plan_mod.execution_telemetry()["winner_cache"]
    assert tele["hits"] == warm["cache_hits"]
    assert tele["seeded"] == warm["seeded"]
    assert tele["hit_rate"] is not None and tele["hit_rate"] > 0.0


def test_launch_counters_and_plan_calls():
    import jax
    import jax.numpy as jnp

    spec = _spec(q=8)
    plan = plan_mod.msda_plan(spec, backend="ref")
    assert plan.launches_per_call() == {"fwd": 0, "bwd": 0}
    B, H, D, L, P = 1, spec.num_heads, spec.head_dim, spec.num_levels, \
        spec.num_points
    S = sum(h * w for h, w in spec.spatial_shapes)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, spec.num_queries, H, L, P, 2))
    attn = jax.nn.softmax(jax.random.normal(
        ks[2], (B, spec.num_queries, H, L, P)).reshape(B, spec.num_queries,
                                                       H, -1)
    ).reshape(B, spec.num_queries, H, L, P)
    before = plan_mod.execution_telemetry()["launches"]
    out = plan(value, loc, attn)
    after = plan_mod.execution_telemetry()["launches"]
    assert after["plan_calls"] == before["plan_calls"] + 1
    assert after["fwd"] == before["fwd"]  # ref backend launches no kernels
    assert jnp.all(jnp.isfinite(out))


# -------------------------------------------------------------- bench gate


def _bench_gate():
    path = os.path.join(obs_bench.repo_root(), "tools", "bench_gate.py")
    spec = importlib.util.spec_from_file_location("bench_gate", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write(path, results, *, gate=None, bench="unit"):
    obs_bench.write_bench(str(path), bench=bench, results=results,
                          gate=gate, created_unix=1000.0)


GATE = [obs_bench.gate_rule("*.launches", "lower", 0.0),
        obs_bench.gate_rule("*.us", "lower", 0.5)]


def test_bench_gate_passes_identical(tmp_path, capsys):
    bg = _bench_gate()
    res = {"L4": {"launches": 1, "us": 100.0}}
    _write(tmp_path / "base.json", res, gate=GATE)
    _write(tmp_path / "fresh.json", res, gate=GATE)
    rc = bg.main(["--baseline", str(tmp_path / "base.json"),
                  "--fresh", str(tmp_path / "fresh.json")])
    assert rc == 0
    assert "OK" in capsys.readouterr().out


def test_bench_gate_fails_on_regression(tmp_path, capsys):
    bg = _bench_gate()
    _write(tmp_path / "base.json", {"L4": {"launches": 1, "us": 100.0}},
           gate=GATE)
    # structural count doubled: regression regardless of tolerance
    _write(tmp_path / "fresh.json", {"L4": {"launches": 2, "us": 100.0}},
           gate=GATE)
    rc = bg.main(["--baseline", str(tmp_path / "base.json"),
                  "--fresh", str(tmp_path / "fresh.json")])
    assert rc == 2
    assert "REGRESSION" in capsys.readouterr().out


def test_bench_gate_timing_within_tolerance_passes(tmp_path):
    bg = _bench_gate()
    _write(tmp_path / "base.json", {"L4": {"us": 100.0}}, gate=GATE)
    _write(tmp_path / "fresh.json", {"L4": {"us": 140.0}}, gate=GATE)
    rc = bg.main(["--baseline", str(tmp_path / "base.json"),
                  "--fresh", str(tmp_path / "fresh.json")])
    assert rc == 0


def test_bench_gate_missing_gated_metric_is_regression(tmp_path):
    bg = _bench_gate()
    _write(tmp_path / "base.json", {"L4": {"launches": 1}}, gate=GATE)
    _write(tmp_path / "fresh.json", {"L4": {}}, gate=GATE)
    rc = bg.main(["--baseline", str(tmp_path / "base.json"),
                  "--fresh", str(tmp_path / "fresh.json")])
    assert rc == 2


def test_bench_gate_update_ratchets_baseline(tmp_path):
    bg = _bench_gate()
    base = tmp_path / "base.json"
    _write(base, {"L4": {"us": 100.0}}, gate=GATE)
    _write(tmp_path / "fresh.json", {"L4": {"us": 40.0}}, gate=GATE)
    rc = bg.main(["--baseline", str(base),
                  "--fresh", str(tmp_path / "fresh.json"), "--update"])
    assert rc == 0
    updated = obs_bench.read_bench(str(base))
    assert updated["results"]["L4"]["us"] == 40.0
    assert len(updated["history"]) == 1
    assert updated["history"][0]["results"]["L4"]["us"] == 100.0
    assert updated["gate"] == GATE, "gate rules survive the ratchet"


def test_bench_gate_bench_id_mismatch_is_error(tmp_path):
    bg = _bench_gate()
    _write(tmp_path / "base.json", {"x": 1}, bench="a")
    _write(tmp_path / "fresh.json", {"x": 1}, bench="b")
    rc = bg.main(["--baseline", str(tmp_path / "base.json"),
                  "--fresh", str(tmp_path / "fresh.json")])
    assert rc == 1


def test_bench_gate_heuristic_fallback_for_legacy_payloads(tmp_path):
    bg = _bench_gate()
    # no gate block at all: count-like keys still gate structurally
    (tmp_path / "base.json").write_text(json.dumps(
        {"bench": "legacy", "results": {"launches_per_call": 1, "us": 9.0}}))
    (tmp_path / "fresh.json").write_text(json.dumps(
        {"bench": "legacy", "results": {"launches_per_call": 3, "us": 2.0}}))
    rc = bg.main(["--baseline", str(tmp_path / "base.json"),
                  "--fresh", str(tmp_path / "fresh.json")])
    assert rc == 2


# --------------------------------------------------------------- exporters


def test_exporters_render_counters(tmp_path):
    obs.counter("unit.export.hits").inc(3)
    text = obs.prometheus_text()
    assert "unit_export_hits 3" in text
    payload = obs.metrics_json()
    assert payload["counters"]["unit.export.hits"] == 3.0
    out = obs.write_metrics(str(tmp_path / "m.json"))
    assert json.loads(open(out).read())["counters"]["unit.export.hits"] == 3.0
    out = obs.write_metrics(str(tmp_path / "m.prom"))
    assert "unit_export_hits" in open(out).read()
