"""Cross-backend x dtype-policy conformance suite for MSDA.

Every backend returned by ``registry.list_backends()`` is parametrized
against the ``"ref"`` oracle for forward and VJP parity, under every
dtype policy — so any future ``register_backend(...)`` call is
automatically covered the moment it lands (collection re-reads the
registry).  CI shards the matrix via two env vars:

* ``REPRO_CONFORMANCE_BACKENDS`` — comma list restricting the backends
  (e.g. ``"ref,cpu"`` for the Pallas-free CPU lane),
* ``REPRO_CONFORMANCE_POLICIES`` — comma list restricting the dtype
  policies (``"float32"`` / ``"bfloat16"``),
* ``REPRO_CONFORMANCE_FUSE`` — comma list restricting the fusion
  tiers (``"off"`` per-level / ``"prefix"`` partial fusion /
  ``"full"`` whole pyramid),
* ``REPRO_CONFORMANCE_SPARSITY`` — comma list restricting the sparsity
  variants (``"off"`` / ``"topk"``).

Tolerance tiers (documented, per dtype policy):

* ``float32`` policy on the ``"ref"`` backend: **bit-identical** — the
  plan executes the oracle itself, so any difference is a planning bug.
* ``float32`` policy elsewhere: ``2e-5`` fwd / ``5e-4`` VJP — fp32
  reassociation only (fused vs per-corner gather order).
* ``bfloat16`` policy (bf16 slab, fp32 accumulation): ``3e-2`` fwd /
  ``1e-1`` VJP against the *fp32* oracle — one bf16 rounding of the
  value slab (8-bit mantissa => ~4e-3 relative per element, amplified
  by the P*L-term reduction); accumulation error does NOT grow with Q
  because the accumulator stays fp32.

Fusion tiers add **no tolerance of their own** — the same per-policy
tiers above apply to every ``fuse`` variant, mixed-dtype prefixes
included.  The packed super-slab is carrier-coded (an unsigned-int
carrier moves each level's committed bytes verbatim, uniform slabs
keep their float dtype), so a fused-prefix plan reads bit-identical
level data to the per-level plan under the same dtype policy: the only
numeric difference between tiers is gather order inside one fp32
accumulation, which the fp32 reassociation tier already budgets for.

Sparsity tier (``sparsity="topk"`` — lossy BY DESIGN): the pruned plan
is conformance-checked against the *masked-renormalised* oracle
(``msda_sparse.topk_mask_weights`` + ``msda_ref``), NOT the dense one,
at the **float32** tolerances regardless of slab policy — the pruned
executor computes in fp32 end to end.  ``sparsity="off"`` and
``"auto"`` resolved without an autotune race must stay **bitwise**
equal to the dense plan on every backend x policy (lossy modes are
never picked untimed).

Also here: finite-difference gradcheck of the backward path on small
geometries, including sampling locations at and outside the [0, 1]
border where bilinear corner weights zero out — plus the pruned plan
with well-separated attention weights (so eps-perturbations cannot
flip the top-k selection AD differentiates through frozen).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import plan as plan_mod
from repro.kernels import registry
from repro.kernels.plan import MsdaSpec, msda_plan
from repro.kernels.ref import msda_ref

LEVELS = ((10, 6), (5, 3))
B, Q, H, D, P = 2, 21, 2, 8, 3

# documented per-policy tolerance tiers (see module docstring)
FWD_TOL = {"float32": 2e-5, "bfloat16": 3e-2}
VJP_TOL = {"float32": 5e-4, "bfloat16": 1e-1}


def _env_subset(env_var, names):
    env = os.environ.get(env_var)
    if not env:
        return tuple(names)
    keep = {s.strip() for s in env.split(",") if s.strip()}
    unknown = keep - set(names)
    if unknown:
        # a typo'd/renamed name must fail the lane, not skip-collect an
        # empty matrix and report a green job that tested nothing
        raise ValueError(
            f"{env_var} names {sorted(unknown)} not in {sorted(names)}")
    return tuple(n for n in names if n in keep)


BACKENDS = _env_subset("REPRO_CONFORMANCE_BACKENDS", registry.list_backends())
POLICIES = _env_subset("REPRO_CONFORMANCE_POLICIES", ("float32", "bfloat16"))
# fusion tiers: every backend is exercised per-level ('off'), with a
# strict partial-fusion prefix ('prefix' — one fused launch over level 0
# plus a per-level tail; k=1 is the only strict tier a 2-level pyramid
# has) and with the whole-pyramid single launch ('full').  Fusion pins
# are honoured only by fusable backends — elsewhere they're a no-op,
# which this matrix proves.
FUSES = _env_subset("REPRO_CONFORMANCE_FUSE", ("off", "prefix", "full"))
# tier name -> the spec's fuse_levels pin that commits it
_FUSE_PIN = {"off": "off", "prefix": "prefix:1", "full": "on"}
SPARSITIES = _env_subset("REPRO_CONFORMANCE_SPARSITY", ("off", "topk"))


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    plan_mod.clear_plans()
    yield
    plan_mod.clear_plans()


def _inputs(seed=0, levels=LEVELS, b=B, q=Q, h=H, d=D, p=P):
    S = sum(hh * ww for hh, ww in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    value = jax.random.normal(ks[0], (b, S, h, d), jnp.float32)
    # straddle the border on purpose: [-0.2, 1.2] exercises the masked
    # (zero-weight) corners every backend must reproduce
    loc = jax.random.uniform(ks[1], (b, q, h, L, p, 2), minval=-0.2, maxval=1.2)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (b, q, h, L, p)).reshape(b, q, h, -1)
    ).reshape(b, q, h, L, p)
    return value, loc, attn


def _spec(policy, *, train=False, levels=LEVELS, q=Q, h=H, d=D, p=P,
          fuse="auto", sparsity="off", sparsity_k=0, query_order="identity"):
    slab_dtype, accum_dtype = plan_mod.resolve_dtype_policy(policy)
    return MsdaSpec(spatial_shapes=levels, num_heads=h, head_dim=d,
                    num_points=p, num_queries=q, dtype="float32", train=train,
                    slab_dtype=slab_dtype, accum_dtype=accum_dtype,
                    fuse_levels=fuse, sparsity=sparsity,
                    sparsity_k=sparsity_k, query_order=query_order)


# --------------------------------------------------------------------------
# fwd parity: every backend x dtype policy x fusion variant vs the oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("fuse", FUSES)
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_fwd_matches_ref_oracle(backend, policy, fuse):
    value, loc, attn = _inputs()
    plan = msda_plan(_spec(policy, fuse=_FUSE_PIN[fuse]), backend=backend)
    out = plan(value, loc, attn)
    ref = msda_ref(value, LEVELS, loc, attn)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    if backend == "ref" and policy == "float32":
        # the plan runs the oracle itself: bit-identical or planning bug
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        tol = FWD_TOL[policy]
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_bf16_policy_commits_bf16_slabs(backend, policy):
    """The plan must *report* the committed dtype variant per level."""
    plan = msda_plan(_spec(policy), backend=backend)
    report = plan.level_report()
    assert len(report) == len(LEVELS)
    # the ref oracle ignores the slab policy (pure fp32 compute) and its
    # report must say so rather than echo an uncommitted policy
    want = "bfloat16" if policy == "bfloat16" and backend != "ref" else "float32"
    assert all(r["slab_dtype"] == want for r in report)
    assert f"accum={plan.spec.accum_dtype}" in plan.describe()
    assert plan.spec.accum_dtype == "float32"  # wide accumulation, always


# --------------------------------------------------------------------------
# VJP parity: grads of every backend vs the fp32 oracle's grads
# --------------------------------------------------------------------------


@pytest.mark.parametrize("fuse", FUSES)
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_vjp_matches_ref_oracle(backend, policy, fuse):
    value, loc, attn = _inputs()
    plan = msda_plan(_spec(policy, train=True, fuse=_FUSE_PIN[fuse]),
                     backend=backend)

    g = jax.grad(lambda v, l, a: jnp.sum(plan(v, l, a) ** 2),
                 argnums=(0, 1, 2))(value, loc, attn)
    gr = jax.grad(lambda v, l, a: jnp.sum(msda_ref(v, LEVELS, l, a) ** 2),
                  argnums=(0, 1, 2))(value, loc, attn)
    tol = VJP_TOL[policy]
    for got, want, name in zip(g, gr, ("value", "loc", "attn")):
        assert got.dtype == want.dtype, name  # grad dtype == operand dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=tol, rtol=tol, err_msg=f"grad_{name} [{backend}/{policy}]")


# --------------------------------------------------------------------------
# finite-difference gradcheck (bwd path, small geometry, border cases)
# --------------------------------------------------------------------------

# x/y samples: outside (<0, >1), exactly at the border, and interior —
# chosen OFF the bilinear kinks (px = x*W - 0.5 never an integer for
# W, H in {4, 5}) so central differences see a smooth function
_BORDER_COORDS = (-0.12, 0.0, 0.31, 0.52, 0.77, 1.0, 1.09, 0.45)


@pytest.mark.parametrize("backend", [b for b in BACKENDS if b != "ref"])
def test_gradcheck_finite_difference_small_geometry(backend):
    levels = ((4, 5),)
    b, q, h, d, p = 1, 4, 1, 4, 2
    value, _, attn = _inputs(seed=3, levels=levels, b=b, q=q, h=h, d=d, p=p)
    coords = np.resize(np.asarray(_BORDER_COORDS, np.float32), q * p * 2)
    loc = jnp.asarray(coords.reshape(b, q, h, 1, p, 2))
    gout = jax.random.normal(jax.random.PRNGKey(7), (b, q, h * d), jnp.float32)

    plan = msda_plan(_spec("float32", train=True, levels=levels, q=q, h=h,
                           d=d, p=p), backend=backend)
    f = jax.jit(lambda v, l, a: jnp.vdot(plan(v, l, a), gout))
    grads = jax.jit(jax.grad(f, argnums=(0, 1, 2)))(value, loc, attn)

    def fd(operand_idx, arr, flat_idx, eps):
        base = [np.asarray(value, np.float64), np.asarray(loc, np.float64),
                np.asarray(attn, np.float64)]

        def at(delta):
            pert = [x.copy() for x in base]
            pert[operand_idx].flat[flat_idx] += delta
            return float(f(*[jnp.asarray(x, jnp.float32) for x in pert]))

        return (at(eps) - at(-eps)) / (2 * eps)

    # loc: every coordinate (the nonlinear argument — border masks live
    # here); fp32 central differences at eps=1e-3 resolve ~1e-3 abs
    g_loc = np.asarray(grads[1], np.float64)
    for i in range(g_loc.size):
        approx = fd(1, loc, i, eps=1e-3)
        np.testing.assert_allclose(
            g_loc.flat[i], approx, atol=5e-3, rtol=5e-2,
            err_msg=f"grad_loc[{i}] (coord={np.asarray(loc).flat[i]:.2f})")

    # value / attn enter linearly: FD is exact up to fp noise; spot-check
    for operand_idx, arr, g in ((0, value, grads[0]), (2, attn, grads[2])):
        garr = np.asarray(g, np.float64)
        for i in range(0, garr.size, max(garr.size // 7, 1)):
            approx = fd(operand_idx, arr, i, eps=1e-2)
            np.testing.assert_allclose(garr.flat[i], approx, atol=2e-3,
                                       rtol=2e-2, err_msg=f"operand{operand_idx}[{i}]")


@pytest.mark.parametrize("backend", [b for b in BACKENDS if b != "ref"])
def test_grad_zero_far_outside_border(backend):
    """>1 pixel outside the map every corner weight masks to zero, so the
    op is locally constant: grad_loc == 0 and the output ignores attn
    mass placed there."""
    levels = ((4, 5),)
    b, q, h, d, p = 1, 3, 1, 4, 2
    value, _, attn = _inputs(seed=5, levels=levels, b=b, q=q, h=h, d=d, p=p)
    loc = jnp.full((b, q, h, 1, p, 2), 1.8)  # deep outside
    plan = msda_plan(_spec("float32", train=True, levels=levels, q=q, h=h,
                           d=d, p=p), backend=backend)
    out = plan(value, loc, attn)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
    g_loc = jax.grad(lambda l: jnp.sum(plan(value, l, attn) ** 2))(loc)
    np.testing.assert_allclose(np.asarray(g_loc), 0.0, atol=1e-6)


# --------------------------------------------------------------------------
# sparsity tier: dense fallback bitwise, pruned vs the masked oracle
# --------------------------------------------------------------------------


@pytest.mark.skipif("off" not in SPARSITIES, reason="sparsity=off lane off")
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_sparsity_auto_unraced_is_bitwise_dense(backend, policy):
    """``sparsity="auto"``/``query_order="auto"`` WITHOUT an autotune
    race must resolve to the dense executor and identity order — lossy
    modes are never picked untimed — and match the explicit-off plan
    bitwise, forward and full VJP."""
    value, loc, attn = _inputs()
    base = msda_plan(_spec(policy, train=True), backend=backend)
    auto = msda_plan(_spec(policy, train=True, sparsity="auto",
                           query_order="auto"), backend=backend)
    assert auto.tuning.sparsity == "dense"
    assert auto.tuning.query_order == "identity"

    def vjp(plan):
        out = plan(value, loc, attn)
        g = jax.grad(lambda v, l, a: jnp.sum(plan(v, l, a) ** 2),
                     argnums=(0, 1, 2))(value, loc, attn)
        return (out,) + g

    for got, want, name in zip(vjp(auto), vjp(base),
                               ("out", "gvalue", "gloc", "gattn")):
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want),
            err_msg=f"{name} [{backend}/{policy}]")


@pytest.mark.skipif("topk" not in SPARSITIES, reason="topk lane off")
@pytest.mark.parametrize("policy", POLICIES)
def test_pruned_matches_masked_renormalised_oracle(policy):
    """The pruned plan vs ``msda_ref`` over top-k-masked renormalised
    weights — fp32 tolerances regardless of slab policy (the pruned
    executor computes in fp32; the slab policy is dense-path tuning)."""
    from repro.kernels import msda_sparse

    value, loc, attn = _inputs()
    k = 4  # of L*P = 6 cells
    plan = msda_plan(_spec(policy, train=True, sparsity="topk",
                           sparsity_k=k), backend="cpu")
    assert plan.tuning.sparsity == "topk"
    masked = msda_sparse.topk_mask_weights(attn, k)
    ref = msda_ref(value, LEVELS, loc, masked)
    out = plan(value, loc, attn)
    tol = FWD_TOL["float32"]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)

    g = jax.grad(lambda v, l, a: jnp.sum(plan(v, l, a) ** 2),
                 argnums=(0, 1, 2))(value, loc, attn)
    gr = jax.grad(
        lambda v, l, a: jnp.sum(
            msda_ref(v, LEVELS, l, msda_sparse.topk_mask_weights(a, k)) ** 2),
        argnums=(0, 1, 2))(value, loc, attn)
    tol = VJP_TOL["float32"]
    for got, want, name in zip(g, gr, ("value", "loc", "attn")):
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=tol, rtol=tol, err_msg=f"grad_{name} [pruned/{policy}]")


@pytest.mark.skipif("topk" not in SPARSITIES, reason="topk lane off")
def test_gradcheck_finite_difference_pruned():
    """FD gradcheck of the pruned plan.  Attention logits are spaced
    >= 2.0 apart per query, so the kept/dropped weight gap (~0.76)
    dwarfs the FD eps and no perturbation can flip the top-k selection
    that AD differentiates through frozen.  Gradients w.r.t. pruned-out
    cells must be zero on both sides; k=2 of 3 keeps the renormalised
    weights a genuine function of attn (k=1 would make them constant)."""
    levels = ((4, 5),)
    b, q, h, d, p = 1, 4, 1, 4, 3  # L*P = 3 cells, keep k=2
    value, _, _ = _inputs(seed=3, levels=levels, b=b, q=q, h=h, d=d, p=p)
    coords = np.resize(np.asarray(_BORDER_COORDS, np.float32), q * p * 2)
    loc = jnp.asarray(coords.reshape(b, q, h, 1, p, 2))
    # rotate which cells win so both kept/dropped index paths vary; the
    # kept-vs-dropped weight gap (softmax([3,1.5,0]) -> 0.175 vs 0.039)
    # stays an order of magnitude above the FD eps
    logits = np.asarray([[3.0, 1.5, 0.0], [0.0, 3.0, 1.5],
                         [1.5, 0.0, 3.0], [3.0, 0.0, 1.5]],
                        np.float32).reshape(b, q, h, 1, p)
    attn = jax.nn.softmax(jnp.asarray(logits).reshape(b, q, h, -1), axis=-1
                          ).reshape(b, q, h, 1, p)
    gout = jax.random.normal(jax.random.PRNGKey(7), (b, q, h * d), jnp.float32)

    plan = msda_plan(_spec("float32", train=True, levels=levels, q=q, h=h,
                           d=d, p=p, sparsity="topk", sparsity_k=2),
                     backend="cpu")
    f = jax.jit(lambda v, l, a: jnp.vdot(plan(v, l, a), gout))
    grads = jax.jit(jax.grad(f, argnums=(0, 1, 2)))(value, loc, attn)

    def fd(operand_idx, flat_idx, eps):
        base = [np.asarray(value, np.float64), np.asarray(loc, np.float64),
                np.asarray(attn, np.float64)]

        def at(delta):
            pert = [x.copy() for x in base]
            pert[operand_idx].flat[flat_idx] += delta
            return float(f(*[jnp.asarray(x, jnp.float32) for x in pert]))

        return (at(eps) - at(-eps)) / (2 * eps)

    g_loc = np.asarray(grads[1], np.float64)
    for i in range(g_loc.size):
        np.testing.assert_allclose(
            g_loc.flat[i], fd(1, i, eps=1e-3), atol=5e-3, rtol=5e-2,
            err_msg=f"grad_loc[{i}] (coord={np.asarray(loc).flat[i]:.2f})")

    g_attn = np.asarray(grads[2], np.float64)
    for i in range(g_attn.size):
        np.testing.assert_allclose(
            g_attn.flat[i], fd(2, i, eps=1e-2), atol=2e-3, rtol=2e-2,
            err_msg=f"grad_attn[{i}]")

    g_val = np.asarray(grads[0], np.float64)
    for i in range(0, g_val.size, max(g_val.size // 7, 1)):
        np.testing.assert_allclose(g_val.flat[i], fd(0, i, eps=1e-2),
                                   atol=2e-3, rtol=2e-2,
                                   err_msg=f"grad_value[{i}]")


# --------------------------------------------------------------------------
# registry auto-coverage: a freshly registered backend enters the matrix
# --------------------------------------------------------------------------


def test_new_backend_is_auto_covered():
    """list_backends() is the parametrization source, so a backend
    registered before collection lands in every test above; this guards
    the mechanism itself."""

    def builder(spec, tuning):
        return lambda v, l, a: msda_ref(v, spec.spatial_shapes, l, a)

    registry.register_backend("conformance-probe", builder)
    try:
        assert "conformance-probe" in registry.list_backends()
        assert set(BACKENDS) <= set(registry.list_backends())
    finally:
        registry.unregister_backend("conformance-probe")


# --------------------------------------------------------------------------
# degradation-ladder conformance: every fallback rung vs its primary
# --------------------------------------------------------------------------
# The serving resilience layer (``serving/resilience.py``) demotes a
# failing plan down ``MsdaPlan.fallback()`` — these tiers pin what a
# demotion costs numerically, per backend x policy (and, via BACKENDS,
# auto-cover any future ``register_backend`` the moment it lands):
#
# * same-backend rungs (fused -> per-level, sparse -> dense identity
#   with a keep-everything k) are **bitwise** — the rung reads the same
#   slab bytes and accumulates in the same dtype, only launch structure
#   changes;
# * the terminal ``ref`` rung matches within the documented per-policy
#   forward tiers (FWD_TOL) — same budget as any backend-vs-oracle gap;
# * every rung is a heuristic build: zero autotune races, never
#   persisted as a winner, and the chain terminates at ``ref``.


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_fallback_ladder_rungs_are_consistent(backend, policy):
    value, loc, attn = _inputs()
    plan_mod.reset_autotune_stats()
    primary = msda_plan(_spec(policy, fuse="on"), backend=backend,
                        tune="heuristic")
    chain = primary.fallback_chain()
    if primary.backend == "ref":
        assert not chain and primary.fallback() is None
        return
    assert chain, f"{primary.rung_label()} has no fallback rung"
    assert chain[-1].backend == "ref", [r.rung_label() for r in chain]
    assert chain[-1].fallback() is None, "ladder does not terminate"
    prev, prev_out = primary, np.asarray(primary(value, loc, attn))
    for rung in chain:
        assert rung.tune == "heuristic", rung.describe()
        out = np.asarray(rung(value, loc, attn))
        if rung.backend == prev.backend:
            np.testing.assert_array_equal(
                out, prev_out,
                err_msg=f"{prev.rung_label()} -> {rung.rung_label()} "
                        f"must be bitwise (same backend, same slab bytes)")
        else:
            np.testing.assert_allclose(
                out, prev_out, rtol=0, atol=FWD_TOL[policy],
                err_msg=f"{prev.rung_label()} -> {rung.rung_label()}")
        prev, prev_out = rung, out
    # demotions must never race or persist winners
    assert plan_mod.autotune_stats()["raced"] == 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_fallback_sparse_demotes_to_dense(backend):
    """A top-k plan's first rung drops sparsity (and Morton order) on
    the SAME backend.  With a keep-every-cell ``sparsity_k`` the prune
    is a no-op, so the demotion is numerically the dense plan — the
    fp32 tier bounds the renormalisation round-trip.  (The lossy gap of
    a truly pruned primary is covered by the masked-renormalised oracle
    tests above; a demotion never has to reproduce the loss.)"""
    L = len(LEVELS)
    spec = _spec("float32", sparsity="topk", sparsity_k=L * P)
    primary = msda_plan(spec, backend=backend, tune="heuristic")
    if primary.tuning.sparsity != "topk":
        pytest.skip(f"{backend} does not execute top-k plans")
    rung = primary.fallback()
    assert rung is not None and rung.backend == primary.backend
    assert rung.tuning.sparsity == "dense"
    assert rung.tuning.query_order == "identity"
    value, loc, attn = _inputs()
    np.testing.assert_allclose(
        np.asarray(rung(value, loc, attn)),
        np.asarray(primary(value, loc, attn)),
        rtol=0, atol=FWD_TOL["float32"],
        err_msg=f"{primary.rung_label()} -> {rung.rung_label()}")
