"""Sharding rules + distributed MSDA (shard_map on a debug mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, reduced
from repro.core import msda as msda_mod
from repro.kernels.ref import msda_ref
from repro.launch import mesh as mesh_lib
from repro.sharding import rules
from repro.train import state as train_state


def test_param_specs_cover_all_archs():
    mesh = mesh_lib.make_debug_mesh()
    for arch in ("llama3-8b", "dbrx-132b", "grok-1-314b", "xlstm-350m",
                 "recurrentgemma-2b", "whisper-large-v3", "phi-3-vision-4.2b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda c=cfg: train_state.init_model(jax.random.PRNGKey(0), c))
        moe_e = cfg.moe.num_experts if cfg.moe else 0
        specs = rules.param_specs(shapes, mesh, moe_experts=moe_e)
        for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(specs)[0],
        ):
            assert len(spec) <= leaf.ndim, (arch, path, spec, leaf.shape)


def test_resolve_axes_multi_pod():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    assert rules.resolve_axis("dp", mesh) == ("pod", "data")
    assert rules.resolve_axis("tp", mesh) == "model"
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    assert rules.resolve_axis("dp", mesh1) == "data"


def test_hint_degrades_nondivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with rules.use_mesh(mesh):
        x = jnp.ones((3, 5))
        y = rules.hint(x, "dp", "tp")  # 3 % 1 == 0 fine on 1-dev mesh
        assert y.shape == x.shape


def test_ep_vs_tp_moe_rule():
    mesh = mesh_lib.make_debug_mesh()  # model axis size 1 -> divisible
    cfg = get_config("grok-1-314b")
    shapes = jax.eval_shape(lambda: train_state.init_model(jax.random.PRNGKey(0), cfg))
    specs = rules.param_specs(shapes, mesh, moe_experts=8)
    # just structural sanity on a 1-dev mesh; the divisibility branch is
    # exercised against the production mesh in the dry-run
    leaves = jax.tree_util.tree_flatten_with_path(specs)[0]
    assert any("experts_wi" in str(p) for p, _ in leaves)


@pytest.mark.parametrize("query_parallel", [False, True])
def test_distributed_msda_matches_ref(query_parallel):
    levels = ((8, 8), (4, 4))
    B, Q, H, D, Pn = 2, 16, 2, 8, 2
    S = sum(h * w for h, w in levels)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, Q, H, len(levels), Pn, 2))
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, len(levels), Pn)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, len(levels), Pn)
    ref = msda_ref(value, levels, loc, attn)
    mesh = mesh_lib.make_debug_mesh()
    with rules.use_mesh(mesh):
        out = msda_mod.distributed_msda(
            value, levels, loc, attn, mesh=mesh,
            query_parallel=query_parallel, backend="ref",
        )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_distributed_msda_grad_value_reduction():
    """query_parallel mode: grad wrt (replicated) value must equal the
    single-device grad — shard_map's transpose inserts the psum that
    realises the paper's staggered-scatter as partials+reduce."""
    levels = ((6, 6),)
    B, Q, H, D, Pn = 1, 8, 1, 8, 2
    S = 36
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, Q, H, 1, Pn, 2))
    attn = jax.nn.softmax(jax.random.normal(ks[2], (B, Q, H, 1, Pn)), axis=-1)
    mesh = mesh_lib.make_debug_mesh()

    def loss_dist(v):
        return jnp.sum(
            msda_mod.distributed_msda(
                v, levels, loc, attn, mesh=mesh, query_parallel=True, backend="ref"
            )
        )

    def loss_ref(v):
        return jnp.sum(msda_ref(v, levels, loc, attn))

    with rules.use_mesh(mesh):
        g1 = jax.grad(loss_dist)(value)
    g2 = jax.grad(loss_ref)(value)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_msda_attention_module():
    from repro.configs.base import MSDAConfig

    mc = MSDAConfig(levels=((8, 8), (4, 4)), num_points=2, num_heads=2, backend="ref")
    d = 32
    p = msda_mod.init_msda_attention(jax.random.PRNGKey(0), d, mc)
    B, Q = 2, 10
    S = sum(h * w for h, w in mc.levels)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, Q, d))
    feats = jax.random.normal(jax.random.PRNGKey(2), (B, S, d))
    refs = jax.random.uniform(jax.random.PRNGKey(3), (B, Q, 2))
    out = msda_mod.msda_attention(p, mc, q, feats, refs)
    assert out.shape == (B, Q, d)
    assert jnp.isfinite(out).all()
    # pallas backend agrees with ref backend through the module
    out_pal = msda_mod.msda_attention(p, mc, q, feats, refs, backend="pallas")
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out), atol=2e-5)
