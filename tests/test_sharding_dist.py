"""Sharding rules + distributed MSDA (shard_map on a debug mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, reduced
from repro.core import msda as msda_mod
from repro.kernels.ref import msda_ref
from repro.launch import mesh as mesh_lib
from repro.sharding import rules
from repro.train import state as train_state


def test_param_specs_cover_all_archs():
    mesh = mesh_lib.make_debug_mesh()
    for arch in ("llama3-8b", "dbrx-132b", "grok-1-314b", "xlstm-350m",
                 "recurrentgemma-2b", "whisper-large-v3", "phi-3-vision-4.2b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda c=cfg: train_state.init_model(jax.random.PRNGKey(0), c))
        moe_e = cfg.moe.num_experts if cfg.moe else 0
        specs = rules.param_specs(shapes, mesh, moe_experts=moe_e)
        for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(specs)[0],
        ):
            assert len(spec) <= leaf.ndim, (arch, path, spec, leaf.shape)


def test_resolve_axes_multi_pod():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    assert rules.resolve_axis("dp", mesh) == ("pod", "data")
    assert rules.resolve_axis("tp", mesh) == "model"
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    assert rules.resolve_axis("dp", mesh1) == "data"


def test_hint_degrades_nondivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with rules.use_mesh(mesh):
        x = jnp.ones((3, 5))
        y = rules.hint(x, "dp", "tp")  # 3 % 1 == 0 fine on 1-dev mesh
        assert y.shape == x.shape


def test_ep_vs_tp_moe_rule():
    mesh = mesh_lib.make_debug_mesh()  # model axis size 1 -> divisible
    cfg = get_config("grok-1-314b")
    shapes = jax.eval_shape(lambda: train_state.init_model(jax.random.PRNGKey(0), cfg))
    specs = rules.param_specs(shapes, mesh, moe_experts=8)
    # just structural sanity on a 1-dev mesh; the divisibility branch is
    # exercised against the production mesh in the dry-run
    leaves = jax.tree_util.tree_flatten_with_path(specs)[0]
    assert any("experts_wi" in str(p) for p, _ in leaves)


@pytest.mark.parametrize("query_parallel", [False, True])
def test_distributed_msda_matches_ref(query_parallel):
    levels = ((8, 8), (4, 4))
    B, Q, H, D, Pn = 2, 16, 2, 8, 2
    S = sum(h * w for h, w in levels)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, Q, H, len(levels), Pn, 2))
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, len(levels), Pn)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, len(levels), Pn)
    ref = msda_ref(value, levels, loc, attn)
    mesh = mesh_lib.make_debug_mesh()
    with rules.use_mesh(mesh):
        out = msda_mod.distributed_msda(
            value, levels, loc, attn, mesh=mesh,
            query_parallel=query_parallel, backend="ref",
        )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_distributed_msda_grad_value_reduction():
    """query_parallel mode: grad wrt (replicated) value must equal the
    single-device grad — shard_map's transpose inserts the psum that
    realises the paper's staggered-scatter as partials+reduce."""
    levels = ((6, 6),)
    B, Q, H, D, Pn = 1, 8, 1, 8, 2
    S = 36
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, Q, H, 1, Pn, 2))
    attn = jax.nn.softmax(jax.random.normal(ks[2], (B, Q, H, 1, Pn)), axis=-1)
    mesh = mesh_lib.make_debug_mesh()

    def loss_dist(v):
        return jnp.sum(
            msda_mod.distributed_msda(
                v, levels, loc, attn, mesh=mesh, query_parallel=True, backend="ref"
            )
        )

    def loss_ref(v):
        return jnp.sum(msda_ref(v, levels, loc, attn))

    with rules.use_mesh(mesh):
        g1 = jax.grad(loss_dist)(value)
    g2 = jax.grad(loss_ref)(value)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


# --------------------------------------------------------------------------
# 2D (dp x tp) query sharding + ring-reduced grad_value slabs
# (conftest splits the host into 4 virtual CPU devices so these meshes
# and their collectives — ppermute rings, psums — actually execute)
# --------------------------------------------------------------------------

from repro.kernels import msda_bwd
from repro.kernels import plan as pm


def _mesh(dp, tp):
    if len(jax.devices()) < dp * tp:
        pytest.skip(f"needs {dp * tp} devices")
    return mesh_lib.make_mesh_2d(dp, tp)


_LEVELS = ((8, 8), (4, 4))


@pytest.fixture(scope="module")
def prob():
    """One small MSDA problem: B=2, Q=16 (divides every mesh under test)."""
    B, Q, H, D, Pn = 2, 16, 2, 8, 2
    S = sum(h * w for h, w in _LEVELS)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, Q, H, len(_LEVELS), Pn, 2))
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, len(_LEVELS), Pn)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, len(_LEVELS), Pn)
    spec = pm.MsdaSpec(spatial_shapes=_LEVELS, num_heads=H, head_dim=D,
                       num_points=Pn, num_queries=Q, train=True)
    return value, loc, attn, spec


def test_ring_allreduce_equals_psum():
    """The ppermute ring is an all-reduce: every device ends with the
    full sum, bitwise equal to psum on a 2-wide axis (fp add is
    commutative; the ring order is a rotation of the device order)."""
    mesh = _mesh(2, 2)
    x = jnp.arange(2 * 37 * 3, dtype=jnp.float32).reshape(2, 37, 3) * 0.37
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def ring(v):
        return msda_bwd.ring_allreduce(v, "model", 2, axis=1)

    def psum(v):
        return jax.lax.psum(v, "model")

    kw = dict(mesh=mesh, in_specs=P(None, None, None),
              out_specs=P(None, None, None), check_rep=False)
    # chunk axis 37 does not divide the axis size: exercises the padding
    out_ring = shard_map(ring, **kw)(x)
    out_psum = shard_map(psum, **kw)(x)
    assert np.array_equal(np.asarray(out_ring), np.asarray(out_psum))


def test_query2d_plan_matches_ref_fwd_and_vjp(prob):
    """Acceptance: on a 2x2 mesh a 2D-sharded plan's forward and VJP
    match the unsharded reference within conformance tolerances."""
    value, loc, attn, spec = prob
    mesh = _mesh(2, 2)
    plan = pm.msda_plan(spec, backend="ref", mesh=mesh, sharding="2d")
    assert plan.sharding_mode == "query2d"
    assert plan.grad_reduce == "ring"
    assert plan.local_spec.num_queries == spec.num_queries // 4

    ref = msda_ref(value, _LEVELS, loc, attn)
    out = plan(value, loc, attn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    g = jax.grad(lambda v, l, a: jnp.sum(plan(v, l, a) ** 2), argnums=(0, 1, 2))(
        value, loc, attn)
    gref = jax.grad(
        lambda v, l, a: jnp.sum(msda_ref(v, _LEVELS, l, a) ** 2), argnums=(0, 1, 2)
    )(value, loc, attn)
    for got, want in zip(g, gref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("sharding,mode", [("2d", "query2d"), ("1d", "query")])
def test_ring_grad_value_equals_allreduce_bitwise(prob, sharding, mode):
    """Acceptance: the ring-reduced grad_value equals the all-reduce
    result BITWISE in fp32.  grad_reduce='psum' builds the identical
    backward with the tp-axis ring swapped for a psum, so the paths
    differ only in the collective under test; on a 2-wide tp axis the
    ring's rotated summation order is a commutation of psum's."""
    value, loc, attn, spec = prob
    mesh = _mesh(2, 2)
    kw = dict(backend="ref", mesh=mesh, sharding=sharding, query_parallel=True)
    p_ring = pm.msda_plan(spec, grad_reduce="ring", **kw)
    p_psum = pm.msda_plan(spec, grad_reduce="psum", **kw)
    assert p_ring.sharding_mode == p_psum.sharding_mode == mode
    assert (p_ring.grad_reduce, p_psum.grad_reduce) == ("ring", "psum")
    g_ring = jax.grad(lambda v: jnp.sum(p_ring(v, loc, attn) ** 2))(value)
    g_psum = jax.grad(lambda v: jnp.sum(p_psum(v, loc, attn) ** 2))(value)
    assert g_ring.dtype == jnp.float32
    assert np.array_equal(np.asarray(g_ring), np.asarray(g_psum))


def test_2d_falls_back_when_tp_does_not_divide(prob):
    """Nondivisible Q (or H) must fall back down the ladder — and the
    fallback plan must still compute the right answer, not idle shards
    silently."""
    del prob
    mesh = _mesh(2, 2)
    # Q=10: not divisible by dp*tp=4, divisible by tp=2 -> 1D query mode
    spec10 = pm.MsdaSpec(spatial_shapes=_LEVELS, num_heads=2, head_dim=8,
                         num_points=2, num_queries=10)
    assert pm.resolve_sharding(spec10, mesh, True, "2d")[0] == "query"
    # Q=9, H=3: neither queries nor heads divide tp=2 -> batch-only
    spec9 = pm.MsdaSpec(spatial_shapes=_LEVELS, num_heads=3, head_dim=8,
                        num_points=2, num_queries=9)
    assert pm.resolve_sharding(spec9, mesh, True, "2d")[0] == "batch"

    # the Q=10 fallback executes correctly end to end
    B, Q, H, D, Pn = 2, 10, 2, 8, 2
    S = sum(h * w for h, w in _LEVELS)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, Q, H, len(_LEVELS), Pn, 2))
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, len(_LEVELS), Pn)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, len(_LEVELS), Pn)
    plan = pm.msda_plan(spec10, backend="ref", mesh=mesh, sharding="2d")
    assert plan.sharding_mode == "query"
    ref = msda_ref(value, _LEVELS, loc, attn)
    np.testing.assert_allclose(np.asarray(plan(value, loc, attn)),
                               np.asarray(ref), atol=1e-5)


def test_degenerate_meshes_resolve_to_1d(prob):
    """1xN and Nx1 meshes have one trivial axis: a 2D request resolves
    to the equivalent 1D rung instead of pretending to be 2D."""
    _, _, _, spec = prob
    m14 = _mesh(1, 4)
    m41 = _mesh(4, 1)
    # 1x4: dp is trivial -> plain query-parallel over tp
    assert pm.resolve_sharding(spec, m14, True, "2d")[0] == "query"
    # 4x1: tp is trivial -> batch-only dp sharding
    assert pm.resolve_sharding(spec, m41, True, "2d")[0] == "batch"


def test_describe_reports_sharding_mode_and_mesh_axes(prob):
    """Satellite: describe() states the resolved mode, the mesh
    topology, which axes shard Q, and the grad_value reduction — the
    truthful output docs/sharding.md quotes."""
    value, loc, attn, spec = prob
    del value, loc, attn
    mesh = _mesh(2, 2)
    text = pm.msda_plan(spec, backend="ref", mesh=mesh, sharding="2d").describe()
    assert "sharding=query2d" in text
    assert "mesh: data2xmodel2" in text
    assert "Q->data+model" in text
    assert "grad_value=ring" in text
    assert "per-shard: Q=4" in text
    rep = pm.msda_plan(spec, backend="ref", mesh=mesh, sharding="2d").sharding_report()
    assert rep["mode"] == "query2d"
    assert rep["query_axes"] == ("data", "model")
    assert rep["grad_reduce"] == "ring"
    # the 1D head-mode report stays truthful too
    nq = pm.msda_plan(dataclasses_replace_q(spec, 10), backend="ref", mesh=mesh)
    assert f"sharding={nq.sharding_mode}" in nq.describe()


def dataclasses_replace_q(spec, q):
    import dataclasses

    return dataclasses.replace(spec, num_queries=q)


def test_autotune_races_1d_vs_2d_and_persists(prob, tmp_path, monkeypatch):
    """Tentpole: under tune='autotune' + sharding='auto' the sharding
    mode is part of the autotune space — raced once, persisted in the
    winner cache ({"block_q","slab_dtypes","sharding"} schema), and a
    fresh plan build resolves from the cache with ZERO timing runs."""
    value, loc, attn, spec = prob
    del value, loc, attn
    mesh = _mesh(2, 2)
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    pm.clear_plans()
    pm.reset_autotune_stats()
    plan = pm.msda_plan(spec, backend="ref", tune="autotune", mesh=mesh,
                        query_parallel=True)
    assert plan.sharding_mode in ("query", "query2d")  # timing decides
    # >= 1: the grad_reduce (ring-vs-psum) race rides along for train specs
    assert pm.autotune_stats()["raced"] >= 1
    winner = pm.get_autotune_winner(
        spec, "ref", mesh_suffix=pm.mesh_winner_suffix(mesh, True))
    assert winner is not None and winner["sharding"] in ("1d", "2d")

    pm.clear_plans()
    pm.reset_autotune_stats()
    plan2 = pm.msda_plan(spec, backend="ref", tune="autotune", mesh=mesh,
                         query_parallel=True)
    stats = pm.autotune_stats()
    assert stats["raced"] == 0 and stats["cache_hits"] >= 1
    assert plan2.sharding_mode == plan.sharding_mode
    pm.clear_plans()


def test_plan_store_roundtrip_restores_2d_zero_races(prob, tmp_path, monkeypatch):
    """Acceptance: a PlanStore round-trip restores the 2D mode with zero
    autotune timing runs and an identical describe()."""
    from repro.serving.persistence import PlanStore

    value, loc, attn, spec = prob
    del value, loc, attn
    mesh = _mesh(2, 2)
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at1.json"))
    pm.clear_plans()
    pm.reset_autotune_stats()
    plan = pm.msda_plan(spec, backend="cpu", tune="autotune", mesh=mesh,
                        sharding="2d", query_parallel=True)
    assert plan.sharding_mode == "query2d"
    store = PlanStore(str(tmp_path / "plans.json"))
    assert store.save_plans([plan]) == 1

    # "restart": fresh plan cache, fresh (empty) winner cache
    pm.clear_plans()
    pm.reset_autotune_stats()
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at2.json"))
    report = store.restore(mesh=mesh)
    assert not report.skipped and not report.describe_mismatches
    assert pm.autotune_stats()["raced"] == 0
    [restored] = report.plans
    assert restored.sharding_mode == "query2d"
    # the raced reduction (ring or psum — timing decides) is restored
    assert restored.grad_reduce == plan.grad_reduce in ("ring", "psum")
    assert persistence_norm(restored.describe()) == persistence_norm(plan.describe())
    pm.clear_plans()


def persistence_norm(text):
    from repro.serving.persistence import _norm_describe

    return _norm_describe(text)


def test_plan_store_sharded_entry_degrades_without_mesh(prob, tmp_path, monkeypatch):
    """A distributed entry restored by a process with no (or the wrong)
    mesh degrades to a skip — never a crash, never a silently-local
    plan."""
    from repro.serving.persistence import PlanStore

    value, loc, attn, spec = prob
    del value, loc, attn
    mesh = _mesh(2, 2)
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    plan = pm.msda_plan(spec, backend="ref", mesh=mesh, sharding="2d")
    store = PlanStore(str(tmp_path / "plans.json"))
    store.save_plans([plan])
    pm.clear_plans()
    report = store.restore()  # no mesh
    assert not report.plans
    assert len(report.skipped) == 1 and "mesh" in report.skipped[0]
    report = store.restore(mesh=_mesh(1, 4))  # wrong topology
    assert not report.plans
    assert len(report.skipped) == 1 and "mismatch" in report.skipped[0]
    pm.clear_plans()


def test_msda_attention_module():
    from repro.configs.base import MSDAConfig

    mc = MSDAConfig(levels=((8, 8), (4, 4)), num_points=2, num_heads=2, backend="ref")
    d = 32
    p = msda_mod.init_msda_attention(jax.random.PRNGKey(0), d, mc)
    B, Q = 2, 10
    S = sum(h * w for h, w in mc.levels)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, Q, d))
    feats = jax.random.normal(jax.random.PRNGKey(2), (B, S, d))
    refs = jax.random.uniform(jax.random.PRNGKey(3), (B, Q, 2))
    out = msda_mod.msda_attention(p, mc, q, feats, refs)
    assert out.shape == (B, Q, d)
    assert jnp.isfinite(out).all()
    # pallas backend agrees with ref backend through the module
    out_pal = msda_mod.msda_attention(p, mc, q, feats, refs, backend="pallas")
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out), atol=2e-5)


# --------------------------------------------------------------------------
# batch x query hybrid sharding ('batchquery'): the whole device set is
# re-racked as (batch_tile x query_fan) so mid-size batches on tp-less
# meshes shard BOTH axes instead of idling on the batch rung
# --------------------------------------------------------------------------


def test_hybrid_resolution_ladder(prob):
    _, _, _, spec = prob
    m41 = _mesh(4, 1)
    # forced: 4 devices re-racked as B->x2, Q->x2
    mode, local = pm.resolve_sharding(spec, m41, True, "hybrid")
    assert mode == "batchquery"
    assert local.num_queries == spec.num_queries // 2
    # auto on a tp-less mesh with query-parallel intent prefers hybrid
    assert pm.resolve_sharding(spec, m41, True, "auto")[0] == "batchquery"
    # the pinned 1d/2d ladders are untouched (degenerate-mesh contract)
    assert pm.resolve_sharding(spec, m41, True, "2d")[0] == "batch"
    assert pm.resolve_sharding(spec, m41, True, "1d")[0] == "batch"
    # no query-parallel intent -> hybrid never surprise-tiles Q
    assert pm.resolve_sharding(spec, m41, False, "auto")[0] == "batch"
    # hybrid needs Q divisible by the query fan; Q=9 falls down the ladder
    spec9 = dataclasses_replace_q(spec, 9)
    assert pm.resolve_sharding(spec9, m41, True, "hybrid")[0] != "batchquery"


def test_hybrid_plan_matches_ref_fwd_and_vjp(prob):
    value, loc, attn, spec = prob
    mesh = _mesh(4, 1)
    plan = pm.msda_plan(spec, backend="ref", mesh=mesh, sharding="hybrid")
    assert plan.sharding_mode == "batchquery"
    assert plan.batch_tile == 2
    assert plan.local_spec.num_queries == spec.num_queries // 2
    rep = plan.sharding_report()
    assert rep["mode"] == "batchquery" and rep["batch_tile"] == 2
    assert "B->x2" in plan.describe() and "Q->x2" in plan.describe()

    ref = msda_ref(value, _LEVELS, loc, attn)
    np.testing.assert_allclose(np.asarray(plan(value, loc, attn)),
                               np.asarray(ref), atol=1e-5)
    g = jax.grad(lambda v, l, a: jnp.sum(plan(v, l, a) ** 2), argnums=(0, 1, 2))(
        value, loc, attn)
    gref = jax.grad(
        lambda v, l, a: jnp.sum(msda_ref(v, _LEVELS, l, a) ** 2), argnums=(0, 1, 2)
    )(value, loc, attn)
    for got, want in zip(g, gref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_autotune_races_hybrid_and_persists(prob, tmp_path, monkeypatch):
    """Satellite: on a tp-less mesh the auto race includes the hybrid
    rung; the winner persists ('hybrid' in the cache schema) and a fresh
    build resolves from the cache with zero timing runs."""
    _, _, _, spec = prob
    mesh = _mesh(4, 1)
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    pm.clear_plans()
    pm.reset_autotune_stats()
    plan = pm.msda_plan(spec, backend="ref", tune="autotune", mesh=mesh,
                        query_parallel=True)
    assert plan.sharding_mode in ("batch", "batchquery")  # timing decides
    assert pm.autotune_stats()["raced_mesh"] >= 1
    winner = pm.get_autotune_winner(
        spec, "ref", mesh_suffix=pm.mesh_winner_suffix(mesh, True))
    assert winner is not None and winner["sharding"] in ("1d", "hybrid")

    pm.clear_plans()
    pm.reset_autotune_stats()
    plan2 = pm.msda_plan(spec, backend="ref", tune="autotune", mesh=mesh,
                         query_parallel=True)
    stats = pm.autotune_stats()
    assert stats["raced"] == 0 and stats["cache_hits"] >= 1
    assert plan2.sharding_mode == plan.sharding_mode
    pm.clear_plans()


def test_plan_store_roundtrip_restores_hybrid(prob, tmp_path, monkeypatch):
    from repro.serving.persistence import PlanStore

    _, _, _, spec = prob
    mesh = _mesh(4, 1)
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    pm.clear_plans()
    plan = pm.msda_plan(spec, backend="ref", mesh=mesh, sharding="hybrid")
    store = PlanStore(str(tmp_path / "plans.json"))
    assert store.save_plans([plan]) == 1
    pm.clear_plans()
    report = store.restore(mesh=mesh)
    assert not report.skipped and not report.describe_mismatches
    [restored] = report.plans
    assert restored.sharding_mode == "batchquery"
    assert restored.batch_tile == 2
    assert persistence_norm(restored.describe()) == persistence_norm(plan.describe())
    pm.clear_plans()
