"""End-to-end behaviour tests for the whole system."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}


def test_train_cli_end_to_end(tmp_path):
    """The training driver runs, converges, checkpoints, and restores."""
    cmd = [
        sys.executable, "-m", "repro.launch.train", "--arch", "llama3-8b",
        "--smoke", "--steps", "12", "--batch", "4", "--seq", "32",
        "--lr", "3e-3", "--ckpt-dir", str(tmp_path), "--ckpt-every", "6",
    ]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=420,
                         env=_ENV, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done: 12 steps" in out.stdout
    # resume from checkpoint
    cmd2 = list(cmd)
    cmd2[cmd2.index("--steps") + 1] = "14"
    out2 = subprocess.run(cmd2, capture_output=True, text=True, timeout=420,
                          env=_ENV, cwd="/root/repo")
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "restored step 12" in out2.stdout


def test_serve_cli():
    cmd = [
        sys.executable, "-m", "repro.launch.serve", "--arch", "stablelm-1.6b",
        "--smoke", "--prompts", "hello", "world", "--max-new", "4",
    ]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=420,
                         env=_ENV, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.count("tokens ->") == 2


def test_msda_in_host_model_trains():
    """Optimizer steps on the paper's host model (reduced) decrease the
    loss on a fixed batch (MSDA gradients flow through the kernel path)."""
    from repro.core import deformable_transformer as dt
    from repro.optim import adamw

    cfg = reduced(get_config("deformable-detr"))
    params = dt.init_detr(jax.random.PRNGKey(0), cfg)
    sp = sum(h * w for h, w in cfg.msda.levels)
    batch = {
        "pyramid": jax.random.normal(jax.random.PRNGKey(1), (2, sp, cfg.d_model)) * 0.1,
        "labels": jnp.array([[1, 5, -1], [2, -1, -1]], jnp.int32),
        "boxes": jax.random.uniform(jax.random.PRNGKey(2), (2, 3, 4)),
    }
    opt = adamw.init_adamw(params)
    loss0 = None
    for _ in range(5):
        loss, grads = jax.value_and_grad(
            lambda p: dt.detr_loss(p, cfg, batch, remat=False)
        )(params)
        loss0 = loss0 if loss0 is not None else float(loss)
        params, opt, _ = adamw.adamw_update(grads, opt, params, lr=1e-3)
    assert float(loss) < loss0
