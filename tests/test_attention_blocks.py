"""Attention + recurrent block unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import attention, rglru, xlstm


def _cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
                num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8)
    base.update(kw)
    return ModelConfig(**base)


def _naive_attend(q, k, v, causal, window=0):
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q * hd ** -0.5, k).astype(jnp.float32)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    m = jnp.zeros((Sq, Sk))
    if causal:
        m = jnp.where(kpos > qpos, -1e30, m)
    if window:
        m = jnp.where(kpos <= qpos - window, -1e30, m)
    w = jax.nn.softmax(s + m[None, None], axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 3), (False, 0)])
@pytest.mark.parametrize("q_chunk", [4, 64])
def test_attend_matches_naive(causal, window, q_chunk):
    B, S, H, hd = 2, 13, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out = attention.attend(q, k, v, causal=causal, window=window, q_chunk=q_chunk)
    ref = _naive_attend(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_chunking_invariance():
    B, S, H, hd = 1, 37, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
    o1 = attention.attend(q, k, v, causal=True, q_chunk=5)
    o2 = attention.attend(q, k, v, causal=True, q_chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_gqa_grouping():
    """GQA must equal MHA with kv heads repeated."""
    cfg = _cfg(num_kv_heads=2)
    p = attention.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    y = attention.attention_fwd(p, cfg, x, causal=True)
    # simulate MHA by expanding wk/wv columns per group
    cfg_mha = _cfg(num_kv_heads=4)
    groups = cfg.num_heads // cfg.num_kv_heads
    wk = p["wk"].reshape(cfg.d_model, cfg.num_kv_heads, cfg.head_dim)
    wk = jnp.repeat(wk, groups, axis=1).reshape(cfg.d_model, -1)
    wv = p["wv"].reshape(cfg.d_model, cfg.num_kv_heads, cfg.head_dim)
    wv = jnp.repeat(wv, groups, axis=1).reshape(cfg.d_model, -1)
    p2 = dict(p, wk=wk, wv=wv)
    y2 = attention.attention_fwd(p2, cfg_mha, x, causal=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-5)


def test_ring_cache_equivalence_long_decode():
    """Ring-buffer window cache == full-cache windowed attention."""
    cfg = _cfg(num_kv_heads=1, window=4)
    p = attention.init_attention(jax.random.PRNGKey(0), cfg)
    B, S = 1, 11
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    # reference: full-sequence local attention, take last position outputs
    ref = attention.attention_fwd(p, cfg, x, causal=True, window=cfg.window)
    # decode path: prefill 5 then decode 6
    cache = attention.init_kv_cache(cfg, B, cfg.window, x.dtype)
    y, cache = attention.prefill_attention(p, cfg, x[:, :5], cache, window=cfg.window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref[:, :5]), atol=1e-5)
    for t in range(5, S):
        y, cache = attention.decode_attention(p, cfg, x[:, t : t + 1], cache,
                                              window=cfg.window)
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(ref[:, t]), atol=1e-5, err_msg=f"t={t}"
        )


def test_mlstm_chunk_vs_step():
    cfg = _cfg(family="ssm", d_ff=0)
    p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg)
    B, T = 2, 19
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.5
    st0 = xlstm.init_mlstm_state(cfg, B, jnp.float32)
    y_seq, st_seq = xlstm.mlstm_seq(p, cfg, x, st0, chunk=5)
    st = st0
    ys = []
    for t in range(T):
        y, st = xlstm.mlstm_step(p, cfg, x[:, t : t + 1], st)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y_seq), atol=2e-4
    )
    eff = lambda s: np.asarray(s.c * jnp.exp(s.m)[..., None, None])
    np.testing.assert_allclose(eff(st_seq), eff(st), atol=2e-4, rtol=1e-3)


def test_rglru_scan_vs_step():
    cfg = _cfg(family="hybrid", lru_width=32)
    p = rglru.init_rglru(jax.random.PRNGKey(0), cfg)
    B, T = 2, 17
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.5
    st0 = rglru.init_rglru_state(cfg, B, jnp.float32)
    y_seq, st_seq = rglru.rglru_seq(p, cfg, x, st0)
    st = st0
    ys = []
    for t in range(T):
        y, st = rglru.rglru_step(p, cfg, x[:, t : t + 1], st)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y_seq), atol=2e-4
    )
    np.testing.assert_allclose(np.asarray(st_seq.h), np.asarray(st.h), atol=2e-4)


def test_slstm_scan_vs_step():
    cfg = _cfg(family="ssm", d_ff=0)
    p = xlstm.init_slstm(jax.random.PRNGKey(0), cfg)
    B, T = 2, 13
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.5
    st0 = xlstm.init_slstm_state(cfg, B, jnp.float32)
    y_seq, _ = xlstm.slstm_seq(p, cfg, x, st0)
    st = st0
    ys = []
    for t in range(T):
        y, st = xlstm.slstm_step(p, cfg, x[:, t : t + 1], st)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y_seq), atol=2e-4
    )


def test_mlstm_long_range_stability():
    """Exponential gating must not overflow over long sequences."""
    cfg = _cfg(family="ssm", d_ff=0)
    p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg)
    B, T = 1, 512
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 2.0
    st0 = xlstm.init_mlstm_state(cfg, B, jnp.float32)
    y, st = xlstm.mlstm_seq(p, cfg, x, st0, chunk=64)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(st.c).all())
