"""Cross-tier differential harness for partial fusion (ISSUE 9).

One oracle, every tier: for a random kernel configuration the
per-level executor (fuse off) is the reference, and EVERY fusion tier
of the same configuration — each strict prefix 1 <= k < L and the
whole-pyramid launch — must reproduce its forward output and full VJP
(value, loc, attn) **bitwise** in fp32.  No tolerances: the packed
super-slab is carrier-coded, so a fused tier reads bit-identical level
data and accumulates in the same order per level.

The sweep varies everything the packing logic branches on:

* pyramid depth 1..5 with irregular level shapes,
* committed per-level slab dtypes — uniform fp32 AND mixed
  fp32/bfloat16 (the carrier-coded super-slab's reason to exist),
* sampling locations straddling the [0, 1] border (masked corners),
* both residual modes — train-style saved corners (``save_sampled``)
  and the inference regather path.

Each tier's launch geometry is asserted structurally by counting
``pallas_call`` equations in the traced jaxpr: a k-prefix tier runs
exactly ``L - k + 1`` launches per direction (``k == 0`` fused means
the whole pyramid: one launch).

A mutation NEGATIVE control proves the harness can fail: perturbing a
single packed corner weight in the super-slab must break bitwise
parity.  A differential suite whose oracle comparison cannot trip is
measuring nothing.

The deterministic seeded sweep below always runs.  When ``hypothesis``
is installed (CI's kernels lane), a property layer drives the same
oracle with minimised random cases on top.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # local dev without the CI extras: seeded sweep only
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------
# case generation: geometry + dtype commitments + residual mode
# --------------------------------------------------------------------------


def _case_from_rng(rng):
    """One differential case drawn from a seeded ``numpy`` Generator —
    the same sampler backs the deterministic sweep and (via integer
    seeds) the hypothesis layer, so a CI-minimised failure replays
    locally as ``_case_from_rng(np.random.default_rng(seed))``."""
    L = int(rng.integers(1, 6))
    shapes = tuple(
        (int(rng.integers(2, 9)), int(rng.integers(2, 9))) for _ in range(L))
    mixed = bool(rng.integers(0, 2)) and L >= 2
    if mixed:
        dtypes = tuple(
            str(rng.choice(["float32", "bfloat16"])) for _ in range(L))
        # force an actual mix: a uniform draw would test the legacy path
        if len(set(dtypes)) == 1:
            flip = {"float32": "bfloat16", "bfloat16": "float32"}
            dtypes = (flip[dtypes[0]],) + dtypes[1:]
    else:
        dtypes = ()
    return {
        "shapes": shapes,
        "dtypes": dtypes,
        "B": int(rng.integers(1, 3)),
        "Q": int(rng.choice([8, 13, 16])),
        "H": int(rng.integers(1, 3)),
        "D": int(rng.choice([4, 8])),
        "P": int(rng.integers(1, 4)),
        "save_sampled": bool(rng.integers(0, 2)),
        "seed": int(rng.integers(0, 2**31)),
    }


def _inputs(case):
    shapes, L = case["shapes"], len(case["shapes"])
    B, Q, H, D, P = (case[k] for k in "BQHDP")
    S = sum(h * w for h, w in shapes)
    ks = jax.random.split(jax.random.PRNGKey(case["seed"]), 3)
    value = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    # straddle the border: masked (zero-weight) corners must pack too
    loc = jax.random.uniform(ks[1], (B, Q, H, L, P, 2),
                             minval=-0.2, maxval=1.2)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, L, P)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, L, P)
    return value, loc, attn


def _params(case, fused, prefix):
    L = len(case["shapes"])
    bq = -(-case["Q"] // 8) * 8
    return ops.MSDAParams(
        spatial_shapes=case["shapes"], block_q=(bq,) * L,
        fuse_levels=fused, fuse_prefix=prefix,
        save_sampled=case["save_sampled"], io_dtype="float32",
        slab_dtypes=tuple(case["dtypes"]))


def _tiers(L):
    """(label, fused, prefix) for every tier of an L-level pyramid:
    per-level, each strict prefix, whole pyramid."""
    tiers = [("per-level", False, 0)]
    tiers += [(f"prefix:{k}", True, k) for k in range(1, L)]
    tiers.append(("full", True, 0))
    return tiers


def _run(case, fused, prefix):
    """(out, (gvalue, gloc, gattn)) for one tier of the case."""
    f = ops.build_kernel_op(_params(case, fused, prefix))
    value, loc, attn = _inputs(case)
    out = f(value, loc, attn)
    g = jax.grad(lambda v, l, a: jnp.sum(f(v, l, a) * 0.5),
                 argnums=(0, 1, 2))(value, loc, attn)
    return out, g


def _assert_tiers_bitwise(case):
    """The differential oracle: every tier bitwise-equals per-level."""
    ref_out, ref_g = _run(case, False, 0)
    assert not np.any(np.isnan(np.asarray(ref_out)))
    for label, fused, prefix in _tiers(len(case["shapes"]))[1:]:
        out, g = _run(case, fused, prefix)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref_out),
            err_msg=f"{label} fwd [{case}]")
        for name, a, b in zip(("value", "loc", "attn"), g, ref_g):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{label} grad_{name} [{case}]")


def count_pallas_calls(fn, *args) -> int:
    """Number of ``pallas_call`` equations anywhere in fn's jaxpr."""
    def walk(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for sub in _jaxprs_of(v):
                    n += walk(sub)
        return n

    def _jaxprs_of(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            return [v.jaxpr]
        if hasattr(v, "jaxpr") and isinstance(getattr(v, "jaxpr", None),
                                              jax.core.Jaxpr):
            return [v.jaxpr]
        if isinstance(v, jax.core.Jaxpr):
            return [v]
        if isinstance(v, (list, tuple)):
            return [j for item in v for j in _jaxprs_of(item)]
        return []

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


# --------------------------------------------------------------------------
# deterministic seeded sweep — always runs, no optional deps
# --------------------------------------------------------------------------

_SWEEP_SEEDS = tuple(range(6))


@pytest.mark.parametrize("sweep_seed", _SWEEP_SEEDS)
def test_all_tiers_bitwise_equal_seeded(sweep_seed):
    _assert_tiers_bitwise(_case_from_rng(np.random.default_rng(sweep_seed)))


def test_sweep_covers_the_interesting_axes():
    """The seeded sweep is only a proof if its cases actually span the
    packing branches: at least one mixed-dtype case, one deep pyramid
    (a strict prefix with a multi-level tail), and both residual
    modes."""
    cases = [_case_from_rng(np.random.default_rng(s)) for s in _SWEEP_SEEDS]
    assert any(c["dtypes"] for c in cases)
    assert any(len(c["shapes"]) >= 3 for c in cases)
    assert any(c["save_sampled"] for c in cases)
    assert any(not c["save_sampled"] for c in cases)


def test_mixed_dtype_prefix_pinpoint():
    """The exact configuration the carrier encoding exists for, pinned
    rather than drawn: a bf16 level INSIDE an fp32 prefix, strict tier,
    both residual modes."""
    for save in (False, True):
        _assert_tiers_bitwise({
            "shapes": ((6, 8), (4, 4), (2, 2)),
            "dtypes": ("float32", "bfloat16", "float32"),
            "B": 2, "Q": 16, "H": 2, "D": 8, "P": 3,
            "save_sampled": save, "seed": 17,
        })


# --------------------------------------------------------------------------
# launch geometry: L - k + 1 launches per direction, counted in the jaxpr
# --------------------------------------------------------------------------


@pytest.mark.parametrize("save_sampled", [False, True],
                         ids=["regather", "saved"])
def test_launches_per_tier(save_sampled):
    case = {
        "shapes": ((6, 8), (4, 4), (3, 3), (2, 2)),
        "dtypes": (), "B": 1, "Q": 8, "H": 1, "D": 4, "P": 2,
        "save_sampled": save_sampled, "seed": 5,
    }
    L = len(case["shapes"])
    value, loc, attn = _inputs(case)
    for label, fused, prefix in _tiers(L):
        f = ops.build_kernel_op(_params(case, fused, prefix))
        per_dir = L if not fused else (1 if prefix == 0 else L - prefix + 1)
        assert count_pallas_calls(f, value, loc, attn) == per_dir, label
        grad = jax.grad(lambda v, l, a: jnp.sum(f(v, l, a)),
                        argnums=(0, 1, 2))
        # the VJP trace holds the forward replay plus the backward
        # kernels: one scatter launch per forward launch
        assert count_pallas_calls(grad, value, loc, attn) == 2 * per_dir, label


# --------------------------------------------------------------------------
# mutation negative control: the oracle must be able to fail
# --------------------------------------------------------------------------


def test_mutated_packed_slab_breaks_parity(monkeypatch):
    """Perturb ONE packed corner weight (a single super-slab element)
    and the differential assertion must trip — proving the bitwise
    comparison actually constrains the fused data path."""
    case = {
        "shapes": ((6, 8), (4, 4), (2, 2)), "dtypes": (),
        "B": 2, "Q": 16, "H": 2, "D": 8, "P": 3,
        "save_sampled": False, "seed": 17,
    }
    orig = ops._pack_pyramid
    # level 0 is (6, 8): padded width 10, real image origin at pixel
    # (1, 1) — row 11 is a REAL corner value, not a zero-pad row whose
    # masked weight would null the perturbation
    row = 1 * (case["shapes"][0][1] + 2) + 1

    def tampered(value_t, spatial_shapes, dtype=None, dtypes=()):
        slab = orig(value_t, spatial_shapes, dtype=dtype, dtypes=dtypes)
        return slab.at[0, 0, row, 0].add(jnp.asarray(1e-3, slab.dtype))

    monkeypatch.setattr(ops, "_pack_pyramid", tampered)
    with pytest.raises(AssertionError):
        _assert_tiers_bitwise(case)


def test_untampered_control_for_the_mutation():
    """Same case as the mutation test, untampered: green.  Pairs with
    the negative control so a failure there can only mean the
    perturbation (not the case itself) broke parity."""
    _assert_tiers_bitwise({
        "shapes": ((6, 8), (4, 4), (2, 2)), "dtypes": (),
        "B": 2, "Q": 16, "H": 2, "D": 8, "P": 3,
        "save_sampled": False, "seed": 17,
    })


# --------------------------------------------------------------------------
# hypothesis layer (CI): random cases through the same oracle
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_all_tiers_bitwise_equal_property(seed):
        _assert_tiers_bitwise(_case_from_rng(np.random.default_rng(seed)))
