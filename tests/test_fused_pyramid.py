"""Fused whole-pyramid MSDA kernels: parity, launch count, planner rung.

The tentpole contract (ISSUE 5):

* a fused plan executes exactly ONE Pallas launch per direction
  (asserted by counting ``pallas_call`` equations in the traced jaxpr,
  with the per-level path as the negative control),
* fused output and FULL VJP match the per-level path **bitwise** in
  fp32 (padded/border sampling locations included),
* the fusion rung is a planned, autotuned, persisted property: 'auto'
  follows the VMEM fitting model, the autotuned winner survives a
  ``PlanStore`` save/restore with zero timing runs and identical
  ``describe()``.

Also here: the satellite races — per-level one-hot routing and the
ring-vs-psum grad_reduce — and the train-mode saved-corner occupancy
fix.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import plan as pm
from repro.kernels.plan import MsdaSpec, msda_plan
from repro.kernels.ref import msda_ref

LEVELS = ((10, 6), (5, 3))
B, Q, H, D, P = 2, 21, 2, 8, 3


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    pm.clear_plans()
    yield
    pm.clear_plans()


def _inputs(seed=0, levels=LEVELS, b=B, q=Q, h=H, d=D, p=P):
    S = sum(hh * ww for hh, ww in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    value = jax.random.normal(ks[0], (b, S, h, d), jnp.float32)
    # straddle the border: masked (zero-weight) corners must fuse too
    loc = jax.random.uniform(ks[1], (b, q, h, L, p, 2), minval=-0.2, maxval=1.2)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (b, q, h, L, p)).reshape(b, q, h, -1)
    ).reshape(b, q, h, L, p)
    return value, loc, attn


def _spec(fuse, *, train=False, levels=LEVELS, q=Q, **kw):
    return MsdaSpec(spatial_shapes=levels, num_heads=H, head_dim=D,
                    num_points=P, num_queries=q, dtype="float32",
                    train=train, fuse_levels=fuse, **kw)


def count_pallas_calls(fn, *args) -> int:
    """Number of ``pallas_call`` equations anywhere in fn's jaxpr."""
    def walk(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for sub in _jaxprs_of(v):
                    n += walk(sub)
        return n

    def _jaxprs_of(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            return [v.jaxpr]
        if hasattr(v, "jaxpr") and isinstance(getattr(v, "jaxpr", None), jax.core.Jaxpr):
            return [v.jaxpr]
        if isinstance(v, jax.core.Jaxpr):
            return [v]
        if isinstance(v, (list, tuple)):
            return [j for item in v for j in _jaxprs_of(item)]
        return []

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


# --------------------------------------------------------------------------
# bitwise parity: fused == per-level in fp32, fwd + full VJP
# --------------------------------------------------------------------------


def test_fused_fwd_bitwise_matches_per_level():
    value, loc, attn = _inputs()
    out_f = msda_plan(_spec("on"), backend="pallas")(value, loc, attn)
    out_p = msda_plan(_spec("off"), backend="pallas")(value, loc, attn)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_p))
    # and both are the right answer
    ref = msda_ref(value, LEVELS, loc, attn)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("train", [False, True], ids=["regather", "saved"])
def test_fused_vjp_bitwise_matches_per_level(train):
    """Full VJP (value, loc, attn) — border locations included, both the
    saved-corner train path and the regather inference path."""
    value, loc, attn = _inputs(seed=1)
    pf = msda_plan(_spec("on", train=train), backend="pallas")
    pp = msda_plan(_spec("off", train=train), backend="pallas")
    gf = jax.grad(lambda v, l, a: jnp.sum(pf(v, l, a) ** 2), argnums=(0, 1, 2))(
        value, loc, attn)
    gp = jax.grad(lambda v, l, a: jnp.sum(pp(v, l, a) ** 2), argnums=(0, 1, 2))(
        value, loc, attn)
    for name, a, b in zip(("value", "loc", "attn"), gf, gp):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"grad_{name}")


def test_fused_onehot_routing_matches():
    """Per-level MXU one-hot routing survives inside the fused loop."""
    value, loc, attn = _inputs(seed=2)
    out_f = msda_plan(_spec("on", onehot_small_levels=True),
                      backend="pallas")(value, loc, attn)
    ref = msda_ref(value, LEVELS, loc, attn)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(ref), atol=2e-5)
    # mixed routing: level 0 VPU, level 1 MXU (hand-pinned via params)
    params = ops.MSDAParams(
        spatial_shapes=LEVELS, block_q=(24, 24), save_sampled=False,
        onehot_levels=(False, True), fuse_levels=True, io_dtype="float32")
    out_m = ops.build_kernel_op(params)(value, loc, attn)
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(ref), atol=2e-5)


def test_fused_unfused_gather_scatter_ablations_match():
    value, loc, attn = _inputs(seed=3)
    base = msda_plan(_spec("on", train=True), backend="pallas")
    abl = msda_plan(_spec("on", train=True, fuse_gather=False,
                          fuse_scatter=False), backend="pallas")
    np.testing.assert_allclose(np.asarray(base(value, loc, attn)),
                               np.asarray(abl(value, loc, attn)), atol=1e-5)
    g1 = jax.grad(lambda v: jnp.sum(base(v, loc, attn) ** 2))(value)
    g2 = jax.grad(lambda v: jnp.sum(abl(v, loc, attn) ** 2))(value)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


# --------------------------------------------------------------------------
# acceptance: exactly one Pallas launch per direction
# --------------------------------------------------------------------------


def test_fused_single_launch_per_direction():
    value, loc, attn = _inputs()
    L = len(LEVELS)
    pf = msda_plan(_spec("on", train=True), backend="pallas")
    pp = msda_plan(_spec("off", train=True), backend="pallas")

    # forward: one launch fused, L launches per-level (negative control)
    assert count_pallas_calls(lambda v, l, a: pf(v, l, a),
                              value, loc, attn) == 1
    assert count_pallas_calls(lambda v, l, a: pp(v, l, a),
                              value, loc, attn) == L

    # fwd + bwd under grad: one launch per direction = 2 total
    def loss(plan):
        return lambda v, l, a: jnp.sum(plan(v, l, a) ** 2)

    assert count_pallas_calls(jax.grad(loss(pf), argnums=(0, 1, 2)),
                              value, loc, attn) == 2
    assert count_pallas_calls(jax.grad(loss(pp), argnums=(0, 1, 2)),
                              value, loc, attn) == 2 * L


# --------------------------------------------------------------------------
# the fusion rung: planned, reported, persisted
# --------------------------------------------------------------------------


def test_fusion_rung_follows_vmem_fitting_model():
    # tiny budget: the packed pyramid + grad slab cannot fit -> per-level
    tight = msda_plan(_spec("auto", train=True, levels=((256, 256), (128, 128)),
                            q=4096, vmem_budget=2 * 2**20), backend="pallas")
    assert not tight.fused
    # roomy budget at DETR-ish scale: fused
    roomy = msda_plan(_spec("auto", train=True, vmem_budget=64 * 2**20),
                      backend="pallas")
    assert roomy.fused
    assert "fuse=pyramid" in roomy.describe()
    assert "fuse=per-level" in tight.describe()
    assert all(r["fused"] for r in roomy.level_report())
    # fused plans share ONE block_q across levels
    assert len(set(roomy.block_q)) == 1


def test_fusion_rung_ignored_by_non_fusable_backends():
    for backend in ("ref", "cpu"):
        plan = msda_plan(_spec("on"), backend=backend)
        assert not plan.fused  # truthful: those backends launch no kernels
        out = plan(*_inputs())
        assert out.shape == (B, Q, H * D)


def test_single_level_auto_stays_per_level():
    plan = msda_plan(_spec("auto", levels=((8, 8),)), backend="pallas")
    assert not plan.fused


def test_fuse_winner_persists_and_reloads(tmp_path, monkeypatch):
    """The autotuned fuse_levels winner lands in the winner cache and a
    fresh plan build resolves it with zero timing runs."""
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    spec = _spec("auto", train=True, levels=((6, 6), (3, 3)), q=16)
    pm.reset_autotune_stats()
    plan = msda_plan(spec, backend="pallas", tune="autotune")
    assert plan.tuning.source == "autotune"
    assert pm.autotune_stats()["raced"] == 1
    entry = next(iter(json.load(open(tmp_path / "at.json")).values()))
    assert entry["fuse_levels"] == plan.fused

    pm.clear_plans()
    pm.reset_autotune_stats()
    plan2 = msda_plan(spec, backend="pallas", tune="autotune")
    stats = pm.autotune_stats()
    assert stats["raced"] == 0 and stats["cache_hits"] >= 1
    assert plan2.tuning.source == "autotune-cache"
    assert plan2.fused == plan.fused
    assert plan2.block_q == plan.block_q


def test_pinned_on_survives_schema_less_winner(tmp_path, monkeypatch):
    """A hand-seeded winner WITHOUT the fuse_levels field (pre-fusion /
    hand-authored schema) must not un-fuse a spec pinned 'on'."""
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    spec = _spec("on", levels=((6, 6), (3, 3)), q=16)
    assert pm.seed_autotune_winner(
        spec, "pallas",
        {"block_q": [16, 16], "slab_dtypes": ["float32", "float32"]})
    plan = msda_plan(spec, backend="pallas", tune="autotune")
    assert plan.tuning.source == "autotune-cache"
    assert plan.fused  # the 'on' pin wins over the field-less entry


def test_fuse_winner_survives_plan_store_roundtrip(tmp_path, monkeypatch):
    """Acceptance: the autotuned fuse_levels winner survives a PlanStore
    save/restore with zero timing runs and identical describe()."""
    from repro.serving.persistence import PlanStore, _norm_describe

    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at1.json"))
    spec = _spec("auto", train=True, levels=((6, 6), (3, 3)), q=16)
    plan = msda_plan(spec, backend="pallas", tune="autotune")
    store = PlanStore(str(tmp_path / "plans.json"))
    assert store.save_plans([plan]) == 1

    # "restart": fresh plan cache, fresh (empty) winner cache
    pm.clear_plans()
    pm.reset_autotune_stats()
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at2.json"))
    report = store.restore()
    assert not report.skipped and not report.describe_mismatches
    assert pm.autotune_stats()["raced"] == 0
    [restored] = report.plans
    assert restored.fused == plan.fused
    assert restored.tuning.source == "autotune-cache"
    assert _norm_describe(restored.describe()) == _norm_describe(plan.describe())


# --------------------------------------------------------------------------
# satellite: autotuned one-hot threshold (replaces the static heuristic)
# --------------------------------------------------------------------------


def test_onehot_race_persists_per_level_flips(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    spec = _spec("off", levels=((6, 6), (3, 3)), q=16,
                 onehot_small_levels=True)
    pm.reset_autotune_stats()
    plan = msda_plan(spec, backend="pallas", tune="autotune")
    assert plan.tuning.source == "autotune"
    assert len(plan.tuning.onehot_levels) == 2
    entry = next(iter(json.load(open(tmp_path / "at.json")).values()))
    # the raced routing is persisted per level, whichever way it went
    assert entry["onehot_levels"] == [bool(x) for x in plan.tuning.onehot_levels]

    pm.clear_plans()
    pm.reset_autotune_stats()
    plan2 = msda_plan(spec, backend="pallas", tune="autotune")
    assert pm.autotune_stats()["raced"] == 0
    assert plan2.tuning.onehot_levels == plan.tuning.onehot_levels
    # the raced plan still computes the right answer
    value, loc, attn = _inputs(levels=((6, 6), (3, 3)), q=16)
    np.testing.assert_allclose(
        np.asarray(plan2(value, loc, attn)),
        np.asarray(msda_ref(value, ((6, 6), (3, 3)), loc, attn)), atol=2e-5)


# --------------------------------------------------------------------------
# satellite: raced grad_reduce (ring vs psum) per mesh topology
# --------------------------------------------------------------------------


def test_grad_reduce_race_persists_per_topology(tmp_path, monkeypatch):
    from repro.launch import mesh as mesh_lib

    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices")
    mesh = mesh_lib.make_mesh_2d(2, 2)
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    spec = MsdaSpec(spatial_shapes=((8, 8), (4, 4)), num_heads=2, head_dim=8,
                    num_points=2, num_queries=16, train=True)
    pm.reset_autotune_stats()
    plan = msda_plan(spec, backend="ref", tune="autotune", mesh=mesh,
                     sharding="2d", query_parallel=True)
    assert plan.sharding_mode == "query2d"
    assert plan.grad_reduce in ("ring", "psum")  # timing decides
    assert pm.autotune_stats()["raced"] >= 1
    winner = pm.get_autotune_winner(
        spec, "ref", mesh_suffix=pm.mesh_winner_suffix(mesh, True))
    assert winner is not None and winner["grad_reduce"] == plan.grad_reduce

    # a fresh build resolves the reduction from the cache: zero races
    pm.clear_plans()
    pm.reset_autotune_stats()
    plan2 = msda_plan(spec, backend="ref", tune="autotune", mesh=mesh,
                      sharding="2d", query_parallel=True)
    assert pm.autotune_stats()["raced"] == 0
    assert plan2.grad_reduce == plan.grad_reduce

    # heuristic tune / inference plans never race: 'auto' stays ring
    pm.clear_plans()
    heur = msda_plan(spec, backend="ref", mesh=mesh, sharding="2d",
                     query_parallel=True)
    assert heur.grad_reduce == "ring"


# --------------------------------------------------------------------------
# satellite: train-mode saved-corner block in the occupancy model
# --------------------------------------------------------------------------


def test_train_occupancy_counts_saved_corner_block():
    # per-query bytes must grow by the (4P, D) slab-dtype corner rows
    base = ops.per_query_bytes(P, D)
    train = ops.per_query_bytes(P, D, train=True, slab_itemsize=4)
    assert train == base + 4 * P * D * 4
    # and the planner therefore never gives a train plan MORE queries
    # per step than the equivalent inference plan
    shapes = ((64, 64), (32, 32))
    kw = dict(num_points=4, head_dim=32, num_queries=8192,
              vmem_budget=8 * 2**20)
    bq_train = ops.plan_blocks(shapes, train=True, **kw)
    bq_infer = ops.plan_blocks(shapes, train=False, **kw)
    assert all(t <= i for t, i in zip(bq_train, bq_infer))
    fused_t = ops.plan_blocks(shapes, train=True, fused=True, **kw)
    fused_i = ops.plan_blocks(shapes, train=False, fused=True, **kw)
    assert len(set(fused_t)) == 1 and len(set(fused_i)) == 1
    assert fused_t[0] <= fused_i[0]
