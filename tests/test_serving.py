"""Serving engine: continuous batching must match isolated decoding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import lm
from repro.serving.engine import Request, ServeEngine


def _isolated_greedy(params, cfg, prompt, max_new, capacity=32):
    lp, cache = lm.lm_prefill(params, cfg, jnp.asarray(prompt)[None], capacity=capacity)
    outs = [int(np.asarray(lp)[0].argmax())]
    for _ in range(max_new - 1):
        ld, cache = lm.lm_decode_step(
            params, cfg, cache, jnp.asarray([outs[-1]], jnp.int32)
        )
        outs.append(int(np.asarray(ld)[0].argmax()))
    return outs


def test_engine_matches_isolated_decode():
    cfg = reduced(get_config("llama3-8b"))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    prompts = [
        np.arange(5, dtype=np.int32) + 5,
        np.arange(3, dtype=np.int32) + 40,
        np.arange(4, dtype=np.int32) + 80,
    ]
    max_new = [6, 4, 5]
    eng = ServeEngine(cfg, params, slots=2, capacity=32)
    reqs = [Request(rid=i, prompt=p, max_new=m) for i, (p, m) in enumerate(zip(prompts, max_new))]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        iso = _isolated_greedy(params, cfg, r.prompt, r.max_new)
        assert r.out == iso, f"req {r.rid}: engine {r.out} != isolated {iso}"


def test_engine_more_requests_than_slots():
    cfg = reduced(get_config("stablelm-1.6b"))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, slots=2, capacity=16)
    reqs = [
        Request(rid=i, prompt=np.arange(3, dtype=np.int32) + i * 7, max_new=3)
        for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 3 for r in reqs)


def test_make_serve_fns_families():
    from repro.serving.engine import make_serve_fns

    for arch in ("llama3-8b", "whisper-large-v3", "phi-3-vision-4.2b"):
        cfg = reduced(get_config(arch))
        prefill, decode = make_serve_fns(cfg)
        assert callable(prefill) and callable(decode)
