"""Plan-layer contracts for partial-fusion tiers (ISSUE 9).

What the planner promises about a fusion tier, independent of kernel
numerics (those live in ``test_fusion_differential.py``):

* ``launches_per_call()`` reports the static Pallas schedule by
  direction — ``L`` per-level, ``1`` whole-pyramid, ``L - k + 1`` for a
  strict ``prefix:k`` tier, with ``bwd`` zeroed on inference plans;
* ``describe()`` names the tier (``fuse=pyramid[0:k)+per-level``) and
  carries the launch schedule so an operator reads the launch bill from
  the plan dump alone;
* each ``plan(...)`` call feeds the ``msda.launches`` observability
  gauge by exactly its schedule (``execution_telemetry()``);
* a VMEM-constrained ``fuse_levels="auto"`` spec commits a STRICT
  prefix — partial fusion engages from the occupancy model, not only
  from pins;
* a strict-prefix autotune winner survives the PlanStore v6 round-trip:
  restore rebuilds the tier with zero timing races and identical
  ``describe()``.
"""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels import plan as plan_mod
from repro.kernels.plan import MsdaSpec, msda_plan
from repro.serving import persistence

SHAPES = ((14, 14), (10, 10), (7, 7), (5, 5), (3, 3))


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    """Fresh plan cache + private autotune winner cache per test."""
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    plan_mod.clear_plans()
    plan_mod.reset_autotune_stats()
    yield
    plan_mod.clear_plans()


def _spec(fuse, *, levels=3, budget=0, train=True):
    return MsdaSpec(
        spatial_shapes=SHAPES[:levels], num_heads=2, head_dim=8,
        num_points=2, num_queries=32, train=train, fuse_levels=fuse,
        vmem_budget=budget)


def _io(spec):
    S = spec.total_pixels
    L = spec.num_levels
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    value = jax.random.normal(k1, (1, S, 2, 8), jnp.float32)
    loc = jax.random.uniform(k2, (1, 32, 2, L, 2, 2))
    attn = jax.nn.softmax(
        jax.random.normal(k3, (1, 32, 2, L * 2)), axis=-1
    ).reshape(1, 32, 2, L, 2)
    return value, loc, attn


# --------------------------------------------------------------------------
# launches_per_call(): the static schedule, by tier and direction
# --------------------------------------------------------------------------


@pytest.mark.parametrize("fuse,fwd", [("off", 3), ("on", 1), ("prefix:2", 2)])
def test_pinned_tier_launch_schedule(fuse, fwd):
    plan = msda_plan(_spec(fuse), backend="pallas")
    assert plan.launches_per_call() == {"fwd": fwd, "bwd": fwd}


def test_inference_plans_carry_no_backward_launches():
    plan = msda_plan(_spec("prefix:2", train=False), backend="pallas")
    assert plan.launches_per_call() == {"fwd": 2, "bwd": 0}


def test_non_pallas_plans_report_zero_launches():
    plan = msda_plan(_spec("off"), backend="cpu")
    assert plan.launches_per_call() == {"fwd": 0, "bwd": 0}


# --------------------------------------------------------------------------
# describe(): tier header, fuse note, launch bill
# --------------------------------------------------------------------------


def test_describe_names_the_strict_tier():
    d = msda_plan(_spec("prefix:2"), backend="pallas").describe()
    head = d.splitlines()[0]
    assert "fuse=pyramid[0:2)+per-level" in head, head
    assert "fused prefix [0:2): 2 launches/direction" in d, d
    assert "tail levels 2..2 per-level" in d, d


@pytest.mark.parametrize("fuse,line", [
    ("off", "launches/call: fwd=3 bwd=3"),
    ("on", "launches/call: fwd=1 bwd=1"),
    ("prefix:2", "launches/call: fwd=2 bwd=2"),
])
def test_describe_carries_the_launch_bill(fuse, line):
    assert line in msda_plan(_spec(fuse), backend="pallas").describe()


def test_describe_tier_rows_show_fusion_membership():
    plan = msda_plan(_spec("prefix:2"), backend="pallas")
    rep = plan.level_report()
    assert [r["fused"] for r in rep] == [True, True, False]
    # prefix rows share the super-slab occupancy figure
    assert rep[0]["vmem_frac"] == rep[1]["vmem_frac"]


# --------------------------------------------------------------------------
# observability: every plan call feeds the launch gauge by its schedule
# --------------------------------------------------------------------------


def test_launch_gauge_advances_by_the_schedule():
    spec = _spec("prefix:2")
    plan = msda_plan(spec, backend="pallas")
    value, loc, attn = _io(spec)
    before = plan_mod.execution_telemetry()["launches"]
    plan(value, loc, attn)
    plan(value, loc, attn)
    after = plan_mod.execution_telemetry()["launches"]
    assert after["fwd"] - before["fwd"] == 2 * 2  # 2 calls x (L - k + 1)
    assert after["bwd"] - before["bwd"] == 2 * 2
    assert after["plan_calls"] - before["plan_calls"] == 2


# --------------------------------------------------------------------------
# acceptance: a VMEM-constrained auto spec commits a strict prefix
# --------------------------------------------------------------------------


def test_vmem_constrained_auto_spec_plans_strict_prefix():
    L = len(SHAPES)
    # roomy default budget: the occupancy model fuses the whole pyramid
    roomy = msda_plan(_spec("auto", levels=L), backend="pallas")
    assert roomy.fused and roomy.fuse_prefix == L
    assert roomy.launches_per_call()["fwd"] == 1

    # walk the budget down to where the model admits only a strict
    # prefix, then confirm the PLANNER (not just the model) commits it
    for b in range(20_000, 3_000_000, 10_000):
        k = ops.fusion_prefix(SHAPES, 2, 8, value_itemsize=4,
                              train=True, vmem_budget=b)
        if 2 <= k < L:
            break
    else:  # pragma: no cover - occupancy model regressed
        pytest.fail("no budget yields a strict prefix")
    tight = msda_plan(_spec("auto", levels=L, budget=b), backend="pallas")
    assert 0 < tight.fuse_prefix < L
    assert tight.fuse_prefix == k
    assert tight.launches_per_call()["fwd"] == L - k + 1
    assert f"fuse=pyramid[0:{k})+per-level" in tight.describe()


# --------------------------------------------------------------------------
# PlanStore v6: strict-prefix winners restore with zero races
# --------------------------------------------------------------------------


def test_plan_store_v6_strict_prefix_round_trip(tmp_path):
    spec = _spec("auto")
    plan_mod.seed_autotune_winner(spec, "pallas", {
        "block_q": [16, 16, 16],
        "slab_dtypes": ["float32"] * 3,
        "fuse_levels": True,
        "fuse_prefix": 2,
    })
    plan = msda_plan(spec, backend="pallas", tune="autotune")
    assert plan.fused and plan.fuse_prefix == 2
    assert plan_mod.autotune_stats()["raced"] == 0  # seeded, not timed
    before = plan.describe()

    store = persistence.PlanStore(str(tmp_path / "plans.json"))
    assert store.save_plans([plan]) == 1

    # simulated restart: plan cache gone, winner cache gone
    plan_mod.clear_plans()
    os.environ["REPRO_MSDA_AUTOTUNE_CACHE"] = str(tmp_path / "autotune2.json")
    plan_mod.reset_autotune_stats()
    report = persistence.PlanStore(store.path).restore()
    assert len(report.plans) == 1 and not report.skipped
    assert report.describe_mismatches == []
    restored = report.plans[0]
    assert restored.fused and restored.fuse_prefix == 2
    assert restored.launches_per_call() == {"fwd": 2, "bwd": 2}
    assert (persistence._norm_describe(restored.describe())
            == persistence._norm_describe(before))
    assert plan_mod.autotune_stats()["raced"] == 0, \
        "restore must not run autotune timing"
