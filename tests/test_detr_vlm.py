"""Deformable-DETR host model + greedy matcher tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import deformable_transformer as dt


def test_greedy_match_properties():
    rng = np.random.default_rng(0)
    cost = jnp.asarray(rng.normal(size=(20, 6)))
    assign = dt.greedy_match(cost, 6)
    a = np.asarray(assign)
    assert len(set(a.tolist())) == 6  # distinct queries
    assert (a >= 0).all() and (a < 20).all()


def test_greedy_match_identity_cost():
    """Zero cost except a clear diagonal -> picks the diagonal."""
    Q, T = 10, 4
    cost = jnp.ones((Q, T))
    for t in range(T):
        cost = cost.at[t + 3, t].set(-10.0 - t)
    assign = dt.greedy_match(cost, T)
    np.testing.assert_array_equal(np.asarray(assign), np.arange(T) + 3)


def test_detr_loss_and_grads():
    cfg = reduced(get_config("deformable-detr"))
    params = dt.init_detr(jax.random.PRNGKey(0), cfg)
    sp = sum(h * w for h, w in cfg.msda.levels)
    batch = {
        "pyramid": jax.random.normal(jax.random.PRNGKey(1), (2, sp, cfg.d_model)) * 0.1,
        "labels": jnp.array([[1, 5, -1], [2, -1, -1]], jnp.int32),
        "boxes": jax.random.uniform(jax.random.PRNGKey(2), (2, 3, 4)),
    }
    loss, grads = jax.value_and_grad(lambda p: dt.detr_loss(p, cfg, batch, remat=False))(params)
    assert jnp.isfinite(loss)
    gn = jnp.sqrt(sum(jnp.vdot(g, g) for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and float(gn) > 0


def test_detr_level_ref_points():
    from repro.core.msda import level_ref_points

    refs = level_ref_points(((2, 2), (1, 1)))
    assert refs.shape == (5, 2)
    np.testing.assert_allclose(np.asarray(refs[-1]), [0.5, 0.5])
    np.testing.assert_allclose(np.asarray(refs[0]), [0.25, 0.25])


def test_detr_encoder_uses_msda_pallas_consistently():
    """Encoder output identical under ref and pallas kernel backends."""
    from dataclasses import replace

    cfg = reduced(get_config("deformable-detr"))
    params = dt.init_detr(jax.random.PRNGKey(0), cfg)
    sp = sum(h * w for h, w in cfg.msda.levels)
    pyr = jax.random.normal(jax.random.PRNGKey(1), (1, sp, cfg.d_model)) * 0.1
    cfg_ref = replace(cfg, msda=replace(cfg.msda, backend="ref"))
    cfg_pal = replace(cfg, msda=replace(cfg.msda, backend="pallas"))
    m1 = dt.encode_pyramid(params, cfg_ref, pyr, remat=False)
    m2 = dt.encode_pyramid(params, cfg_pal, pyr, remat=False)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=5e-5)
