"""Sparsity-aware plans: top-k point pruning + Morton query permutation.

The tentpole contract (ISSUE 7):

* ``sparsity="off"`` / ``query_order="identity"`` plans stay bitwise
  equal to pre-sparsity plans (the axes are pure additions), and
  lossy/permuted modes are NEVER picked without a timing race;
* the Morton permutation is bitwise-neutral: forward, grad_loc and
  grad_attn are bit-identical to the identity plan (the permutation is
  a bijection — the only reassociation is in the grad_value scatter,
  which is allclose);
* the pruned executor matches the masked-renormalised oracle
  (``topk_mask_weights`` + ``msda_ref``) and reports itself truthfully
  (``xla-topk`` gather, never ``fuse=pyramid``);
* both axes are planned, autotuned, persisted properties: winners
  survive the winner cache AND a ``PlanStore`` v5 save/restore with
  zero timing runs and identical ``describe()``.

Also here: the winner-cache forward-compat regression — unknown keys a
newer build persisted must ride through parse -> re-persist.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import msda_sparse
from repro.kernels import plan as pm
from repro.kernels.plan import MsdaSpec, msda_plan
from repro.kernels.ref import msda_ref

# encoder-like geometry: queries ARE the pyramid pixels (Q == S), which
# is what makes the Morton permutation statically computable
LEVELS = ((6, 6), (3, 3))
SQ = sum(h * w for h, w in LEVELS)  # 45
B, H, D, P = 2, 2, 8, 3


@pytest.fixture(autouse=True)
def _fresh_plan_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    pm.clear_plans()
    pm.reset_autotune_stats()
    yield
    pm.clear_plans()


def _inputs(seed=0, levels=LEVELS, b=B, q=SQ, h=H, d=D, p=P):
    S = sum(hh * ww for hh, ww in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    value = jax.random.normal(ks[0], (b, S, h, d), jnp.float32)
    loc = jax.random.uniform(ks[1], (b, q, h, L, p, 2), minval=-0.2, maxval=1.2)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (b, q, h, L, p)).reshape(b, q, h, -1)
    ).reshape(b, q, h, L, p)
    return value, loc, attn


def _spec(sparsity="off", query_order="identity", *, k=0, train=False,
          levels=LEVELS, q=SQ, **kw):
    return MsdaSpec(spatial_shapes=levels, num_heads=H, head_dim=D,
                    num_points=P, num_queries=q, dtype="float32", train=train,
                    sparsity=sparsity, sparsity_k=k, query_order=query_order,
                    **kw)


# --------------------------------------------------------------------------
# Morton permutation: validity + bitwise neutrality
# --------------------------------------------------------------------------


def test_morton_codes_follow_z_order():
    # 2x2 grid (raster order): Z-curve visits (y,x) = (0,0),(0,1),
    # (1,0),(1,1) in code order 0,1,2,3 (x bits even, y bits odd)
    codes = msda_sparse.morton_codes(2, 2)
    np.testing.assert_array_equal(codes, [0, 1, 2, 3])
    # 4x4: each 2x2 quad is contiguous in code space
    codes4 = msda_sparse.morton_codes(4, 4).reshape(4, 4)
    assert codes4[0, 2] == 4 and codes4[2, 0] == 8 and codes4[2, 2] == 12


def test_morton_permutation_is_per_level_bijection():
    perm = msda_sparse.morton_permutation(LEVELS)
    assert sorted(perm.tolist()) == list(range(SQ))
    # per level: rows of level 1 never migrate into level 0's block
    n0 = LEVELS[0][0] * LEVELS[0][1]
    assert set(perm[:n0].tolist()) == set(range(n0))


def test_morton_fwd_and_grads_bitwise_neutral():
    """Permuted plan == identity plan: fwd, grad_loc, grad_attn bitwise
    (per-query slots just move through a bijection); grad_value sees a
    reordered scatter -> allclose only."""
    value, loc, attn = _inputs()
    ident = msda_plan(_spec(), backend="pallas")
    mort = msda_plan(_spec(query_order="morton"), backend="pallas")
    assert mort.tuning.query_order == "morton"
    np.testing.assert_array_equal(np.asarray(mort(value, loc, attn)),
                                  np.asarray(ident(value, loc, attn)))

    def grads(plan):
        return jax.grad(lambda v, l, a: jnp.sum(plan(v, l, a) ** 2),
                        argnums=(0, 1, 2))(value, loc, attn)

    gi, gm = grads(ident), grads(mort)
    np.testing.assert_allclose(np.asarray(gm[0]), np.asarray(gi[0]),
                               atol=1e-5, rtol=1e-5)  # value: scatter order
    np.testing.assert_array_equal(np.asarray(gm[1]), np.asarray(gi[1]))
    np.testing.assert_array_equal(np.asarray(gm[2]), np.asarray(gi[2]))


def test_morton_pin_ineligible_geometry_stays_identity():
    # Q != total pixels: no static raster layout to permute — the plan
    # must report identity rather than silently half-apply the pin
    plan = msda_plan(_spec(query_order="morton", q=21), backend="pallas")
    assert plan.tuning.query_order == "identity"
    assert "morton" not in plan.describe()


# --------------------------------------------------------------------------
# top-k pruning: executor parity + truthful reporting
# --------------------------------------------------------------------------


def test_resolved_sparsity_k_defaults_and_clamps():
    assert _spec().resolved_sparsity_k() == 3      # ceil(6/2) default
    assert _spec(k=2).resolved_sparsity_k() == 2
    assert _spec(k=99).resolved_sparsity_k() == 6  # clamped to L*P
    counts = msda_sparse.gather_counts(_spec("topk", k=2))
    assert counts["dense_corner_gathers"] == 24
    assert counts["topk_corner_gathers"] == 8
    assert counts["gather_reduction"] == pytest.approx(2 / 3)


def test_topk_matches_masked_renormalised_oracle():
    value, loc, attn = _inputs(seed=1)
    k = 2
    plan = msda_plan(_spec("topk", k=k), backend="pallas")
    out = plan(value, loc, attn)
    ref = msda_ref(value, LEVELS, loc, msda_sparse.topk_mask_weights(attn, k))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_topk_plan_reports_itself_truthfully():
    plan = msda_plan(_spec("topk", k=2, train=True, vmem_budget=64 * 2**20),
                     backend="pallas")
    assert plan.tuning.sparsity == "topk"
    assert not plan.fused  # the pruned executor launches no pallas kernels
    d = plan.describe()
    assert "sparsity: topk k=2/6" in d and "fuse=pyramid" not in d
    assert all(r["gather"] == "xla-topk" for r in plan.level_report())


def test_topk_composes_with_morton():
    value, loc, attn = _inputs(seed=2)
    k = 2
    plan = msda_plan(_spec("topk", "morton", k=k), backend="pallas")
    assert plan.tuning.query_order == "morton"
    ref = msda_ref(value, LEVELS, loc, msda_sparse.topk_mask_weights(attn, k))
    np.testing.assert_allclose(np.asarray(plan(value, loc, attn)),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# planned, autotuned, persisted: winner cache + PlanStore v5
# --------------------------------------------------------------------------


def test_auto_heuristic_resolves_dense_identity_without_race():
    plan = msda_plan(_spec("auto", "auto"), backend="pallas")
    assert plan.tuning.sparsity == "dense"
    assert plan.tuning.query_order == "identity"
    assert pm.autotune_stats()["raced"] == 0


def test_sparsity_race_persists_and_reloads(tmp_path):
    spec = _spec("auto", "auto", train=True)
    plan = msda_plan(spec, backend="cpu", tune="autotune")
    assert plan.tuning.source == "autotune"
    assert pm.autotune_stats()["raced"] == 1
    assert plan.tuning.sparsity in ("dense", "topk")
    assert plan.tuning.query_order in ("identity", "morton")
    entry = next(iter(json.load(open(tmp_path / "autotune.json")).values()))
    assert entry["sparsity"] == plan.tuning.sparsity
    assert entry["query_order"] == plan.tuning.query_order

    pm.clear_plans()
    pm.reset_autotune_stats()
    plan2 = msda_plan(spec, backend="cpu", tune="autotune")
    stats = pm.autotune_stats()
    assert stats["raced"] == 0 and stats["cache_hits"] >= 1
    assert plan2.tuning.source == "autotune-cache"
    assert plan2.tuning.sparsity == plan.tuning.sparsity
    assert plan2.tuning.query_order == plan.tuning.query_order


def test_pinned_axes_keep_entries_byte_identical(tmp_path):
    """off/pinned specs must not grow winner-cache fields: an autotuned
    off-spec entry carries NO sparsity/query_order keys, so pre-PR
    entries and new ones stay byte-compatible."""
    msda_plan(_spec(), backend="pallas", tune="autotune")
    entry = next(iter(json.load(open(tmp_path / "autotune.json")).values()))
    assert "sparsity" not in entry and "query_order" not in entry


def test_winner_cache_preserves_unknown_keys():
    """Forward-compat regression: a field persisted by a newer build
    must survive this build's parse -> re-persist round trip."""
    spec = _spec()
    entry = {"block_q": [16, 16], "slab_dtypes": ["float32", "float32"],
             "fuse_levels": True, "future_field": {"nested": [1, 2]},
             "another_unknown": "keep-me"}
    parsed = pm._parse_cache_entry(entry, spec)
    assert parsed is not None
    assert parsed["extras"] == {"future_field": {"nested": [1, 2]},
                                "another_unknown": "keep-me"}
    out = pm._winner_entry(parsed)
    assert out["future_field"] == {"nested": [1, 2]}
    assert out["another_unknown"] == "keep-me"
    # and seeding through the public API keeps them on disk
    assert pm.seed_autotune_winner(spec, "cpu", entry)
    disk = json.load(open(pm.autotune_cache_path()))
    assert next(iter(disk.values()))["future_field"] == {"nested": [1, 2]}


def test_sparsity_winners_survive_plan_store_roundtrip(tmp_path, monkeypatch):
    """Acceptance: auto-axis winners survive a PlanStore save/restore
    with zero timing runs and identical describe()."""
    from repro.serving.persistence import (PLAN_STORE_VERSION, PlanStore,
                                           _norm_describe)

    spec = _spec("auto", "auto", train=True)
    plan = msda_plan(spec, backend="cpu", tune="autotune")
    store = PlanStore(str(tmp_path / "plans.json"))
    assert store.save_plans([plan]) == 1
    raw = json.load(open(tmp_path / "plans.json"))
    assert raw["version"] == PLAN_STORE_VERSION

    pm.clear_plans()
    pm.reset_autotune_stats()
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE", str(tmp_path / "at2.json"))
    report = store.restore()
    assert not report.skipped and not report.describe_mismatches
    assert pm.autotune_stats()["raced"] == 0
    [restored] = report.plans
    assert restored.tuning.source == "autotune-cache"
    assert restored.tuning.sparsity == plan.tuning.sparsity
    assert restored.tuning.query_order == plan.tuning.query_order
    assert _norm_describe(restored.describe()) == _norm_describe(plan.describe())
