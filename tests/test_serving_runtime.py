"""Serving runtime: AOT zero-retrace, plan-store round-trip, bucketed batcher.

The three contracts the subsystem promises:

* requests whose signature was warmed NEVER trace or compile
  (``aot.probe()`` counts both);
* a restarted process rebuilds its full plan set from the store with
  zero autotune timing runs and identical ``plan.describe()``;
* pyramids padded into a bucket produce the same outputs as the
  unbatched exact-geometry reference (valid-ratio coordinate scaling).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.kernels import plan as plan_mod
from repro.kernels.plan import MsdaSpec
from repro.kernels.ref import msda_ref
from repro.serving import aot
from repro.serving import batcher as bm
from repro.serving import persistence
from repro.serving.engine import Request, ServeEngine, warmup_msda_plans
from repro.serving.metrics import ServeMetrics


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    """Fresh plan cache + private autotune winner cache per test."""
    monkeypatch.setenv("REPRO_MSDA_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    plan_mod.clear_plans()
    plan_mod.reset_autotune_stats()
    aot.reset_stats()
    yield
    plan_mod.clear_plans()


def _lm_engine(slots=2, capacity=32, arch="llama3-8b", **kw):
    from repro.models import lm

    cfg = reduced(get_config(arch))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params, ServeEngine(cfg, params, slots=slots,
                                    capacity=capacity, **kw)


def _vlm_engine(slots=2, capacity=64, **kw):
    from repro.models import vlm

    cfg = reduced(get_config("phi-3-vision-4.2b"))
    params = vlm.init_vlm(jax.random.PRNGKey(0), cfg)
    return cfg, params, ServeEngine(cfg, params, slots=slots,
                                    capacity=capacity, **kw)


def _pyr_request(rid, vc, levels, prompt_len=4, max_new=4, seed=0):
    rng = np.random.default_rng(seed + rid)
    S = sum(h * w for h, w in levels)
    return Request(
        rid=rid, prompt=np.arange(prompt_len, dtype=np.int32) + rid,
        max_new=max_new,
        pyramid=rng.standard_normal((S, vc.vision_dim)).astype(np.float32),
        levels=levels)


# --------------------------------------------------------------------------
# AOT: zero retraces at request time
# --------------------------------------------------------------------------


def test_aot_zero_retrace_lm():
    _, _, eng = _lm_engine()
    eng.warmup(prompt_lengths=(5, 3))
    reqs = [Request(rid=i, prompt=np.arange(n, dtype=np.int32) + i, max_new=3)
            for i, n in enumerate((5, 3, 5))]
    with aot.probe() as p:
        for r in reqs:
            eng.submit(r)
        eng.run()
    assert all(r.done and len(r.out) == 3 for r in reqs)
    assert p.traces == 0 and p.compiles == 0, f"request-time retrace: {p}"
    assert p.aot_calls > 0  # the compiled executors actually served


def test_unwarmed_prompt_length_is_counted_as_retrace():
    _, _, eng = _lm_engine()
    eng.warmup(prompt_lengths=(5,))
    with aot.probe() as p:
        eng.submit(Request(rid=0, prompt=np.arange(7, dtype=np.int32),
                           max_new=2))
        eng.run()
    assert p.traces >= 1  # the probe sees the jit fallback trace


def test_aot_zero_retrace_vlm_bucketed():
    cfg, _, eng = _vlm_engine()
    eng.warmup(prompt_lengths=(4,))
    vc = cfg.vision
    half = tuple((h // 2, w // 2) for h, w in vc.levels)
    reqs = [_pyr_request(0, vc, vc.levels), _pyr_request(1, vc, half),
            _pyr_request(2, vc, half)]
    with aot.probe() as p:
        for r in reqs:
            eng.submit(r)
        eng.run()
    assert all(r.done for r in reqs)
    assert p.traces == 0 and p.compiles == 0, f"request-time retrace: {p}"


def test_plan_executor_aot():
    spec = MsdaSpec(spatial_shapes=((8, 8), (4, 4)), num_heads=2, head_dim=8,
                    num_points=2, num_queries=16)
    plan = plan_mod.msda_plan(spec, backend="ref")
    ex = aot.compile_plan_executor(plan, batch_size=2)
    v, l, a = (jnp.zeros(s.shape, s.dtype) for s in aot.plan_arg_structs(spec, 2))
    with aot.probe() as p:
        out = ex(v, l, a)
    assert out.shape == (2, 16, 16)
    assert p.traces == 0 and p.compiles == 0


# --------------------------------------------------------------------------
# plan store round-trip
# --------------------------------------------------------------------------


def test_spec_json_round_trip():
    spec = MsdaSpec(spatial_shapes=((10, 6), (5, 3)), num_heads=2, head_dim=8,
                    num_points=3, num_queries=21, train=True,
                    slab_dtype="bfloat16")
    again = plan_mod.spec_from_json(json.loads(json.dumps(plan_mod.spec_to_json(spec))))
    assert again == spec
    with pytest.raises(ValueError, match="unknown MsdaSpec fields"):
        plan_mod.spec_from_json({**plan_mod.spec_to_json(spec), "future": 1})


def test_plan_store_round_trip_identical_describe(tmp_path):
    specs = [
        MsdaSpec(spatial_shapes=((8, 8), (4, 4)), num_heads=2, head_dim=8,
                 num_points=2, num_queries=32, slab_dtype="auto"),
        MsdaSpec(spatial_shapes=((6, 6),), num_heads=2, head_dim=8,
                 num_points=2, num_queries=16),
    ]
    plans = [plan_mod.msda_plan(s, backend="cpu", tune="autotune") for s in specs]
    describes = [p.describe() for p in plans]
    store = persistence.PlanStore(str(tmp_path / "plans.json"))
    assert store.save_plans(plans) == 2

    # simulated restart: in-process plan cache gone, winner cache gone
    plan_mod.clear_plans()
    os.environ["REPRO_MSDA_AUTOTUNE_CACHE"] = str(tmp_path / "autotune2.json")
    plan_mod.reset_autotune_stats()
    report = persistence.PlanStore(store.path).restore()
    assert len(report.plans) == 2 and not report.skipped
    assert report.describe_mismatches == []
    for restored, before in zip(report.plans, describes):
        assert (persistence._norm_describe(restored.describe())
                == persistence._norm_describe(before))
    stats = plan_mod.autotune_stats()
    assert stats["raced"] == 0, "restore must not run autotune timing"
    assert stats["seeded"] >= 1  # the autotuned winner was seeded


def test_plan_store_version_and_corruption_degrade_cold(tmp_path):
    path = tmp_path / "plans.json"
    path.write_text(json.dumps({"version": 999, "entries": [{}]}))
    store = persistence.PlanStore(str(path))
    assert store.load() is None
    report = store.restore()
    assert report.cold and not report.plans
    path.write_text("{not json")
    assert persistence.PlanStore(str(path)).restore().cold


def test_plan_store_skips_newer_schema_entries(tmp_path):
    spec = MsdaSpec(spatial_shapes=((4, 4),), num_heads=2, head_dim=8,
                    num_points=2, num_queries=8)
    plan = plan_mod.msda_plan(spec, backend="ref")
    store = persistence.PlanStore(str(tmp_path / "p.json"))
    store.save_plans([plan])
    data = json.loads(open(store.path).read())
    data["entries"].append({"spec": {"mystery_field": 1}, "backend": "ref"})
    open(store.path, "w").write(json.dumps(data))
    report = store.restore()
    assert len(report.plans) == 1 and len(report.skipped) == 1


def test_engine_store_restart_zero_races(tmp_path):
    store_path = str(tmp_path / "engine-plans.json")
    cfg, params, eng = _vlm_engine(store_path=store_path, tune="autotune",
                                   dtype_policy="auto")
    assert eng.restore_report is None and os.path.exists(store_path)
    n_plans = len(eng.plans)
    assert plan_mod.autotune_stats()["raced"] >= 1  # cold boot really tuned

    plan_mod.clear_plans()
    plan_mod.reset_autotune_stats()
    os.environ["REPRO_MSDA_AUTOTUNE_CACHE"] = str(tmp_path / "autotune2.json")
    from repro.models import vlm  # params reused; fresh engine = new process

    eng2 = ServeEngine(cfg, params, slots=2, capacity=64,
                       store_path=store_path, tune="autotune",
                       dtype_policy="auto")
    assert eng2.restore_report is not None
    assert len(eng2.restore_report.plans) == n_plans
    assert eng2.restore_report.describe_mismatches == []
    assert plan_mod.autotune_stats()["raced"] == 0
    # restored plans serve requests end-to-end
    eng2.warmup(prompt_lengths=(4,))
    req = _pyr_request(0, cfg.vision, cfg.vision.levels)
    with aot.probe() as p:
        eng2.submit(req)
        eng2.run()
    assert req.done and p.traces == 0


def test_engine_never_clobbers_mismatched_store(tmp_path):
    """A store written under different plan axes (e.g. a sweep artifact)
    must survive a mis-configured boot untouched — servers with the
    right flags still restore it afterwards."""
    store_path = str(tmp_path / "fleet.json")
    cfg, params, _ = _vlm_engine(store_path=store_path, dtype_policy="bfloat16")
    before = open(store_path).read()
    plan_mod.clear_plans()
    eng2 = ServeEngine(cfg, params, slots=2, capacity=64,
                       store_path=store_path)  # default policy: gate fails
    assert eng2.store_meta_mismatch and eng2.restore_report is None
    assert eng2.plans  # still serves, from a fresh warm-up
    assert open(store_path).read() == before


# --------------------------------------------------------------------------
# bucketed batcher: padding correctness
# --------------------------------------------------------------------------


def test_padded_bucket_matches_unbatched_reference():
    """Kernel-level: pad value into a bigger grid + scale locations ==
    the unpadded op (zeros padding == zero out-of-range corners)."""
    levels = ((6, 5), (3, 2))
    bucket = ((8, 8), (4, 4))
    B, Q, H, D, P = 2, 9, 2, 8, 3
    L = len(levels)
    S = sum(h * w for h, w in levels)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    value = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    loc = jax.random.uniform(ks[1], (B, Q, H, L, P, 2), minval=-0.1, maxval=1.1)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, L, P)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, L, P)

    ref_out = msda_ref(value, levels, loc, attn)

    ratios = bm.valid_ratios(levels, bucket)
    vp = np.stack([
        np.concatenate([
            bm.pad_pyramid(np.asarray(value[b, :, h]), levels, bucket)[None]
            for h in range(H)])
        for b in range(B)])  # (B, H, S_b, D)
    vp = jnp.asarray(np.transpose(vp, (0, 2, 1, 3)))  # (B, S_b, H, D)
    loc_b = jnp.asarray(bm.scale_locations(np.asarray(loc), ratios))
    pad_out = msda_ref(vp, bucket, loc_b, attn)
    np.testing.assert_allclose(np.asarray(pad_out), np.asarray(ref_out),
                               atol=1e-5, rtol=1e-5)


def test_bucketed_engine_matches_exact_geometry_serving():
    """Engine-level: a request padded into a bucket decodes the same
    tokens as direct serving at its exact pyramid geometry."""
    from repro.models import vlm

    cfg, params, eng = _vlm_engine()
    vc = cfg.vision
    # power-of-two fractions make the valid-ratio rescale exact in fp32
    # (quarter size: strictly inside the smallest bucket, so it pads)
    levels = tuple((max(1, h // 4), max(1, w // 4)) for h, w in vc.levels)
    req = _pyr_request(0, vc, levels, max_new=5)
    bucket = bm.bucket_for(levels, eng.buckets)
    assert bucket is not None and bucket.levels != levels  # really padded

    eng.submit(req)
    eng.run()
    assert req.done

    lp, cache = vlm.vlm_prefill(params, cfg, jnp.asarray(req.pyramid[None]),
                                jnp.asarray(req.prompt[None]), 64,
                                levels=levels)
    outs = [int(np.asarray(lp)[0].argmax())]
    for _ in range(req.max_new - 1):
        ld, cache = vlm.vlm_decode_step(params, cfg, cache,
                                        jnp.asarray([outs[-1]], jnp.int32))
        outs.append(int(np.asarray(ld)[0].argmax()))
    assert req.out == outs


def test_batcher_utilities():
    buckets = bm.default_buckets(((8, 8), (4, 4)), scales=(1.0, 0.5))
    assert [b.key for b in buckets] == ["4x4/2x2", "8x8/4x4"]
    assert bm.bucket_for(((3, 4), (2, 2)), buckets).key == "4x4/2x2"
    assert bm.bucket_for(((5, 4), (2, 2)), buckets).key == "8x8/4x4"
    assert bm.bucket_for(((9, 9), (4, 4)), buckets) is None

    feats = np.arange(6 * 2, dtype=np.float32).reshape(6, 2)
    padded = bm.pad_pyramid(feats, ((2, 3),), ((4, 4),))
    assert padded.shape == (16, 2)
    np.testing.assert_array_equal(padded.reshape(4, 4, 2)[:2, :3], feats.reshape(2, 3, 2))
    assert padded.reshape(4, 4, 2)[2:].sum() == 0
    np.testing.assert_allclose(bm.valid_ratios(((2, 3),), ((4, 4),)),
                               [[0.75, 0.5]])  # (x=w/W, y=h/H)


def test_batcher_groups_same_bucket_and_key():
    buckets = bm.default_buckets(((4, 4),), scales=(1.0, 0.5))
    q = bm.PyramidBatcher(buckets)
    d = 3
    small, big = ((2, 2),), ((4, 4),)
    for i, (lv, key) in enumerate([(small, 5), (big, 5), (small, 5), (small, 7)]):
        S = sum(h * w for h, w in lv)
        q.submit(np.zeros((S, d), np.float32), lv, f"r{i}", group_key=key)
    b1 = q.next_batch(4)  # head r0: small/5 -> r0 + r2 (NOT r1: bucket, r3: key)
    assert b1.items == ["r0", "r2"] and b1.bucket.key == "2x2"
    assert b1.feats.shape == (2, 4, d) and b1.ratios.shape == (2, 1, 2)
    b2 = q.next_batch(4)
    assert b2.items == ["r1"]
    assert q.next_batch(4).items == ["r3"] and len(q) == 0


def test_batcher_exactness_gate_non_pow2_ratio():
    """6->8 and 3->4 are non-pow2 ratios: the gate must route those
    requests to a padding-free exact-geometry bucket (and equal exact
    buckets must still batch together), while lossy_ok keeps the old
    pad-into-the-ladder behaviour."""
    buckets = (bm.PyramidBucket(((8, 8), (4, 4))),)
    levels = ((6, 6), (3, 3))
    assert not bm.exact_bucket_ratios(levels, buckets[0].levels)
    assert bm.exact_bucket_ratios(((4, 4), (2, 2)), buckets[0].levels)
    assert bm.exact_bucket_ratios(((8, 8), (4, 4)), buckets[0].levels)

    S = sum(h * w for h, w in levels)
    rng = np.random.default_rng(0)
    f0 = rng.standard_normal((S, 3)).astype(np.float32)
    f1 = rng.standard_normal((S, 3)).astype(np.float32)

    q = bm.PyramidBatcher(buckets)
    assert q.submit(f0, levels, "r0").levels == levels  # rerouted
    assert q.submit(f1, levels, "r1").levels == levels
    batch = q.next_batch(4)
    # distinct-but-equal exact buckets batch together (dataclass ==)
    assert batch.items == ["r0", "r1"]
    assert batch.bucket.levels == levels and batch.padding_frac == 0.0
    np.testing.assert_array_equal(batch.ratios, 1.0)
    np.testing.assert_array_equal(batch.feats, np.stack([f0, f1]))

    lossy = bm.PyramidBatcher(buckets, lossy_ok=True)
    assert lossy.submit(f0, levels, "r0").levels == buckets[0].levels


def test_non_pow2_bucketed_vs_unbatched():
    """At a non-pow2 ratio the valid-ratio rescale rounds: the gated
    (exact-geometry) path is bitwise-identical to unbatched serving,
    the lossy padded path only matches within tolerance."""
    levels = ((6, 6), (3, 3))
    bucket = ((8, 8), (4, 4))
    B, Q, H, D, P = 2, 9, 2, 8, 3
    L = len(levels)
    S = sum(h * w for h, w in levels)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    value = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    loc = jax.random.uniform(ks[1], (B, Q, H, L, P, 2), minval=-0.1, maxval=1.1)
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, Q, H, L, P)).reshape(B, Q, H, -1)
    ).reshape(B, Q, H, L, P)

    ref_out = msda_ref(value, levels, loc, attn)

    # gated path: the batcher hands back the exact geometry untouched,
    # so the op sees identical operands -> identical bits
    q = bm.PyramidBatcher((bm.PyramidBucket(bucket),))
    q.submit(np.asarray(value[0].reshape(S, H * D)), levels, "r")
    batch = q.next_batch(1)
    np.testing.assert_array_equal(batch.ratios, 1.0)
    gated = msda_ref(value, batch.bucket.levels,
                     bm.scale_locations(loc, jnp.asarray(batch.ratios[0])),
                     attn)
    np.testing.assert_array_equal(np.asarray(gated), np.asarray(ref_out))

    # lossy path (what submit did before the gate): pad + rescale — the
    # 0.75 ratio is not an exponent shift, so only allclose holds
    ratios = bm.valid_ratios(levels, bucket)
    vp = np.stack([
        np.concatenate([
            bm.pad_pyramid(np.asarray(value[b, :, h]), levels, bucket)[None]
            for h in range(H)])
        for b in range(B)])
    vp = jnp.asarray(np.transpose(vp, (0, 2, 1, 3)))
    pad_out = msda_ref(vp, bucket, jnp.asarray(
        bm.scale_locations(np.asarray(loc), ratios)), attn)
    np.testing.assert_allclose(np.asarray(pad_out), np.asarray(ref_out),
                               atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# engine scheduling + metrics
# --------------------------------------------------------------------------


def test_retire_frees_slot_same_tick():
    """slots=1, two requests: the slot freed by a finished request is
    re-admitted before that tick's decode — zero idle decode ticks."""
    _, _, eng = _lm_engine(slots=1, arch="stablelm-1.6b", capacity=16)
    reqs = [Request(rid=i, prompt=np.arange(3, dtype=np.int32) + 7 * i,
                    max_new=3) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 3 for r in reqs)
    # each request decodes (max_new - 1) ticks; any admit-after-retire
    # lag would add idle ticks on top
    assert eng.metrics.ticks == sum(r.max_new - 1 for r in reqs)
    assert eng.metrics.retired == 2


def test_queue_is_deque_fifo_over_capacity():
    _, _, eng = _lm_engine(slots=2, arch="stablelm-1.6b", capacity=16)
    reqs = [Request(rid=i, prompt=np.arange(3, dtype=np.int32) + i * 7,
                    max_new=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 3 for r in reqs)
    s = eng.metrics.snapshot()
    assert s["submitted"] == s["admitted"] == s["retired"] == 5


def test_metrics_padding_and_latency():
    m = ServeMetrics()
    m.record_submit(0)
    m.record_tick()
    m.record_admit([0], "8x8", real_tokens=30, padded_tokens=64)
    m.record_tick()
    m.record_retire(0)
    s = m.snapshot()
    assert s["buckets"]["8x8"]["admitted"] == 1
    assert abs(s["buckets"]["8x8"]["padding_frac"] - (1 - 30 / 64)) < 1e-9
    assert s["queue_ticks"]["max"] == 1.0 and s["latency_ticks"]["max"] == 1.0
    assert "8x8" in m.format()


def test_make_serve_fns_threads_dtype_policy():
    cfg = reduced(get_config("phi-3-vision-4.2b"))
    plans = warmup_msda_plans(cfg, dtype_policy="bfloat16")
    assert plans and all(p.spec.slab_dtype == "bfloat16" for p in plans)
    # the prefill closure must build the SAME spec (policy reaches the
    # resampler, not just the warm-up): tracing it adds no plan-cache miss
    from repro.serving.engine import make_serve_fns

    prefill, _ = make_serve_fns(cfg, dtype_policy="bfloat16")
    misses0 = plan_mod.plan_cache_info()["misses"]
    from repro.models import vlm

    vd, nv = cfg.vision.vision_dim, sum(h * w for h, w in cfg.vision.levels)
    params_avals = jax.eval_shape(lambda: vlm.init_vlm(jax.random.PRNGKey(0), cfg))
    jax.eval_shape(lambda p, py, t: prefill(p, py, t, 32), params_avals,
                   jax.ShapeDtypeStruct((1, nv, vd), jnp.float32),
                   jax.ShapeDtypeStruct((1, 4), jnp.int32))
    assert plan_mod.plan_cache_info()["misses"] == misses0


# --------------------------------------------------------------------------
# sweep CLI
# --------------------------------------------------------------------------


def test_sweep_cli_populates_store(tmp_path, monkeypatch, capsys):
    import benchmarks.sweep as sweep

    monkeypatch.setattr(
        "sys.argv",
        ["sweep", "--smoke", "--archs", "phi-3-vision-4.2b",
         "--policies", "follow", "--store-dir", str(tmp_path / "fleet")])
    sweep.main()
    out = capsys.readouterr().out
    assert "phi-3-vision-4.2b-smoke,follow" in out
    stores = list((tmp_path / "fleet").glob("*.json"))
    assert len(stores) == 1
    # the store restores with zero races in a "new" process
    plan_mod.clear_plans()
    plan_mod.reset_autotune_stats()
    report = persistence.PlanStore(str(stores[0])).restore()
    assert report.plans and not report.describe_mismatches
    assert plan_mod.autotune_stats()["raced"] == 0
