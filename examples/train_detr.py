"""End-to-end: train a deformable-DETR detector with MSDA encoders.

The paper's host workload: every encoder layer runs multi-scale
deformable attention over the feature pyramid.  Synthetic detection
data (boxes whose pyramid features carry a planted signature) — the
loss drops as MSDA learns to pool the right locations.

The loop runs inside :class:`repro.training.TrainingHarness`, so the
example doubles as the fault-tolerance demo: give it ``--ckpt-dir`` and
``--preempt-at 40`` and watch it lose step 40 mid-compute, restore the
latest checkpoint, and replay to a bit-identical trajectory.
``--bench-out`` writes the ``BENCH_train.json`` step-time telemetry.

    PYTHONPATH=src python examples/train_detr.py --steps 150
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import deformable_transformer as dt
from repro.optim import adamw, schedule
from repro.training import (FaultSchedule, HarnessConfig, StepTimeRecorder,
                            TrainingHarness)


def synth_batch(cfg, key, B=4, T=3):
    """Boxes + labels; the pyramid gets a bump at each object's center."""
    mc = cfg.msda
    kb, kl, kf = jax.random.split(key, 3)
    boxes = jax.random.uniform(kb, (B, T, 4), minval=0.2, maxval=0.8)
    labels = jax.random.randint(kl, (B, T), 1, cfg.vocab_size)
    sp = sum(h * w for h, w in mc.levels)
    pyr = jax.random.normal(kf, (B, sp, cfg.d_model)) * 0.05
    # plant a label-dependent signature at each object's center pixel
    offset = 0
    for (h, w) in mc.levels:
        cx = jnp.clip((boxes[..., 0] * w).astype(int), 0, w - 1)
        cy = jnp.clip((boxes[..., 1] * h).astype(int), 0, h - 1)
        flat = offset + cy * w + cx  # (B,T)
        sig = jax.nn.one_hot(labels % cfg.d_model, cfg.d_model) * 2.0
        pyr = pyr.at[jnp.arange(B)[:, None], flat].add(sig)
        offset += h * w
    return {"pyramid": pyr, "labels": labels, "boxes": boxes}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="inject a mid-step preemption at this step")
    ap.add_argument("--bench-out", default=None,
                    help="write BENCH_train.json telemetry here")
    args = ap.parse_args()

    cfg = reduced(get_config("deformable-detr"))
    B = 4

    # warm the MSDA plans (backend + block planning committed once, before
    # the first jitted step traces) and show what was decided
    for name, plan in dt.msda_plans(cfg, dtype="float32", train=True).items():
        print(f"msda plan ({name}):\n{plan.describe()}")

    @jax.jit
    def step_fn(state, batch):
        params, opt = state["params"], state["opt"]
        loss, grads = jax.value_and_grad(
            lambda p: dt.detr_loss(p, cfg, batch, remat=False)
        )(params)
        lr = schedule.warmup_cosine(state["step"], peak_lr=args.lr,
                                    warmup_steps=10, total_steps=args.steps)
        params, opt, gnorm = adamw.adamw_update(grads, opt, params, lr=lr)
        return ({"params": params, "opt": opt, "step": state["step"] + 1},
                {"loss": loss, "grad_norm": gnorm, "lr": lr})

    def init_fn():
        params = dt.init_detr(jax.random.PRNGKey(0), cfg)
        return {"params": params, "opt": adamw.init_adamw(params),
                "step": jnp.zeros((), jnp.int32)}

    # batches are a pure function of the step index -> a recovered run
    # replays exactly the data the lost steps saw
    def batch_fn(step):
        return synth_batch(cfg, jax.random.PRNGKey(1000 + step), B=B)

    sp = sum(h * w for h, w in cfg.msda.levels)
    recorder = StepTimeRecorder(
        tokens_per_step=B * sp,
        config={"example": "train_detr", "steps": args.steps, "batch": B})
    faults = (FaultSchedule.from_spec(f"preempt@{args.preempt_at}")
              if args.preempt_at is not None else None)
    harness = TrainingHarness(
        step_fn=step_fn, batch_fn=batch_fn, init_fn=init_fn,
        config=HarnessConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                             ckpt_every=args.ckpt_every),
        faults=faults, telemetry=recorder)
    out = harness.run()

    losses = out["losses"]
    for s in sorted(losses):
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:4d}  loss {losses[s]:7.4f}")
    for rec in out["recovery_log"]:
        print(f"recovered from {rec['kind']} at step {rec['failed_step']}, "
              f"resumed from checkpoint step {rec['resumed_from']}")
    first, last = min(losses), max(losses)
    summ = recorder.summary()
    print(f"loss {losses[first]:.3f} -> {losses[last]:.3f} over "
          f"{out['final_step']} steps ({summ['mean_step_s']:.2f}s/step, "
          f"{out['restarts']} restarts)")
    if args.bench_out:
        print(f"wrote telemetry -> {recorder.write(args.bench_out)}")


if __name__ == "__main__":
    main()
