"""End-to-end: train a deformable-DETR detector with MSDA encoders.

The paper's host workload: every encoder layer runs multi-scale
deformable attention over the feature pyramid.  Synthetic detection
data (boxes whose pyramid features carry a planted signature) — the
loss drops as MSDA learns to pool the right locations.

    PYTHONPATH=src python examples/train_detr.py --steps 150
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs.base import get_config, reduced
from repro.core import deformable_transformer as dt
from repro.optim import adamw, schedule
from repro.train import state as train_state


def synth_batch(cfg, key, B=4, T=3):
    """Boxes + labels; the pyramid gets a bump at each object's center."""
    mc = cfg.msda
    kb, kl, kf = jax.random.split(key, 3)
    boxes = jax.random.uniform(kb, (B, T, 4), minval=0.2, maxval=0.8)
    labels = jax.random.randint(kl, (B, T), 1, cfg.vocab_size)
    sp = sum(h * w for h, w in mc.levels)
    pyr = jax.random.normal(kf, (B, sp, cfg.d_model)) * 0.05
    # plant a label-dependent signature at each object's center pixel
    offset = 0
    for (h, w) in mc.levels:
        cx = jnp.clip((boxes[..., 0] * w).astype(int), 0, w - 1)
        cy = jnp.clip((boxes[..., 1] * h).astype(int), 0, h - 1)
        flat = offset + cy * w + cx  # (B,T)
        sig = jax.nn.one_hot(labels % cfg.d_model, cfg.d_model) * 2.0
        pyr = pyr.at[jnp.arange(B)[:, None], flat].add(sig)
        offset += h * w
    return {"pyramid": pyr, "labels": labels, "boxes": boxes}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = reduced(get_config("deformable-detr"))
    params = dt.init_detr(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_adamw(params)

    # warm the MSDA plans (backend + block planning committed once, before
    # the first jitted step traces) and show what was decided
    for name, plan in dt.msda_plans(cfg, dtype="float32", train=True).items():
        print(f"msda plan ({name}):\n{plan.describe()}")

    @jax.jit
    def step(params, opt, batch, lr):
        loss, grads = jax.value_and_grad(
            lambda p: dt.detr_loss(p, cfg, batch, remat=False)
        )(params)
        params, opt, gnorm = adamw.adamw_update(grads, opt, params, lr=lr)
        return params, opt, loss, gnorm

    t0 = time.time()
    first = None
    for s in range(args.steps):
        batch = synth_batch(cfg, jax.random.PRNGKey(1000 + s))
        lr = schedule.warmup_cosine(jnp.asarray(s), peak_lr=args.lr,
                                    warmup_steps=10, total_steps=args.steps)
        params, opt, loss, gnorm = step(params, opt, batch, lr)
        first = first if first is not None else float(loss)
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:4d}  loss {float(loss):7.4f}  gnorm {float(gnorm):6.2f}"
                  f"  ({(time.time()-t0)/(s+1):.2f}s/step)", flush=True)
        if args.ckpt_dir and (s + 1) % 50 == 0:
            ckpt.save({"params": params, "step": jnp.asarray(s)}, args.ckpt_dir, s + 1)
    print(f"loss {first:.3f} -> {float(loss):.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
