"""End-to-end LM pretraining driver: data -> train -> checkpoint -> restart.

Runs a reduced llama3-family model on deterministic synthetic data with
checkpoint/restore mid-run (the fault-tolerance path), then greedy-decodes
from the trained weights.  On a real slice the same code drives the full
config (see launch/train.py + the dry-run for the production mesh).

    PYTHONPATH=src python examples/train_lm_e2e.py --steps 200
"""
import argparse
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import lm
from repro.train import loop as train_loop, state as train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_e2e")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = reduced(get_config("llama3-8b"))
    pipe = Pipeline(DataConfig(global_batch=args.batch, seq_len=args.seq,
                               vocab_size=cfg.vocab_size, seed=0))
    step_fn = jax.jit(train_loop.make_train_step(
        cfg, peak_lr=3e-3, warmup_steps=10, total_steps=args.steps,
        num_microbatches=2,
    ), donate_argnums=(0,))
    state = train_state.init_state(jax.random.PRNGKey(0), cfg)

    half = args.steps // 2
    t0 = time.time()
    for s in range(half):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        state, m = step_fn(state, batch)
        if s % 20 == 0:
            print(f"step {s:4d} loss {float(m['loss']):.4f}")
    ckpt.save(state, args.ckpt_dir, half)
    print(f"-- simulated preemption at step {half}; restoring --")
    state2 = train_state.init_state(jax.random.PRNGKey(0), cfg)  # cold start
    state2 = ckpt.restore(args.ckpt_dir, state2)
    assert int(state2.step) == half
    for s in range(half, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        state2, m = step_fn(state2, batch)
        if s % 20 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(m['loss']):.4f}")
    print(f"{args.steps} steps in {time.time()-t0:.1f}s; final loss "
          f"{float(m['loss']):.4f} (ln V = {np.log(cfg.vocab_size):.2f} at init)")

    # greedy decode from the trained model
    logits, cache = lm.lm_prefill(state2.params, cfg,
                                  jnp.asarray([[1, 7, 7]]), capacity=32)
    toks = [int(np.asarray(logits)[0].argmax())]
    for _ in range(8):
        logits, cache = lm.lm_decode_step(
            state2.params, cfg, cache, jnp.asarray([toks[-1]], jnp.int32))
        toks.append(int(np.asarray(logits)[0].argmax()))
    print("greedy continuation:", toks)


if __name__ == "__main__":
    main()
