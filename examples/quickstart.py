"""Quickstart: plan once, execute many — the xMSDA plan/execute API.

The paper's lesson is that MSDA gets fast when the static problem
geometry is exploited *ahead of time*.  The API mirrors that:

1. describe the problem once (``MsdaSpec``),
2. build a plan (``msda_plan`` — backend registry + block planning +
   VJP wiring, all committed here),
3. execute the plan per batch (``plan(value, loc, attn)``).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.plan import MsdaSpec, msda_plan, plan_cache_info
from repro.kernels.ref import msda_grid_sample_baseline, msda_ref

# a small multi-scale feature pyramid: 3 levels, 2 heads x 16 dims
levels = ((32, 32), (16, 16), (8, 8))
B, Q, H, D, P = 2, 500, 2, 16, 4
S = sum(h * w for h, w in levels)

key = jax.random.PRNGKey(0)
kv, kl, ka = jax.random.split(key, 3)
value = jax.random.normal(kv, (B, S, H, D))                      # (B, S, H, D)
loc = jax.random.uniform(kl, (B, Q, H, len(levels), P, 2))       # in [0, 1]
attn = jax.nn.softmax(
    jax.random.normal(ka, (B, Q, H, len(levels), P)).reshape(B, Q, H, -1)
).reshape(B, Q, H, len(levels), P)

# 1-2) spec + plan: every hardware-aware decision happens HERE, once.
spec = MsdaSpec(spatial_shapes=levels, num_heads=H, head_dim=D,
                num_points=P, num_queries=Q, dtype="float32")
plan = msda_plan(spec, backend="pallas")   # or "ref", "auto", tune="autotune"
print(plan.describe())                     # per-level block_q / slabs / VMEM
print("registered backends:", registry.list_backends())

# 3) execute — same MMCV conventions as the one-shot op
out_pal = plan(value, loc, attn)
out_ref = msda_ref(value, levels, loc, attn)                    # fused oracle
out_base = msda_grid_sample_baseline(value, levels, loc, attn)  # paper "Baseline"
print("baseline vs ref  max err:", float(jnp.abs(out_base - out_ref).max()))
print("pallas   vs ref  max err:", float(jnp.abs(out_pal - out_ref).max()))

# plans are cached by spec: an identical spec returns the SAME object and
# never re-runs block planning (serving processes call clear_plans())
assert msda_plan(spec, backend="pallas") is plan
print("plan cache:", plan_cache_info())

# it differentiates (custom VJP wired at plan time; train=True saves the
# gathered corners for a gather-free backward phase 1)
train_plan = msda_plan(MsdaSpec(spatial_shapes=levels, num_heads=H, head_dim=D,
                                num_points=P, num_queries=Q, dtype="float32",
                                train=True), backend="pallas")
grads = jax.grad(
    lambda v, l, a: jnp.sum(train_plan(v, l, a) ** 2), argnums=(0, 1, 2)
)(value, loc, attn)
print("grad shapes:", [g.shape for g in grads])

# the adaptive block plan (paper Fig. 7): bigger levels -> smaller blocks
print("block plan:", plan.block_q)

# -- the dtype-policy knob: mixed precision is a PLANNED axis -------------
# bf16 slabs halve VMEM residency (the planner widens block_q for it);
# accumulation stays fp32 inside the kernels, so error doesn't grow with
# Q.  slab_dtype="auto" + tune="autotune" races fp32 vs bf16 per level
# and persists the winner per device kind.  Model configs expose this as
# MSDAConfig.dtype_policy ('follow' | 'float32' | 'bfloat16' | 'auto').
bf16_plan = msda_plan(MsdaSpec(spatial_shapes=levels, num_heads=H, head_dim=D,
                               num_points=P, num_queries=Q, dtype="float32",
                               slab_dtype="bfloat16"), backend="pallas")
print(bf16_plan.describe())  # note the slab_dt column + accum=float32
err = jnp.abs(bf16_plan(value, loc, attn) - out_ref).max()
print("bf16-slab vs fp32 ref max err:", float(err), "(bf16 tolerance tier)")

# -- the backend matrix ---------------------------------------------------
# every registered backend executes the same plan contract; "auto" picks
# pallas on TPU and the vectorised "cpu" backend elsewhere (padded-slab
# per-corner gathers, head-major layout — faster forward than the "ref"
# oracle; backward is scatter-bound for both, so train is parity).
# tests/conformance.py checks fwd+VJP parity for every (backend, policy).
for name in registry.list_backends():
    p = msda_plan(spec, backend=name)
    e = jnp.abs(p(value, loc, attn) - out_ref).max()
    print(f"backend {name:8s} gather={p.level_report()[0]['gather']:<11s} "
          f"max err vs ref: {float(e):.2e}")

# -- warm-start serving: persist plans + AOT-compile across restarts -----
# A serving process saves its warmed plans (specs + autotune winners) to
# a versioned store; a RESTARTED process rebuilds the identical plan set
# with zero autotune timing runs, then AOT-compiles the executors at
# boot (jit(...).lower().compile()) so the first request never traces.
# serving.persistence.enable_jax_compilation_cache(dir) additionally
# makes those boot compiles disk hits on a restart.
import os
import tempfile

from repro.serving import aot
from repro.serving.persistence import PlanStore

store = PlanStore(os.path.join(tempfile.mkdtemp(), "plans.json"))
store.save_plans([plan, train_plan])
# --- imagine a process restart here ---
report = store.restore()          # seeds winners, rebuilds plans, 0 races
warm_plan = report.plans[0]
assert warm_plan.describe() == plan.describe()
executor = aot.compile_plan_executor(warm_plan, batch_size=B)  # boot-time
with aot.probe() as probe:
    out_warm = executor(value, loc, attn)                      # request-time
print(f"warm-start: {len(report.plans)} plans restored, "
      f"request-time traces={probe.traces} (AOT), "
      f"max err vs ref: {float(jnp.abs(out_warm - out_ref).max()):.2e}")

# CPU timing: fused vs materialising baseline
f_ref = jax.jit(lambda v, l, a: msda_ref(v, levels, l, a))
f_base = jax.jit(lambda v, l, a: msda_grid_sample_baseline(v, levels, l, a))
for name, f in (("fused", f_ref), ("baseline", f_base)):
    jax.block_until_ready(f(value, loc, attn))
    t = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(f(value, loc, attn))
    print(f"{name:9s}: {(time.perf_counter() - t) / 20 * 1e3:.2f} ms/call")
