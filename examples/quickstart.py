"""Quickstart: the xMSDA op in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels.ops import msda, plan_blocks
from repro.kernels.ref import msda_grid_sample_baseline, msda_ref

# a small multi-scale feature pyramid: 3 levels, 2 heads x 16 dims
levels = ((32, 32), (16, 16), (8, 8))
B, Q, H, D, P = 2, 500, 2, 16, 4
S = sum(h * w for h, w in levels)

key = jax.random.PRNGKey(0)
kv, kl, ka = jax.random.split(key, 3)
value = jax.random.normal(kv, (B, S, H, D))                      # (B, S, H, D)
loc = jax.random.uniform(kl, (B, Q, H, len(levels), P, 2))       # in [0, 1]
attn = jax.nn.softmax(
    jax.random.normal(ka, (B, Q, H, len(levels), P)).reshape(B, Q, H, -1)
).reshape(B, Q, H, len(levels), P)

# three implementations of the same op
out_base = msda_grid_sample_baseline(value, levels, loc, attn)  # paper "Baseline"
out_ref = msda_ref(value, levels, loc, attn)                    # fused oracle
out_pal = msda(value, levels, loc, attn, backend="pallas")      # xMSDA kernels
print("baseline vs ref  max err:", float(jnp.abs(out_base - out_ref).max()))
print("pallas   vs ref  max err:", float(jnp.abs(out_pal - out_ref).max()))

# it differentiates (custom VJP: fused bwd kernels with scatter-add)
grads = jax.grad(
    lambda v, l, a: jnp.sum(msda(v, levels, l, a, backend="pallas", train=True) ** 2),
    argnums=(0, 1, 2),
)(value, loc, attn)
print("grad shapes:", [g.shape for g in grads])

# the adaptive block plan (paper Fig. 7): bigger levels -> smaller blocks
print("block plan:", plan_blocks(levels, P, D, Q))

# CPU timing: fused vs materialising baseline
f_ref = jax.jit(lambda v, l, a: msda_ref(v, levels, l, a))
f_base = jax.jit(lambda v, l, a: msda_grid_sample_baseline(v, levels, l, a))
for name, f in (("fused", f_ref), ("baseline", f_base)):
    jax.block_until_ready(f(value, loc, attn))
    t = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(f(value, loc, attn))
    print(f"{name:9s}: {(time.perf_counter() - t) / 20 * 1e3:.2f} ms/call")
