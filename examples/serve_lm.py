"""Continuous-batching LM serving with staggered request arrival.

Requests of different lengths share a fixed slot pool; slots admit new
work as they free up (per-slot cache positions).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.data import tokenizer
from repro.serving.engine import Request, ServeEngine
from repro.train import state as train_state

cfg = reduced(get_config("llama3-8b"))
params = train_state.init_model(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(cfg, params, slots=3, capacity=96, temperature=0.0)

prompts = [
    "multi-scale deformable attention",
    "the quick brown fox",
    "tpu kernels",
    "gather and scatter",
    "roofline",
]
reqs = []
for i, text in enumerate(prompts):
    ids = np.asarray(tokenizer.encode(text), np.int32) % cfg.vocab_size
    req = Request(rid=i, prompt=ids, max_new=12)
    reqs.append(req)

# AOT warm-up: trace + compile decode and every expected prefill length
# BEFORE traffic — requests then run with zero retraces (serving.aot).
engine.warmup(prompt_lengths=tuple(sorted({len(r.prompt) for r in reqs})))

for req in reqs:
    engine.submit(req)

t0 = time.time()
ticks = 0
while any(not r.done for r in reqs):
    if not engine.step():
        break
    ticks += 1
dt = time.time() - t0
tok = sum(len(r.out) for r in reqs)
print(f"{len(reqs)} requests on 3 slots: {tok} tokens in {ticks} ticks "
      f"({tok/dt:.1f} tok/s on CPU)")
for r in reqs:
    print(f"  req {r.rid}: {len(r.out)} new tokens {r.out[:8]}...")
print(engine.metrics.format())
