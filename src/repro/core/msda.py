"""MSDAttention: the paper's op as a composable model module.

Wraps the xMSDA kernel (``repro.kernels.ops.msda``) with the standard
Deformable-DETR parameterisation: per-query learned sampling offsets
around reference points + softmaxed attention weights, value/output
projections.

Distribution (``distributed_msda``): the op is sharded with
``shard_map`` —

* batch over the 'dp' axes, heads over 'tp' (value sharded, no
  reduction needed: each shard owns its heads' slice of grad_value);
* optionally queries over 'tp' instead (``query_parallel=True``) for
  huge-Q workloads (the DETR encoder's 87k pixel queries). The value
  tensor is then replicated over 'tp' and shard_map's reverse-mode
  transpose emits the **psum of per-shard partial grad_value slabs** —
  the TPU-idiomatic realisation of the paper's staggered-scatter idea
  (contention eliminated via partial accumulators + reduction, §4.2).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.models import layers
from repro.sharding import rules


def level_ref_points(levels) -> jax.Array:
    """Normalised (x, y) centers for every pixel of every level: (S, 2)."""
    out = []
    for (h, w) in levels:
        ys = (jnp.arange(h, dtype=jnp.float32) + 0.5) / h
        xs = (jnp.arange(w, dtype=jnp.float32) + 0.5) / w
        gy, gx = jnp.meshgrid(ys, xs, indexing="ij")
        out.append(jnp.stack([gx, gy], -1).reshape(h * w, 2))
    return jnp.concatenate(out, axis=0)


def init_msda_attention(key, d_model: int, msda_cfg) -> dict:
    L = len(msda_cfg.levels)
    H, Pn = msda_cfg.num_heads, msda_cfg.num_points
    ks = jax.random.split(key, 4)
    p = {
        "value_proj": layers.dense_init(ks[0], (d_model, d_model)),
        "out_proj": layers.dense_init(ks[1], (d_model, d_model)),
        "w_offsets": jnp.zeros((d_model, H * L * Pn * 2), jnp.float32),
        "w_weights": layers.dense_init(ks[2], (d_model, H * L * Pn)) * 0.01,
        "b_weights": jnp.zeros((H * L * Pn,), jnp.float32),
    }
    # Deformable-DETR offset-bias init: points spread on a ring per head
    theta = jnp.arange(H, dtype=jnp.float32) * (2.0 * math.pi / H)
    grid = jnp.stack([jnp.cos(theta), jnp.sin(theta)], -1)  # (H,2)
    grid = grid / jnp.abs(grid).max(-1, keepdims=True)
    grid = jnp.tile(grid[:, None, None], (1, L, Pn, 1))
    scale = (jnp.arange(Pn, dtype=jnp.float32) + 1.0)[None, None, :, None]
    p["b_offsets"] = (grid * scale).reshape(-1)
    return p


def msda_attention(
    p: dict,
    msda_cfg,
    query: jax.Array,  # (B, Q, d)
    value_feats: jax.Array,  # (B, S, d)
    reference_points: jax.Array,  # (B, Q, 2) normalised
    *,
    train: bool = False,
    backend: Optional[str] = None,
    query_parallel: bool = False,
) -> jax.Array:
    levels = msda_cfg.levels
    L, H, Pn = len(levels), msda_cfg.num_heads, msda_cfg.num_points
    B, Q, d = query.shape
    D = d // H
    value = (value_feats @ p["value_proj"].astype(query.dtype)).reshape(B, -1, H, D)

    off = query @ p["w_offsets"].astype(query.dtype) + p["b_offsets"].astype(query.dtype)
    off = off.reshape(B, Q, H, L, Pn, 2).astype(jnp.float32)
    wh = jnp.asarray([[w, h] for (h, w) in levels], jnp.float32)  # (L,2) x,y order
    loc = reference_points[:, :, None, None, None, :] + off / wh[None, None, None, :, None, :]

    aw = query @ p["w_weights"].astype(query.dtype) + p["b_weights"].astype(query.dtype)
    aw = jax.nn.softmax(aw.reshape(B, Q, H, L * Pn).astype(jnp.float32), axis=-1)
    aw = aw.reshape(B, Q, H, L, Pn)

    be = backend or msda_cfg.backend
    mesh = rules.current_mesh()
    if mesh is not None and mesh.devices.size > 1:
        # distributed op: keeps the irregular gathers LOCAL per shard
        # (GSPMD left to itself model-parallelises them and pays huge
        # reshards — same failure mode as the MoE dispatch, see §Perf)
        out = distributed_msda(
            value.astype(query.dtype), levels, loc,
            aw.astype(query.dtype), mesh=mesh,
            query_parallel=query_parallel, backend=be, train=train,
        )
    else:
        out = ops.msda(
            value.astype(query.dtype), levels, loc,
            aw.astype(query.dtype), backend=be, train=train,
        )
    return out @ p["out_proj"].astype(query.dtype)


# --------------------------------------------------------------------------
# distributed op (shard_map over the kernel)
# --------------------------------------------------------------------------


def distributed_msda(
    value: jax.Array,  # (B, S, H, D)
    levels,
    loc: jax.Array,  # (B, Q, H, L, P, 2)
    attn: jax.Array,  # (B, Q, H, L, P)
    *,
    mesh=None,
    query_parallel: bool = False,
    backend: str = "auto",
    train: bool = False,
) -> jax.Array:
    """shard_map-distributed MSDA (see module docstring)."""
    mesh = mesh or rules.current_mesh()
    if mesh is None:
        return ops.msda(value, levels, loc, attn, backend=backend, train=train)
    dp = rules.resolve_axis("dp", mesh)
    tp = rules.resolve_axis("tp", mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp_size = sizes.get("model", 1)
    B, S, Hh, D = value.shape
    Q = loc.shape[1]
    # pick a legal sharding mode: query-parallel needs Q % tp == 0,
    # head-parallel needs H % tp == 0; otherwise batch-only (tp idle)
    if query_parallel and Q % tp_size:
        query_parallel = False
    if not query_parallel and Hh % tp_size:
        tp = None

    if query_parallel:
        # value replicated over tp; queries split over tp.  Backward: the
        # cotangent of the replicated value is psum'd across tp shards —
        # the contention-free analogue of the paper's staggered scatter.
        vspec = P(dp, None, None, None)
        qspec = P(dp, tp, None, None, None, None)
        wspec = P(dp, tp, None, None, None)
        ospec = P(dp, tp, None)
    else:
        vspec = P(dp, None, tp, None)
        qspec = P(dp, None, tp, None, None, None)
        wspec = P(dp, None, tp, None, None)
        ospec = P(dp, None, tp)

    @partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(vspec, qspec, wspec),
        out_specs=ospec,
        check_vma=False,
    )
    def run(v, l, a):
        B, S, Hh, D = v.shape
        out = ops.msda(v, levels, l, a, backend=backend, train=train)
        return out.reshape(*l.shape[:2], Hh, D).reshape(l.shape[0], l.shape[1], Hh * D)

    return run(value, loc, attn)
