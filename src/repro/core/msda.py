"""MSDAttention: the paper's op as a composable model module.

Wraps the xMSDA plan/execute API (``repro.kernels.plan``) with the
standard Deformable-DETR parameterisation: per-query learned sampling
offsets around reference points + softmaxed attention weights,
value/output projections.

Planning: :func:`attention_plan` builds the :class:`MsdaPlan` for a
module's static geometry **once** — backend resolution, per-level block
sizes (heuristic or autotuned via ``msda_cfg.tune``) and the sharding
mode are all committed at plan time, and repeated forwards with the same
geometry fetch the cached plan (no per-call re-planning).

Distribution is baked into the plan when a mesh is installed —

* batch over the 'dp' axes, heads over 'tp' (value sharded, no
  reduction needed: each shard owns its heads' slice of grad_value);
* queries over 'tp' instead (``query_parallel=True``) for huge-Q
  workloads (the DETR encoder's 87k pixel queries), or tiled over
  **dp x tp jointly** (the 2D 'query2d' mode — picked automatically
  when Q amortises both axes, forceable via ``sharding="2d"``).  The
  value tensor is then replicated over the query axes and the
  per-shard partial grad_value slabs are reduced explicitly: a
  ppermute **ring** over 'tp' (one slab shard resident per hop) plus a
  psum over 'dp' — the TPU-idiomatic realisation of the paper's
  staggered-scatter idea (contention eliminated via partial
  accumulators + reduction, §4.2), QUILL-style cache-resident.  See
  ``docs/sharding.md``.

``distributed_msda`` survives as a thin compatibility wrapper over a
mesh-carrying plan.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import plan as plan_mod
from repro.models import layers
from repro.sharding import rules


def level_ref_points(levels) -> jax.Array:
    """Normalised (x, y) centers for every pixel of every level: (S, 2)."""
    out = []
    for (h, w) in levels:
        ys = (jnp.arange(h, dtype=jnp.float32) + 0.5) / h
        xs = (jnp.arange(w, dtype=jnp.float32) + 0.5) / w
        gy, gx = jnp.meshgrid(ys, xs, indexing="ij")
        out.append(jnp.stack([gx, gy], -1).reshape(h * w, 2))
    return jnp.concatenate(out, axis=0)


def init_msda_attention(key, d_model: int, msda_cfg) -> dict:
    L = len(msda_cfg.levels)
    H, Pn = msda_cfg.num_heads, msda_cfg.num_points
    ks = jax.random.split(key, 4)
    p = {
        "value_proj": layers.dense_init(ks[0], (d_model, d_model)),
        "out_proj": layers.dense_init(ks[1], (d_model, d_model)),
        "w_offsets": jnp.zeros((d_model, H * L * Pn * 2), jnp.float32),
        "w_weights": layers.dense_init(ks[2], (d_model, H * L * Pn)) * 0.01,
        "b_weights": jnp.zeros((H * L * Pn,), jnp.float32),
    }
    # Deformable-DETR offset-bias init: points spread on a ring per head
    theta = jnp.arange(H, dtype=jnp.float32) * (2.0 * math.pi / H)
    grid = jnp.stack([jnp.cos(theta), jnp.sin(theta)], -1)  # (H,2)
    grid = grid / jnp.abs(grid).max(-1, keepdims=True)
    grid = jnp.tile(grid[:, None, None], (1, L, Pn, 1))
    scale = (jnp.arange(Pn, dtype=jnp.float32) + 1.0)[None, None, :, None]
    p["b_offsets"] = (grid * scale).reshape(-1)
    return p


def attention_plan(
    msda_cfg,
    *,
    num_queries: int,
    head_dim: int,
    dtype,
    train: bool = False,
    backend: Optional[str] = None,
    mesh=None,
    query_parallel: bool = False,
    dtype_policy: Optional[str] = None,
    tune: Optional[str] = None,
    sharding: Optional[str] = None,
    grad_reduce: Optional[str] = None,
) -> plan_mod.MsdaPlan:
    """The module's :class:`MsdaPlan` for one static geometry (cached).

    All hardware-aware decisions (backend, per-level block_q, slab
    dtypes, MXU one-hot routing, shard_map wiring) are committed here,
    once; forwards just execute.  ``msda_cfg.tune`` selects heuristic vs
    autotuned block planning (``tune`` overrides it per call — the
    offline sweep CLI forces "autotune" on configs that default to the
    heuristic), ``msda_cfg.vmem_budget`` overrides the per-device VMEM
    default (0 = auto), and ``msda_cfg.dtype_policy`` (overridable per
    call) picks the mixed-precision plan variant — 'follow' | 'float32'
    | 'bfloat16' | 'auto' (see
    :func:`repro.kernels.plan.resolve_dtype_policy`).
    ``msda_cfg.fuse_levels`` ('auto' | 'on' | 'off') commits the
    whole-pyramid kernel-fusion rung (one pallas launch per direction
    when the packed pyramid fits VMEM).  ``msda_cfg.sparsity`` /
    ``sparsity_k`` / ``query_order`` commit the sparsity rungs — top-k
    point pruning (lossy, dense fallback) and the Morton query
    permutation (bitwise-neutral).  When a mesh is given,
    ``msda_cfg.sharding`` / ``msda_cfg.grad_reduce`` (both overridable
    per call) select the distribution family and the grad_value
    reduction — see ``docs/sharding.md``.
    """
    policy = dtype_policy or getattr(msda_cfg, "dtype_policy", "follow")
    slab_dtype, accum_dtype = plan_mod.resolve_dtype_policy(policy)
    spec = plan_mod.MsdaSpec(
        spatial_shapes=msda_cfg.levels,
        num_heads=msda_cfg.num_heads,
        head_dim=head_dim,
        num_points=msda_cfg.num_points,
        num_queries=num_queries,
        dtype=str(jnp.dtype(dtype)),
        train=train,
        vmem_budget=getattr(msda_cfg, "vmem_budget", 0),
        slab_dtype=slab_dtype,
        accum_dtype=accum_dtype,
        fuse_levels=getattr(msda_cfg, "fuse_levels", "auto"),
        sparsity=getattr(msda_cfg, "sparsity", "off"),
        sparsity_k=getattr(msda_cfg, "sparsity_k", 0),
        query_order=getattr(msda_cfg, "query_order", "identity"),
    )
    return plan_mod.msda_plan(
        spec,
        backend=backend or msda_cfg.backend,
        tune=tune or getattr(msda_cfg, "tune", "heuristic"),
        mesh=mesh,
        query_parallel=query_parallel,
        sharding=sharding or getattr(msda_cfg, "sharding", "auto"),
        grad_reduce=grad_reduce or getattr(msda_cfg, "grad_reduce", "auto"),
    )


def msda_attention(
    p: dict,
    msda_cfg,
    query: jax.Array,  # (B, Q, d)
    value_feats: jax.Array,  # (B, S, d)
    reference_points: jax.Array,  # (B, Q, 2) normalised
    *,
    train: bool = False,
    backend: Optional[str] = None,
    query_parallel: bool = False,
    valid_ratios: Optional[jax.Array] = None,  # (B, L, 2) x,y fractions
) -> jax.Array:
    levels = msda_cfg.levels
    L, H, Pn = len(levels), msda_cfg.num_heads, msda_cfg.num_points
    B, Q, d = query.shape
    D = d // H
    value = (value_feats @ p["value_proj"].astype(query.dtype)).reshape(B, -1, H, D)

    off = query @ p["w_offsets"].astype(query.dtype) + p["b_offsets"].astype(query.dtype)
    off = off.reshape(B, Q, H, L, Pn, 2).astype(jnp.float32)
    wh = jnp.asarray([[w, h] for (h, w) in levels], jnp.float32)  # (L,2) x,y order
    refs = reference_points[:, :, None, None, None, :]
    if valid_ratios is not None:
        # bucketed serving (Deformable-DETR valid_ratios): the pyramid
        # only occupies the top-left (w*rx, h*ry) region of each padded
        # level.  Scaling the REFERENCE POINTS by the ratio (offsets stay
        # normalised by the padded extents wh) lands every sample on the
        # same pixel coordinate as in the unpadded level:
        # (x*r)*W - 0.5 == x*w - 0.5, and pad-region corners gather the
        # zeros that out-of-range corners contributed anyway.
        refs = refs * valid_ratios[:, None, None, :, None, :].astype(jnp.float32)
    loc = refs + off / wh[None, None, None, :, None, :]

    aw = query @ p["w_weights"].astype(query.dtype) + p["b_weights"].astype(query.dtype)
    aw = jax.nn.softmax(aw.reshape(B, Q, H, L * Pn).astype(jnp.float32), axis=-1)
    aw = aw.reshape(B, Q, H, L, Pn)

    # one cached plan per static geometry: the mesh (when >1 device) bakes
    # shard_map wiring in, keeping the irregular gathers LOCAL per shard
    # (GSPMD left to itself model-parallelises them and pays huge
    # reshards — same failure mode as the MoE dispatch, see §Perf)
    mesh = rules.current_mesh()
    if mesh is not None and mesh.devices.size <= 1:
        mesh = None
    plan = attention_plan(
        msda_cfg, num_queries=Q, head_dim=D, dtype=query.dtype, train=train,
        backend=backend, mesh=mesh, query_parallel=query_parallel,
    )
    out = plan(value.astype(query.dtype), loc, aw.astype(query.dtype))
    return out @ p["out_proj"].astype(query.dtype)


# --------------------------------------------------------------------------
# distributed op — compatibility wrapper over a mesh-carrying plan
# --------------------------------------------------------------------------


def distributed_msda(
    value: jax.Array,  # (B, S, H, D)
    levels,
    loc: jax.Array,  # (B, Q, H, L, P, 2)
    attn: jax.Array,  # (B, Q, H, L, P)
    *,
    mesh=None,
    query_parallel: bool = False,
    sharding: str = "auto",
    grad_reduce: str = "auto",
    backend: str = "auto",
    train: bool = False,
) -> jax.Array:
    """shard_map-distributed MSDA (see module docstring).

    Thin wrapper: builds/fetches the mesh-carrying plan and executes it.
    The sharding-mode ladder (2D dp x tp query tiling -> query-parallel
    -> head-parallel -> batch-only) lives in ``plan._plan_sharding``;
    ``sharding``/``grad_reduce`` pass straight through to
    :func:`repro.kernels.plan.msda_plan`.
    """
    mesh = mesh or rules.current_mesh()
    spec = plan_mod.spec_from_arrays(value, levels, loc, attn, train=train)
    plan = plan_mod.msda_plan(
        spec, backend=backend, mesh=mesh, query_parallel=query_parallel,
        sharding=sharding, grad_reduce=grad_reduce)
    return plan(value, loc, attn)
