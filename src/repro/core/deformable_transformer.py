"""Deformable-DETR-style host model — the paper's own workload.

Encoder: every pixel of the multi-scale pyramid is a query; each layer
applies MSDA over the pyramid (Q = S = sum HW, the paper's 87296 at the
1024x1024 eval scale) followed by an FFN.  Decoder: 300 object queries
with self-attention + MSDA cross-attention into the encoder memory.
Heads: class logits + sigmoid boxes; the training loss uses a greedy
bipartite matcher (documented approximation of Hungarian matching —
cost-identical construction, greedy assignment).

The backbone is a stub per the assignment: ``input_specs`` provides the
projected pyramid features directly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import msda as msda_mod
from repro.models import attention, layers
from repro.sharding import rules


def init_detr(key, cfg) -> dict:
    mc = cfg.msda
    L = len(mc.levels)
    d = cfg.d_model
    ks = jax.random.split(key, 12)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": layers.init_norm(cfg),
            "msda": msda_mod.init_msda_attention(k1, d, mc),
            "norm2": layers.init_norm(cfg),
            "mlp": layers.init_mlp(k2, cfg),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": layers.init_norm(cfg),
            "self_attn": attention.init_attention(k1, cfg),
            "norm2": layers.init_norm(cfg),
            "msda": msda_mod.init_msda_attention(k2, d, mc),
            "norm3": layers.init_norm(cfg),
            "mlp": layers.init_mlp(k3, cfg),
        }

    n_dec = cfg.num_layers
    return {
        "level_emb": layers.embed_init(ks[0], (L, d), 0.02),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(ks[1], cfg.num_layers)),
        "query_emb": layers.embed_init(ks[2], (300, d), 0.02),
        "ref_head": layers.init_linear(ks[3], d, 2),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(ks[4], n_dec)),
        "class_head": layers.init_linear(ks[5], d, cfg.vocab_size, bias=True),
        "box_head": {
            "l1": layers.init_linear(ks[6], d, d, bias=True),
            "l2": layers.init_linear(ks[7], d, 4, bias=True),
        },
        "final_norm": layers.init_norm(cfg),
    }


def msda_plans(cfg, *, dtype="float32", train: bool = False, mesh=None,
               dtype_policy=None, tune=None):
    """Build (and cache) the model's MsdaPlans for warm-up / inspection.

    One plan per static geometry in the model: the encoder's huge-Q
    self-MSDA (Q = sum HW pixel queries) and the decoder's 300-query
    cross-MSDA.  Call before the first step to front-load backend
    resolution + block planning (and autotuning, if configured); print
    ``plan.describe()`` for the per-level block_q / slab-dtype / VMEM
    report.  ``dtype_policy`` overrides ``cfg.msda.dtype_policy`` and
    ``tune`` overrides ``cfg.msda.tune`` (the offline sweep CLI forces
    "autotune" when pre-populating the fleet winner cache).
    """
    mc = cfg.msda
    sp = sum(h * w for h, w in mc.levels)
    D = cfg.d_model // mc.num_heads
    enc = msda_mod.attention_plan(
        mc, num_queries=sp, head_dim=D, dtype=dtype, train=train,
        mesh=mesh, query_parallel=mc.query_parallel, dtype_policy=dtype_policy,
        tune=tune)
    dec = msda_mod.attention_plan(
        mc, num_queries=300, head_dim=D, dtype=dtype, train=train, mesh=mesh,
        dtype_policy=dtype_policy, tune=tune)
    return {"encoder": enc, "decoder": dec}


def _level_emb_expanded(params, cfg, dtype):
    mc = cfg.msda
    parts = [
        jnp.broadcast_to(params["level_emb"][i].astype(dtype), (h * w, cfg.d_model))
        for i, (h, w) in enumerate(mc.levels)
    ]
    return jnp.concatenate(parts, axis=0)


def encode_pyramid(params, cfg, pyramid: jax.Array, *, train: bool = False,
                   remat: bool = True) -> jax.Array:
    """pyramid: (B, S, d) flattened multi-scale features -> memory (B, S, d)."""
    mc = cfg.msda
    dt = pyramid.dtype
    x = pyramid + _level_emb_expanded(params, cfg, dt)[None]
    refs = msda_mod.level_ref_points(mc.levels)[None].astype(jnp.float32)  # (1,S,2)
    refs = jnp.broadcast_to(refs, (x.shape[0], *refs.shape[1:]))
    x = rules.hint(x, "dp", None, None)

    def step(x, lp):
        h = layers.apply_norm(lp["norm1"], x, cfg.norm_eps)
        # 87k pixel queries: shard queries over 'model' — or dp x tp
        # jointly when the mesh + Q clear the 2D threshold (value
        # replicated per shard; grad_value ring-reduced — the
        # staggered-scatter analogue, see docs/sharding.md).  The
        # sharding mode is committed on the cached MsdaPlan.
        y = msda_mod.msda_attention(lp["msda"], mc, h, h, refs, train=train,
                                    query_parallel=mc.query_parallel)
        x = x + y
        h2 = layers.apply_norm(lp["norm2"], x, cfg.norm_eps)
        x = x + layers.apply_mlp(lp["mlp"], cfg, h2)
        return x, None

    if remat:
        step = jax.checkpoint(step)
    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return x


def decode_queries(params, cfg, memory: jax.Array, *, train: bool = False):
    """300 object queries -> (class_logits (B,300,C), boxes (B,300,4))."""
    mc = cfg.msda
    B = memory.shape[0]
    dt = memory.dtype
    q = jnp.broadcast_to(params["query_emb"].astype(dt)[None], (B, 300, cfg.d_model))
    refs = jax.nn.sigmoid(layers.apply_linear(params["ref_head"], params["query_emb"]))
    refs = jnp.broadcast_to(refs[None].astype(jnp.float32), (B, 300, 2))

    def step(q, lp):
        h = layers.apply_norm(lp["norm1"], q, cfg.norm_eps)
        q = q + attention.attention_fwd(lp["self_attn"], cfg, h, causal=False, rope=False)
        h2 = layers.apply_norm(lp["norm2"], q, cfg.norm_eps)
        q = q + msda_mod.msda_attention(lp["msda"], mc, h2, memory, refs, train=train)
        h3 = layers.apply_norm(lp["norm3"], q, cfg.norm_eps)
        q = q + layers.apply_mlp(lp["mlp"], cfg, h3)
        return q, None

    q, _ = jax.lax.scan(step, q, params["dec_layers"])
    q = layers.apply_norm(params["final_norm"], q, cfg.norm_eps)
    logits = layers.apply_linear(params["class_head"], q)
    b = jax.nn.gelu(layers.apply_linear(params["box_head"]["l1"], q))
    boxes = jax.nn.sigmoid(layers.apply_linear(params["box_head"]["l2"], b))
    return logits, boxes


# --------------------------------------------------------------------------
# detection loss (greedy bipartite matching)
# --------------------------------------------------------------------------


def greedy_match(cost: jax.Array, n_targets: int) -> jax.Array:
    """cost: (Q, T) -> for each target t, a distinct query index.

    Greedy approximation of Hungarian matching: repeatedly takes the
    globally-cheapest unassigned (query, target) pair.
    """
    Q, T = cost.shape

    def body(i, state):
        c, assign = state
        flat = jnp.argmin(c)
        qi, ti = flat // T, flat % T
        assign = assign.at[ti].set(qi)
        c = c.at[qi, :].set(jnp.inf)
        c = c.at[:, ti].set(jnp.inf)
        return c, assign

    _, assign = jax.lax.fori_loop(
        0, n_targets, body, (cost.astype(jnp.float32), jnp.zeros((T,), jnp.int32))
    )
    return assign


def detr_loss(params, cfg, batch: Dict[str, jax.Array], *, train: bool = True,
              remat: bool = True) -> jax.Array:
    """batch: pyramid (B,S,d), labels (B,T) int (-1 = pad), boxes (B,T,4)."""
    memory = encode_pyramid(params, cfg, batch["pyramid"], train=train, remat=remat)
    logits, boxes = decode_queries(params, cfg, memory, train=train)
    labels, gt_boxes = batch["labels"], batch["boxes"]
    B, T = labels.shape
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)  # (B,Q,C)

    def one(lp, bx, lab, gbx):
        valid = lab >= 0
        lab_c = jnp.maximum(lab, 0)
        cost_cls = -lp[:, lab_c]  # (Q,T)
        cost_l1 = jnp.abs(bx[:, None, :] - gbx[None, :, :]).sum(-1)
        cost = cost_cls + 5.0 * cost_l1
        cost = jnp.where(valid[None, :], cost, jnp.inf)
        assign = greedy_match(cost, T)
        nll = -lp[assign, lab_c] * valid
        l1 = (jnp.abs(bx[assign] - gbx).sum(-1)) * valid
        # unmatched queries pushed to the background class (= class 0 here)
        matched = jnp.zeros((lp.shape[0],), bool).at[assign].set(valid)
        bg = -lp[:, 0] * (~matched)
        denom = jnp.maximum(valid.sum(), 1)
        return (nll.sum() + 5.0 * l1.sum()) / denom + bg.mean()

    losses = jax.vmap(one)(logp, boxes.astype(jnp.float32), labels, gt_boxes.astype(jnp.float32))
    return losses.mean()
