"""Deterministic fault injection for the training harness.

The implementation moved to :mod:`repro.runtime.faults` when serving
grew its own fault kinds (PR 10) — one seeded, fire-once
``FaultSchedule`` contract now drives both the training harness's
recovery paths and the serving engine's resilience layer.  This module
stays as the training-facing surface: it re-exports the shared types
and keeps ``FAULT_KINDS`` pinned to the TRAINING subset so existing
callers (and seeded schedules) see exactly the namespace they always
did.  See the shared module's docstring for the kind semantics.
"""
from repro.runtime.faults import (  # noqa: F401
    TRAINING_FAULT_KINDS as FAULT_KINDS,
    FaultEvent,
    FaultSchedule,
    HostLoss,
    Preemption,
    corrupt_latest_checkpoint,
)
