"""Deterministic fault injection for the training harness.

Every recovery path the harness has must be testable under the
4-virtual-device conftest, so faults are *data*, not monkeypatches: a
:class:`FaultSchedule` is an explicit (or seeded) list of
:class:`FaultEvent`, each fired exactly once when the harness reaches
its step.  Because the schedule, the data pipeline (pure function of
``(seed, step)``) and the checkpoint cadence are all deterministic, two
runs with the same schedule make IDENTICAL recovery decisions — which
``tests/test_checkpoint_ft.py`` asserts literally.

Kinds:

* ``"host_loss"`` — raised BEFORE the step runs: the process "dies" and
  the harness restores the newest checkpoint (losing any steps since).
* ``"preempt"`` — raised AFTER the step computed but BEFORE it commits:
  the classic mid-step preemption; the finished step's work is lost.
* ``"corrupt_ckpt"`` — truncates the newest on-disk checkpoint, then
  dies like ``host_loss``; recovery must fall back to the PREVIOUS
  step (``checkpoint.manager.restore_latest_valid``).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.checkpoint import manager as ckpt

FAULT_KINDS = ("host_loss", "preempt", "corrupt_ckpt")


class HostLoss(RuntimeError):
    """Simulated host/process loss (the harness restores and resumes)."""


class Preemption(RuntimeError):
    """Simulated mid-step preemption (the in-flight step is discarded)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")


class FaultSchedule:
    """An ordered, fire-once schedule of injected faults.

    Each event fires the FIRST time the harness reaches its step —
    replayed steps after a recovery do NOT re-trigger it (a real host
    doesn't die twice from one failure).  ``describe()`` returns the
    schedule as plain dicts for telemetry.
    """

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: Dict[int, FaultEvent] = {}
        for e in events:
            if e.step in self.events:
                raise ValueError(f"two faults scheduled at step {e.step}")
            self.events[e.step] = e
        self.fired: List[FaultEvent] = []

    @classmethod
    def from_spec(cls, spec: str) -> "FaultSchedule":
        """Parse the CLI format: ``"host_loss@5,corrupt_ckpt@9"``."""
        events = []
        for tok in spec.split(","):
            tok = tok.strip()
            if not tok:
                continue
            kind, _, step = tok.partition("@")
            if not step:
                raise ValueError(f"fault {tok!r} is not kind@step")
            events.append(FaultEvent(step=int(step), kind=kind))
        return cls(events)

    @classmethod
    def generate(cls, seed: int, total_steps: int, *, n_faults: int = 2,
                 kinds: Sequence[str] = FAULT_KINDS) -> "FaultSchedule":
        """Seeded random schedule — same seed, same faults, every run.

        Steps are drawn without replacement from ``[1, total_steps)``
        (step 0 has no checkpoint to recover to yet), kinds cycle
        through a seeded permutation of ``kinds``.
        """
        kinds = tuple(kinds)
        if not kinds:
            raise ValueError(
                "FaultSchedule.generate needs at least one fault kind; "
                f"pass a non-empty subset of {FAULT_KINDS}")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}; one of {FAULT_KINDS}")
        if int(n_faults) < 0:
            raise ValueError(f"n_faults must be >= 0, got {n_faults}")
        rng = np.random.default_rng(seed)
        hi = max(2, int(total_steps))
        n = min(int(n_faults), hi - 1)
        steps = sorted(rng.choice(np.arange(1, hi), size=n, replace=False))
        order = list(rng.permutation(list(kinds)))
        return cls([FaultEvent(step=int(s), kind=order[i % len(order)])
                    for i, s in enumerate(steps)])

    def take(self, step: int) -> Optional[FaultEvent]:
        """The fault scheduled at ``step``, popped so it fires once."""
        ev = self.events.pop(step, None)
        if ev is not None:
            self.fired.append(ev)
        return ev

    def describe(self) -> List[Dict[str, int]]:
        pending = [dataclasses.asdict(e) for _, e in sorted(self.events.items())]
        return [dict(d, fired=False) for d in pending] + \
               [dict(dataclasses.asdict(e), fired=True) for e in self.fired]


def corrupt_latest_checkpoint(directory: str) -> Optional[str]:
    """Deterministically damage the newest committed checkpoint.

    Truncates its first leaf ``.npy`` to 16 bytes — the manifest stays
    valid, so ``latest_step`` still points at it, but ``restore()``
    raises on the mangled array.  Exactly the shape of a crash that
    tore a write.  Returns the damaged file's path (None when there is
    no checkpoint to damage).
    """
    step = ckpt.latest_step(directory)
    if step is None:
        return None
    path = os.path.join(directory, f"step_{step:08d}", "leaf_00000.npy")
    if not os.path.exists(path):
        return None
    with open(path, "r+b") as f:
        f.truncate(16)
    return path
