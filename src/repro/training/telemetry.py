"""Step-time telemetry -> ``BENCH_train.json``.

The training counterpart of ``benchmarks/paper_benchmarks.py``'s
``BENCH_kernels.json``: one recorder object rides the harness, collects
the per-step wall-time trajectory plus the runtime's discrete events
(recoveries, re-plans), and writes a single JSON payload in the same
``{"bench", "config", "note", "results", ...}`` shape, so the CI
artifact tooling treats both files identically.

``results`` carries the headline scalars (mean/p50 step time,
tokens/sec, re-plan count, recovery count + latencies); ``trajectory``
the full per-step series the step-time plot is drawn from; ``events``
the recovery/replan log with latencies.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

from repro.obs import bench as _bench
from repro.obs import registry as _obs


class StepTimeRecorder:
    """Accumulates the step-time trajectory + runtime events.

    ``tokens_per_step``: global tokens (or queries, for detection
    workloads) consumed per optimizer step — the tokens/sec headline is
    derived from it; 0 disables that row.
    """

    def __init__(self, *, tokens_per_step: int = 0,
                 config: Optional[Dict[str, Any]] = None,
                 window: int = 4096):
        self.tokens_per_step = int(tokens_per_step)
        self.config = dict(config or {})
        # bounded rings (arbitrarily long runs must not grow host
        # memory): raw step/event rows are windowed to the last
        # ``window`` entries — headline scalars stay EXACT via the
        # running aggregates below; the p50 and the trajectory/events
        # blocks of the payload are windowed views
        self.steps: _obs.EventWindow = _obs.EventWindow(window)
        self.events: _obs.EventWindow = _obs.EventWindow(window)
        self._wall = _obs.NumericWindow(window)
        self._event_counts: Dict[str, int] = {}
        self._created = time.time()
        # registry mirror (process-wide obs substrate)
        self._step_hist = _obs.histogram(
            "train.step_wall_s", help="per-step wall time (seconds)")
        self._event_ctr = _obs.counter(
            "train.events", help="harness runtime events by kind")

    # -- recording --------------------------------------------------------
    def record_step(self, step: int, wall_s: float,
                    loss: Optional[float] = None) -> None:
        row: Dict[str, Any] = {"step": int(step), "wall_s": float(wall_s)}
        if loss is not None:
            row["loss"] = float(loss)
        self.steps.append(row)
        self._wall.append(float(wall_s))
        self._step_hist.observe(float(wall_s))

    def record_event(self, kind: str, *, step: int, latency_s: float = 0.0,
                     detail: str = "", **extra: Any) -> None:
        """``kind``: 'recovery' | 'replan' | anything the harness emits.

        ``extra`` keys ride into the event row verbatim — the harness
        uses this to promote its ``recovery_log`` fields (failed step,
        resume point, skipped checkpoints) to first-class event fields.
        """
        row = {"kind": str(kind), "step": int(step),
               "latency_s": float(latency_s), "detail": str(detail)}
        for k, v in extra.items():
            row.setdefault(k, v)
        self.events.append(row)
        self._event_counts[str(kind)] = self._event_counts.get(str(kind), 0) + 1
        self._event_ctr.inc(kind=str(kind))

    # -- reporting --------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        # counts/total/mean/max are exact over the full run; p50 and
        # recovery_latency_s come from the bounded window (the last
        # ``window`` steps/events)
        n = self._wall.count
        total = self._wall.total
        recoveries = [e for e in self.events if e["kind"] == "recovery"]
        out: Dict[str, Any] = {
            "steps": n,
            "total_step_wall_s": total,
            "mean_step_s": self._wall.mean,
            "p50_step_s": self._wall.p50,
            "max_step_s": self._wall.max,
            "recoveries": self._event_counts.get("recovery", 0),
            "recovery_latency_s": [e["latency_s"] for e in recoveries],
            "replan_count": self._event_counts.get("replan", 0),
        }
        if self.tokens_per_step and total > 0:
            out["tokens_per_sec"] = self.tokens_per_step * n / total
        from repro.kernels import plan as plan_mod

        out["plan_execution"] = plan_mod.execution_telemetry()
        return out

    def payload(self, *, note: str = "") -> Dict[str, Any]:
        return {
            "bench": "train_runtime",
            "config": self.config,
            "note": note or (
                "step wall-time trajectory + recovery/replan events from "
                "the elastic training harness (repro.training)"),
            "results": self.summary(),
            "trajectory": list(self.steps),
            "events": list(self.events),
            "created_unix": self._created,
        }

    # regression-gate rules for BENCH_train.json: step timings and
    # tokens/sec are machine-relative, so only very generous slack;
    # steps/recoveries depend on the run config and are not gated
    GATE = [
        _bench.gate_rule("mean_step_s", "lower", 4.0),
        _bench.gate_rule("p50_step_s", "lower", 4.0),
        _bench.gate_rule("tokens_per_sec", "higher", 0.8),
    ]

    def write(self, path: str, *, note: str = "") -> str:
        """Atomic JSON dump (tmp + rename) via ``obs.bench.write_bench``."""
        p = self.payload(note=note)
        return _bench.write_bench(
            path, bench=p["bench"], results=p["results"], config=p["config"],
            note=p["note"], trajectory=p["trajectory"], events=p["events"],
            gate=self.GATE, created_unix=p["created_unix"])
