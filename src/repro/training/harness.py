"""The restartable training harness.

Wraps a jitted train step + deterministic batch function into a loop
that survives failure: every fault (injected via
:class:`~repro.training.faults.FaultSchedule`, or a real exception of
the same types) triggers restore-from-checkpoint and deterministic
replay.  Because batches are a pure function of the step index and
checkpoints round-trip bitwise (raw ``.npy`` leaves), a recovered run's
loss trajectory is BIT-IDENTICAL to an uninterrupted one — the
continuity contract the CI train-smoke job asserts.

Step accounting: ``batch_fn(step)`` consumes 0-based step indices; a
checkpoint written after completing index ``s`` is stamped ``s + 1``
(the number of completed steps), so a restore resumes at exactly the
next unconsumed index.

The harness is deliberately model-agnostic — ``launch/train.py`` drives
it with ``train/loop.py`` states, ``examples/train_detr.py`` with its
hand-rolled param/opt pairs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import manager as ckpt
from repro.obs import trace as _obs_trace
from repro.training import faults as faults_mod
from repro.training.telemetry import StepTimeRecorder


@dataclasses.dataclass
class HarnessConfig:
    total_steps: int
    ckpt_every: int = 25
    ckpt_dir: Optional[str] = None
    keep_last: int = 3
    max_restarts: int = 8
    # async checkpointing overlaps the save with the next step's compute;
    # sync is available for tests that need the file on disk immediately
    async_ckpt: bool = True


class TrainingHarness:
    """Run ``step_fn`` to ``total_steps`` with checkpointed recovery.

    ``step_fn(state, batch) -> (state, metrics)`` — metrics must carry a
    scalar ``"loss"``.  ``batch_fn(step) -> batch`` must be a pure
    function of the step index (the determinism the replay contract
    rests on).  ``init_fn() -> state`` builds the step-0 state; it is
    called once and its result reused as the restore template.
    """

    def __init__(self, *, step_fn: Callable, batch_fn: Callable,
                 init_fn: Callable, config: HarnessConfig,
                 faults: Optional[faults_mod.FaultSchedule] = None,
                 telemetry: Optional[StepTimeRecorder] = None):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.init_fn = init_fn
        self.config = config
        self.faults = faults
        self.telemetry = telemetry or StepTimeRecorder()
        self._pending_save = None

    # -- checkpoint plumbing ----------------------------------------------
    def _join_pending(self) -> None:
        if self._pending_save is not None:
            self._pending_save.join(timeout=120)
            self._pending_save = None

    def _save(self, state, step: int) -> None:
        cfg = self.config
        if not cfg.ckpt_dir:
            return
        if cfg.async_ckpt:
            self._join_pending()  # never two writers racing
            self._pending_save = ckpt.save_async(
                state, cfg.ckpt_dir, step, keep_last=cfg.keep_last)
        else:
            ckpt.save(state, cfg.ckpt_dir, step, keep_last=cfg.keep_last)

    def _restore_or_init(self, like):
        """(state, next_step_index, skipped_ckpts)."""
        cfg = self.config
        if cfg.ckpt_dir and ckpt.available_steps(cfg.ckpt_dir):
            state, step, skipped = ckpt.restore_latest_valid(
                cfg.ckpt_dir, like)
            return state, int(step), skipped
        return like, 0, []

    # -- the loop ---------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        cfg = self.config
        like = self.init_fn()
        state, step, skipped0 = self._restore_or_init(like)
        restarts = 0
        recovery_log: List[Dict[str, Any]] = []
        losses: Dict[int, float] = {}
        for s, why in skipped0:
            self.telemetry.record_event(
                "ckpt_skipped", step=int(s), detail=why)
        while step < cfg.total_steps:
            ev = self.faults.take(step) if self.faults is not None else None
            try:
                if ev is not None and ev.kind == "host_loss":
                    raise faults_mod.HostLoss(
                        f"injected host loss before step {step}")
                if ev is not None and ev.kind == "corrupt_ckpt":
                    # a torn write took the newest checkpoint with it
                    self._join_pending()
                    faults_mod.corrupt_latest_checkpoint(cfg.ckpt_dir or "")
                    raise faults_mod.HostLoss(
                        f"injected corrupt-checkpoint loss before step {step}")
                batch = self.batch_fn(step)
                t0 = time.perf_counter()
                with _obs_trace.span("train.step", level=4, step=step):
                    new_state, metrics = self.step_fn(state, batch)
                    metrics = jax.device_get(metrics)
                    jax.block_until_ready(new_state)
                wall = time.perf_counter() - t0
                if ev is not None and ev.kind == "preempt":
                    # mid-step preemption: the step computed but never
                    # commits — its work is lost, the replay redoes it
                    raise faults_mod.Preemption(
                        f"injected preemption during step {step}")
                state = new_state
                loss = float(metrics["loss"]) if "loss" in metrics else None
                if loss is not None:
                    losses[step] = loss
                self.telemetry.record_step(step, wall, loss=loss)
                step += 1
                if cfg.ckpt_dir and step % cfg.ckpt_every == 0:
                    self._save(state, step)
            except (faults_mod.HostLoss, faults_mod.Preemption) as e:
                restarts += 1
                if restarts > cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={cfg.max_restarts}") from e
                t0 = time.perf_counter()
                with _obs_trace.span("train.recovery", level=2,
                                     failed_step=step) as sp:
                    self._join_pending()
                    state, resumed, skipped = self._restore_or_init(like)
                    sp["resumed_from"] = resumed
                latency = time.perf_counter() - t0
                entry = {
                    "failed_step": step,
                    "kind": ev.kind if ev is not None else type(e).__name__,
                    "resumed_from": resumed,
                    "ckpt_skipped": [int(s) for s, _ in skipped],
                }
                recovery_log.append(entry)
                # recovery_log fields ride into the telemetry payload as
                # first-class event fields, so a fault-injection run is
                # diagnosable from BENCH_train.json alone
                self.telemetry.record_event(
                    "recovery", step=resumed, latency_s=latency,
                    detail=f"{entry['kind']}@{step} -> resume@{resumed}",
                    failed_step=entry["failed_step"],
                    resumed_from=entry["resumed_from"],
                    ckpt_skipped=entry["ckpt_skipped"])
                step = resumed
        self._join_pending()
        if cfg.ckpt_dir and step % cfg.ckpt_every != 0:
            ckpt.save(state, cfg.ckpt_dir, step, keep_last=cfg.keep_last)
        return {
            "final_step": step,
            "restarts": restarts,
            "recovery_log": recovery_log,
            "losses": losses,
            "state": state,
        }
