"""Elastic, fault-tolerant training runtime.

The resilient wrapper around ``train/loop.py``: a restartable harness
(:mod:`~repro.training.harness`) that checkpoints through
``checkpoint/manager.py`` and survives injected or real failures, a
deterministic fault-injection layer (:mod:`~repro.training.faults`), an
elastic plan-recovery rung (:mod:`~repro.training.elastic`) that
re-races the mesh-keyed autotune axes when the topology changed under a
restored ``PlanStore``, and a step-time recorder
(:mod:`~repro.training.telemetry`) emitting ``BENCH_train.json`` in the
same trajectory format as ``BENCH_kernels.json``.
"""
from repro.training.elastic import ElasticPlanReport, recover_plans  # noqa: F401
from repro.training.faults import (  # noqa: F401
    FaultEvent, FaultSchedule, HostLoss, Preemption,
    corrupt_latest_checkpoint)
from repro.training.harness import HarnessConfig, TrainingHarness  # noqa: F401
from repro.training.telemetry import StepTimeRecorder  # noqa: F401
