"""Elastic plan recovery: mesh resize -> re-race -> persist.

The rung ROADMAP's "End-to-end training at scale" item asked for: a
training process that restarts on a DIFFERENT topology (lost a host,
grew a slice) used to hit the ``PlanStore`` mesh gate and boot cold —
worse, ``restore(mesh=...)`` refused the store outright.
:func:`recover_plans` flips that gate from reject to recover:

* topology matches  -> plain restore, zero timing runs (unchanged);
* topology differs  -> ``restore(..., on_mesh_mismatch="rerace")``
  re-keys each entry's LOCAL autotune winner onto the new per-shard
  geometry (block/dtype/fuse axes stay cache hits) and re-races ONLY
  the mesh-keyed axes — the sharding mode (1d / 2d / hybrid) and the
  grad_value reduction (ring / psum) — then **persists the new
  winners** back to the store, so the NEXT restart on this topology is
  again a zero-race boot.

The returned :class:`ElasticPlanReport` carries what happened (how many
entries re-raced, the autotune stat delta) so the harness's telemetry
can report the re-plan count and its latency.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro.kernels import plan as plan_mod
from repro.serving.persistence import PlanStore


@dataclasses.dataclass
class ElasticPlanReport:
    """What :func:`recover_plans` did."""

    plans: List[Any] = dataclasses.field(default_factory=list)
    reraced: List[str] = dataclasses.field(default_factory=list)
    skipped: List[str] = dataclasses.field(default_factory=list)
    seeded_winners: int = 0
    # autotune stat deltas across the restore: a matching-topology boot
    # has raced == 0; a resized one has raced_mesh >= 1, raced_local == 0
    raced: int = 0
    raced_local: int = 0
    raced_mesh: int = 0
    recovery_s: float = 0.0
    persisted: bool = False

    @property
    def replan_count(self) -> int:
        return len(self.reraced)


def recover_plans(store_path: str, *, mesh=None, persist: bool = True,
                  verify_describe: bool = True) -> ElasticPlanReport:
    """Restore a plan store elastically onto ``mesh``.

    Missing store -> empty report (cold boot, not an error).  When any
    entry re-raced (topology changed), the rebuilt plans are written
    back with ``meta.mesh`` updated — restore-then-persist is the whole
    elastic contract — unless ``persist=False``.
    """
    report = ElasticPlanReport()
    store = PlanStore(store_path)
    if not store.exists():
        return report
    before = plan_mod.autotune_stats()
    t0 = time.perf_counter()
    rr = store.restore(mesh=mesh, verify_describe=verify_describe,
                       on_mesh_mismatch="rerace")
    report.recovery_s = time.perf_counter() - t0
    after = plan_mod.autotune_stats()
    report.plans = rr.plans
    report.reraced = rr.reraced
    report.skipped = rr.skipped
    report.seeded_winners = rr.seeded_winners
    for k in ("raced", "raced_local", "raced_mesh"):
        setattr(report, k, after[k] - before[k])
    if rr.reraced and rr.plans and persist:
        meta: Dict[str, Any] = {"elastic_reraced": len(rr.reraced)}
        if mesh is not None:
            meta["mesh"] = plan_mod.mesh_token(mesh)
        store.save_plans(rr.plans, meta=meta)
        report.persisted = True
    return report


def mesh_or_none(mesh) -> Optional[str]:
    """Telemetry helper: the store-meta mesh token (None local)."""
    return None if mesh is None else plan_mod.mesh_token(mesh)
