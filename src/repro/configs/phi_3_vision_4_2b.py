"""phi-3-vision-4.2b: phi3-mini backbone + CLIP stub frontend.

The vision tower is a STUB (input_specs provides precomputed multi-scale
patch-feature maps); this repo wires the paper's MSDA op as the
multi-scale visual resampler pooling the pyramid into visual tokens —
the one assigned arch where the paper's technique applies natively.

[hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from repro.configs.base import ModelConfig, VisionConfig, register

CONFIG = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    gated_mlp=True,
    act="silu",
    vision=VisionConfig(
        num_visual_tokens=144,
        vision_dim=1024,
        levels=((32, 32), (16, 16), (8, 8)),
        msda_points=4,
        msda_heads=8,
    ),
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
))
