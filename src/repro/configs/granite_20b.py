"""granite-20b: dense llama-arch code model, 52L, MQA (kv=1).

[arXiv:2405.04324; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,   # MQA
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    gated_mlp=False,   # GPT-BigCode-style dense MLP
    act="gelu",
    norm_type="layernorm",
    source="arXiv:2405.04324 (Granite Code Models); hf",
))
