"""Config system: model configs, input-shape cells, and the registry.

Every assigned architecture lives in its own ``src/repro/configs/<id>.py``
module that instantiates a :class:`ModelConfig` and registers it.  The
full configs are exercised only through the AOT dry-run
(ShapeDtypeStruct, no allocation); smoke tests use :func:`reduced`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

# --------------------------------------------------------------------------
# Sub-configs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor bounds tokens routed per expert (train-time dispatch)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) archs.

    The modality frontend (conv-over-mel) is a STUB per the assignment:
    ``input_specs`` provides precomputed frame embeddings of shape
    ``(batch, num_frames, d_model)``.
    """

    num_layers: int
    num_frames: int  # fixed encoder sequence length (1500 for whisper)


@dataclass(frozen=True)
class VisionConfig:
    """VLM frontend stub: precomputed patch embeddings + MSDA resampler.

    ``levels`` are the multi-scale feature-map sizes of the (stub) CLIP
    pyramid that the MSDA resampler pools into ``num_visual_tokens``.
    """

    num_visual_tokens: int
    vision_dim: int
    levels: Tuple[Tuple[int, int], ...] = ((32, 32), (16, 16), (8, 8))
    msda_points: int = 4
    msda_heads: int = 8
    # serving: variable incoming pyramids are padded into this ladder of
    # fixed bucket geometries (fractions of ``levels``), bounding the
    # plan cache and the set of compiled prefill programs
    # (serving.batcher.default_buckets).
    bucket_scales: Tuple[float, ...] = (1.0, 0.75, 0.5)


@dataclass(frozen=True)
class MSDAConfig:
    """Multi-scale deformable attention config (the paper's op).

    ``backend`` / ``tune`` / ``vmem_budget`` feed straight into the
    plan/execute API (``repro.kernels.plan.msda_plan``): the backend is
    resolved through the registry and block planning runs once per
    static geometry — heuristically, or measured when ``tune="autotune"``.
    """

    levels: Tuple[Tuple[int, int], ...]
    num_points: int = 4
    num_heads: int = 8
    # kernel backend: 'auto' | 'pallas' | 'cpu' | 'ref' | any registered
    backend: str = "auto"
    save_sampled: bool = True  # train mode: stash gathered corners for bwd
    # block planning: 'heuristic' (paper Fig. 7 VMEM model) | 'autotune'
    # (measure candidates once, persist winners per device kind)
    tune: str = "heuristic"
    # per-core VMEM budget for block planning; 0 = default for the
    # device kind (plan.default_vmem_budget)
    vmem_budget: int = 0
    # shard queries (not heads) over 'tp' in the encoder's huge-Q layers
    query_parallel: bool = True
    # distribution family when a mesh is installed: 'auto' walks the
    # ladder (and autotune races 1D vs 2D), '1d' pins the classic
    # query/head/batch ladder, '2d' forces dp x tp query tiling
    sharding: str = "auto"
    # grad_value reduction for query-sharded plans: 'auto' (-> ring),
    # 'ring' (ppermute ring over tp), 'psum' (shard_map transpose
    # all-reduce — ablation / parity baseline)
    grad_reduce: str = "auto"
    # msda dtype policy — the planned precision axis:
    #   'follow'   value-slab dtype tracks the operand dtype (default)
    #   'float32'  force fp32 slabs
    #   'bfloat16' bf16 slabs + fp32 accumulation (half the VMEM
    #              residency, so block planning widens the vec-len)
    #   'auto'     tune='autotune' races fp32 vs bf16 per level and
    #              persists the winner per device kind
    # (mapped to spec fields by repro.kernels.plan.resolve_dtype_policy)
    dtype_policy: str = "follow"
    # whole-pyramid kernel fusion — one pallas launch per direction with
    # every level's slab packed into a single VMEM-resident super-slab:
    #   'auto'  fuse when the packed pyramid fits the VMEM budget
    #           (tune='autotune' races fused vs per-level instead)
    #   'on'    force fusion, 'off' pin the per-level launches
    fuse_levels: str = "auto"
    # top-k attention-weight point pruning (LOSSY — DEFA-style):
    #   'off'   dense MSDA, bitwise-identical to pre-sparsity plans
    #   'topk'  pin the pruned executor (keep sparsity_k cells/query)
    #   'auto'  tune='autotune' races pruned vs dense; heuristic stays
    #           dense (a lossy mode is never picked untimed)
    sparsity: str = "off"
    # cells kept per query under 'topk'; 0 -> ceil(levels*points / 2)
    sparsity_k: int = 0
    # plan-time query ordering (bitwise-neutral to outputs):
    #   'identity' leave queries in raster order
    #   'morton'   Z-curve-permute queries at the executor boundary
    #              (engages only on encoder layouts where Q == S)
    #   'auto'     tune='autotune' races morton vs identity
    query_order: str = "identity"

    def __post_init__(self):
        # mirror of plan.DTYPE_POLICIES keys — kept local so the config
        # layer stays importable without jax / the kernel stack
        if self.dtype_policy not in ("follow", "float32", "bfloat16", "auto"):
            raise ValueError(
                f"unknown msda dtype_policy {self.dtype_policy!r}; one of "
                "'follow' | 'float32' | 'bfloat16' | 'auto'")
        if self.sharding not in ("auto", "1d", "2d"):
            raise ValueError(
                f"unknown msda sharding {self.sharding!r}; one of "
                "'auto' | '1d' | '2d'")
        if self.grad_reduce not in ("auto", "ring", "psum"):
            raise ValueError(
                f"unknown msda grad_reduce {self.grad_reduce!r}; one of "
                "'auto' | 'ring' | 'psum'")
        if self.sparsity not in ("off", "topk", "auto"):
            raise ValueError(
                f"unknown msda sparsity {self.sparsity!r}; one of "
                "'off' | 'topk' | 'auto'")
        if self.sparsity_k < 0:
            raise ValueError(
                f"msda sparsity_k must be >= 0, got {self.sparsity_k}")
        if self.query_order not in ("identity", "morton", "auto"):
            raise ValueError(
                f"unknown msda query_order {self.query_order!r}; one of "
                "'identity' | 'morton' | 'auto'")


# --------------------------------------------------------------------------
# ModelConfig
# --------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "hybrid", "ssm", "audio", "vlm", "vision")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (gated) | gelu (dense ff)
    gated_mlp: bool = True
    moe: Optional[MoEConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    msda: Optional[MSDAConfig] = None
    # hybrid (recurrentgemma): repeating per-layer block kinds
    block_pattern: Tuple[str, ...] = ("attn",)  # attn|rglru|slstm|mlstm|local
    window: int = 0  # sliding-window size for 'local' attention blocks
    # ssm extras
    lru_width: int = 0  # rglru recurrence width (0 -> d_model)
    # int8 KV cache (serving): halves cache HBM; enabled automatically by
    # the dry-run when the bf16 cache would not fit the mesh (see
    # launch/dryrun.py), or explicitly per config
    kv_quant: bool = False
    dtype: str = "bfloat16"
    # max positions (rope table sizing at trace time is dynamic; informational)
    max_seq_len: int = 524288
    source: str = ""  # provenance note

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError("num_heads must be divisible by num_kv_heads")

    # -- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer block kinds (len == num_layers)."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d
        head = 0 if self.tie_embeddings else v * d
        total = emb + head + d  # final norm
        kinds = self.layer_kinds()
        for kind in kinds:
            total += 2 * d  # two pre-norms (approx; some blocks have one)
            if kind in ("attn", "local"):
                total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qkv_bias:
                    total += self.q_dim + 2 * self.kv_dim
            elif kind == "rglru":
                w = self.lru_width or d
                total += 2 * d * w + w * d + 2 * w * 4  # conv/gates approx
            elif kind in ("slstm", "mlstm"):
                # xlstm blocks carry their own up/down projections
                up = 2 * d
                total += d * up * 2 + up * d + 4 * up * up // 4
            if kind in ("slstm", "mlstm"):
                pass  # no separate FFN (d_ff == 0)
            elif self.moe is not None:
                total += d * self.moe.num_experts  # router
                total += self.moe.num_experts * (3 if self.gated_mlp else 2) * d * dff
            elif dff:
                total += (3 if self.gated_mlp else 2) * d * dff
        if self.encoder is not None:
            # encoder stack (self-attn + ff) + decoder cross-attn already in kinds? no:
            enc = self.encoder.num_layers * (
                2 * d + 2 * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d) // 2
                + (3 if self.gated_mlp else 2) * d * dff
            )
            # decoder cross-attention per layer
            enc += self.num_layers * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + d)
            total += enc
        if self.vision is not None:
            vc = self.vision
            total += vc.vision_dim * d  # projector
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        per_expert = (3 if self.gated_mlp else 2) * d * dff
        dense = self.param_count() - self.num_layers * self.moe.num_experts * per_expert
        return dense + self.num_layers * self.moe.top_k * per_expert


# --------------------------------------------------------------------------
# Input-shape cells
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
    # extra, paper-native: deformable-DETR at the paper's 1024x1024 eval
    # scale (sum HW = 87296 pixel queries); not part of the 40 LM cells
    "detr_1k": ShapeConfig("detr_1k", 87296, 64, "train"),
}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell is runnable; reason if not.

    ``long_500k`` needs sub-quadratic attention: only archs whose
    per-token state is bounded (recurrent / sliding-window) run it.
    """
    if shape.name == "detr_1k":
        if cfg.family != "vision":
            return False, "detr_1k is the vision detector's own cell"
        return True, ""
    if shape.name == "long_500k":
        kinds = set(cfg.layer_kinds())
        quadratic = "attn" in kinds
        if quadratic:
            return False, "full quadratic attention — long_500k skipped per assignment"
    if cfg.family == "vision":
        return False, "vision detector runs its own detr_1k cell"
    return True, ""


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_configs() -> Sequence[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import every sibling config module so it registers itself
    import importlib
    import pkgutil

    import repro.configs as pkg

    for m in pkgutil.iter_modules(pkg.__path__):
        if m.name not in ("base",):
            importlib.import_module(f"repro.configs.{m.name}")


# --------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# --------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for single-CPU smoke tests."""
    pat = cfg.block_pattern
    n_layers = max(len(pat), 2)
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        lru_width=64 if cfg.lru_width else 0,
        window=min(cfg.window, 32) if cfg.window else 0,
        dtype="float32",
        max_seq_len=256,
    )
    if cfg.moe is not None:
        kw["moe"] = replace(cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2))
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(num_layers=2, num_frames=16)
    if cfg.vision is not None:
        kw["vision"] = VisionConfig(
            num_visual_tokens=8, vision_dim=32, levels=((8, 8), (4, 4)), msda_points=2, msda_heads=2
        )
    if cfg.msda is not None:
        kw["msda"] = replace(cfg.msda, levels=((8, 8), (4, 4)), num_points=2, num_heads=2)
    smoke = replace(cfg, **kw)
    # bypass registry (smoke configs are ephemeral)
    return smoke
