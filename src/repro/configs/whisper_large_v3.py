"""whisper-large-v3: encoder-decoder audio backbone; conv frontend STUB.

32 decoder layers (per spec); encoder 32L over 1500 precomputed frame
embeddings supplied by input_specs() (the mel+conv frontend is a stub per
the assignment).

[arXiv:2212.04356; unverified]
"""
from repro.configs.base import EncoderConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    gated_mlp=False,
    act="gelu",
    norm_type="layernorm",
    encoder=EncoderConfig(num_layers=32, num_frames=1500),
    source="arXiv:2212.04356 (Whisper); unverified",
))
