"""recurrentgemma-2b: Griffin-style hybrid — RG-LRU + local attention, 1:2.

26 layers, repeating (rglru, rglru, local-attn); MQA kv=1; window 2048.
Sub-quadratic: runs long_500k.

[arXiv:2402.19427 (Griffin); hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    gated_mlp=True,
    act="gelu",
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=2560,
    tie_embeddings=True,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
))
