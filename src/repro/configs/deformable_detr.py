"""deformable-detr: the paper's own host architecture (extra, paper-native).

Six-layer MSDA encoder over a 5-level pyramid from a (stub) Swin
backbone at 1024x1024 input — the exact workload of the paper's
evaluation (sum HW = 87296, d=256, 8 heads, 4 points) — plus a 6-layer
deformable decoder with 300 object queries.
"""
from repro.configs.base import MSDAConfig, ModelConfig, register

PAPER_LEVELS = ((256, 256), (128, 128), (64, 64), (32, 32), (16, 16))

CONFIG = register(ModelConfig(
    name="deformable-detr",
    family="vision",
    num_layers=6,            # encoder layers (decoder mirrors with 6)
    d_model=256,
    num_heads=8,
    num_kv_heads=8,
    d_ff=1024,
    vocab_size=91,           # COCO classes as the 'vocab' (detection head)
    head_dim=32,
    gated_mlp=False,
    act="gelu",
    norm_type="layernorm",
    # plan/execute knobs: backend resolved once through the registry;
    # tune="autotune" measures per-level block_q candidates and persists
    # winners per device kind (see repro.kernels.plan.msda_plan).
    # dtype_policy="auto" defers the per-level fp32-vs-bf16 slab choice
    # to the autotune race — which only runs under tune="autotune" (flip
    # it on a real fleet; the default heuristic planning keeps the
    # operand dtype, so this knob is a no-op until then).  When the race
    # does pick bf16 for the 256x256 level its slab halves to ~4 MiB and
    # block re-planning widens the encoder's vec-len; accumulation stays
    # fp32 either way.
    # sharding="auto": on a real mesh the 87k-query encoder clears the
    # 2D threshold (87040 / 16 = 5440 queries per shard on a 4x4 slice),
    # so its plan commits dp x tp query tiling with ring-reduced
    # grad_value slabs; the 300-query decoder stays on the 1D ladder.
    msda=MSDAConfig(levels=PAPER_LEVELS, num_points=4, num_heads=8,
                    backend="auto", tune="heuristic", vmem_budget=0,
                    query_parallel=True, dtype_policy="auto",
                    sharding="auto", grad_reduce="auto"),
    source="arXiv:2010.04159 (Deformable DETR) + paper §3 input spec",
))
