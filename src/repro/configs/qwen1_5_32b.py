"""qwen1.5-32b: dense with QKV bias, 64L.

[hf:Qwen/Qwen1.5-0.5B (family); hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    gated_mlp=True,
    act="silu",
    source="hf:Qwen/Qwen1.5-32B; hf",
))
