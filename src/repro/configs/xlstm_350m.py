"""xlstm-350m: alternating sLSTM + mLSTM blocks, attention-free.

d_ff=0 per spec — xLSTM blocks carry their own up/down projections.
Sub-quadratic (constant recurrent state): runs long_500k.

[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    gated_mlp=False,
    act="gelu",
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
    source="arXiv:2405.04517 (xLSTM); unverified",
))
