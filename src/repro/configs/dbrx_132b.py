"""dbrx-132b: fine-grained MoE, 16 experts top-4, GQA kv=8.

[hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=4),
    gated_mlp=True,
    act="silu",
    norm_type="layernorm",
    source="hf:databricks/dbrx-base; unverified",
))
