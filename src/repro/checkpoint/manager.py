"""Checkpointing: atomic, async-capable, elastic-restore.

Layout: ``<dir>/step_<k>/`` with one ``.npy`` per leaf plus a JSON
manifest (tree structure + dtypes + shapes).  Writes go to a temp dir
then ``rename`` — a crashed writer can never corrupt the latest
checkpoint (the commit protocol a multi-host job runs on process 0).

* ``save(state, dir, step)`` — blocking; ``save_async`` runs it on a
  background thread (overlaps the next step's compute).
* ``restore(dir, like=...)`` — reads the newest committed step; when a
  target pytree/sharding is given, leaves are ``device_put`` straight to
  the (possibly different) mesh: **elastic restore** — a 512-chip
  checkpoint restores onto any surviving mesh whose axes still divide
  the leaf dims (GSPMD resharding handles the rest).
* ``restore_latest_valid`` — same, but walks newest -> oldest past any
  corrupt step (truncated leaf / bad manifest) instead of raising — the
  training harness's fallback when a crash corrupted the newest write.
* ``keep_last`` garbage-collects old steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree) -> Tuple[list, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else f"i{p.idx}" if hasattr(p, "idx") else str(p)
            for p in path
        )
        names.append(name)
        leaves.append(leaf)
    return list(zip(names, leaves)), treedef


def save(state, directory: str, step: int, *, keep_last: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named, _ = _flatten_with_names(state)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({"name": name, "file": fn, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(directory, keep_last)
    return final


def save_async(state, directory: str, step: int, *, keep_last: int = 3) -> threading.Thread:
    # snapshot to host first so the donated device buffers can move on
    host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    t = threading.Thread(target=save, args=(host_state, directory, step),
                         kwargs={"keep_last": keep_last}, daemon=True)
    t.start()
    return t


def _step_number(entry: str) -> Optional[int]:
    """``"step_00000007"`` -> 7; None for anything malformed (``step_``,
    ``step_final``, ...) — junk entries must never make listing raise."""
    try:
        return int(entry.split("_", 1)[1])
    except (IndexError, ValueError):
        return None


def available_steps(directory: str) -> list:
    """All committed step numbers, ascending (empty when none — a
    missing, empty, or junk-entry-only directory is not an error)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        n = _step_number(d)
        if n is not None and os.path.exists(
                os.path.join(directory, d, "manifest.json")):
            steps.append(n)
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, like, *, step: Optional[int] = None, shardings=None):
    """Restore into the structure of ``like`` (names must match).

    ``shardings``: optional pytree of NamedSharding — elastic restore
    puts each leaf directly onto the new mesh.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    named, treedef = _flatten_with_names(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _flatten_with_names(shardings)[0]]
    out = []
    for i, (name, leaf) in enumerate(named):
        entry = by_name[name]
        arr = np.load(os.path.join(path, entry["file"]))
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i][1] if isinstance(shard_leaves[i], tuple) else shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest_valid(directory: str, like, *, shardings=None):
    """Restore the newest checkpoint that actually loads.

    ``restore()`` raises on a corrupt step (truncated leaf, bad
    manifest, missing array).  The training harness must instead fall
    back: walk the committed steps newest -> oldest, skip any that fail
    to load, and return the first that round-trips.  Returns
    ``(state, step, skipped)`` where ``skipped`` is a list of
    ``(step, reason)`` for every corrupt checkpoint passed over — the
    recovery log the fault-injection tests assert on.  Raises
    ``FileNotFoundError`` only when NO committed step loads.
    """
    skipped = []
    for step in reversed(available_steps(directory)):
        try:
            state = restore(directory, like, step=step, shardings=shardings)
            return state, step, skipped
        # every step here had a manifest, so even FileNotFoundError means
        # a torn write (missing leaf file) — skip it like any corruption
        except Exception as e:  # noqa: BLE001 — corrupt step: fall back
            skipped.append((step, f"{type(e).__name__}: {e}"))
    raise FileNotFoundError(
        f"no loadable checkpoint in {directory} "
        f"(skipped {[s for s, _ in skipped]})")


def _gc(directory: str, keep_last: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
