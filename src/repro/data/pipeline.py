"""Deterministic, restart-safe data pipeline.

Batches are a pure function of ``(seed, step)`` so a job restored from a
checkpoint at step k regenerates exactly the batches it would have seen
— the data side of fault tolerance.  Two sources:

* synthetic token streams (structured, learnable: repeated n-gram
  processes, not uniform noise — loss actually decreases);
* a byte-tokenised text file (for the end-to-end examples);
* synthetic detection batches (pyramid + boxes + labels with a planted
  label signature at each box center — the DETR training workload the
  elastic harness drives through ``launch/train.py``).

A background prefetcher overlaps host-side batch synthesis with device
compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.data import tokenizer


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    source: str = "synthetic"  # 'synthetic' | 'file' | 'detection'
    path: Optional[str] = None
    # detection-source geometry (matches the model config's msda levels)
    levels: Tuple[Tuple[int, int], ...] = ()
    feat_dim: int = 0
    num_targets: int = 3


def _synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Markov stream: next token = fixed affine rule(prev) + noise.

    The rule is a function of the SEED only (not the step/sequence), so
    it is learnable; loss decreases from ln(V) toward the noise floor.
    """
    rule_rng = np.random.default_rng(cfg.seed)
    V = cfg.vocab_size
    a = int(rule_rng.integers(1, 97))
    b = int(rule_rng.integers(0, V))
    rng = np.random.default_rng((cfg.seed, step))
    B, S = cfg.global_batch, cfg.seq_len
    x0 = rng.integers(0, V, size=(B, 1))
    toks = np.zeros((B, S + 1), np.int64)
    toks[:, :1] = x0
    for t in range(1, S + 1):
        nxt = (a * toks[:, t - 1 : t] + b) % V
        noise = rng.integers(0, V, size=(B, 1))
        use_noise = rng.random((B, 1)) < 0.05
        toks[:, t : t + 1] = np.where(use_noise, noise, nxt)
    return {"tokens": toks[:, :-1].astype(np.int32), "targets": toks[:, 1:].astype(np.int32)}


def _detection_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Synthetic detection batch: pyramid + boxes + labels.

    Numpy port of ``examples/train_detr.synth_batch``, keyed by
    ``(seed, step)`` like every other source so a restored run replays
    bit-identical batches: each object's center pixel (per level) gets a
    label-dependent one-hot bump the MSDA encoder can learn to pool.
    """
    if not cfg.levels or not cfg.feat_dim:
        raise ValueError("detection source needs DataConfig.levels and feat_dim")
    rng = np.random.default_rng((cfg.seed, step, 7))  # distinct LM stream
    B, T, d = cfg.global_batch, cfg.num_targets, cfg.feat_dim
    boxes = rng.uniform(0.2, 0.8, size=(B, T, 4)).astype(np.float32)
    labels = rng.integers(1, cfg.vocab_size, size=(B, T))
    sp = sum(h * w for h, w in cfg.levels)
    pyr = (rng.standard_normal((B, sp, d)) * 0.05).astype(np.float32)
    offset = 0
    for h, w in cfg.levels:
        cx = np.clip((boxes[..., 0] * w).astype(int), 0, w - 1)
        cy = np.clip((boxes[..., 1] * h).astype(int), 0, h - 1)
        flat = offset + cy * w + cx  # (B,T)
        sig = 2.0 * np.eye(d, dtype=np.float32)[labels % d]
        np.add.at(pyr, (np.arange(B)[:, None], flat), sig)
        offset += h * w
    return {"pyramid": pyr, "labels": labels.astype(np.int32), "boxes": boxes}


class FileSource:
    def __init__(self, path: str):
        with open(path, "rb") as f:
            raw = f.read().decode("utf-8", errors="replace")
        self.ids = np.asarray(tokenizer.encode(raw, add_bos=False), np.int32)

    def batch(self, cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        n = len(self.ids) - (S + 1)
        starts = rng.integers(0, max(n, 1), size=(B,))
        rows = np.stack([self.ids[s : s + S + 1] for s in starts])
        return {"tokens": rows[:, :-1], "targets": rows[:, 1:]}


class Pipeline:
    """step -> batch, with deterministic regeneration and prefetch."""

    def __init__(self, cfg: DataConfig, prefetch: int = 2):
        self.cfg = cfg
        self._file = FileSource(cfg.path) if cfg.source == "file" else None
        self._prefetch = prefetch

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        if self._file is not None:
            return self._file.batch(self.cfg, step)
        if self.cfg.source == "detection":
            return _detection_batch(self.cfg, step)
        return _synthetic_batch(self.cfg, step)

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        q: queue.Queue = queue.Queue(maxsize=self._prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                q.put(self.batch(s))
                s += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
