"""Byte-level tokenizer with a small reserved-special-token header.

Good enough for end-to-end training examples without external vocab
files: token = byte + N_SPECIAL, ids < N_SPECIAL are specials.
"""
from __future__ import annotations

from typing import List

PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 16


def encode(text: str, *, add_bos: bool = True, add_eos: bool = False) -> List[int]:
    ids = [b + N_SPECIAL for b in text.encode("utf-8")]
    if add_bos:
        ids = [BOS] + ids
    if add_eos:
        ids = ids + [EOS]
    return ids


def decode(ids) -> str:
    data = bytes(i - N_SPECIAL for i in ids if i >= N_SPECIAL)
    return data.decode("utf-8", errors="replace")


def vocab_size() -> int:
    return 256 + N_SPECIAL
