"""Logical-axis sharding rules -> physical PartitionSpecs.

Contract: this module owns the mapping from *logical* axes ('dp', 'tp',
'ep', 'sp') to *physical* mesh axes, and derives parameter
PartitionSpecs from leaf names — models never name physical axes, and
anything that does not divide a physical axis degrades to replicated
rather than erroring.  The MSDA planner (``repro.kernels.plan``) builds
its 1D/2D sharding ladder on :func:`resolve_axis` / :func:`axis_size` /
:func:`flat_axes`, so a mesh-topology change lands here, once.  See
``docs/sharding.md`` for the full ladder and the 2D (dp x tp) mode.

Logical axes:
  'dp' — data/FSDP axis: batch and the fsdp-sharded dim of weights.
         Maps to ('pod', 'data') on the multi-pod mesh, ('data',) single-pod.
  'tp' — tensor-parallel axis ('model'): heads / d_ff / vocab / experts.
  'ep' — expert-parallel: same physical axis as 'tp' (experts claim it
         when E is divisible by the axis size; otherwise experts fall
         back to TP over d_ff — grok-1's 8 experts on a 16-wide axis).
  'sp' — sequence-parallel: also the 'model' axis, claimed by sequence
         dims (decode KV cache, long-context activations).

Parameter specs are derived from leaf *names* (the contract with
``repro.models``) so any model assembled from those layers inherits a
complete sharding without per-arch tables.  Stacked (scanned) params get
leading ``None`` dims automatically.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar("mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Install a mesh for spec resolution + sharding hints."""
    tok = _MESH.set(mesh)
    try:
        # jax.set_mesh is the >=0.6 spelling; older jax uses the Mesh
        # object itself as the ambient-mesh context manager.
        setter = getattr(jax, "set_mesh", None)
        with (setter(mesh) if setter is not None else mesh):
            yield mesh
    finally:
        _MESH.reset(tok)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def resolve_axis(logical: Optional[str], mesh: Mesh):
    """Logical axis name -> physical mesh axis (or tuple), or None."""
    if logical is None:
        return None
    names = mesh.axis_names
    if logical == "dp":
        phys = tuple(a for a in ("pod", "data") if a in names)
        return phys if len(phys) > 1 else (phys[0] if phys else None)
    if logical in ("tp", "ep", "sp"):
        return "model" if "model" in names else None
    raise ValueError(f"unknown logical axis {logical!r}")


def flat_axes(axis) -> Tuple[str, ...]:
    """A resolved physical axis (name | tuple | None) as a flat tuple."""
    if axis is None:
        return ()
    return tuple(axis) if isinstance(axis, tuple) else (axis,)


def axis_size(axis, mesh: Mesh) -> int:
    """Total device count along a resolved physical axis (1 for None).

    Accepts the same name | tuple | None shapes :func:`resolve_axis`
    returns, so ``axis_size(resolve_axis('dp', mesh), mesh)`` is the
    data-parallel width even on the multi-pod ('pod', 'data') mesh.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for a in flat_axes(axis):
        total *= sizes[a]
    return total


def spec(*logical: Optional[str], mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    return P(*(resolve_axis(a, mesh) for a in logical))


def _divisible(n: int, axis, mesh: Mesh) -> bool:
    if axis is None:
        return False
    return n % axis_size(axis, mesh) == 0


def hint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint if a mesh is installed; no-op otherwise.

    Logical dims that don't divide the physical axis degrade to None
    (replicated) rather than erroring — keeps one rule set valid across
    every (arch x mesh) cell.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    axes = []
    for dim, a in enumerate(logical):
        phys = resolve_axis(a, mesh)
        if phys is not None and not _divisible(x.shape[dim], phys, mesh):
            phys = None
        axes.append(phys)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))


# --------------------------------------------------------------------------
# parameter specs by leaf name
# --------------------------------------------------------------------------

_NAME_RULES = {
    # embeddings / output head
    "emb": ("tp", "dp"),
    "head": ("dp", "tp"),
    # attention
    "wq": ("dp", "tp"),
    "wk": ("dp", "tp"),
    "wv": ("dp", "tp"),
    "wo": ("tp", "dp"),
    "bq": ("tp",),
    "bk": ("tp",),
    "bv": ("tp",),
    # mlp
    "wi": ("dp", "tp"),
    "wg": ("dp", "tp"),
    "wd": ("tp", "dp"),
    # moe (expert tensors handled specially below)
    "router": ("dp", None),
    # rglru
    "wx": ("dp", "tp"),
    "wgate": ("dp", "tp"),
    "conv_w": (None, "tp"),
    "wr": ("tp", None),
    "br": (None,),
    "lam": ("tp",),
    # xlstm
    "wup": ("dp", "tp"),
    "wdown": ("tp", "dp"),
    "wif": ("tp", None),
    "bif": (None,),
    "wz": ("dp", "tp"),
    "rz": (None, None, None, None),
    "bz": (None,),
    # norms / misc
    "scale": (None,),
    "bias": (None,),
    "ngroups": (),
    "b": (None,),
    "w": ("dp", "tp"),  # generic linear
    # msda / detr extras
    "query_emb": (None, None),
    "ref_points": (None, None),
    "level_emb": (None, None),
    "pos_emb": (None, None),
}


def _leaf_logical(path: Tuple[str, ...], ndim: int) -> Tuple[Optional[str], ...]:
    name = path[-1]
    if name.startswith("experts_"):
        # (E, d, ff) or (E, ff, d): EP over 'ep' when divisible (checked at
        # resolution time via hint degradation); orientation by suffix.
        if name.endswith("_wi") or name.endswith("_wg"):
            base = ("ep", "dp", None)
        else:
            base = ("ep", None, "dp")
    elif name in _NAME_RULES:
        base = _NAME_RULES[name]
    else:
        base = (None,) * ndim
    if len(base) > ndim:
        base = base[-ndim:] if ndim else ()
    # stacked/scanned params: leading period dims replicate
    return (None,) * (ndim - len(base)) + tuple(base)


def param_specs(params, mesh: Optional[Mesh] = None, *, moe_experts: int = 0):
    """Pytree of PartitionSpec matching ``params``.

    ``moe_experts``: #experts, used to pick EP vs TP-MoE per mesh size.
    """
    mesh = mesh or current_mesh()

    def one(path, leaf):
        names = tuple(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in path
        )
        ndim = getattr(leaf, "ndim", 0)
        logical = _leaf_logical(names, ndim)
        name = names[-1] if names else ""
        if name.startswith("experts_") and mesh is not None:
            ep_ax = resolve_axis("ep", mesh)
            if not _divisible(moe_experts, ep_ax, mesh):
                # TP-MoE fallback: shard d_ff instead of experts
                if name.endswith("_wi") or name.endswith("_wg"):
                    logical = (None,) * (ndim - 3) + (None, "dp", "tp")
                else:
                    logical = (None,) * (ndim - 3) + (None, "tp", "dp")
        if mesh is None:
            return P()
        axes = []
        for dim, a in enumerate(logical):
            phys = resolve_axis(a, mesh)
            if phys is not None and not _divisible(leaf.shape[dim], phys, mesh):
                phys = None
            axes.append(phys)
        return P(*axes)

    return jax.tree_util.tree_map_with_path(one, params)


def named_sharding_tree(params, mesh: Optional[Mesh] = None, *, moe_experts: int = 0):
    mesh = mesh or current_mesh()
    specs = param_specs(params, mesh, moe_experts=moe_experts)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
