"""Plan/execute API for multi-scale deformable attention.

Contract (the one rule of the system — ``docs/architecture.md``):
every hardware-aware decision is committed HERE, at plan time, and
execution only executes.  ``MsdaSpec`` (frozen geometry) resolves via
``msda_plan`` into an ``MsdaPlan`` carrying the backend, per-level
blocks + slab dtypes, the whole-pyramid fusion decision
(``fuse_levels`` — one pallas launch per direction when the packed
pyramid fits VMEM; heuristic fitting model or autotuned race, winners
persisted per device kind), and — when a mesh is given — the sharding
mode: the 1D query/head/batch ladder or the 2D dp x tp query tiling
with ring-reduced grad_value slabs, plus the raced ring-vs-psum
grad_value reduction (``docs/sharding.md``).  Plans live in a bounded
LRU; ``plan.describe()`` states everything that was committed.

The paper's central observation is that MSDA gets fast only when the
*static* problem geometry — level shapes, points, head dim, the VMEM
budget — is exploited ahead of time: adaptive vec-len planning (Fig. 7),
gather/scatter fusion and the MXU one-hot routing are all compile-time
decisions.  This module makes those decisions a first-class artifact:

* :class:`MsdaSpec` — frozen, hashable description of one MSDA problem
  (spatial shapes, heads, head dim, points, queries, dtype, train flag,
  per-device VMEM budget, and the precision policy: ``slab_dtype`` /
  ``accum_dtype`` — bf16 slabs with fp32 accumulation are a *planned*
  variant, not a call-site cast).
* :func:`msda_plan` — resolves a backend through the registry
  (``repro.kernels.registry``), computes block sizes **and per-level
  slab dtypes** once (heuristic, or measured via ``tune="autotune"``
  which races fp32-vs-bf16 per level, with an on-disk winner cache),
  bakes in ``shard_map`` wiring when a mesh is given, and returns a
  :class:`MsdaPlan`.
* :class:`MsdaPlan` — the executable artifact: ``plan(value, loc, attn)``
  runs the op (differentiable; the custom VJP was built at plan time) and
  ``plan.describe()`` reports per-level ``block_q``, slab bytes, the
  committed slab dtype, VMEM occupancy and the chosen gather path.

Plans are cached in an explicit, bounded LRU (:func:`clear_plans`,
:func:`plan_cache_info`) — repeated calls with an identical spec return
the *same* plan object and never re-run block planning.  The legacy
9-kwarg ``ops.msda(...)`` entry point is now a thin shim over this cache.

Typical use::

    from repro.kernels import plan as msda_plan_mod

    spec = msda_plan_mod.MsdaSpec(
        spatial_shapes=((64, 64), (32, 32)), num_heads=8, head_dim=32,
        num_points=4, num_queries=5120, dtype="float32", train=True)
    plan = msda_plan_mod.msda_plan(spec, backend="pallas")
    print(plan.describe())
    out = plan(value, loc, attn)        # (B, Q, H*D), differentiable
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.obs import registry as _obs
from repro.obs import trace as _obs_trace

Shapes = Tuple[Tuple[int, int], ...]

_SUBLANE = 8

FUSE_LEVELS_CHOICES = ("auto", "on", "off")


def _is_prefix_pin(value: Any) -> bool:
    """True for a ``"prefix:k"`` fuse_levels pin (k >= 1): commit the
    partial-fusion tier with a fused prefix of exactly k levels."""
    if not (isinstance(value, str) and value.startswith("prefix:")):
        return False
    try:
        return int(value.split(":", 1)[1]) >= 1
    except ValueError:
        return False



SPARSITY_CHOICES = ("off", "topk", "auto")
QUERY_ORDER_CHOICES = ("identity", "morton", "auto")


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# --------------------------------------------------------------------------
# per-device VMEM budgets (satellite: budget is a spec field, defaulted by
# device kind, so plans for larger-VMEM parts stop under-blocking)
# --------------------------------------------------------------------------

# substring of jax.Device.device_kind (lowercased) -> usable per-core bytes.
# Conservative: leaves headroom for Mosaic spills and double-buffering.
DEVICE_VMEM_BUDGETS: Tuple[Tuple[str, int], ...] = (
    ("v6", 64 * 2**20),  # trillium-class
    ("v5p", 64 * 2**20),
    ("v5 lite", 32 * 2**20),
    ("v5e", 32 * 2**20),
    ("v4", 32 * 2**20),
    ("v3", 16 * 2**20),
    ("v2", 16 * 2**20),
)
_FALLBACK_VMEM_BUDGET = 32 * 2**20  # CPU / interpret / unknown parts


def default_vmem_budget(device_kind: Optional[str] = None) -> int:
    """Usable VMEM bytes for block planning, by accelerator kind."""
    if device_kind is None:
        try:
            device_kind = jax.devices()[0].device_kind
        except Exception:  # no backend initialised yet
            device_kind = "cpu"
    kind = device_kind.lower()
    for sub, budget in DEVICE_VMEM_BUDGETS:
        if sub in kind:
            return budget
    return _FALLBACK_VMEM_BUDGET


# --------------------------------------------------------------------------
# MsdaSpec
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MsdaSpec:
    """Static geometry of one MSDA problem (hashable; the plan-cache key).

    ``vmem_budget=0`` resolves to :func:`default_vmem_budget` for the
    current device at construction time, so the budget is always an
    explicit, inspectable number on the spec.
    """

    spatial_shapes: Shapes
    num_heads: int
    head_dim: int
    num_points: int
    num_queries: int
    dtype: str = "float32"
    train: bool = False
    vmem_budget: int = 0  # 0 -> per-device default
    # tuning-surface flags (kept on the spec so ablations stay plannable)
    fuse_gather: bool = True
    fuse_scatter: bool = True
    adaptive_block: bool = True
    onehot_small_levels: bool = False
    # -- precision policy (the second planned axis) -----------------------
    # slab_dtype: dtype the VMEM value slab is STORED in.  '' follows the
    # operand dtype; 'auto' lets tune="autotune" race fp32 vs bf16 per
    # level; any concrete dtype pins it (bf16 halves residency -> the
    # planner widens block_q).  accum_dtype: the widened accumulator for
    # fwd partial outputs and the bwd grad_value slab — kept fp32 so a
    # bf16-slab plan is "bf16 storage, fp32 math", per DEFA's
    # reduced-precision-sampling / wide-accumulation observation.
    slab_dtype: str = ""
    accum_dtype: str = "float32"
    # -- pyramid kernel fusion tiers (the third planned axis) -------------
    # 'auto' plans the largest level prefix [0..k) whose packed
    # super-slab + per-query working set fits the VMEM budget
    # (ops.fusion_prefix): a full fit fuses the whole pyramid, a strict
    # prefix commits the partial-fusion tier (one fused launch over the
    # prefix + per-level tail launches), no useful prefix stays
    # per-level.  tune="autotune" races full-fuse vs the model's prefix
    # vs per-level instead of trusting the model.  'on'/'off' pin the
    # whole-pyramid/per-level extremes; 'prefix:k' pins the tier.  Only
    # kernel backends that understand fusion (pallas) honour any of
    # this; others stay per-level.
    fuse_levels: str = "auto"
    # -- sparsity (the fourth planned axis) -------------------------------
    # 'off' executes dense MSDA exactly as before (bitwise-identical
    # plans); 'topk' pins the pruned executor — keep the sparsity_k
    # highest-weight (level, point) cells per query, renormalise, gather
    # only the surviving corners (DEFA-style point pruning; LOSSY, with
    # its own conformance tolerance tier); 'auto' lets tune="autotune"
    # race pruned-vs-dense (fwd+VJP for train specs) and stays dense
    # under the heuristic — a lossy mode is never picked untimed.
    sparsity: str = "off"
    # cells kept per query under 'topk'; 0 -> ceil(L*P / 2), always
    # clamped to L*P (see resolved_sparsity_k)
    sparsity_k: int = 0
    # -- query ordering (the fifth planned axis) --------------------------
    # 'morton' permutes queries into reference-pixel Z-curve order at the
    # executor boundary (inverted on output) so near-in-space queries
    # gather near-in-slab corners (QUILL-style locality).  Bitwise-
    # neutral to the forward and the loc/attn grads; only engages when
    # the query grid IS the pixel grid (Q == S, the encoder layout).
    # 'auto' races permuted-vs-identity under autotune.
    query_order: str = "identity"

    def __post_init__(self):
        shapes = tuple((int(h), int(w)) for h, w in self.spatial_shapes)
        object.__setattr__(self, "spatial_shapes", shapes)
        object.__setattr__(self, "dtype", str(jnp.dtype(self.dtype)))
        if self.slab_dtype not in ("", "auto"):
            object.__setattr__(self, "slab_dtype", str(jnp.dtype(self.slab_dtype)))
        object.__setattr__(self, "accum_dtype", str(jnp.dtype(self.accum_dtype)))
        if (self.fuse_levels not in FUSE_LEVELS_CHOICES
                and not _is_prefix_pin(self.fuse_levels)):
            raise ValueError(
                f"unknown fuse_levels {self.fuse_levels!r}; "
                f"one of {FUSE_LEVELS_CHOICES} or 'prefix:k' (k >= 1)")
        if self.sparsity not in SPARSITY_CHOICES:
            raise ValueError(
                f"unknown sparsity {self.sparsity!r}; "
                f"one of {SPARSITY_CHOICES}")
        if self.sparsity_k < 0:
            raise ValueError(f"sparsity_k must be >= 0, got {self.sparsity_k}")
        if self.query_order not in QUERY_ORDER_CHOICES:
            raise ValueError(
                f"unknown query_order {self.query_order!r}; "
                f"one of {QUERY_ORDER_CHOICES}")
        if self.vmem_budget <= 0:
            object.__setattr__(self, "vmem_budget", default_vmem_budget())

    # -- derived ----------------------------------------------------------
    @property
    def num_levels(self) -> int:
        return len(self.spatial_shapes)

    @property
    def total_pixels(self) -> int:
        return sum(h * w for h, w in self.spatial_shapes)

    @property
    def value_itemsize(self) -> int:
        return jnp.dtype(self.dtype).itemsize

    def resolved_slab_dtype(self) -> str:
        """The slab storage dtype before any per-level autotune override
        ('' and 'auto' fall back to the operand dtype)."""
        if self.slab_dtype in ("", "auto"):
            return self.dtype
        return self.slab_dtype

    @property
    def slab_itemsize(self) -> int:
        return jnp.dtype(self.resolved_slab_dtype()).itemsize

    @property
    def accum_itemsize(self) -> int:
        return jnp.dtype(self.accum_dtype).itemsize

    def fuse_prefix_pin(self) -> int:
        """The k of a ``"prefix:k"`` fuse_levels pin, else 0."""
        if _is_prefix_pin(self.fuse_levels):
            return int(self.fuse_levels.split(":", 1)[1])
        return 0

    def resolved_sparsity_k(self) -> int:
        """Cells kept per query when the pruned executor runs (0 pins
        the half-the-cells default; always clamped to the cell count)."""
        cells = self.num_levels * self.num_points
        k = self.sparsity_k if self.sparsity_k > 0 else max(1, -(-cells // 2))
        return min(k, cells)

    def cache_token(self) -> str:
        """Stable string key (autotune disk cache)."""
        f = dataclasses.astuple(self)
        return "|".join(str(x) for x in f)


def spec_to_json(spec: MsdaSpec) -> Dict[str, Any]:
    """JSON-serialisable dict for ``spec`` (plan store / sweep tooling)."""
    d = dataclasses.asdict(spec)
    d["spatial_shapes"] = [[int(h), int(w)] for h, w in spec.spatial_shapes]
    return d


def spec_from_json(d: Dict[str, Any]) -> MsdaSpec:
    """Inverse of :func:`spec_to_json`.  Unknown keys raise — the plan
    store is versioned, so a field this build doesn't know means the
    entry was written by a newer schema and must not be half-loaded."""
    d = dict(d)
    known = {f.name for f in dataclasses.fields(MsdaSpec)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(f"unknown MsdaSpec fields {unknown}")
    d["spatial_shapes"] = tuple((int(h), int(w)) for h, w in d["spatial_shapes"])
    return MsdaSpec(**d)


# dtype-policy knob (configs' ``msda.dtype_policy``) -> spec fields.
# 'follow' keeps the operand dtype; 'bfloat16' commits bf16 slabs with
# fp32 accumulation; 'auto' defers the per-level choice to autotune.
DTYPE_POLICIES: Dict[str, Tuple[str, str]] = {
    "follow": ("", "float32"),
    "float32": ("float32", "float32"),
    "bfloat16": ("bfloat16", "float32"),
    "auto": ("auto", "float32"),
}


def resolve_dtype_policy(policy: str) -> Tuple[str, str]:
    """Map a policy name to ``(slab_dtype, accum_dtype)`` spec fields."""
    try:
        return DTYPE_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown msda dtype policy {policy!r}; one of {sorted(DTYPE_POLICIES)}"
        ) from None


def spec_from_arrays(
    value: jax.Array,
    spatial_shapes: Shapes,
    sampling_locations: jax.Array,
    attention_weights: jax.Array,
    *,
    train: bool = False,
    **overrides: Any,
) -> MsdaSpec:
    """Build the spec for concrete operands (the shim's entry path)."""
    del attention_weights  # shapes implied by loc
    B, S, H, D = value.shape
    Q, P = sampling_locations.shape[1], sampling_locations.shape[4]
    return MsdaSpec(
        spatial_shapes=tuple((int(h), int(w)) for h, w in spatial_shapes),
        num_heads=int(H),
        head_dim=int(D),
        num_points=int(P),
        num_queries=int(Q),
        dtype=str(value.dtype),
        train=train,
        **overrides,
    )


# --------------------------------------------------------------------------
# PlanTuning: the decisions a backend builder receives
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class PlanTuning:
    """Resolved per-plan tuning knobs handed to the backend builder."""

    block_q: Tuple[int, ...]
    onehot_levels: Tuple[bool, ...]
    interpret: bool
    source: str = "heuristic"  # heuristic | autotune | autotune-cache | override
    # per-level committed slab storage dtype; () -> the spec's resolved
    # slab dtype for every level (autotune may mix fp32/bf16 per level)
    slab_dtypes: Tuple[str, ...] = ()
    # committed pyramid-fusion decision: one pallas launch per direction
    # over the fused levels (the fused share of block_q is one shared
    # value, replicated across those levels)
    fuse_levels: bool = False
    # committed fused-prefix length when fuse_levels is set: 0 fuses ALL
    # levels (legacy whole-pyramid fusion), 0 < k < L commits the
    # partial tier — one fused launch over levels [0..k) plus per-level
    # launches for the tail
    fuse_prefix: int = 0
    # committed sparsity decision: 'dense' runs the backend executor
    # unchanged; 'topk' swaps in the pruned top-k gather executor
    sparsity: str = "dense"
    # committed query ordering: 'morton' wraps the executor in the
    # Z-curve permutation (inverted on output); 'identity' leaves it
    query_order: str = "identity"


def _default_slab_dtypes(spec: MsdaSpec) -> Tuple[str, ...]:
    return (spec.resolved_slab_dtype(),) * spec.num_levels


def _resolve_sparsity(spec: MsdaSpec) -> str:
    """Pin/heuristic side of the sparsity rung: only an explicit 'topk'
    commits the lossy executor without a timing race ('auto' stays
    dense until autotune measures a win)."""
    return "topk" if spec.sparsity == "topk" else "dense"


def _resolve_query_order(spec: MsdaSpec) -> str:
    """Pin/heuristic side of the ordering rung: a 'morton' pin engages
    only on eligible (Q == S) geometry — anything else stays identity,
    truthfully recorded in the tuning."""
    from repro.kernels import msda_sparse

    if spec.query_order == "morton" and msda_sparse.morton_eligible(spec):
        return "morton"
    return "identity"


def _apply_sparsity_wrappers(exec_fn: Callable, spec: MsdaSpec,
                             sparsity: str, query_order: str) -> Callable:
    """Commit the resolved sparsity/ordering decisions onto an executor.
    'dense' + 'identity' returns ``exec_fn`` untouched — the
    ``sparsity="off"`` path stays byte-identical to pre-sparsity plans."""
    from repro.kernels import msda_sparse

    if sparsity == "topk":
        exec_fn = msda_sparse.build_topk_exec(spec)
    if query_order == "morton":
        exec_fn = msda_sparse.wrap_query_permutation(
            exec_fn, spec.spatial_shapes)
    return exec_fn


# backends whose builders understand the whole-pyramid fused kernels;
# everyone else gets (truthful) per-level plans regardless of the policy
_FUSABLE_BACKENDS = frozenset({"pallas"})


def _fused_slab_itemsize(slab_dtypes: Tuple[str, ...]) -> int:
    """Widest committed per-level slab itemsize — the per-query working
    set of a fused launch is sized by its widest resident level."""
    return max(jnp.dtype(d).itemsize for d in slab_dtypes)


def _slab_itemsizes(slab_dtypes: Tuple[str, ...]) -> Tuple[int, ...]:
    return tuple(jnp.dtype(d).itemsize for d in slab_dtypes)


def _resolve_fuse_tier(spec: MsdaSpec, slab_dtypes: Tuple[str, ...],
                       backend_name: str) -> Tuple[bool, int]:
    """The planner's fusion rung (heuristic side): ``(fused, prefix)``.

    ``prefix == 0`` means ALL levels (whole-pyramid fusion) when
    ``fused``; ``0 < k < L`` commits the partial-fusion tier (one fused
    launch over levels [0..k) plus a per-level tail).  ``'off'`` and
    non-fusable backends resolve ``(False, 0)``; ``'on'`` pins
    whole-pyramid fusion; ``'prefix:k'`` pins the tier (k >= L
    degenerates to whole-pyramid).  ``'auto'`` plans the prefix from
    the occupancy model (:func:`ops.fusion_prefix`) with the committed
    per-level slab itemsizes: a full fit fuses everything, a strict
    prefix of at least 2 levels commits the tier, anything shorter
    stays per-level — a 1-level fused launch replaces exactly one
    per-level launch, saving nothing.
    """
    from repro.kernels import ops

    if backend_name not in _FUSABLE_BACKENDS or spec.fuse_levels == "off":
        return False, 0
    L = spec.num_levels
    pin = spec.fuse_prefix_pin()
    if pin:
        return (True, 0) if pin >= L else (True, pin)
    if spec.fuse_levels == "on":
        return True, 0
    if L < 2:
        return False, 0
    k = ops.fusion_prefix(
        spec.spatial_shapes, spec.num_points, spec.head_dim,
        value_itemsize=_slab_itemsizes(slab_dtypes),
        train=spec.train, vmem_budget=spec.vmem_budget,
        accum_itemsize=spec.accum_itemsize)
    if k == L:
        return True, 0
    if k >= 2:
        return True, k
    return False, 0


def _tier_block_q(spec: MsdaSpec, slab_dtypes: Tuple[str, ...],
                  prefix: int) -> Tuple[int, ...]:
    """Heuristic block plan for a fusion tier: ONE shared block for the
    fused prefix — planned against the prefix's packed residency and
    replicated across the prefix levels so ``block_q`` keeps one entry
    per level — plus per-level tail blocks at their own itemsizes.
    ``prefix=0`` plans whole-pyramid fusion (no tail)."""
    from repro.kernels import ops

    k = prefix if prefix else spec.num_levels
    items = _slab_itemsizes(slab_dtypes)
    pre = ops.plan_blocks(
        spec.spatial_shapes[:k], spec.num_points, spec.head_dim,
        spec.num_queries, value_itemsize=items[:k], train=spec.train,
        vmem_budget=spec.vmem_budget, adaptive=spec.adaptive_block,
        accum_itemsize=spec.accum_itemsize, fused=True)
    bq = (pre[0],) * k
    for hw, it in zip(spec.spatial_shapes[k:], items[k:]):
        bq += (ops.plan_blocks(
            (hw,), spec.num_points, spec.head_dim, spec.num_queries,
            value_itemsize=it, train=spec.train,
            vmem_budget=spec.vmem_budget, adaptive=spec.adaptive_block,
            accum_itemsize=spec.accum_itemsize)[0],)
    return bq


# --------------------------------------------------------------------------
# built-in backends
# --------------------------------------------------------------------------


@registry.backend("ref")
def _build_ref(spec: MsdaSpec, tuning: PlanTuning) -> Callable:
    """Pure-jnp oracle; tuning is irrelevant (XLA fuses it on its own)."""
    from repro.kernels import ref

    shapes = spec.spatial_shapes

    def run(value, loc, attn):
        return ref.msda_ref(value, shapes, loc, attn)

    return run


@registry.backend("pallas")
def _build_pallas(spec: MsdaSpec, tuning: PlanTuning) -> Callable:
    """xMSDA Pallas kernels with the plan's committed tiling + dtypes."""
    from repro.kernels import ops

    params = ops.MSDAParams(
        spatial_shapes=spec.spatial_shapes,
        block_q=tuple(tuning.block_q),
        fuse_gather=spec.fuse_gather,
        fuse_scatter=spec.fuse_scatter,
        save_sampled=spec.train,
        interpret=tuning.interpret,
        onehot_levels=tuple(tuning.onehot_levels),
        slab_dtypes=tuple(tuning.slab_dtypes) or _default_slab_dtypes(spec),
        accum_dtype=spec.accum_dtype,
        io_dtype=spec.dtype,
        fuse_levels=bool(tuning.fuse_levels),
        fuse_prefix=int(tuning.fuse_prefix),
    )
    return ops.build_kernel_op(params)


@registry.backend("cpu")
def _build_cpu(spec: MsdaSpec, tuning: PlanTuning) -> Callable:
    """CPU-vectorised backend: one vmapped fused gather per level."""
    from repro.kernels import msda_cpu

    return msda_cpu.build_cpu_exec(spec, tuning)


# --------------------------------------------------------------------------
# tuning resolution (heuristic / autotune / override)
# --------------------------------------------------------------------------


def _heuristic_block_q(spec: MsdaSpec, *, fused: bool = False,
                       value_itemsize: Optional[int] = None) -> Tuple[int, ...]:
    from repro.kernels import ops

    return ops.plan_blocks(
        spec.spatial_shapes,
        spec.num_points,
        spec.head_dim,
        spec.num_queries,
        value_itemsize=(spec.slab_itemsize if value_itemsize is None
                        else value_itemsize),
        train=spec.train,
        vmem_budget=spec.vmem_budget,
        adaptive=spec.adaptive_block,
        accum_itemsize=spec.accum_itemsize,
        fused=fused,
    )


def _blocks_for_slab_dtypes(spec: MsdaSpec, slab_dtypes: Tuple[str, ...]) -> Tuple[int, ...]:
    """Heuristic block plan with PER-LEVEL slab itemsizes (a mixed
    fp32/bf16 dtype commitment changes each level's VMEM residency)."""
    from repro.kernels import ops

    out = []
    for hw, sdt in zip(spec.spatial_shapes, slab_dtypes):
        out.append(ops.plan_blocks(
            (hw,),
            spec.num_points,
            spec.head_dim,
            spec.num_queries,
            value_itemsize=jnp.dtype(sdt).itemsize,
            train=spec.train,
            vmem_budget=spec.vmem_budget,
            adaptive=spec.adaptive_block,
            accum_itemsize=spec.accum_itemsize,
        )[0])
    return tuple(out)


def _onehot_levels(spec: MsdaSpec) -> Tuple[bool, ...]:
    from repro.kernels import ops

    if not spec.onehot_small_levels:
        return ()
    return ops.plan_onehot(spec.spatial_shapes)


# process-wide autotune activity counters.  "raced" counts specs whose
# candidates were actually TIMED this process; a serving boot restored
# from a plan store must keep it at zero (the CI smoke job asserts it).
# "raced" counts every timing race; "raced_local" only the per-shard
# block/dtype/onehot/fuse races, "raced_mesh" only the mesh-keyed
# sharding / grad_reduce races — the elastic restore path asserts a
# mesh-resized restart re-races EXACTLY the mesh-keyed axes
# (raced_local == 0) against this split.
_AUTOTUNE_STATS = {
    "raced": _obs.counter("msda.autotune.raced",
                          help="autotune races actually timed"),
    "raced_local": _obs.counter("msda.autotune.raced_local",
                                help="per-shard block/dtype/fuse races"),
    "raced_mesh": _obs.counter("msda.autotune.raced_mesh",
                               help="mesh-keyed sharding/grad_reduce races"),
    "cache_hits": _obs.counter("msda.winner_cache.hits",
                               help="on-disk autotune winner-cache hits"),
    "seeded": _obs.counter("msda.winner_cache.seeded",
                           help="winners installed without racing"),
}
# the winner-cache flip side: a consulted entry that was absent or
# unparseable (-> a timing race follows).  Not part of the historical
# autotune_stats() shape; read it via execution_telemetry().
_WINNER_CACHE_MISSES = _obs.counter(
    "msda.winner_cache.misses",
    help="on-disk winner lookups that found no usable entry")

# plan-execution telemetry: every MsdaPlan.__call__ whose Python body
# runs (eagerly, or once per jit trace / AOT boot compile) attributes
# its STATIC per-call launch schedule here — a zero-retrace serving
# steady state therefore adds zero, which is the invariant the smoke
# job audits.  Train plans attribute fwd+bwd together (the backward is
# wired into the same custom-VJP call).
_PLAN_CALLS = _obs.counter(
    "msda.plan_calls", help="MsdaPlan invocations (eager or traced)")
_LAUNCHES = _obs.counter(
    "msda.launches",
    help="Pallas launches attributed per direction "
         "(static schedule x plan invocations)")
_VMEM_GAUGE = _obs.gauge(
    "msda.vmem_frac",
    help="per-level VMEM occupancy of the most recently built plan "
         "(kind=committed|predicted)")


def autotune_stats() -> Dict[str, int]:
    return {k: int(c.value()) for k, c in _AUTOTUNE_STATS.items()}


def reset_autotune_stats() -> None:
    for c in _AUTOTUNE_STATS.values():
        c.reset()
    _WINNER_CACHE_MISSES.reset()


def autotune_cache_path() -> str:
    """On-disk winner cache (override via REPRO_MSDA_AUTOTUNE_CACHE)."""
    env = os.environ.get("REPRO_MSDA_AUTOTUNE_CACHE")
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME", os.path.expanduser("~/.cache"))
    return os.path.join(base, "repro", "msda_autotune.json")


def _load_autotune_cache() -> Dict[str, Any]:
    path = autotune_cache_path()
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _store_autotune_cache(cache: Dict[str, Any]) -> None:
    path = autotune_cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS: autotune still works, winners just aren't kept


def _autotune_inputs(spec: MsdaSpec, batch: int = 1):
    """Deterministic synthetic operands at the spec's exact geometry.

    All three operands honour ``spec.dtype``: timing a bf16 spec with
    fp32 operands would trace (and cache a winner for) a *different*
    program than the one real calls execute — the casts, slab residency
    and gather widths all change with the operand dtype.

    ``batch``: the sharding race times full shard_mapped executors, and
    the 1D candidate shards batch over dp — so it asks for B = dp_size.
    """
    B = batch
    S, H, D = spec.total_pixels, spec.num_heads, spec.head_dim
    Q, L, P = spec.num_queries, spec.num_levels, spec.num_points
    dt = jnp.dtype(spec.dtype)
    value = jnp.linspace(-1.0, 1.0, B * S * H * D, dtype=jnp.float32)
    value = value.reshape(B, S, H, D).astype(dt)
    loc = jnp.linspace(0.05, 0.95, B * Q * H * L * P * 2, dtype=jnp.float32)
    loc = loc.reshape(B, Q, H, L, P, 2).astype(dt)
    attn = jnp.full((B, Q, H, L, P), 1.0 / (L * P), jnp.float32).astype(dt)
    return value, loc, attn


# a candidate must win the interleaved median by this relative margin to
# replace the incumbent — sub-noise deltas must not get PERSISTED into the
# per-device winner cache (shared runners drift 2-3x between sequential
# timing blocks; interleaving cancels most of it, the margin eats the rest)
_AUTOTUNE_MARGIN = 0.05


def _time_executors(fns: Dict[Any, Callable], args, iters: int = 3) -> Dict[Any, float]:
    """Median seconds/call per candidate, measured ALTERNATELY.

    ``fns`` values must already be jitted + warmed.  Interleaving puts
    every candidate under the same machine-load profile, so the medians
    stay comparable — sequential per-candidate blocks let load drift
    masquerade as a tuning delta.
    """
    times: Dict[Any, List[float]] = {k: [] for k in fns}
    for _ in range(iters):
        for k, f in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f(*args))
            times[k].append(time.perf_counter() - t0)
    return {k: sorted(ts)[len(ts) // 2] for k, ts in times.items()}


# backends whose executors ignore block_q (nothing to race on that axis)
_BLOCKLESS_BACKENDS = frozenset({"ref", "cpu"})

# the two slab dtypes autotune races per level under the 'auto' policy
_SLAB_DTYPE_CANDIDATES = ("float32", "bfloat16")


# every field the winner-entry schema knows how to validate; anything
# else a cache entry carries was written by a NEWER build and must ride
# through this build's parse/rewrite cycle untouched (the "extras" dict)
_WINNER_FIELDS = ("block_q", "slab_dtypes", "sharding", "onehot_levels",
                  "fuse_levels", "fuse_prefix", "grad_reduce", "sparsity",
                  "query_order")


def _parse_cache_entry(hit, spec: MsdaSpec) -> Optional[Dict[str, Any]]:
    """Decode a winner-cache entry into the normalised winner dict.

    Returns ``{"block_q": tuple, "slab_dtypes": tuple, "sharding":
    None|'1d'|'2d'|'hybrid', "onehot_levels": None|tuple, "fuse_levels":
    None|bool, "grad_reduce": None|'ring'|'psum', "sparsity":
    None|'dense'|'topk', "query_order": None|'identity'|'morton',
    "extras": dict}`` or ``None`` on a miss.  The
    ``sharding``/``grad_reduce`` fields live on mesh-keyed entries (the
    1D-vs-2D and ring-vs-psum races of distributed plans);
    ``fuse_levels`` records the whole-pyramid fusion race;
    ``onehot_levels`` the per-level MXU-routing race; ``sparsity`` /
    ``query_order`` the pruned-vs-dense and Morton-vs-identity races;
    ``fuse_prefix`` the partial-fusion tier a fused winner committed
    (absent on whole-pyramid winners, so pre-tier entries mean "fuse
    everything" exactly as they always did).
    All are OPTIONAL, so every pre-existing entry still parses with
    ``None`` there.  Keys this build does NOT know land in ``extras``
    verbatim and :func:`_winner_entry` writes them back — a field
    persisted by a newer build survives an older build re-persisting
    the entry instead of being silently erased.  A flat ``[block_q...]``
    list is accepted for hand-authored caches (offline sweep tooling /
    the pre-dtype-policy format).  Anything malformed is treated as a
    miss, never an error: a corrupt cache file must degrade to
    re-tuning.
    """
    L = spec.num_levels

    def _out(bq, dts, sharding=None, onehot=None, fused=None, gr=None,
             sparsity=None, query_order=None, extras=None, fuse_prefix=None):
        return {"block_q": bq, "slab_dtypes": dts, "sharding": sharding,
                "onehot_levels": onehot, "fuse_levels": fused,
                "fuse_prefix": fuse_prefix,
                "grad_reduce": gr, "sparsity": sparsity,
                "query_order": query_order, "extras": dict(extras or {})}

    try:
        if isinstance(hit, list) and len(hit) == L:
            return _out(tuple(int(b) for b in hit), _default_slab_dtypes(spec))
        if isinstance(hit, dict):
            bq = hit.get("block_q")
            dts = hit.get("slab_dtypes")
            sharding = hit.get("sharding")
            if sharding is not None and sharding not in ("1d", "2d", "hybrid"):
                return None
            gr = hit.get("grad_reduce")
            if gr is not None and gr not in ("ring", "psum"):
                return None
            sparsity = hit.get("sparsity")
            if sparsity is not None and sparsity not in ("dense", "topk"):
                return None
            qorder = hit.get("query_order")
            if qorder is not None and qorder not in ("identity", "morton"):
                return None
            if not (isinstance(bq, list) and len(bq) == L):
                return None
            if not (isinstance(dts, list) and len(dts) == L):
                dts = _default_slab_dtypes(spec)
            dts = tuple(str(jnp.dtype(d)) for d in dts)
            onehot = hit.get("onehot_levels")
            if onehot is not None:
                if not (isinstance(onehot, list) and len(onehot) == L):
                    return None
                onehot = tuple(bool(x) for x in onehot)
            fused = hit.get("fuse_levels")
            if fused is not None:
                fused = bool(fused)
            fp = hit.get("fuse_prefix")
            if fp is not None:
                fp = int(fp)
                if fp < 0:
                    return None
            extras = {k: v for k, v in hit.items() if k not in _WINNER_FIELDS}
            return _out(tuple(int(b) for b in bq), dts, sharding, onehot,
                        fused, gr, sparsity, qorder, extras, fp)
    except (TypeError, ValueError):  # hand-edited / corrupted entries
        return None
    return None


def mesh_token_from(axes, shape) -> str:
    """'data2xmodel2'-style token from bare (axis names, shape) tuples."""
    return "x".join(f"{a}{s}" for a, s in zip(axes, shape))


def mesh_token(mesh) -> str:
    """Stable 'data2xmodel2'-style token for a mesh's (axes, shape).

    The canonical mesh name wherever device objects can't travel: the
    winner-cache key suffix for distributed plans, the plan store's
    sharded entries, and the serving store meta gate.  Deliberately
    ignores device *ids* — a winner tuned on one 2x2 slice applies to
    any other 2x2 slice of the same part.
    """
    return mesh_token_from(mesh.axis_names, mesh.devices.shape)


def mesh_winner_suffix(mesh, query_parallel: bool) -> str:
    """Winner-cache key suffix for (mesh topology, query-parallel flag) —
    the two inputs besides the spec that change which sharding modes are
    even legal to race."""
    return f"mesh[{mesh_token(mesh)}]|qp{int(bool(query_parallel))}"


def autotune_winner_key(spec: MsdaSpec, backend: str,
                        device_kind: Optional[str] = None,
                        mesh_suffix: Optional[str] = None) -> str:
    """The on-disk winner-cache key for (device kind, backend, spec).

    ``mesh_suffix`` (see :func:`mesh_winner_suffix`) keys the
    *distributed* winner — the 1D-vs-2D sharding race — separately from
    the local block/dtype winner of the same spec.
    """
    if device_kind is None:
        device_kind = jax.devices()[0].device_kind
    key = f"{device_kind}|{registry.resolve_backend(backend)}|{spec.cache_token()}"
    if mesh_suffix:
        key += f"|{mesh_suffix}"
    return key


def get_autotune_winner(spec: MsdaSpec, backend: str,
                        device_kind: Optional[str] = None,
                        mesh_suffix: Optional[str] = None) -> Optional[Dict[str, Any]]:
    """Read (and normalise) the persisted winner for a spec, or None."""
    hit = _load_autotune_cache().get(
        autotune_winner_key(spec, backend, device_kind, mesh_suffix))
    parsed = _parse_cache_entry(hit, spec)
    if parsed is None:
        return None
    return _winner_entry(parsed)


def _winner_entry(parsed: Dict[str, Any]) -> Dict[str, Any]:
    """Parsed winner dict -> the JSON entry shape (optional fields only
    when present — old schemas round-trip unchanged; unknown keys a
    newer build persisted ride through via ``extras``)."""
    out = {"block_q": [int(b) for b in parsed["block_q"]],
           "slab_dtypes": list(parsed["slab_dtypes"])}
    if parsed.get("sharding") is not None:
        out["sharding"] = parsed["sharding"]
    if parsed.get("onehot_levels") is not None:
        out["onehot_levels"] = [bool(x) for x in parsed["onehot_levels"]]
    if parsed.get("fuse_levels") is not None:
        out["fuse_levels"] = bool(parsed["fuse_levels"])
    if parsed.get("fuse_prefix"):  # only a committed STRICT tier is written
        out["fuse_prefix"] = int(parsed["fuse_prefix"])
    if parsed.get("grad_reduce") is not None:
        out["grad_reduce"] = parsed["grad_reduce"]
    if parsed.get("sparsity") is not None:
        out["sparsity"] = parsed["sparsity"]
    if parsed.get("query_order") is not None:
        out["query_order"] = parsed["query_order"]
    for k, v in (parsed.get("extras") or {}).items():
        if k not in _WINNER_FIELDS:
            out[k] = v
    return out


def seed_autotune_winners(entries, device_kind: Optional[str] = None) -> int:
    """Install winners into the on-disk cache WITHOUT racing (batch).

    ``entries``: iterable of ``(spec, backend, winner)`` or ``(spec,
    backend, winner, mesh_suffix)`` — the 4-tuple form seeds the
    mesh-keyed 1D-vs-2D sharding winner of a distributed plan (see
    :func:`mesh_winner_suffix`).  The restore path of the serving plan
    store and the offline sweep CLI use this to pre-populate the cache a
    fleet (or a restarted server) reads, so ``tune="autotune"`` resolves
    to ``autotune-cache`` with zero timing runs.  One cache read + one
    atomic write for the whole batch.  Each winner is validated with the
    same parser the cache reader uses; malformed winners are skipped
    (returns the number actually written) rather than written where they
    would poison future boots.
    """
    disk = _load_autotune_cache()
    n = 0
    for entry in entries:
        spec, backend, winner = entry[:3]
        mesh_suffix = entry[3] if len(entry) > 3 else None
        parsed = _parse_cache_entry(winner, spec)
        if parsed is None:
            continue
        if not mesh_suffix:  # sharding/grad_reduce live on mesh-keyed entries
            parsed = dict(parsed, sharding=None, grad_reduce=None)
        stored = _winner_entry(parsed)
        disk[autotune_winner_key(spec, backend, device_kind, mesh_suffix)] = stored
        n += 1
    if n:
        _store_autotune_cache(disk)
        _AUTOTUNE_STATS["seeded"].inc(n)
    return n


def seed_autotune_winner(spec: MsdaSpec, backend: str, winner: Any,
                         device_kind: Optional[str] = None) -> bool:
    """Single-entry convenience over :func:`seed_autotune_winners`."""
    return seed_autotune_winners([(spec, backend, winner)], device_kind) == 1


@_obs_trace.traced_span("autotune.race", level=3)
def _autotune_plan(
    spec: MsdaSpec, backend_name: str, builder: Callable, interpret: bool
) -> Tuple[Tuple[int, ...], Tuple[str, ...], Tuple[bool, ...], bool, int,
           str, str, str]:
    """Measure candidate plans; persist the winner per (device, spec).

    Six raced axes:

    * ``block_q`` — the heuristic plan scaled by {1/2, 1, 2} per level
      (uniformly — the per-level cross product explodes), snapped to the
      sublane multiple.  Skipped for blockless backends ("cpu").
    * slab dtype — under the ``slab_dtype="auto"`` policy, fp32 vs bf16
      is raced PER LEVEL (greedy marginal flips on the block winner): a
      bf16 slab halves VMEM residency but pays cast/precision overhead,
      and which side wins is level-size- and backend-dependent.
    * MXU one-hot routing — under ``onehot_small_levels=True``, the
      static ``ONEHOT_MAX_ROWS`` threshold is only the STARTING point:
      each level's routing is raced with greedy flips, so a level moves
      between the VPU gather and the MXU matmul on measurement, not on a
      hand-picked row count.
    * pyramid fusion tiers — under ``fuse_levels="auto"``, the
      whole-pyramid fused plan (its own shared block, packed
      super-slab) AND the occupancy model's partial tier (fused prefix
      [0..k) + per-level tail, when the model proposes a strict one)
      race the per-level incumbent three ways.  **Train specs time
      forward + full VJP**: fusion changes the backward's launch count
      and gout re-streaming, so a forward-only race would crown the
      wrong side for training.
    * top-k point pruning — under ``sparsity="auto"``, the pruned
      executor (4k corner gathers per query instead of 4LP, LOSSY —
      see ``kernels/msda_sparse.py``) races the committed dense winner;
      timed fwd+VJP for train specs.  The heuristic never picks it:
      lossy plans only come from an explicit pin or a measured win.
    * Morton query ordering — under ``query_order="auto"`` on eligible
      (Q == S) geometry, the Z-curve-permuted executor races identity.
      The permutation is bitwise-neutral to outputs, so this race is
      purely about gather locality vs permute overhead.

    All timings are interleaved medians (see :func:`_time_executors`)
    and a challenger must beat the incumbent by ``_AUTOTUNE_MARGIN`` —
    load jitter must never pick a winner.

    Winners ``{"block_q", "slab_dtypes"}`` (+ optional ``onehot_levels``
    / ``fuse_levels`` / ``fuse_prefix`` / ``sparsity`` /
    ``query_order``) are keyed by spec + device kind so a cache
    produced on one part never mis-tunes another.  Returns ``(block_q,
    slab_dtypes, onehot_levels, fuse_levels, fuse_prefix, sparsity,
    query_order, source)``.
    """
    from repro.kernels import msda_sparse

    onehot = _onehot_levels(spec)
    heur = _heuristic_block_q(spec)
    base_dts = _default_slab_dtypes(spec)
    fusable = backend_name in _FUSABLE_BACKENDS
    key = autotune_winner_key(spec, backend_name)
    disk = _load_autotune_cache()
    # an 'on' / 'prefix:k' pin fixes the tier; only 'auto' races it
    pinned_tier = spec.fuse_levels == "on" or spec.fuse_prefix_pin() > 0
    pin_fused, pin_prefix = (_resolve_fuse_tier(spec, base_dts, backend_name)
                             if pinned_tier else (False, 0))
    parsed = _parse_cache_entry(disk.get(key), spec)
    if parsed is None:
        _WINNER_CACHE_MISSES.inc()
    if parsed is not None:
        _AUTOTUNE_STATS["cache_hits"].inc()
        oh = parsed["onehot_levels"] if parsed["onehot_levels"] is not None else onehot
        # entries without the field (hand-authored / pre-fusion schema)
        # must not override an explicit 'on'/'prefix:k' pin
        if parsed["fuse_levels"] is not None:
            fused = bool(parsed["fuse_levels"])
            # pre-tier fused entries carry no prefix: whole-pyramid,
            # exactly what they committed when written
            prefix = int(parsed["fuse_prefix"] or 0) if fused else 0
            if prefix >= spec.num_levels:
                prefix = 0
        else:
            fused, prefix = pin_fused, pin_prefix
        # field-less entries (older schema) resolve the sparsity rungs
        # the way a pin/heuristic would — never surprise-lossy
        sp = (parsed["sparsity"] if parsed["sparsity"] is not None
              else _resolve_sparsity(spec))
        qo = (parsed["query_order"] if parsed["query_order"] is not None
              else _resolve_query_order(spec))
        if qo == "morton" and not msda_sparse.morton_eligible(spec):
            qo = "identity"  # entry from a differently-shaped past: ignore
        return (parsed["block_q"], parsed["slab_dtypes"], oh, fused, prefix,
                sp, qo, "autotune-cache")

    qcap = _round_up(spec.num_queries, _SUBLANE)
    race_fuse = fusable and spec.fuse_levels == "auto" and spec.num_levels >= 2
    race_sparsity = spec.sparsity == "auto"
    race_qorder = (spec.query_order == "auto"
                   and msda_sparse.morton_eligible(spec))
    candidates = []
    if backend_name not in _BLOCKLESS_BACKENDS:
        # pin_fused: the only plan family is the pinned tier, so the
        # block race scales ITS geometry (shared prefix block + tail
        # blocks) instead of the per-level ones
        base_bq = (_tier_block_q(spec, base_dts, pin_prefix)
                   if pin_fused else heur)
        for scale_num, scale_den in ((1, 2), (1, 1), (2, 1)):
            cand = tuple(
                max(_SUBLANE, min(2048, qcap, (b * scale_num // scale_den) // _SUBLANE * _SUBLANE))
                for b in base_bq
            )
            if cand not in candidates:
                candidates.append(cand)
    else:
        candidates.append(heur)
    race_dtypes = spec.slab_dtype == "auto"
    race_onehot = bool(onehot) and backend_name not in _BLOCKLESS_BACKENDS
    if len(candidates) == 1 and not (race_dtypes or race_onehot or race_fuse
                                     or race_sparsity or race_qorder):
        return (candidates[0], base_dts, onehot, pin_fused, pin_prefix,
                _resolve_sparsity(spec), _resolve_query_order(spec),
                "autotune")

    _AUTOTUNE_STATS["raced"].inc()
    _AUTOTUNE_STATS["raced_local"].inc()
    args = _autotune_inputs(spec)
    jit_cache: Dict[tuple, Callable] = {}

    def get_fn(bq, dts, oh=None, fused=None, prefix=None, timed="fwd"):
        """Jitted + warmed executor for one candidate, cached so incumbent
        re-appearances across race rounds never recompile.  ``timed``:
        'fwd' times the forward, 'train' times forward + full VJP."""
        oh = onehot if oh is None else oh
        fused = pin_fused if fused is None else fused
        prefix = pin_prefix if prefix is None else prefix
        ck = (bq, dts, oh, fused, prefix, timed)
        if ck not in jit_cache:
            tuning = PlanTuning(block_q=bq, onehot_levels=oh,
                                interpret=interpret, source="autotune",
                                slab_dtypes=dts, fuse_levels=fused,
                                fuse_prefix=prefix)
            exec_fn = builder(spec, tuning)
            if timed == "train":
                f = jax.jit(jax.grad(
                    lambda v, l, a, e=exec_fn: jnp.sum(e(v, l, a)),
                    argnums=(0, 1, 2)))
            else:
                f = jax.jit(exec_fn)
            jax.block_until_ready(f(*args))  # compile + warm (may raise)
            jit_cache[ck] = f
        return jit_cache[ck]

    def race(variants: Dict[Any, tuple], timed="fwd"):
        """Interleave-time variants {key: (bq, dts[, oh[, fused[,
        prefix]]])}; unbuildable candidates drop out."""
        fns = {}
        for k, v in variants.items():
            try:
                fns[k] = get_fn(*v, timed=timed)
            except Exception:
                continue  # candidate doesn't fit/compile: skip
        if not fns:
            return None, {}
        times = _time_executors(fns, args)
        return min(times, key=times.get), times

    bkey, _ = race({c: (c, base_dts) for c in candidates})
    if bkey is None:
        # every candidate failed to build: fall back to the heuristic and
        # do NOT persist — a never-validated plan must not poison the
        # per-device winner cache for future processes
        return (heur, base_dts, onehot, False, 0, _resolve_sparsity(spec),
                _resolve_query_order(spec), "heuristic")
    best = bkey

    best_dts = base_dts
    if race_dtypes:
        # greedy per-level flips against the committed block winner; each
        # round re-times incumbent vs challenger INTERLEAVED and the flip
        # must clear the noise margin, so a level goes bf16 only when its
        # marginal saving genuinely beats its cast cost end-to-end
        wide, narrow = (str(jnp.dtype(d)) for d in _SLAB_DTYPE_CANDIDATES)
        current = (wide,) * spec.num_levels
        # per-level flips even under a fused pin: the packed super-slab
        # keeps each level's committed dtype (carrier-coded when they
        # mix — see ops.packed_pyramid_layout), so a bf16-winner level
        # keeps its residency win inside the fused launch
        for ls in [(l,) for l in range(spec.num_levels)]:
            trial = tuple(narrow if l in ls else d
                          for l, d in enumerate(current))
            k, times = race({"cur": (best, current), "trial": (best, trial)})
            if (k == "trial"
                    and times["trial"] < times["cur"] * (1 - _AUTOTUNE_MARGIN)):
                current = trial
        best_dts = current
        if best_dts != base_dts and backend_name not in _BLOCKLESS_BACKENDS:
            # flipped levels halved their residency: re-plan blocks with
            # the committed itemsizes (the 'bf16 frees VMEM -> wider
            # vec-len' payoff — per-level itemsizes, or the pinned
            # tier's packed residency) and keep the clear winner
            rebq = (_tier_block_q(spec, best_dts, pin_prefix)
                    if pin_fused else _blocks_for_slab_dtypes(spec, best_dts))
            if rebq != best:
                k, times = race({"cur": (best, best_dts), "re": (rebq, best_dts)})
                if (k == "re"
                        and times["re"] < times["cur"] * (1 - _AUTOTUNE_MARGIN)):
                    best = rebq

    best_onehot = onehot
    if race_onehot:
        # greedy per-level routing flips from the static-threshold start:
        # the ONEHOT_MAX_ROWS heuristic proposes, the race disposes.
        # Train specs time fwd+VJP — the routing also picks the
        # backward's scatter path (onehot_scatter), where it matters most
        timed = "train" if spec.train else "fwd"
        current = onehot
        for l in range(spec.num_levels):
            trial = current[:l] + (not current[l],) + current[l + 1:]
            k, times = race({"cur": (best, best_dts, current),
                             "trial": (best, best_dts, trial)}, timed=timed)
            if (k == "trial"
                    and times["trial"] < times["cur"] * (1 - _AUTOTUNE_MARGIN)):
                current = trial
        best_onehot = current

    best_fused, best_prefix = pin_fused, pin_prefix
    if race_fuse:
        # fusion-tier race, three ways: the per-level incumbent, the
        # whole-pyramid fused challenger, and — when the occupancy
        # model proposes a strict prefix — the partial tier at the
        # model's k.  Every challenger runs at its OWN geometry (shared
        # prefix block planned against the packed residency, per-level
        # tail blocks) with the COMMITTED per-level slab dtypes (the
        # carrier-coded super-slab keeps mixed commitments).  Timed
        # fwd+VJP for train specs — the backward is where fusion
        # changes launch count and gout streaming the most.
        from repro.kernels import ops

        k_model = ops.fusion_prefix(
            spec.spatial_shapes, spec.num_points, spec.head_dim,
            value_itemsize=_slab_itemsizes(best_dts),
            train=spec.train, vmem_budget=spec.vmem_budget,
            accum_itemsize=spec.accum_itemsize)
        timed = "train" if spec.train else "fwd"
        full_bq = _tier_block_q(spec, best_dts, 0)
        tier_bqs = {"fused": (full_bq, 0)}
        variants = {"per-level": (best, best_dts, best_onehot, False, 0),
                    "fused": (full_bq, best_dts, best_onehot, True, 0)}
        if 2 <= k_model < spec.num_levels:
            pre_bq = _tier_block_q(spec, best_dts, k_model)
            tier_bqs["prefix"] = (pre_bq, k_model)
            variants["prefix"] = (pre_bq, best_dts, best_onehot, True, k_model)
        k, times = race(variants, timed=timed)
        if k is not None:
            challengers = {n: t for n, t in times.items() if n != "per-level"}
            if challengers:
                champ = min(challengers, key=challengers.get)
                inc = times.get("per-level")
                # per-level stays incumbent: a fused tier wins only by
                # clearing the noise margin (or when per-level itself
                # failed to build)
                if (inc is None
                        or challengers[champ] < inc * (1 - _AUTOTUNE_MARGIN)):
                    best, best_prefix = tier_bqs[champ]
                    best_fused = True

    def _warm(exec_fn, timed):
        """Jit + warm an executor built OUTSIDE the (bq, dts, ...) tuning
        space (the pruned / permuted challengers); may raise."""
        if timed == "train":
            f = jax.jit(jax.grad(
                lambda v, l, a, e=exec_fn: jnp.sum(e(v, l, a)),
                argnums=(0, 1, 2)))
        else:
            f = jax.jit(exec_fn)
        jax.block_until_ready(f(*args))
        return f

    best_sparsity = _resolve_sparsity(spec)
    if race_sparsity:
        # pruned challenger vs the fully committed dense winner; the
        # dense side stays the incumbent (lossy never wins on jitter).
        # Timed fwd+VJP for train specs — pruning shrinks the backward's
        # scatter set as much as the forward's gather set.
        timed = "train" if spec.train else "fwd"
        try:
            fns = {
                "dense": get_fn(best, best_dts, best_onehot, best_fused,
                                best_prefix, timed=timed),
                "topk": _warm(msda_sparse.build_topk_exec(spec), timed),
            }
            times = _time_executors(fns, args)
            if times["topk"] < times["dense"] * (1 - _AUTOTUNE_MARGIN):
                best_sparsity = "topk"
            else:
                best_sparsity = "dense"
        except Exception:
            best_sparsity = "dense"  # challenger didn't build: stay dense

    best_qorder = _resolve_query_order(spec)
    if race_qorder:
        # Morton permutation around whatever executor the sparsity rung
        # just committed — the permutation's locality payoff (and its
        # permute overhead) must be measured on the plan that will run
        timed = "train" if spec.train else "fwd"
        try:
            if best_sparsity == "topk":
                base_exec = msda_sparse.build_topk_exec(spec)
            else:
                base_exec = builder(spec, PlanTuning(
                    block_q=best, onehot_levels=best_onehot,
                    interpret=interpret, source="autotune",
                    slab_dtypes=best_dts, fuse_levels=best_fused,
                    fuse_prefix=best_prefix))
            wrapped = msda_sparse.wrap_query_permutation(
                base_exec, spec.spatial_shapes)
            fns = {"identity": _warm(base_exec, timed),
                   "morton": _warm(wrapped, timed)}
            times = _time_executors(fns, args)
            if times["morton"] < times["identity"] * (1 - _AUTOTUNE_MARGIN):
                best_qorder = "morton"
            else:
                best_qorder = "identity"
        except Exception:
            best_qorder = "identity"

    parsed = {"block_q": best, "slab_dtypes": best_dts,
              "sharding": None, "grad_reduce": None,
              "onehot_levels": best_onehot if race_onehot else None,
              "fuse_levels": best_fused if fusable else None,
              # only a committed STRICT tier persists the field — full-
              # fusion / per-level winners stay byte-identical to the
              # pre-tier entry schema
              "fuse_prefix": (best_prefix
                              if fusable and best_fused and best_prefix
                              else None),
              "sparsity": best_sparsity if race_sparsity else None,
              "query_order": best_qorder if race_qorder else None,
              "extras": {}}
    disk[key] = _winner_entry(parsed)
    _store_autotune_cache(disk)
    return (best, best_dts, best_onehot, best_fused, best_prefix,
            best_sparsity, best_qorder, "autotune")


@_obs_trace.traced_span("autotune.race_sharding", level=3)
def _autotune_sharding(spec: MsdaSpec, backend_name: str, mesh,
                       query_parallel: bool, grad_reduce: str,
                       build_local: Callable):
    """Race the 1D ladder vs the 2D (dp x tp) — and, where the 1D rung
    degenerates to batch-only, the hybrid batch x query — modes.

    Returns ``(choice, built)`` where ``choice`` is ``'1d' | '2d' |
    'hybrid'`` and ``built`` is the winner's already-constructed
    ``(sharded_exec, tuning, resolution)`` — or None on a cache hit /
    degenerate race — so the caller never rebuilds what the race just
    built.

    The sharding mode joined the autotune space in the same spirit as
    block_q and the slab dtypes: which side wins is geometry- and
    topology-dependent (2D buys a dp_size-wider query fan-out but pays
    value replication over dp plus the dp-psum leg of the grad
    reduction; hybrid trades batch ways for query ways on tp-less
    meshes), so under ``tune="autotune"`` + ``sharding="auto"`` the
    full sharded executors are built — each at its OWN tuned local
    geometry, the nested block/dtype races caching per local spec as
    usual — and timed interleaved on synthetic operands at the GLOBAL
    geometry.  **Train specs time forward + backward**: the modes
    differ mostly in backward cost (the grad_value reduction), so a
    forward-only race would crown the wrong mode for training.  The
    winner persists in the standard winner-cache schema grown by a
    ``"sharding"`` field (old entries parse unchanged), keyed by
    (device kind, backend, spec, mesh topology, qp flag) so a 2x2
    winner never mis-tunes a 1x4 mesh.  The hybrid challenger only
    joins when the 1D rung resolved to batch/replicated (a trivial tp
    axis): on meshes where the ladder already tiles queries the hybrid
    tiling is redundant, and racing it would only add jitter.
    """
    from repro.sharding import rules

    r1 = _plan_sharding(spec, mesh, query_parallel, "1d")
    cands: List[tuple] = [("1d", r1)]
    r2 = _plan_sharding(spec, mesh, query_parallel, "2d")
    if r2[0] == "query2d":
        cands.append(("2d", r2))
    rh = _plan_sharding(spec, mesh, query_parallel, "hybrid")
    if rh[0] == "batchquery" and r1[0] in ("batch", "replicated"):
        cands.append(("hybrid", rh))
    if len(cands) == 1:
        return "1d", None  # no challenger on this (spec, mesh)
    key = autotune_winner_key(
        spec, backend_name, mesh_suffix=mesh_winner_suffix(mesh, query_parallel))
    disk = _load_autotune_cache()
    parsed = _parse_cache_entry(disk.get(key), spec)
    if parsed is None or parsed["sharding"] not in ("1d", "2d", "hybrid"):
        _WINNER_CACHE_MISSES.inc()
    if parsed is not None and parsed["sharding"] in ("1d", "2d", "hybrid"):
        _AUTOTUNE_STATS["cache_hits"].inc()
        return parsed["sharding"], None

    _AUTOTUNE_STATS["raced"].inc()
    _AUTOTUNE_STATS["raced_mesh"].inc()
    # batch must divide dp for the 1D candidate (dp shards batch there)
    batch = rules.axis_size(rules.resolve_axis("dp", mesh), mesh)
    if any(n == "hybrid" for n, _ in cands):
        # ... and the hybrid tile for its candidate (lcm keeps both legal)
        bt = HYBRID_BATCH_TILE
        batch = batch * bt // math.gcd(batch, bt)
    args = _autotune_inputs(spec, batch=batch)
    fns: Dict[str, Callable] = {}
    built: Dict[str, tuple] = {}
    for name, r in cands:
        mode, dp, tp, tp_size, local = r
        try:
            inner_exec, tuning = build_local(local)
            exec_fn = _build_sharded_exec(
                spec, inner_exec, local, mesh, mode, dp, tp, tp_size,
                grad_reduce)
            if spec.train:
                # time what training executes: fwd + full VJP (the
                # ring/psum grad_value legs live in the backward)
                f = jax.jit(jax.grad(
                    lambda v, l, a, e=exec_fn: jnp.sum(e(v, l, a)),
                    argnums=(0, 1, 2)))
            else:
                f = jax.jit(exec_fn)
            jax.block_until_ready(f(*args))  # compile + warm (may raise)
            fns[name] = f
            built[name] = (exec_fn, tuning, r, inner_exec)
        except Exception:
            continue  # candidate doesn't build on this mesh: skip
    if not fns:
        return "1d", None  # nothing raced: fall back, persist nothing
    if len(fns) < 2:
        # lone survivor: use it for THIS process but do NOT persist — a
        # transient compile failure on the other candidate must not
        # become a permanent (never re-raced) fleet-wide tuning decision
        winner = next(iter(fns))
        return winner, built[winner]
    times = _time_executors(fns, args)
    # the incumbent is the 1D ladder; a challenger must clear the margin
    winner = "1d"
    if "1d" in times:
        best = min((n for n in times if n != "1d"), key=times.get)
        if times[best] < times["1d"] * (1 - _AUTOTUNE_MARGIN):
            winner = best
    else:
        winner = min(times, key=times.get)
    t = built[winner][1]
    disk = _load_autotune_cache()
    disk[key] = _winner_entry({
        "block_q": t.block_q,
        "slab_dtypes": t.slab_dtypes or _default_slab_dtypes(spec),
        "sharding": winner,
        "onehot_levels": None,
        "fuse_levels": (t.fuse_levels
                        if backend_name in _FUSABLE_BACKENDS else None),
        "fuse_prefix": (t.fuse_prefix
                        if (backend_name in _FUSABLE_BACKENDS
                            and t.fuse_levels and t.fuse_prefix) else None),
        "grad_reduce": None})
    _store_autotune_cache(disk)
    return winner, built[winner]


@_obs_trace.traced_span("autotune.race_grad_reduce", level=3)
def _autotune_grad_reduce(spec: MsdaSpec, backend_name: str, mesh,
                          query_parallel: bool, mode: str, dp, tp,
                          tp_size: int, inner_exec: Callable,
                          local_spec: MsdaSpec, tuning: "PlanTuning"):
    """Race the grad_value reduction (ring vs psum) per mesh topology.

    The roadmap's distribution follow-up: whether the ppermute ring or
    the monolithic psum wins the query-sharded backward's tp-axis
    grad_value reduction is topology-dependent (on DCN-crossing meshes
    the single collective can win; on ICI rings the chunked circulation
    does) — so under ``tune="autotune"`` + ``grad_reduce="auto"`` the
    two legs are raced the way the sharding mode is: both sharded
    executors share the SAME inner (unsharded) executor and differ only
    in the collective, timings are full fwd+VJP (the legs only exist in
    the backward), and the winner persists in the mesh-keyed winner
    entry's optional ``"grad_reduce"`` field alongside ``"sharding"``.

    Returns ``(choice, exec_fn_or_None)`` — the winner's built sharded
    executor when the race ran, ``None`` on a cache hit (the caller
    rebuilds; wiring a shard_map is cheap).  Only called for train
    specs: inference plans never run the backward, so 'auto' stays ring.
    """
    key = autotune_winner_key(
        spec, backend_name, mesh_suffix=mesh_winner_suffix(mesh, query_parallel))
    disk = _load_autotune_cache()
    parsed = _parse_cache_entry(disk.get(key), spec)
    if parsed is None or parsed["grad_reduce"] not in ("ring", "psum"):
        _WINNER_CACHE_MISSES.inc()
    if parsed is not None and parsed["grad_reduce"] in ("ring", "psum"):
        _AUTOTUNE_STATS["cache_hits"].inc()
        return parsed["grad_reduce"], None

    from repro.sharding import rules

    _AUTOTUNE_STATS["raced"].inc()
    _AUTOTUNE_STATS["raced_mesh"].inc()
    batch = rules.axis_size(rules.resolve_axis("dp", mesh), mesh)
    if mode == "batchquery":
        bt = HYBRID_BATCH_TILE
        batch = batch * bt // math.gcd(batch, bt)
    args = _autotune_inputs(spec, batch=batch)
    fns: Dict[str, Callable] = {}
    built: Dict[str, Callable] = {}
    for gr in ("ring", "psum"):
        try:
            exec_fn = _build_sharded_exec(
                spec, inner_exec, local_spec, mesh, mode, dp, tp, tp_size, gr)
            f = jax.jit(jax.grad(
                lambda v, l, a, e=exec_fn: jnp.sum(e(v, l, a)),
                argnums=(0, 1, 2)))
            jax.block_until_ready(f(*args))  # compile + warm (may raise)
            fns[gr] = f
            built[gr] = exec_fn
        except Exception:
            continue
    if not fns:
        return "ring", None  # nothing raced: keep the default, persist nothing
    if len(fns) < 2:
        # lone survivor: use it, don't persist (same contract as sharding)
        gr = next(iter(fns))
        return gr, built[gr]
    times = _time_executors(fns, args)
    # ring is the incumbent default; psum must clear the noise margin
    choice = ("psum" if times["psum"] < times["ring"] * (1 - _AUTOTUNE_MARGIN)
              else "ring")
    disk = _load_autotune_cache()
    prev = _parse_cache_entry(disk.get(key), spec)
    if prev is None:  # no sharding race ran (mode was pinned): start fresh
        prev = {"block_q": tuning.block_q,
                "slab_dtypes": tuning.slab_dtypes or _default_slab_dtypes(local_spec),
                "sharding": None, "onehot_levels": None,
                "fuse_levels": None, "fuse_prefix": None,
                "grad_reduce": None,
                "sparsity": None, "query_order": None, "extras": {}}
    prev["grad_reduce"] = choice
    disk[key] = _winner_entry(prev)
    _store_autotune_cache(disk)
    return choice, built[choice]


# --------------------------------------------------------------------------
# sharding (baked into the plan; collapses the old distributed_msda fork)
# --------------------------------------------------------------------------


def _shard_map_compat(f, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):  # jax >= 0.6 spelling
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _mesh_cache_key(mesh) -> Optional[tuple]:
    if mesh is None:
        return None
    return (
        tuple(mesh.axis_names),
        tuple(mesh.devices.shape),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


# below this per-shard query count the 2D mode stops amortising the
# second axis (ring hops + replicated-value HBM cost what the extra way
# of parallelism buys back); 'auto' then stays on the 1D ladder.  The
# 87k-query Deformable-DETR encoder clears it on any realistic mesh
# (87040 / 16 devices = 5440 per shard).  sharding="2d" overrides.
QUERY2D_MIN_LOCAL_Q = 2048

SHARDING_CHOICES = ("auto", "1d", "2d", "hybrid")
GRAD_REDUCE_CHOICES = ("auto", "ring", "psum")

# the hybrid batch x query rung re-racks the WHOLE device set as
# (batch_tile, n // batch_tile): batch shards over the first factor,
# queries tile over the second.  On a tp-less mesh (Nx1) the classic
# ladder degenerates to batch-only — mid-size B can't fill N batch ways,
# while hybrid still keeps every device busy with B=batch_tile.  The
# tile is a fixed small factor (not raced per B: B is unknown at plan
# time) — 2 is the smallest non-trivial split and keeps the query
# factor maximal.
HYBRID_BATCH_TILE = 2


def _hybrid_tiling(spec: MsdaSpec, mesh) -> Optional[Tuple[int, int]]:
    """(batch_tile, query_tile) for the hybrid rung, or None if illegal
    on this (spec, mesh): needs the device count to split as bt x qf
    with a non-trivial query factor that divides Q."""
    n = int(mesh.devices.size)
    bt = HYBRID_BATCH_TILE
    if n % bt:
        return None
    qf = n // bt
    if qf <= 1 or spec.num_queries % qf:
        return None
    return bt, qf


def _plan_sharding(spec: MsdaSpec, mesh, query_parallel: bool,
                   sharding: str = "auto"):
    """Resolve the legal sharding mode for this spec on this mesh.

    Returns (mode, dp_axis, tp_axis, tp_size, inner_spec) where ``mode``
    is one of 'replicated' | 'batch' | 'head' | 'query' | 'query2d' |
    'batchquery'.

    The 2D mode ('query2d') tiles QUERIES over dp x tp jointly — heads,
    batch and the value tensor are replicated — and is taken when both
    axes are real (dp > 1 and tp > 1), Q divides by dp*tp, and either
    ``sharding="2d"`` forces it or Q is large enough to amortise both
    axes (``QUERY2D_MIN_LOCAL_Q`` per shard; the 87k-query encoder).
    On a 1xN or Nx1 mesh one of the axes is trivial, so a 2D request
    resolves to the equivalent 1D rung instead of pretending.

    The hybrid mode ('batchquery') ignores the mesh's named factoring
    entirely and re-racks ALL devices as ``HYBRID_BATCH_TILE`` batch
    ways x ``n/HYBRID_BATCH_TILE`` query ways (see
    :func:`_hybrid_tiling`); ``tp_size`` in the returned tuple is the
    QUERY factor (the width of the grad_value reduction).  Forced by
    ``sharding="hybrid"``; under "auto" it slots between the query/head
    rungs and the batch-only floor, so a query-parallel plan on an Nx1
    mesh gets a non-degenerate step instead of idling N/B devices.

    The 1D ladder is otherwise unchanged: query-parallel needs
    Q % tp == 0, head-parallel H % tp == 0; otherwise tp idles
    (batch-only) — same degradation ladder the old distributed_msda had,
    now committed once at plan time instead of re-derived per call.
    """
    from repro.sharding import rules

    dp = rules.resolve_axis("dp", mesh)
    tp = rules.resolve_axis("tp", mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp_size = sizes.get("model", 1)
    dp_size = rules.axis_size(dp, mesh)
    H, Q = spec.num_heads, spec.num_queries
    want_query = query_parallel or sharding in ("2d", "hybrid")
    if sharding == "hybrid":
        hy = _hybrid_tiling(spec, mesh)
        if hy is not None:
            bt, qf = hy
            inner = dataclasses.replace(spec, num_queries=Q // qf)
            return "batchquery", dp, tp, qf, inner
    if (sharding not in ("1d", "hybrid") and want_query
            and dp is not None and dp_size > 1
            and tp is not None and tp_size > 1
            and Q % (dp_size * tp_size) == 0):
        local_q = Q // (dp_size * tp_size)
        if sharding == "2d" or local_q >= QUERY2D_MIN_LOCAL_Q:
            inner = dataclasses.replace(spec, num_queries=local_q)
            return "query2d", dp, tp, tp_size, inner
    if want_query and Q % tp_size == 0 and tp is not None and tp_size > 1:
        inner = dataclasses.replace(spec, num_queries=Q // tp_size)
        return "query", dp, tp, tp_size, inner
    if tp is not None and tp_size > 1 and H % tp_size == 0:
        inner = dataclasses.replace(spec, num_heads=H // tp_size)
        return "head", dp, tp, tp_size, inner
    if sharding == "auto" and want_query and (tp is None or tp_size == 1):
        # hybrid rung: the named ladder has no query axis left, but the
        # raw device count still splits as batch_tile x query_tile
        hy = _hybrid_tiling(spec, mesh)
        if hy is not None:
            bt, qf = hy
            inner = dataclasses.replace(spec, num_queries=Q // qf)
            return "batchquery", dp, tp, qf, inner
    # tp idle (or size 1): shards see the full head/query extent
    mode = "batch" if dp is not None else "replicated"
    return mode, dp, None, 1, spec


def resolve_sharding(spec: MsdaSpec, mesh, query_parallel: bool,
                     sharding: str = "auto") -> Tuple[str, MsdaSpec]:
    """Public probe: the (mode, per-shard spec) a plan would commit.

    Used by the plan store to re-derive a persisted distributed plan's
    local geometry (whose autotune winner is keyed on the LOCAL spec)
    and by tests/docs that assert on the ladder without building a plan.
    """
    mode, _, _, _, inner = _plan_sharding(spec, mesh, query_parallel, sharding)
    return mode, inner


def _resolve_grad_reduce(grad_reduce: str, mode: str, tp_size: int) -> str:
    """'auto' -> ring for the query-sharded modes (where grad_value is a
    cross-shard reduction), psum-via-AD everywhere else.  Modes whose
    value tensor is sharded ('head', 'batch') have nothing to reduce and
    always report 'none'."""
    if mode not in ("query", "query2d", "batchquery") or tp_size <= 1:
        return "none"
    if grad_reduce == "auto":
        return "ring"
    return grad_reduce


def _build_sharded_exec(spec, inner_exec, inner_spec, mesh, mode, dp, tp,
                        tp_size: int, grad_reduce: str):
    from repro.sharding import rules

    from jax.sharding import Mesh, PartitionSpec as P

    if mode == "batchquery":
        # hybrid rung: re-rack the WHOLE device set as (batch_tile x
        # query_tile) — an internal mesh over the same devices — then the
        # wiring IS the query mode's on that mesh: value batch-sharded
        # over the tile, queries split over the query factor, grad_value
        # ring/psum-reduced over it.  The caller's named axes don't
        # appear inside; the plan records the ORIGINAL mesh topology.
        qf = int(tp_size)
        bt = int(mesh.devices.size) // qf
        mesh = Mesh(mesh.devices.reshape(bt, qf), ("data", "model"))
        mode, dp, tp = "query", "data", "model"

    if mode == "query2d":
        # queries tiled over dp x tp jointly; heads, batch and the value
        # tensor replicated — the whole mesh works one huge-Q problem
        # (the 87k-query encoder) instead of only the tp slice of it.
        qaxes = rules.flat_axes(dp) + rules.flat_axes(tp)
        vspec = P(None, None, None, None)
        qspec = P(None, qaxes, None, None, None, None)
        wspec = P(None, qaxes, None, None, None)
        ospec = P(None, qaxes, None)
    elif mode == "query":
        # value replicated over tp; queries split.  Backward: the
        # per-shard partial grad_value slabs are reduced over tp — by
        # the explicit ppermute ring below (default), or by shard_map's
        # transpose psum when grad_reduce="psum" — the TPU-idiomatic
        # realisation of the paper's staggered scatter (contention
        # eliminated via partial accumulators + reduction).
        vspec = P(dp, None, None, None)
        qspec = P(dp, tp, None, None, None, None)
        wspec = P(dp, tp, None, None, None)
        ospec = P(dp, tp, None)
    else:
        vspec = P(dp, None, tp, None)
        qspec = P(dp, None, tp, None, None, None)
        wspec = P(dp, None, tp, None, None)
        ospec = P(dp, None, tp)

    Hd = inner_spec.num_heads * inner_spec.head_dim

    def run(v, l, a):
        out = inner_exec(v, l, a)
        return out.reshape(l.shape[0], l.shape[1], Hd)

    fwd_sharded = _shard_map_compat(run, mesh, (vspec, qspec, wspec), ospec)
    reduce = _resolve_grad_reduce(grad_reduce, mode, tp_size)
    if reduce == "none":
        return fwd_sharded

    # Explicit grad_value reduction: shard_map's transpose would emit
    # one monolithic all-reduce of the full fp32 slab per backward.
    # Instead the backward runs as its own shard_map whose body computes
    # the per-shard partial slab and reduces it hierarchically — over
    # the tp axis first, then psum over the dp axes when value is
    # replicated there too (2D mode), matching the ICI-ring-then-DCN
    # topology.  The tp leg is the raced axis: a ppermute ring
    # (``msda_bwd.ring_allreduce`` — one slab shard resident per hop,
    # QUILL-style) by default, or a plain psum under
    # ``grad_reduce="psum"`` (the ablation/parity baseline — identical
    # structure, so the two paths differ ONLY in the tp reduction).
    # The per-shard forward is recomputed inside the backward (remat at
    # the shard_map boundary): at dp x tp scale the residual slabs would
    # otherwise sit resident across the whole ring schedule.
    from repro.kernels import msda_bwd

    dp_axes = rules.flat_axes(dp)
    accum = jnp.dtype(spec.accum_dtype)

    def bwd_shard(v, l, a, g):
        _, vjp = jax.vjp(run, v, l, a)
        gv, gl, ga = vjp(g)
        vdt = gv.dtype
        # reduce the slab in the widened accum dtype: cross-shard adds
        # must not round through a narrow operand dtype between hops
        gv = gv.astype(accum)
        if reduce == "ring":
            gv = msda_bwd.ring_allreduce(gv, tp, tp_size, axis=1)
        else:
            gv = jax.lax.psum(gv, tp)
        if mode == "query2d" and dp_axes:
            gv = jax.lax.psum(gv, dp_axes)
        return gv.astype(vdt), gl, ga

    bwd_sharded = _shard_map_compat(
        bwd_shard, mesh, (vspec, qspec, wspec, ospec), (vspec, qspec, wspec))

    @jax.custom_vjp
    def op(v, l, a):
        return fwd_sharded(v, l, a)

    def op_fwd(v, l, a):
        return fwd_sharded(v, l, a), (v, l, a)

    def op_bwd(res, g):
        return bwd_sharded(*res, g)

    op.defvjp(op_fwd, op_bwd)
    return op


# --------------------------------------------------------------------------
# MsdaPlan
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MsdaPlan:
    """Executable MSDA plan: backend + tuning + (optional) sharding, fixed.

    Call it like the op: ``plan(value, loc, attn) -> (B, Q, H*D)``.  The
    VJP was wired at build time — ``jax.grad`` through the call just works.
    """

    spec: MsdaSpec
    backend: str
    tuning: PlanTuning
    # 'local' | 'replicated' | 'batch' | 'head' | 'query' | 'query2d'
    # | 'batchquery' (hybrid batch x query tiling over the whole mesh)
    sharding_mode: str
    # the per-shard geometry the tuning was computed for (== spec for
    # unsharded plans; Q or H divided by the sharded axes otherwise)
    local_spec: MsdaSpec
    _exec: Callable = dataclasses.field(repr=False, compare=False)
    # -- distribution record (how the mode above maps onto the mesh) ------
    # kept as plain tuples/strings (no device objects) so the plan store
    # can persist them and a restored process can validate its own mesh
    mesh_axes: Tuple[str, ...] = ()
    mesh_shape: Tuple[int, ...] = ()
    query_parallel: bool = False
    # 'none' (no cross-shard grad_value reduction) | 'ring' | 'psum'
    grad_reduce: str = "none"
    # hybrid ('batchquery') plans only: how many batch ways the whole
    # device set was re-racked into (queries take the remaining factor)
    batch_tile: int = 0
    # the tune mode the plan was REQUESTED with.  tuning.source alone
    # can't recover this: a backend with no local tuning surface (ref)
    # still races the mesh-keyed axes under "autotune", and the plan
    # store must know to re-race them after an elastic mesh resize
    tune: str = "heuristic"

    def __call__(self, value: jax.Array, sampling_locations: jax.Array,
                 attention_weights: jax.Array) -> jax.Array:
        s = self.spec
        if value.shape[1] != s.total_pixels or value.shape[3] != s.head_dim:
            raise ValueError(
                f"value {value.shape} does not match plan spec "
                f"(S={s.total_pixels}, D={s.head_dim})")
        if sampling_locations.shape[1] != s.num_queries:
            raise ValueError(
                f"loc Q={sampling_locations.shape[1]} != spec Q={s.num_queries}")
        lp = self.launches_per_call()
        _PLAN_CALLS.inc(backend=self.backend)
        if lp["fwd"]:
            _LAUNCHES.inc(lp["fwd"], direction="fwd")
        if lp["bwd"]:
            _LAUNCHES.inc(lp["bwd"], direction="bwd")
        return self._exec(value, sampling_locations, attention_weights)

    apply = __call__

    @property
    def block_q(self) -> Tuple[int, ...]:
        return self.tuning.block_q

    def launches_per_call(self) -> Dict[str, int]:
        """Static Pallas launch schedule for one plan call, by direction.

        Whole-pyramid fused plans launch once per direction over the
        packed super-slab; a partial-fusion tier with a fused prefix of
        ``k`` levels launches ``L - k + 1`` times (one fused prefix
        launch + the per-level tail); per-level plans launch once per
        level.  The ref/cpu backends and the top-k pruned executor run
        as plain XLA — zero Pallas launches.  ``bwd`` counts the
        custom-VJP backward a ``train`` plan carries (0 for inference
        plans).
        """
        if self.backend != "pallas" or self.tuning.sparsity == "topk":
            return {"fwd": 0, "bwd": 0}
        L = self.local_spec.num_levels
        k = self.fuse_prefix
        per_dir = L if k == 0 else L - k + 1
        return {"fwd": per_dir, "bwd": per_dir if self.spec.train else 0}

    # -- inspectability ---------------------------------------------------
    @property
    def fused(self) -> bool:
        """True when this plan runs fused pyramid kernels (whole-pyramid
        or a partial-fusion tier)."""
        return bool(self.tuning.fuse_levels)

    @property
    def fuse_prefix(self) -> int:
        """Effective committed fused-prefix length: 0 for per-level
        plans, L for whole-pyramid fusion, else the strict tier
        ``0 < k < L``."""
        if not self.fused:
            return 0
        L = self.local_spec.num_levels
        k = int(self.tuning.fuse_prefix)
        return L if (k == 0 or k >= L) else k

    def level_report(self) -> List[Dict[str, Any]]:
        """Per-level planning facts (the numbers ``describe`` prints).

        Reported against ``local_spec`` — the per-shard geometry the
        tuning was actually computed for.  ``vmem_frac`` is PER TIER:
        levels inside the fused prefix report the packed prefix's
        occupancy (every prefix slab resident at once, identical on
        those rows); tail levels (and fully per-level plans) report
        their own slab's.
        """
        from repro.kernels import ops

        s = self.local_spec
        dts = self.tuning.slab_dtypes or _default_slab_dtypes(s)
        resolved = tuple(
            dts[l] if l < len(dts) and dts[l] else s.resolved_slab_dtype()
            for l in range(s.num_levels))
        items = _slab_itemsizes(resolved)
        k = self.fuse_prefix  # 0 per-level, L whole-pyramid, else the tier
        prefix_resident = 0
        if k:
            prefix_resident = ops.fused_resident_bytes(
                s.spatial_shapes[:k], s.head_dim,
                slab_itemsize=items[:k], train=s.train,
                accum_itemsize=s.accum_itemsize)
        # what the occupancy model would have picked on its own, so the
        # report carries predicted-vs-committed occupancy per level (a
        # raced/overridden block plan can land far from the model)
        if k:
            heur_bq = _tier_block_q(s, resolved, self.tuning.fuse_prefix)
        else:
            heur_bq = _blocks_for_slab_dtypes(s, resolved)
        rows = []
        for l, hw in enumerate(s.spatial_shapes):
            slab = ops.slab_rows(hw)
            sdt = resolved[l]
            if self.backend == "ref":
                # the oracle ignores the slab policy: pure fp32 compute,
                # no resident slabs — report what actually executes
                sdt = "float32"
            in_prefix = l < k
            slab_bytes = slab * s.head_dim * jnp.dtype(sdt).itemsize
            if s.train:  # widened (accum-dtype) grad slab rides along
                slab_bytes += slab * s.head_dim * s.accum_itemsize
            bq = self.tuning.block_q[l] if l < len(self.tuning.block_q) else 0
            # the fused prefix's per-step working set is sized by its
            # widest resident level, not by this level's own (possibly
            # narrower) commitment
            step_item = (_fused_slab_itemsize(resolved[:k]) if in_prefix
                         else jnp.dtype(sdt).itemsize)
            per_q = ops.per_query_bytes(
                s.num_points, s.head_dim, train=s.train,
                slab_itemsize=step_item,
                levels=k if in_prefix else 1)
            resident = prefix_resident if in_prefix else slab_bytes
            occupancy = (resident + bq * per_q) / max(s.vmem_budget, 1)
            pred_bq = heur_bq[l] if l < len(heur_bq) else bq
            predicted = (resident + pred_bq * per_q) / max(s.vmem_budget, 1)
            onehot = bool(self.tuning.onehot_levels[l]) if self.tuning.onehot_levels else False
            if self.tuning.sparsity == "topk":
                # the pruned executor replaces the backend's gather path
                # wholesale (XLA top-k gather) — report what runs
                gather = "xla-topk"
            elif self.backend == "ref":
                gather = "xla"
            elif self.backend == "cpu":
                gather = "cpu-fused"
            elif onehot:
                gather = "mxu-onehot"
            else:
                gather = "vpu-fused" if s.fuse_gather else "vpu-4x"
            rows.append({
                "level": l,
                "hw": hw,
                "slab_rows": slab,
                "slab_bytes": slab_bytes,
                "slab_dtype": str(sdt),
                "block_q": bq,
                "q_steps": -(-_round_up(s.num_queries, max(bq, 1)) // max(bq, 1)),
                "gather": gather,
                "vmem_frac": occupancy,
                "block_q_predicted": pred_bq,
                "vmem_frac_predicted": predicted,
                "fused": in_prefix,
            })
            _VMEM_GAUGE.set(occupancy, level=l, kind="committed")
            _VMEM_GAUGE.set(predicted, level=l, kind="predicted")
        return rows

    def sharding_report(self) -> Dict[str, Any]:
        """Structured record of the committed distribution.

        Which mesh axes shard which operand dims, plus the grad_value
        reduction strategy — the facts ``describe()``'s mesh line prints
        and the plan store persists.  Empty-axes dict for local plans.
        """
        sizes = dict(zip(self.mesh_axes, self.mesh_shape))
        dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
        tp = "model" if "model" in sizes else None
        mode = self.sharding_mode
        q_axes: Tuple[str, ...] = ()
        h_axes: Tuple[str, ...] = ()
        b_axes: Tuple[str, ...] = ()
        if mode == "query2d":
            q_axes = dp_axes + ((tp,) if tp else ())
        elif mode == "query":
            q_axes, b_axes = ((tp,) if tp else ()), dp_axes
        elif mode == "head":
            h_axes, b_axes = ((tp,) if tp else ()), dp_axes
        elif mode == "batch":
            b_axes = dp_axes
        out = {
            "mode": mode,
            "mesh": sizes,
            "query_axes": q_axes,
            "head_axes": h_axes,
            "batch_axes": b_axes,
            "query_parallel": self.query_parallel,
            "grad_reduce": self.grad_reduce,
        }
        if mode == "batchquery":
            # hybrid: the tiling ignores the named axes — report the
            # anonymous (batch_tile x query_tile) factoring instead
            n = 1
            for s in self.mesh_shape:
                n *= int(s)
            out["batch_tile"] = int(self.batch_tile)
            out["query_tile"] = n // max(int(self.batch_tile), 1)
        return out

    def describe(self) -> str:
        """Human-readable plan report.

        The header states the resolved sharding MODE and the committed
        fusion tier (``fuse=per-level`` / ``fuse=pyramid`` /
        ``fuse=pyramid[0:k)+per-level`` for a partial tier, whose
        ``fused prefix`` line carries the launch count and the prefix
        super-slab extent); mesh-carrying plans add a ``mesh:`` line
        with the topology, which mesh axes
        shard which operand dims, the per-shard geometry, and the
        committed grad_value reduction (``ring`` / ``psum`` / ``local``)
        — so the report is the full distribution contract, not just the
        mode name.  Then one line per level with the committed
        ``block_q``, slab bytes / VMEM occupancy, the gather path, and —
        the mixed-precision axis — the **chosen slab dtype variant** per
        level (``slab_dt`` column: fp32, or bf16 when the policy /
        autotune committed a narrow slab; accumulation stays in
        ``accum_dtype``, shown in the header).
        """
        s = self.spec
        shard_note = ""
        if self.mesh_axes:
            r = self.sharding_report()
            dims = []
            if r["mode"] == "batchquery":
                dims = [f"B->x{r['batch_tile']}", f"Q->x{r['query_tile']}"]
            if r["batch_axes"]:
                dims.append("B->" + "+".join(r["batch_axes"]))
            if r["query_axes"]:
                dims.append("Q->" + "+".join(r["query_axes"]))
            if r["head_axes"]:
                dims.append("H->" + "+".join(r["head_axes"]))
            gr = self.grad_reduce if self.grad_reduce != "none" else "local"
            shard_note = (
                f"  mesh: {mesh_token_from(self.mesh_axes, self.mesh_shape)}  "
                f"{'  '.join(dims) if dims else 'replicated'}  "
                f"grad_value={gr}\n")
        if self.local_spec is not self.spec:
            shard_note += (f"  per-shard: Q={self.local_spec.num_queries} "
                           f"H={self.local_spec.num_heads} (levels below are per shard)\n")
        fuse_note = ""
        if self.fused:
            from repro.kernels import ops

            ls = self.local_spec
            k = self.fuse_prefix
            if k == ls.num_levels:
                _, total = ops.pyramid_row_offsets(ls.spatial_shapes)
                fuse_note = (
                    f"  fused pyramid: 1 launch/direction  "
                    f"super_slab_rows={total}  shared block_q={self.block_q[0]}\n")
            else:
                _, total = ops.pyramid_row_offsets(ls.spatial_shapes[:k])
                fuse_note = (
                    f"  fused prefix [0:{k}): {ls.num_levels - k + 1} "
                    f"launches/direction  super_slab_rows={total}  "
                    f"shared block_q={self.block_q[0]}  "
                    f"tail levels {k}..{ls.num_levels - 1} per-level\n")
        sparse_note = ""
        if self.tuning.sparsity == "topk":
            ls = self.local_spec
            cells = ls.num_levels * ls.num_points
            k = ls.resolved_sparsity_k()
            sparse_note = (
                f"  sparsity: topk k={k}/{cells} cells/query  "
                f"corner gathers {4 * k}/query (dense {4 * cells})\n")
        if self.tuning.query_order == "morton":
            sparse_note += ("  query order: morton (plan-time Z-curve "
                            "permutation, inverted on output)\n")
        lp = self.launches_per_call()
        launch_note = (f"  launches/call: fwd={lp['fwd']} bwd={lp['bwd']}"
                       + ("" if self.backend == "pallas"
                          else f"  (no pallas kernels on '{self.backend}')")
                       + "\n")
        if not self.fused:
            fuse_hdr = "per-level"
        elif self.fuse_prefix == self.local_spec.num_levels:
            fuse_hdr = "pyramid"
        else:
            fuse_hdr = f"pyramid[0:{self.fuse_prefix})+per-level"
        head = (
            f"MsdaPlan(backend={self.backend}, tune={self.tuning.source}, "
            f"sharding={self.sharding_mode}, "
            f"fuse={fuse_hdr}, "
            f"train={s.train}, dtype={s.dtype}, "
            f"accum={s.accum_dtype})\n"
            f"  Q={s.num_queries} H={s.num_heads} D={s.head_dim} P={s.num_points} "
            f"levels={s.num_levels} S={s.total_pixels}\n"
            + shard_note + fuse_note + sparse_note + launch_note +
            f"  vmem_budget={s.vmem_budget / 2**20:.1f} MiB  "
            f"interpret={self.tuning.interpret}\n"
        )
        lines = [head,
                 "  lvl  hw         slab_rows  slab_KiB   slab_dt   block_q  steps  gather      vmem%  pred%"]
        for r in self.level_report():
            hw = "%dx%d" % r["hw"]
            lines.append(
                f"  {r['level']:<4d} {hw:<10s} "
                f"{r['slab_rows']:<10d} {r['slab_bytes'] / 1024:<10.1f} "
                f"{r['slab_dtype']:<9s} "
                f"{r['block_q']:<8d} {r['q_steps']:<6d} {r['gather']:<11s} "
                f"{100 * r['vmem_frac']:<6.1f} {100 * r['vmem_frac_predicted']:.1f}")
        return "\n".join(lines)

    # -- degradation ladder -----------------------------------------------
    def rung_label(self) -> str:
        """Short human token for this plan's ladder rung, e.g.
        ``"pallas/fused+topk"`` / ``"pallas/per-level"`` / ``"ref"``."""
        if self.backend == "ref":
            return "ref"
        traits = []
        if self.fused:
            traits.append("fused")
        if self.tuning.sparsity == "topk":
            traits.append("topk")
        if self.tuning.query_order == "morton":
            traits.append("morton")
        return f"{self.backend}/{'+'.join(traits) if traits else 'per-level'}"

    def fallback(self, *, mesh=None) -> Optional["MsdaPlan"]:
        """One rung down the degradation ladder (None at the bottom).

        The ladder walks from most- to least-optimised, one committed
        decision at a time::

            sparse / reordered (topk, morton)  ->  dense identity, same backend
            fused (whole-pyramid or prefix)    ->  per-level, same backend
            per-level dense, non-ref backend   ->  the "ref" oracle
            ref                                ->  None (nothing below the oracle)

        Built RACE-FREE from the existing spec: the demoted plan pins
        the axes it drops (``sparsity="off"``, ``query_order=
        "identity"``, ``fuse_levels="off"``) and is constructed with
        ``tune="heuristic"`` — no autotune timing run executes and no
        winner is ever persisted, so a circuit-breaker demotion cannot
        poison the winner cache with panic-built plans (conformance:
        every rung is numerically consistent with the primary — see
        ``tests/conformance.py``).  Mesh-carrying plans need the live
        ``mesh`` object to rebuild their shard wiring; demoting one
        without it raises rather than silently going local.
        """
        if self.mesh_axes and mesh is None:
            raise ValueError(
                f"mesh-carrying plan (mode={self.sharding_mode}) needs "
                "mesh= to build its fallback rung")
        s = self.spec
        if self.tuning.sparsity == "topk" or self.tuning.query_order == "morton":
            ns = dataclasses.replace(s, sparsity="off", query_order="identity")
            backend = self.backend
        elif self.fused:
            ns = dataclasses.replace(s, sparsity="off", query_order="identity",
                                     fuse_levels="off")
            backend = self.backend
        elif self.backend != "ref":
            ns = dataclasses.replace(s, sparsity="off", query_order="identity",
                                     fuse_levels="off")
            backend = "ref"
        else:
            return None
        return msda_plan(ns, backend=backend, tune="heuristic", mesh=mesh,
                         query_parallel=self.query_parallel,
                         interpret=self.tuning.interpret)

    def fallback_chain(self, *, mesh=None) -> Tuple["MsdaPlan", ...]:
        """Every rung below this plan, top to bottom (ends at the ref
        oracle; empty for a plan already on the bottom rung)."""
        chain: List[MsdaPlan] = []
        p = self.fallback(mesh=mesh)
        while p is not None:
            chain.append(p)
            p = p.fallback(mesh=mesh)
        return tuple(chain)


# --------------------------------------------------------------------------
# the plan cache (explicit, bounded — replaces the old unbounded lru_cache
# on the compiled op; serving processes call clear_plans() to drop them)
# --------------------------------------------------------------------------

_PLAN_CACHE: "OrderedDict[tuple, MsdaPlan]" = OrderedDict()
_PLAN_CACHE_MAX = 128
_CACHE_STATS = {
    "hits": _obs.counter("msda.plan_cache.hits",
                         help="in-process plan-cache hits"),
    "misses": _obs.counter("msda.plan_cache.misses",
                           help="in-process plan-cache misses (plan builds)"),
}


def configure_plan_cache(maxsize: int) -> None:
    """Bound the in-process plan cache (evicts LRU beyond ``maxsize``)."""
    global _PLAN_CACHE_MAX
    _PLAN_CACHE_MAX = max(1, int(maxsize))
    while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
        _PLAN_CACHE.popitem(last=False)


def clear_plans() -> None:
    """Drop every cached plan (and its compiled op closures).

    Hit/miss counters survive the clear (they are monotonic process
    counters, so an engine shutdown does not erase the metrics export);
    zero them explicitly with ``obs.reset("msda.plan_cache")``.
    """
    _PLAN_CACHE.clear()


def plan_cache_info() -> Dict[str, int]:
    return {"hits": int(_CACHE_STATS["hits"].value()),
            "misses": int(_CACHE_STATS["misses"].value()),
            "size": len(_PLAN_CACHE), "maxsize": _PLAN_CACHE_MAX}


def _hit_rate(hits: int, misses: int) -> Optional[float]:
    total = hits + misses
    return (hits / total) if total else None


def execution_telemetry() -> Dict[str, Any]:
    """Process-wide plan-execution counters, registry-backed.

    The block the serve/train snapshots embed: plan-cache and
    winner-cache hit rates plus Pallas launches per direction.  Launch
    counts are *static-schedule x traced-call* attributions — each
    :meth:`MsdaPlan.__call__` whose Python body runs (eagerly, or once
    per jit trace / AOT compile) adds its plan's per-call launch
    schedule, so a zero-retrace serving steady state adds zero.
    """
    pc = plan_cache_info()
    a = autotune_stats()
    wc_misses = int(_WINNER_CACHE_MISSES.value())
    return {
        "plan_cache": {
            "hits": pc["hits"], "misses": pc["misses"],
            "size": pc["size"],
            "hit_rate": _hit_rate(pc["hits"], pc["misses"]),
        },
        "winner_cache": {
            "hits": a["cache_hits"], "misses": wc_misses,
            "seeded": a["seeded"],
            "hit_rate": _hit_rate(a["cache_hits"], wc_misses),
        },
        "launches": {
            "fwd": int(_LAUNCHES.value(direction="fwd")),
            "bwd": int(_LAUNCHES.value(direction="bwd")),
            "plan_calls": int(_PLAN_CALLS.total()),
        },
    }


def msda_plan(
    spec: MsdaSpec,
    *,
    backend: str = "auto",
    tune: str = "heuristic",
    mesh=None,
    query_parallel: bool = False,
    sharding: str = "auto",
    grad_reduce: str = "auto",
    block_q: Optional[Tuple[int, ...]] = None,
    interpret: Optional[bool] = None,
) -> MsdaPlan:
    """Resolve backend + tuning + sharding for ``spec``; cached.

    ``tune``: ``"heuristic"`` uses the paper's VMEM-occupancy model
    (Fig. 7); ``"autotune"`` times candidate block plans on synthetic
    operands and persists winners per (device kind, spec) on disk.
    ``block_q`` overrides both (ablation hook).  ``mesh`` bakes the
    shard_map wiring into the returned plan; ``sharding`` picks the
    distribution family — ``"auto"`` walks the ladder (and, under
    ``tune="autotune"``, RACES 1D vs 2D vs hybrid and persists the
    winner per mesh topology), ``"1d"`` pins the classic
    query/head/batch ladder, ``"2d"`` forces dp x tp query tiling when
    legal, ``"hybrid"`` forces the batch x query whole-mesh tiling
    (mid-size B on tp-less meshes).  ``grad_reduce``
    picks the query-sharded backward's grad_value reduction:
    ``"ring"`` (default via "auto") circulates the fp32 slab over the
    tp axis with ppermute, ``"psum"`` keeps shard_map's transpose
    all-reduce (ablation / parity baseline).
    """
    if tune not in ("heuristic", "autotune"):
        raise ValueError(f"unknown tune mode {tune!r}; use 'heuristic' or 'autotune'")
    if sharding not in SHARDING_CHOICES:
        raise ValueError(
            f"unknown sharding {sharding!r}; one of {SHARDING_CHOICES}")
    if grad_reduce not in GRAD_REDUCE_CHOICES:
        raise ValueError(
            f"unknown grad_reduce {grad_reduce!r}; one of {GRAD_REDUCE_CHOICES}")
    backend_name = registry.resolve_backend(backend)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if mesh is not None and mesh.devices.size <= 1:
        mesh = None  # single-device mesh: sharding is a no-op

    key = (spec, backend_name, tune, tuple(block_q) if block_q else None,
           bool(interpret), _mesh_cache_key(mesh), bool(query_parallel),
           sharding, grad_reduce)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _CACHE_STATS["hits"].inc()
        _PLAN_CACHE.move_to_end(key)
        return cached
    _CACHE_STATS["misses"].inc()

    builder = registry.get_backend(backend_name)

    def _build_local_impl(s: MsdaSpec) -> Tuple[Callable, PlanTuning]:
        dts = _default_slab_dtypes(s)
        onehot = _onehot_levels(s)
        sparsity, qorder = _resolve_sparsity(s), _resolve_query_order(s)
        if block_q is not None:
            if len(block_q) != s.num_levels:
                raise ValueError(
                    f"block_q has {len(block_q)} entries for {s.num_levels} levels")
            bq, source = tuple(int(b) for b in block_q), "override"
            # a NON-uniform override pins per-level blocks the fused
            # kernel (one shared block) cannot honour — never silently
            # reinterpret it; only a uniform override may still fuse
            if len(set(bq)) == 1:
                fused, prefix = _resolve_fuse_tier(s, dts, backend_name)
            else:
                fused, prefix = False, 0
        elif tune == "autotune" and backend_name != "ref":
            (bq, dts, onehot, fused, prefix, sparsity, qorder,
             source) = _autotune_plan(s, backend_name, builder, interpret)
        else:
            fused, prefix = _resolve_fuse_tier(s, dts, backend_name)
            bq = (_tier_block_q(s, dts, prefix) if fused
                  else _heuristic_block_q(s))
            source = "heuristic"
        if sparsity == "topk":
            # the pruned executor is one XLA computation — it neither
            # fuses pyramid launches nor routes through the MXU; the
            # committed tuning must describe what actually runs
            fused, prefix = False, 0
        tuning = PlanTuning(block_q=bq, onehot_levels=onehot,
                            interpret=interpret, source=source,
                            slab_dtypes=dts, fuse_levels=fused,
                            fuse_prefix=prefix,
                            sparsity=sparsity, query_order=qorder)
        # a pruned plan swaps in the top-k executor (the backend's dense
        # executor is the fallback every other decision still describes);
        # dense+identity is byte-identical to the pre-sparsity build
        if sparsity == "topk":
            exec_fn = _apply_sparsity_wrappers(None, s, sparsity, qorder)
        else:
            exec_fn = _apply_sparsity_wrappers(
                builder(s, tuning), s, sparsity, qorder)
        return exec_fn, tuning

    def build_local(s: MsdaSpec) -> Tuple[Callable, PlanTuning]:
        # the span wraps ONE local build (sharded plans may build both
        # race candidates); autotune races nest inside as children
        with _obs_trace.span("plan.build", level=2, backend=backend_name,
                             q=s.num_queries, levels=s.num_levels,
                             train=s.train, tune=tune) as sp:
            exec_fn, tuning = _build_local_impl(s)
            sp["source"] = tuning.source
            return exec_fn, tuning

    if mesh is None:
        exec_fn, tuning = build_local(spec)
        plan = MsdaPlan(spec=spec, backend=backend_name, tuning=tuning,
                        sharding_mode="local", local_spec=spec, _exec=exec_fn,
                        tune=tune)
    else:
        shard_choice, prebuilt = sharding, None
        # the 1D-vs-2D race rides on query-parallel INTENT: 2D is the
        # huge-Q encoder's axis, so plans that never asked to tile
        # queries (head/batch users) are not surprise-resharded by a
        # timing run
        if tune == "autotune" and sharding == "auto" and query_parallel:
            shard_choice, prebuilt = _autotune_sharding(
                spec, backend_name, mesh, query_parallel, grad_reduce,
                build_local)
        if prebuilt is not None:
            # the race already built (and block-planned) the winner
            exec_fn, tuning, (mode, dp, tp, tp_size, local_spec), inner_exec = prebuilt
        else:
            mode, dp, tp, tp_size, local_spec = _plan_sharding(
                spec, mesh, query_parallel, shard_choice)
            inner_exec, tuning = build_local(local_spec)
            exec_fn = _build_sharded_exec(
                spec, inner_exec, local_spec, mesh, mode, dp, tp, tp_size,
                grad_reduce)
        resolved_gr = _resolve_grad_reduce(grad_reduce, mode, tp_size)
        if (tune == "autotune" and grad_reduce == "auto" and spec.train
                and resolved_gr == "ring"):
            # raced grad_value reduction (ring vs psum) per mesh topology
            choice, raced_exec = _autotune_grad_reduce(
                spec, backend_name, mesh, query_parallel, mode, dp, tp,
                tp_size, inner_exec, local_spec, tuning)
            if choice != "ring":
                exec_fn = raced_exec or _build_sharded_exec(
                    spec, inner_exec, local_spec, mesh, mode, dp, tp,
                    tp_size, choice)
                resolved_gr = choice
        plan = MsdaPlan(spec=spec, backend=backend_name, tuning=tuning,
                        sharding_mode=mode, local_spec=local_spec,
                        _exec=exec_fn,
                        mesh_axes=tuple(mesh.axis_names),
                        mesh_shape=tuple(int(s) for s in mesh.devices.shape),
                        query_parallel=bool(query_parallel),
                        grad_reduce=resolved_gr,
                        batch_tile=(int(mesh.devices.size) // tp_size
                                    if mode == "batchquery" else 0),
                        tune=tune)
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
        _PLAN_CACHE.popitem(last=False)
    return plan
