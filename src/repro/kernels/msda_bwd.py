"""Pallas-TPU backward kernel for multi-scale deformable attention.

Paper mapping (xMSDA §4.2 → TPU):

* Phase 1 (grad w.r.t. sampling locations + attention weights) is pure
  element-wise vector math over the bilinear corners.  In train mode the
  corners were **saved by the forward kernel** (paper §4.1) so phase 1
  issues no gathers at all; otherwise it re-gathers (fused, like fwd).
* Phase 2 (grad w.r.t. value) is the scatter-add hotspot.  The paper
  staggers vector-core phases to reduce GM write contention; on TPU the
  Pallas grid is *sequential per TensorCore*, so we instead keep the
  whole level's ``grad_value`` slab **resident in VMEM** and scatter-add
  into it across query blocks — contention-free by construction, with a
  single UB→GM (VMEM→HBM) writeback when the (batch, head) block
  retires.  Cross-core/chip parallelism gets per-shard partial slabs
  reduced by ``psum`` at the distribution layer (see
  ``core/msda.py``) — the TPU-idiomatic equivalent of staggered writes.
* **Scatter fusion**: all four corners × P points of a query block are
  scattered with ONE batched ``.at[idx].add`` (duplicate indices
  accumulate); the ablation flag ``fuse_scatter=False`` issues four
  per-corner scatters (the paper's "-Scatter Fusion" column).

Outputs per level: grad_value slab (``accum_dtype``, fp32 by default,
padded layout), grad_loc, grad_attn.  Grid ``(B, H, num_q_blocks)`` with
the grad slab revisited (accumulated in VMEM) across the innermost ``q``
dimension.

Mixed precision: when the plan commits a bf16 value slab, the *inputs*
(slab / saved corners) arrive narrow but the resident grad slab is a
genuine **widened accumulator** — allocated and scatter-added in
``accum_dtype`` inside the kernel, not a bf16 slab cast afterwards —
so Q-many scatter contributions never round through bf16.

**Fused whole-pyramid variant** (``msda_bwd_fused``): under the
planner's fusion rung the whole pyramid's grad slab is the residency
unit — one ``pallas_call`` streams ``gout`` once, scatter-adds every
level into a single packed grad super-slab (disjoint row ranges per
level, so the merged scatter is contention-free), and writes it to HBM
exactly once, instead of re-streaming ``gout`` and re-launching per
level.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import msda_fwd
from repro.kernels.msda_fwd import _CompilerParams, corner_indices

Shapes = Tuple[Tuple[int, int], ...]


def _bwd_kernel(
    value_ref,  # (1, 1, HWp, D) VMEM-resident level slab (None if saved)
    loc_ref,    # (1, 1, Qb, P, 2)
    attn_ref,   # (1, 1, Qb, P)
    gout_ref,   # (1, 1, Qb, D)
    saved_ref,  # (1, 1, Qb, 4P, D) corners saved by fwd (None if regather)
    gval_ref,   # out: (1, 1, HWp, D) fp32, accumulated across q blocks
    gloc_ref,   # out: (1, 1, Qb, P, 2)
    gattn_ref,  # out: (1, 1, Qb, P)
    *,
    H: int,
    W: int,
    Wp: int,
    fuse_scatter: bool,
    onehot_scatter: bool = False,
):
    q_idx = pl.program_id(2)

    loc = loc_ref[0, 0].astype(jnp.float32)  # (Qb, P, 2)
    attn = attn_ref[0, 0].astype(jnp.float32)  # (Qb, P)
    gout = gout_ref[0, 0].astype(jnp.float32)  # (Qb, D)
    Qb, P, _ = loc.shape
    D = gout.shape[-1]

    idx00, lx, ly, (m00, m10, m01, m11) = corner_indices(loc, H, W, Wp)
    i00 = idx00.reshape(-1)  # (Qb*P,)

    # ---- corners: saved by fwd (no gather) or re-gathered (fused) --------
    if saved_ref is not None:
        corners = saved_ref[0, 0].astype(jnp.float32)  # (Qb, 4P, D)
        v00, v10, v01, v11 = jnp.split(corners, 4, axis=1)
    else:
        all_idx = jnp.concatenate([i00, i00 + 1, i00 + Wp, i00 + Wp + 1])
        g = jnp.take(value_ref[0, 0], all_idx, axis=0).astype(jnp.float32)
        v00, v10, v01, v11 = (x.reshape(Qb, P, D) for x in jnp.split(g, 4, axis=0))
    v00 = v00.reshape(Qb, P, D) * m00[..., None]
    v10 = v10.reshape(Qb, P, D) * m10[..., None]
    v01 = v01.reshape(Qb, P, D) * m01[..., None]
    v11 = v11.reshape(Qb, P, D) * m11[..., None]

    w00 = ((1 - lx) * (1 - ly))[..., None]  # (Qb,P,1)
    w10 = (lx * (1 - ly))[..., None]
    w01 = ((1 - lx) * ly)[..., None]
    w11 = (lx * ly)[..., None]

    # ---- phase 1: vector-only grads (paper: element-wise vector ops) -----
    sampled = v00 * w00 + v10 * w10 + v01 * w01 + v11 * w11  # (Qb,P,D)
    gattn_ref[0, 0] = jnp.einsum("qd,qpd->qp", gout, sampled).astype(gattn_ref.dtype)

    g_s = attn[..., None] * gout[:, None, :]  # (Qb,P,D): dL/d(sampled)
    # d sampled / d px = (v10 - v00)(1-ly) + (v11 - v01) ly   (masked corners
    # are zeroed, matching grid_sample zero-padding gradients)
    dpx = ((v10 - v00) * (1 - ly)[..., None] + (v11 - v01) * ly[..., None])
    dpy = ((v01 - v00) * (1 - lx)[..., None] + (v11 - v10) * lx[..., None])
    glx = jnp.einsum("qpd,qpd->qp", g_s, dpx) * W
    gly = jnp.einsum("qpd,qpd->qp", g_s, dpy) * H
    gloc_ref[0, 0] = jnp.stack([glx, gly], axis=-1).astype(gloc_ref.dtype)

    # ---- phase 2: scatter-add grad_value into the resident slab ----------
    @pl.when(q_idx == 0)
    def _init():
        gval_ref[0, 0] = jnp.zeros_like(gval_ref[0, 0])

    c00 = (g_s * w00 * m00[..., None]).reshape(-1, D)
    c10 = (g_s * w10 * m10[..., None]).reshape(-1, D)
    c01 = (g_s * w01 * m01[..., None]).reshape(-1, D)
    c11 = (g_s * w11 * m11[..., None]).reshape(-1, D)
    slab = gval_ref[0, 0]
    if onehot_scatter:
        # Beyond-paper MXU path: scatter-add as a transposed one-hot
        # matmul (HWp, 4QbP) @ (4QbP, D) — contention-free by algebra
        # (duplicate indices sum inside the dot), no serialized scatter.
        all_idx = jnp.concatenate([i00, i00 + 1, i00 + Wp, i00 + Wp + 1])
        contrib = jnp.concatenate([c00, c10, c01, c11], axis=0)
        onehot = (jnp.arange(slab.shape[0])[:, None] == all_idx[None, :]).astype(
            jnp.float32
        )
        gval_ref[0, 0] = slab + (onehot @ contrib).astype(slab.dtype)
    elif fuse_scatter:
        all_idx = jnp.concatenate([i00, i00 + 1, i00 + Wp, i00 + Wp + 1])
        contrib = jnp.concatenate([c00, c10, c01, c11], axis=0)
        gval_ref[0, 0] = slab.at[all_idx].add(contrib.astype(slab.dtype))
    else:
        # ablation: four separate per-corner scatters
        slab = slab.at[i00].add(c00.astype(slab.dtype))
        slab = slab.at[i00 + 1].add(c10.astype(slab.dtype))
        slab = slab.at[i00 + Wp].add(c01.astype(slab.dtype))
        slab = slab.at[i00 + Wp + 1].add(c11.astype(slab.dtype))
        gval_ref[0, 0] = slab


def msda_bwd_level(
    value_l: Optional[jax.Array],  # (B, H, HWp, D) or None when saved given
    loc_l: jax.Array,              # (B, H, Q, P, 2)
    attn_l: jax.Array,             # (B, H, Q, P)
    gout: jax.Array,               # (B, H, Q, D)
    saved_l: Optional[jax.Array],  # (B, H, Q, 4P, D) or None
    *,
    hw: Tuple[int, int],
    hwp_rows: int,
    block_q: int,
    fuse_scatter: bool = True,
    onehot_scatter: bool = False,
    interpret: bool = False,
    accum_dtype=jnp.float32,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-level backward.

    Returns (grad_value_slab in ``accum_dtype``, grad_loc, grad_attn).
    """
    B, Hh, Q, P, _ = loc_l.shape
    D = gout.shape[-1]
    Hl, Wl = hw
    Wp = Wl + 2
    assert Q % block_q == 0, (Q, block_q)
    nq = Q // block_q

    kernel = functools.partial(
        _bwd_kernel, H=Hl, W=Wl, Wp=Wp, fuse_scatter=fuse_scatter,
        onehot_scatter=onehot_scatter,
    )

    in_specs = []
    operands = []
    if saved_l is None:
        assert value_l is not None
        in_specs.append(pl.BlockSpec((1, 1, hwp_rows, D), lambda b, h, q: (b, h, 0, 0)))
        operands.append(value_l)
        kernel_fn = functools.partial(_regather_wrap, kernel)
    else:
        in_specs.append(
            pl.BlockSpec((1, 1, block_q, 4 * P, D), lambda b, h, q: (b, h, q, 0, 0))
        )
        operands.append(saved_l)
        kernel_fn = functools.partial(_saved_wrap, kernel)
    in_specs += [
        pl.BlockSpec((1, 1, block_q, P, 2), lambda b, h, q: (b, h, q, 0, 0)),
        pl.BlockSpec((1, 1, block_q, P), lambda b, h, q: (b, h, q, 0)),
        pl.BlockSpec((1, 1, block_q, D), lambda b, h, q: (b, h, q, 0)),
    ]
    operands += [loc_l, attn_l, gout]

    gval, gloc, gattn = pl.pallas_call(
        kernel_fn,
        grid=(B, Hh, nq),
        in_specs=in_specs,
        out_specs=[
            # grad slab: revisited/accumulated across q, written back once
            pl.BlockSpec((1, 1, hwp_rows, D), lambda b, h, q: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_q, P, 2), lambda b, h, q: (b, h, q, 0, 0)),
            pl.BlockSpec((1, 1, block_q, P), lambda b, h, q: (b, h, q, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hh, hwp_rows, D), jnp.dtype(accum_dtype)),
            jax.ShapeDtypeStruct((B, Hh, Q, P, 2), loc_l.dtype),
            jax.ShapeDtypeStruct((B, Hh, Q, P), attn_l.dtype),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
    return gval, gloc, gattn


# --------------------------------------------------------------------------
# fused whole-pyramid backward: ONE pallas launch for all L levels
# --------------------------------------------------------------------------


def _bwd_fused_kernel(
    value_ref,  # (1, 1, R, D) packed super-slab (None when saved given)
    loc_ref,    # (1, 1, Qb, L, P, 2)
    attn_ref,   # (1, 1, Qb, L, P)
    gout_ref,   # (1, 1, Qb, D)
    saved_ref,  # (1, 1, Qb, L*4P, D) packed corners (None if regather)
    gval_ref,   # out: (1, 1, R, D) accum dtype, accumulated across q
    gloc_ref,   # out: (1, 1, Qb, L, P, 2)
    gattn_ref,  # out: (1, 1, Qb, L, P)
    *,
    hws: Shapes,
    row_offsets: Tuple[int, ...],
    fuse_scatter: bool,
    onehot_levels: Tuple[bool, ...] = (),
    slab_dtypes: Tuple[str, ...] = (),
    gather_offsets: Tuple[int, ...] = (),
):
    """Whole-pyramid backward step.

    Phase 1 (grad loc/attn) is the per-level vector math looped over the
    packed levels; phase 2 scatter-adds EVERY level's corner
    contribution into the one resident grad super-slab — for the VPU
    levels via a single merged ``.at[idx].add`` whose indices are lifted
    by the static per-level row offsets (levels occupy disjoint row
    ranges, so the merge is contention-free by construction), for
    one-hot levels via the MXU matmul against their own sub-slab rows.
    ``gout`` is streamed ONCE for the whole pyramid instead of once per
    level, and the grad super-slab goes to HBM exactly once.

    Mixed-dtype super-slabs (``slab_dtypes``) only change the regather
    side: the value slab is carrier-coded so its row offsets
    (``gather_offsets``) differ from the grad super-slab's — the grad
    slab is ALWAYS a uniform accum-dtype array at the plain
    ``row_offsets`` layout, so phase 2 is untouched.
    """
    q_idx = pl.program_id(2)

    loc = loc_ref[0, 0].astype(jnp.float32)  # (Qb, L, P, 2)
    attn = attn_ref[0, 0].astype(jnp.float32)  # (Qb, L, P)
    gout = gout_ref[0, 0].astype(jnp.float32)  # (Qb, D)
    Qb, L, P, _ = loc.shape
    D = gout.shape[-1]

    cidx, geom = msda_fwd.fused_level_corner_indices(loc, hws)
    onehot = tuple(onehot_levels) if onehot_levels else (False,) * L

    def _corner_idx(l):
        return cidx[l]

    # ---- corners: saved by fwd (packed, no gather) or re-gathered --------
    if saved_ref is not None:
        packed = saved_ref[0, 0].astype(jnp.float32)  # (Qb, L*4P, D)
        corners = [
            [c.reshape(Qb * P, D)
             for c in jnp.split(packed[:, l * 4 * P:(l + 1) * 4 * P], 4, axis=1)]
            for l in range(L)
        ]
    else:
        # same routing as the forward: shared helper, directions can't drift
        corners = msda_fwd.fused_gather_corners(
            value_ref[0, 0], cidx,
            tuple(gather_offsets) or row_offsets, onehot,
            fuse_gather=True, slab_dtypes=slab_dtypes)

    # ---- phase 1 per level + collect phase-2 scatter contributions -------
    glocs, gattns = [], []
    contribs = [None] * L  # per level: (c00, c10, c01, c11) each (Qb*P, D)
    for l, (Hl, Wl) in enumerate(hws):
        lx, ly, (m00, m10, m01, m11) = geom[l]
        v00, v10, v01, v11 = (c.reshape(Qb, P, D) for c in corners[l])
        v00 = v00 * m00[..., None]
        v10 = v10 * m10[..., None]
        v01 = v01 * m01[..., None]
        v11 = v11 * m11[..., None]
        w00 = ((1 - lx) * (1 - ly))[..., None]
        w10 = (lx * (1 - ly))[..., None]
        w01 = ((1 - lx) * ly)[..., None]
        w11 = (lx * ly)[..., None]

        sampled = v00 * w00 + v10 * w10 + v01 * w01 + v11 * w11
        gattns.append(jnp.einsum("qd,qpd->qp", gout, sampled))

        g_s = attn[:, l][..., None] * gout[:, None, :]  # (Qb,P,D)
        dpx = ((v10 - v00) * (1 - ly)[..., None] + (v11 - v01) * ly[..., None])
        dpy = ((v01 - v00) * (1 - lx)[..., None] + (v11 - v10) * lx[..., None])
        glx = jnp.einsum("qpd,qpd->qp", g_s, dpx) * Wl
        gly = jnp.einsum("qpd,qpd->qp", g_s, dpy) * Hl
        glocs.append(jnp.stack([glx, gly], axis=-1))

        contribs[l] = (
            (g_s * w00 * m00[..., None]).reshape(-1, D),
            (g_s * w10 * m10[..., None]).reshape(-1, D),
            (g_s * w01 * m01[..., None]).reshape(-1, D),
            (g_s * w11 * m11[..., None]).reshape(-1, D),
        )
    gattn_ref[0, 0] = jnp.stack(gattns, axis=1).astype(gattn_ref.dtype)
    gloc_ref[0, 0] = jnp.stack(glocs, axis=1).astype(gloc_ref.dtype)

    # ---- phase 2: scatter-add into the ONE resident grad super-slab ------
    @pl.when(q_idx == 0)
    def _init():
        gval_ref[0, 0] = jnp.zeros_like(gval_ref[0, 0])

    slab = gval_ref[0, 0]
    vpu = [l for l in range(L) if not onehot[l]]
    if vpu:
        if fuse_scatter:
            # one merged scatter across corners, points AND levels
            big = jnp.concatenate(
                [c + row_offsets[l] for l in vpu for c in _corner_idx(l)])
            upd = jnp.concatenate([c for l in vpu for c in contribs[l]], axis=0)
            slab = slab.at[big].add(upd.astype(slab.dtype))
        else:
            # ablation: four merged per-corner scatters
            for c in range(4):
                big = jnp.concatenate(
                    [_corner_idx(l)[c] + row_offsets[l] for l in vpu])
                upd = jnp.concatenate([contribs[l][c] for l in vpu], axis=0)
                slab = slab.at[big].add(upd.astype(slab.dtype))
    for l in range(L):
        if not onehot[l]:
            continue
        end = row_offsets[l + 1] if l + 1 < L else slab.shape[0]
        rows = end - row_offsets[l]
        all_idx = jnp.concatenate(_corner_idx(l))
        contrib = jnp.concatenate(contribs[l], axis=0)
        oh = (jnp.arange(rows)[:, None] == all_idx[None, :]).astype(jnp.float32)
        slab = slab.at[row_offsets[l]:end].add((oh @ contrib).astype(slab.dtype))
    gval_ref[0, 0] = slab


def msda_bwd_fused(
    value_p: Optional[jax.Array],  # (B, H, R, D) or None when saved given
    loc_f: jax.Array,              # (B, H, Q, L, P, 2)
    attn_f: jax.Array,             # (B, H, Q, L, P)
    gout: jax.Array,               # (B, H, Q, D)
    saved_p: Optional[jax.Array],  # (B, H, Q, L*4P, D) or None
    *,
    hws: Shapes,
    row_offsets: Tuple[int, ...],
    total_rows: int,
    block_q: int,
    fuse_scatter: bool = True,
    onehot_levels: Tuple[bool, ...] = (),
    interpret: bool = False,
    accum_dtype=jnp.float32,
    slab_dtypes: Tuple[str, ...] = (),
    gather_offsets: Tuple[int, ...] = (),
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Whole-pyramid backward: ONE ``pallas_call`` for all levels.

    Returns ``(grad_value super-slab in accum_dtype, grad_loc,
    grad_attn)`` — the grad slab covers every level (packed layout,
    written back to HBM exactly once when the (batch, head) block
    retires); grad_loc/grad_attn come back ``(B, H, Q, L, P, ...)``.
    ``row_offsets`` / ``total_rows`` describe the (uniform accum-dtype)
    grad super-slab; a mixed-dtype value slab passes its own carrier
    layout via ``slab_dtypes`` + ``gather_offsets`` for the regather.
    """
    B, Hh, Q, L, P, _ = loc_f.shape
    D = gout.shape[-1]
    assert Q % block_q == 0, (Q, block_q)
    nq = Q // block_q

    kernel = functools.partial(
        _bwd_fused_kernel, hws=tuple(hws), row_offsets=tuple(row_offsets),
        fuse_scatter=fuse_scatter, onehot_levels=tuple(onehot_levels),
        slab_dtypes=tuple(slab_dtypes), gather_offsets=tuple(gather_offsets),
    )

    in_specs = []
    operands = []
    if saved_p is None:
        assert value_p is not None
        # the value slab's own row extent, NOT total_rows: a mixed-dtype
        # carrier slab holds MORE rows than the plain grad layout
        in_specs.append(
            pl.BlockSpec((1, 1, value_p.shape[2], D),
                         lambda b, h, q: (b, h, 0, 0)))
        operands.append(value_p)
        kernel_fn = functools.partial(_regather_wrap, kernel)
    else:
        in_specs.append(
            pl.BlockSpec((1, 1, block_q, L * 4 * P, D),
                         lambda b, h, q: (b, h, q, 0, 0)))
        operands.append(saved_p)
        kernel_fn = functools.partial(_saved_wrap, kernel)
    in_specs += [
        pl.BlockSpec((1, 1, block_q, L, P, 2),
                     lambda b, h, q: (b, h, q, 0, 0, 0)),
        pl.BlockSpec((1, 1, block_q, L, P), lambda b, h, q: (b, h, q, 0, 0)),
        pl.BlockSpec((1, 1, block_q, D), lambda b, h, q: (b, h, q, 0)),
    ]
    operands += [loc_f, attn_f, gout]

    gval, gloc, gattn = pl.pallas_call(
        kernel_fn,
        grid=(B, Hh, nq),
        in_specs=in_specs,
        out_specs=[
            # grad super-slab: accumulated across q, written back once
            pl.BlockSpec((1, 1, total_rows, D), lambda b, h, q: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_q, L, P, 2),
                         lambda b, h, q: (b, h, q, 0, 0, 0)),
            pl.BlockSpec((1, 1, block_q, L, P), lambda b, h, q: (b, h, q, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hh, total_rows, D), jnp.dtype(accum_dtype)),
            jax.ShapeDtypeStruct((B, Hh, Q, L, P, 2), loc_f.dtype),
            jax.ShapeDtypeStruct((B, Hh, Q, L, P), attn_f.dtype),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
    return gval, gloc, gattn


# --------------------------------------------------------------------------
# ring-reduced grad_value slabs (the 2D dp x tp distribution path)
# --------------------------------------------------------------------------


def ring_allreduce(x: jax.Array, axis_name: str, axis_size: int,
                   *, axis: int = 1) -> jax.Array:
    """All-reduce ``x`` over ``axis_name`` as an explicit ppermute ring.

    QUILL's cache-locality argument, applied across chips: the per-shard
    partial ``grad_value`` slabs a query-sharded backward produces should
    *circulate* — one slab shard resident per step — instead of
    round-tripping through a monolithic all-reduce that materialises the
    full fp32 slab twice per hop.  Classic two-phase ring over the
    ``axis_size`` neighbours:

    * reduce-scatter: ``x`` is chunked along ``axis`` into ``axis_size``
      shards; each step every device forwards its running partial one
      hop (``jax.lax.ppermute``) and folds in its own copy of the chunk
      that just arrived.  After N-1 hops device *i* owns chunk
      ``(i+1) % N`` fully reduced — peak extra residency is ONE chunk,
      not the whole slab.
    * all-gather: the reduced chunks take N-1 more hops around the same
      ring, each device slotting the passing chunk into its output.

    2(N-1) hops of 1/N of the slab — bandwidth-optimal, and every add
    runs in ``x.dtype`` (the caller keeps the slab in fp32/accum dtype).
    Each chunk's final value sums the device partials in ring order (a
    rotation of the device order per chunk); for N=2 that is bitwise
    identical to ``psum`` because fp addition is commutative — the
    parity the conformance tests pin down.

    The chunk axis is zero-padded up to a multiple of ``axis_size``
    (grad slabs are zero there anyway; sums of zeros stay zero).
    """
    n = int(axis_size)
    if n <= 1:
        return x
    xt = jnp.moveaxis(x, axis, 0)
    rows = xt.shape[0]
    pad = (-rows) % n
    if pad:
        xt = jnp.pad(xt, ((0, pad),) + ((0, 0),) * (xt.ndim - 1))
    parts = xt.reshape((n, (rows + pad) // n) + xt.shape[1:])
    i = jax.lax.axis_index(axis_name)
    perm = [(j, (j + 1) % n) for j in range(n)]

    # reduce-scatter: circulate one running chunk per device
    send = jax.lax.dynamic_index_in_dim(parts, i, axis=0, keepdims=False)
    for s in range(n - 1):
        recv = jax.lax.ppermute(send, axis_name, perm)
        k = (i - s - 1) % n
        send = recv + jax.lax.dynamic_index_in_dim(parts, k, axis=0,
                                                   keepdims=False)
    # all-gather: the reduced chunks take another lap
    out = jnp.zeros_like(parts)
    cur = send
    out = jax.lax.dynamic_update_index_in_dim(out, cur, (i + 1) % n, axis=0)
    for s in range(1, n):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        out = jax.lax.dynamic_update_index_in_dim(out, cur, (i + 1 - s) % n,
                                                  axis=0)
    out = out.reshape((rows + pad,) + xt.shape[1:])
    if pad:
        out = out[:rows]
    return jnp.moveaxis(out, 0, axis)


def _regather_wrap(kernel, value_ref, loc_ref, attn_ref, gout_ref, gval_ref, gloc_ref, gattn_ref):
    kernel(value_ref, loc_ref, attn_ref, gout_ref, None, gval_ref, gloc_ref, gattn_ref)


def _saved_wrap(kernel, saved_ref, loc_ref, attn_ref, gout_ref, gval_ref, gloc_ref, gattn_ref):
    kernel(None, loc_ref, attn_ref, gout_ref, saved_ref, gval_ref, gloc_ref, gattn_ref)
