# MSDA kernel package — the compute hot-spot the paper optimizes.
#
# Public surface:
#   plan.MsdaSpec / plan.msda_plan / plan.MsdaPlan — plan/execute API
#   registry.register_backend / registry.list_backends — backend registry
#   ops.msda — legacy one-shot shim over the plan cache
#   ref.msda_ref — pure-jnp oracle
from repro.kernels.plan import (  # noqa: F401
    MsdaPlan,
    MsdaSpec,
    clear_plans,
    configure_plan_cache,
    msda_plan,
    plan_cache_info,
)
from repro.kernels.registry import (  # noqa: F401
    list_backends,
    register_backend,
    resolve_backend,
)
