"""Sparsity-aware MSDA executors: top-k point pruning + Morton query order.

Two plan rungs the related work motivates (ROADMAP "Sparsity-aware
plans"), both committed at plan time like every other axis:

* **Top-k point pruning** (DEFA, arxiv 2403.10913): most of a trained
  MSDA head's attention mass concentrates in a few (level, point) cells
  per query, so keeping only the ``k`` highest-weight cells,
  renormalising, and gathering only the surviving corners cuts the
  gather count from ``4*L*P`` to ``4*k`` per query.  This is LOSSY —
  the dense path stays the always-available fallback, conformance
  checks the pruned executor against :func:`topk_mask_weights` +
  ``msda_ref`` under its own tolerance tier, and ``tune="autotune"``
  races pruned-vs-dense instead of trusting the ~2x FLOP cut to
  translate into wall time.

* **Morton query permutation** (QUILL, arxiv 2511.13679): when the
  query grid IS the pixel grid (the encoder layout, ``Q == S``),
  sorting queries by the Z-curve order of their reference pixels makes
  spatially-near queries adjacent, so a query block's corner gathers
  cluster within a slab row instead of striding the whole level.  The
  permutation is applied to loc/attn at the executor boundary and
  inverted on the output — per-query MSDA math is independent along Q,
  so the forward result and the loc/attn gradients are BITWISE
  unchanged (grad_value changes only its scatter accumulation order).

Selection uses ``jax.lax.top_k`` (deterministic, ties broken by lowest
index), so the executor and the conformance oracle always prune the
SAME cells — parity is a rounding question, never a selection gamble.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Shapes = Tuple[Tuple[int, int], ...]

# renormalisation guard: softmaxed weights are positive, but the pruned
# executor and the masked oracle must share ONE denominator convention
# so VJP parity holds on any input conformance throws at them
_RENORM_FLOOR = 1e-20


# --------------------------------------------------------------------------
# Morton (Z-curve) query ordering
# --------------------------------------------------------------------------


def morton_codes(h: int, w: int) -> np.ndarray:
    """Z-curve code of every (y, x) pixel of an ``h x w`` grid, raster
    order — interleaves the coordinate bits (x even, y odd)."""
    ys, xs = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    y = ys.reshape(-1).astype(np.uint64)
    x = xs.reshape(-1).astype(np.uint64)
    code = np.zeros(h * w, dtype=np.uint64)
    for b in range(max(int(h).bit_length(), int(w).bit_length())):
        code |= ((x >> np.uint64(b)) & np.uint64(1)) << np.uint64(2 * b)
        code |= ((y >> np.uint64(b)) & np.uint64(1)) << np.uint64(2 * b + 1)
    return code


def morton_permutation(spatial_shapes: Shapes) -> np.ndarray:
    """``perm[i]`` = raster-order query index of the i-th Morton-ordered
    query.  Per level (each level's queries are its own raster grid —
    ``core.msda.level_ref_points``), offset by the level's start, so the
    permutation never mixes levels."""
    parts = []
    off = 0
    for h, w in spatial_shapes:
        order = np.argsort(morton_codes(h, w), kind="stable")
        parts.append(order.astype(np.int64) + off)
        off += h * w
    if not parts:
        return np.zeros(0, dtype=np.int64)
    return np.concatenate(parts)


def morton_eligible(spec) -> bool:
    """The permutation is statically known only when the query grid is
    the pixel grid (encoder layout): one query per pixel, raster order."""
    return spec.num_queries == spec.total_pixels and spec.num_queries > 1


def wrap_query_permutation(exec_fn: Callable, spatial_shapes: Shapes) -> Callable:
    """Executor wrapper: loc/attn enter Morton-ordered, output leaves in
    the caller's (raster) order.  Bitwise-neutral for the forward and
    the loc/attn gradients (per-query independence; the permutation is
    a bijection so its VJP scatter has no collisions)."""
    perm = morton_permutation(spatial_shapes)
    inv = np.argsort(perm)
    perm_j = jnp.asarray(perm, dtype=jnp.int32)
    inv_j = jnp.asarray(inv, dtype=jnp.int32)

    def run(value, loc, attn):
        out = exec_fn(value, jnp.take(loc, perm_j, axis=1),
                      jnp.take(attn, perm_j, axis=1))
        return jnp.take(out, inv_j, axis=1)

    return run


# --------------------------------------------------------------------------
# top-k point pruning
# --------------------------------------------------------------------------


def topk_mask_weights(attn: jax.Array, k: int) -> jax.Array:
    """Dense-shaped oracle weights: the ``k`` highest of each query's
    ``L*P`` cells kept and renormalised, the rest zeroed.  Conformance
    feeds these to ``msda_ref`` to get the pruned executor's exact
    mathematical target (same ``top_k`` selection, same denominator)."""
    B, Q, H, L, P = attn.shape
    w = attn.reshape(B, Q, H, L * P).astype(jnp.float32)
    topw, topi = jax.lax.top_k(w, k)
    keep = jnp.sum(jax.nn.one_hot(topi, L * P, dtype=w.dtype), axis=-2)
    kept = w * keep
    den = jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), _RENORM_FLOOR)
    return (kept / den).reshape(B, Q, H, L, P).astype(attn.dtype)


def gather_counts(spec) -> Dict[str, int]:
    """Per-query gather arithmetic of pruned vs dense (benchmark report)."""
    cells = spec.num_levels * spec.num_points
    k = spec.resolved_sparsity_k()
    return {
        "dense_cells": cells,
        "topk_cells": k,
        "dense_corner_gathers": 4 * cells,
        "topk_corner_gathers": 4 * k,
        "gather_reduction": 1.0 - k / cells,
    }


def build_topk_exec(spec) -> Callable:
    """Pruned executor for ``spec``: top-k cell selection, renormalise,
    gather ONLY the surviving cells' corners (``4*k`` per query instead
    of ``4*L*P``).  Pure jnp — XLA AD provides the VJP, every backend
    shares it (the dense backend executor is the fallback the planner
    swaps back in for ``sparsity="off"`` / losing races).

    fp32 compute regardless of the slab policy (like the ref oracle):
    the pruned tier's tolerance budget is spent on the pruning, not on
    narrow-dtype gathers.
    """
    shapes = spec.spatial_shapes
    L, P = spec.num_levels, spec.num_points
    k = spec.resolved_sparsity_k()
    hs = jnp.asarray([h for h, _ in shapes], dtype=jnp.int32)
    ws = jnp.asarray([w for _, w in shapes], dtype=jnp.int32)
    sizes = [h * w for h, w in shapes]
    offs = jnp.asarray(np.cumsum([0] + sizes)[:-1], dtype=jnp.int32)

    def run(value, loc, attn):
        B, S, H, D = value.shape
        Q = loc.shape[1]
        w = attn.reshape(B, Q, H, L * P).astype(jnp.float32)
        topw, topi = jax.lax.top_k(w, k)                    # (B,Q,H,k)
        den = jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), _RENORM_FLOOR)
        topw = topw / den
        locf = loc.reshape(B, Q, H, L * P, 2).astype(jnp.float32)
        sel = jnp.take_along_axis(locf, topi[..., None], axis=3)  # (B,Q,H,k,2)
        lvl = topi // P
        hl = jnp.take(hs, lvl)                              # (B,Q,H,k) int32
        wl = jnp.take(ws, lvl)
        off = jnp.take(offs, lvl)
        # grid_sample(align_corners=False) corners, per surviving cell
        px = sel[..., 0] * wl.astype(jnp.float32) - 0.5
        py = sel[..., 1] * hl.astype(jnp.float32) - 0.5
        x0f = jnp.floor(px)
        y0f = jnp.floor(py)
        lx = px - x0f
        ly = py - y0f
        x0 = x0f.astype(jnp.int32)
        y0 = y0f.astype(jnp.int32)
        value_t = jnp.transpose(value, (0, 2, 1, 3)).astype(jnp.float32)

        def corner(xi, yi):
            inb = (xi >= 0) & (xi < wl) & (yi >= 0) & (yi < hl)
            xc = jnp.clip(xi, 0, wl - 1)
            yc = jnp.clip(yi, 0, hl - 1)
            flat = off + yc * wl + xc                       # (B,Q,H,k)
            idx = jnp.transpose(flat, (0, 2, 1, 3)).reshape(B, H, Q * k)
            g = jnp.take_along_axis(value_t, idx[..., None], axis=2)
            g = jnp.transpose(g.reshape(B, H, Q, k, D), (0, 2, 1, 3, 4))
            return g * inb[..., None].astype(g.dtype)

        w00 = (1 - lx) * (1 - ly)
        w10 = lx * (1 - ly)
        w01 = (1 - lx) * ly
        w11 = lx * ly
        sampled = (corner(x0, y0) * w00[..., None]
                   + corner(x0 + 1, y0) * w10[..., None]
                   + corner(x0, y0 + 1) * w01[..., None]
                   + corner(x0 + 1, y0 + 1) * w11[..., None])  # (B,Q,H,k,D)
        out = jnp.einsum("bqhkd,bqhk->bqhd", sampled, topw)
        return out.reshape(B, Q, H * D).astype(value.dtype)

    return run
