"""MSDA kernel glue + the legacy one-shot ``msda(...)`` shim.

The *planning* surface lives in ``repro.kernels.plan`` (``MsdaSpec`` →
``msda_plan`` → ``MsdaPlan``) and the backend registry in
``repro.kernels.registry``; this module keeps

* the layout/padding contract and per-level kernel drivers
  (``_fwd_impl`` / ``_bwd_impl`` / ``build_kernel_op``) the pallas
  backend builder compiles into an executor,
* the heuristic block planner (``plan_blocks`` — the paper's adaptive
  vec-len model, Fig. 7) and the MXU one-hot routing rule
  (``plan_onehot``), both invoked once per plan, and
* ``msda(...)``: a thin compatibility shim that builds a spec, fetches
  the cached plan, and executes it.  Per-call tuning kwargs
  (``block_q``, ``fuse_gather``, …) are deprecated — commit them on the
  spec / plan instead.

The layout/padding contract between the wrapper and the kernels:
each level is zero-padded from ``(H, W)`` to ``(H+2, W+2)`` (leading +
trailing pad row/column — the paper's §4.1 padding fix, re-derived for
branch-free corner pairs) and flattened row-major to a slab of
``hwp_rows = round_up((H+2) * (W+2), 8)`` rows × ``D`` lanes.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import msda_bwd, msda_fwd, ref

Shapes = Tuple[Tuple[int, int], ...]

# Legacy default block-planning budget (v5e-class part).  Plans carry an
# explicit per-device budget on the spec (plan.default_vmem_budget); this
# constant only backs direct plan_blocks() calls that don't pass one.
VMEM_BUDGET = 32 * 2**20
_SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def slab_rows(hw: Tuple[int, int]) -> int:
    h, w = hw
    return _round_up((h + 2) * (w + 2), _SUBLANE)


def per_query_bytes(num_points: int, head_dim: int) -> int:
    """Per-query VMEM working set: 4 corners x P points x D lanes in fp32,
    ~4 concurrent copies (gathered, weighted, contribs, temporaries).

    Single source of truth for the paper's occupancy model — used by the
    block planner below and by ``MsdaPlan.level_report``.
    """
    return 4 * num_points * head_dim * 4 * 4 + num_points * 64


def plan_blocks(
    spatial_shapes: Shapes,
    num_points: int,
    head_dim: int,
    num_queries: int,
    *,
    value_itemsize: int = 4,
    train: bool = True,
    vmem_budget: int = VMEM_BUDGET,
    adaptive: bool = True,
    accum_itemsize: int = 4,
) -> Tuple[int, ...]:
    """Per-level query-block sizes (the paper's adaptive vec-len, Fig. 7).

    Larger levels leave less VMEM for per-step tensors, so their blocks
    shrink; tiny levels get wide blocks (long vectors).  ``adaptive=False``
    reproduces the "-Adaptive VecLen" ablation (fixed minimal block).

    ``value_itemsize`` is the itemsize of the dtype the value slab is
    *stored* in (a bf16-slab plan halves residency and widens blocks);
    ``accum_itemsize`` sizes the train-mode grad slab, which stays wide
    (fp32) regardless of the slab dtype.
    """
    out = []
    for hw in spatial_shapes:
        if not adaptive:
            out.append(_SUBLANE)
            continue
        resident = slab_rows(hw) * head_dim * value_itemsize
        if train:  # bwd keeps a widened (accum-dtype) grad slab too
            resident += slab_rows(hw) * head_dim * accum_itemsize
        avail = max(vmem_budget - resident, 1 * 2**20)
        per_q = per_query_bytes(num_points, head_dim)
        bq = avail // per_q
        bq = max(_SUBLANE, min(2048, (bq // _SUBLANE) * _SUBLANE))
        bq = min(bq, _round_up(num_queries, _SUBLANE))
        out.append(int(bq))
    return tuple(out)


@dataclass(frozen=True)
class MSDAParams:
    """Static (hashable) kernel configuration."""

    spatial_shapes: Shapes
    block_q: Tuple[int, ...]
    fuse_gather: bool = True
    fuse_scatter: bool = True
    save_sampled: bool = False
    interpret: bool = True
    # per-level: route sampling through the MXU via one-hot matmuls
    # (beyond-paper; profitable for small levels where HWp fits an MXU
    # operand and the VPU gather would under-fill the vector unit)
    onehot_levels: Tuple[bool, ...] = ()
    # mixed precision: per-level dtype the VMEM value slab is stored in
    # ('' entries / empty tuple -> keep the operand dtype) and the dtype
    # partial outputs + the bwd grad slab accumulate in
    slab_dtypes: Tuple[str, ...] = ()
    accum_dtype: str = "float32"
    # dtype the grad_value must be emitted in (custom-VJP contract with
    # the primal); '' -> infer from the residual slab (legacy behaviour,
    # only correct when slab dtype == operand dtype)
    io_dtype: str = ""

    def slab_dtype(self, level: int) -> str:
        if self.slab_dtypes and self.slab_dtypes[level]:
            return self.slab_dtypes[level]
        return ""


# levels with padded slabs up to this many rows use the MXU one-hot path
ONEHOT_MAX_ROWS = 1152


def plan_onehot(spatial_shapes: Shapes) -> Tuple[bool, ...]:
    return tuple(slab_rows(hw) <= ONEHOT_MAX_ROWS for hw in spatial_shapes)


def _pad_level(value_t: jax.Array, offset: int, hw: Tuple[int, int]) -> jax.Array:
    """(B,H,S,D) -> zero-padded level slab (B,H,hwp_rows,D)."""
    B, Hh, S, D = value_t.shape
    h, w = hw
    lvl = jax.lax.dynamic_slice_in_dim(value_t, offset, h * w, axis=2)
    lvl = lvl.reshape(B, Hh, h, w, D)
    lvl = jnp.pad(lvl, ((0, 0), (0, 0), (1, 1), (1, 1), (0, 0)))
    lvl = lvl.reshape(B, Hh, (h + 2) * (w + 2), D)
    rows = slab_rows(hw)
    extra = rows - (h + 2) * (w + 2)
    if extra:
        lvl = jnp.pad(lvl, ((0, 0), (0, 0), (0, extra), (0, 0)))
    return lvl


def _unpad_grad(slab: jax.Array, hw: Tuple[int, int]) -> jax.Array:
    """Inverse of _pad_level for the grad slab: (B,H,rows,D) -> (B,H,HW,D)."""
    B, Hh, rows, D = slab.shape
    h, w = hw
    slab = slab[:, :, : (h + 2) * (w + 2)].reshape(B, Hh, h + 2, w + 2, D)
    return slab[:, :, 1 : h + 1, 1 : w + 1].reshape(B, Hh, h * w, D)


def _pad_q(x: jax.Array, q_axis: int, qpad: int, fill=0.0) -> jax.Array:
    q = x.shape[q_axis]
    if q == qpad:
        return x
    pads = [(0, 0)] * x.ndim
    pads[q_axis] = (0, qpad - q)
    return jnp.pad(x, pads, constant_values=fill)


def _fwd_impl(p: MSDAParams, value, loc, attn):
    """Kernel-backed forward. Returns (out, residuals)."""
    B, S, Hh, D = value.shape
    _, Q, _, L, P, _ = loc.shape
    # (B,S,H,D) -> (B,H,S,D); (B,Q,H,L,P,2) -> (B,H,L,Q,P,2)
    value_t = jnp.transpose(value, (0, 2, 1, 3))
    loc_t = jnp.transpose(loc, (0, 2, 3, 1, 4, 5))
    attn_t = jnp.transpose(attn, (0, 2, 3, 1, 4))

    accum = jnp.dtype(p.accum_dtype)
    out = jnp.zeros((B, Hh, Q, D), accum)
    slabs, saved_all = [], []
    offset = 0
    for l, hw in enumerate(p.spatial_shapes):
        bq = p.block_q[l]
        qpad = _round_up(Q, bq)
        slab = _pad_level(value_t, offset, hw)
        sdt = p.slab_dtype(l)
        if sdt:  # committed slab dtype (may narrow: bf16 slab, fp32 accum)
            slab = slab.astype(sdt)
        offset += hw[0] * hw[1]
        loc_l = _pad_q(loc_t[:, :, l], 2, qpad, 0.5)
        attn_l = _pad_q(attn_t[:, :, l], 2, qpad, 0.0)
        onehot = p.onehot_levels[l] if p.onehot_levels else False
        out_l, saved_l = msda_fwd.msda_fwd_level(
            slab,
            loc_l,
            attn_l,
            hw=hw,
            block_q=bq,
            fuse_gather=p.fuse_gather,
            save_sampled=p.save_sampled,
            onehot_gather=onehot,
            interpret=p.interpret,
            out_dtype=accum,
        )
        out = out + out_l[:, :, :Q]
        slabs.append(slab)
        saved_all.append(saved_l)
    out = jnp.transpose(out, (0, 2, 1, 3)).reshape(B, Q, Hh * D)
    out = out.astype(value.dtype)
    if p.save_sampled:
        residuals = (None, tuple(saved_all), loc_t, attn_t)
    else:
        residuals = (tuple(slabs), None, loc_t, attn_t)
    return out, residuals


def _bwd_impl(p: MSDAParams, residuals, gout):
    slabs, saved_all, loc_t, attn_t = residuals
    B, Hh, L, Q, P, _ = loc_t.shape
    HD = gout.shape[-1]
    D = HD // Hh
    gout_t = jnp.transpose(gout.reshape(B, Q, Hh, D), (0, 2, 1, 3))  # (B,H,Q,D)

    gvals, glocs, gattns = [], [], []
    for l, hw in enumerate(p.spatial_shapes):
        bq = p.block_q[l]
        qpad = _round_up(Q, bq)
        loc_l = _pad_q(loc_t[:, :, l], 2, qpad, 0.5)
        attn_l = _pad_q(attn_t[:, :, l], 2, qpad, 0.0)
        gout_l = _pad_q(gout_t, 2, qpad, 0.0)
        saved_l = saved_all[l] if saved_all is not None else None
        slab_l = slabs[l] if slabs is not None else None
        gval, gloc, gattn = msda_bwd.msda_bwd_level(
            slab_l,
            loc_l,
            attn_l,
            gout_l,
            saved_l,
            hw=hw,
            hwp_rows=slab_rows(hw),
            block_q=bq,
            fuse_scatter=p.fuse_scatter,
            onehot_scatter=p.onehot_levels[l] if p.onehot_levels else False,
            interpret=p.interpret,
            accum_dtype=p.accum_dtype,
        )
        gvals.append(_unpad_grad(gval, hw))
        glocs.append(gloc[:, :, :Q])
        gattns.append(gattn[:, :, :Q])

    gvalue = jnp.concatenate(gvals, axis=2)  # (B,H,S,D) accum dtype
    gvalue = jnp.transpose(gvalue, (0, 2, 1, 3))
    gloc = jnp.stack(glocs, axis=2)  # (B,H,L,Q,P,2)
    gloc = jnp.transpose(gloc, (0, 3, 1, 2, 4, 5))  # (B,Q,H,L,P,2)
    gattn = jnp.stack(gattns, axis=2)  # (B,H,L,Q,P)
    gattn = jnp.transpose(gattn, (0, 3, 1, 2, 4))  # (B,Q,H,L,P)
    return gvalue, gloc, gattn


def build_kernel_op(p: MSDAParams):
    """Custom-VJP executor for one committed kernel configuration.

    Deliberately *uncached*: the bounded plan cache in
    ``repro.kernels.plan`` owns the lifetime of compiled ops (and its
    ``clear_plans()`` hook lets long-lived serving processes drop them) —
    the old unbounded ``lru_cache`` here leaked one op per distinct
    config forever.
    """

    @jax.custom_vjp
    def op(value, loc, attn):
        return _fwd_impl(p, value, loc, attn)[0]

    def fwd(value, loc, attn):
        out, res = _fwd_impl(p, value, loc, attn)
        return out, res

    def bwd(res, gout):
        slabs, saved_all, loc_t, attn_t = res
        # grad_value must match the *operand* dtype, which a bf16-slab
        # plan no longer shares with the residual slabs
        vdt = p.io_dtype or (slabs[0] if slabs is not None else saved_all[0]).dtype
        gvalue, gloc, gattn = _bwd_impl(p, res, gout)
        return gvalue.astype(vdt), gloc.astype(loc_t.dtype), gattn.astype(attn_t.dtype)

    op.defvjp(fwd, bwd)
    return op


def resolve_backend(backend: str) -> str:
    from repro.kernels import registry

    return registry.resolve_backend(backend)


_UNSET = object()
_WARNED_KWARGS: set = set()


def _deprecated_kwarg(name: str) -> None:
    if name not in _WARNED_KWARGS:
        _WARNED_KWARGS.add(name)
        warnings.warn(
            f"ops.msda(..., {name}=...) is deprecated: commit tuning on an "
            "MsdaSpec and build a plan via repro.kernels.plan.msda_plan "
            "(the shim still honours the kwarg)",
            DeprecationWarning,
            stacklevel=3,
        )


def msda(
    value: jax.Array,
    spatial_shapes: Shapes,
    sampling_locations: jax.Array,
    attention_weights: jax.Array,
    *,
    backend: str = "auto",
    train: bool = False,
    dtype_policy: str = "follow",
    block_q=_UNSET,
    fuse_gather=_UNSET,
    fuse_scatter=_UNSET,
    adaptive_block=_UNSET,
    onehot_small_levels=_UNSET,
    interpret=_UNSET,
) -> jax.Array:
    """Multi-scale deformable attention (differentiable) — compat shim.

    value: (B, S, H, D); sampling_locations: (B, Q, H, L, P, 2) in [0,1];
    attention_weights: (B, Q, H, L, P); returns (B, Q, H*D).

    This entry point now builds an :class:`~repro.kernels.plan.MsdaSpec`
    from the operands and executes the cached
    :class:`~repro.kernels.plan.MsdaPlan` — repeated calls with an
    identical spec never re-run block planning.  ``dtype_policy``
    ('follow' | 'float32' | 'bfloat16' | 'auto') commits the
    mixed-precision plan variant (bf16 slab + fp32 accumulate; see
    ``plan.resolve_dtype_policy``).  The per-call tuning kwargs
    (``block_q``, ``fuse_gather``, ``fuse_scatter``,
    ``adaptive_block``, ``onehot_small_levels``, ``interpret``) are
    deprecated; put them on the spec / plan instead.
    """
    from repro.kernels import plan as plan_mod

    slab_dtype, accum_dtype = plan_mod.resolve_dtype_policy(dtype_policy)
    overrides = {"slab_dtype": slab_dtype, "accum_dtype": accum_dtype}
    for name, val in (("fuse_gather", fuse_gather), ("fuse_scatter", fuse_scatter),
                      ("adaptive_block", adaptive_block),
                      ("onehot_small_levels", onehot_small_levels)):
        if val is not _UNSET:
            _deprecated_kwarg(name)
            overrides[name] = val
    plan_kwargs = {}
    for name, val in (("block_q", block_q), ("interpret", interpret)):
        if val is not _UNSET:
            _deprecated_kwarg(name)
            plan_kwargs[name] = tuple(val) if name == "block_q" and val is not None else val

    spec = plan_mod.spec_from_arrays(
        value, spatial_shapes, sampling_locations, attention_weights,
        train=train, **overrides)
    plan = plan_mod.msda_plan(spec, backend=backend, **plan_kwargs)
    return plan(value, sampling_locations, attention_weights)
