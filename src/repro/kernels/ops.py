"""MSDA kernel glue + the legacy one-shot ``msda(...)`` shim.

The *planning* surface lives in ``repro.kernels.plan`` (``MsdaSpec`` →
``msda_plan`` → ``MsdaPlan``) and the backend registry in
``repro.kernels.registry``; this module keeps

* the layout/padding contract and the kernel drivers
  (``_fwd_impl`` / ``_bwd_impl`` / ``build_kernel_op``) the pallas
  backend builder compiles into an executor — per-level launches, or
  the fused whole-pyramid pair (``MSDAParams.fuse_levels``: all levels
  packed into one super-slab via ``_pack_pyramid`` /
  ``pyramid_row_offsets``, ONE pallas launch per direction),
* the heuristic block planner (``plan_blocks`` — the paper's adaptive
  vec-len model, Fig. 7) and the MXU one-hot routing rule
  (``plan_onehot``), both invoked once per plan, and
* ``msda(...)``: a thin compatibility shim that builds a spec, fetches
  the cached plan, and executes it.  Per-call tuning kwargs
  (``block_q``, ``fuse_gather``, …) are deprecated — commit them on the
  spec / plan instead.

The layout/padding contract between the wrapper and the kernels:
each level is zero-padded from ``(H, W)`` to ``(H+2, W+2)`` (leading +
trailing pad row/column — the paper's §4.1 padding fix, re-derived for
branch-free corner pairs) and flattened row-major to a slab of
``hwp_rows = round_up((H+2) * (W+2), 8)`` rows × ``D`` lanes.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import msda_bwd, msda_fwd, ref

Shapes = Tuple[Tuple[int, int], ...]

# Legacy default block-planning budget (v5e-class part).  Plans carry an
# explicit per-device budget on the spec (plan.default_vmem_budget); this
# constant only backs direct plan_blocks() calls that don't pass one.
VMEM_BUDGET = 32 * 2**20
_SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def slab_rows(hw: Tuple[int, int]) -> int:
    h, w = hw
    return _round_up((h + 2) * (w + 2), _SUBLANE)


def per_query_bytes(num_points: int, head_dim: int, *, train: bool = False,
                    slab_itemsize: int = 4, levels: int = 1) -> int:
    """Per-query VMEM working set: 4 corners x P points x D lanes in fp32,
    ~4 concurrent copies (gathered, weighted, contribs, temporaries).

    ``train=True`` adds the saved-corner OUTPUT block the forward kernel
    keeps resident per step (``4P x D`` rows per query in the slab
    dtype, streamed to HBM for the backward) — omitting it made train
    plans overshoot the budget.  ``levels > 1`` scales the whole set for
    the fused whole-pyramid kernels, whose every query step touches all
    L levels.

    Single source of truth for the paper's occupancy model — used by the
    block planner below and by ``MsdaPlan.level_report``.
    """
    per_level = 4 * num_points * head_dim * 4 * 4 + num_points * 64
    if train:  # saved-corner output block: (block_q, 4P, D) slab dtype
        per_level += 4 * num_points * head_dim * slab_itemsize
    return levels * per_level


def pyramid_row_offsets(spatial_shapes: Shapes) -> Tuple[Tuple[int, ...], int]:
    """Static row offsets of each level inside the packed super-slab.

    Returns ``(offsets, total_rows)``: level ``l`` occupies rows
    ``[offsets[l], offsets[l] + slab_rows(hw_l))`` of the row-major
    ``(total_rows, D)`` super-slab (every level's slab is already padded
    to a sublane multiple, so the offsets stay aligned).
    """
    offs, total = [], 0
    for hw in spatial_shapes:
        offs.append(total)
        total += slab_rows(hw)
    return tuple(offs), total


def _per_level_itemsizes(spatial_shapes: Shapes, value_itemsize) -> Tuple[int, ...]:
    """Normalise a scalar-or-per-level itemsize spec to a per-level tuple."""
    if isinstance(value_itemsize, (tuple, list)):
        assert len(value_itemsize) == len(spatial_shapes), (
            value_itemsize, spatial_shapes)
        return tuple(int(i) for i in value_itemsize)
    return (int(value_itemsize),) * len(spatial_shapes)


def fused_resident_bytes(spatial_shapes: Shapes, head_dim: int, *,
                         slab_itemsize=4, train: bool = True,
                         accum_itemsize: int = 4) -> int:
    """VMEM-resident bytes of the fused whole-pyramid kernels.

    Σ slab_rows(hw) x D in each level's COMMITTED slab dtype
    (``slab_itemsize`` may be a per-level sequence — the mixed-dtype
    super-slab stores every level at its own width), plus — in train
    mode — the same row extent again in the accum dtype for the resident
    grad super-slab.  The ONE definition of the packed pyramid's
    residency: the fitting rung, the fused block planner and
    ``MsdaPlan.level_report`` all read it from here.
    """
    items = _per_level_itemsizes(spatial_shapes, slab_itemsize)
    _, total = pyramid_row_offsets(spatial_shapes)
    resident = sum(slab_rows(hw) * head_dim * it
                   for hw, it in zip(spatial_shapes, items))
    if train:
        resident += total * head_dim * accum_itemsize
    return resident


def fusion_prefix(
    spatial_shapes: Shapes,
    num_points: int,
    head_dim: int,
    *,
    value_itemsize=4,
    train: bool = True,
    vmem_budget: int = VMEM_BUDGET,
    accum_itemsize: int = 4,
) -> int:
    """The planner's partial-fusion occupancy model.

    Returns the largest level prefix length ``k`` such that the packed
    super-slab of levels ``[0..k)`` (:func:`fused_resident_bytes`, each
    level at its committed itemsize) PLUS a minimal one-sublane query
    step's working set over those ``k`` levels fits ``vmem_budget`` —
    ``k == len(spatial_shapes)`` means the whole pyramid fuses, ``0``
    means not even a single level does.  The fused launch covers
    ``[0..k)`` and the tail runs per-level, so launches per direction
    drop from ``L`` to ``L - k + 1``.
    """
    L = len(spatial_shapes)
    items = _per_level_itemsizes(spatial_shapes, value_itemsize)
    for k in range(L, 0, -1):
        resident = fused_resident_bytes(
            spatial_shapes[:k], head_dim, slab_itemsize=items[:k],
            train=train, accum_itemsize=accum_itemsize)
        per_q = per_query_bytes(num_points, head_dim, train=train,
                                slab_itemsize=max(items[:k]), levels=k)
        if resident + _SUBLANE * per_q <= vmem_budget:
            return k
    return 0


def fused_pyramid_fits(
    spatial_shapes: Shapes,
    num_points: int,
    head_dim: int,
    *,
    value_itemsize=4,
    train: bool = True,
    vmem_budget: int = VMEM_BUDGET,
    accum_itemsize: int = 4,
) -> bool:
    """Whole-pyramid fitting rung: does the FULL prefix fit?

    Thin compatibility wrapper over :func:`fusion_prefix` — fused-all
    exactly when the largest fitting prefix is the whole pyramid.
    """
    return fusion_prefix(
        spatial_shapes, num_points, head_dim, value_itemsize=value_itemsize,
        train=train, vmem_budget=vmem_budget,
        accum_itemsize=accum_itemsize) == len(spatial_shapes)


def plan_blocks(
    spatial_shapes: Shapes,
    num_points: int,
    head_dim: int,
    num_queries: int,
    *,
    value_itemsize=4,
    train: bool = True,
    vmem_budget: int = VMEM_BUDGET,
    adaptive: bool = True,
    accum_itemsize: int = 4,
    fused: bool = False,
) -> Tuple[int, ...]:
    """Per-level query-block sizes (the paper's adaptive vec-len, Fig. 7).

    Larger levels leave less VMEM for per-step tensors, so their blocks
    shrink; tiny levels get wide blocks (long vectors).  ``adaptive=False``
    reproduces the "-Adaptive VecLen" ablation (fixed minimal block).

    ``value_itemsize`` is the itemsize of the dtype the value slab is
    *stored* in (a bf16-slab plan halves residency and widens blocks) —
    a scalar, or a per-level sequence when the committed slab dtypes
    mix; ``accum_itemsize`` sizes the train-mode grad slab, which stays
    wide (fp32) regardless of the slab dtype.  The per-step working set
    includes the train-mode saved-corner output block (see
    :func:`per_query_bytes`).

    ``fused=True`` plans the whole-pyramid kernel instead: the resident
    set is the PACKED super-slab (all given levels at their own
    itemsizes, plus the train grad super-slab) and one shared block
    serves every level — returned replicated per level so the tuple
    shape stays uniform.  To plan a partial-fusion prefix, pass the
    prefix's shapes/itemsizes only.
    """
    def _clamp(bq: int) -> int:
        bq = max(_SUBLANE, min(2048, (bq // _SUBLANE) * _SUBLANE))
        return min(bq, _round_up(num_queries, _SUBLANE))

    items = _per_level_itemsizes(spatial_shapes, value_itemsize)
    if fused:
        L = len(spatial_shapes)
        if not adaptive:
            return (_SUBLANE,) * L
        resident = fused_resident_bytes(
            spatial_shapes, head_dim, slab_itemsize=items,
            train=train, accum_itemsize=accum_itemsize)
        avail = max(vmem_budget - resident, 1 * 2**20)
        per_q = per_query_bytes(num_points, head_dim, train=train,
                                slab_itemsize=max(items), levels=L)
        return (int(_clamp(avail // per_q)),) * L

    out = []
    for hw, it in zip(spatial_shapes, items):
        if not adaptive:
            out.append(_SUBLANE)
            continue
        resident = slab_rows(hw) * head_dim * it
        if train:  # bwd keeps a widened (accum-dtype) grad slab too
            resident += slab_rows(hw) * head_dim * accum_itemsize
        avail = max(vmem_budget - resident, 1 * 2**20)
        per_q = per_query_bytes(num_points, head_dim, train=train,
                                slab_itemsize=it)
        out.append(int(_clamp(avail // per_q)))
    return tuple(out)


@dataclass(frozen=True)
class MSDAParams:
    """Static (hashable) kernel configuration."""

    spatial_shapes: Shapes
    block_q: Tuple[int, ...]
    fuse_gather: bool = True
    fuse_scatter: bool = True
    save_sampled: bool = False
    interpret: bool = True
    # per-level: route sampling through the MXU via one-hot matmuls
    # (beyond-paper; profitable for small levels where HWp fits an MXU
    # operand and the VPU gather would under-fill the vector unit)
    onehot_levels: Tuple[bool, ...] = ()
    # mixed precision: per-level dtype the VMEM value slab is stored in
    # ('' entries / empty tuple -> keep the operand dtype) and the dtype
    # partial outputs + the bwd grad slab accumulate in
    slab_dtypes: Tuple[str, ...] = ()
    accum_dtype: str = "float32"
    # dtype the grad_value must be emitted in (custom-VJP contract with
    # the primal); '' -> infer from the residual slab (legacy behaviour,
    # only correct when slab dtype == operand dtype)
    io_dtype: str = ""
    # fused whole-pyramid kernels: levels packed into ONE super-slab,
    # one pallas launch per direction with a single shared block_q
    # (block_q[0]; the planner replicates it across the fused levels)
    fuse_levels: bool = False
    # partial fusion: number of levels in the fused prefix [0..k).
    # 0 means "all levels" when fuse_levels is set (legacy whole-pyramid
    # fusion); 0 < k < L runs ONE fused launch over the prefix plus
    # per-level launches for the tail, summed into the same accumulator.
    fuse_prefix: int = 0

    def slab_dtype(self, level: int) -> str:
        if self.slab_dtypes and self.slab_dtypes[level]:
            return self.slab_dtypes[level]
        return ""

    def fused_prefix_len(self) -> int:
        """Committed fused prefix length k: L when fully fused, 0 when
        per-level, else the strict prefix ``0 < k < L``."""
        L = len(self.spatial_shapes)
        if not self.fuse_levels:
            return 0
        return min(self.fuse_prefix, L) if self.fuse_prefix else L

    def fused_slab_dtypes(self, operand_dtype) -> Tuple[str, ...]:
        """Per-level storage dtypes INSIDE the packed super-slab: each
        level keeps its committed slab dtype (operand dtype where
        uncommitted), so bf16-winner levels keep their residency win
        under fusion — the slab is carrier-coded when they mix (see
        :func:`packed_pyramid_layout`)."""
        return tuple(self.slab_dtype(l) or str(jnp.dtype(operand_dtype))
                     for l in range(len(self.spatial_shapes)))


# levels with padded slabs up to this many rows use the MXU one-hot path
ONEHOT_MAX_ROWS = 1152


def plan_onehot(spatial_shapes: Shapes) -> Tuple[bool, ...]:
    return tuple(slab_rows(hw) <= ONEHOT_MAX_ROWS for hw in spatial_shapes)


def _pad_level(value_t: jax.Array, offset: int, hw: Tuple[int, int]) -> jax.Array:
    """(B,H,S,D) -> zero-padded level slab (B,H,hwp_rows,D)."""
    B, Hh, S, D = value_t.shape
    h, w = hw
    lvl = jax.lax.dynamic_slice_in_dim(value_t, offset, h * w, axis=2)
    lvl = lvl.reshape(B, Hh, h, w, D)
    lvl = jnp.pad(lvl, ((0, 0), (0, 0), (1, 1), (1, 1), (0, 0)))
    lvl = lvl.reshape(B, Hh, (h + 2) * (w + 2), D)
    rows = slab_rows(hw)
    extra = rows - (h + 2) * (w + 2)
    if extra:
        lvl = jnp.pad(lvl, ((0, 0), (0, 0), (0, extra), (0, 0)))
    return lvl


def _unpad_grad(slab: jax.Array, hw: Tuple[int, int]) -> jax.Array:
    """Inverse of _pad_level for the grad slab: (B,H,rows,D) -> (B,H,HW,D)."""
    B, Hh, rows, D = slab.shape
    h, w = hw
    slab = slab[:, :, : (h + 2) * (w + 2)].reshape(B, Hh, h + 2, w + 2, D)
    return slab[:, :, 1 : h + 1, 1 : w + 1].reshape(B, Hh, h * w, D)


def _pad_q(x: jax.Array, q_axis: int, qpad: int, fill=0.0) -> jax.Array:
    q = x.shape[q_axis]
    if q == qpad:
        return x
    pads = [(0, 0)] * x.ndim
    pads[q_axis] = (0, qpad - q)
    return jnp.pad(x, pads, constant_values=fill)


def packed_pyramid_layout(spatial_shapes: Shapes,
                          dtype_names: Tuple[str, ...]):
    """Carrier layout of a (possibly mixed-dtype) packed super-slab.

    One JAX array has one dtype, so a super-slab whose levels commit
    DIFFERENT dtypes is stored in an UNSIGNED-INT *carrier* whose
    itemsize is the narrowest committed itemsize, with each level's
    rows reinterpreted byte-for-byte: a level whose itemsize is
    ``ratio`` x the carrier's occupies ``slab_rows(hw) * ratio``
    carrier rows.  ``slab_rows`` is always a sublane multiple and
    ``ratio >= 1``, so every offset stays aligned.  The carrier must
    be an integer dtype: reinterpreting fp32 halves as bfloat16 can
    produce NaN bit patterns that backends silently canonicalise in
    transit (payload 0x7fc0), corrupting the wide level's low bytes —
    integer lanes move bytes verbatim.

    Returns ``(carrier, offsets, total, ratios)``: carrier dtype name,
    per-level CARRIER row offsets, total carrier rows, and per-level
    carrier-rows-per-logical-row.  With uniform dtypes the committed
    dtype itself is the carrier and this degenerates to exactly
    :func:`pyramid_row_offsets` (ratios all 1).
    """
    names = tuple(str(jnp.dtype(d)) for d in dtype_names)
    assert len(names) == len(spatial_shapes), (names, spatial_shapes)
    if len(set(names)) == 1:
        carrier = names[0]
    else:
        carrier = f"uint{8 * min(jnp.dtype(n).itemsize for n in names)}"
    ci = jnp.dtype(carrier).itemsize
    ratios = tuple(jnp.dtype(n).itemsize // ci for n in names)
    offs, total = [], 0
    for hw, r in zip(spatial_shapes, ratios):
        offs.append(total)
        total += slab_rows(hw) * r
    return carrier, tuple(offs), total, ratios


def _encode_packed_level(lvl: jax.Array, carrier) -> jax.Array:
    """(B,H,rows,D) level slab -> (B,H,rows*ratio,D) carrier rows.

    Row-major byte reinterpretation — the exact inverse of
    ``msda_fwd.decode_packed_rows`` (ratio consecutive carrier rows per
    logical row, consecutive carrier elements per wide element).
    """
    dt = jnp.dtype(carrier)
    if lvl.dtype == dt:
        return lvl
    ratio = lvl.dtype.itemsize // dt.itemsize
    out = jax.lax.bitcast_convert_type(lvl, dt)
    if ratio == 1:  # same itemsize, different dtype: shape unchanged
        return out
    B, Hh, rows, D = lvl.shape
    return out.reshape(B, Hh, rows * ratio, D)


def _pack_pyramid(value_t: jax.Array, spatial_shapes: Shapes,
                  dtype=None, dtypes: Tuple[str, ...] = ()) -> jax.Array:
    """(B,H,S,D) -> packed super-slab (B,H,total_rows,D), every level
    zero-padded to its ``slab_rows`` extent at its static row offset.

    ``dtype`` casts the whole slab uniformly (legacy whole-pyramid
    path); ``dtypes`` instead commits a PER-LEVEL storage dtype — each
    level is cast to its own dtype and byte-packed into the carrier
    layout of :func:`packed_pyramid_layout`.
    """
    if dtypes:
        carrier, _, _, _ = packed_pyramid_layout(spatial_shapes, dtypes)
        parts = []
        offset = 0
        for hw, dt in zip(spatial_shapes, dtypes):
            lvl = _pad_level(value_t, offset, hw).astype(dt)
            parts.append(_encode_packed_level(lvl, carrier))
            offset += hw[0] * hw[1]
        return jnp.concatenate(parts, axis=2)
    parts = []
    offset = 0
    for hw in spatial_shapes:
        parts.append(_pad_level(value_t, offset, hw))
        offset += hw[0] * hw[1]
    slab = jnp.concatenate(parts, axis=2)
    if dtype is not None and slab.dtype != jnp.dtype(dtype):
        slab = slab.astype(dtype)
    return slab


def _unpack_grad_pyramid(slab: jax.Array, spatial_shapes: Shapes) -> jax.Array:
    """Inverse of :func:`_pack_pyramid` for the grad super-slab:
    (B,H,total_rows,D) -> (B,H,S,D)."""
    outs = []
    r = 0
    for hw in spatial_shapes:
        rows = slab_rows(hw)
        outs.append(_unpad_grad(slab[:, :, r:r + rows], hw))
        r += rows
    return jnp.concatenate(outs, axis=2)


def _fused_launch_meta(p: MSDAParams, operand_dtype, k: int):
    """Static layout of the fused prefix launch over levels [0..k):
    (per-level dtype names, carrier gather offsets, plain grad offsets,
    grad total rows, mixed?)."""
    hws = p.spatial_shapes[:k]
    dtypes = p.fused_slab_dtypes(operand_dtype)[:k]
    carrier, goffs, _, _ = packed_pyramid_layout(hws, dtypes)
    row_offsets, total_rows = pyramid_row_offsets(hws)
    mixed = any(str(jnp.dtype(d)) != carrier for d in dtypes)
    return dtypes, goffs, row_offsets, total_rows, mixed


def _fwd_impl_fused(p: MSDAParams, value, loc, attn):
    """Fused whole-pyramid forward: ONE pallas launch. Returns (out, res)."""
    B, S, Hh, D = value.shape
    _, Q, _, L, P, _ = loc.shape
    # (B,S,H,D) -> (B,H,S,D); (B,Q,H,L,P,2) -> (B,H,Q,L,P,2)
    value_t = jnp.transpose(value, (0, 2, 1, 3))
    loc_f = jnp.transpose(loc, (0, 2, 1, 3, 4, 5))
    attn_f = jnp.transpose(attn, (0, 2, 1, 3, 4))

    accum = jnp.dtype(p.accum_dtype)
    dtypes, goffs, _, _, mixed = _fused_launch_meta(p, value.dtype, L)
    slab = _pack_pyramid(value_t, p.spatial_shapes, dtypes=dtypes)
    bq = p.block_q[0]
    qpad = _round_up(Q, bq)
    loc_f = _pad_q(loc_f, 2, qpad, 0.5)
    attn_f = _pad_q(attn_f, 2, qpad, 0.0)
    out, saved = msda_fwd.msda_fwd_fused(
        slab,
        loc_f,
        attn_f,
        hws=p.spatial_shapes,
        row_offsets=goffs,
        block_q=bq,
        fuse_gather=p.fuse_gather,
        save_sampled=p.save_sampled,
        onehot_levels=p.onehot_levels,
        interpret=p.interpret,
        out_dtype=accum,
        slab_dtypes=dtypes if mixed else (),
    )
    out = jnp.transpose(out[:, :, :Q], (0, 2, 1, 3)).reshape(B, Q, Hh * D)
    out = out.astype(value.dtype)
    if p.save_sampled:
        residuals = (None, saved, loc_f, attn_f)
    else:
        residuals = (slab, None, loc_f, attn_f)
    return out, residuals


def _bwd_impl_fused(p: MSDAParams, residuals, gout):
    """Fused whole-pyramid backward: ONE pallas launch."""
    slab, saved, loc_f, attn_f = residuals
    B, Hh, Qpad, L, P, _ = loc_f.shape
    HD = gout.shape[-1]
    D = HD // Hh
    Q = gout.shape[1]
    gout_t = jnp.transpose(gout.reshape(B, Q, Hh, D), (0, 2, 1, 3))
    gout_t = _pad_q(gout_t, 2, Qpad, 0.0)
    io_dtype = p.io_dtype or (slab.dtype if saved is None else saved.dtype)
    dtypes, goffs, row_offsets, total_rows, mixed = _fused_launch_meta(
        p, io_dtype, L)
    gval, gloc, gattn = msda_bwd.msda_bwd_fused(
        slab,
        loc_f,
        attn_f,
        gout_t,
        saved,
        hws=p.spatial_shapes,
        row_offsets=row_offsets,
        total_rows=total_rows,
        block_q=p.block_q[0],
        fuse_scatter=p.fuse_scatter,
        onehot_levels=p.onehot_levels,
        interpret=p.interpret,
        accum_dtype=p.accum_dtype,
        slab_dtypes=dtypes if mixed else (),
        gather_offsets=goffs if mixed else (),
    )
    gvalue = _unpack_grad_pyramid(gval, p.spatial_shapes)  # (B,H,S,D)
    gvalue = jnp.transpose(gvalue, (0, 2, 1, 3))
    gloc = jnp.transpose(gloc[:, :, :Q], (0, 2, 1, 3, 4, 5))  # (B,Q,H,L,P,2)
    gattn = jnp.transpose(gattn[:, :, :Q], (0, 2, 1, 3, 4))  # (B,Q,H,L,P)
    return gvalue, gloc, gattn


def _fwd_impl_prefix(p: MSDAParams, k: int, value, loc, attn):
    """Partial-fusion forward: ONE fused launch over levels [0..k) plus
    per-level launches for the tail, summed into the same accumulator —
    ``L - k + 1`` launches instead of ``L``.  Returns (out, res)."""
    B, S, Hh, D = value.shape
    _, Q, _, L, P, _ = loc.shape
    value_t = jnp.transpose(value, (0, 2, 1, 3))
    # fused-layout loc/attn (query-major); tail levels slice level l out
    loc_f = jnp.transpose(loc, (0, 2, 1, 3, 4, 5))   # (B,H,Q,L,P,2)
    attn_f = jnp.transpose(attn, (0, 2, 1, 3, 4))    # (B,H,Q,L,P)

    accum = jnp.dtype(p.accum_dtype)
    dtypes, goffs, _, _, mixed = _fused_launch_meta(p, value.dtype, k)
    slab_pre = _pack_pyramid(value_t, p.spatial_shapes[:k], dtypes=dtypes)

    bq0 = p.block_q[0]
    qpad0 = _round_up(Q, bq0)
    out_pre, saved_pre = msda_fwd.msda_fwd_fused(
        slab_pre,
        _pad_q(loc_f[:, :, :, :k], 2, qpad0, 0.5),
        _pad_q(attn_f[:, :, :, :k], 2, qpad0, 0.0),
        hws=p.spatial_shapes[:k],
        row_offsets=goffs,
        block_q=bq0,
        fuse_gather=p.fuse_gather,
        save_sampled=p.save_sampled,
        onehot_levels=p.onehot_levels[:k] if p.onehot_levels else (),
        interpret=p.interpret,
        out_dtype=accum,
        slab_dtypes=dtypes if mixed else (),
    )
    out = out_pre[:, :, :Q]  # (B,H,Q,D) accum dtype

    tail_slabs, tail_saved = [], []
    offset = sum(h * w for h, w in p.spatial_shapes[:k])
    for l in range(k, L):
        hw = p.spatial_shapes[l]
        bq = p.block_q[l]
        qpad = _round_up(Q, bq)
        slab = _pad_level(value_t, offset, hw)
        sdt = p.slab_dtype(l)
        if sdt:
            slab = slab.astype(sdt)
        offset += hw[0] * hw[1]
        out_l, saved_l = msda_fwd.msda_fwd_level(
            slab,
            _pad_q(loc_f[:, :, :, l], 2, qpad, 0.5),
            _pad_q(attn_f[:, :, :, l], 2, qpad, 0.0),
            hw=hw,
            block_q=bq,
            fuse_gather=p.fuse_gather,
            save_sampled=p.save_sampled,
            onehot_gather=p.onehot_levels[l] if p.onehot_levels else False,
            interpret=p.interpret,
            out_dtype=accum,
        )
        out = out + out_l[:, :, :Q]
        tail_slabs.append(slab)
        tail_saved.append(saved_l)
    out = jnp.transpose(out, (0, 2, 1, 3)).reshape(B, Q, Hh * D)
    out = out.astype(value.dtype)
    # residuals carry UNPADDED loc/attn in the fused layout — fwd/bwd
    # re-pad per launch (the fused prefix and each tail level may
    # commit different block sizes)
    loc_r = loc_f[:, :, :Q]
    attn_r = attn_f[:, :, :Q]
    if p.save_sampled:
        residuals = (None, (saved_pre, *tail_saved), loc_r, attn_r)
    else:
        residuals = ((slab_pre, *tail_slabs), None, loc_r, attn_r)
    return out, residuals


def _bwd_impl_prefix(p: MSDAParams, k: int, residuals, gout):
    """Partial-fusion backward: ONE fused launch over the prefix plus
    per-level launches for the tail."""
    slabs, saved_all, loc_f, attn_f = residuals
    B, Hh, Q, L, P, _ = loc_f.shape
    HD = gout.shape[-1]
    D = HD // Hh
    gout_t = jnp.transpose(gout.reshape(B, Q, Hh, D), (0, 2, 1, 3))  # (B,H,Q,D)

    slab_pre = slabs[0] if slabs is not None else None
    saved_pre = saved_all[0] if saved_all is not None else None
    io_dtype = p.io_dtype or (slab_pre if slab_pre is not None
                              else saved_pre).dtype
    dtypes, goffs, row_offsets, total_rows, mixed = _fused_launch_meta(
        p, io_dtype, k)

    bq0 = p.block_q[0]
    qpad0 = _round_up(Q, bq0)
    gval_pre, gloc_pre, gattn_pre = msda_bwd.msda_bwd_fused(
        slab_pre,
        _pad_q(loc_f[:, :, :, :k], 2, qpad0, 0.5),
        _pad_q(attn_f[:, :, :, :k], 2, qpad0, 0.0),
        _pad_q(gout_t, 2, qpad0, 0.0),
        saved_pre,
        hws=p.spatial_shapes[:k],
        row_offsets=row_offsets,
        total_rows=total_rows,
        block_q=bq0,
        fuse_scatter=p.fuse_scatter,
        onehot_levels=p.onehot_levels[:k] if p.onehot_levels else (),
        interpret=p.interpret,
        accum_dtype=p.accum_dtype,
        slab_dtypes=dtypes if mixed else (),
        gather_offsets=goffs if mixed else (),
    )
    gvals = [_unpack_grad_pyramid(gval_pre, p.spatial_shapes[:k])]
    glocs = [gloc_pre[:, :, :Q]]    # (B,H,Q,k,P,2)
    gattns = [gattn_pre[:, :, :Q]]  # (B,H,Q,k,P)

    for l in range(k, L):
        hw = p.spatial_shapes[l]
        bq = p.block_q[l]
        qpad = _round_up(Q, bq)
        saved_l = saved_all[1 + l - k] if saved_all is not None else None
        slab_l = slabs[1 + l - k] if slabs is not None else None
        gval, gloc, gattn = msda_bwd.msda_bwd_level(
            slab_l,
            _pad_q(loc_f[:, :, :, l], 2, qpad, 0.5),
            _pad_q(attn_f[:, :, :, l], 2, qpad, 0.0),
            _pad_q(gout_t, 2, qpad, 0.0),
            saved_l,
            hw=hw,
            hwp_rows=slab_rows(hw),
            block_q=bq,
            fuse_scatter=p.fuse_scatter,
            onehot_scatter=p.onehot_levels[l] if p.onehot_levels else False,
            interpret=p.interpret,
            accum_dtype=p.accum_dtype,
        )
        gvals.append(_unpad_grad(gval, hw))
        glocs.append(gloc[:, :, :Q])    # (B,H,Q,P,2)
        gattns.append(gattn[:, :, :Q])  # (B,H,Q,P)

    gvalue = jnp.concatenate(gvals, axis=2)  # (B,H,S,D) accum dtype
    gvalue = jnp.transpose(gvalue, (0, 2, 1, 3))
    # tail grads are (B,H,Q,P,...) per level — lift to the L axis and
    # append after the prefix block
    gloc = jnp.concatenate(
        [glocs[0]] + [g.reshape(B, Hh, Q, 1, P, 2) for g in glocs[1:]], axis=3)
    gattn = jnp.concatenate(
        [gattns[0]] + [g.reshape(B, Hh, Q, 1, P) for g in gattns[1:]], axis=3)
    gloc = jnp.transpose(gloc, (0, 2, 1, 3, 4, 5))  # (B,Q,H,L,P,2)
    gattn = jnp.transpose(gattn, (0, 2, 1, 3, 4))   # (B,Q,H,L,P)
    return gvalue, gloc, gattn


def _fwd_impl(p: MSDAParams, value, loc, attn):
    """Kernel-backed forward. Returns (out, residuals)."""
    k = p.fused_prefix_len()
    if k == len(p.spatial_shapes) and k:
        return _fwd_impl_fused(p, value, loc, attn)
    if k:
        return _fwd_impl_prefix(p, k, value, loc, attn)
    B, S, Hh, D = value.shape
    _, Q, _, L, P, _ = loc.shape
    # (B,S,H,D) -> (B,H,S,D); (B,Q,H,L,P,2) -> (B,H,L,Q,P,2)
    value_t = jnp.transpose(value, (0, 2, 1, 3))
    loc_t = jnp.transpose(loc, (0, 2, 3, 1, 4, 5))
    attn_t = jnp.transpose(attn, (0, 2, 3, 1, 4))

    accum = jnp.dtype(p.accum_dtype)
    out = jnp.zeros((B, Hh, Q, D), accum)
    slabs, saved_all = [], []
    offset = 0
    for l, hw in enumerate(p.spatial_shapes):
        bq = p.block_q[l]
        qpad = _round_up(Q, bq)
        slab = _pad_level(value_t, offset, hw)
        sdt = p.slab_dtype(l)
        if sdt:  # committed slab dtype (may narrow: bf16 slab, fp32 accum)
            slab = slab.astype(sdt)
        offset += hw[0] * hw[1]
        loc_l = _pad_q(loc_t[:, :, l], 2, qpad, 0.5)
        attn_l = _pad_q(attn_t[:, :, l], 2, qpad, 0.0)
        onehot = p.onehot_levels[l] if p.onehot_levels else False
        out_l, saved_l = msda_fwd.msda_fwd_level(
            slab,
            loc_l,
            attn_l,
            hw=hw,
            block_q=bq,
            fuse_gather=p.fuse_gather,
            save_sampled=p.save_sampled,
            onehot_gather=onehot,
            interpret=p.interpret,
            out_dtype=accum,
        )
        out = out + out_l[:, :, :Q]
        slabs.append(slab)
        saved_all.append(saved_l)
    out = jnp.transpose(out, (0, 2, 1, 3)).reshape(B, Q, Hh * D)
    out = out.astype(value.dtype)
    if p.save_sampled:
        residuals = (None, tuple(saved_all), loc_t, attn_t)
    else:
        residuals = (tuple(slabs), None, loc_t, attn_t)
    return out, residuals


def _bwd_impl(p: MSDAParams, residuals, gout):
    k = p.fused_prefix_len()
    if k == len(p.spatial_shapes) and k:
        return _bwd_impl_fused(p, residuals, gout)
    if k:
        return _bwd_impl_prefix(p, k, residuals, gout)
    slabs, saved_all, loc_t, attn_t = residuals
    B, Hh, L, Q, P, _ = loc_t.shape
    HD = gout.shape[-1]
    D = HD // Hh
    gout_t = jnp.transpose(gout.reshape(B, Q, Hh, D), (0, 2, 1, 3))  # (B,H,Q,D)

    gvals, glocs, gattns = [], [], []
    for l, hw in enumerate(p.spatial_shapes):
        bq = p.block_q[l]
        qpad = _round_up(Q, bq)
        loc_l = _pad_q(loc_t[:, :, l], 2, qpad, 0.5)
        attn_l = _pad_q(attn_t[:, :, l], 2, qpad, 0.0)
        gout_l = _pad_q(gout_t, 2, qpad, 0.0)
        saved_l = saved_all[l] if saved_all is not None else None
        slab_l = slabs[l] if slabs is not None else None
        gval, gloc, gattn = msda_bwd.msda_bwd_level(
            slab_l,
            loc_l,
            attn_l,
            gout_l,
            saved_l,
            hw=hw,
            hwp_rows=slab_rows(hw),
            block_q=bq,
            fuse_scatter=p.fuse_scatter,
            onehot_scatter=p.onehot_levels[l] if p.onehot_levels else False,
            interpret=p.interpret,
            accum_dtype=p.accum_dtype,
        )
        gvals.append(_unpad_grad(gval, hw))
        glocs.append(gloc[:, :, :Q])
        gattns.append(gattn[:, :, :Q])

    gvalue = jnp.concatenate(gvals, axis=2)  # (B,H,S,D) accum dtype
    gvalue = jnp.transpose(gvalue, (0, 2, 1, 3))
    gloc = jnp.stack(glocs, axis=2)  # (B,H,L,Q,P,2)
    gloc = jnp.transpose(gloc, (0, 3, 1, 2, 4, 5))  # (B,Q,H,L,P,2)
    gattn = jnp.stack(gattns, axis=2)  # (B,H,L,Q,P)
    gattn = jnp.transpose(gattn, (0, 3, 1, 2, 4))  # (B,Q,H,L,P)
    return gvalue, gloc, gattn


def build_kernel_op(p: MSDAParams):
    """Custom-VJP executor for one committed kernel configuration.

    Deliberately *uncached*: the bounded plan cache in
    ``repro.kernels.plan`` owns the lifetime of compiled ops (and its
    ``clear_plans()`` hook lets long-lived serving processes drop them) —
    the old unbounded ``lru_cache`` here leaked one op per distinct
    config forever.
    """

    @jax.custom_vjp
    def op(value, loc, attn):
        return _fwd_impl(p, value, loc, attn)[0]

    def fwd(value, loc, attn):
        out, res = _fwd_impl(p, value, loc, attn)
        return out, res

    def bwd(res, gout):
        slabs, saved_all, loc_t, attn_t = res
        # grad_value must match the *operand* dtype, which a bf16-slab
        # plan no longer shares with the residual slabs
        vdt = p.io_dtype or (slabs[0] if slabs is not None else saved_all[0]).dtype
        gvalue, gloc, gattn = _bwd_impl(p, res, gout)
        return gvalue.astype(vdt), gloc.astype(loc_t.dtype), gattn.astype(attn_t.dtype)

    op.defvjp(fwd, bwd)
    return op


def resolve_backend(backend: str) -> str:
    from repro.kernels import registry

    return registry.resolve_backend(backend)


_UNSET = object()
_WARNED_KWARGS: set = set()


def _deprecated_kwarg(name: str) -> None:
    if name not in _WARNED_KWARGS:
        _WARNED_KWARGS.add(name)
        warnings.warn(
            f"ops.msda(..., {name}=...) is deprecated: commit tuning on an "
            "MsdaSpec and build a plan via repro.kernels.plan.msda_plan "
            "(the shim still honours the kwarg)",
            DeprecationWarning,
            stacklevel=3,
        )


def msda(
    value: jax.Array,
    spatial_shapes: Shapes,
    sampling_locations: jax.Array,
    attention_weights: jax.Array,
    *,
    backend: str = "auto",
    train: bool = False,
    dtype_policy: str = "follow",
    fuse_levels: str = "auto",
    sparsity: str = "off",
    sparsity_k: int = 0,
    query_order: str = "identity",
    block_q=_UNSET,
    fuse_gather=_UNSET,
    fuse_scatter=_UNSET,
    adaptive_block=_UNSET,
    onehot_small_levels=_UNSET,
    interpret=_UNSET,
) -> jax.Array:
    """Multi-scale deformable attention (differentiable) — compat shim.

    value: (B, S, H, D); sampling_locations: (B, Q, H, L, P, 2) in [0,1];
    attention_weights: (B, Q, H, L, P); returns (B, Q, H*D).

    This entry point now builds an :class:`~repro.kernels.plan.MsdaSpec`
    from the operands and executes the cached
    :class:`~repro.kernels.plan.MsdaPlan` — repeated calls with an
    identical spec never re-run block planning.  ``dtype_policy``
    ('follow' | 'float32' | 'bfloat16' | 'auto') commits the
    mixed-precision plan variant (bf16 slab + fp32 accumulate; see
    ``plan.resolve_dtype_policy``).  ``fuse_levels``
    ('auto' | 'on' | 'off') commits the whole-pyramid kernel fusion
    rung (one pallas launch per direction when the packed pyramid fits
    VMEM).  ``sparsity`` / ``sparsity_k`` / ``query_order`` commit the
    sparsity rungs: DEFA-style top-k point pruning (lossy, dense
    fallback — see ``kernels/msda_sparse.py``) and the bitwise-neutral
    Morton query permutation.  The per-call tuning kwargs
    (``block_q``, ``fuse_gather``, ``fuse_scatter``,
    ``adaptive_block``, ``onehot_small_levels``, ``interpret``) are
    deprecated; put them on the spec / plan instead.
    """
    from repro.kernels import plan as plan_mod

    slab_dtype, accum_dtype = plan_mod.resolve_dtype_policy(dtype_policy)
    overrides = {"slab_dtype": slab_dtype, "accum_dtype": accum_dtype,
                 "fuse_levels": fuse_levels, "sparsity": sparsity,
                 "sparsity_k": sparsity_k, "query_order": query_order}
    for name, val in (("fuse_gather", fuse_gather), ("fuse_scatter", fuse_scatter),
                      ("adaptive_block", adaptive_block),
                      ("onehot_small_levels", onehot_small_levels)):
        if val is not _UNSET:
            _deprecated_kwarg(name)
            overrides[name] = val
    plan_kwargs = {}
    for name, val in (("block_q", block_q), ("interpret", interpret)):
        if val is not _UNSET:
            _deprecated_kwarg(name)
            plan_kwargs[name] = tuple(val) if name == "block_q" and val is not None else val

    spec = plan_mod.spec_from_arrays(
        value, spatial_shapes, sampling_locations, attention_weights,
        train=train, **overrides)
    plan = plan_mod.msda_plan(spec, backend=backend, **plan_kwargs)
    return plan(value, sampling_locations, attention_weights)
