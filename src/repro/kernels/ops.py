"""MSDA kernel glue + the legacy one-shot ``msda(...)`` shim.

The *planning* surface lives in ``repro.kernels.plan`` (``MsdaSpec`` →
``msda_plan`` → ``MsdaPlan``) and the backend registry in
``repro.kernels.registry``; this module keeps

* the layout/padding contract and the kernel drivers
  (``_fwd_impl`` / ``_bwd_impl`` / ``build_kernel_op``) the pallas
  backend builder compiles into an executor — per-level launches, or
  the fused whole-pyramid pair (``MSDAParams.fuse_levels``: all levels
  packed into one super-slab via ``_pack_pyramid`` /
  ``pyramid_row_offsets``, ONE pallas launch per direction),
* the heuristic block planner (``plan_blocks`` — the paper's adaptive
  vec-len model, Fig. 7) and the MXU one-hot routing rule
  (``plan_onehot``), both invoked once per plan, and
* ``msda(...)``: a thin compatibility shim that builds a spec, fetches
  the cached plan, and executes it.  Per-call tuning kwargs
  (``block_q``, ``fuse_gather``, …) are deprecated — commit them on the
  spec / plan instead.

The layout/padding contract between the wrapper and the kernels:
each level is zero-padded from ``(H, W)`` to ``(H+2, W+2)`` (leading +
trailing pad row/column — the paper's §4.1 padding fix, re-derived for
branch-free corner pairs) and flattened row-major to a slab of
``hwp_rows = round_up((H+2) * (W+2), 8)`` rows × ``D`` lanes.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import msda_bwd, msda_fwd, ref

Shapes = Tuple[Tuple[int, int], ...]

# Legacy default block-planning budget (v5e-class part).  Plans carry an
# explicit per-device budget on the spec (plan.default_vmem_budget); this
# constant only backs direct plan_blocks() calls that don't pass one.
VMEM_BUDGET = 32 * 2**20
_SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def slab_rows(hw: Tuple[int, int]) -> int:
    h, w = hw
    return _round_up((h + 2) * (w + 2), _SUBLANE)


def per_query_bytes(num_points: int, head_dim: int, *, train: bool = False,
                    slab_itemsize: int = 4, levels: int = 1) -> int:
    """Per-query VMEM working set: 4 corners x P points x D lanes in fp32,
    ~4 concurrent copies (gathered, weighted, contribs, temporaries).

    ``train=True`` adds the saved-corner OUTPUT block the forward kernel
    keeps resident per step (``4P x D`` rows per query in the slab
    dtype, streamed to HBM for the backward) — omitting it made train
    plans overshoot the budget.  ``levels > 1`` scales the whole set for
    the fused whole-pyramid kernels, whose every query step touches all
    L levels.

    Single source of truth for the paper's occupancy model — used by the
    block planner below and by ``MsdaPlan.level_report``.
    """
    per_level = 4 * num_points * head_dim * 4 * 4 + num_points * 64
    if train:  # saved-corner output block: (block_q, 4P, D) slab dtype
        per_level += 4 * num_points * head_dim * slab_itemsize
    return levels * per_level


def pyramid_row_offsets(spatial_shapes: Shapes) -> Tuple[Tuple[int, ...], int]:
    """Static row offsets of each level inside the packed super-slab.

    Returns ``(offsets, total_rows)``: level ``l`` occupies rows
    ``[offsets[l], offsets[l] + slab_rows(hw_l))`` of the row-major
    ``(total_rows, D)`` super-slab (every level's slab is already padded
    to a sublane multiple, so the offsets stay aligned).
    """
    offs, total = [], 0
    for hw in spatial_shapes:
        offs.append(total)
        total += slab_rows(hw)
    return tuple(offs), total


def fused_resident_bytes(spatial_shapes: Shapes, head_dim: int, *,
                         slab_itemsize: int = 4, train: bool = True,
                         accum_itemsize: int = 4) -> int:
    """VMEM-resident bytes of the fused whole-pyramid kernels.

    Σ slab_rows(hw) x D in the (uniform, widest-committed) slab dtype,
    plus — in train mode — the same extent again in the accum dtype for
    the resident grad super-slab.  The ONE definition of the packed
    pyramid's residency: the fitting rung, the fused block planner and
    ``MsdaPlan.level_report`` all read it from here.
    """
    _, total = pyramid_row_offsets(spatial_shapes)
    resident = total * head_dim * slab_itemsize
    if train:
        resident += total * head_dim * accum_itemsize
    return resident


def fused_pyramid_fits(
    spatial_shapes: Shapes,
    num_points: int,
    head_dim: int,
    *,
    value_itemsize: int = 4,
    train: bool = True,
    vmem_budget: int = VMEM_BUDGET,
    accum_itemsize: int = 4,
) -> bool:
    """The planner's fusion-rung fitting model.

    Fused when the whole packed pyramid (:func:`fused_resident_bytes`)
    AND a minimal (one-sublane) query step's working set fit the VMEM
    budget together.
    """
    resident = fused_resident_bytes(
        spatial_shapes, head_dim, slab_itemsize=value_itemsize,
        train=train, accum_itemsize=accum_itemsize)
    per_q = per_query_bytes(num_points, head_dim, train=train,
                            slab_itemsize=value_itemsize,
                            levels=len(spatial_shapes))
    return resident + _SUBLANE * per_q <= vmem_budget


def plan_blocks(
    spatial_shapes: Shapes,
    num_points: int,
    head_dim: int,
    num_queries: int,
    *,
    value_itemsize: int = 4,
    train: bool = True,
    vmem_budget: int = VMEM_BUDGET,
    adaptive: bool = True,
    accum_itemsize: int = 4,
    fused: bool = False,
) -> Tuple[int, ...]:
    """Per-level query-block sizes (the paper's adaptive vec-len, Fig. 7).

    Larger levels leave less VMEM for per-step tensors, so their blocks
    shrink; tiny levels get wide blocks (long vectors).  ``adaptive=False``
    reproduces the "-Adaptive VecLen" ablation (fixed minimal block).

    ``value_itemsize`` is the itemsize of the dtype the value slab is
    *stored* in (a bf16-slab plan halves residency and widens blocks);
    ``accum_itemsize`` sizes the train-mode grad slab, which stays wide
    (fp32) regardless of the slab dtype.  The per-step working set
    includes the train-mode saved-corner output block (see
    :func:`per_query_bytes`).

    ``fused=True`` plans the whole-pyramid kernel instead: the resident
    set is the PACKED super-slab (all levels, plus the train grad
    super-slab) and one shared block serves every level — returned
    replicated per level so the tuple shape stays uniform.
    """
    def _clamp(bq: int) -> int:
        bq = max(_SUBLANE, min(2048, (bq // _SUBLANE) * _SUBLANE))
        return min(bq, _round_up(num_queries, _SUBLANE))

    if fused:
        L = len(spatial_shapes)
        if not adaptive:
            return (_SUBLANE,) * L
        resident = fused_resident_bytes(
            spatial_shapes, head_dim, slab_itemsize=value_itemsize,
            train=train, accum_itemsize=accum_itemsize)
        avail = max(vmem_budget - resident, 1 * 2**20)
        per_q = per_query_bytes(num_points, head_dim, train=train,
                                slab_itemsize=value_itemsize, levels=L)
        return (int(_clamp(avail // per_q)),) * L

    out = []
    for hw in spatial_shapes:
        if not adaptive:
            out.append(_SUBLANE)
            continue
        resident = slab_rows(hw) * head_dim * value_itemsize
        if train:  # bwd keeps a widened (accum-dtype) grad slab too
            resident += slab_rows(hw) * head_dim * accum_itemsize
        avail = max(vmem_budget - resident, 1 * 2**20)
        per_q = per_query_bytes(num_points, head_dim, train=train,
                                slab_itemsize=value_itemsize)
        out.append(int(_clamp(avail // per_q)))
    return tuple(out)


@dataclass(frozen=True)
class MSDAParams:
    """Static (hashable) kernel configuration."""

    spatial_shapes: Shapes
    block_q: Tuple[int, ...]
    fuse_gather: bool = True
    fuse_scatter: bool = True
    save_sampled: bool = False
    interpret: bool = True
    # per-level: route sampling through the MXU via one-hot matmuls
    # (beyond-paper; profitable for small levels where HWp fits an MXU
    # operand and the VPU gather would under-fill the vector unit)
    onehot_levels: Tuple[bool, ...] = ()
    # mixed precision: per-level dtype the VMEM value slab is stored in
    # ('' entries / empty tuple -> keep the operand dtype) and the dtype
    # partial outputs + the bwd grad slab accumulate in
    slab_dtypes: Tuple[str, ...] = ()
    accum_dtype: str = "float32"
    # dtype the grad_value must be emitted in (custom-VJP contract with
    # the primal); '' -> infer from the residual slab (legacy behaviour,
    # only correct when slab dtype == operand dtype)
    io_dtype: str = ""
    # fused whole-pyramid kernels: all levels packed into ONE super-slab,
    # one pallas launch per direction with a single shared block_q
    # (block_q[0]; the planner replicates it per level)
    fuse_levels: bool = False

    def slab_dtype(self, level: int) -> str:
        if self.slab_dtypes and self.slab_dtypes[level]:
            return self.slab_dtypes[level]
        return ""

    def fused_slab_dtype(self, operand_dtype) -> str:
        """Uniform storage dtype of the packed super-slab (one array, one
        dtype): the WIDEST committed per-level dtype, so fusing a plan
        never narrows any level below what the planner committed."""
        names = [self.slab_dtype(l) or str(operand_dtype)
                 for l in range(len(self.spatial_shapes))]
        return max(names, key=lambda n: jnp.dtype(n).itemsize)


# levels with padded slabs up to this many rows use the MXU one-hot path
ONEHOT_MAX_ROWS = 1152


def plan_onehot(spatial_shapes: Shapes) -> Tuple[bool, ...]:
    return tuple(slab_rows(hw) <= ONEHOT_MAX_ROWS for hw in spatial_shapes)


def _pad_level(value_t: jax.Array, offset: int, hw: Tuple[int, int]) -> jax.Array:
    """(B,H,S,D) -> zero-padded level slab (B,H,hwp_rows,D)."""
    B, Hh, S, D = value_t.shape
    h, w = hw
    lvl = jax.lax.dynamic_slice_in_dim(value_t, offset, h * w, axis=2)
    lvl = lvl.reshape(B, Hh, h, w, D)
    lvl = jnp.pad(lvl, ((0, 0), (0, 0), (1, 1), (1, 1), (0, 0)))
    lvl = lvl.reshape(B, Hh, (h + 2) * (w + 2), D)
    rows = slab_rows(hw)
    extra = rows - (h + 2) * (w + 2)
    if extra:
        lvl = jnp.pad(lvl, ((0, 0), (0, 0), (0, extra), (0, 0)))
    return lvl


def _unpad_grad(slab: jax.Array, hw: Tuple[int, int]) -> jax.Array:
    """Inverse of _pad_level for the grad slab: (B,H,rows,D) -> (B,H,HW,D)."""
    B, Hh, rows, D = slab.shape
    h, w = hw
    slab = slab[:, :, : (h + 2) * (w + 2)].reshape(B, Hh, h + 2, w + 2, D)
    return slab[:, :, 1 : h + 1, 1 : w + 1].reshape(B, Hh, h * w, D)


def _pad_q(x: jax.Array, q_axis: int, qpad: int, fill=0.0) -> jax.Array:
    q = x.shape[q_axis]
    if q == qpad:
        return x
    pads = [(0, 0)] * x.ndim
    pads[q_axis] = (0, qpad - q)
    return jnp.pad(x, pads, constant_values=fill)


def _pack_pyramid(value_t: jax.Array, spatial_shapes: Shapes,
                  dtype=None) -> jax.Array:
    """(B,H,S,D) -> packed super-slab (B,H,total_rows,D), every level
    zero-padded to its ``slab_rows`` extent at its static row offset."""
    parts = []
    offset = 0
    for hw in spatial_shapes:
        parts.append(_pad_level(value_t, offset, hw))
        offset += hw[0] * hw[1]
    slab = jnp.concatenate(parts, axis=2)
    if dtype is not None and slab.dtype != jnp.dtype(dtype):
        slab = slab.astype(dtype)
    return slab


def _unpack_grad_pyramid(slab: jax.Array, spatial_shapes: Shapes) -> jax.Array:
    """Inverse of :func:`_pack_pyramid` for the grad super-slab:
    (B,H,total_rows,D) -> (B,H,S,D)."""
    outs = []
    r = 0
    for hw in spatial_shapes:
        rows = slab_rows(hw)
        outs.append(_unpad_grad(slab[:, :, r:r + rows], hw))
        r += rows
    return jnp.concatenate(outs, axis=2)


def _fwd_impl_fused(p: MSDAParams, value, loc, attn):
    """Fused whole-pyramid forward: ONE pallas launch. Returns (out, res)."""
    B, S, Hh, D = value.shape
    _, Q, _, L, P, _ = loc.shape
    # (B,S,H,D) -> (B,H,S,D); (B,Q,H,L,P,2) -> (B,H,Q,L,P,2)
    value_t = jnp.transpose(value, (0, 2, 1, 3))
    loc_f = jnp.transpose(loc, (0, 2, 1, 3, 4, 5))
    attn_f = jnp.transpose(attn, (0, 2, 1, 3, 4))

    accum = jnp.dtype(p.accum_dtype)
    slab = _pack_pyramid(value_t, p.spatial_shapes,
                         dtype=p.fused_slab_dtype(value.dtype))
    row_offsets, _ = pyramid_row_offsets(p.spatial_shapes)
    bq = p.block_q[0]
    qpad = _round_up(Q, bq)
    loc_f = _pad_q(loc_f, 2, qpad, 0.5)
    attn_f = _pad_q(attn_f, 2, qpad, 0.0)
    out, saved = msda_fwd.msda_fwd_fused(
        slab,
        loc_f,
        attn_f,
        hws=p.spatial_shapes,
        row_offsets=row_offsets,
        block_q=bq,
        fuse_gather=p.fuse_gather,
        save_sampled=p.save_sampled,
        onehot_levels=p.onehot_levels,
        interpret=p.interpret,
        out_dtype=accum,
    )
    out = jnp.transpose(out[:, :, :Q], (0, 2, 1, 3)).reshape(B, Q, Hh * D)
    out = out.astype(value.dtype)
    if p.save_sampled:
        residuals = (None, saved, loc_f, attn_f)
    else:
        residuals = (slab, None, loc_f, attn_f)
    return out, residuals


def _bwd_impl_fused(p: MSDAParams, residuals, gout):
    """Fused whole-pyramid backward: ONE pallas launch."""
    slab, saved, loc_f, attn_f = residuals
    B, Hh, Qpad, L, P, _ = loc_f.shape
    HD = gout.shape[-1]
    D = HD // Hh
    Q = gout.shape[1]
    gout_t = jnp.transpose(gout.reshape(B, Q, Hh, D), (0, 2, 1, 3))
    gout_t = _pad_q(gout_t, 2, Qpad, 0.0)
    row_offsets, total_rows = pyramid_row_offsets(p.spatial_shapes)
    gval, gloc, gattn = msda_bwd.msda_bwd_fused(
        slab,
        loc_f,
        attn_f,
        gout_t,
        saved,
        hws=p.spatial_shapes,
        row_offsets=row_offsets,
        total_rows=total_rows,
        block_q=p.block_q[0],
        fuse_scatter=p.fuse_scatter,
        onehot_levels=p.onehot_levels,
        interpret=p.interpret,
        accum_dtype=p.accum_dtype,
    )
    gvalue = _unpack_grad_pyramid(gval, p.spatial_shapes)  # (B,H,S,D)
    gvalue = jnp.transpose(gvalue, (0, 2, 1, 3))
    gloc = jnp.transpose(gloc[:, :, :Q], (0, 2, 1, 3, 4, 5))  # (B,Q,H,L,P,2)
    gattn = jnp.transpose(gattn[:, :, :Q], (0, 2, 1, 3, 4))  # (B,Q,H,L,P)
    return gvalue, gloc, gattn


def _fwd_impl(p: MSDAParams, value, loc, attn):
    """Kernel-backed forward. Returns (out, residuals)."""
    if p.fuse_levels:
        return _fwd_impl_fused(p, value, loc, attn)
    B, S, Hh, D = value.shape
    _, Q, _, L, P, _ = loc.shape
    # (B,S,H,D) -> (B,H,S,D); (B,Q,H,L,P,2) -> (B,H,L,Q,P,2)
    value_t = jnp.transpose(value, (0, 2, 1, 3))
    loc_t = jnp.transpose(loc, (0, 2, 3, 1, 4, 5))
    attn_t = jnp.transpose(attn, (0, 2, 3, 1, 4))

    accum = jnp.dtype(p.accum_dtype)
    out = jnp.zeros((B, Hh, Q, D), accum)
    slabs, saved_all = [], []
    offset = 0
    for l, hw in enumerate(p.spatial_shapes):
        bq = p.block_q[l]
        qpad = _round_up(Q, bq)
        slab = _pad_level(value_t, offset, hw)
        sdt = p.slab_dtype(l)
        if sdt:  # committed slab dtype (may narrow: bf16 slab, fp32 accum)
            slab = slab.astype(sdt)
        offset += hw[0] * hw[1]
        loc_l = _pad_q(loc_t[:, :, l], 2, qpad, 0.5)
        attn_l = _pad_q(attn_t[:, :, l], 2, qpad, 0.0)
        onehot = p.onehot_levels[l] if p.onehot_levels else False
        out_l, saved_l = msda_fwd.msda_fwd_level(
            slab,
            loc_l,
            attn_l,
            hw=hw,
            block_q=bq,
            fuse_gather=p.fuse_gather,
            save_sampled=p.save_sampled,
            onehot_gather=onehot,
            interpret=p.interpret,
            out_dtype=accum,
        )
        out = out + out_l[:, :, :Q]
        slabs.append(slab)
        saved_all.append(saved_l)
    out = jnp.transpose(out, (0, 2, 1, 3)).reshape(B, Q, Hh * D)
    out = out.astype(value.dtype)
    if p.save_sampled:
        residuals = (None, tuple(saved_all), loc_t, attn_t)
    else:
        residuals = (tuple(slabs), None, loc_t, attn_t)
    return out, residuals


def _bwd_impl(p: MSDAParams, residuals, gout):
    if p.fuse_levels:
        return _bwd_impl_fused(p, residuals, gout)
    slabs, saved_all, loc_t, attn_t = residuals
    B, Hh, L, Q, P, _ = loc_t.shape
    HD = gout.shape[-1]
    D = HD // Hh
    gout_t = jnp.transpose(gout.reshape(B, Q, Hh, D), (0, 2, 1, 3))  # (B,H,Q,D)

    gvals, glocs, gattns = [], [], []
    for l, hw in enumerate(p.spatial_shapes):
        bq = p.block_q[l]
        qpad = _round_up(Q, bq)
        loc_l = _pad_q(loc_t[:, :, l], 2, qpad, 0.5)
        attn_l = _pad_q(attn_t[:, :, l], 2, qpad, 0.0)
        gout_l = _pad_q(gout_t, 2, qpad, 0.0)
        saved_l = saved_all[l] if saved_all is not None else None
        slab_l = slabs[l] if slabs is not None else None
        gval, gloc, gattn = msda_bwd.msda_bwd_level(
            slab_l,
            loc_l,
            attn_l,
            gout_l,
            saved_l,
            hw=hw,
            hwp_rows=slab_rows(hw),
            block_q=bq,
            fuse_scatter=p.fuse_scatter,
            onehot_scatter=p.onehot_levels[l] if p.onehot_levels else False,
            interpret=p.interpret,
            accum_dtype=p.accum_dtype,
        )
        gvals.append(_unpad_grad(gval, hw))
        glocs.append(gloc[:, :, :Q])
        gattns.append(gattn[:, :, :Q])

    gvalue = jnp.concatenate(gvals, axis=2)  # (B,H,S,D) accum dtype
    gvalue = jnp.transpose(gvalue, (0, 2, 1, 3))
    gloc = jnp.stack(glocs, axis=2)  # (B,H,L,Q,P,2)
    gloc = jnp.transpose(gloc, (0, 3, 1, 2, 4, 5))  # (B,Q,H,L,P,2)
    gattn = jnp.stack(gattns, axis=2)  # (B,H,L,Q,P)
    gattn = jnp.transpose(gattn, (0, 3, 1, 2, 4))  # (B,Q,H,L,P)
    return gvalue, gloc, gattn


def build_kernel_op(p: MSDAParams):
    """Custom-VJP executor for one committed kernel configuration.

    Deliberately *uncached*: the bounded plan cache in
    ``repro.kernels.plan`` owns the lifetime of compiled ops (and its
    ``clear_plans()`` hook lets long-lived serving processes drop them) —
    the old unbounded ``lru_cache`` here leaked one op per distinct
    config forever.
    """

    @jax.custom_vjp
    def op(value, loc, attn):
        return _fwd_impl(p, value, loc, attn)[0]

    def fwd(value, loc, attn):
        out, res = _fwd_impl(p, value, loc, attn)
        return out, res

    def bwd(res, gout):
        slabs, saved_all, loc_t, attn_t = res
        # grad_value must match the *operand* dtype, which a bf16-slab
        # plan no longer shares with the residual slabs
        vdt = p.io_dtype or (slabs[0] if slabs is not None else saved_all[0]).dtype
        gvalue, gloc, gattn = _bwd_impl(p, res, gout)
        return gvalue.astype(vdt), gloc.astype(loc_t.dtype), gattn.astype(attn_t.dtype)

    op.defvjp(fwd, bwd)
    return op


def resolve_backend(backend: str) -> str:
    from repro.kernels import registry

    return registry.resolve_backend(backend)


_UNSET = object()
_WARNED_KWARGS: set = set()


def _deprecated_kwarg(name: str) -> None:
    if name not in _WARNED_KWARGS:
        _WARNED_KWARGS.add(name)
        warnings.warn(
            f"ops.msda(..., {name}=...) is deprecated: commit tuning on an "
            "MsdaSpec and build a plan via repro.kernels.plan.msda_plan "
            "(the shim still honours the kwarg)",
            DeprecationWarning,
            stacklevel=3,
        )


def msda(
    value: jax.Array,
    spatial_shapes: Shapes,
    sampling_locations: jax.Array,
    attention_weights: jax.Array,
    *,
    backend: str = "auto",
    train: bool = False,
    dtype_policy: str = "follow",
    fuse_levels: str = "auto",
    sparsity: str = "off",
    sparsity_k: int = 0,
    query_order: str = "identity",
    block_q=_UNSET,
    fuse_gather=_UNSET,
    fuse_scatter=_UNSET,
    adaptive_block=_UNSET,
    onehot_small_levels=_UNSET,
    interpret=_UNSET,
) -> jax.Array:
    """Multi-scale deformable attention (differentiable) — compat shim.

    value: (B, S, H, D); sampling_locations: (B, Q, H, L, P, 2) in [0,1];
    attention_weights: (B, Q, H, L, P); returns (B, Q, H*D).

    This entry point now builds an :class:`~repro.kernels.plan.MsdaSpec`
    from the operands and executes the cached
    :class:`~repro.kernels.plan.MsdaPlan` — repeated calls with an
    identical spec never re-run block planning.  ``dtype_policy``
    ('follow' | 'float32' | 'bfloat16' | 'auto') commits the
    mixed-precision plan variant (bf16 slab + fp32 accumulate; see
    ``plan.resolve_dtype_policy``).  ``fuse_levels``
    ('auto' | 'on' | 'off') commits the whole-pyramid kernel fusion
    rung (one pallas launch per direction when the packed pyramid fits
    VMEM).  ``sparsity`` / ``sparsity_k`` / ``query_order`` commit the
    sparsity rungs: DEFA-style top-k point pruning (lossy, dense
    fallback — see ``kernels/msda_sparse.py``) and the bitwise-neutral
    Morton query permutation.  The per-call tuning kwargs
    (``block_q``, ``fuse_gather``, ``fuse_scatter``,
    ``adaptive_block``, ``onehot_small_levels``, ``interpret``) are
    deprecated; put them on the spec / plan instead.
    """
    from repro.kernels import plan as plan_mod

    slab_dtype, accum_dtype = plan_mod.resolve_dtype_policy(dtype_policy)
    overrides = {"slab_dtype": slab_dtype, "accum_dtype": accum_dtype,
                 "fuse_levels": fuse_levels, "sparsity": sparsity,
                 "sparsity_k": sparsity_k, "query_order": query_order}
    for name, val in (("fuse_gather", fuse_gather), ("fuse_scatter", fuse_scatter),
                      ("adaptive_block", adaptive_block),
                      ("onehot_small_levels", onehot_small_levels)):
        if val is not _UNSET:
            _deprecated_kwarg(name)
            overrides[name] = val
    plan_kwargs = {}
    for name, val in (("block_q", block_q), ("interpret", interpret)):
        if val is not _UNSET:
            _deprecated_kwarg(name)
            plan_kwargs[name] = tuple(val) if name == "block_q" and val is not None else val

    spec = plan_mod.spec_from_arrays(
        value, spatial_shapes, sampling_locations, attention_weights,
        train=train, **overrides)
    plan = plan_mod.msda_plan(spec, backend=backend, **plan_kwargs)
    return plan(value, sampling_locations, attention_weights)
