"""Pallas-TPU forward kernel for multi-scale deformable attention.

Paper mapping (xMSDA §4.1 → TPU):

* Per-level processing with the level's padded feature map **resident in
  VMEM** across all query blocks (the paper's "single-channel feature
  map fits UB" insight; TPU VMEM holds the whole per-(batch, head) level
  slab, all channels).
* **Gather fusion**: all four bilinear corners × P points of a query
  block are gathered with ONE batched index vector — the TPU analogue of
  the paper's pixel-pair merged gather (x-adjacent corners are adjacent
  rows ``idx`` / ``idx+1`` of the row-major ``(HW, D)`` slab and ride the
  same gather op, maximising effective vector length, the quantity the
  paper's Fig. 4 shows drives gather throughput).  The ablation flag
  ``fuse_gather=False`` issues four separate per-corner gathers instead.
* **Padding-based alignment fix**: each level is zero-padded to
  ``(H+1, W+1)`` so ``x0+1`` / ``y0+1`` never leave the slab and the
  merged pair load is always legal (paper Fig. 6, re-motivated: TPU has
  no unaligned-gather erratum, but the same padding makes the corner
  arithmetic branch-free).  Out-of-bounds corners are masked on the
  *weights*, reproducing ``grid_sample(padding_mode='zeros')``.
* **Adaptive vec-len**: the query-block size ``block_q`` is planned per
  level so (slab + gathered corners + temporaries) fill the VMEM budget
  (paper Fig. 7). See ``ops.plan_blocks``.
* **Train mode** (``save_sampled``): the kernel additionally streams the
  gathered corner values to HBM for the backward pass (paper §4.1 "store
  the gather result ... additional IO"), trading fwd MTE3 traffic for a
  gather-free backward phase 1.
* **Mixed precision**: the value slab may be stored in a narrower dtype
  (bf16 — half the VMEM residency, so the planner can widen ``block_q``)
  while the kernel still computes and *emits* its per-level partial
  output in ``out_dtype`` (fp32 by default) — a widened accumulator, not
  a cast wrapper: cross-level accumulation never rounds through bf16.

Grid: ``(B, H, num_q_blocks)`` — ``q`` innermost so the value slab block
``(1, 1, HW_pad, D)`` is revisited (stays in VMEM) across query blocks.

**Fused whole-pyramid variant** (``msda_fwd_fused``): when the packed
slabs of ALL levels fit the VMEM budget (the planner's fusion rung,
``MsdaSpec.fuse_levels``), the pyramid — not the level — becomes the
residency unit: one ``pallas_call`` gathers every level from a single
row-major super-slab (per-level row offsets static), accumulates the
cross-level sum in-kernel, and writes the output to HBM exactly once.
The merged gather then spans corners x points x LEVELS — another factor
of L of effective vector length on top of the pixel-pair merge.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams (~0.6); support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Shapes = Tuple[Tuple[int, int], ...]


def corner_indices(loc, H: int, W: int, Wp: int):
    """Bilinear corner bookkeeping shared by fwd/bwd kernels.

    loc: (..., 2) fp32 in [0,1] (x, y), grid_sample(align_corners=False).
    Returns (idx00, lx, ly, masks) where ``idx00`` indexes the padded
    row-major slab of width ``Wp = W + 2`` whose real image origin sits
    at pixel (1, 1) — one LEADING and one TRAILING zero pad row/column.
    The x-pair partner is ``idx + 1`` and the y-pair partner ``idx + Wp``;
    with ``x0`` clipped into ``[-1, W-1]`` every pair lands in-slab and
    clipped-to-pad corners read zeros.  masks = (m00, m10, m01, m11)
    validity of each corner (required: e.g. ``x0 = -5`` clips to ``-1``
    whose +1 partner would read real column 0).
    """
    px = loc[..., 0] * W - 0.5
    py = loc[..., 1] * H - 0.5
    x0f = jnp.floor(px)
    y0f = jnp.floor(py)
    lx = px - x0f
    ly = py - y0f
    x0 = x0f.astype(jnp.int32)
    y0 = y0f.astype(jnp.int32)
    vx0 = (x0 >= 0) & (x0 < W)
    vx1 = (x0 + 1 >= 0) & (x0 + 1 < W)
    vy0 = (y0 >= 0) & (y0 < H)
    vy1 = (y0 + 1 >= 0) & (y0 + 1 < H)
    # clip into [-1, W-1]; +1 shift lands on the padded origin
    x0c = jnp.clip(x0, -1, W - 1) + 1
    y0c = jnp.clip(y0, -1, H - 1) + 1
    idx00 = y0c * Wp + x0c
    masks = (vx0 & vy0, vx1 & vy0, vx0 & vy1, vx1 & vy1)
    return idx00, lx, ly, masks


def _fwd_kernel(
    value_ref,  # (1, 1, HWp, D)   VMEM-resident level slab
    loc_ref,    # (1, 1, Qb, P, 2)
    attn_ref,   # (1, 1, Qb, P)
    out_ref,    # (1, 1, Qb, D)
    saved_ref,  # (1, 1, Qb, P*4, D) or None
    *,
    H: int,
    W: int,
    Wp: int,
    fuse_gather: bool,
    onehot_gather: bool = False,
):
    v = value_ref[0, 0]  # (HWp, D)
    loc = loc_ref[0, 0].astype(jnp.float32)  # (Qb, P, 2)
    attn = attn_ref[0, 0].astype(jnp.float32)  # (Qb, P)
    Qb, P, _ = loc.shape

    idx00, lx, ly, (m00, m10, m01, m11) = corner_indices(loc, H, W, Wp)
    i00 = idx00.reshape(-1)  # (Qb*P,)

    if onehot_gather:
        # Beyond-paper MXU path (small levels): gather as a one-hot
        # matmul (4QbP, HWp) @ (HWp, D) — the systolic array does the
        # "random access".  The Ascend design could not express this
        # (cube cores cannot address UB); on TPU the MXU sits idle during
        # VPU gathers, so shifting small-level sampling there overlaps
        # with the big-level vector path.
        all_idx = jnp.concatenate([i00, i00 + 1, i00 + Wp, i00 + Wp + 1])
        onehot = (all_idx[:, None] == jnp.arange(v.shape[0])[None, :]).astype(
            jnp.float32
        )
        g = onehot @ v.astype(jnp.float32)  # (4*Qb*P, D) via MXU
        v00, v10, v01, v11 = jnp.split(g, 4, axis=0)
    elif fuse_gather:
        # ONE batched gather for all corners & points: [x0y0; x1y0; x0y1; x1y1]
        all_idx = jnp.concatenate([i00, i00 + 1, i00 + Wp, i00 + Wp + 1])
        g = jnp.take(v, all_idx, axis=0).astype(jnp.float32)  # (4*Qb*P, D)
        v00, v10, v01, v11 = jnp.split(g, 4, axis=0)
    else:
        # ablation: four separate per-corner gathers (halved vec-len twice)
        v00 = jnp.take(v, i00, axis=0).astype(jnp.float32)
        v10 = jnp.take(v, i00 + 1, axis=0).astype(jnp.float32)
        v01 = jnp.take(v, i00 + Wp, axis=0).astype(jnp.float32)
        v11 = jnp.take(v, i00 + Wp + 1, axis=0).astype(jnp.float32)

    shape = (Qb, P, 1)
    w00 = ((1 - lx) * (1 - ly) * m00).reshape(shape)
    w10 = (lx * (1 - ly) * m10).reshape(shape)
    w01 = ((1 - lx) * ly * m01).reshape(shape)
    w11 = (lx * ly * m11).reshape(shape)

    D = v.shape[-1]
    v00 = v00.reshape(Qb, P, D)
    v10 = v10.reshape(Qb, P, D)
    v01 = v01.reshape(Qb, P, D)
    v11 = v11.reshape(Qb, P, D)
    sampled = v00 * w00 + v10 * w10 + v01 * w01 + v11 * w11  # (Qb,P,D)
    out = jnp.einsum("qpd,qp->qd", sampled, attn)
    out_ref[0, 0] = out.astype(out_ref.dtype)

    if saved_ref is not None:
        # train mode: stream raw corners to HBM for the backward pass
        corners = jnp.concatenate([v00, v10, v01, v11], axis=1)  # (Qb, 4P, D)
        saved_ref[0, 0] = corners.astype(saved_ref.dtype)


def msda_fwd_level(
    value_l: jax.Array,  # (B, H, HWp, D) zero-padded level slab
    loc_l: jax.Array,    # (B, H, Q, P, 2)
    attn_l: jax.Array,   # (B, H, Q, P)
    *,
    hw: Tuple[int, int],
    block_q: int,
    fuse_gather: bool = True,
    save_sampled: bool = False,
    onehot_gather: bool = False,
    interpret: bool = False,
    out_dtype=None,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """One level's contribution: (B,H,Q,D) partial output (+ saved corners).

    ``out_dtype`` is the accumulator dtype the partial output is emitted
    in (default: the slab dtype).  Saved corners always keep the slab
    dtype — they are re-read, not accumulated.
    """
    B, Hh, HWp, D = value_l.shape
    out_dtype = value_l.dtype if out_dtype is None else jnp.dtype(out_dtype)
    _, _, Q, P, _ = loc_l.shape
    Hl, Wl = hw
    Wp = Wl + 2  # leading + trailing pad column
    assert Q % block_q == 0, (Q, block_q)
    nq = Q // block_q

    kernel = functools.partial(
        _fwd_kernel, H=Hl, W=Wl, Wp=Wp, fuse_gather=fuse_gather,
        onehot_gather=onehot_gather,
    )
    out_shapes = [jax.ShapeDtypeStruct((B, Hh, Q, D), out_dtype)]
    out_specs = [pl.BlockSpec((1, 1, block_q, D), lambda b, h, q: (b, h, q, 0))]
    if save_sampled:
        out_shapes.append(jax.ShapeDtypeStruct((B, Hh, Q, 4 * P, D), value_l.dtype))
        out_specs.append(
            pl.BlockSpec((1, 1, block_q, 4 * P, D), lambda b, h, q: (b, h, q, 0, 0))
        )
    else:
        kernel = functools.partial(_nosave_wrap, kernel)

    outs = pl.pallas_call(
        kernel,
        grid=(B, Hh, nq),
        in_specs=[
            # level slab: revisited across q (resident in VMEM per (b,h))
            pl.BlockSpec((1, 1, HWp, D), lambda b, h, q: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_q, P, 2), lambda b, h, q: (b, h, q, 0, 0)),
            pl.BlockSpec((1, 1, block_q, P), lambda b, h, q: (b, h, q, 0)),
        ],
        out_specs=out_specs if save_sampled else out_specs[:1],
        out_shape=out_shapes if save_sampled else out_shapes[:1],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(value_l, loc_l, attn_l)
    if save_sampled:
        return outs[0], outs[1]
    return outs[0], None


def _nosave_wrap(kernel, value_ref, loc_ref, attn_ref, out_ref):
    kernel(value_ref, loc_ref, attn_ref, out_ref, None)


# --------------------------------------------------------------------------
# fused whole-pyramid forward: ONE pallas launch for all L levels
# --------------------------------------------------------------------------


def fused_level_corner_indices(loc, hws: Shapes):
    """Per-level corner bookkeeping for the fused kernels.

    ``loc``: (Qb, L, P, 2).  Returns ``(cidx, geom)`` where ``cidx[l]``
    is the tuple of 4 LOCAL corner index vectors ``(Qb*P,)`` (x-pair
    partner ``+1``, y-pair partner ``+Wp`` — see :func:`corner_indices`)
    and ``geom[l] = (lx, ly, masks)``.
    """
    cidx, geom = [], []
    for l, (Hl, Wl) in enumerate(hws):
        Wp = Wl + 2
        idx00, lx, ly, masks = corner_indices(loc[:, l], Hl, Wl, Wp)
        i00 = idx00.reshape(-1)
        cidx.append((i00, i00 + 1, i00 + Wp, i00 + Wp + 1))
        geom.append((lx, ly, masks))
    return cidx, geom


def packed_ratios(slab_dtypes: Tuple[str, ...], carrier_dtype) -> Tuple[int, ...]:
    """Per-level carrier-rows-per-slab-row of a mixed-dtype super-slab.

    The packed super-slab is stored in the NARROWEST committed dtype
    (the carrier); a level committed to a wider dtype occupies
    ``itemsize(level) // itemsize(carrier)`` carrier rows per logical
    row (its bytes reinterpreted row-major), so row offsets stay
    sublane-aligned while every level keeps its own dtype — bf16-winner
    levels keep their residency win under fusion.
    """
    ci = jnp.dtype(carrier_dtype).itemsize
    return tuple(jnp.dtype(d).itemsize // ci for d in slab_dtypes)


def decode_packed_rows(seg: jax.Array, ratio: int, dtype) -> jax.Array:
    """(n*ratio, D) carrier rows -> (n, D) rows in the level's dtype.

    Inverse of the row-major byte reinterpretation ``ops._pack_pyramid``
    applies when packing a wide level into a narrow carrier: ``ratio``
    consecutive carrier rows hold one logical row, consecutive carrier
    elements pairing into one wide element.
    """
    dt = jnp.dtype(dtype)
    if dt == seg.dtype:
        return seg
    if ratio == 1:  # same itemsize, different dtype (e.g. bf16 vs f16)
        return jax.lax.bitcast_convert_type(seg, dt)
    n = seg.shape[0] // ratio
    d = seg.shape[1]
    return jax.lax.bitcast_convert_type(
        seg.reshape(n, ratio * d).reshape(n, d, ratio), dt)


def fused_gather_corners(v, cidx, row_offsets: Tuple[int, ...],
                         onehot: Tuple[bool, ...], fuse_gather: bool,
                         *, slab_dtypes: Tuple[str, ...] = ()):
    """Gather every level's bilinear corners from the packed super-slab.

    Shared by the fused forward and the fused backward's regather
    branch — the routing logic must never diverge between directions.
    VPU levels share ONE merged index vector across corners, points and
    levels (``row_offsets`` lift local indices into the super-slab;
    ``fuse_gather=False`` degrades to four merged per-corner gathers);
    one-hot levels ride the MXU against their own sub-slab rows.

    ``slab_dtypes`` commits a per-level storage dtype inside the packed
    slab (see :func:`packed_ratios`): ``row_offsets`` are then CARRIER
    row offsets, each logical corner row widens to ``ratio`` consecutive
    carrier rows inside the same merged index vector, and the gathered
    carrier rows are bitcast back to the level dtype before the fp32
    upcast.  Empty / uniform-carrier ``slab_dtypes`` take the exact
    legacy path (bitwise-stable).
    Returns ``corners[l]``: list of 4 ``(Qb*P, D)`` fp32 arrays.
    """
    L = len(cidx)
    n = cidx[0][0].shape[0]  # Qb*P
    carrier = str(v.dtype)
    dts = (tuple(str(jnp.dtype(d)) for d in slab_dtypes) if slab_dtypes
           else (carrier,) * L)
    ratios = packed_ratios(dts, v.dtype)
    mixed = any(d != carrier for d in dts)
    corners = [None] * L
    vpu = [l for l in range(L) if not onehot[l]]
    if vpu and not mixed:
        if fuse_gather:
            big = jnp.concatenate(
                [c + row_offsets[l] for l in vpu for c in cidx[l]])
            g = jnp.take(v, big, axis=0).astype(jnp.float32)
            for i, l in enumerate(vpu):
                corners[l] = jnp.split(g[i * 4 * n:(i + 1) * 4 * n], 4, axis=0)
        else:
            per_corner = [
                jnp.take(v, jnp.concatenate(
                    [cidx[l][c] + row_offsets[l] for l in vpu]),
                    axis=0).astype(jnp.float32)
                for c in range(4)
            ]
            for i, l in enumerate(vpu):
                sl = slice(i * n, (i + 1) * n)
                corners[l] = [pc[sl] for pc in per_corner]
    elif vpu:
        # mixed-dtype super-slab: still ONE merged gather over carrier
        # rows — a ratio-r level contributes r consecutive carrier rows
        # per corner, decoded back to its dtype after the take
        def _carrier_idx(l, c):
            base = c * ratios[l] + row_offsets[l]
            if ratios[l] == 1:
                return base
            return (base[:, None] + jnp.arange(ratios[l])).reshape(-1)

        if fuse_gather:
            big = jnp.concatenate(
                [_carrier_idx(l, c) for l in vpu for c in cidx[l]])
            g = jnp.take(v, big, axis=0)
            pos = 0
            for l in vpu:
                cs = []
                for _ in range(4):
                    m = n * ratios[l]
                    cs.append(decode_packed_rows(
                        g[pos:pos + m], ratios[l], dts[l]).astype(jnp.float32))
                    pos += m
                corners[l] = cs
        else:
            for l in vpu:
                corners[l] = [
                    decode_packed_rows(
                        jnp.take(v, _carrier_idx(l, c), axis=0),
                        ratios[l], dts[l]).astype(jnp.float32)
                    for c in cidx[l]
                ]
    for l in range(L):
        if not onehot[l]:
            continue
        end = row_offsets[l + 1] if l + 1 < L else v.shape[0]
        sub = v[row_offsets[l]:end]
        if dts[l] != carrier:
            sub = decode_packed_rows(sub, ratios[l], dts[l])
        all_idx = jnp.concatenate(cidx[l])
        oh = (all_idx[:, None] == jnp.arange(sub.shape[0])[None, :]).astype(
            jnp.float32)
        corners[l] = jnp.split(oh @ sub.astype(jnp.float32), 4, axis=0)
    return corners


def _fwd_fused_kernel(
    value_ref,  # (1, 1, R, D)   VMEM-resident packed pyramid super-slab
    loc_ref,    # (1, 1, Qb, L, P, 2)
    attn_ref,   # (1, 1, Qb, L, P)
    out_ref,    # (1, 1, Qb, D)
    saved_ref,  # (1, 1, Qb, L*4P, D) or None
    *,
    hws: Shapes,
    row_offsets: Tuple[int, ...],
    fuse_gather: bool,
    onehot_levels: Tuple[bool, ...] = (),
    slab_dtypes: Tuple[str, ...] = (),
):
    """Whole-pyramid forward step: cross-level accumulation in-kernel.

    The per-level kernel's math, run over every level of the packed
    super-slab inside one grid step — the output block is written to HBM
    exactly once, instead of L fp32 partials round-tripping through HBM
    and being summed by XLA.  Gather fusion goes one step further than
    the per-level kernel: all VPU levels' corners ride ONE merged index
    vector (per-level row offsets lift local indices into the
    super-slab), so the effective gather vector length grows by another
    factor of L on top of the paper's pixel-pair merge.  Levels routed
    to the MXU one-hot path keep it, against their own sub-slab rows.
    """
    v = value_ref[0, 0]  # (R, D)
    loc = loc_ref[0, 0].astype(jnp.float32)  # (Qb, L, P, 2)
    attn = attn_ref[0, 0].astype(jnp.float32)  # (Qb, L, P)
    Qb, L, P, _ = loc.shape
    D = v.shape[-1]

    cidx, geom = fused_level_corner_indices(loc, hws)
    onehot = tuple(onehot_levels) if onehot_levels else (False,) * L
    corners = fused_gather_corners(v, cidx, row_offsets, onehot, fuse_gather,
                                   slab_dtypes=slab_dtypes)

    contribs = []
    saved_parts = []
    for l in range(L):
        lx, ly, (m00, m10, m01, m11) = geom[l]
        v00, v10, v01, v11 = (c.reshape(Qb, P, D) for c in corners[l])
        shape = (Qb, P, 1)
        w00 = ((1 - lx) * (1 - ly) * m00).reshape(shape)
        w10 = (lx * (1 - ly) * m10).reshape(shape)
        w01 = ((1 - lx) * ly * m01).reshape(shape)
        w11 = (lx * ly * m11).reshape(shape)
        sampled = v00 * w00 + v10 * w10 + v01 * w01 + v11 * w11  # (Qb,P,D)
        contribs.append(jnp.einsum("qpd,qp->qd", sampled, attn[:, l]))
        if saved_ref is not None:
            saved_parts.append(jnp.concatenate([v00, v10, v01, v11], axis=1))
    # Cross-level accumulation through a fori_loop over MATERIALISED
    # per-level partials — not a straight-line `out += contrib` chain.
    # The loop boundary forces each contribution to be rounded to fp32
    # before its add, exactly like the per-level path's partial outputs
    # (separate launches round at the HBM write).  Straight-line code
    # lets XLA:CPU contract a P=1 einsum (which simplifies to a bare
    # multiply) with the accumulation into one FMA — the product then
    # reaches the add UNROUNDED and tier parity breaks by 1 ulp; no
    # optimization_barrier or bitcast survives that contraction pass.
    stacked = jnp.stack(contribs)  # (L, Qb, D) rounded fp32 partials
    out = jax.lax.fori_loop(
        0, L,
        lambda l, acc: acc + jax.lax.dynamic_index_in_dim(
            stacked, l, keepdims=False),
        jnp.zeros((Qb, D), jnp.float32))
    out_ref[0, 0] = out.astype(out_ref.dtype)
    if saved_ref is not None:
        # train mode: corners packed (Qb, L*4P, D), streamed once
        saved_ref[0, 0] = jnp.concatenate(saved_parts, axis=1).astype(
            saved_ref.dtype)


def msda_fwd_fused(
    value_p: jax.Array,  # (B, H, R, D) packed pyramid super-slab
    loc_f: jax.Array,    # (B, H, Q, L, P, 2)
    attn_f: jax.Array,   # (B, H, Q, L, P)
    *,
    hws: Shapes,
    row_offsets: Tuple[int, ...],
    block_q: int,
    fuse_gather: bool = True,
    save_sampled: bool = False,
    onehot_levels: Tuple[bool, ...] = (),
    interpret: bool = False,
    out_dtype=None,
    slab_dtypes: Tuple[str, ...] = (),
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Whole-pyramid forward: ONE ``pallas_call`` for all levels.

    The packed super-slab stays VMEM-resident across query blocks;
    loc/attn are streamed once as ``(Qb, L, P, ...)`` blocks with a
    single shared ``block_q``; the output (and, in train mode, the
    packed saved corners ``(Qb, L*4P, D)``) are written to HBM exactly
    once.  ``out_dtype`` is the in-kernel cross-level accumulator dtype.

    ``slab_dtypes`` commits mixed per-level storage dtypes inside the
    packed slab — ``value_p`` is then CARRIER-coded (narrowest dtype;
    ``row_offsets`` in carrier rows, see :func:`packed_ratios`) and the
    train-mode saved corners are emitted in the WIDEST committed dtype
    so no level's corners round through a narrower type.
    """
    B, Hh, R, D = value_p.shape
    out_dtype = value_p.dtype if out_dtype is None else jnp.dtype(out_dtype)
    _, _, Q, L, P, _ = loc_f.shape
    assert Q % block_q == 0, (Q, block_q)
    nq = Q // block_q
    saved_dtype = value_p.dtype
    if slab_dtypes:
        saved_dtype = jnp.dtype(max(slab_dtypes,
                                    key=lambda d: jnp.dtype(d).itemsize))

    kernel = functools.partial(
        _fwd_fused_kernel, hws=tuple(hws), row_offsets=tuple(row_offsets),
        fuse_gather=fuse_gather, onehot_levels=tuple(onehot_levels),
        slab_dtypes=tuple(slab_dtypes),
    )
    out_shapes = [jax.ShapeDtypeStruct((B, Hh, Q, D), out_dtype)]
    out_specs = [pl.BlockSpec((1, 1, block_q, D), lambda b, h, q: (b, h, q, 0))]
    if save_sampled:
        out_shapes.append(
            jax.ShapeDtypeStruct((B, Hh, Q, L * 4 * P, D), saved_dtype))
        out_specs.append(
            pl.BlockSpec((1, 1, block_q, L * 4 * P, D),
                         lambda b, h, q: (b, h, q, 0, 0)))
    else:
        kernel = functools.partial(_nosave_wrap, kernel)

    outs = pl.pallas_call(
        kernel,
        grid=(B, Hh, nq),
        in_specs=[
            # packed pyramid: revisited across q (resident per (b, h))
            pl.BlockSpec((1, 1, R, D), lambda b, h, q: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_q, L, P, 2),
                         lambda b, h, q: (b, h, q, 0, 0, 0)),
            pl.BlockSpec((1, 1, block_q, L, P), lambda b, h, q: (b, h, q, 0, 0)),
        ],
        out_specs=out_specs if save_sampled else out_specs[:1],
        out_shape=out_shapes if save_sampled else out_shapes[:1],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(value_p, loc_f, attn_f)
    if save_sampled:
        return outs[0], outs[1]
    return outs[0], None
