"""MSDA backend registry: name -> executor builder.

A *backend* is a strategy for executing one :class:`~repro.kernels.plan.MsdaSpec`
worth of multi-scale deformable attention.  Builders are registered under a
string name and invoked exactly once per plan (see ``plan.msda_plan``); the
returned executor is a differentiable callable ``exec(value, loc, attn)``
whose VJP wiring was committed at build time.

Builder protocol::

    def builder(spec: MsdaSpec, tuning: PlanTuning) -> Callable:
        ...

Built-in backends (registered on first use, from ``repro.kernels.plan``):

* ``"ref"``    — pure-jnp oracle (the conformance suite's ground truth).
* ``"pallas"`` — the xMSDA Pallas kernels (fwd + custom-VJP bwd); tuning
  decides per-level ``block_q``, slab dtypes and the MXU one-hot gather
  routing.
* ``"cpu"``    — CPU-vectorised fused-gather path (vmapped batched
  gather, no Pallas; see ``repro.kernels.msda_cpu``).

Third parties add backends with::

    from repro.kernels import registry

    @registry.backend("my-npu")
    def _build(spec, tuning):
        return my_executor

Every registered backend is automatically exercised by the cross-backend
conformance suite (``tests/conformance.py``), which parametrizes fwd and
VJP parity against ``"ref"`` over ``list_backends()`` x dtype policies.

``"auto"`` is reserved: at plan time it resolves to ``"pallas"`` on TPU,
``"cpu"`` on CPU hosts, and the portable ``"ref"`` oracle anywhere else
(see :func:`resolve_backend`).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax

BackendBuilder = Callable  # (spec, tuning) -> executor

_BACKENDS: Dict[str, BackendBuilder] = {}
_RESERVED = ("auto",)


class UnknownBackendError(ValueError):
    """Raised when a plan names a backend nobody registered."""


def register_backend(name: str, builder: BackendBuilder, *, overwrite: bool = False) -> BackendBuilder:
    """Register ``builder`` under ``name``; returns the builder (decorator-safe)."""
    if name in _RESERVED:
        raise ValueError(f"backend name {name!r} is reserved")
    if name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {name!r} already registered (overwrite=True to replace)")
    _BACKENDS[name] = builder
    return builder


def backend(name: str, *, overwrite: bool = False):
    """Decorator form of :func:`register_backend`."""

    def deco(builder: BackendBuilder) -> BackendBuilder:
        return register_backend(name, builder, overwrite=overwrite)

    return deco


def unregister_backend(name: str) -> None:
    _BACKENDS.pop(name, None)


def resolve_backend(name: str) -> str:
    """``"auto"`` -> concrete backend for the current jax platform.

    TPU gets the Pallas kernels; CPU gets the vectorised ``"cpu"``
    backend: faster forward than the ``"ref"`` oracle (no per-corner
    transposes or gather-side masks; ~1.2x at the paper-scale CPU
    benchmark) and train parity (backward is scatter-bound for both).
    Anything else (GPU, plugins) keeps the portable ``"ref"`` oracle —
    the cpu backend's gather granularity is tuned to XLA:CPU cache
    behaviour and is unmeasured elsewhere.  ``"ref"`` stays the
    conformance target everywhere.
    """
    if name == "auto":
        platform = jax.default_backend()
        if platform == "tpu":
            return "pallas"
        if platform == "cpu":
            return "cpu"
        return "ref"
    return name


def get_backend(name: str) -> BackendBuilder:
    """Look up a registered builder; raises :class:`UnknownBackendError`."""
    _ensure_defaults()
    name = resolve_backend(name)
    try:
        return _BACKENDS[name]
    except KeyError:
        raise UnknownBackendError(
            f"unknown MSDA backend {name!r}; registered: {sorted(_BACKENDS)}"
        ) from None


def list_backends() -> Tuple[str, ...]:
    _ensure_defaults()
    return tuple(sorted(_BACKENDS))


def _ensure_defaults() -> None:
    """Import the plan module so the built-in backends self-register."""
    if not {"ref", "pallas", "cpu"} <= set(_BACKENDS):
        import repro.kernels.plan  # noqa: F401  (registers on import)
