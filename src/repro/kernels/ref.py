"""Pure-jnp oracles for multi-scale deformable attention (MSDA).

Two reference paths, mirroring the paper's evaluation targets:

* :func:`msda_ref` — the fused, vectorised oracle (semantics of the MMCV
  CUDA op / the vendor "CANN" kernel).  This is the correctness oracle
  every Pallas kernel is tested against, and the CPU fallback backend.
* :func:`msda_grid_sample_baseline` — the un-fused ``grid_sample``
  composition (MMCV's pure-PyTorch fallback, the paper's "Baseline"
  column in Table 2): one grid-sample per level, stack, weighted sum,
  materialising the ``(B, H*D, Q, L*P)`` intermediate.

Conventions (MMCV ``MultiScaleDeformableAttnFunction``):

* ``value``:              ``(B, S, H, D)`` with ``S = sum_l H_l * W_l``
* ``spatial_shapes``:     static tuple ``((H_0, W_0), ...)``
* ``sampling_locations``: ``(B, Q, H, L, P, 2)`` normalised to ``[0, 1]``,
  last axis ``(x, y)``
* ``attention_weights``:  ``(B, Q, H, L, P)`` (softmaxed over ``L*P``)
* returns                 ``(B, Q, H * D)``

Bilinear sampling follows ``F.grid_sample(align_corners=False,
padding_mode='zeros')``: pixel coords ``px = x * W - 0.5`` and
out-of-bounds corners contribute zero.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

Shapes = Tuple[Tuple[int, int], ...]


def level_sizes(spatial_shapes: Shapes) -> Tuple[int, ...]:
    return tuple(h * w for h, w in spatial_shapes)


def _bilinear_corners(loc_x, loc_y, H, W):
    """Corner indices + weights for grid_sample(align_corners=False).

    Returns (x0, y0, lx, ly) in fp32; callers derive the 4 corners.
    """
    px = loc_x * W - 0.5
    py = loc_y * H - 0.5
    x0 = jnp.floor(px)
    y0 = jnp.floor(py)
    lx = px - x0
    ly = py - y0
    return x0, y0, lx, ly


def _gather_2d(value_l, x, y, H, W):
    """Zero-padded gather: value_l (B,H,HW,D), x/y (B,Q,H,P) int corners."""
    inb = (x >= 0) & (x < W) & (y >= 0) & (y < H)
    xc = jnp.clip(x, 0, W - 1)
    yc = jnp.clip(y, 0, H - 1)
    flat = yc * W + xc  # (B,Q,Hh,P)
    # value_l: (B, Hh, HW, D) -> gather along HW per (B,Hh)
    # indices: (B,Q,Hh,P) -> (B,Hh,Q*P)
    B, Q, Hh, P = flat.shape
    idx = jnp.transpose(flat, (0, 2, 1, 3)).reshape(B, Hh, Q * P)
    out = jnp.take_along_axis(value_l, idx[..., None], axis=2)  # (B,Hh,Q*P,D)
    out = out.reshape(B, Hh, Q, P, -1)
    out = jnp.transpose(out, (0, 2, 1, 3, 4))  # (B,Q,Hh,P,D)
    return out * inb[..., None].astype(out.dtype)


def msda_ref(
    value: jax.Array,
    spatial_shapes: Shapes,
    sampling_locations: jax.Array,
    attention_weights: jax.Array,
    *,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Fused vectorised MSDA oracle. See module docstring for shapes."""
    B, S, H, D = value.shape
    _, Q, _, L, P, _ = sampling_locations.shape
    assert S == sum(level_sizes(spatial_shapes)), (S, spatial_shapes)
    assert attention_weights.shape == (B, Q, H, L, P)

    out_dtype = value.dtype
    value = value.astype(compute_dtype)
    loc = sampling_locations.astype(compute_dtype)
    attn = attention_weights.astype(compute_dtype)

    # (B, S, H, D) -> (B, H, S, D) once; split per level.
    value_t = jnp.transpose(value, (0, 2, 1, 3))
    out = jnp.zeros((B, Q, H, D), compute_dtype)
    offset = 0
    for l, (Hl, Wl) in enumerate(spatial_shapes):
        hw = Hl * Wl
        value_l = jax.lax.dynamic_slice_in_dim(value_t, offset, hw, axis=2)
        offset += hw
        loc_l = loc[:, :, :, l]  # (B,Q,H,P,2)
        x0f, y0f, lx, ly = _bilinear_corners(loc_l[..., 0], loc_l[..., 1], Hl, Wl)
        x0 = x0f.astype(jnp.int32)
        y0 = y0f.astype(jnp.int32)
        w00 = (1 - lx) * (1 - ly)
        w10 = lx * (1 - ly)
        w01 = (1 - lx) * ly
        w11 = lx * ly
        v00 = _gather_2d(value_l, x0, y0, Hl, Wl)
        v10 = _gather_2d(value_l, x0 + 1, y0, Hl, Wl)
        v01 = _gather_2d(value_l, x0, y0 + 1, Hl, Wl)
        v11 = _gather_2d(value_l, x0 + 1, y0 + 1, Hl, Wl)
        sampled = (
            v00 * w00[..., None]
            + v10 * w10[..., None]
            + v01 * w01[..., None]
            + v11 * w11[..., None]
        )  # (B,Q,H,P,D)
        out = out + jnp.einsum("bqhpd,bqhp->bqhd", sampled, attn[:, :, :, l])
    return out.reshape(B, Q, H * D).astype(out_dtype)


# --------------------------------------------------------------------------
# grid_sample + the un-fused baseline
# --------------------------------------------------------------------------


def grid_sample(input_: jax.Array, grid: jax.Array) -> jax.Array:
    """``F.grid_sample(input, grid, align_corners=False, mode='bilinear',
    padding_mode='zeros')``.

    input_: (B, C, H, W); grid: (B, Hg, Wg, 2) in [-1, 1] (x, y).
    returns (B, C, Hg, Wg).
    """
    B, C, H, W = input_.shape
    gx = (grid[..., 0] + 1.0) * 0.5  # -> [0,1]
    gy = (grid[..., 1] + 1.0) * 0.5
    x0f, y0f, lx, ly = _bilinear_corners(gx, gy, H, W)
    x0 = x0f.astype(jnp.int32)
    y0 = y0f.astype(jnp.int32)

    def corner(xi, yi):
        inb = (xi >= 0) & (xi < W) & (yi >= 0) & (yi < H)
        xc = jnp.clip(xi, 0, W - 1)
        yc = jnp.clip(yi, 0, H - 1)
        flat = (yc * W + xc).reshape(B, -1)  # (B, Hg*Wg)
        v = jnp.take_along_axis(
            input_.reshape(B, C, H * W), flat[:, None, :], axis=2
        )  # (B, C, Hg*Wg)
        return v * inb.reshape(B, 1, -1).astype(v.dtype)

    v00 = corner(x0, y0)
    v10 = corner(x0 + 1, y0)
    v01 = corner(x0, y0 + 1)
    v11 = corner(x0 + 1, y0 + 1)
    w00 = ((1 - lx) * (1 - ly)).reshape(B, 1, -1)
    w10 = (lx * (1 - ly)).reshape(B, 1, -1)
    w01 = ((1 - lx) * ly).reshape(B, 1, -1)
    w11 = (lx * ly).reshape(B, 1, -1)
    out = v00 * w00 + v10 * w10 + v01 * w01 + v11 * w11
    Hg, Wg = grid.shape[1], grid.shape[2]
    return out.reshape(B, C, Hg, Wg)


def msda_grid_sample_baseline(
    value: jax.Array,
    spatial_shapes: Shapes,
    sampling_locations: jax.Array,
    attention_weights: jax.Array,
) -> jax.Array:
    """The paper's "Baseline": MMCV's pure grid-sample composition.

    Materialises per-level sampled tensors and a (B*H, D, Q, L*P)
    intermediate — the memory-traffic-heavy path the paper beats.
    """
    B, S, H, D = value.shape
    _, Q, _, L, P, _ = sampling_locations.shape
    dtype = jnp.float32
    value = value.astype(dtype)
    sizes = level_sizes(spatial_shapes)
    # split per level: list of (B, H*D? ...) -> (B*H, D, Hl, Wl)
    offs = 0
    sampled_all = []
    grids = 2.0 * sampling_locations.astype(dtype) - 1.0  # (B,Q,H,L,P,2)
    for l, (Hl, Wl) in enumerate(spatial_shapes):
        v_l = jax.lax.dynamic_slice_in_dim(value, offs, sizes[l], axis=1)
        offs += sizes[l]
        v_l = jnp.transpose(v_l, (0, 2, 3, 1)).reshape(B * H, D, Hl, Wl)
        g_l = jnp.transpose(grids[:, :, :, l], (0, 2, 1, 3, 4)).reshape(B * H, Q, P, 2)
        sampled = grid_sample(v_l, g_l)  # (B*H, D, Q, P)
        sampled_all.append(sampled)
    stacked = jnp.stack(sampled_all, axis=-2)  # (B*H, D, Q, L, P)
    stacked = stacked.reshape(B * H, D, Q, L * P)
    attn = jnp.transpose(attention_weights.astype(dtype), (0, 2, 1, 3, 4))
    attn = attn.reshape(B * H, 1, Q, L * P)
    out = (stacked * attn).sum(-1)  # (B*H, D, Q)
    out = out.reshape(B, H, D, Q)
    out = jnp.transpose(out, (0, 3, 1, 2)).reshape(B, Q, H * D)
    return out.astype(value.dtype)
