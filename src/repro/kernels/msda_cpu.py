"""CPU-vectorised MSDA backend: batched per-corner gathers, no Pallas.

Off-TPU the registry used to fall back to the ``"ref"`` oracle.  This
backend beats it by restructuring the same math around what XLA:CPU
executes well — and, instructively, NOT by the paper's gather fusion:

* **Padded-slab corners instead of masked gathers.**  It reuses the
  Pallas layout contract (zero-padded ``(H+2, W+2)`` level slabs,
  branch-free corner pairs from ``msda_fwd.corner_indices``), so the
  four bilinear corners are plain ``idx + {0, 1, Wp, Wp+1}`` lookups
  with no per-corner clip and no ``in-bounds`` multiply over the
  gathered ``(B, H, Q, P, D)`` tensor — border masking folds into the
  scalar corner *weights* once.
* **Head-major layout end to end.**  The oracle transposes every
  gathered corner to ``(B, Q, H, P, D)`` (four large copies per level);
  here everything stays ``(B, H, ...)`` with one vmapped batched
  ``jnp.take`` per corner, and only the final output transposes.
* **Four medium gathers, not one giant one.**  A single fused gather of
  all ``4*Q*P`` rows (the TPU-optimal shape) measures ~2-3x SLOWER here:
  its output working set blows the cache hierarchy, while per-corner
  gathers interleave with the weight-multiply consumer.  Fusion
  granularity is a *backend* decision — exactly why the registry keeps
  per-backend builders (and why QUILL-style cache-local execution
  arguments transfer: commit the strategy per backend at plan time).

Differentiation is plain JAX autodiff (gather transposes to
scatter-add), so the backend needs no custom VJP; the dtype policy from
the plan still applies: slabs are stored per-level in
``tuning.slab_dtypes`` and everything accumulates in
``spec.accum_dtype``.

Registered as ``"cpu"`` (see ``repro.kernels.plan``);
``resolve_backend("auto")`` picks it on non-TPU platforms.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def build_cpu_exec(spec, tuning) -> Callable:
    """Backend builder (spec, tuning) -> executor; see registry protocol.

    ``tuning.block_q`` is irrelevant here (XLA:CPU streams the gathers);
    only the dtype commitments are honoured.
    """
    from repro.kernels import ops
    from repro.kernels.msda_fwd import corner_indices
    from repro.kernels.plan import _default_slab_dtypes

    shapes = spec.spatial_shapes
    accum = jnp.dtype(spec.accum_dtype)
    # () -> the spec's resolved slab dtype per level (PlanTuning contract);
    # '' entries (legal per MSDAParams) also fall back to the spec
    slab_dtypes = tuple(
        d or spec.resolved_slab_dtype()
        for d in (tuple(tuning.slab_dtypes) or _default_slab_dtypes(spec)))

    # one batched gather per (b, h): rows of the padded slab by flat index
    take = jax.vmap(jax.vmap(lambda slab, idx: jnp.take(slab, idx, axis=0)))

    def run(value, loc, attn):
        B, S, Hh, D = value.shape
        _, Q, _, L, P, _ = loc.shape
        value_t = jnp.transpose(value, (0, 2, 1, 3))  # (B,H,S,D)
        loc_t = jnp.transpose(loc, (0, 2, 3, 1, 4, 5)).astype(jnp.float32)
        attn_t = jnp.transpose(attn, (0, 2, 3, 1, 4)).astype(accum)

        out = jnp.zeros((B, Hh, Q, D), accum)
        offset = 0
        for l, (h, w) in enumerate(shapes):
            Wp = w + 2
            slab = ops._pad_level(value_t, offset, (h, w)).astype(slab_dtypes[l])
            offset += h * w
            idx00, lx, ly, (m00, m10, m01, m11) = corner_indices(
                loc_t[:, :, l], h, w, Wp)
            i00 = idx00.reshape(B, Hh, Q * P)
            wshape = (B, Hh, Q, P, 1)
            sampled = jnp.zeros((B, Hh, Q, P, D), accum)
            for shift, wgt in (
                (0, (1 - lx) * (1 - ly) * m00),
                (1, lx * (1 - ly) * m10),
                (Wp, (1 - lx) * ly * m01),
                (Wp + 1, lx * ly * m11),
            ):
                g = take(slab, i00 + shift).astype(accum)
                sampled = sampled + g.reshape(B, Hh, Q, P, D) * wgt.astype(
                    accum).reshape(wshape)
            out = out + jnp.einsum("bhqpd,bhqp->bhqd", sampled, attn_t[:, :, l])
        out = jnp.transpose(out, (0, 2, 1, 3)).reshape(B, Q, Hh * D)
        return out.astype(value.dtype)

    return run
