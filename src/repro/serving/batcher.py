"""Shape-bucketed continuous batching for pyramid (DETR/VLM) requests.

The plan cache — and every compiled prefill program — is keyed by the
pyramid's static level geometry.  A serving front end that traces one
program per incoming image size churns both caches without bound.  This
module pads variable image pyramids into a SMALL FIXED SET of bucket
geometries, so the bounded plan cache holds one plan per bucket forever
and every request reuses a boot-compiled executable.

Correctness of the padding (the Deformable-DETR ``valid_ratios`` idiom):
a level ``(h, w)`` is placed top-left into the bucket grid ``(H, W)``
and the *reference points* are scaled by ``(w/W, h/H)``.  With the
MMCV/grid_sample convention ``px = x * W - 0.5`` the scaled coordinate
lands on exactly the same pixel as in the unpadded level —
``(x * w/W) * W - 0.5 == x * w - 0.5`` — and out-of-range corners that
contributed zero via ``padding_mode='zeros'`` now gather literal zeros
from the pad region: same value.  ``tests/test_serving_runtime.py``
checks bucketed outputs against the unbatched reference.

That coordinate identity is EXACT only when every ``w/W`` (and ``h/H``)
is a power of two (the two multiplies are then pure exponent shifts);
at any other ratio ``(x * 0.75) * W`` rounds differently from
``x * w`` by ulps and bucketed serving silently drifts from
exact-geometry serving.  :func:`exact_bucket_ratios` is the admission
gate: :class:`PyramidBatcher` routes non-pow2-ratio requests to a
padding-free exact-geometry bucket (one plan per such geometry — the
bounded-cache trade is explicit) unless the caller opts into the lossy
padding with ``lossy_ok=True``.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

Shapes = Tuple[Tuple[int, int], ...]


@dataclasses.dataclass(frozen=True)
class PyramidBucket:
    """One fixed pyramid geometry requests are padded into."""

    levels: Shapes

    def __post_init__(self):
        object.__setattr__(
            self, "levels", tuple((int(h), int(w)) for h, w in self.levels))

    @property
    def tokens(self) -> int:
        return sum(h * w for h, w in self.levels)

    def fits(self, levels: Shapes) -> bool:
        return len(levels) == len(self.levels) and all(
            h <= H and w <= W for (h, w), (H, W) in zip(levels, self.levels))

    @property
    def key(self) -> str:
        return "/".join(f"{h}x{w}" for h, w in self.levels)


def default_buckets(max_levels: Shapes,
                    scales: Sequence[float] = (1.0, 0.75, 0.5),
                    multiple: int = 2) -> Tuple[PyramidBucket, ...]:
    """A geometric ladder of buckets under the config's maximum pyramid.

    Each scale shrinks every level dimension (rounded up to
    ``multiple``), so small images don't pay full-pyramid padding waste.
    Returned ascending by token count — :func:`bucket_for` picks the
    smallest fit.
    """
    buckets = set()
    for s in scales:
        levels = tuple(
            (max(multiple, math.ceil(h * s / multiple) * multiple),
             max(multiple, math.ceil(w * s / multiple) * multiple))
            for h, w in max_levels)
        buckets.add(PyramidBucket(levels))
    return tuple(sorted(buckets, key=lambda b: b.tokens))


def _pow2_ratio(n: int, d: int) -> bool:
    """True iff d == n * 2**k for integer k >= 0 (exact fp rescale)."""
    if n <= 0 or d % n:
        return False
    q = d // n
    return (q & (q - 1)) == 0


def exact_bucket_ratios(levels: Shapes, bucket_levels: Shapes) -> bool:
    """True iff every valid-ratio rescale is bit-exact in float32.

    ``(x * (w/W)) * W == x * w`` holds for all float32 ``x`` exactly when
    ``W = w * 2**k`` — the ratio is then a pure exponent shift and
    neither multiply rounds.  Checked per level on both axes.
    """
    return all(
        _pow2_ratio(h, H) and _pow2_ratio(w, W)
        for (h, w), (H, W) in zip(levels, bucket_levels))


def bucket_for(levels: Shapes,
               buckets: Sequence[PyramidBucket]) -> Optional[PyramidBucket]:
    """Smallest bucket the pyramid fits in, or None (caller rejects)."""
    for b in sorted(buckets, key=lambda b: b.tokens):
        if b.fits(levels):
            return b
    return None


def pad_pyramid(feats: np.ndarray, levels: Shapes, bucket_levels: Shapes) -> np.ndarray:
    """Pad flattened per-level features ``(S, d)`` into the bucket grid.

    Each level block is reshaped to its 2D grid, placed top-left in the
    bucket's grid, zero-padded right/bottom, and re-flattened row-major
    — so pixel ``(y, x)`` keeps its integer coordinates, which is what
    makes the valid-ratio coordinate scaling exact (module docstring).
    """
    feats = np.asarray(feats)
    total = sum(h * w for h, w in levels)
    if feats.shape[0] != total:
        raise ValueError(f"pyramid has {feats.shape[0]} rows, levels imply {total}")
    d = feats.shape[-1]
    parts, off = [], 0
    for (h, w), (H, W) in zip(levels, bucket_levels):
        grid = np.zeros((H, W, d), feats.dtype)
        grid[:h, :w] = feats[off:off + h * w].reshape(h, w, d)
        parts.append(grid.reshape(H * W, d))
        off += h * w
    return np.concatenate(parts, axis=0)


def valid_ratios(levels: Shapes, bucket_levels: Shapes) -> np.ndarray:
    """Per-level ``(x, y)`` valid fractions ``(w/W, h/H)``: shape (L, 2).

    Axis order matches the sampling-location convention (last axis is
    ``(x, y)``).
    """
    return np.asarray(
        [(w / W, h / H) for (h, w), (H, W) in zip(levels, bucket_levels)],
        np.float32)


def scale_locations(loc, ratios):
    """Map unpadded sampling locations onto the bucket grid.

    ``loc``: (..., L, P, 2) normalised to the ORIGINAL levels; ``ratios``
    from :func:`valid_ratios`.  Raw locations scale directly (the
    refs-vs-offsets split only matters inside the model, where offsets
    are normalised by the padded extents — see ``core.msda``).
    """
    return loc * ratios[..., :, None, :]


# --------------------------------------------------------------------------
# the batching front end
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PyramidBatch:
    """One admitted batch: padded operands + the requests they carry."""

    bucket: PyramidBucket
    feats: np.ndarray  # (B, S_bucket, d)
    ratios: np.ndarray  # (B, L, 2) float32
    items: List[Any]  # caller payloads, batch order
    real_tokens: int
    padded_tokens: int

    @property
    def padding_frac(self) -> float:
        return 1.0 - self.real_tokens / max(self.padded_tokens, 1)


@dataclasses.dataclass
class _Pending:
    feats: np.ndarray
    levels: Shapes
    bucket: PyramidBucket
    group_key: Any
    payload: Any


class PyramidBatcher:
    """FIFO queue that drains same-bucket runs of pyramid requests.

    ``group_key`` is an extra batching constraint supplied by the caller
    (the serving engine uses the prompt length — prefill programs are
    compiled per (bucket, prompt length, batch size)).  Head-of-line
    order is preserved: ``next_batch`` always includes the OLDEST
    pending request and only batches younger requests that share its
    (bucket, group_key), so no bucket can starve another.

    ``lossy_ok=False`` (the default) is the exactness gate: a request
    whose geometry→bucket ratio is not a power of two on every axis is
    routed to a padding-free bucket of its own exact geometry instead of
    being padded (the rescale would round — module docstring).  Pass
    ``lossy_ok=True`` to accept the ulp-level drift and keep the bounded
    bucket set for every request.
    """

    def __init__(self, buckets: Sequence[PyramidBucket],
                 lossy_ok: bool = False):
        if not buckets:
            raise ValueError("need at least one bucket")
        self.buckets = tuple(sorted(buckets, key=lambda b: b.tokens))
        self.lossy_ok = bool(lossy_ok)
        self._queue: Deque[_Pending] = deque()

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, feats: np.ndarray, levels: Shapes, payload: Any,
               group_key: Any = None) -> PyramidBucket:
        levels = tuple((int(h), int(w)) for h, w in levels)
        bucket = bucket_for(levels, self.buckets)
        if bucket is None:
            raise ValueError(
                f"pyramid {levels} fits no bucket "
                f"(largest: {self.buckets[-1].levels})")
        if not self.lossy_ok and not exact_bucket_ratios(levels, bucket.levels):
            # non-pow2 ratio: the valid-ratio rescale would round, so
            # serve this geometry unpadded (ratios all 1.0, no drift)
            bucket = PyramidBucket(levels)
        self._queue.append(_Pending(np.asarray(feats), levels, bucket,
                                    group_key, payload))
        return bucket

    def expire(self, predicate) -> List[Any]:
        """Remove queued requests whose payload matches ``predicate``.

        The engine's deadline sweep: a request whose deadline passed
        while waiting for a slot must leave the queue with a typed
        timeout response instead of being admitted late.  Returns the
        removed payloads in queue order; head-of-line order of the
        survivors is preserved.
        """
        keep: Deque[_Pending] = deque()
        out: List[Any] = []
        for p in self._queue:
            (out.append(p.payload) if predicate(p.payload)
             else keep.append(p))
        self._queue = keep
        return out

    def next_batch(self, max_batch: int) -> Optional[PyramidBatch]:
        """Drain up to ``max_batch`` requests batchable with the head."""
        if not self._queue or max_batch <= 0:
            return None
        head = self._queue[0]
        take: List[_Pending] = []
        keep: List[_Pending] = []
        for p in self._queue:
            if (len(take) < max_batch and p.bucket == head.bucket
                    and p.group_key == head.group_key):
                take.append(p)
            else:
                keep.append(p)
        self._queue = deque(keep)
        bl = head.bucket.levels
        feats = np.stack([pad_pyramid(p.feats, p.levels, bl) for p in take])
        ratios = np.stack([valid_ratios(p.levels, bl) for p in take])
        real = sum(p.feats.shape[0] for p in take)
        return PyramidBatch(
            bucket=head.bucket, feats=feats, ratios=ratios,
            items=[p.payload for p in take], real_tokens=real,
            padded_tokens=len(take) * head.bucket.tokens)
