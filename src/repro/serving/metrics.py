"""Serving metrics: per-bucket admission / padding / latency / retire counters.

Host-side only (plain Python ints — nothing here touches a trace).  The
engine records one event per lifecycle transition; ``snapshot()`` is the
machine-readable view the smoke job and benchmarks consume, and
``format()`` is the human table ``launch/serve.py`` prints.

Latency is measured in engine *ticks* (one batched decode step each),
the natural unit for a continuous-batching engine: queue ticks count
time spent waiting for a slot, decode ticks count time in service.

Every lifecycle event is mirrored into the process-wide ``repro.obs``
registry (``serve.*`` series, labeled per engine instance) so
``--metrics-out`` exports the same numbers; ``snapshot()`` additionally
embeds the plan-execution block (plan-cache / winner-cache hit rates,
Pallas launches per direction) from ``kernels/plan.py``.

Memory is bounded for arbitrarily long serving runs: raw latency /
queue-wait samples live in an ``obs.NumericWindow`` ring (exact
count/mean/max, windowed p50 — the same contract registry histograms
already use), and the per-request submit/admit tick maps are dropped on
retire.  Resilience events (PR 10: sheds, deadline misses, executor
errors) are plain exact counters surfaced under ``snapshot()["shed"]``
/ ``["deadline_misses"]`` and the ``serve.resilience.*`` registry
series.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, Optional

from repro.obs import registry as _obs

_ENGINE_IDS = itertools.count()

# raw per-request samples kept for percentiles; counters stay exact
_SAMPLE_WINDOW = 4096


def _bucket_row() -> Dict[str, int]:
    return {"admitted": 0, "batches": 0, "real_tokens": 0, "padded_tokens": 0}


class ServeMetrics:
    """Counters for one engine's lifetime."""

    def __init__(self):
        self.ticks = 0
        self.submitted = 0
        self.admitted = 0
        self.retired = 0
        self.decode_tokens = 0
        self.shed = 0
        self.deadline_misses = 0
        self.exec_errors = 0
        self.stragglers = 0
        self.buckets: Dict[str, Dict[str, int]] = {}
        self._submit_tick: Dict[int, int] = {}
        self._admit_tick: Dict[int, int] = {}
        self.latency_ticks = _obs.NumericWindow(_SAMPLE_WINDOW)
        self.queue_ticks = _obs.NumericWindow(_SAMPLE_WINDOW)
        # registry mirror: one label value per engine instance so two
        # engines in one process stay separable in the export
        self._eid = f"e{next(_ENGINE_IDS)}"
        self._events = _obs.counter(
            "serve.events", help="engine lifecycle events by type")
        self._resil = _obs.counter(
            "serve.resilience.events",
            help="resilience events by type (shed/deadline_miss/retry/...)")
        self._lat = _obs.histogram(
            "serve.latency_ticks", help="submit->retire latency in ticks")
        self._queue = _obs.histogram(
            "serve.queue_ticks", help="submit->admit wait in ticks")

    # -- lifecycle events --------------------------------------------------
    def record_tick(self) -> None:
        self.ticks += 1
        self._events.inc(engine=self._eid, type="tick")

    def record_submit(self, rid: int) -> None:
        self.submitted += 1
        self._submit_tick[rid] = self.ticks
        self._events.inc(engine=self._eid, type="submit")

    def record_admit(self, rids, bucket_key: str = "lm", *,
                     real_tokens: int = 0, padded_tokens: int = 0) -> None:
        """One admitted batch (``rids`` may be a single id or a list)."""
        rids = rids if isinstance(rids, (list, tuple)) else [rids]
        row = self.buckets.setdefault(bucket_key, _bucket_row())
        row["admitted"] += len(rids)
        row["batches"] += 1
        row["real_tokens"] += int(real_tokens)
        row["padded_tokens"] += int(padded_tokens)
        self.admitted += len(rids)
        self._events.inc(len(rids), engine=self._eid, type="admit")
        for rid in rids:
            self._admit_tick[rid] = self.ticks
            if rid in self._submit_tick:
                wait = self.ticks - self._submit_tick[rid]
                self.queue_ticks.append(wait)
                self._queue.observe(wait, engine=self._eid)

    def record_decode(self, n_active: int) -> None:
        self.decode_tokens += int(n_active)
        self._events.inc(int(n_active), engine=self._eid, type="decode_token")

    def record_retire(self, rid: int) -> None:
        self.retired += 1
        self._events.inc(engine=self._eid, type="retire")
        # pop, not get: the per-request maps must not outlive the request
        admit = self._admit_tick.pop(rid, None)
        submit = self._submit_tick.pop(rid, None)
        start = admit if admit is not None else submit
        if start is not None:
            lat = self.ticks - start
            self.latency_ticks.append(lat)
            self._lat.observe(lat, engine=self._eid)

    # -- resilience events (PR 10) ----------------------------------------
    # shed's obs mirror lives in resilience.AdmissionController (the
    # component that makes the decision); here it is the exact counter
    def record_shed(self, rid: int) -> None:
        self.shed += 1
        self._submit_tick.pop(rid, None)

    def record_deadline_miss(self, rid: int) -> None:
        self.deadline_misses += 1
        self._resil.inc(engine=self._eid, type="deadline_miss")
        self._submit_tick.pop(rid, None)
        self._admit_tick.pop(rid, None)

    def record_exec_error(self, rid: int) -> None:
        self.exec_errors += 1
        self._resil.inc(engine=self._eid, type="exec_error")
        self._submit_tick.pop(rid, None)
        self._admit_tick.pop(rid, None)

    def record_straggler(self) -> None:
        self.stragglers += 1
        self._resil.inc(engine=self._eid, type="straggler")

    # -- views -------------------------------------------------------------
    @staticmethod
    def _summ(xs: "_obs.NumericWindow") -> Optional[Dict[str, float]]:
        if not xs:
            return None
        return {"p50": xs.p50, "max": xs.max, "mean": xs.mean}

    def snapshot(self) -> Dict[str, Any]:
        buckets = {}
        for key, row in self.buckets.items():
            pad = row["padded_tokens"]
            buckets[key] = dict(
                row, padding_frac=1.0 - row["real_tokens"] / pad if pad else 0.0)
        from repro.kernels import plan as plan_mod

        return {
            "ticks": self.ticks, "submitted": self.submitted,
            "admitted": self.admitted, "retired": self.retired,
            "decode_tokens": self.decode_tokens, "buckets": buckets,
            "shed": self.shed, "deadline_misses": self.deadline_misses,
            "exec_errors": self.exec_errors, "stragglers": self.stragglers,
            "latency_ticks": self._summ(self.latency_ticks),
            "queue_ticks": self._summ(self.queue_ticks),
            "plan_execution": plan_mod.execution_telemetry(),
        }

    def format(self) -> str:
        s = self.snapshot()
        lines = [
            f"serve metrics: {s['submitted']} submitted, {s['admitted']} admitted, "
            f"{s['retired']} retired over {s['ticks']} ticks "
            f"({s['decode_tokens']} decode tokens)"]
        pe = s["plan_execution"]
        lines.append(
            f"  plan cache {pe['plan_cache']['hits']}H/"
            f"{pe['plan_cache']['misses']}M  winner cache "
            f"{pe['winner_cache']['hits']}H/{pe['winner_cache']['misses']}M "
            f"(+{pe['winner_cache']['seeded']} seeded)  launches "
            f"fwd={pe['launches']['fwd']} bwd={pe['launches']['bwd']}")
        if s["latency_ticks"]:
            lt, qt = s["latency_ticks"], s["queue_ticks"]
            lines.append(
                f"  latency ticks p50={lt['p50']:.0f} max={lt['max']:.0f}"
                + (f"  queue p50={qt['p50']:.0f} max={qt['max']:.0f}" if qt else ""))
        if s["shed"] or s["deadline_misses"] or s["exec_errors"] or s["stragglers"]:
            lines.append(
                f"  resilience: {s['shed']} shed, {s['deadline_misses']} deadline "
                f"misses, {s['exec_errors']} exec errors, "
                f"{s['stragglers']} stragglers")
        if s["buckets"]:
            lines.append("  bucket                    admitted  batches  pad%")
            for key, row in sorted(s["buckets"].items()):
                lines.append(
                    f"  {key:<25s} {row['admitted']:<9d} {row['batches']:<8d} "
                    f"{100 * row['padding_frac']:.1f}")
        return "\n".join(lines)
