"""Persistent plan store: rebuild a server's full plan set across restarts.

Three things make a cold serving boot slow: plan construction (block
planning), the autotune candidate races (real timing runs), and XLA
compilation.  This module removes all three from a *restarted* process:

* :class:`PlanStore` — a versioned JSON file holding every warmed
  :class:`~repro.kernels.plan.MsdaPlan`'s spec, backend, tune mode and
  autotune winner.  ``restore()`` seeds the winners into the on-disk
  autotune cache (``seed_autotune_winner`` — same ``cache_token()``
  keying the race itself uses) and rebuilds each plan; ``tune="autotune"``
  then resolves to ``autotune-cache`` with ZERO timing runs, which the
  CI serving-smoke job asserts via ``plan.autotune_stats()``.
* :func:`enable_jax_compilation_cache` — wires JAX's persistent
  compilation cache to a directory, so the restarted process's AOT
  ``lower().compile()`` calls at boot are disk hits, not fresh XLA
  compiles (:func:`compilation_cache_entries` counts the artifacts for
  the smoke job's no-recompilation assertion).

The store is written atomically (tmp + rename) and refuses nothing at
read time: a missing file, a version mismatch, or an entry written by a
newer schema all degrade to a cold start for that entry, never an error
— a stale store must not take a server down.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

import jax

from repro.kernels import plan as plan_mod

# v2 grew the optional per-entry "sharding" record (distributed plans:
# mode, mesh axes/shape, query_parallel, grad_reduce) and the mesh-keyed
# winner seeding that goes with it.  v3 grew the whole-pyramid fusion
# decision: specs carry ``fuse_levels``, autotune winners the optional
# ``fuse_levels`` / ``onehot_levels`` / ``grad_reduce`` fields — all
# round-tripped so a restored plan keeps the raced decisions with zero
# timing runs.  v1/v2 stores load unchanged; entries a NEWER schema
# writes still degrade per entry.
PLAN_STORE_VERSION = 3
_READABLE_VERSIONS = (1, 2, 3)


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


def _norm_describe(text: str) -> str:
    """Canonical describe() for drift comparison: a plan autotuned live
    and the same plan restored from its persisted winner differ only in
    the tune-source tag ("autotune" vs "autotune-cache") — that is
    provenance, not plan content."""
    return text.replace("tune=autotune-cache", "tune=autotune")


@dataclasses.dataclass
class RestoreReport:
    """What a ``PlanStore.restore()`` actually did."""

    plans: List[Any] = dataclasses.field(default_factory=list)
    seeded_winners: int = 0
    skipped: List[str] = dataclasses.field(default_factory=list)
    describe_mismatches: List[str] = dataclasses.field(default_factory=list)

    @property
    def cold(self) -> bool:
        return not self.plans and not self.skipped


class PlanStore:
    """Versioned on-disk record of a serving process's warmed plans."""

    def __init__(self, path: str):
        self.path = str(path)

    def exists(self) -> bool:
        return os.path.exists(self.path)

    # -- save --------------------------------------------------------------
    def save_plans(self, plans: Sequence, *, meta: Optional[Dict[str, Any]] = None) -> int:
        """Serialise every plan — local AND distributed; returns the count.

        Autotuned plans store their winner; heuristic plans re-derive
        their blocks deterministically at restore (same spec, same
        device kind -> same plan), so nothing extra is persisted.

        Mesh-carrying plans store their distribution record (mode, mesh
        axes + shape, query_parallel, grad_reduce) — NOT device objects;
        a restarted process supplies its own mesh to ``restore(mesh=...)``
        and the entry only applies when the topology matches, so a store
        written on a 2x2 slice never silently mis-shards a 1x4 boot.
        The winner of a sharded plan is keyed on its LOCAL (per-shard)
        spec plus a mesh-keyed 1D-vs-2D entry; both are re-seeded at
        restore so the rebuild races nothing.
        """
        entries = []
        for plan in plans:
            src = plan.tuning.source
            entry: Dict[str, Any] = {
                "spec": plan_mod.spec_to_json(plan.spec),
                "backend": plan.backend,
                "tune": "autotune" if src.startswith("autotune") else "heuristic",
                "source": src,
                "device_kind": _device_kind(),
                "describe": plan.describe(),
            }
            if plan.sharding_mode != "local":
                entry["sharding"] = {
                    "mode": plan.sharding_mode,
                    "mesh_axes": list(plan.mesh_axes),
                    "mesh_shape": [int(s) for s in plan.mesh_shape],
                    "query_parallel": bool(plan.query_parallel),
                    "grad_reduce": plan.grad_reduce,
                }
            if src == "override":
                entry["block_q"] = [int(b) for b in plan.tuning.block_q]
            if src.startswith("autotune"):
                winner: Dict[str, Any] = {
                    "block_q": [int(b) for b in plan.tuning.block_q],
                    "slab_dtypes": list(plan.tuning.slab_dtypes),
                    # the fusion race's decision rides along so a
                    # restored plan re-commits it with zero timing runs
                    "fuse_levels": bool(plan.tuning.fuse_levels),
                }
                if plan.spec.onehot_small_levels and plan.tuning.onehot_levels:
                    winner["onehot_levels"] = [
                        bool(x) for x in plan.tuning.onehot_levels]
                entry["winner"] = winner
            entries.append(entry)
        payload = {
            "version": PLAN_STORE_VERSION,
            "jax": jax.__version__,
            "device_kind": _device_kind(),
            "created_unix": time.time(),
            "meta": meta or {},
            "entries": entries,
        }
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)
        return len(entries)

    # -- load / restore ----------------------------------------------------
    def load(self) -> Optional[Dict[str, Any]]:
        """Raw payload, or None when missing/corrupt/wrong version."""
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return None
        if not isinstance(data, dict) or data.get("version") not in _READABLE_VERSIONS:
            return None
        return data

    def restore(self, *, mesh=None, verify_describe: bool = True) -> RestoreReport:
        """Rebuild every stored plan; zero autotune races, by seeding.

        For each entry: the persisted winner (if any, and if recorded on
        this device kind) is seeded into the autotune disk cache first,
        so the subsequent ``msda_plan(..., tune="autotune")`` is a cache
        hit — plan construction runs, timing does not.  Entries that
        fail to parse (newer schema, unknown backend) are recorded in
        ``report.skipped`` and the boot proceeds cold for them.

        ``mesh``: the restarting process's mesh.  A distributed entry is
        rebuilt only when the mesh's (axis names, shape) match the
        entry's record — its winner is then ALSO seeded under the
        mesh-keyed 1D-vs-2D race key and its local (per-shard) spec key,
        and the plan is rebuilt with the stored mode PINNED, so the
        restore performs zero sharding races and zero block races.
        Distributed entries with no/mismatched mesh are skipped
        (degrade, never die — same contract as every other field).
        """
        report = RestoreReport()
        data = self.load()
        if data is None:
            return report
        here = _device_kind()
        # pass 1: parse specs + batch-seed every winner (one cache write)
        parsed = []
        seeds = []
        for i, entry in enumerate(data.get("entries", ())):
            try:
                spec = plan_mod.spec_from_json(entry["spec"])
                shard = entry.get("sharding")
                choice = None
                if shard is not None:
                    if mesh is None:
                        raise ValueError(
                            f"distributed entry ({shard.get('mode')}) needs a mesh")
                    if (list(mesh.axis_names) != list(shard["mesh_axes"])
                            or [int(s) for s in mesh.devices.shape]
                            != [int(s) for s in shard["mesh_shape"]]):
                        raise ValueError(
                            f"mesh mismatch: store has "
                            f"{plan_mod.mesh_token_from(shard['mesh_axes'], shard['mesh_shape'])}, "
                            f"process has {plan_mod.mesh_token(mesh)}")
                    choice = "2d" if shard["mode"] == "query2d" else "1d"
                parsed.append((i, entry, spec, shard, choice))
            except Exception as e:  # noqa: BLE001 — degrade per entry, never die
                report.skipped.append(f"entry {i}: {type(e).__name__}: {e}")
                continue
            if (entry.get("winner") is not None and entry.get("backend")
                    and entry.get("device_kind", here) == here):
                if shard is None:
                    seeds.append((spec, entry["backend"], entry["winner"]))
                else:
                    qp = bool(shard.get("query_parallel"))
                    # the block/dtype winner belongs to the LOCAL spec
                    # (the geometry the race actually timed) ...
                    _, local_spec = plan_mod.resolve_sharding(
                        spec, mesh, qp, choice)
                    seeds.append((local_spec, entry["backend"], entry["winner"]))
                    # ... and the sharding choice — plus the raced
                    # grad_value reduction, so request-time
                    # grad_reduce="auto" plans resolve it from the cache
                    # instead of re-racing ring vs psum — to the
                    # mesh-keyed race entry
                    mesh_winner = dict(entry["winner"], sharding=choice)
                    if shard.get("grad_reduce") in ("ring", "psum"):
                        mesh_winner["grad_reduce"] = shard["grad_reduce"]
                    seeds.append((spec, entry["backend"], mesh_winner,
                                  plan_mod.mesh_winner_suffix(mesh, qp)))
        report.seeded_winners = plan_mod.seed_autotune_winners(seeds)
        # pass 2: rebuild the plans (autotune resolves via the seeds)
        for i, entry, spec, shard, choice in parsed:
            try:
                block_q = entry.get("block_q")
                kwargs: Dict[str, Any] = {}
                if shard is not None:
                    kwargs = dict(
                        mesh=mesh,
                        query_parallel=bool(shard.get("query_parallel")),
                        grad_reduce=shard.get("grad_reduce") or "auto")
                    if kwargs["grad_reduce"] == "none":
                        kwargs["grad_reduce"] = "auto"
                common = dict(
                    backend=entry["backend"],
                    tune=entry.get("tune", "heuristic"),
                    block_q=tuple(block_q) if block_q else None, **kwargs)
                if shard is not None:
                    # try sharding="auto" FIRST: the request path
                    # (attention_plan with the config default) asks for
                    # "auto", and the plan cache keys on the sharding
                    # string — restoring under "auto" lets requests hit
                    # THIS plan object.  The seeded mesh-race winner
                    # pins "auto" to the stored mode with zero timing;
                    # if the ladder still resolves differently (e.g. a
                    # 2d-forced plan below the auto threshold), retry
                    # with the mode pinned so the rebuild stays exact.
                    plan = plan_mod.msda_plan(spec, sharding="auto", **common)
                    if plan.sharding_mode != shard["mode"]:
                        plan = plan_mod.msda_plan(
                            spec, sharding=choice, **common)
                else:
                    plan = plan_mod.msda_plan(spec, **common)
                if shard is not None and plan.sharding_mode != shard["mode"]:
                    report.skipped.append(
                        f"entry {i}: sharding mode drifted "
                        f"({shard['mode']} -> {plan.sharding_mode})")
                    continue
            except Exception as e:  # noqa: BLE001
                report.skipped.append(f"entry {i}: {type(e).__name__}: {e}")
                continue
            if verify_describe and entry.get("describe"):
                if _norm_describe(plan.describe()) != _norm_describe(entry["describe"]):
                    report.describe_mismatches.append(
                        f"entry {i}: plan.describe() differs from stored "
                        f"(device_kind {entry.get('device_kind')} -> {here}?)")
            report.plans.append(plan)
        return report


# --------------------------------------------------------------------------
# JAX persistent compilation cache
# --------------------------------------------------------------------------


def enable_jax_compilation_cache(cache_dir: str) -> bool:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Thresholds are zeroed so even the CPU tier's fast compiles persist
    (the default min-compile-time gate would skip them, and the smoke
    job's no-recompilation assertion needs every executable cached).
    Best-effort: an old jax without the knobs just serves cold.
    """
    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(cache_dir))
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:
        return False
    try:
        # jax latches cache initialisation at the process's FIRST compile
        # and never re-reads the dir config: a boot that compiled anything
        # (params init!) before reaching here would silently cache nothing.
        # Drop the latched (empty-dir) state so the next compile re-reads.
        from jax._src import compilation_cache as _cc

        _cc.reset_cache()
    except Exception:
        pass  # private API moved: processes that set the dir early still cache
    return True


def compilation_cache_entries(cache_dir: str) -> int:
    """Number of persisted executables (the smoke job's probe)."""
    try:
        return sum(1 for n in os.listdir(cache_dir) if n.endswith("-cache"))
    except OSError:
        return 0
