"""Persistent plan store: rebuild a server's full plan set across restarts.

Three things make a cold serving boot slow: plan construction (block
planning), the autotune candidate races (real timing runs), and XLA
compilation.  This module removes all three from a *restarted* process:

* :class:`PlanStore` — a versioned JSON file holding every warmed
  :class:`~repro.kernels.plan.MsdaPlan`'s spec, backend, tune mode and
  autotune winner.  ``restore()`` seeds the winners into the on-disk
  autotune cache (``seed_autotune_winner`` — same ``cache_token()``
  keying the race itself uses) and rebuilds each plan; ``tune="autotune"``
  then resolves to ``autotune-cache`` with ZERO timing runs, which the
  CI serving-smoke job asserts via ``plan.autotune_stats()``.
* :func:`enable_jax_compilation_cache` — wires JAX's persistent
  compilation cache to a directory, so the restarted process's AOT
  ``lower().compile()`` calls at boot are disk hits, not fresh XLA
  compiles (:func:`compilation_cache_entries` counts the artifacts for
  the smoke job's no-recompilation assertion).

The store is written atomically (tmp + rename) and refuses nothing at
read time: a missing file, a version mismatch, or an entry written by a
newer schema all degrade to a cold start for that entry, never an error
— a stale store must not take a server down.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

import jax

from repro.kernels import plan as plan_mod
from repro.obs import trace as _obs_trace

# v2 grew the optional per-entry "sharding" record (distributed plans:
# mode, mesh axes/shape, query_parallel, grad_reduce) and the mesh-keyed
# winner seeding that goes with it.  v3 grew the whole-pyramid fusion
# decision: specs carry ``fuse_levels``, autotune winners the optional
# ``fuse_levels`` / ``onehot_levels`` / ``grad_reduce`` fields — all
# round-tripped so a restored plan keeps the raced decisions with zero
# timing runs.  v4 grew the hybrid batch x query sharding mode
# ('batchquery', with its ``batch_tile`` in the sharding record) and the
# elastic restore path (``on_mesh_mismatch="rerace"``).  v5 grew the
# sparsity axes: specs carry ``sparsity``/``sparsity_k``/``query_order``
# and autotune winners the optional ``sparsity`` / ``query_order``
# fields (pruned-vs-dense and Morton-vs-identity race decisions).
# v6 grew the partial-fusion tier: specs may pin ``fuse_levels`` to
# "prefix:k" and autotune winners carry the optional ``fuse_prefix``
# field (the 3-way per-level / prefix / full-pyramid race's decision) —
# absent means what it always meant, "fuse everything fuse_levels says
# to", so every pre-tier winner keeps its exact historical semantics.
# v1-v5 stores load unchanged; entries a NEWER schema writes still
# degrade per entry, and unknown winner fields ride through the
# parse/rewrite cycle untouched (``_winner_entry`` extras).
PLAN_STORE_VERSION = 6
_READABLE_VERSIONS = (1, 2, 3, 4, 5, 6)

# stored sharding mode -> the planner's sharding= pin that reproduces it
_MODE_TO_CHOICE = {"query2d": "2d", "batchquery": "hybrid"}


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


def _norm_describe(text: str) -> str:
    """Canonical describe() for drift comparison: a plan autotuned live
    and the same plan restored from its persisted winner differ only in
    the tune-source tag ("autotune" vs "autotune-cache") — that is
    provenance, not plan content."""
    return text.replace("tune=autotune-cache", "tune=autotune")


@dataclasses.dataclass
class RestoreReport:
    """What a ``PlanStore.restore()`` actually did."""

    plans: List[Any] = dataclasses.field(default_factory=list)
    seeded_winners: int = 0
    skipped: List[str] = dataclasses.field(default_factory=list)
    describe_mismatches: List[str] = dataclasses.field(default_factory=list)
    # entries whose stored mesh topology did not match the process's and
    # were recovered by re-racing the mesh-keyed axes (elastic restore,
    # ``on_mesh_mismatch="rerace"``); one human-readable line per entry
    reraced: List[str] = dataclasses.field(default_factory=list)

    @property
    def cold(self) -> bool:
        # "cold" = nothing restored.  An unreadable store also lands a
        # named line in ``skipped``, but the boot is cold either way.
        return not self.plans


class PlanStore:
    """Versioned on-disk record of a serving process's warmed plans."""

    def __init__(self, path: str):
        self.path = str(path)

    def exists(self) -> bool:
        return os.path.exists(self.path)

    # -- save --------------------------------------------------------------
    def save_plans(self, plans: Sequence, *, meta: Optional[Dict[str, Any]] = None) -> int:
        """Serialise every plan — local AND distributed; returns the count.

        Autotuned plans store their winner; heuristic plans re-derive
        their blocks deterministically at restore (same spec, same
        device kind -> same plan), so nothing extra is persisted.

        Mesh-carrying plans store their distribution record (mode, mesh
        axes + shape, query_parallel, grad_reduce) — NOT device objects;
        a restarted process supplies its own mesh to ``restore(mesh=...)``
        and the entry only applies when the topology matches, so a store
        written on a 2x2 slice never silently mis-shards a 1x4 boot.
        The winner of a sharded plan is keyed on its LOCAL (per-shard)
        spec plus a mesh-keyed 1D-vs-2D entry; both are re-seeded at
        restore so the rebuild races nothing.
        """
        entries = []
        for plan in plans:
            src = plan.tuning.source
            entry: Dict[str, Any] = {
                "spec": plan_mod.spec_to_json(plan.spec),
                "backend": plan.backend,
                "tune": ("autotune"
                         if src.startswith("autotune")
                         or getattr(plan, "tune", "heuristic") == "autotune"
                         else "heuristic"),
                "source": src,
                "device_kind": _device_kind(),
                "describe": plan.describe(),
            }
            if plan.sharding_mode != "local":
                entry["sharding"] = {
                    "mode": plan.sharding_mode,
                    "mesh_axes": list(plan.mesh_axes),
                    "mesh_shape": [int(s) for s in plan.mesh_shape],
                    "query_parallel": bool(plan.query_parallel),
                    "grad_reduce": plan.grad_reduce,
                }
                if plan.sharding_mode == "batchquery":
                    entry["sharding"]["batch_tile"] = int(plan.batch_tile)
            if src == "override":
                entry["block_q"] = [int(b) for b in plan.tuning.block_q]
            if src.startswith("autotune"):
                winner: Dict[str, Any] = {
                    "block_q": [int(b) for b in plan.tuning.block_q],
                    "slab_dtypes": list(plan.tuning.slab_dtypes),
                    # the fusion race's decision rides along so a
                    # restored plan re-commits it with zero timing runs
                    "fuse_levels": bool(plan.tuning.fuse_levels),
                }
                # strict partial-fusion tier (0 < k < L): persisted only
                # when the race actually chose one, so full-fusion and
                # per-level winners stay byte-identical to pre-v6 stores
                if plan.tuning.fuse_levels and plan.tuning.fuse_prefix:
                    winner["fuse_prefix"] = int(plan.tuning.fuse_prefix)
                if plan.spec.onehot_small_levels and plan.tuning.onehot_levels:
                    winner["onehot_levels"] = [
                        bool(x) for x in plan.tuning.onehot_levels]
                # the sparsity rungs' raced decisions persist only when
                # the axis actually raced ('auto') — pinned/off specs
                # keep their pre-sparsity entry byte-identical
                if plan.spec.sparsity == "auto":
                    winner["sparsity"] = plan.tuning.sparsity
                if plan.spec.query_order == "auto":
                    winner["query_order"] = plan.tuning.query_order
                entry["winner"] = winner
            entries.append(entry)
        payload = {
            "version": PLAN_STORE_VERSION,
            "jax": jax.__version__,
            "device_kind": _device_kind(),
            "created_unix": time.time(),
            "meta": meta or {},
            "entries": entries,
        }
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)
        return len(entries)

    # -- load / restore ----------------------------------------------------
    def load(self) -> Optional[Dict[str, Any]]:
        """Raw payload, or None when missing/corrupt/wrong version."""
        data, _ = self._load_with_reason()
        return data

    def _load_with_reason(self):
        """(payload, None) or (None, reason) — the reason distinguishes a
        merely-missing store (no message) from a store that EXISTS but
        could not be read, which ``restore()`` surfaces in
        ``report.skipped`` instead of silently booting cold."""
        try:
            with open(self.path) as f:
                data = json.load(f)
        except FileNotFoundError:
            return None, None
        except OSError as e:
            return None, f"store {self.path}: unreadable ({e})"
        except ValueError as e:
            return None, f"store {self.path}: corrupt JSON ({e})"
        if not isinstance(data, dict):
            return None, f"store {self.path}: not a JSON object"
        if data.get("version") not in _READABLE_VERSIONS:
            return None, (f"store {self.path}: version {data.get('version')!r} "
                          f"not in readable {_READABLE_VERSIONS}")
        return data, None

    @_obs_trace.traced_span("plan.restore", level=2)
    def restore(self, *, mesh=None, verify_describe: bool = True,
                on_mesh_mismatch: str = "skip") -> RestoreReport:
        """Rebuild every stored plan; zero autotune races, by seeding.

        For each entry: the persisted winner (if any, and if recorded on
        this device kind) is seeded into the autotune disk cache first,
        so the subsequent ``msda_plan(..., tune="autotune")`` is a cache
        hit — plan construction runs, timing does not.  Entries that
        fail to parse (newer schema, unknown backend) are recorded in
        ``report.skipped`` — each line names the offending ENTRY (index,
        backend, geometry), never the whole file — and the boot proceeds
        cold for them.  A store that exists but cannot be read at all is
        itself one named ``skipped`` line.

        ``mesh``: the restarting process's mesh.  A distributed entry is
        rebuilt only when the mesh's (axis names, shape) match the
        entry's record — its winner is then ALSO seeded under the
        mesh-keyed sharding-race key and its local (per-shard) spec key,
        and the plan is rebuilt with the stored mode PINNED, so the
        restore performs zero sharding races and zero block races.

        ``on_mesh_mismatch`` decides what a topology mismatch does:

        * ``"skip"`` (default — the serving boot contract): the entry is
          recorded in ``report.skipped`` and that plan boots cold.
        * ``"rerace"`` (the elastic training path): the entry's LOCAL
          winner is re-seeded onto the per-shard geometry the NEW mesh
          implies — so the block/dtype/fuse axes stay zero-timing cache
          hits — and the plan is rebuilt under ``sharding="auto"`` /
          ``grad_reduce="auto"``, which re-races EXACTLY the mesh-keyed
          axes (sharding mode, grad_value reduction) and persists the
          new winners per the new topology.  Recovered entries are
          listed in ``report.reraced``.  When the topology matches,
          behaviour is identical to "skip" (zero re-race either way).
        """
        if on_mesh_mismatch not in ("skip", "rerace"):
            raise ValueError(
                f"on_mesh_mismatch={on_mesh_mismatch!r}; 'skip' or 'rerace'")
        report = RestoreReport()
        data, why = self._load_with_reason()
        if data is None:
            if why:
                report.skipped.append(why)
            return report
        here = _device_kind()

        def _label(i, entry) -> str:
            """Name the offending entry, not the whole file."""
            bits = [f"entry {i}"]
            try:
                s = entry.get("spec") or {}
                bits.append(f"backend={entry.get('backend')}")
                if "num_queries" in s:
                    bits.append(f"Q={s['num_queries']}")
                if "spatial_shapes" in s:
                    bits.append(f"levels={len(s['spatial_shapes'])}")
                shard = entry.get("sharding")
                if shard:
                    bits.append(f"mode={shard.get('mode')}")
            except Exception:  # noqa: BLE001 — labels must never throw
                pass
            return " ".join(bits)

        # pass 1: parse specs + batch-seed every winner (one cache write)
        parsed = []
        seeds = []
        for i, entry in enumerate(data.get("entries", ())):
            try:
                spec = plan_mod.spec_from_json(entry["spec"])
                shard = entry.get("sharding")
                choice = None
                elastic = False
                if shard is not None:
                    if mesh is None:
                        raise ValueError(
                            f"distributed entry ({shard.get('mode')}) needs a mesh")
                    if (list(mesh.axis_names) != list(shard["mesh_axes"])
                            or [int(s) for s in mesh.devices.shape]
                            != [int(s) for s in shard["mesh_shape"]]):
                        if on_mesh_mismatch != "rerace":
                            raise ValueError(
                                f"mesh mismatch: store has "
                                f"{plan_mod.mesh_token_from(shard['mesh_axes'], shard['mesh_shape'])}, "
                                f"process has {plan_mod.mesh_token(mesh)}")
                        elastic = True
                    if not elastic:
                        choice = _MODE_TO_CHOICE.get(shard["mode"], "1d")
                parsed.append((i, entry, spec, shard, choice, elastic))
            except Exception as e:  # noqa: BLE001 — degrade per entry, never die
                report.skipped.append(
                    f"{_label(i, entry)}: {type(e).__name__}: {e}")
                continue
            if (entry.get("winner") is not None and entry.get("backend")
                    and entry.get("device_kind", here) == here):
                if shard is None:
                    seeds.append((spec, entry["backend"], entry["winner"]))
                elif elastic:
                    # topology changed: the stored LOCAL winner still
                    # applies — re-key it onto the per-shard geometry
                    # the NEW mesh's auto ladder implies (blocks clamped
                    # to the new local query extent), so the rebuild's
                    # block/dtype/fuse races are cache hits and only the
                    # mesh-keyed axes re-race
                    qp = bool(shard.get("query_parallel"))
                    _, local_spec = plan_mod.resolve_sharding(
                        spec, mesh, qp, "auto")
                    winner = dict(entry["winner"])
                    bq = winner.get("block_q")
                    if isinstance(bq, list):
                        qcap = -(-local_spec.num_queries // 8) * 8
                        winner["block_q"] = [
                            max(8, min(int(b), qcap)) for b in bq]
                    seeds.append((local_spec, entry["backend"], winner))
                else:
                    qp = bool(shard.get("query_parallel"))
                    # the block/dtype winner belongs to the LOCAL spec
                    # (the geometry the race actually timed) ...
                    _, local_spec = plan_mod.resolve_sharding(
                        spec, mesh, qp, choice)
                    seeds.append((local_spec, entry["backend"], entry["winner"]))
                    # ... and the sharding choice — plus the raced
                    # grad_value reduction, so request-time
                    # grad_reduce="auto" plans resolve it from the cache
                    # instead of re-racing ring vs psum — to the
                    # mesh-keyed race entry
                    mesh_winner = dict(entry["winner"], sharding=choice)
                    if shard.get("grad_reduce") in ("ring", "psum"):
                        mesh_winner["grad_reduce"] = shard["grad_reduce"]
                    seeds.append((spec, entry["backend"], mesh_winner,
                                  plan_mod.mesh_winner_suffix(mesh, qp)))
        report.seeded_winners = plan_mod.seed_autotune_winners(seeds)
        # pass 2: rebuild the plans (autotune resolves via the seeds)
        for i, entry, spec, shard, choice, elastic in parsed:
            try:
                block_q = entry.get("block_q")
                kwargs: Dict[str, Any] = {}
                if shard is not None:
                    kwargs = dict(
                        mesh=mesh,
                        query_parallel=bool(shard.get("query_parallel")),
                        grad_reduce=shard.get("grad_reduce") or "auto")
                    if kwargs["grad_reduce"] == "none" or elastic:
                        # elastic: the stored reduction was raced on the
                        # OLD topology — let the new mesh re-race it
                        kwargs["grad_reduce"] = "auto"
                common = dict(
                    backend=entry["backend"],
                    tune=entry.get("tune", "heuristic"),
                    block_q=tuple(block_q) if block_q else None, **kwargs)
                if shard is not None and not elastic:
                    # try sharding="auto" FIRST: the request path
                    # (attention_plan with the config default) asks for
                    # "auto", and the plan cache keys on the sharding
                    # string — restoring under "auto" lets requests hit
                    # THIS plan object.  The seeded mesh-race winner
                    # pins "auto" to the stored mode with zero timing;
                    # if the ladder still resolves differently (e.g. a
                    # 2d-forced plan below the auto threshold), retry
                    # with the mode pinned so the rebuild stays exact.
                    plan = plan_mod.msda_plan(spec, sharding="auto", **common)
                    if plan.sharding_mode != shard["mode"]:
                        plan = plan_mod.msda_plan(
                            spec, sharding=choice, **common)
                else:
                    plan = plan_mod.msda_plan(spec, sharding="auto", **common) \
                        if elastic else plan_mod.msda_plan(spec, **common)
                if shard is not None and not elastic \
                        and plan.sharding_mode != shard["mode"]:
                    report.skipped.append(
                        f"{_label(i, entry)}: sharding mode drifted "
                        f"({shard['mode']} -> {plan.sharding_mode})")
                    continue
            except Exception as e:  # noqa: BLE001
                report.skipped.append(
                    f"{_label(i, entry)}: {type(e).__name__}: {e}")
                continue
            if elastic:
                report.reraced.append(
                    f"{_label(i, entry)}: "
                    f"{plan_mod.mesh_token_from(shard['mesh_axes'], shard['mesh_shape'])} "
                    f"-> {plan_mod.mesh_token(mesh)} "
                    f"({shard['mode']} -> {plan.sharding_mode})")
            elif verify_describe and entry.get("describe"):
                # (describe drift is only meaningful when the geometry
                # was supposed to be identical — elastic entries changed
                # topology by definition)
                if _norm_describe(plan.describe()) != _norm_describe(entry["describe"]):
                    report.describe_mismatches.append(
                        f"entry {i}: plan.describe() differs from stored "
                        f"(device_kind {entry.get('device_kind')} -> {here}?)")
            report.plans.append(plan)
        return report


# --------------------------------------------------------------------------
# JAX persistent compilation cache
# --------------------------------------------------------------------------


def enable_jax_compilation_cache(cache_dir: str) -> bool:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Thresholds are zeroed so even the CPU tier's fast compiles persist
    (the default min-compile-time gate would skip them, and the smoke
    job's no-recompilation assertion needs every executable cached).
    Best-effort: an old jax without the knobs just serves cold.
    """
    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(cache_dir))
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:
        return False
    try:
        # jax latches cache initialisation at the process's FIRST compile
        # and never re-reads the dir config: a boot that compiled anything
        # (params init!) before reaching here would silently cache nothing.
        # Drop the latched (empty-dir) state so the next compile re-reads.
        from jax._src import compilation_cache as _cc

        _cc.reset_cache()
    except Exception:
        pass  # private API moved: processes that set the dir early still cache
    return True


def compilation_cache_entries(cache_dir: str) -> int:
    """Number of persisted executables (the smoke job's probe)."""
    try:
        return sum(1 for n in os.listdir(cache_dir) if n.endswith("-cache"))
    except OSError:
        return 0
