"""Serving resilience: deadlines, admission control, breakers, ladders.

The layer that turns the warmed/AOT/persisted plan machinery into
something that degrades gracefully instead of falling over
(``docs/serving.md`` §Resilience):

* **Typed responses** — every request the engine ADMITS ends with a
  :class:`ServeResponse`; load shedding and deadline misses resolve
  requests with ``"shed"`` / ``"timeout"`` statuses instead of
  dropping them, executor exhaustion resolves with ``"error"``.
* **Admission control** — the engine's queue is bounded
  (``ResilienceConfig.max_queue``); past the bound, ``submit`` sheds
  with a typed response and the backpressure gauge
  (``serve.resilience.queue_depth`` / ``backpressure``) tells the
  frontend to back off BEFORE the bound is hit.
* **Retry + circuit breaker + degradation ladder** —
  :class:`GuardedExecutor` wraps an executor callable: transient
  failures retry with backoff; ``breaker_threshold`` CONSECUTIVE
  exhausted calls open the breaker and demote one rung down the
  ladder (for MSDA plans: ``MsdaPlan.fallback()`` — fused ->
  per-level -> ref, sparse -> dense; built race-free, never persisted
  as a winner); while demoted, the primary is probed on a half-open
  schedule every ``probe_interval`` calls and promoted back on
  success.

Every resilience event lands in the PR 8 obs registry
(``serve.resilience.*`` series + ``resilience.*`` spans).  The CLEAN
path stays zero-overhead: a guarded call in the steady state is one
Python ``try`` around the same executor — no new traces, no plan
builds, no extra ``MsdaPlan.__call__`` (fallback rungs are
materialised lazily, on first demotion), so
``plan.execution_telemetry()`` is unchanged on a fault-free run.

Chaos injection rides :class:`repro.runtime.faults.FaultInjector` —
the shared seeded ``FaultSchedule`` contract the training harness
uses, extended with serving kinds (``exec_raise`` / ``straggler`` /
``corrupt_store``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import registry as _obs
from repro.obs import trace as _obs_trace
from repro.runtime.faults import FaultInjector, InjectedExecutorError  # noqa: F401

RESPONSE_STATUSES = ("ok", "shed", "timeout", "error")

# breaker states (GuardedExecutor.state)
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

_EVENTS = _obs.counter(
    "serve.resilience.events",
    help="resilience events by type (shed/deadline_miss/retry/...)")
_BREAKER = _obs.counter(
    "serve.resilience.breaker",
    help="circuit-breaker state transitions by executor")
_RUNG = _obs.gauge(
    "serve.resilience.rung",
    help="active degradation-ladder rung per executor (0 = primary)")
_DEPTH = _obs.gauge(
    "serve.resilience.queue_depth",
    help="admission queue depth (pending requests)")
_BACKPRESSURE = _obs.gauge(
    "serve.resilience.backpressure",
    help="admission queue fill fraction (1.0 = shedding)")


@dataclasses.dataclass(frozen=True)
class ServeResponse:
    """The typed terminal state of one request.

    ``status``: ``"ok"`` (served, ``tokens`` carries the output),
    ``"shed"`` (rejected at admission: queue full), ``"timeout"``
    (deadline exceeded — queued or mid-decode), ``"error"`` (executor
    failed past every retry and ladder rung).
    """

    status: str
    rid: int
    detail: str = ""
    tokens: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.status not in RESPONSE_STATUSES:
            raise ValueError(
                f"unknown status {self.status!r}; one of {RESPONSE_STATUSES}")

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for one engine's resilience layer (all host-side).

    ``max_queue`` bounds admission (sheds past it); ``deadline_ticks``
    is the default per-request deadline in engine ticks (None = no
    deadline unless the request carries one); ``max_retries`` /
    ``backoff_s`` drive retry-with-backoff (backoff doubles per
    attempt; 0.0 keeps tests instant); ``breaker_threshold`` is K —
    consecutive retry-exhausted calls before the breaker opens and
    demotes; ``probe_interval`` is the half-open schedule — while
    demoted, every Nth call probes the primary.
    """

    max_queue: int = 256
    deadline_ticks: Optional[int] = None
    max_retries: int = 2
    backoff_s: float = 0.0
    breaker_threshold: int = 3
    probe_interval: int = 4

    def __post_init__(self):
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {self.breaker_threshold}")
        if self.probe_interval < 1:
            raise ValueError(
                f"probe_interval must be >= 1, got {self.probe_interval}")


class ExecutorFailure(RuntimeError):
    """Every retry and every ladder rung failed for one call."""


class AdmissionController:
    """Bounded-queue admission with load shedding + backpressure.

    The engine consults :meth:`admit` with its CURRENT pending depth
    before enqueueing; past ``max_queue`` the request sheds.  The
    backpressure gauge is exported continuously so a frontend can
    shape traffic before the hard bound sheds it.
    """

    def __init__(self, max_queue: int, *, engine: str = "e?"):
        self.max_queue = int(max_queue)
        self.shed_count = 0
        self._engine = engine

    def admit(self, pending: int) -> bool:
        ok = pending < self.max_queue
        if not ok:
            self.shed_count += 1
            _EVENTS.inc(engine=self._engine, type="shed")
        self.observe(pending if ok else self.max_queue)
        return ok

    def observe(self, pending: int) -> None:
        _DEPTH.set(pending, engine=self._engine)
        _BACKPRESSURE.set(self.backpressure(pending), engine=self._engine)

    def backpressure(self, pending: int) -> float:
        return min(1.0, pending / self.max_queue)


class GuardedExecutor:
    """Retry + circuit breaker + degradation ladder around one executor.

    ``primary`` is the rung-0 callable; ``demote_fn(current) ->
    next | None`` materialises the ladder LAZILY (clean runs build
    nothing).  For MSDA plans use :func:`guard_plan`; for a fixed
    ladder use ``demote_fn=ladder_of([...])``.

    State machine (``self.state``): ``closed`` — primary serving;
    after ``breaker_threshold`` CONSECUTIVE retry-exhausted calls the
    breaker transitions to ``open`` and the active rung demotes (the
    same call then continues down the ladder — a demotion is not a
    failed request).  While any rung below primary is active, every
    ``probe_interval``-th call first transitions to ``half_open`` and
    probes the primary: success promotes straight back to rung 0
    (``closed``), failure re-``open``s and the call proceeds on the
    demoted rung.  Transitions are metered
    (``serve.resilience.breaker``), the active rung is a gauge, and
    ``self.transitions`` keeps the ordered log the reproducibility
    tests compare.
    """

    def __init__(self, name: str, primary: Callable, *,
                 demote_fn: Optional[Callable[[Callable], Optional[Callable]]] = None,
                 policy: Optional[ResilienceConfig] = None,
                 label_fn: Callable[[Callable], str] = lambda f: getattr(
                     f, "__name__", f.__class__.__name__),
                 injector: Optional[FaultInjector] = None,
                 engine: str = "e?"):
        self.name = name
        self.policy = policy or ResilienceConfig()
        self._rungs: List[Callable] = [primary]
        self._demote_fn = demote_fn
        self._ladder_done = demote_fn is None
        self._label_fn = label_fn
        self.injector = injector
        self._engine = engine
        self.rung = 0
        self.state = CLOSED
        self.consecutive_failures = 0
        self._calls_since_demote = 0
        self.retry_count = 0
        self.transitions: List[Tuple[str, int]] = []  # (state, rung) log
        _RUNG.set(0, engine=engine, executor=name)

    # -- ladder -----------------------------------------------------------
    def _materialise(self, i: int) -> Optional[Callable]:
        """Rung ``i``'s callable, building the ladder as needed."""
        while len(self._rungs) <= i and not self._ladder_done:
            nxt = self._demote_fn(self._rungs[-1])
            if nxt is None:
                self._ladder_done = True
            else:
                self._rungs.append(nxt)
        return self._rungs[i] if i < len(self._rungs) else None

    def rung_labels(self) -> List[str]:
        """Labels of the rungs materialised SO FAR (clean runs: just
        the primary — the ladder is built on demand)."""
        return [self._label_fn(r) for r in self._rungs]

    # -- state transitions ------------------------------------------------
    def _transition(self, state: str) -> None:
        self.state = state
        self.transitions.append((state, self.rung))
        _BREAKER.inc(engine=self._engine, executor=self.name, transition=state)
        _RUNG.set(self.rung, engine=self._engine, executor=self.name)
        with _obs_trace.span("resilience.breaker", level=2,
                             executor=self.name, transition=state,
                             rung=self.rung):
            pass

    # -- call path --------------------------------------------------------
    def _attempt(self, fn: Callable, rung: int, args, kwargs):
        if self.injector is not None and self.injector.should_raise(
                self.name, rung):
            raise InjectedExecutorError(
                f"injected fault: executor {self.name!r} rung {rung}")
        return fn(*args, **kwargs)

    def _try_rung(self, rung: int, args, kwargs):
        """One rung with the full retry budget; raises the last error."""
        fn = self._materialise(rung)
        assert fn is not None
        p = self.policy
        for attempt in range(p.max_retries + 1):
            try:
                return self._attempt(fn, rung, args, kwargs)
            except Exception as e:  # noqa: BLE001 — retries see everything
                if attempt >= p.max_retries:
                    raise
                self.retry_count += 1
                _EVENTS.inc(engine=self._engine, type="retry")
                with _obs_trace.span("resilience.retry", level=2,
                                     executor=self.name, rung=rung,
                                     attempt=attempt + 1,
                                     error=type(e).__name__):
                    pass
                if p.backoff_s > 0:
                    time.sleep(p.backoff_s * (2 ** attempt))

    def call(self, *args, **kwargs):
        """Execute with the full resilience stack; raises
        :class:`ExecutorFailure` only when every rung is exhausted."""
        p = self.policy
        # half-open probe: while demoted, periodically try the primary
        if self.rung > 0:
            self._calls_since_demote += 1
            if self._calls_since_demote % p.probe_interval == 0:
                self._transition(HALF_OPEN)
                try:
                    out = self._attempt(self._rungs[0], 0, args, kwargs)
                except Exception as e:  # noqa: BLE001 — probe failed
                    _EVENTS.inc(engine=self._engine, type="probe_failure")
                    with _obs_trace.span("resilience.probe", level=2,
                                         executor=self.name, ok=False,
                                         error=type(e).__name__):
                        pass
                    self._transition(OPEN)
                else:
                    self.rung = 0
                    self.consecutive_failures = 0
                    self._calls_since_demote = 0
                    _EVENTS.inc(engine=self._engine, type="probe_success")
                    self._transition(CLOSED)
                    return out
        rung = self.rung
        while True:
            try:
                out = self._try_rung(rung, args, kwargs)
            except Exception as e:  # noqa: BLE001 — rung exhausted
                _EVENTS.inc(engine=self._engine, type="exec_failure")
                self.consecutive_failures += 1
                if (self.consecutive_failures >= p.breaker_threshold
                        and self._materialise(rung + 1) is not None):
                    # demote: open the breaker, continue THIS call on
                    # the next rung with a fresh retry budget
                    rung = self.rung = rung + 1
                    self.consecutive_failures = 0
                    self._calls_since_demote = 0
                    self._transition(OPEN)
                    continue
                if self._materialise(rung + 1) is None and rung > 0:
                    # bottom of a demoted ladder still failing: give the
                    # caller the typed failure, keep the rung
                    raise ExecutorFailure(
                        f"executor {self.name!r} failed on every rung "
                        f"(last: {type(e).__name__}: {e})") from e
                if self.consecutive_failures < p.breaker_threshold:
                    raise ExecutorFailure(
                        f"executor {self.name!r} exhausted retries on rung "
                        f"{rung} ({type(e).__name__}: {e})") from e
                raise ExecutorFailure(
                    f"executor {self.name!r} failed with no rung to demote "
                    f"to ({type(e).__name__}: {e})") from e
            else:
                self.consecutive_failures = 0
                return out

    __call__ = call


def ladder_of(rungs: Sequence[Callable]) -> Callable[[Callable], Optional[Callable]]:
    """A ``demote_fn`` walking a fixed rung list (primary excluded)."""
    rungs = list(rungs)

    def demote(_current: Callable) -> Optional[Callable]:
        return rungs.pop(0) if rungs else None

    return demote


def guard_plan(plan, policy: Optional[ResilienceConfig] = None, *,
               mesh=None, injector: Optional[FaultInjector] = None,
               name: Optional[str] = None,
               engine: str = "e?") -> GuardedExecutor:
    """A per-plan circuit breaker over ``MsdaPlan.fallback()``.

    The ladder is materialised lazily — a clean run builds no fallback
    plan and adds no plan-cache traffic.  Demoted rungs are heuristic
    builds (never autotuned, never persisted as winners).
    """
    label = name or f"plan[{plan.rung_label()}|Q={plan.spec.num_queries}]"
    return GuardedExecutor(
        label, plan,
        demote_fn=lambda p: p.fallback(mesh=mesh),
        policy=policy,
        label_fn=lambda p: p.rung_label() if hasattr(p, "rung_label")
        else getattr(p, "__name__", "fn"),
        injector=injector, engine=engine)


def resilience_snapshot(guards: Sequence[GuardedExecutor],
                        admission: Optional[AdmissionController] = None
                        ) -> Dict[str, Any]:
    """Machine-readable view of one engine's resilience state — the
    block the chaos smoke asserts on and ``BENCH_resilience.json``
    gates."""
    out: Dict[str, Any] = {
        "sheds": admission.shed_count if admission else 0,
        "executors": {},
    }
    for g in guards:
        out["executors"][g.name] = {
            "state": g.state,
            "rung": g.rung,
            "rungs_built": g.rung_labels(),
            "retries": g.retry_count,
            "transitions": [list(t) for t in g.transitions],
        }
    return out
