"""AOT compilation for serving: trace + compile at boot, never at request time.

``jax.jit`` defers tracing and XLA compilation to the first call with a
new input signature, so a server that builds its :class:`MsdaPlan`\\ s at
boot still pays the first *request* the trace and the compile.  This
module moves both to boot via the AOT path —
``jax.jit(fn).lower(shapes).compile()`` returns an executable bound to
exact input shapes/dtypes; calling it never re-traces (a shape mismatch
raises instead of silently recompiling a new variant).

The module also carries the process-wide **compile-count probe**: every
function routed through :func:`traced` bumps a trace counter each time
its Python body actually runs under a JAX trace, and :func:`aot_compile`
bumps a compile counter.  Tests and the CI serving-smoke job snapshot
the counters after warm-up and assert ZERO retraces at request time::

    engine.warmup(prompt_lengths=(8,))
    with aot.probe() as p:
        engine.run()
    assert p.traces == 0 and p.compiles == 0
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Iterator, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.obs import registry as _obs
from repro.obs import trace as _obs_trace

# --------------------------------------------------------------------------
# compile-count probe (backed by the process-wide obs registry)
# --------------------------------------------------------------------------

_STATS = {
    "traces": _obs.counter("serving.aot.traces",
                           help="function bodies (re)traced under jit"),
    "compiles": _obs.counter("serving.aot.compiles",
                             help="AOT XLA compilations performed"),
    "aot_calls": _obs.counter("serving.aot.aot_calls",
                              help="calls into compiled AOT executors"),
}


def stats() -> Dict[str, int]:
    return {k: int(c.value()) for k, c in _STATS.items()}


def reset_stats() -> None:
    for c in _STATS.values():
        c.reset()


class Probe:
    """Delta view over the trace/compile counters since construction."""

    def __init__(self):
        self._base = stats()

    @property
    def traces(self) -> int:
        return int(_STATS["traces"].value()) - self._base["traces"]

    @property
    def compiles(self) -> int:
        return int(_STATS["compiles"].value()) - self._base["compiles"]

    @property
    def aot_calls(self) -> int:
        return int(_STATS["aot_calls"].value()) - self._base["aot_calls"]

    def __repr__(self):
        return (f"Probe(traces={self.traces}, compiles={self.compiles}, "
                f"aot_calls={self.aot_calls})")


@contextlib.contextmanager
def probe() -> Iterator[Probe]:
    """``with aot.probe() as p: ...; assert p.traces == 0``."""
    yield Probe()


def traced(fn: Callable, name: str = "") -> Callable:
    """Wrap ``fn`` so every (re)trace bumps the probe's trace counter.

    The wrapper's body only executes while JAX is tracing (jit replays
    compiled programs without re-entering Python), so the counter is an
    exact retrace count.  Wrap the function BEFORE handing it to
    ``jax.jit`` — the engine routes its jit fallbacks through this, so a
    request that misses the AOT warm-up set shows up in the probe.
    """

    def wrapper(*args, **kwargs):
        _STATS["traces"].inc()
        return fn(*args, **kwargs)

    wrapper.__name__ = name or getattr(fn, "__name__", "fn")
    return wrapper


# --------------------------------------------------------------------------
# AOT executors
# --------------------------------------------------------------------------


@dataclasses.dataclass
class AotExecutor:
    """A compiled executable bound to one input signature.

    Calling it never traces or compiles; argument shapes/dtypes that
    don't match the signature raise (jax's ``Compiled`` contract) — the
    serving engine treats that as "fall back to jit + count the retrace".
    """

    name: str
    in_avals: Tuple[Any, ...]
    _compiled: Any = dataclasses.field(repr=False)

    def __call__(self, *args):
        _STATS["aot_calls"].inc()
        return self._compiled(*args)


def aot_compile(fn: Callable, *args, name: str = "") -> AotExecutor:
    """Trace + XLA-compile ``fn`` for the given example args, now.

    ``args`` may be concrete arrays, pytrees of arrays, or
    ``jax.ShapeDtypeStruct``\\ s — ``lower`` only needs shapes/dtypes and
    never executes the computation.  The one trace this performs is a
    *boot-time* trace; probes are snapshotted after warm-up.
    """
    name = name or getattr(fn, "__name__", "fn")
    with _obs_trace.span("aot.compile", level=2, fn=name):
        lowered = jax.jit(traced(fn, name)).lower(*args)
        compiled = lowered.compile()
    _STATS["compiles"].inc()
    avals = tuple(jax.tree.map(lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype)
                               if hasattr(x, "dtype") else x, a) for a in args)
    return AotExecutor(name=name, in_avals=avals, _compiled=compiled)


# --------------------------------------------------------------------------
# MsdaPlan executors
# --------------------------------------------------------------------------


def plan_arg_structs(spec, batch_size: int = 1) -> Tuple[Any, Any, Any]:
    """ShapeDtypeStructs for one plan call at ``batch_size``.

    Locations stay fp32 regardless of the operand dtype — that is what
    every call site passes (reference points + offsets are computed in
    fp32; see ``core.msda.msda_attention``).
    """
    S, H, D = spec.total_pixels, spec.num_heads, spec.head_dim
    Q, L, P = spec.num_queries, spec.num_levels, spec.num_points
    return (
        jax.ShapeDtypeStruct((batch_size, S, H, D), spec.dtype),
        jax.ShapeDtypeStruct((batch_size, Q, H, L, P, 2), jnp.float32),
        jax.ShapeDtypeStruct((batch_size, Q, H, L, P), spec.dtype),
    )


def compile_plan_executor(plan, batch_size: int = 1) -> AotExecutor:
    """AOT-compile one warmed plan's executor for a fixed batch size."""
    label = (f"msda[{plan.backend}|Q={plan.spec.num_queries}"
             f"|L={plan.spec.num_levels}|B={batch_size}]")
    return aot_compile(plan.__call__, *plan_arg_structs(plan.spec, batch_size),
                       name=label)


def compile_plan_executors(
    plans: Sequence, batch_sizes: Sequence[int] = (1,)
) -> Dict[Tuple[str, int], AotExecutor]:
    """AOT-compile every warmed plan at every batch size.

    Keyed by ``(spec.cache_token(), batch_size)`` so the serving engine
    can look an executor up from the spec it is about to run.
    """
    out = {}
    for plan in plans:
        for b in batch_sizes:
            out[(plan.spec.cache_token(), int(b))] = compile_plan_executor(plan, b)
    return out
