"""Serving engine: batched prefill + decode with continuous batching.

``make_serve_fns(cfg)`` returns the pure jittable pair used by both the
engine and the dry-run cells:

* ``prefill(params, prompt_inputs...) -> (logits, cache)``
* ``decode_step(params, cache, token) -> (logits, cache)``

``ServeEngine`` adds request scheduling on top: a fixed pool of batch
slots, each slot independently in {empty, prefilling, decoding}; new
requests are admitted into free slots between decode steps (continuous
batching).  Slot state is host-side; the device-side cache is a single
batched pytree so every decode step is one fused program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_serve_fns(cfg) -> Tuple[Callable, Callable]:
    if cfg.family in ("dense", "moe", "hybrid", "ssm"):
        from repro.models import lm

        def prefill(params, tokens, capacity):
            return lm.lm_prefill(params, cfg, tokens, capacity)

        def decode(params, cache, token):
            return lm.lm_decode_step(params, cfg, cache, token)

        return prefill, decode
    if cfg.family == "audio":
        from repro.models import whisper

        def prefill(params, frames, tokens, capacity):
            return whisper.whisper_prefill(params, cfg, frames, tokens, capacity)

        def decode(params, cache, token):
            return whisper.whisper_decode_step(params, cfg, cache, token)

        return prefill, decode
    if cfg.family == "vlm":
        from repro.models import vlm

        # Warm the MSDA resampler plan at engine-build time: backend
        # resolution + block planning (+ autotune, if configured) happen
        # here, once, instead of inside the first prefill's trace.
        warmup_msda_plans(cfg)

        def prefill(params, pyramid, tokens, capacity):
            return vlm.vlm_prefill(params, cfg, pyramid, tokens, capacity)

        def decode(params, cache, token):
            return vlm.vlm_decode_step(params, cfg, cache, token)

        return prefill, decode
    raise ValueError(f"{cfg.family} has no serving path")


def warmup_msda_plans(cfg, *, dtype_policy: Optional[str] = None):
    """Pre-build every MsdaPlan a serving process will execute.

    Returns the plans (empty tuple for pure-LM families) so callers can
    log ``plan.describe()``.  Idempotent: plans are cached by spec.

    ``dtype_policy`` overrides the config's ``msda.dtype_policy`` for
    every warmed plan (e.g. force ``"bfloat16"`` slabs fleet-wide, or
    ``"auto"`` so the warm-up absorbs the autotune fp32-vs-bf16 race —
    and its winner-cache disk write — instead of the first request).
    """
    plans = []
    if getattr(cfg, "vision", None) is not None:
        from repro.core import msda as msda_mod
        from repro.models import vlm

        vc = cfg.vision
        mc = vlm._msda_cfg(vc)
        plans.append(msda_mod.attention_plan(
            mc, num_queries=vc.num_visual_tokens,
            head_dim=vc.vision_dim // mc.num_heads, dtype=cfg.dtype,
            dtype_policy=dtype_policy))
    if getattr(cfg, "msda", None) is not None:
        from repro.core import deformable_transformer as dt

        plans.extend(
            dt.msda_plans(cfg, dtype=cfg.dtype, dtype_policy=dtype_policy).values())
    return tuple(plans)


def clear_kernel_plans() -> None:
    """Drop cached MSDA plans + their compiled ops (long-lived servers).

    The plan cache is bounded, but a server that cycles through many
    model configs can still pin compiled executors; call this between
    model swaps to release them.
    """
    from repro.kernels import plan as plan_mod

    plan_mod.clear_plans()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous-batching engine over a fixed slot pool (LM families)."""

    def __init__(self, cfg, params, *, slots: int = 4, capacity: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        from repro.models import lm

        self.cfg, self.params = cfg, params
        self.slots = slots
        self.capacity = capacity
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        self._occupant: List[Optional[Request]] = [None] * slots
        self._queue: List[Request] = []
        dt = jnp.dtype(cfg.dtype)
        self.cache = lm.init_cache(cfg, slots, capacity, dt)
        self._prefill_one = jax.jit(
            lambda p, t: lm.lm_prefill(p, cfg, t, capacity)
        )
        self._decode = jax.jit(lambda p, c, t: lm.lm_decode_step(p, cfg, c, t))

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request):
        self._queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self._occupant[s] is None and self._queue:
                req = self._queue.pop(0)
                logits, cache1 = self._prefill_one(self.params, req.prompt[None, :])
                # splice slot s of the batched cache with the fresh cache
                self.cache = jax.tree.map(
                    lambda big, one: _splice(big, one, s), self.cache, cache1
                )
                req.out.append(self._sample(np.asarray(logits)[0]))
                self._occupant[s] = req

    def _sample(self, logits: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(logits.argmax())
        p = np.exp((logits - logits.max()) / self.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def step(self):
        """One engine tick: admit, batched decode, retire."""
        self._admit()
        tok = np.zeros((self.slots,), np.int32)
        active = []
        for s, req in enumerate(self._occupant):
            if req is not None:
                tok[s] = req.out[-1]
                active.append(s)
        if not active:
            return False
        logits, self.cache = self._decode(self.params, self.cache, jnp.asarray(tok))
        logits = np.asarray(logits)
        for s in active:
            req = self._occupant[s]
            req.out.append(self._sample(logits[s]))
            if len(req.out) >= req.max_new:
                req.done = True
                self._occupant[s] = None
        return True

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step() and not self._queue:
                break

    def shutdown(self) -> None:
        """Release compiled kernel plans (see :func:`clear_kernel_plans`)."""
        clear_kernel_plans()


def _splice(big: jax.Array, one: jax.Array, s: int) -> jax.Array:
    """Write the single-request cache leaf into slot s of the batched leaf.

    Cache leaves are either stacked-over-layers (n, B, ...) or plain
    (B, ...); the batch dim is the one where shapes differ by slots vs 1.
    Scalars (pos counters) are shared across slots and taken from `one`.
    """
    if big.ndim == 0 or big.shape == one.shape:
        return one
    # find batch axis: first axis where big != one
    for ax in range(big.ndim):
        if big.shape[ax] != one.shape[ax]:
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(s, s + 1)
            return big.at[tuple(idx)].set(one)
    return one
