"""Serving engine: batched prefill + decode with continuous batching.

Contract (``docs/serving.md``): all latency-shaped work — plan
construction, autotune races, XLA compilation — happens at boot, never
at request time.  Plans restore from a ``PlanStore`` (zero races; a
mesh-carrying boot passes ``mesh=`` and gets identical distributed
plans), ``warmup()`` AOT-compiles every request-shape executor, and the
``aot.probe()`` counters prove zero request-time traces.  Requests then
flow through a fixed slot pool: retire, admit (vlm: bucket-padded
pyramid batches), one fused decode per tick.

``make_serve_fns(cfg)`` returns the pure jittable pair used by both the
engine and the dry-run cells:

* ``prefill(params, prompt_inputs...) -> (logits, cache)``
* ``decode_step(params, cache, token) -> (logits, cache)``

``ServeEngine`` adds request scheduling on top: a fixed pool of batch
slots, each slot independently in {empty, prefilling, decoding}; new
requests are admitted into free slots between decode steps (continuous
batching).  Slot state is host-side; the device-side cache is a single
batched pytree so every decode step is one fused program.

The engine composes the serving-runtime subsystem:

* ``serving.aot``         — ``warmup()`` AOT-compiles the decode step,
  the prefill programs and every warmed ``MsdaPlan`` executor at boot;
  the compile-count probe then asserts zero retraces at request time.
* ``serving.persistence`` — ``store_path=`` restores the full plan set
  (specs + autotune winners) from a previous process with zero autotune
  races, and ``compile_cache_dir=`` wires JAX's persistent compilation
  cache so the boot compiles themselves are disk hits.
* ``serving.batcher``     — vlm requests carry variable image pyramids;
  a shape-bucketed front end pads them into a fixed bucket ladder so
  the bounded plan cache never churns and prefill programs are reused.
* ``serving.metrics``     — per-bucket admission/padding/latency/retire
  counters, surfaced by ``launch/serve.py``.
* ``serving.resilience``  — deadlines, bounded-queue admission with
  load shedding, retry + circuit breakers with the plan degradation
  ladder, chaos injection via ``runtime.faults.FaultInjector``
  (``faults=`` kwarg).  Every ADMITTED request terminates with a typed
  ``ServeResponse`` (``req.response``); sheds and deadline misses are
  typed too, never silent drops.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.faults import FaultInjector
from repro.serving import aot
from repro.serving import batcher as batcher_mod
from repro.serving import persistence
from repro.serving import resilience as resil_mod
from repro.serving.metrics import ServeMetrics
from repro.serving.resilience import (
    AdmissionController,
    ExecutorFailure,
    GuardedExecutor,
    ResilienceConfig,
    ServeResponse,
    ladder_of,
)

_LM_FAMILIES = ("dense", "moe", "hybrid", "ssm")


def make_serve_fns(cfg, *, dtype_policy: Optional[str] = None,
                   tune: Optional[str] = None,
                   warm_plans: bool = True) -> Tuple[Callable, Callable]:
    """The pure (prefill, decode) pair for a family.

    This is the ONE place the per-family serving closures are defined —
    the engine builds its jitted/AOT variants from the same pair, so a
    plan axis added here (dtype_policy, tune, the vlm bucketing
    ``levels``/``valid_ratios`` kwargs) reaches every consumer at once.

    ``dtype_policy``/``tune`` thread the MSDA plan axes into BOTH the
    plan warm-up and the vlm prefill itself, so the plans warmed at
    build time are byte-for-byte the specs the first prefill trace asks
    for (an override that only reached the warm-up would re-plan — and
    possibly re-race — at request time).  ``warm_plans=False`` skips the
    warm-up for callers that warm their own plan set (the engine warms
    its bucket ladder instead of the single config geometry).
    """
    if cfg.family in _LM_FAMILIES:
        from repro.models import lm

        def prefill(params, tokens, capacity):
            return lm.lm_prefill(params, cfg, tokens, capacity)

        def decode(params, cache, token):
            return lm.lm_decode_step(params, cfg, cache, token)

        return prefill, decode
    if cfg.family == "audio":
        from repro.models import whisper

        def prefill(params, frames, tokens, capacity):
            return whisper.whisper_prefill(params, cfg, frames, tokens, capacity)

        def decode(params, cache, token):
            return whisper.whisper_decode_step(params, cfg, cache, token)

        return prefill, decode
    if cfg.family == "vlm":
        from repro.models import vlm

        # Warm the MSDA resampler plan at engine-build time: backend
        # resolution + block planning (+ autotune, if configured) happen
        # here, once, instead of inside the first prefill's trace.
        if warm_plans:
            warmup_msda_plans(cfg, dtype_policy=dtype_policy, tune=tune)

        def prefill(params, pyramid, tokens, capacity, *,
                    levels=None, valid_ratios=None):
            return vlm.vlm_prefill(params, cfg, pyramid, tokens, capacity,
                                   levels=levels, valid_ratios=valid_ratios,
                                   dtype_policy=dtype_policy, tune=tune)

        def decode(params, cache, token):
            return vlm.vlm_decode_step(params, cfg, cache, token)

        return prefill, decode
    raise ValueError(f"{cfg.family} has no serving path")


def warmup_msda_plans(cfg, *, dtype_policy: Optional[str] = None,
                      tune: Optional[str] = None, buckets=None, mesh=None):
    """Pre-build every MsdaPlan a serving process will execute.

    Returns the plans (empty tuple for pure-LM families) so callers can
    log ``plan.describe()``.  Idempotent: plans are cached by spec.

    ``dtype_policy`` overrides the config's ``msda.dtype_policy`` for
    every warmed plan (e.g. force ``"bfloat16"`` slabs fleet-wide, or
    ``"auto"`` so the warm-up absorbs the autotune fp32-vs-bf16 race —
    and its winner-cache disk write — instead of the first request).
    ``tune`` similarly overrides the config's tune mode (the sweep CLI
    forces "autotune").  ``buckets`` (vlm): warm one resampler plan per
    bucket geometry instead of the config's single pyramid — the set the
    bucketed batcher actually serves.  ``mesh``: warm DISTRIBUTED plans
    (the sharding ladder — incl. the 2D dp x tp mode — commits per plan
    at warm-up, exactly like blocks and slab dtypes).
    """
    plans = []
    if getattr(cfg, "vision", None) is not None:
        from repro.core import msda as msda_mod
        from repro.models import vlm

        vc = cfg.vision
        geometries = [vc.levels] if not buckets else [b.levels for b in buckets]
        for levels in geometries:
            mc = vlm._msda_cfg(vc, levels, dtype_policy=dtype_policy)
            plans.append(msda_mod.attention_plan(
                mc, num_queries=vc.num_visual_tokens,
                head_dim=vc.vision_dim // mc.num_heads, dtype=cfg.dtype,
                dtype_policy=dtype_policy, tune=tune, mesh=mesh))
    if getattr(cfg, "msda", None) is not None:
        from repro.core import deformable_transformer as dt

        plans.extend(
            dt.msda_plans(cfg, dtype=cfg.dtype, dtype_policy=dtype_policy,
                          tune=tune, mesh=mesh).values())
    return tuple(plans)


def clear_kernel_plans() -> None:
    """Drop cached MSDA plans + their compiled ops (long-lived servers).

    The plan cache is bounded, but a server that cycles through many
    model configs can still pin compiled executors; call this between
    model swaps to release them.
    """
    from repro.kernels import plan as plan_mod

    plan_mod.clear_plans()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    # vlm: per-request image pyramid, flattened (S_v, vision_dim) fp32,
    # at its own geometry — the bucketed batcher pads it for admission
    pyramid: Optional[np.ndarray] = None
    levels: Optional[Tuple[Tuple[int, int], ...]] = None  # None -> config levels
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # resilience: per-request deadline in engine ticks (None inherits the
    # engine's ResilienceConfig.deadline_ticks); every request that
    # reaches a terminal state carries its typed ServeResponse
    deadline_ticks: Optional[int] = None
    submit_tick: int = -1
    response: Optional[ServeResponse] = None


def _pow2_batches(slots: int) -> Tuple[int, ...]:
    """The fixed set of admitted batch sizes: powers of two, plus the
    full slot count — bounds the number of compiled prefill variants."""
    sizes = {slots}
    b = 1
    while b <= slots:
        sizes.add(b)
        b *= 2
    return tuple(sorted(sizes))


def _batch_quantum(mesh) -> int:
    """Smallest legal batch for mesh-carrying plans (1 without a mesh).

    The 1D sharded modes ('query', 'head', 'batch') shard BATCH over the
    dp axes, so every batch that reaches a distributed plan must be a
    multiple of the dp width — the engine quantizes its admitted batch
    ladder to it rather than letting shard_map reject a size-1 prefill
    at request time."""
    if mesh is None:
        return 1
    from repro.sharding import rules

    return rules.axis_size(rules.resolve_axis("dp", mesh), mesh)


def _quantize_batches(sizes, quantum: int, slots: int) -> Tuple[int, ...]:
    """Round each admitted batch size up to the quantum, capped at the
    slot count (slots is asserted to be a multiple of the quantum)."""
    q = max(1, int(quantum))
    out = {min(slots, -(-int(b) // q) * q) for b in sizes}
    return tuple(sorted(out))


def _diff_axis(a, b) -> int:
    """First axis where two cache-leaf avals differ (-1: no batch axis)."""
    if a.shape == b.shape:
        return -1
    for ax in range(len(a.shape)):
        if a.shape[ax] != b.shape[ax]:
            return ax
    return -1


class ServeEngine:
    """Continuous-batching engine over a fixed slot pool (LM + VLM).

    Boot sequence (everything traffic-latency-critical happens here):

    1. plans   — restored from ``store_path`` when the store exists
       (zero autotune races; winners seeded from the store), else warmed
       fresh and persisted for the next boot.
    2. ``warmup()`` — AOT-compiles decode/prefill/plan executors so the
       first request triggers no trace and no XLA compile (with
       ``compile_cache_dir`` even the boot compiles are disk hits on a
       restart).
    3. traffic — ``submit()`` + ``run()``/``step()``.  Each tick:
       retire finished slots, admit queued requests into the freed
       slots (same tick), one batched decode.
    """

    def __init__(self, cfg, params, *, slots: int = 4, capacity: int = 256,
                 temperature: float = 0.0, seed: int = 0,
                 store_path: Optional[str] = None,
                 compile_cache_dir: Optional[str] = None,
                 dtype_policy: Optional[str] = None,
                 tune: Optional[str] = None,
                 buckets=None, metrics: Optional[ServeMetrics] = None,
                 mesh=None, exact_buckets: bool = False,
                 resilience: Optional[ResilienceConfig] = None,
                 max_queue: Optional[int] = None,
                 faults: Optional[FaultInjector] = None):
        from repro.models import lm

        if cfg.family not in _LM_FAMILIES + ("vlm",):
            raise ValueError(f"{cfg.family} has no engine path")
        self.cfg, self.params = cfg, params
        self.slots = slots
        self.capacity = capacity
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        self.metrics = metrics or ServeMetrics()
        self.is_vlm = cfg.family == "vlm"
        self._occupant: List[Optional[Request]] = [None] * slots
        # the queue itself stays a deque; the BOUND is enforced by the
        # admission controller in submit() (sheds with a typed response
        # instead of growing without limit)
        self._queue: Deque[Request] = deque()
        if max_queue is not None:
            resilience = dataclasses.replace(
                resilience or ResilienceConfig(), max_queue=max_queue)
        self.resilience = resilience or ResilienceConfig()
        self.faults = faults
        eid = self.metrics._eid
        self._admission = AdmissionController(
            self.resilience.max_queue, engine=eid)
        self._decode_guard = GuardedExecutor(
            "decode",
            lambda p, c, t: self._aot.get("decode", self._decode_jit)(p, c, t),
            # degraded rung: bypass the AOT table and run the plain jit
            # decode (still the warmed program in the steady state, but
            # immune to a poisoned AOT executable)
            demote_fn=ladder_of([lambda p, c, t: self._decode_jit(p, c, t)]),
            policy=self.resilience, injector=faults, engine=eid)
        self._prefill_guard = GuardedExecutor(
            "prefill", lambda fn, *a: fn(*a),
            policy=self.resilience, injector=faults, engine=eid)
        self._plan_guards: Dict[int, GuardedExecutor] = {}

        if compile_cache_dir:
            persistence.enable_jax_compilation_cache(compile_cache_dir)

        # -- pyramid buckets (vlm) ----------------------------------------
        self.batcher = None
        self.buckets = ()
        if self.is_vlm:
            vc = cfg.vision
            if buckets is None:
                buckets = batcher_mod.default_buckets(
                    vc.levels, getattr(vc, "bucket_scales", (1.0,)))
            self.buckets = tuple(buckets)
            # serving's contract is the bounded, boot-compiled bucket set
            # and zero request-time retraces, so the engine opts into the
            # batcher's lossy (ulp-level rescale drift) padding for
            # non-pow2 geometry->bucket ratios; exact_buckets=True flips
            # the gate to exact-geometry buckets, paying one jit-fallback
            # compile per novel geometry instead
            self.batcher = batcher_mod.PyramidBatcher(
                self.buckets, lossy_ok=not exact_buckets)

        # -- plans: restore from the store, or warm fresh + persist -------
        # The meta gate covers every axis that changes which SPECS the
        # engine serves (arch, dtype policy, tune mode, bucket ladder,
        # mesh topology): restoring a store written under different axes
        # would AOT the wrong plans and re-race the right ones on a
        # nominally warm boot.
        from repro.kernels import plan as plan_mod

        self.mesh = mesh
        self._batch_q = _batch_quantum(mesh)
        if self.is_vlm and slots % self._batch_q:
            raise ValueError(
                f"slots={slots} must be a multiple of the mesh's dp width "
                f"{self._batch_q}: distributed plans shard batch over dp")
        self._store_meta = {
            "arch": cfg.name,
            "dtype_policy": dtype_policy or "follow",
            "tune": tune or "heuristic",
            "buckets": [b.key for b in self.buckets],
            "mesh": plan_mod.mesh_token(mesh) if mesh is not None else None,
        }
        # chaos: boot-time faults (corrupt_store) fire BEFORE the store
        # is read — a damaged store must degrade to a cold warm-up +
        # re-persist, which the meta-gated load below already does
        # (PlanStore.load() returns None for unreadable JSON)
        self.boot_faults: List[str] = (
            faults.apply_boot_faults(store_path) if faults is not None else [])
        self.store = persistence.PlanStore(store_path) if store_path else None
        self.restore_report = None
        self.store_meta_mismatch = False
        self.plans = ()
        existing = self.store.load() if self.store is not None else None
        if existing is not None:
            stored_meta = existing.get("meta", {})
            # v1 stores carry no "mesh" key: treat absent as None so a
            # mesh-less boot keeps restoring its pre-2D stores unchanged
            if all(stored_meta.get(k) == v for k, v in self._store_meta.items()):
                self.restore_report = self.store.restore(mesh=mesh)
                self.plans = tuple(self.restore_report.plans)
            else:
                self.store_meta_mismatch = True
        if not self.plans:
            self.plans = warmup_msda_plans(
                cfg, dtype_policy=dtype_policy, tune=tune,
                buckets=self.buckets or None, mesh=mesh)
            # Persist only onto an empty/unreadable path: a loadable store
            # whose meta doesn't match this boot belongs to a DIFFERENTLY
            # CONFIGURED fleet (e.g. a sweep artifact) — overwriting it
            # would silently destroy the plans every correctly-configured
            # server restores from.  Pure-LM families warm no MSDA plans
            # and never write a store at all.
            if self.store is not None and self.plans and existing is None:
                self.store.save_plans(self.plans, meta=self._store_meta)

        # -- model fns + cache --------------------------------------------
        dt = jnp.dtype(cfg.dtype)
        self.cache = lm.init_cache(cfg, slots, capacity, dt)
        # per-leaf batch axis, identified structurally (B=1 vs B=2 avals)
        # so splicing never guesses which axis is the slot axis
        s1 = jax.eval_shape(lambda: lm.init_cache(cfg, 1, capacity, dt))
        s2 = jax.eval_shape(lambda: lm.init_cache(cfg, 2, capacity, dt))
        self._batch_axes = jax.tree.map(_diff_axis, s1, s2)

        # one source of truth for the family closures (plans were warmed
        # above, bucket-aware — so skip make_serve_fns' own warm-up)
        self._serve_prefill, self._decode_model = make_serve_fns(
            cfg, dtype_policy=dtype_policy, tune=tune, warm_plans=False)
        if self.is_vlm:
            self._vlm_prefill_jit: Dict[tuple, Callable] = {}
        else:
            self._prefill_model = lambda p, t: self._serve_prefill(p, t, capacity)
            self._prefill_jit = jax.jit(aot.traced(self._prefill_model, "prefill"))
        self._decode_jit = jax.jit(aot.traced(self._decode_model, "decode"))
        self._aot: Dict[Any, aot.AotExecutor] = {}
        self.plan_executors: Dict[Any, aot.AotExecutor] = {}
        self._batch_ladder = _quantize_batches(
            _pow2_batches(slots), self._batch_q, slots)

    # -- AOT warm-up -------------------------------------------------------
    def _vlm_prefill_fn(self, bucket) -> Callable:
        prefill, capacity, levels = self._serve_prefill, self.capacity, bucket.levels
        mesh = self.mesh

        def f(params, pyramid, ratios, tokens):
            if mesh is None:
                return prefill(params, pyramid, tokens, capacity,
                               levels=levels, valid_ratios=ratios)
            # install the mesh at TRACE time: attention_plan resolves
            # the mesh via rules.current_mesh(), so without this the
            # request path would silently build fresh LOCAL plans while
            # the distributed plans the boot warmed/restored never
            # serve — the zero-retrace contract requires the prefill
            # trace to fetch exactly the warmed mesh-carrying plans
            from repro.sharding import rules

            with rules.use_mesh(mesh):
                return prefill(params, pyramid, tokens, capacity,
                               levels=levels, valid_ratios=ratios)

        return f

    def _vlm_prefill(self, bucket) -> Callable:
        """Jit fallback for a bucket (counts as a request-time trace)."""
        if bucket.levels not in self._vlm_prefill_jit:
            self._vlm_prefill_jit[bucket.levels] = jax.jit(aot.traced(
                self._vlm_prefill_fn(bucket), f"prefill[{bucket.key}]"))
        return self._vlm_prefill_jit[bucket.levels]

    def warmup(self, *, prompt_lengths: Tuple[int, ...] = (),
               batch_sizes: Optional[Tuple[int, ...]] = None,
               plan_batch_sizes: Tuple[int, ...] = (1,)) -> "ServeEngine":
        """AOT-compile every request-time executor, before traffic.

        Decode is always compiled; prefill per prompt length (vlm: per
        (bucket, admitted batch size, prompt length)); plus every warmed
        MsdaPlan's standalone executor (``self.plan_executors``).  After
        this, requests matching the warmed signatures run with zero
        traces/compiles — ``aot.probe()`` proves it.
        """
        tok = jax.ShapeDtypeStruct((self.slots,), jnp.int32)
        self._aot["decode"] = aot.aot_compile(
            self._decode_model, self.params, self.cache, tok, name="decode")
        if batch_sizes is None:
            batch_sizes = _pow2_batches(self.slots)
        # admission pads to THIS ladder — it must be exactly the warmed
        # set, or a padded batch size would miss the AOT table and hit
        # the jit fallback at request time.  Quantized to the mesh's dp
        # width: sizes a distributed plan cannot execute are never
        # compiled or admitted.
        batch_sizes = _quantize_batches(batch_sizes, self._batch_q, self.slots)
        self._batch_ladder = batch_sizes
        # standalone plan executors obey the same quantum (uncapped)
        plan_batch_sizes = tuple(sorted(
            {-(-int(b) // self._batch_q) * self._batch_q
             for b in plan_batch_sizes}))
        for L in prompt_lengths:
            if self.is_vlm:
                vd = self.cfg.vision.vision_dim
                nl = len(self.cfg.vision.levels)
                for bucket in self.buckets:
                    for B in batch_sizes:
                        self._aot[("prefill", bucket.levels, B, L)] = aot.aot_compile(
                            self._vlm_prefill_fn(bucket), self.params,
                            jax.ShapeDtypeStruct((B, bucket.tokens, vd), jnp.float32),
                            jax.ShapeDtypeStruct((B, nl, 2), jnp.float32),
                            jax.ShapeDtypeStruct((B, L), jnp.int32),
                            name=f"prefill[{bucket.key}|B={B}|L={L}]")
            else:
                self._aot[("prefill", 1, L)] = aot.aot_compile(
                    self._prefill_model, self.params,
                    jax.ShapeDtypeStruct((1, L), jnp.int32), name=f"prefill[L={L}]")
        self.plan_executors = aot.compile_plan_executors(self.plans, plan_batch_sizes)
        return self

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request) -> Optional[ServeResponse]:
        """Enqueue a request, or SHED it with a typed response.

        Returns the shed response when admission rejects (queue at
        ``resilience.max_queue``); None when accepted — the terminal
        response then lands on ``req.response`` when the request
        finishes, times out, or fails.
        """
        req.submit_tick = self.metrics.ticks
        if req.deadline_ticks is None:
            req.deadline_ticks = self.resilience.deadline_ticks
        if not self._admission.admit(self.pending):
            req.response = ServeResponse(
                "shed", req.rid,
                detail=f"queue at capacity ({self.resilience.max_queue})")
            req.done = True
            self.metrics.record_shed(req.rid)
            return req.response
        if self.is_vlm:
            if req.pyramid is None:
                raise ValueError("vlm requests need a pyramid")
            levels = req.levels or self.cfg.vision.levels
            # may reject (fits no bucket) — count only accepted requests
            self.batcher.submit(req.pyramid, levels, req,
                                group_key=len(req.prompt))
        else:
            self._queue.append(req)
        self.metrics.record_submit(req.rid)
        return None

    @property
    def pending(self) -> int:
        return len(self._queue) + (len(self.batcher) if self.batcher else 0)

    def _free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self._occupant) if r is None]

    def _retire(self):
        """Free slots of finished requests — runs at the top of each
        tick, before admission, so a freed slot is re-filled and decoded
        in the SAME tick instead of idling one.  (Completion itself is
        metered at done-marking time, so metrics don't need a trailing
        tick to see the last requests finish.)"""
        for s, req in enumerate(self._occupant):
            if req is not None and req.done:
                self._occupant[s] = None

    def _finish(self, req: Request):
        req.done = True
        req.response = ServeResponse("ok", req.rid, tokens=tuple(req.out))
        self.metrics.record_retire(req.rid)

    def _fail(self, req: Request, status: str, detail: str):
        """Resolve a request with a non-ok typed response."""
        req.done = True
        req.response = ServeResponse(status, req.rid, detail=detail)
        if status == "timeout":
            self.metrics.record_deadline_miss(req.rid)
        else:
            self.metrics.record_exec_error(req.rid)

    def _deadline_expired(self, req: Request) -> bool:
        return (req.deadline_ticks is not None and req.submit_tick >= 0
                and self.metrics.ticks - req.submit_tick >= req.deadline_ticks)

    def _sweep_deadlines(self):
        """Resolve every expired request — queued, bucketed, or
        in-flight — with a typed timeout response.  Runs at the top of
        each tick, before admission, so an expired queued request is
        never admitted late."""
        expired: List[Request] = []
        if self._queue:
            keep: Deque[Request] = deque()
            for req in self._queue:
                (expired if self._deadline_expired(req) else keep).append(req)
            self._queue = keep
        if self.batcher is not None:
            expired.extend(self.batcher.expire(self._deadline_expired))
        for s, req in enumerate(self._occupant):
            if req is not None and not req.done and self._deadline_expired(req):
                expired.append(req)
                self._occupant[s] = None  # the cache row just goes stale
        for req in expired:
            self._fail(req, "timeout",
                       f"deadline of {req.deadline_ticks} ticks exceeded "
                       f"(submitted at tick {req.submit_tick})")

    def guarded_plan(self, i: int = 0, *,
                     policy: Optional[ResilienceConfig] = None,
                     injector: Optional[FaultInjector] = None
                     ) -> GuardedExecutor:
        """The per-plan circuit breaker for warmed plan ``i`` —
        retries, then demotes down ``MsdaPlan.fallback()`` (fused ->
        per-level -> ref; sparse -> dense) and probes the primary on
        the half-open schedule.  Built on first use; clean runs build
        nothing."""
        if i not in self._plan_guards:
            self._plan_guards[i] = resil_mod.guard_plan(
                self.plans[i], policy or self.resilience, mesh=self.mesh,
                injector=injector if injector is not None else self.faults,
                engine=self.metrics._eid)
        return self._plan_guards[i]

    def resilience_state(self) -> Dict[str, Any]:
        """Machine-readable resilience block (smoke + bench artifact)."""
        guards = [self._decode_guard, self._prefill_guard,
                  *self._plan_guards.values()]
        out = resil_mod.resilience_snapshot(guards, self._admission)
        out["deadline_misses"] = self.metrics.deadline_misses
        out["exec_errors"] = self.metrics.exec_errors
        out["stragglers"] = self.metrics.stragglers
        out["boot_faults"] = list(self.boot_faults)
        if self.faults is not None:
            out["fault_log"] = [dict(d) for d in self.faults.log]
        return out

    def _splice_slot(self, new_cache, src_row: int, slot: int):
        """Copy row ``src_row`` of a (possibly batched) prefill cache
        into slot ``slot`` of the engine cache, axis-mapped per leaf."""

        def splice(big, new, ax):
            if ax < 0:
                return new  # shared leaves (pos counters) track the prefill
            src = [slice(None)] * new.ndim
            src[ax] = slice(src_row, src_row + 1)
            dst = [slice(None)] * big.ndim
            dst[ax] = slice(slot, slot + 1)
            return big.at[tuple(dst)].set(new[tuple(src)])

        self.cache = jax.tree.map(splice, self.cache, new_cache, self._batch_axes)

    def _admit(self):
        if self.is_vlm:
            return self._admit_vlm()
        free = self._free_slots()
        while free and self._queue:
            req = self._queue.popleft()
            L = len(req.prompt)
            fn = self._aot.get(("prefill", 1, L), self._prefill_jit)
            try:
                logits, cache1 = self._prefill_guard.call(
                    fn, self.params, jnp.asarray(req.prompt[None, :]))
            except ExecutorFailure as e:
                self._fail(req, "error", str(e))
                continue
            s = free.pop(0)
            self._splice_slot(cache1, 0, s)
            req.out.append(self._sample(np.asarray(logits)[0]))
            if len(req.out) >= req.max_new:
                self._finish(req)
            self._occupant[s] = req
            self.metrics.record_admit(req.rid, "lm",
                                      real_tokens=L, padded_tokens=L)

    def _admit_vlm(self):
        free = self._free_slots()
        while free and len(self.batcher):
            batch = self.batcher.next_batch(min(len(free), max(self._batch_ladder)))
            reqs = batch.items
            B = len(reqs)
            # pad the admitted batch to the next planned size so prefill
            # executes one of the boot-compiled variants, never a fresh one
            Bp = next(b for b in self._batch_ladder if b >= B)
            feats, ratios = batch.feats, batch.ratios
            tokens = np.stack([r.prompt for r in reqs]).astype(np.int32)
            if Bp > B:
                pad = Bp - B
                feats = np.concatenate(
                    [feats, np.zeros((pad,) + feats.shape[1:], feats.dtype)])
                ratios = np.concatenate(
                    [ratios, np.ones((pad,) + ratios.shape[1:], ratios.dtype)])
                tokens = np.concatenate(
                    [tokens, np.zeros((pad, tokens.shape[1]), tokens.dtype)])
            key = ("prefill", batch.bucket.levels, Bp, tokens.shape[1])
            fn = self._aot.get(key) or self._vlm_prefill(batch.bucket)
            try:
                logits, cache_b = self._prefill_guard.call(
                    fn, self.params, jnp.asarray(feats),
                    jnp.asarray(ratios), jnp.asarray(tokens))
            except ExecutorFailure as e:
                for req in reqs:
                    self._fail(req, "error", str(e))
                continue
            if self.mesh is not None:
                # a mesh-carrying prefill commits its outputs to the
                # mesh (NamedSharding); decode is a single-device AOT
                # executable, so pull the (replicated) cache rows back
                # before they are spliced into the decode cache
                dev = jax.devices()[0]
                cache_b = jax.tree.map(lambda x: jax.device_put(x, dev), cache_b)
            logits = np.asarray(logits)
            for i, req in enumerate(reqs):
                s = free.pop(0)
                self._splice_slot(cache_b, i, s)
                req.out.append(self._sample(logits[i]))
                if len(req.out) >= req.max_new:
                    self._finish(req)
                self._occupant[s] = req
            self.metrics.record_admit(
                [r.rid for r in reqs], batch.bucket.key,
                real_tokens=batch.real_tokens,
                padded_tokens=Bp * batch.bucket.tokens)

    def _sample(self, logits: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(logits.argmax())
        p = np.exp((logits - logits.max()) / self.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def step(self):
        """One engine tick: faults, retire, deadline sweep, admit
        (into freed slots), batched decode (guarded)."""
        if self.faults is not None:
            ev = self.faults.begin_tick(self.metrics.ticks)
            if ev is not None and ev.kind == "straggler":
                if self.faults.straggler_s > 0:
                    time.sleep(self.faults.straggler_s)
                self.metrics.record_straggler()
        self._retire()
        self._sweep_deadlines()
        self._admit()
        self._admission.observe(self.pending)
        tok = np.zeros((self.slots,), np.int32)
        active = [s for s, r in enumerate(self._occupant)
                  if r is not None and not r.done]
        for s in active:
            tok[s] = self._occupant[s].out[-1]
        if not active:
            return False
        try:
            logits, self.cache = self._decode_guard.call(
                self.params, self.cache, jnp.asarray(tok))
        except ExecutorFailure as e:
            # the whole batched step failed past every retry and rung:
            # resolve the in-flight requests with typed errors (the tick
            # still counts — time passed)
            self.metrics.record_tick()
            for s in active:
                self._fail(self._occupant[s], "error", str(e))
                self._occupant[s] = None
            return True
        logits = np.asarray(logits)
        self.metrics.record_tick()
        self.metrics.record_decode(len(active))
        for s in active:
            req = self._occupant[s]
            req.out.append(self._sample(logits[s]))
            if len(req.out) >= req.max_new:
                self._finish(req)
        return True

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step() and not self.pending:
                break
        self._retire()

    def shutdown(self) -> None:
        """Release compiled kernel plans (see :func:`clear_kernel_plans`)."""
        clear_kernel_plans()
