"""Decoder-LM assembly: dense / MoE / hybrid / SSM from a ModelConfig.

Layers are grouped into *periods* (one repetition of ``block_pattern``)
and scanned with ``jax.lax.scan`` so the lowered HLO is depth-independent
(critical for compiling 64-layer configs against a 512-device mesh).
Remainder layers (num_layers % len(pattern)) run unscanned.

Three entry points, matching the assignment's shape kinds:
  * :func:`lm_loss`      — training forward + chunked CE (no (B,S,V) logits)
  * :func:`lm_prefill`   — prompt pass filling a decode cache
  * :func:`lm_decode_step` — one token against the cache
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, rglru, xlstm
from repro.sharding import rules


# --------------------------------------------------------------------------
# per-kind block init / apply
# --------------------------------------------------------------------------


def _init_block(key, cfg, kind: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": layers.init_norm(cfg)}
    if kind in ("attn", "local"):
        p["attn"] = attention.init_attention(k1, cfg)
    elif kind == "rglru":
        p["rglru"] = rglru.init_rglru(k1, cfg)
    elif kind == "mlstm":
        p["mlstm"] = xlstm.init_mlstm(k1, cfg)
        return p  # self-contained block (no separate FFN)
    elif kind == "slstm":
        p["slstm"] = xlstm.init_slstm(k1, cfg)
        return p
    else:
        raise ValueError(kind)
    p["norm2"] = layers.init_norm(cfg)
    if cfg.moe is not None:
        p["moe"] = moe.init_moe(k2, cfg)
    elif cfg.d_ff:
        p["mlp"] = layers.init_mlp(k2, cfg)
    return p


def _init_block_state(cfg, kind: str, batch: int, capacity: int, dtype):
    if kind == "attn":
        return attention.init_kv_cache(cfg, batch, capacity, dtype)
    if kind == "local":
        return attention.init_kv_cache(cfg, batch, min(cfg.window, capacity), dtype)
    if kind == "rglru":
        return rglru.init_rglru_state(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm.init_mlstm_state(cfg, batch, dtype)
    if kind == "slstm":
        return xlstm.init_slstm_state(cfg, batch, dtype)
    raise ValueError(kind)


def _apply_block(
    p: dict, cfg, kind: str, x: jax.Array, *, mode: str, state=None
) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x_out, new_state, aux_loss)."""
    aux = jnp.float32(0.0)
    h = layers.apply_norm(p["norm1"], x, cfg.norm_eps)
    window = cfg.window if kind == "local" else 0
    if kind in ("attn", "local"):
        if mode == "train":
            y = attention.attention_fwd(p["attn"], cfg, h, causal=True, window=window)
            new_state = state
        elif mode == "prefill":
            y, new_state = attention.prefill_attention(p["attn"], cfg, h, state, window=window)
        else:  # decode
            y, new_state = attention.decode_attention(p["attn"], cfg, h, state, window=window)
    elif kind == "rglru":
        st = state if state is not None else rglru.init_rglru_state(cfg, x.shape[0], x.dtype)
        y, new_state = (
            rglru.rglru_seq(p["rglru"], cfg, h, st)
            if mode != "decode"
            else rglru.rglru_step(p["rglru"], cfg, h, st)
        )
    elif kind == "mlstm":
        st = state if state is not None else xlstm.init_mlstm_state(cfg, x.shape[0], x.dtype)
        y, new_state = (
            xlstm.mlstm_seq(p["mlstm"], cfg, h, st)
            if mode != "decode"
            else xlstm.mlstm_step(p["mlstm"], cfg, h, st)
        )
        return x + y, new_state, aux
    elif kind == "slstm":
        st = state if state is not None else xlstm.init_slstm_state(cfg, x.shape[0], x.dtype)
        y, new_state = (
            xlstm.slstm_seq(p["slstm"], cfg, h, st)
            if mode != "decode"
            else xlstm.slstm_step(p["slstm"], cfg, h, st)
        )
        return x + y, new_state, aux
    else:
        raise ValueError(kind)
    x = x + y
    h2 = layers.apply_norm(p["norm2"], x, cfg.norm_eps)
    if "moe" in p:
        if mode == "train":
            y2, aux = moe.moe_ffn(p["moe"], cfg, h2)
        else:  # inference: exact dropless routing (prefill == decode)
            y2, aux = moe.moe_ffn_dropless(p["moe"], cfg, h2)
    elif "mlp" in p:
        y2 = layers.apply_mlp(p["mlp"], cfg, h2)
    else:
        y2 = jnp.zeros_like(x)
    return x + y2, new_state, aux


# --------------------------------------------------------------------------
# model init
# --------------------------------------------------------------------------


def _pattern(cfg) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    pat = tuple(cfg.block_pattern)
    n_periods = cfg.num_layers // len(pat)
    rem = tuple(pat[: cfg.num_layers % len(pat)])
    return pat, n_periods, rem


def init_lm(key, cfg) -> dict:
    pat, n_periods, rem = _pattern(cfg)
    k_emb, k_blocks, k_rem, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = dict(layers.init_embedding(k_emb, cfg.vocab_size, cfg.d_model))

    def init_period(k):
        ks = jax.random.split(k, len(pat))
        return {f"pos{i}": _init_block(ks[i], cfg, kind) for i, kind in enumerate(pat)}

    period_keys = jax.random.split(k_blocks, n_periods)
    params["periods"] = jax.vmap(init_period)(period_keys)
    if rem:
        ks = jax.random.split(k_rem, len(rem))
        params["rem"] = {
            f"pos{i}": _init_block(ks[i], cfg, kind) for i, kind in enumerate(rem)
        }
    params["final_norm"] = layers.init_norm(cfg)
    if not cfg.tie_embeddings:
        params["head"] = layers.dense_init(k_head, (cfg.d_model, cfg.vocab_size))
    return params


def head_weight(params, cfg) -> jax.Array:
    return params["head"] if not cfg.tie_embeddings else params["emb"].T


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------


def init_cache(cfg, batch: int, capacity: int, dtype) -> dict:
    """Decode-state pytree mirroring the scanned period structure."""
    pat, n_periods, rem = _pattern(cfg)

    def one_period():
        return {
            f"pos{i}": _init_block_state(cfg, kind, batch, capacity, dtype)
            for i, kind in enumerate(pat)
        }

    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_periods, *x.shape)), one_period()
    )
    cache = {"periods": stacked}
    if rem:
        cache["rem"] = {
            f"pos{i}": _init_block_state(cfg, kind, batch, capacity, dtype)
            for i, kind in enumerate(rem)
        }
    return cache


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------


def _run_blocks(params, cfg, x, *, mode: str, cache=None, remat: bool = False):
    """Scan periods + remainder. Returns (x, new_cache, aux_sum)."""
    pat, n_periods, rem = _pattern(cfg)

    def period_body(x, pp, pcache):
        aux_tot = jnp.float32(0.0)
        new_cache = {}
        for i, kind in enumerate(pat):
            st = pcache[f"pos{i}"] if pcache is not None else None
            x, st2, aux = _apply_block(pp[f"pos{i}"], cfg, kind, x, mode=mode, state=st)
            new_cache[f"pos{i}"] = st2
            aux_tot = aux_tot + aux
        # residual stream: batch over dp only.  Sequence-parallel hints
        # were tried and REVERTED twice (§Perf): in train they make
        # weight grads partial over 'model' (+560 GB/chip on dbrx); in
        # prefill they collide with flash attention's seq-dim dynamic
        # slices — GSPMD reshards inside the innermost kv loop
        # (llama3 prefill regressed 8.9 -> 167 s).  SP belongs UNDER
        # shard_map (like the MoE dispatch), left as future work.
        x = rules.hint(x, "dp", None, None)
        return x, new_cache, aux_tot

    if remat:
        period_body = jax.checkpoint(period_body)

    if n_periods:
        if cache is None:
            def scan_step(carry, pp):
                x, aux_acc = carry
                x, _, aux = period_body(x, pp, None)
                return (x, aux_acc + aux), None

            (x, aux), new_period_caches = jax.lax.scan(
                scan_step, (x, jnp.float32(0.0)), params["periods"]
            )
        else:
            def scan_step(carry, xs):
                x, aux_acc = carry
                pp, pcache = xs
                x, new_cache, aux = period_body(x, pp, pcache)
                return (x, aux_acc + aux), new_cache

            (x, aux), new_period_caches = jax.lax.scan(
                scan_step, (x, jnp.float32(0.0)), (params["periods"], cache["periods"])
            )
    else:
        aux = jnp.float32(0.0)
        new_period_caches = None

    new_cache = {"periods": new_period_caches} if n_periods else {}
    if rem:
        new_cache["rem"] = {}
        for i, kind in enumerate(rem):
            st = cache["rem"][f"pos{i}"] if cache is not None else None
            x, st2, aux_i = _apply_block(
                params["rem"][f"pos{i}"], cfg, kind, x, mode=mode, state=st
            )
            new_cache["rem"][f"pos{i}"] = st2
            aux = aux + aux_i
    return x, (new_cache if cache is not None else None), aux


def lm_hidden(params, cfg, tokens: jax.Array, *, remat: bool = True, dtype=None):
    """Token ids (B, S) -> final hidden states (B, S, d). Training path."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    x = layers.embed(params, tokens, dtype)
    x = rules.hint(x, "dp", None, None)
    x, _, aux = _run_blocks(params, cfg, x, mode="train", remat=remat)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def lm_loss(
    params, cfg, tokens: jax.Array, targets: jax.Array, mask=None, *, remat: bool = True
) -> jax.Array:
    """Mean next-token CE + MoE aux. tokens/targets: (B, S)."""
    hidden, aux = lm_hidden(params, cfg, tokens, remat=remat)
    w = head_weight(params, cfg)
    ce = layers.chunked_ce_loss(hidden, w, targets, mask)
    return ce + 0.01 * aux


def lm_prefill(params, cfg, tokens: jax.Array, capacity: int, *, dtype=None):
    """Prompt pass. Returns (last-token logits (B, V), cache)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    cache = init_cache(cfg, B, capacity, dtype)
    x = layers.embed(params, tokens, dtype)
    x = rules.hint(x, "dp", None, None)
    x, cache, _ = _run_blocks(params, cfg, x, mode="prefill", cache=cache)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = x[:, -1] @ head_weight(params, cfg).astype(x.dtype)
    return logits.astype(jnp.float32), cache


def lm_decode_step(params, cfg, cache, token: jax.Array, *, dtype=None):
    """One decode step. token: (B,) int32. Returns (logits (B, V), cache)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    x = layers.embed(params, token[:, None], dtype)  # (B, 1, d)
    x, cache, _ = _run_blocks(params, cfg, x, mode="decode", cache=cache)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = x[:, 0] @ head_weight(params, cfg).astype(x.dtype)
    return logits.astype(jnp.float32), cache
