"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM recurrence (per head, stabilised — xLSTM paper eqs. 19-27):
  C_t = f_t C_{t-1} + i_t k_t v_t^T      (C: dk x dv matrix memory)
  n_t = f_t n_{t-1} + i_t k_t
  h_t = (q_t^T C_t) / max(|q_t^T n_t|, exp(-m_t))
with exponential input gate i = exp(~i), forget gate f = sigmoid(~f),
and running log-stabiliser m.  Training/prefill run a **chunkwise
parallel** form: quadratic attention-like math inside a chunk plus a
recurrent (C, n, m) carry across chunks — O(T * chunk) memory, exact
(validated against the step recurrence in tests).  Decode is a single
step with constant state, which qualifies the arch for ``long_500k``.

sLSTM has true sequential dependence (h_{t-1} feeds the gates), so the
sequence path is a ``lax.scan`` over time — inherent to the cell, as in
the paper.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

_CONV_W = 4
NEG_INF = -1e30


# ==========================================================================
# mLSTM
# ==========================================================================


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dk, dv) stabilised matrix memory (fp32)
    n: jax.Array  # (B, H, dk)
    m: jax.Array  # (B, H) log stabiliser
    conv: jax.Array  # (B, _CONV_W-1, inner) conv tail


def _inner(cfg) -> int:
    return 2 * cfg.d_model


def init_mlstm(key, cfg) -> dict:
    d = cfg.d_model
    inner = _inner(cfg)
    ks = jax.random.split(key, 8)
    return {
        "wup": layers.dense_init(ks[0], (d, 2 * inner)),  # [x_m | z-gate]
        "conv_w": layers.dense_init(ks[1], (_CONV_W, inner)) * 0.1,
        "wq": layers.dense_init(ks[2], (inner, inner)),
        "wk": layers.dense_init(ks[3], (inner, inner)),
        "wv": layers.dense_init(ks[4], (inner, inner)),
        "wif": layers.dense_init(ks[5], (inner, 2 * cfg.num_heads)) * 0.1,
        "bif": jnp.concatenate(
            [jnp.zeros((cfg.num_heads,)), 3.0 * jnp.ones((cfg.num_heads,))]
        ),
        "gn": layers.init_groupnorm(cfg.num_heads, inner),
        "wdown": layers.dense_init(ks[6], (inner, d)),
    }


def init_mlstm_state(cfg, batch: int, dtype) -> MLSTMState:
    H = cfg.num_heads
    inner = _inner(cfg)
    dh = inner // H
    return MLSTMState(
        c=jnp.zeros((batch, H, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H, dh), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
        conv=jnp.zeros((batch, _CONV_W - 1, inner), dtype),
    )


def _mlstm_proj(p, cfg, x, conv_tail):
    """Shared projections. x: (B,T,d) -> q,k,v (B,H,T,dh), li/lf (B,H,T), z."""
    dt = x.dtype
    B, T, _ = x.shape
    H = cfg.num_heads
    inner = _inner(cfg)
    dh = inner // H
    up = x @ p["wup"].astype(dt)
    xm, z = jnp.split(up, 2, axis=-1)
    from repro.models.rglru import _conv_causal

    xc = _conv_causal(xm, p["conv_w"], conv_tail)
    xc = jax.nn.silu(xc)

    def heads(t):
        return t.reshape(B, T, H, dh).transpose(0, 2, 1, 3)

    q = heads(xc @ p["wq"].astype(dt)) * dh ** -0.5
    k = heads(xc @ p["wk"].astype(dt)) * dh ** -0.5
    v = heads(xm @ p["wv"].astype(dt))
    gates = (xm @ p["wif"].astype(dt)).astype(jnp.float32) + p["bif"]
    gi, gf = jnp.split(gates, 2, axis=-1)  # (B,T,H)
    li = gi.transpose(0, 2, 1)  # log input gate (exp gate: li = ~i)
    lf = jax.nn.log_sigmoid(gf).transpose(0, 2, 1)
    return q, k, v, li, lf, z, xm


def _mlstm_chunk(q, k, v, li, lf, c_hat, n_hat, m_prev):
    """One chunk of the stabilised chunkwise-parallel mLSTM.

    q,k,v: (B,H,T,dh) (q,k pre-scaled); li,lf: (B,H,T) fp32.
    carry: c_hat (B,H,dk,dv), n_hat (B,H,dk), m_prev (B,H).
    Returns h (B,H,T,dh) fp32 and the new carry.
    """
    B, H, T, dh = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    A = jnp.cumsum(lf, axis=-1)  # (B,H,T) inclusive cumulative log f
    # intra-chunk decay matrix D[t,s] = A_t - A_s + li_s  (s <= t)
    Dm = A[..., :, None] - A[..., None, :] + li[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    Dm = jnp.where(mask, Dm, NEG_INF)
    dmax = Dm.max(-1)  # (B,H,T)
    e_inter = A + m_prev[..., None]  # exponent carried by the inter-chunk term
    m_t = jnp.maximum(e_inter, dmax)  # (B,H,T) per-step stabiliser
    W = jnp.exp(Dm - m_t[..., None])  # (B,H,T,T)
    scores = jnp.einsum("bhtd,bhsd->bhts", qf, kf)  # (B,H,T,T)
    intra_num = jnp.einsum("bhts,bhsd->bhtd", W * scores, vf)
    intra_den = jnp.einsum("bhts,bhts->bht", W, scores)
    inter_scale = jnp.exp(e_inter - m_t)  # (B,H,T)
    inter_num = jnp.einsum("bhtd,bhdv->bhtv", qf, c_hat) * inter_scale[..., None]
    inter_den = jnp.einsum("bhtd,bhd->bht", qf, n_hat) * inter_scale
    num = intra_num + inter_num
    den = intra_den + inter_den
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # end-of-chunk state
    AT = A[..., -1]  # (B,H)
    li_rel = AT[..., None] - A + li  # exp weight of step s in C_T
    m_end = jnp.maximum(AT + m_prev, li_rel.max(-1))
    w_end = jnp.exp(li_rel - m_end[..., None])  # (B,H,T)
    c_new = jnp.exp(AT + m_prev - m_end)[..., None, None] * c_hat + jnp.einsum(
        "bhs,bhsd,bhsv->bhdv", w_end, kf, vf
    )
    n_new = jnp.exp(AT + m_prev - m_end)[..., None] * n_hat + jnp.einsum(
        "bhs,bhsd->bhd", w_end, kf
    )
    return h, (c_new, n_new, m_end)


def mlstm_seq(
    p: dict, cfg, x: jax.Array, state: MLSTMState, *, chunk: int = 128
) -> Tuple[jax.Array, MLSTMState]:
    """Full-sequence mLSTM block. x: (B, T, d)."""
    dt = x.dtype
    B, T, d = x.shape
    H = cfg.num_heads
    inner = _inner(cfg)
    q, k, v, li, lf, z, xm = _mlstm_proj(p, cfg, x, state.conv)

    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0))) for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, 0), (0, pad)))
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))
    nch = (T + pad) // chunk

    def split_chunks(t):
        return t.reshape(B, H, nch, chunk, -1).transpose(2, 0, 1, 3, 4)

    qs, ks, vs = split_chunks(q), split_chunks(k), split_chunks(v)
    lis = li.reshape(B, H, nch, chunk).transpose(2, 0, 1, 3)
    lfs = lf.reshape(B, H, nch, chunk).transpose(2, 0, 1, 3)

    def step(carry, xs):
        qc, kc, vc, lic, lfc = xs
        h, new = _mlstm_chunk(qc, kc, vc, lic, lfc, *carry)
        return new, h

    carry = (state.c, state.n, state.m)
    carry, hs = jax.lax.scan(step, carry, (qs, ks, vs, lis, lfs))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, T + pad, inner // H)[:, :, :T]
    h = h.transpose(0, 2, 1, 3).reshape(B, T, inner).astype(dt)

    h = layers.apply_groupnorm(p["gn"], h, cfg.num_heads)
    h = h * jax.nn.silu(z)
    y = h @ p["wdown"].astype(dt)
    new_state = MLSTMState(
        c=carry[0],
        n=carry[1],
        m=carry[2],
        conv=jnp.concatenate([state.conv.astype(dt), xm], axis=1)[:, -(_CONV_W - 1) :],
    )
    return y, new_state


def mlstm_step(p: dict, cfg, x: jax.Array, state: MLSTMState) -> Tuple[jax.Array, MLSTMState]:
    """Single decode step. x: (B, 1, d). Exact stabilised recurrence."""
    dt = x.dtype
    B = x.shape[0]
    inner = _inner(cfg)
    q, k, v, li, lf, z, xm = _mlstm_proj(p, cfg, x, state.conv)
    qf = q[..., 0, :].astype(jnp.float32)  # (B,H,dh)
    kf = k[..., 0, :].astype(jnp.float32)
    vf = v[..., 0, :].astype(jnp.float32)
    li0 = li[..., 0]
    lf0 = lf[..., 0]
    m_new = jnp.maximum(lf0 + state.m, li0)
    fs = jnp.exp(lf0 + state.m - m_new)[..., None]
    is_ = jnp.exp(li0 - m_new)[..., None]
    c = fs[..., None] * state.c + is_[..., None] * kf[..., :, None] * vf[..., None, :]
    n = fs * state.n + is_ * kf
    num = jnp.einsum("bhd,bhdv->bhv", qf, c)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(B, 1, inner).astype(dt)
    h = layers.apply_groupnorm(p["gn"], h, cfg.num_heads)
    h = h * jax.nn.silu(z)
    y = h @ p["wdown"].astype(dt)
    new_state = MLSTMState(
        c=c, n=n, m=m_new,
        conv=jnp.concatenate([state.conv.astype(dt), xm], axis=1)[:, 1:],
    )
    return y, new_state


# ==========================================================================
# sLSTM
# ==========================================================================


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, inner) cell
    n: jax.Array  # (B, inner) normaliser
    h: jax.Array  # (B, inner) hidden (feeds recurrent gates)
    m: jax.Array  # (B, inner) log stabiliser
    conv: jax.Array  # (B, _CONV_W-1, d)


def init_slstm(key, cfg) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    return {
        "conv_w": layers.dense_init(ks[0], (_CONV_W, d)) * 0.1,
        # input weights for 4 gates (z, i, f, o)
        "wz": layers.dense_init(ks[1], (d, 4 * d)),
        # block-diagonal recurrent weights per head, per gate
        "rz": layers.dense_init(ks[2], (4, H, dh, dh), in_axis=2) * 0.5,
        "bz": jnp.concatenate(
            [jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)), jnp.zeros((d,))]
        ),
        "gn": layers.init_groupnorm(cfg.num_heads, d),
        "wup": layers.dense_init(ks[3], (d, 2 * d)),
        "wdown": layers.dense_init(jax.random.fold_in(ks[3], 1), (d, d)),
    }


def init_slstm_state(cfg, batch: int, dtype) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(
        c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32),
        conv=jnp.zeros((batch, _CONV_W - 1, d), dtype),
    )


def _slstm_cell(p, cfg, xc_t, state: SLSTMState):
    """xc_t: (B, d) conv-ed input at one step; returns (h_out, new_state)."""
    B, d = xc_t.shape
    H = cfg.num_heads
    dh = d // H
    hp = state.h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hp, p["rz"].astype(jnp.float32))
    rec = rec.reshape(4, B, d)
    pre = (xc_t @ p["wz"].astype(xc_t.dtype)).astype(jnp.float32) + p["bz"]
    zp, ip, fp, op = jnp.split(pre, 4, axis=-1)
    zp = zp + rec[0]
    ip = ip + rec[1]
    fp = fp + rec[2]
    op = op + rec[3]
    z = jnp.tanh(zp)
    o = jax.nn.sigmoid(op)
    lf = jax.nn.log_sigmoid(fp)
    m_new = jnp.maximum(lf + state.m, ip)
    fs = jnp.exp(lf + state.m - m_new)
    is_ = jnp.exp(ip - m_new)
    c = fs * state.c + is_ * z
    n = fs * state.n + is_
    h = o * c / jnp.maximum(n, jnp.exp(-m_new))
    return h, SLSTMState(c=c, n=n, h=h, m=m_new, conv=state.conv)


def slstm_seq(p: dict, cfg, x: jax.Array, state: SLSTMState) -> Tuple[jax.Array, SLSTMState]:
    """Sequential scan over time (inherent to sLSTM). x: (B, T, d)."""
    dt = x.dtype
    B, T, d = x.shape
    from repro.models.rglru import _conv_causal

    xc = jax.nn.silu(_conv_causal(x, p["conv_w"], state.conv))

    def step(st, xt):
        h, st2 = _slstm_cell(p, cfg, xt, st)
        return st2, h

    st, hs = jax.lax.scan(step, state, xc.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(dt)  # (B,T,d)
    hs = layers.apply_groupnorm(p["gn"], hs, cfg.num_heads)
    up = hs @ p["wup"].astype(dt)
    a, b = jnp.split(up, 2, axis=-1)
    y = (a * jax.nn.gelu(b)) @ p["wdown"].astype(dt)
    new_state = SLSTMState(
        c=st.c, n=st.n, h=st.h, m=st.m,
        conv=jnp.concatenate([state.conv.astype(dt), x], axis=1)[:, -(_CONV_W - 1) :],
    )
    return y, new_state


def slstm_step(p: dict, cfg, x: jax.Array, state: SLSTMState) -> Tuple[jax.Array, SLSTMState]:
    """Single decode step. x: (B, 1, d)."""
    dt = x.dtype
    from repro.models.rglru import _conv_causal

    xc = jax.nn.silu(_conv_causal(x, p["conv_w"], state.conv))
    h, st = _slstm_cell(p, cfg, xc[:, 0], state)
    hs = layers.apply_groupnorm(p["gn"], h[:, None, :].astype(dt), cfg.num_heads)
    up = hs @ p["wup"].astype(dt)
    a, b = jnp.split(up, 2, axis=-1)
    y = (a * jax.nn.gelu(b)) @ p["wdown"].astype(dt)
    new_state = SLSTMState(
        c=st.c, n=st.n, h=st.h, m=st.m,
        conv=jnp.concatenate([state.conv.astype(dt), x], axis=1)[:, 1:],
    )
    return y, new_state
