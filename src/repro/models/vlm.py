"""phi-3-vision VLM: dense decoder backbone + MSDA visual resampler.

The vision tower is a STUB per the assignment (``input_specs`` provides
a precomputed multi-scale CLIP feature pyramid).  This is the assigned
arch where the paper's op runs natively: a set of learned queries pools
the pyramid through MSDA into ``num_visual_tokens`` tokens that are
prepended to the text sequence.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import msda as msda_mod
from repro.models import layers, lm
from repro.sharding import rules


def pyramid_len(vision) -> int:
    return sum(h * w for h, w in vision.levels)


def _msda_cfg(vision, levels=None, dtype_policy=None, tune=None):
    """Resampler MSDA config; ``levels`` overrides the config pyramid
    (the serving batcher runs the resampler at BUCKET geometry) and
    ``dtype_policy``/``tune`` pin the precision/tuning plan axes so
    serving executes exactly the specs its warm-up planned (the plan
    cache keys on them — a mismatch would silently re-plan per request)."""
    from repro.configs.base import MSDAConfig

    return MSDAConfig(
        levels=levels or vision.levels, num_points=vision.msda_points,
        num_heads=vision.msda_heads, dtype_policy=dtype_policy or "follow",
        tune=tune or "heuristic",
    )


def init_vlm(key, cfg) -> dict:
    vc = cfg.vision
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "backbone": lm.init_lm(k1, cfg),
        "vis_queries": layers.embed_init(k2, (vc.num_visual_tokens, vc.vision_dim), 0.02),
        "vis_ref": layers.init_linear(k3, vc.vision_dim, 2),
        "resampler": msda_mod.init_msda_attention(k4, vc.vision_dim, _msda_cfg(vc)),
        "projector": layers.init_linear(k5, vc.vision_dim, cfg.d_model),
    }


def visual_tokens(params, cfg, pyramid: jax.Array, *, train: bool = False,
                  levels=None, valid_ratios=None, dtype_policy=None,
                  tune=None) -> jax.Array:
    """pyramid: (B, S_v, vision_dim) -> (B, Nv, d_model).

    ``levels``/``valid_ratios`` serve the bucketed batcher: the pyramid
    arrives padded to a bucket's geometry and each request's valid
    fractions rescale the reference points so sampling is equivalent to
    the unpadded pyramid (see ``serving.batcher``).
    """
    vc = cfg.vision
    B = pyramid.shape[0]
    q = jnp.broadcast_to(
        params["vis_queries"].astype(pyramid.dtype)[None],
        (B, vc.num_visual_tokens, vc.vision_dim),
    )
    refs = jax.nn.sigmoid(layers.apply_linear(params["vis_ref"], params["vis_queries"]))
    refs = jnp.broadcast_to(refs[None].astype(jnp.float32), (B, vc.num_visual_tokens, 2))
    vt = msda_mod.msda_attention(
        params["resampler"], _msda_cfg(vc, levels, dtype_policy, tune), q,
        pyramid, refs, train=train, valid_ratios=valid_ratios,
    )
    return layers.apply_linear(params["projector"], vt)


def vlm_loss(params, cfg, pyramid, tokens, targets, *, remat: bool = True) -> jax.Array:
    """Next-token CE on the text positions, visual prefix masked out."""
    dt = jnp.dtype(cfg.dtype)
    vt = visual_tokens(params, cfg, pyramid.astype(dt), train=True)
    te = layers.embed(params["backbone"], tokens, dt)
    x = jnp.concatenate([vt.astype(dt), te], axis=1)
    x = rules.hint(x, "dp", None, None)
    x, _, aux = lm._run_blocks(params["backbone"], cfg, x, mode="train", remat=remat)
    x = layers.apply_norm(params["backbone"]["final_norm"], x, cfg.norm_eps)
    Nv = cfg.vision.num_visual_tokens
    hidden_text = x[:, Nv:]
    w = lm.head_weight(params["backbone"], cfg)
    return layers.chunked_ce_loss(hidden_text, w, targets) + 0.01 * aux


def vlm_prefill(params, cfg, pyramid, tokens, capacity: int, *,
                levels=None, valid_ratios=None, dtype_policy=None, tune=None):
    """Image + prompt prefill. Cache capacity covers Nv + text budget."""
    dt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    vt = visual_tokens(params, cfg, pyramid.astype(dt),
                       levels=levels, valid_ratios=valid_ratios,
                       dtype_policy=dtype_policy, tune=tune)
    te = layers.embed(params["backbone"], tokens, dt)
    x = jnp.concatenate([vt.astype(dt), te], axis=1)
    cache = lm.init_cache(cfg, B, capacity, dt)
    x, cache, _ = lm._run_blocks(params["backbone"], cfg, x, mode="prefill", cache=cache)
    x = layers.apply_norm(params["backbone"]["final_norm"], x, cfg.norm_eps)
    logits = x[:, -1] @ lm.head_weight(params["backbone"], cfg).astype(x.dtype)
    return logits.astype(jnp.float32), cache


def vlm_decode_step(params, cfg, cache, token):
    return lm.lm_decode_step(params["backbone"], cfg, cache, token)
