"""Token-choice top-k mixture-of-experts with capacity-bounded dispatch.

Dispatch strategy (scales to EP on the ``model`` mesh axis):

* tokens are viewed as ``(G, N, d)`` groups; the group axis is aligned
  with the data/batch sharding, so routing decisions are local;
* per group, each token's top-k experts get a slot via a cumulative-sum
  position inside a fixed-capacity buffer ``(G, E, C, d)`` — overflow
  tokens are dropped (standard capacity-factor semantics);
* the buffer is resharded expert-major for expert compute; under pjit
  this boundary is where GSPMD emits the all-to-all;
* combine gathers each token's k expert outputs and mixes with the
  renormalised router weights.

Expert placement adapts to divisibility: ``E % model_axis == 0`` → one
(or more) whole experts per shard (EP); otherwise experts are replicated
and their ``d_ff`` is tensor-parallel (TP-MoE, e.g. grok-1's 8 experts
on a 16-wide model axis).  See ``sharding/rules.py``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import layers
from repro.sharding import rules


def _expert_compute_specs(cfg):
    """Compute-time shardings for the expert einsums.

    Without these, GSPMD contracts the FSDP-sharded ``d`` dim partially
    and ALL-REDUCES the (huge) expert activations — measured at 3.9TB
    per device per step on dbrx train_4k.  Constraining the weights to
    the gathered/EP layout (and the dispatch buffers to match) makes the
    contraction local: weights are all-gathered instead (MBs, not GBs).
    §Perf iteration 2.
    """
    mesh = rules.current_mesh()
    if mesh is None:
        return None
    E = cfg.moe.num_experts
    tp = rules.resolve_axis("tp", mesh)
    dp = rules.resolve_axis("dp", mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp_size = sizes.get("model", 1)
    ep_mode = tp is not None and E % tp_size == 0
    if ep_mode:
        w_spec = P(tp, None, None)  # whole experts per model shard
        ebuf_spec = P(tp, dp, None)
    else:
        w_spec = P(None, None, tp)  # TP over d_ff inside each expert
        ebuf_spec = P(None, dp, tp)

    def ns(spec):
        return NamedSharding(mesh, spec)

    return {
        "wi": ns(w_spec),
        "wd": ns(P(*(w_spec[0], w_spec[2], w_spec[1]))),
        "ebuf": ns(ebuf_spec),
        "buf": ns(P(dp, None, None)),
        "vals": ns(P(dp, None, None, None)),
        "out": ns(P(dp, None, None)),
    }


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    kr, ki, kg, kd = jax.random.split(key, 4)
    d, ff, E = cfg.d_model, cfg.d_ff, m.num_experts
    p = {
        "router": layers.dense_init(kr, (d, E)),
        "experts_wi": layers.dense_init(ki, (E, d, ff), in_axis=1),
        "experts_wd": layers.dense_init(kd, (E, ff, d), in_axis=1),
    }
    if cfg.gated_mlp:
        p["experts_wg"] = layers.dense_init(kg, (E, d, ff), in_axis=1)
    return p


def moe_ffn_dropless(p: dict, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Exact dropless MoE: every expert computed densely, mixed by top-k
    gates.  Used for inference (prefill/decode): capacity-based dispatch
    drops tokens data-dependently, which would make decode logits diverge
    from prefill logits (and serving nondeterministic under batching).
    Costs E/k x the active FLOPs — the standard small-batch serving
    trade-off; a megablocks-style sorted dispatch is the at-scale
    alternative (see DESIGN.md).
    """
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k

    def chunk_fn(xc):
        # xc: (B, C, d) — dense all-expert compute for one seq chunk
        logits = (xc @ p["router"].astype(xc.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, ids = jax.lax.top_k(probs, k)  # (B,C,k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        C = xc.shape[1]
        mix = jnp.zeros((B, C, E), jnp.float32)
        bidx = jnp.arange(B)[:, None, None]
        sidx = jnp.arange(C)[None, :, None]
        mix = mix.at[bidx, sidx, ids].add(gate)
        h = jnp.einsum("bsd,edf->bsef", xc, p["experts_wi"].astype(xc.dtype))
        if "experts_wg" in p:
            g = jnp.einsum("bsd,edf->bsef", xc, p["experts_wg"].astype(xc.dtype))
            h = h * (jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g))
        else:
            h = jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)
        out_e = jnp.einsum("bsef,efd->bsed", h, p["experts_wd"].astype(xc.dtype))
        return jnp.einsum("bsed,bse->bsd", out_e, mix.astype(xc.dtype))

    # chunk over sequence: the dense (B,S,E,ff) tensors of an unchunked
    # pass blow prefill_32k temps (grok: 38 GB/chip); per-chunk temps are
    # bounded at (B, chunk, E, ff)
    chunk = 2048
    if S <= chunk:
        return chunk_fn(x), jnp.float32(0.0)
    pad = (-S) % chunk
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    n = xp.shape[1] // chunk
    xs = xp.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    out = jax.lax.map(chunk_fn, xs)
    out = out.transpose(1, 0, 2, 3).reshape(B, n * chunk, d)[:, :S]
    return out, jnp.float32(0.0)


def moe_ffn(p: dict, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux load-balancing loss)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    G, N = B, S  # one routing group per sequence: aligns with batch sharding
    xt = x.reshape(G, N, d)

    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (G,N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)  # (G,N,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch): E * mean(frac_tokens_e * frac_prob_e)
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)  # (G,N,k,E)
    tok_frac = onehot.sum(2).mean(1)  # (G,E)
    prob_frac = probs.mean(1)  # (G,E)
    aux = E * (tok_frac * prob_frac).sum(-1).mean()

    # capacity slots: position of each (token, choice) within its expert
    C = max(int(N * k / E * m.capacity_factor), 1)
    flat_choice = onehot.reshape(G, N * k, E)
    pos = jnp.cumsum(flat_choice, axis=1) - 1.0  # (G,N*k,E)
    slot = (pos * flat_choice).sum(-1).reshape(G, N, k)  # (G,N,k) fp32
    keep = (slot < C) & (gate > 0)
    slot = slot.astype(jnp.int32)

    vals = jnp.broadcast_to(xt[:, :, None, :], (G, N, k, d))
    vals = vals * keep[..., None].astype(x.dtype)
    gatek = (gate * keep).astype(jnp.float32)  # (G,N,k)

    wi = p["experts_wi"].astype(x.dtype)
    wg = p.get("experts_wg")
    wg = wg.astype(x.dtype) if wg is not None else None
    wd = p["experts_wd"].astype(x.dtype)

    mesh = rules.current_mesh()
    if mesh is None:
        mixed = _moe_compute(cfg, vals, ids, slot, keep, gatek, wi, wg, wd,
                             shard_e=0, n_shards=1)
        return mixed.reshape(B, S, d), aux.astype(jnp.float32)

    # Manual-EP shard_map block (§Perf iteration 2): dispatch scatter is
    # local per data shard; the buffer is REPLICATED over 'model' (inputs
    # are dp-sharded only), so each model shard slices ITS experts for
    # free; combine mixes only the local experts' outputs and a single
    # activation-sized psum over 'model' finishes the job.  GSPMD's own
    # partitioning of the same math moved the full dispatch buffers
    # through all-reduce / all-gather chains (3.9 TB/chip/step on dbrx).
    dp = rules.resolve_axis("dp", mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp_size = sizes.get("model", 1)
    has_model = "model" in mesh.axis_names and tp_size > 1
    ep_mode = has_model and E % tp_size == 0
    dspec = lambda nd: P(*((dp,) + (None,) * (nd - 1)))  # noqa: E731
    if ep_mode:
        w_spec = P("model", None, None)
    elif has_model:
        w_spec = P(None, None, "model")  # TP over d_ff inside each expert
    else:
        w_spec = P(None, None, None)
    wd_spec = P(w_spec[0], w_spec[2], w_spec[1])

    def body(vals_, ids_, slot_, keep_, gatek_, wi_, wg_, wd_):
        j = jax.lax.axis_index("model") if ep_mode else 0
        out = _moe_compute(cfg, vals_, ids_, slot_, keep_, gatek_,
                           wi_, wg_ if wg is not None else None, wd_,
                           shard_e=j, n_shards=tp_size if ep_mode else 1)
        if has_model:
            # EP: sum partial mixes from each expert shard;
            # TP: sum ff-slice partial products — same psum either way
            out = jax.lax.psum(out, "model")
        return out

    wg_arg = wg if wg is not None else jnp.zeros((1, 1, 1), x.dtype)
    mixed = jax.shard_map(
        body, mesh=mesh,
        in_specs=(dspec(4), dspec(3), dspec(3), dspec(3), dspec(3),
                  w_spec, w_spec if wg is not None else P(None, None, None),
                  wd_spec),
        out_specs=dspec(3), check_vma=False,
    )(vals, ids, slot, keep, gatek, wi, wg_arg, wd)
    return mixed.reshape(B, S, d), aux.astype(jnp.float32)


def _moe_compute(cfg, vals, ids, slot, keep, gatek, wi, wg, wd, *,
                 shard_e, n_shards):
    """Dispatch -> expert FFN -> combine for one shard's experts.

    vals: (G,N,k,d) masked token copies; ids/slot/keep/gatek: (G,N,k).
    EP (n_shards>1): this shard owns experts [shard_e*E_loc, ...).
    TP-MoE: n_shards==1 with ff-sliced weights; the caller psums the
    partial outputs over 'model'.
    """
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    G, N, _, d = vals.shape
    C = max(int(N * k / E * m.capacity_factor), 1)
    E_loc = E // n_shards
    lo = shard_e * E_loc

    if n_shards > 1:
        mine = (ids >= lo) & (ids < lo + E_loc) & keep
    else:
        mine = keep
    local_e = jnp.clip(ids - lo, 0, E_loc - 1)
    flat_idx = jnp.where(mine, local_e * C + slot, 0)  # (G,N,k)

    gi = jnp.arange(G)[:, None, None]
    buf = jnp.zeros((G, E_loc * C, d), vals.dtype)
    buf = buf.at[gi, flat_idx].add(vals * mine[..., None].astype(vals.dtype))

    ebuf = buf.reshape(G, E_loc, C, d).transpose(1, 0, 2, 3).reshape(E_loc, G * C, d)
    h = jnp.einsum("egd,edf->egf", ebuf, wi)
    if wg is not None:
        gg = jnp.einsum("egd,edf->egf", ebuf, wg)
        h = h * (jax.nn.silu(gg) if cfg.act == "silu" else jax.nn.gelu(gg))
    else:
        h = jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)
    out_e = jnp.einsum("egf,efd->egd", h, wd)

    obuf = out_e.reshape(E_loc, G, C, d).transpose(1, 0, 2, 3).reshape(G, E_loc * C, d)
    picked = jnp.take_along_axis(
        obuf, flat_idx.reshape(G, N * k)[..., None], axis=1
    ).reshape(G, N, k, d)
    w = gatek * mine.astype(jnp.float32)
    return (picked * w[..., None].astype(vals.dtype)).sum(2)  # (G,N,d)
