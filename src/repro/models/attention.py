"""Attention substrate: GQA/MHA/MQA, sliding-window, KV caches, decode.

Memory posture: training/prefill attention is computed in query chunks
(``lax.scan`` over chunks) so peak temp is ``O(S * q_chunk)`` per head
rather than ``O(S^2)`` — required for the 32k prefill cells.  Decode
attends one query against either a full cache or a ring-buffer window
cache (bounded state for the long-context cells).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -1e30


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------


def init_attention(key, cfg, *, cross: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": layers.dense_init(kq, (d, cfg.q_dim)),
        "wk": layers.dense_init(kk, (d, cfg.kv_dim)),
        "wv": layers.dense_init(kv, (d, cfg.kv_dim)),
        "wo": layers.dense_init(ko, (cfg.q_dim, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
    return p


def _project_q(p, cfg, x):
    q = x @ p["wq"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    return q.reshape(*x.shape[:-1], cfg.num_heads, cfg.head_dim)


def _project_kv(p, cfg, x):
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = k.reshape(*x.shape[:-1], cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(*x.shape[:-1], cfg.num_kv_heads, cfg.head_dim)
    return k, v


def _expand_kv(x: jax.Array, groups: int) -> jax.Array:
    """(B, S, kv, hd) -> (B, S, kv*groups, hd) by repetition (GQA)."""
    if groups == 1:
        return x
    return jnp.repeat(x, groups, axis=2)


# --------------------------------------------------------------------------
# core attention (query-chunked)
# --------------------------------------------------------------------------


def attend(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, Kv, hd) — GQA-native, Kv may be < H
    v: jax.Array,  # (B, Sk, Kv, hd)
    *,
    causal: bool,
    window: int = 0,
    q_offset: int = 0,
    q_chunk: int = 512,
    bias_mask: Optional[jax.Array] = None,  # (B, Sq, Sk) additive
    impl: str = "flash",  # 'flash' (default) | 'chunked' (baseline ablation)
) -> jax.Array:
    """Softmax attention. ``window>0`` = sliding-window (causal).

    'flash' = online-softmax custom-VJP (no S x S traffic, O(S·d)
    residuals, no GQA head expansion) — the beyond-paper optimisation
    driven by the roofline's memory term; 'chunked' = the materialising
    baseline kept for the §Perf before/after comparison.
    """
    if impl == "flash" and bias_mask is None:
        from repro.models import flash

        kv_chunk = min(max(k.shape[1], 1), 1024)
        return flash.flash_attend(q, k, v, None, causal, window, q_offset, kv_chunk)
    if k.shape[2] != q.shape[2]:  # chunked baseline needs expanded heads
        k = _expand_kv(k, q.shape[2] // k.shape[2])
        v = _expand_kv(v, q.shape[2] // v.shape[2])
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    q = q * scale

    def block(qc, qpos):
        # qc: (B, C, H, hd); qpos: (C,) absolute positions
        s = jnp.einsum("bchd,bkhd->bhck", qc, k).astype(jnp.float32)
        kpos = jnp.arange(Sk)
        m = jnp.zeros((qpos.shape[0], Sk), jnp.float32)
        if causal:
            m = jnp.where(kpos[None, :] > qpos[:, None], NEG_INF, m)
        if window:
            m = jnp.where(kpos[None, :] <= qpos[:, None] - window, NEG_INF, m)
        s = s + m[None, None]
        if bias_mask is not None:
            # bias rows for this chunk
            bm = jax.lax.dynamic_slice_in_dim(bias_mask, qpos[0], qpos.shape[0], axis=1)
            s = s + bm[:, None].astype(jnp.float32)
        w = jax.nn.softmax(s, axis=-1).astype(qc.dtype)
        return jnp.einsum("bhck,bkhd->bchd", w, v)

    if Sq <= q_chunk:
        return block(q, q_offset + jnp.arange(Sq))

    pad = (-Sq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = q.shape[1] // q_chunk
    qs = q.reshape(B, n, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    starts = q_offset + jnp.arange(n) * q_chunk

    def step(_, xs):
        qc, st = xs
        return None, block(qc, st + jnp.arange(q_chunk))

    _, out = jax.lax.scan(step, None, (qs, starts))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, n * q_chunk, H, hd)
    return out[:, :Sq]


# --------------------------------------------------------------------------
# full-sequence (train / prefill) attention block
# --------------------------------------------------------------------------


def attention_fwd(
    p: dict,
    cfg,
    x: jax.Array,  # (B, S, d)
    *,
    causal: bool = True,
    window: int = 0,
    positions: Optional[jax.Array] = None,
    rope: bool = True,
    kv_source: Optional[jax.Array] = None,  # cross-attention source
    q_chunk: int = 512,
    impl: str = "flash",
) -> jax.Array:
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q = _project_q(p, cfg, x)
    kv_in = x if kv_source is None else kv_source
    k, v = _project_kv(p, cfg, kv_in)
    if rope and kv_source is None:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    out = attend(q, k, v, causal=causal, window=window, q_chunk=q_chunk, impl=impl)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------------
# KV caches
# --------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Full cache (prefill/decode) or ring-buffer window cache.

    k/v: (B, C, n_kv, hd) where C = max_len (full) or window (ring).
    ``pos``: tokens already absorbed PER SLOT, shape (B,) int32 —
    required for continuous batching (slots decode at different depths).

    **int8 mode** (``k.dtype == int8``): per-(slot, head) symmetric
    quantisation with fp32 scales ``(B, C, n_kv, 1)`` — halves cache HBM
    vs bf16 (qwen1.5-32B MHA decode_32k: 21.5 -> ~11 GB/chip, which is
    what makes that cell fit).  The scale fields are size-0 placeholders
    in the non-quantised mode (static pytree structure across modes).
    """

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array
    v_scale: jax.Array
    pos: jax.Array


def init_kv_cache(cfg, batch: int, capacity: int, dtype) -> KVCache:
    shape = (batch, capacity, cfg.num_kv_heads, cfg.head_dim)
    if getattr(cfg, "kv_quant", False):
        sshape = (batch, capacity, cfg.num_kv_heads, 1)
        return KVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(sshape, jnp.float32),
            v_scale=jnp.zeros(sshape, jnp.float32),
            pos=jnp.zeros((batch,), jnp.int32),
        )
    empty = jnp.zeros((0,), jnp.float32)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        k_scale=empty, v_scale=empty,
        pos=jnp.zeros((batch,), jnp.int32),
    )


def _quantize(x):
    """(..., hd) -> (int8 (..., hd), fp32 scale (..., 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def cache_kv(cache: KVCache, dtype):
    """Read the cache at compute precision (dequantise int8 mode)."""
    if cache.k.dtype == jnp.int8:
        k = cache.k.astype(jnp.float32) * cache.k_scale
        v = cache.v.astype(jnp.float32) * cache.v_scale
        return k.astype(dtype), v.astype(dtype)
    return cache.k.astype(dtype), cache.v.astype(dtype)


def _write_token(cache: KVCache, k_new, v_new, slot: jax.Array) -> KVCache:
    """Scatter one token per batch element at per-slot positions.

    k_new/v_new: (B, 1, n_kv, hd); slot: (B,) int32 write positions.
    """
    b = jnp.arange(cache.k.shape[0])
    if cache.k.dtype == jnp.int8:
        kq, ks = _quantize(k_new[:, 0])
        vq, vs = _quantize(v_new[:, 0])
        return cache._replace(
            k=cache.k.at[b, slot].set(kq), v=cache.v.at[b, slot].set(vq),
            k_scale=cache.k_scale.at[b, slot].set(ks),
            v_scale=cache.v_scale.at[b, slot].set(vs),
            pos=cache.pos + 1,
        )
    k = cache.k.at[b, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[b, slot].set(v_new[:, 0].astype(cache.v.dtype))
    return cache._replace(k=k, v=v, pos=cache.pos + 1)


def _bulk_write(cache: KVCache, k, v, pos_new, *, at_start: bool = False) -> KVCache:
    """Write a full (B, T, n_kv, hd) block (prefill), quantising if int8."""
    if cache.k.dtype == jnp.int8:
        kq, ks = _quantize(k)
        vq, vs = _quantize(v)
        if at_start:
            return cache._replace(
                k=jax.lax.dynamic_update_slice(cache.k, kq, (0, 0, 0, 0)),
                v=jax.lax.dynamic_update_slice(cache.v, vq, (0, 0, 0, 0)),
                k_scale=jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, 0, 0, 0)),
                v_scale=jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, 0, 0, 0)),
                pos=pos_new)
        return cache._replace(k=kq, v=vq, k_scale=ks, v_scale=vs, pos=pos_new)
    k = k.astype(cache.k.dtype)
    v = v.astype(cache.v.dtype)
    if at_start:
        return cache._replace(
            k=jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0)),
            v=jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0)),
            pos=pos_new)
    return cache._replace(k=k, v=v, pos=pos_new)


def prefill_attention(
    p: dict,
    cfg,
    x: jax.Array,
    cache: KVCache,
    *,
    window: int = 0,
    rope: bool = True,
    q_chunk: int = 512,
    impl: str = "flash",
) -> Tuple[jax.Array, KVCache]:
    """Process a full prompt, producing output and a filled cache."""
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q = _project_q(p, cfg, x)
    k, v = _project_kv(p, cfg, x)
    if rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    out = attend(q, k, v, causal=True, window=window, q_chunk=q_chunk, impl=impl)
    if window:
        # keep the trailing window in the ring buffer, ring-aligned so that
        # decode's ``slot = pos % window`` indexing lines up
        if S >= window:
            start = S - window
            # token at absolute position start+i must land at slot
            # (start+i) % window  ->  right-roll by start % window
            kk = jnp.roll(k[:, -window:], start % window, axis=1)
            vv = jnp.roll(v[:, -window:], start % window, axis=1)
        else:
            pad = ((0, 0), (0, window - S), (0, 0), (0, 0))
            kk = jnp.pad(k, pad)  # position i already sits at slot i
            vv = jnp.pad(v, pad)
        cache = _bulk_write(cache, kk, vv, jnp.full((B,), S, jnp.int32))
    else:
        cache = _bulk_write(cache, k, v, jnp.full((B,), S, jnp.int32),
                            at_start=True)
    y = out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(x.dtype)
    return y, cache


def decode_attention(
    p: dict,
    cfg,
    x: jax.Array,  # (B, 1, d)
    cache: KVCache,
    *,
    window: int = 0,
    rope: bool = True,
) -> Tuple[jax.Array, KVCache]:
    """One decode step against the cache (per-slot positions)."""
    B = x.shape[0]
    pos = cache.pos  # (B,): index of the token being generated, per slot
    q = _project_q(p, cfg, x)
    k_new, v_new = _project_kv(p, cfg, x)
    if rope:
        q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = layers.apply_rope(k_new, pos[:, None], cfg.rope_theta)
    k_new = k_new.astype(cache.k.dtype)
    v_new = v_new.astype(cache.v.dtype)
    cache = _write_token(cache, k_new, v_new, pos % window if window else pos)

    C = cache.k.shape[1]
    kpos_slot = jnp.arange(C)[None, :]  # (1, C)
    posb = pos[:, None]
    if window:
        # slot j holds absolute position: largest p <= pos with p % window == j
        delta = (posb % window - kpos_slot) % window
        abs_pos = posb - delta
        valid = (abs_pos >= 0) & (abs_pos <= posb) & (abs_pos > posb - window)
    else:
        valid = kpos_slot <= posb  # (B, C)
    # flash path, GQA-native: the cache is streamed ONCE in chunks at its
    # n_kv width — no head expansion, no (B,H,C) fp32 score tensor
    # (§Perf iteration 4: MQA decode regressed 6x with expansion)
    from repro.models import flash

    if cache.k.dtype == jnp.int8:
        out = flash.flash_decode_quant(
            q, cache.k, cache.v, cache.k_scale, cache.v_scale, valid,
            kv_chunk=min(C, 1024),
        )
    else:
        kk, vv = cache_kv(cache, x.dtype)
        out = flash.flash_attend(q, kk, vv, valid, False, 0, 0, min(C, 1024))
    y = out.reshape(B, 1, cfg.q_dim) @ p["wo"].astype(x.dtype)
    return y, cache
