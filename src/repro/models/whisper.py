"""Whisper-style encoder-decoder backbone.

The audio frontend (mel + conv) is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings
``(B, num_frames, d_model)``.  Encoder: bidirectional attention with
sinusoidal positions.  Decoder: causal self-attention + cross-attention
with per-layer precomputed cross K/V at prefill (decode touches only
the self cache).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers
from repro.sharding import rules


class DecLayerCache(NamedTuple):
    self_kv: attention.KVCache
    cross_k: jax.Array  # (B, F, n_kv, hd) precomputed at prefill
    cross_v: jax.Array


def init_whisper(key, cfg) -> dict:
    enc = cfg.encoder
    ks = jax.random.split(key, 6)
    d = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": layers.init_norm(cfg),
            "attn": attention.init_attention(k1, cfg),
            "norm2": layers.init_norm(cfg),
            "mlp": layers.init_mlp(k2, cfg),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": layers.init_norm(cfg),
            "self_attn": attention.init_attention(k1, cfg),
            "norm_x": layers.init_norm(cfg),
            "cross_attn": attention.init_attention(k2, cfg),
            "norm2": layers.init_norm(cfg),
            "mlp": layers.init_mlp(k3, cfg),
        }

    params: Dict[str, Any] = {
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(ks[0], enc.num_layers)),
        "enc_norm": layers.init_norm(cfg),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(ks[1], cfg.num_layers)),
        "final_norm": layers.init_norm(cfg),
        **layers.init_embedding(ks[2], cfg.vocab_size, d),
    }
    # NOTE: real whisper uses learned decoder positions capped at 448;
    # the assigned decode shapes stretch to 32k, so we use sinusoidal
    # decoder positions computed at the running offset instead.
    return params


def encode(params, cfg, frames: jax.Array, *, remat: bool = True) -> jax.Array:
    """frames: (B, F, d) stub frontend output -> encoder states (B, F, d)."""
    dt = frames.dtype
    F = frames.shape[1]
    x = frames + layers.sinusoidal_positions(F, cfg.d_model).astype(dt)
    x = rules.hint(x, "dp", None, None)

    def step(x, lp):
        h = layers.apply_norm(lp["norm1"], x, cfg.norm_eps)
        y = attention.attention_fwd(lp["attn"], cfg, h, causal=False, rope=False)
        x = x + y
        h2 = layers.apply_norm(lp["norm2"], x, cfg.norm_eps)
        x = x + layers.apply_mlp(lp["mlp"], cfg, h2)
        return x, None

    if remat:  # without this, 32 layers of saved (B,H,F,F) probs blow HBM
        step = jax.checkpoint(step)
    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return layers.apply_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer_full(lp, cfg, x, enc_out, mode, cache: DecLayerCache | None):
    """Full-sequence decoder layer (train/prefill)."""
    h = layers.apply_norm(lp["norm1"], x, cfg.norm_eps)
    if mode == "train":
        y = attention.attention_fwd(lp["self_attn"], cfg, h, causal=True, rope=False)
        new_self = None
    else:
        y, new_self = attention.prefill_attention(lp["self_attn"], cfg, h, cache.self_kv, rope=False)
    x = x + y
    hx = layers.apply_norm(lp["norm_x"], x, cfg.norm_eps)
    y = attention.attention_fwd(lp["cross_attn"], cfg, hx, causal=False, rope=False, kv_source=enc_out)
    x = x + y
    h2 = layers.apply_norm(lp["norm2"], x, cfg.norm_eps)
    x = x + layers.apply_mlp(lp["mlp"], cfg, h2)
    if mode == "train":
        return x, None
    ck, cv = attention._project_kv(lp["cross_attn"], cfg, enc_out)
    return x, DecLayerCache(self_kv=new_self, cross_k=ck, cross_v=cv)


def _dec_layer_step(lp, cfg, x, cache: DecLayerCache):
    """One decode step: self-attn against cache + cross-attn against
    the precomputed cross K/V (no encoder recompute)."""
    h = layers.apply_norm(lp["norm1"], x, cfg.norm_eps)
    y, new_self = attention.decode_attention(lp["self_attn"], cfg, h, cache.self_kv, rope=False)
    x = x + y
    hx = layers.apply_norm(lp["norm_x"], x, cfg.norm_eps)
    q = attention._project_q(lp["cross_attn"], cfg, hx)
    from repro.models import flash

    y = flash.flash_attend(
        q, cache.cross_k.astype(x.dtype), cache.cross_v.astype(x.dtype),
        None, False, 0, 0, min(cache.cross_k.shape[1], 1024),
    ).reshape(*x.shape[:2], cfg.q_dim)
    x = x + y @ lp["cross_attn"]["wo"].astype(x.dtype)
    h2 = layers.apply_norm(lp["norm2"], x, cfg.norm_eps)
    x = x + layers.apply_mlp(lp["mlp"], cfg, h2)
    return x, DecLayerCache(self_kv=new_self, cross_k=cache.cross_k, cross_v=cache.cross_v)


def _sinusoid_at(offset, length: int, dim: int) -> jax.Array:
    """Sinusoidal positions [offset, offset+length).

    offset may be a scalar or a per-batch (B,) vector (continuous
    batching decodes slots at different depths); returns (..., length, dim).
    """
    import math

    off = jnp.asarray(offset, jnp.float32)
    pos = off[..., None] + jnp.arange(length, dtype=jnp.float32)
    div = jnp.exp(-math.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = pos[..., None] * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed_tokens(params, cfg, tokens, dtype, offset=0):
    x = layers.embed(params, tokens, dtype)
    pos = _sinusoid_at(offset, tokens.shape[1], cfg.d_model)
    return x + pos.astype(dtype)


def whisper_loss(params, cfg, frames, tokens, targets, *, remat: bool = True):
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, frames.astype(dt), remat=remat)
    x = _embed_tokens(params, cfg, tokens, dt)

    def step(x, lp):
        y, _ = _dec_layer_full(lp, cfg, x, enc_out, "train", None)
        return y, None

    if remat:
        step = jax.checkpoint(step)
    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_eps)
    return layers.chunked_ce_loss(x, params["emb"].T, targets)


def whisper_prefill(params, cfg, frames, tokens, capacity: int):
    """Returns (last-token logits, cache pytree stacked over layers)."""
    dt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    enc_out = encode(params, cfg, frames.astype(dt))
    x = _embed_tokens(params, cfg, tokens, dt)
    n_layers = cfg.num_layers
    self0 = attention.init_kv_cache(cfg, B, capacity, dt)
    F = frames.shape[1]
    cache0 = DecLayerCache(
        self_kv=self0,
        cross_k=jnp.zeros((B, F, cfg.num_kv_heads, cfg.head_dim), dt),
        cross_v=jnp.zeros((B, F, cfg.num_kv_heads, cfg.head_dim), dt),
    )
    cache0 = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_layers, *a.shape)), cache0)

    def step(x, xs):
        lp, c = xs
        y, c2 = _dec_layer_full(lp, cfg, x, enc_out, "prefill", c)
        return y, c2

    x, cache = jax.lax.scan(step, x, (params["dec_layers"], cache0))
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = x[:, -1] @ params["emb"].T.astype(x.dtype)
    return logits.astype(jnp.float32), cache


def whisper_decode_step(params, cfg, cache, token):
    dt = jnp.dtype(cfg.dtype)
    pos = cache.self_kv.pos[0]  # same across layers
    x = _embed_tokens(params, cfg, token[:, None], dt, offset=pos)

    def step(x, xs):
        lp, c = xs
        y, c2 = _dec_layer_step(lp, cfg, x, c)
        return y, c2

    x, cache = jax.lax.scan(step, x, (params["dec_layers"], cache))
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = x[:, 0] @ params["emb"].T.astype(x.dtype)
    return logits.astype(jnp.float32), cache
