"""Flash-style attention (jnp-level, custom VJP) — beyond-paper perf.

The baseline q-chunked attention materialises (and, under autodiff,
*saves*) S x S_k score tensors; the dry-run roofline showed that traffic
dominating every attention arch's memory term.  This implementation:

* **GQA-native**: k/v keep their ``n_kv`` heads — no ``jnp.repeat``
  expansion (the v1 expansion made MQA/GQA decode re-materialise the
  whole cache ``H/n_kv`` times: granite decode_32k regressed 6x until
  this fix — §Perf iteration 4);
* forward: *both* q and kv are chunked — q chunks run under ``lax.map``
  (bounded carry: the v1 full-length-q carry was rewritten once per kv
  chunk, adding O(S·d·n_kv_chunks) traffic that regressed the 32k
  prefills — §Perf iteration 4), kv chunks scanned with online softmax;
* residuals: only ``(q, k, v, o, lse)`` — O(S·d), never O(S²);
* backward: recomputes probabilities chunk-by-chunk from ``lse``,
  accumulating dq/dk/dv in the same scan.

Supports causal, sliding-window, query-position offsets, and an
optional per-key validity mask (decode caches).  Layouts:
q ``(B, Sq, H, hd)``, k/v ``(B, Sk, Kv, hd)`` with ``H % Kv == 0``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_mask(qpos, kpos, causal: bool, window: int):
    m = jnp.zeros((qpos.shape[0], kpos.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(kpos[None, :] > qpos[:, None], NEG_INF, m)
    if window:
        m = jnp.where(kpos[None, :] <= qpos[:, None] - window, NEG_INF, m)
    return m


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, pad)
    return jnp.pad(x, pads)


def _kv_padded(k, v, valid, kv_chunk):
    """Zero-pad KV to a chunk multiple.  Chunks are DYNAMIC-SLICED inside
    the scan (not pre-split): pre-splitting materialises a transposed
    copy of the whole cache — measured +22 GB/chip on qwen decode_32k."""
    kp = _pad_to(k, kv_chunk, 1)
    vp = _pad_to(v, kv_chunk, 1)
    if valid is None:
        valid = jnp.ones((k.shape[0], k.shape[1]), bool)
    validp = _pad_to(valid, kv_chunk, 1)
    return kp, vp, validp, kp.shape[1] // kv_chunk


def _slice_chunk(arr, idx, kv_chunk):
    return jax.lax.dynamic_slice_in_dim(arr, idx * kv_chunk, kv_chunk, axis=1)


def flash_decode_quant(q, k_q, v_q, k_scale, v_scale, valid, kv_chunk: int = 1024):
    """Decode against an int8 cache, dequantising PER CHUNK inside the
    scan — the full-precision cache never exists (inference only, no VJP).

    q: (B, 1, H, hd); k_q/v_q: (B, Sk, Kv, int8); scales: (B, Sk, Kv, 1).
    """
    B, Sq, H, hd = q.shape
    Kv = k_q.shape[2]
    G = H // Kv
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(B, Sq, Kv, G, hd)
    kp, vp, validp, nkv = _kv_padded(k_q, v_q, valid, kv_chunk)
    ksp = _pad_to(k_scale, kv_chunk, 1)
    vsp = _pad_to(v_scale, kv_chunk, 1)

    def step(carry, idx):
        o, m, l = carry
        kc = _slice_chunk(kp, idx, kv_chunk)
        vc = _slice_chunk(vp, idx, kv_chunk)
        ks = _slice_chunk(ksp, idx, kv_chunk)
        vs = _slice_chunk(vsp, idx, kv_chunk)
        vm = _slice_chunk(validp, idx, kv_chunk)
        kcf = kc.astype(jnp.float32) * ks  # per-chunk dequant (transient)
        vcf = vc.astype(jnp.float32) * vs
        s = jnp.einsum("bqvgd,bkvd->bqvgk", qf, kcf)
        s = jnp.where(vm[:, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(-1)
        o = o * alpha[..., None] + jnp.einsum("bqvgk,bkvd->bqvgd", p, vcf)
        return (o, m_new, l), None

    o0 = jnp.zeros((B, Sq, Kv, G, hd), jnp.float32)
    m0 = jnp.full((B, Sq, Kv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Kv, G), jnp.float32)
    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), jnp.arange(nkv))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def _fwd_one_qchunk(qc, q0, kp, vp, validp, nkv, *, causal, window, kv_chunk):
    """qc: (B, qc_len, Kv, G, hd) fp32 pre-scaled; q0: absolute start pos.
    kp/vp/validp: full padded KV (sliced per scan step).
    Returns (o fp32, lse fp32)."""
    B, qlen, Kv, G, hd = qc.shape
    qpos = q0 + jnp.arange(qlen)

    def step(carry, idx):
        o, m, l = carry
        kc = _slice_chunk(kp, idx, kv_chunk)
        vc = _slice_chunk(vp, idx, kv_chunk)
        vm = _slice_chunk(validp, idx, kv_chunk)
        kpos = idx * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqvgd,bkvd->bqvgk", qc, kc.astype(jnp.float32))
        msk = _chunk_mask(qpos, kpos, causal, window)  # (qlen, kc)
        s = s + msk[None, :, None, None, :]
        s = jnp.where(vm[:, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(-1)
        o = o * alpha[..., None] + jnp.einsum(
            "bqvgk,bkvd->bqvgd", p, vc.astype(jnp.float32)
        )
        return (o, m_new, l), None

    o0 = jnp.zeros((B, qlen, Kv, G, hd), jnp.float32)
    m0 = jnp.full((B, qlen, Kv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, qlen, Kv, G), jnp.float32)
    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), jnp.arange(nkv))
    l_safe = jnp.maximum(l, 1e-30)
    return o / l_safe[..., None], m + jnp.log(l_safe)


def _flash_fwd_impl(q, k, v, valid, *, causal, window, q_offset, kv_chunk,
                    q_chunk: int = 2048):  # wide q tiles: 4x fewer KV re-reads
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Kv, G, hd)
    kp, vp, validp, nkv = _kv_padded(k, v, valid, kv_chunk)

    if Sq <= q_chunk:
        o, lse = _fwd_one_qchunk(qf, q_offset, kp, vp, validp, nkv,
                                 causal=causal, window=window, kv_chunk=kv_chunk)
        return o.reshape(B, Sq, H, hd), lse.reshape(B, Sq, H)

    qp = _pad_to(qf, q_chunk, 1)
    nq = qp.shape[1] // q_chunk
    qchunks = qp.reshape(B, nq, q_chunk, Kv, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def one(args):
        qc, idx = args
        return _fwd_one_qchunk(qc, q_offset + idx * q_chunk, kp, vp, validp, nkv,
                               causal=causal, window=window, kv_chunk=kv_chunk)

    o, lse = jax.lax.map(one, (qchunks, jnp.arange(nq)))
    o = o.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, hd)[:, :Sq]
    lse = lse.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H)[:, :Sq]
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def flash_attend(q, k, v, valid, causal: bool, window: int, q_offset: int,
                 kv_chunk: int):
    """Memory-optimal GQA attention. q:(B,Sq,H,hd), k/v:(B,Sk,Kv,hd).

    valid: optional (B, Sk) bool key mask (decode caches). Returns
    (B,Sq,H,hd) in q.dtype.
    """
    o, _ = _flash_fwd_impl(q, k, v, valid, causal=causal, window=window,
                           q_offset=q_offset, kv_chunk=kv_chunk)
    return o.astype(q.dtype)


def _fwd(q, k, v, valid, causal, window, q_offset, kv_chunk):
    o, lse = _flash_fwd_impl(q, k, v, valid, causal=causal, window=window,
                             q_offset=q_offset, kv_chunk=kv_chunk)
    return o.astype(q.dtype), (q, k, v, valid, o, lse)


def _bwd(causal, window, q_offset, kv_chunk, res, do):
    q, k, v, valid, o, lse = res
    B, Sq, H, hd = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Kv, G, hd)
    dof = do.astype(jnp.float32).reshape(B, Sq, Kv, G, hd)
    of = o.reshape(B, Sq, Kv, G, hd)
    lsef = lse.reshape(B, Sq, Kv, G)
    delta = jnp.einsum("bqvgd,bqvgd->bqvg", dof, of)

    kp, vp, validp, nkv = _kv_padded(k, v, valid, kv_chunk)
    qpos = q_offset + jnp.arange(Sq)

    def step(dq, idx):
        kc = _slice_chunk(kp, idx, kv_chunk)
        vc = _slice_chunk(vp, idx, kv_chunk)
        vm = _slice_chunk(validp, idx, kv_chunk)
        kpos = idx * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqvgd,bkvd->bqvgk", qf, kc.astype(jnp.float32))
        s = s + _chunk_mask(qpos, kpos, causal, window)[None, :, None, None, :]
        s = jnp.where(vm[:, None, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lsef[..., None])  # recomputed probs
        dv_c = jnp.einsum("bqvgk,bqvgd->bkvd", p, dof)
        dp = jnp.einsum("bqvgd,bkvd->bqvgk", dof, vc.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        dq = dq + jnp.einsum("bqvgk,bkvd->bqvgd", ds, kc.astype(jnp.float32)) * scale
        dk_c = jnp.einsum("bqvgk,bqvgd->bkvd", ds, qf)  # qf pre-scaled
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((B, Sq, Kv, G, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, jnp.arange(nkv))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, nkv * kv_chunk, Kv, hd)[:, :Sk]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, nkv * kv_chunk, Kv, hd)[:, :Sk]
    return (dq.reshape(B, Sq, H, hd).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype), None)


flash_attend.defvjp(_fwd, _bwd)
