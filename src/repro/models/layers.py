"""Shared neural-net building blocks (pure JAX, functional params-as-dicts).

Conventions:
* every ``init_*`` returns a nested dict of fp32 arrays;
* linear weights are stored ``(in_features, out_features)``;
* leaf names ('wq', 'wi', 'emb', ...) are the contract with
  ``repro.sharding.rules`` — rename only in lockstep.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0):
    """LeCun-normal fan-in init."""
    fan_in = shape[in_axis]
    return jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)


def embed_init(key, shape, scale: float = 1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def init_norm(cfg) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = (x * x).mean(-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dt)


def init_groupnorm(num_groups: int, dim: int) -> dict:
    del num_groups  # static; passed to apply_groupnorm
    return {"scale": jnp.ones((dim,), jnp.float32)}


def apply_groupnorm(p: dict, x: jax.Array, g: int, eps: float = 1e-5) -> jax.Array:
    """GroupNorm over the last dim (used by xLSTM heads)."""
    dt = x.dtype
    shp = x.shape
    x = x.astype(jnp.float32).reshape(*shp[:-1], g, shp[-1] // g)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = ((x - mu) * jax.lax.rsqrt(var + eps)).reshape(shp)
    return (y * p["scale"]).astype(dt)


# --------------------------------------------------------------------------
# linear / mlp
# --------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, bias: bool = False) -> dict:
    p = {"w": dense_init(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def apply_linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


def init_mlp(key, cfg) -> dict:
    kws = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    p = {"wi": dense_init(kws[0], (d, ff)), "wd": dense_init(kws[1], (ff, d))}
    if cfg.gated_mlp:
        p["wg"] = dense_init(kws[2], (d, ff))
    return p


def apply_mlp(p: dict, cfg, x: jax.Array) -> jax.Array:
    h = x @ p["wi"].astype(x.dtype)
    if "wg" in p:
        h = _act(cfg.act, x @ p["wg"].astype(x.dtype)) * h
    else:
        h = _act(cfg.act, h)
    return h @ p["wd"].astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,T,hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (...,T,1,hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (length, dim)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(-math.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = pos * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int) -> dict:
    return {"emb": embed_init(key, (vocab, d), scale=0.02)}


def embed(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["emb"], tokens, axis=0).astype(dtype)


def chunked_ce_loss(
    hidden: jax.Array,  # (B, S, d)
    head_w: jax.Array,  # (d, V)
    targets: jax.Array,  # (B, S) int32
    mask: Optional[jax.Array] = None,  # (B, S)
    chunk: int = 512,
) -> jax.Array:
    """Cross-entropy without materialising (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits live only inside one
    (rematerialised) scan step — the memory bound is (B, chunk, V).
    """
    B, S, d = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = hidden.shape[1] // chunk
    hidden = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    targets = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    mask = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def step(carry, xs):
        h, t, m = xs
        logits = (h @ head_w.astype(h.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)), (hidden, targets, mask))
    return tot / jnp.maximum(cnt, 1.0)
