"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block: pre-norm -> two branches:
  (a) linear -> causal depthwise conv(4) -> RG-LRU recurrence
  (b) linear -> GeLU gate
merged a*b -> output projection.

Recurrence (per channel):
  r_t = sigmoid(x_t @ W_r + b_r)            recurrence gate
  i_t = sigmoid(x_t @ W_i + b_i)            input gate
  log a_t = -c * softplus(L) * r_t          (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill use ``jax.lax.associative_scan`` (parallel, O(log T)
depth); decode is a single fused step with carried state ``(h, conv
tail)`` — constant memory, which is what qualifies this arch for the
``long_500k`` cell.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

_C = 8.0
_CONV_W = 4


class RGLRUState(NamedTuple):
    h: jax.Array  # (B, width) fp32 recurrent state
    conv: jax.Array  # (B, _CONV_W - 1, width) conv tail


def init_rglru(key, cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    lam_init = jax.random.uniform(ks[5], (w,), minval=0.9, maxval=0.999)
    return {
        "wx": layers.dense_init(ks[0], (d, w)),
        "wgate": layers.dense_init(ks[1], (d, w)),
        "wo": layers.dense_init(ks[2], (w, d)),
        "conv_w": layers.dense_init(ks[3], (_CONV_W, w)) * 0.1,
        "wr": layers.dense_init(ks[4], (w, 2 * w)),  # fused r|i gates
        "br": jnp.zeros((2 * w,), jnp.float32),
        # parametrise L so that softplus(L) > 0; init near `lam`
        "lam": jnp.log(jnp.exp(-jnp.log(lam_init) / _C) - 1.0),
    }


def init_rglru_state(cfg, batch: int, dtype) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, _CONV_W - 1, w), dtype),
    )


def _conv_causal(x: jax.Array, w: jax.Array, tail: jax.Array) -> jax.Array:
    """Depthwise causal conv width 4 via shifted adds.

    x: (B, T, w); tail: (B, 3, w) inputs preceding x.
    """
    full = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, T+3, w)
    T = x.shape[1]
    out = sum(
        full[:, (_CONV_W - 1 - i) : (_CONV_W - 1 - i) + T] * w[i].astype(x.dtype)
        for i in range(_CONV_W)
    )
    return out


def _gates(p, xb):
    """xb: (B, T, w) conv output -> (log_a, gated_input) both fp32."""
    ri = (xb @ p["wr"].astype(xb.dtype) + p["br"].astype(xb.dtype)).astype(jnp.float32)
    r, i = jnp.split(jax.nn.sigmoid(ri), 2, axis=-1)
    log_a = -_C * jax.nn.softplus(p["lam"]) * r  # (B,T,w) <= 0
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * xb.astype(jnp.float32))
    return log_a, gated


def rglru_seq(p: dict, cfg, x: jax.Array, state: RGLRUState) -> Tuple[jax.Array, RGLRUState]:
    """Full-sequence branch (train/prefill). x: (B, T, d)."""
    dt = x.dtype
    xb = x @ p["wx"].astype(dt)
    gate = jax.nn.gelu(x @ p["wgate"].astype(dt))
    xb = _conv_causal(xb, p["conv_w"], state.conv)
    log_a, gated = _gates(p, xb)

    # h_t = a_t h_{t-1} + b_t  via associative scan over (log_a, b)
    b = gated
    # incorporate initial state as a virtual step 0
    def combine(c1, c2):
        la1, b1 = c1
        la2, b2 = c2
        return la1 + la2, jnp.exp(la2) * b1 + b2

    la0 = jnp.zeros_like(log_a[:, :1])
    b0 = state.h[:, None, :]
    las = jnp.concatenate([la0, log_a], axis=1)
    bs = jnp.concatenate([b0, b], axis=1)
    _, hs = jax.lax.associative_scan(combine, (las, bs), axis=1)
    hs = hs[:, 1:]  # (B,T,w) fp32

    new_state = RGLRUState(
        h=hs[:, -1],
        conv=jnp.concatenate([state.conv.astype(dt), (x @ p["wx"].astype(dt))], axis=1)[
            :, -(_CONV_W - 1) :
        ],
    )
    y = (hs.astype(dt) * gate) @ p["wo"].astype(dt)
    return y, new_state


def rglru_step(p: dict, cfg, x: jax.Array, state: RGLRUState) -> Tuple[jax.Array, RGLRUState]:
    """Single decode step. x: (B, 1, d)."""
    dt = x.dtype
    xb_raw = x @ p["wx"].astype(dt)  # (B,1,w)
    gate = jax.nn.gelu(x @ p["wgate"].astype(dt))
    xb = _conv_causal(xb_raw, p["conv_w"], state.conv)
    log_a, gated = _gates(p, xb)
    h = jnp.exp(log_a[:, 0]) * state.h + gated[:, 0]
    new_state = RGLRUState(
        h=h,
        conv=jnp.concatenate([state.conv.astype(dt), xb_raw], axis=1)[:, 1:],
    )
    y = (h[:, None, :].astype(dt) * gate) @ p["wo"].astype(dt)
    return y, new_state
