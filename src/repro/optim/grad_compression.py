"""Error-feedback int8 gradient compression for cross-pod reduction.

At 1000+ node scale the inter-pod links (DCN) are the scarcest
bandwidth; compressing the *pod-axis* gradient all-reduce 4x (fp32 ->
int8 + per-tensor scale) with error feedback keeps convergence intact
(residual is re-added next step).

Usage (manual-collectives training variant, see train/loop.py):

    q, scale, new_err = compress(g + err)
    q_sum = jax.lax.psum(q.astype(jnp.int32), 'pod')     # cheap link
    g_avg = decompress(q_sum, scale_psum) / pod_size
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress(x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x fp32 -> (int8 q, scalar scale, residual error). x ~ q * scale."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    err = x - q.astype(jnp.float32) * scale
    return q, scale, err


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, err_tree):
    """Tree-wise error-feedback compression. Returns (q_tree, scales, errs)."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(err_tree) if err_tree is not None else [0.0] * len(leaves)
    qs, scales, new_errs = [], [], []
    for g, e in zip(leaves, errs):
        q, s, ne = compress(g.astype(jnp.float32) + e)
        qs.append(q)
        scales.append(s)
        new_errs.append(ne)
    return (
        jax.tree.unflatten(treedef, qs),
        jax.tree.unflatten(treedef, scales),
        jax.tree.unflatten(treedef, new_errs),
    )


def decompress_tree(q_tree, scale_tree):
    return jax.tree.map(lambda q, s: decompress(q, s), q_tree, scale_tree)


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
