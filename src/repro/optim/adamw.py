"""Sharded AdamW with decoupled weight decay and global-norm clipping.

Optimizer states are plain pytrees mirroring the params, so they inherit
the parameter PartitionSpecs (ZeRO-style: FSDP-sharded params => sharded
m/v, no replication anywhere).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jax.Array


def init_adamw(params, state_dtype=jnp.float32) -> AdamWState:
    """state_dtype=bf16 halves m/v memory (the 314B-on-one-pod enabler);
    the update still runs in fp32 (cast on read, round on write)."""
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=state_dtype), params)
    return AdamWState(m=zeros, v=jax.tree.map(jnp.copy, zeros), count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Tuple[dict, AdamWState, jax.Array]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        sdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = weight_decay if p.ndim >= 2 else 0.0
        p2 = p.astype(jnp.float32) - lr * (step + wd * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2.astype(sdt), v2.astype(sdt)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v, count=count), gnorm
