"""Metrics registry: labeled counters / gauges / histograms, thread-safe.

Everything is host-side Python (no tracers, no device sync) so recording
from inside a traced function body is safe — it simply counts *traces*,
which is exactly the semantics the serving zero-retrace probe relies on.

Metric names are dotted (``msda.plan_cache.hits``); labels are kwargs
(``counter.inc(direction="fwd")``).  Each (name, label-set) pair is one
independent series.  ``Registry.snapshot()`` returns plain dicts,
``Registry.reset()`` zeroes everything (or a name prefix), and
``Registry.scope()`` yields a delta view — the mechanism behind
``aot.Probe`` and the elastic restore's autotune-delta asserts.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Iterator, List, Optional, Tuple

# histograms keep a bounded window of raw observations for percentiles;
# count/sum/min/max stay exact over the full lifetime
_HIST_WINDOW = 1024

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def render_series(name: str, key: LabelKey) -> str:
    """``name{a="1",b="x"}`` — the flat-map series id snapshots use."""
    if not key:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return f"{name}{{{inner}}}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = "", *,
                 lock: Optional[threading.RLock] = None):
        self.name = name
        self.help = help
        self._lock = lock or threading.RLock()
        self._series: Dict[LabelKey, Any] = {}

    def labels(self) -> List[LabelKey]:
        with self._lock:
            return list(self._series)

    def reset(self) -> None:
        with self._lock:
            self._series.clear()


class Counter(_Metric):
    """Monotonic (between resets) float counter."""

    kind = "counter"

    def inc(self, n: float = 1.0, **labels: Any) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + n

    def value(self, **labels: Any) -> float:
        with self._lock:
            return float(self._series.get(_label_key(labels), 0.0))

    def total(self) -> float:
        """Sum over every label-set series."""
        with self._lock:
            return float(sum(self._series.values()))

    def values(self) -> Dict[str, float]:
        with self._lock:
            return {render_series(self.name, k): float(v)
                    for k, v in self._series.items()}


class Gauge(_Metric):
    """Last-write-wins value (VMEM occupancy, queue depth, ...)."""

    kind = "gauge"

    def set(self, v: float, **labels: Any) -> None:
        with self._lock:
            self._series[_label_key(labels)] = float(v)

    def value(self, **labels: Any) -> float:
        with self._lock:
            return float(self._series.get(_label_key(labels), 0.0))

    def values(self) -> Dict[str, float]:
        with self._lock:
            return {render_series(self.name, k): float(v)
                    for k, v in self._series.items()}


class Histogram(_Metric):
    """Streaming summary: exact count/sum/min/max + windowed p50."""

    kind = "histogram"

    def observe(self, v: float, **labels: Any) -> None:
        v = float(v)
        key = _label_key(labels)
        with self._lock:
            row = self._series.get(key)
            if row is None:
                row = {"count": 0, "sum": 0.0, "min": v, "max": v,
                       "window": []}
                self._series[key] = row
            row["count"] += 1
            row["sum"] += v
            row["min"] = min(row["min"], v)
            row["max"] = max(row["max"], v)
            row["window"].append(v)
            if len(row["window"]) > _HIST_WINDOW:
                del row["window"][: len(row["window"]) - _HIST_WINDOW]

    def summary(self, **labels: Any) -> Optional[Dict[str, float]]:
        with self._lock:
            row = self._series.get(_label_key(labels))
            if row is None:
                return None
            return self._summ(row)

    @staticmethod
    def _summ(row: Dict[str, Any]) -> Dict[str, float]:
        w = sorted(row["window"])
        return {"count": float(row["count"]), "sum": row["sum"],
                "min": row["min"], "max": row["max"],
                "mean": row["sum"] / row["count"] if row["count"] else 0.0,
                "p50": w[len(w) // 2] if w else 0.0}

    def values(self) -> Dict[str, Dict[str, float]]:
        with self._lock:
            return {render_series(self.name, k): self._summ(row)
                    for k, row in self._series.items()}


class EventWindow:
    """A bounded ring of raw observations with EXACT aggregate stats.

    The pattern :class:`Histogram` uses internally, packaged for any
    long-lived recorder that must not grow without bound (serving
    latency samples, training step times): raw items are kept only for
    the last ``window`` observations (percentiles, trajectories), while
    ``count`` / ``total`` / ``max`` stay exact over the full lifetime —
    so summary shapes built on top of it are unchanged except that p50
    becomes windowed (mean and max remain exact).
    """

    def __init__(self, window: int = _HIST_WINDOW):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = int(window)
        self._items: List[Any] = []
        self.count = 0

    def append(self, item: Any) -> None:
        self.count += 1
        self._items.append(item)
        if len(self._items) > self.window:
            del self._items[: len(self._items) - self.window]

    def items(self) -> List[Any]:
        """The windowed raw items (newest last)."""
        return list(self._items)

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    def __bool__(self) -> bool:
        return self.count > 0


class NumericWindow(EventWindow):
    """:class:`EventWindow` over floats with exact total/max running."""

    def __init__(self, window: int = _HIST_WINDOW):
        super().__init__(window)
        self.total = 0.0
        self.max = 0.0

    def append(self, item: float) -> None:  # type: ignore[override]
        v = float(item)
        super().append(v)
        self.total += v
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    @property
    def p50(self) -> float:
        w = sorted(self._items)
        return w[len(w) // 2] if w else 0.0


class Scope:
    """Delta view over a registry's counters/histograms since entry."""

    def __init__(self, registry: "Registry"):
        self._registry = registry
        self._base = registry.flat_counters()
        self._base_hist = registry.flat_hist_counts()

    def deltas(self) -> Dict[str, float]:
        """Counter series deltas since the scope opened (non-zero only)."""
        cur = self._registry.flat_counters()
        out = {}
        for series, v in cur.items():
            d = v - self._base.get(series, 0.0)
            if d:
                out[series] = d
        return out

    def hist_deltas(self) -> Dict[str, float]:
        """Histogram observation-count deltas since the scope opened."""
        cur = self._registry.flat_hist_counts()
        out = {}
        for series, v in cur.items():
            d = v - self._base_hist.get(series, 0.0)
            if d:
                out[series] = d
        return out


class Registry:
    """Get-or-create metric store; one process-wide default below."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, lock=self._lock)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    # -- views -------------------------------------------------------------
    def flat_counters(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for m in self.metrics():
            if isinstance(m, Counter):
                out.update(m.values())
        return out

    def flat_hist_counts(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for m in self.metrics():
            if isinstance(m, Histogram):
                for series, summ in m.values().items():
                    out[series] = summ["count"]
        return out

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view: ``{"counters", "gauges", "histograms"}``."""
        out: Dict[str, Any] = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in self.metrics():
            if isinstance(m, Counter):
                out["counters"].update(m.values())
            elif isinstance(m, Gauge):
                out["gauges"].update(m.values())
            elif isinstance(m, Histogram):
                out["histograms"].update(m.values())
        return out

    def reset(self, prefix: str = "") -> None:
        """Zero every metric (or only names under ``prefix``)."""
        for m in self.metrics():
            if not prefix or m.name == prefix or m.name.startswith(prefix + "."):
                m.reset()

    def scope(self) -> Iterator[Scope]:
        import contextlib

        @contextlib.contextmanager
        def _cm():
            yield Scope(self)

        return _cm()


REGISTRY = Registry()


def counter(name: str, help: str = "") -> Counter:
    return REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "") -> Histogram:
    return REGISTRY.histogram(name, help)


def snapshot() -> Dict[str, Any]:
    return REGISTRY.snapshot()


def reset(prefix: str = "") -> None:
    REGISTRY.reset(prefix)


def scope():
    return REGISTRY.scope()
