"""Registry exporters: Prometheus exposition text + JSON.

``write_metrics(path)`` picks the format by extension — ``.json`` gets
the structured :meth:`Registry.snapshot` payload, anything else the
Prometheus text format (one scrape-able page; histograms exported as
``_count`` / ``_sum`` / ``_min`` / ``_max`` / ``_p50`` series).  Dots in
metric names become underscores for Prometheus (``msda.plan_cache.hits``
-> ``msda_plan_cache_hits``); the JSON view keeps dotted names verbatim.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional

from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    Registry,
    REGISTRY,
)


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _prom_series(series: str) -> str:
    # series ids are rendered as name{k="v"}; only the name needs mangling
    if "{" in series:
        name, rest = series.split("{", 1)
        return _prom_name(name) + "{" + rest
    return _prom_name(series)


def prometheus_text(registry: Optional[Registry] = None) -> str:
    """The whole registry in Prometheus exposition format."""
    reg = registry or REGISTRY
    lines = []
    for m in reg.metrics():
        pname = _prom_name(m.name)
        if m.help:
            lines.append(f"# HELP {pname} {m.help}")
        if isinstance(m, (Counter, Gauge)):
            lines.append(f"# TYPE {pname} {m.kind}")
            for series, v in m.values().items():
                lines.append(f"{_prom_series(series)} {v:g}")
        elif isinstance(m, Histogram):
            lines.append(f"# TYPE {pname} summary")
            for series, summ in m.values().items():
                for stat in ("count", "sum", "min", "max", "p50"):
                    lines.append(
                        f"{_prom_series(series)}_{stat} {summ[stat]:g}"
                        if "{" not in series else
                        _suffix_labeled(_prom_series(series), stat, summ[stat]))
    return "\n".join(lines) + "\n"


def _suffix_labeled(series: str, stat: str, v: float) -> str:
    # name{labels} -> name_stat{labels} value
    name, rest = series.split("{", 1)
    return f"{name}_{stat}{{{rest} {v:g}"


def metrics_json(registry: Optional[Registry] = None) -> Dict[str, Any]:
    reg = registry or REGISTRY
    return {"created_unix": time.time(), **reg.snapshot()}


def write_metrics(path: str, registry: Optional[Registry] = None) -> str:
    """Dump the registry to ``path``; format chosen by extension."""
    path = str(path)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        if path.endswith(".json"):
            json.dump(metrics_json(registry), f, indent=1, sort_keys=True)
        else:
            f.write(prometheus_text(registry))
    os.replace(tmp, path)
    return path
