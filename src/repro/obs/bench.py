"""The one writer behind every ``BENCH_*.json`` trajectory file.

Common schema (``"schema": 1``) shared by ``BENCH_kernels.json``,
``BENCH_sparsity.json`` and ``BENCH_train.json``:

    {
      "bench":        str,      # benchmark id ("fused_vs_per_level", ...)
      "schema":       1,
      "config":       {...},    # geometry / run config the numbers depend on
      "note":         str,
      "results":      {...},    # numeric leaves — what bench_gate diffs
      "trajectory":   [...],    # optional per-step / per-point series
      "events":       [...],    # optional discrete-event log
      "gate":         [...],    # optional regression-gate rules (below)
      "history":      [...],    # appended by `bench_gate --update`
      "created_unix": float,
    }

``gate`` tells ``tools/bench_gate.py`` which ``results`` leaves are
comparable across machines and in which direction:

    {"pattern": "*.launches_per_call", "direction": "lower", "tolerance": 0.0}

``pattern`` is an fnmatch over the flattened dotted result key,
``direction`` is ``"lower"`` or ``"higher"`` (which way is better), and
``tolerance`` is the relative slack before a worse value counts as a
regression (0.0 = structural, must not move at all).  Leaves matched by
no rule are informational only — raw timings from different machines
never gate the build unless a rule says so.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1


def repo_root() -> str:
    """The checkout root (this file lives at src/repro/obs/bench.py)."""
    here = os.path.abspath(__file__)
    return os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(here))))


def bench_path(name: str) -> str:
    """Canonical root-level path for trajectory ``name`` ("kernels", ...)."""
    return os.path.join(repo_root(), f"BENCH_{name}.json")


def gate_rule(pattern: str, direction: str, tolerance: float) -> Dict[str, Any]:
    if direction not in ("lower", "higher"):
        raise ValueError(f"direction {direction!r}: use 'lower' or 'higher'")
    return {"pattern": str(pattern), "direction": direction,
            "tolerance": float(tolerance)}


def write_bench(
    path: str,
    *,
    bench: str,
    results: Dict[str, Any],
    config: Optional[Dict[str, Any]] = None,
    note: str = "",
    trajectory: Optional[List[Any]] = None,
    events: Optional[List[Any]] = None,
    gate: Optional[List[Dict[str, Any]]] = None,
    created_unix: Optional[float] = None,
    extra: Optional[Dict[str, Any]] = None,
) -> str:
    """Atomic (tmp + rename) dump of one trajectory payload."""
    payload: Dict[str, Any] = {
        "bench": str(bench),
        "schema": SCHEMA_VERSION,
        "config": dict(config or {}),
        "note": str(note),
        "results": results,
        "created_unix": (time.time() if created_unix is None
                         else float(created_unix)),
    }
    if trajectory is not None:
        payload["trajectory"] = list(trajectory)
    if events is not None:
        payload["events"] = list(events)
    if gate is not None:
        payload["gate"] = list(gate)
    if extra:
        for k, v in extra.items():
            payload.setdefault(k, v)
    path = str(path)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def read_bench(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)
