"""Process-wide observability: metrics registry, structured spans, exporters.

One substrate for every instrumenter in the repo.  The four historical
counters (``serving/metrics.py``, ``training/telemetry.py``,
``kernels/plan.py:autotune_stats``, ``serving/aot.py:probe``) all back
onto :data:`REGISTRY` while keeping their original public APIs; the
launchers export the registry via ``--metrics-out`` (Prometheus text or
JSON, by extension) and stream spans via ``--trace-out`` (JSONL).

    from repro import obs

    calls = obs.counter("msda.plan_calls", help="plan invocations")
    calls.inc(backend="pallas")

    with obs.span("autotune.race", level=3, backend="pallas"):
        ...  # nested spans land in the JSONL trace + XLA profile

    obs.write_metrics("metrics.prom")        # Prometheus exposition text
    obs.write_metrics("metrics.json")        # same registry, JSON

``obs.bench.write_bench`` is the one writer behind every
``BENCH_*.json`` trajectory file (see ``docs/observability.md`` for the
schema and the ``tools/bench_gate.py`` regression contract).
"""
from __future__ import annotations

from repro.obs.registry import (  # noqa: F401
    Counter,
    EventWindow,
    Gauge,
    Histogram,
    NumericWindow,
    Registry,
    REGISTRY,
    counter,
    gauge,
    histogram,
    reset,
    scope,
    snapshot,
)
from repro.obs.trace import (  # noqa: F401
    disable_trace,
    enable_trace,
    set_trace_level,
    span,
    trace_path,
    traced_span,
)
from repro.obs.export import (  # noqa: F401
    metrics_json,
    prometheus_text,
    write_metrics,
)
from repro.obs import bench  # noqa: F401
