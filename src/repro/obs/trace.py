"""Nested structured spans with a JSONL exporter + XLA profile pass-through.

    with obs.span("autotune.race", level=3, backend="pallas") as sp:
        ...
        sp["candidates"] = 7          # attrs may be added inside the span

Spans nest per-thread: each record carries its slash-joined ``path``
(``plan.build/autotune.race``) and depth, so the JSONL trace reconstructs
the call tree without ids.  Every span also feeds a registry histogram
(``span.<name>``), so durations show up in ``--metrics-out`` even when
no trace sink is enabled.

``level`` is verbosity (1 = coarse lifecycle, 4 = per-step): spans above
the sink's threshold (default 3) are still timed into the histogram but
not written to the JSONL file.  When ``jax.profiler.TraceAnnotation`` is
available, every span body also runs under an annotation of the same
name, so spans land in XLA profiles too.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

from repro.obs import registry as _registry

try:  # pass-through to XLA profiles when jax is importable
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax is a hard dep everywhere else
    _TraceAnnotation = None

_DEFAULT_LEVEL = 3

_lock = threading.Lock()
_sink = None  # open file handle for the JSONL trace, or None
_sink_path: Optional[str] = None
_sink_level = _DEFAULT_LEVEL
_tls = threading.local()


def enable_trace(path: str, level: int = _DEFAULT_LEVEL) -> str:
    """Open ``path`` (append) as the process-wide JSONL span sink."""
    global _sink, _sink_path, _sink_level
    path = str(path)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with _lock:
        if _sink is not None:
            _sink.close()
        _sink = open(path, "a")
        _sink_path = path
        _sink_level = int(level)
    return path


def disable_trace() -> None:
    global _sink, _sink_path
    with _lock:
        if _sink is not None:
            _sink.close()
        _sink = None
        _sink_path = None


def trace_path() -> Optional[str]:
    return _sink_path


def set_trace_level(level: int) -> None:
    """Spans with ``level`` above this are timed but not exported."""
    global _sink_level
    _sink_level = int(level)


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _emit(record: Dict[str, Any]) -> None:
    with _lock:
        if _sink is None:
            return
        _sink.write(json.dumps(record, sort_keys=True) + "\n")
        _sink.flush()


@contextlib.contextmanager
def span(name: str, level: int = 2, **attrs: Any) -> Iterator[Dict[str, Any]]:
    """Time a block as a named span; yields the (mutable) attrs dict."""
    st = _stack()
    path = "/".join([s for s in st] + [name])
    st.append(name)
    t0_unix = time.time()
    t0 = time.perf_counter()
    ann = _TraceAnnotation(name) if _TraceAnnotation is not None else None
    if ann is not None:
        ann.__enter__()
    try:
        yield attrs
    finally:
        if ann is not None:
            ann.__exit__(None, None, None)
        st.pop()
        dur = time.perf_counter() - t0
        _registry.histogram(f"span.{name}").observe(dur)
        if _sink is not None and level <= _sink_level:
            _emit({
                "name": name, "path": path, "depth": len(st),
                "level": int(level), "t0_unix": t0_unix, "dur_s": dur,
                "attrs": {k: _jsonable(v) for k, v in attrs.items()},
                "thread": threading.current_thread().name,
            })


def traced_span(name: str, level: int = 2) -> Callable:
    """Decorator form: run the whole function under :func:`span`."""

    def deco(fn: Callable) -> Callable:
        def wrapper(*args, **kwargs):
            with span(name, level=level):
                return fn(*args, **kwargs)

        wrapper.__name__ = getattr(fn, "__name__", name)
        wrapper.__doc__ = fn.__doc__
        wrapper.__wrapped__ = fn
        return wrapper

    return deco


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)
