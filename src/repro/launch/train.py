"""Training driver: mesh setup, elastic plans, fault-tolerant loop.

CPU-scale usage (reduced config, real optimization):
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

The loop itself lives in :class:`repro.training.TrainingHarness`:
checkpointed restart, deterministic fault injection (``--faults
host_loss@20,corrupt_ckpt@35`` or a seeded ``--fault-seed`` schedule),
and step-time telemetry (``--bench-out BENCH_train.json``).  ``--mesh
DPxTP`` + ``--plan-store`` restore MSDA plans elastically: a store
written on a different topology re-races only the mesh-keyed autotune
axes and persists the new winners (``repro.training.elastic``).

``--train-smoke`` is the CI entry point: a short DETR run under the
4-virtual-device host that injects one mid-step preemption, kills and
resumes the loop, asserts bitwise loss continuity + elastic re-race
behaviour, and writes ``BENCH_train.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch.mesh import make_mesh_2d, parse_mesh_shape
from repro.train import loop as train_loop
from repro.train import state as train_state
from repro.training import (
    FaultSchedule, HarnessConfig, StepTimeRecorder, TrainingHarness,
    recover_plans)


def _mesh_from_arg(token: str):
    shape = parse_mesh_shape(token)
    return None if shape is None else make_mesh_2d(*shape)


def _data_config(cfg, args) -> DataConfig:
    if cfg.family == "vision":
        return DataConfig(
            global_batch=args.batch, seq_len=args.seq,
            vocab_size=cfg.vocab_size, seed=args.seed, source="detection",
            levels=tuple(cfg.msda.levels), feat_dim=cfg.d_model)
    return DataConfig(
        global_batch=args.batch, seq_len=args.seq, vocab_size=cfg.vocab_size,
        seed=args.seed, source=args.data, path=args.data_path)


def _tokens_per_step(cfg, args) -> int:
    if cfg.family == "vision":
        # detection: encoder pixel-queries processed per step
        return args.batch * sum(h * w for h, w in cfg.msda.levels)
    return args.batch * args.seq


def _warm_plans(cfg, mesh, recorder, plan_store: str) -> None:
    """Commit MSDA plans before the first trace; elastic via the store."""
    if cfg.msda is None:
        return
    from repro.core import deformable_transformer as dt
    from repro.kernels import plan as plan_mod
    from repro.serving.persistence import PlanStore

    if plan_store:
        rep = recover_plans(plan_store, mesh=mesh)
        for line in rep.reraced:
            print(f"[train] elastic re-race: {line}")
            recorder.record_event("replan", step=0, latency_s=rep.recovery_s,
                                  detail=line)
        for line in rep.skipped:
            print(f"[train] plan store skipped: {line}")
    plans = dt.msda_plans(cfg, dtype=cfg.dtype, train=True, mesh=mesh)
    for name, plan in plans.items():
        print(f"[train] msda plan ({name}):\n{plan.describe()}")
    if plan_store:
        n = PlanStore(plan_store).save_plans(
            list(plans.values()),
            meta={"writer": "launch.train",
                  "mesh": None if mesh is None else plan_mod.mesh_token(mesh)})
        print(f"[train] plan store: persisted {n} plans -> {plan_store}")


def _build_harness(cfg, args, mesh, recorder, faults=None,
                   ckpt_dir=None, total_steps=None) -> TrainingHarness:
    pipe = Pipeline(_data_config(cfg, args))
    steps = total_steps if total_steps is not None else args.steps
    step_fn = jax.jit(
        train_loop.make_train_step(
            cfg, num_microbatches=args.microbatches, peak_lr=args.lr,
            warmup_steps=max(steps // 10, 1), total_steps=steps,
        ),
        donate_argnums=(0,),
    )

    def batch_fn(step: int):
        return {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}

    def init_fn():
        return train_state.init_state(jax.random.PRNGKey(args.seed), cfg)

    hcfg = HarnessConfig(
        total_steps=steps, ckpt_every=args.ckpt_every,
        ckpt_dir=ckpt_dir if ckpt_dir is not None else args.ckpt_dir,
        keep_last=args.keep_last, max_restarts=args.max_restarts)
    return TrainingHarness(step_fn=step_fn, batch_fn=batch_fn,
                           init_fn=init_fn, config=hcfg, faults=faults,
                           telemetry=recorder)


def _parse_faults(args) -> "FaultSchedule | None":
    if args.faults:
        return FaultSchedule.from_spec(args.faults)
    if args.fault_seed is not None:
        return FaultSchedule.generate(args.fault_seed, args.steps,
                                      n_faults=args.fault_count)
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deformable-detr")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--keep-last", type=int, default=3)
    ap.add_argument("--max-restarts", type=int, default=8)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="1", help="'1' (no mesh) or DPxTP")
    ap.add_argument("--plan-store", default=None,
                    help="elastic MSDA plan store (restored + persisted)")
    ap.add_argument("--faults", default=None,
                    help="deterministic schedule, e.g. 'host_loss@20,preempt@35'")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="seeded random fault schedule")
    ap.add_argument("--fault-count", type=int, default=2)
    ap.add_argument("--bench-out", default=None,
                    help="write BENCH_train.json telemetry here")
    ap.add_argument("--train-smoke", action="store_true",
                    help="self-asserting CI smoke (see module docstring)")
    ap.add_argument("--trace-out", default=None,
                    help="stream obs spans (plan builds, autotune races, "
                         "recoveries, per-step timings) to this JSONL file")
    ap.add_argument("--trace-level", type=int, default=3,
                    help="span verbosity exported to --trace-out (1-4; "
                         "4 adds per-step spans)")
    ap.add_argument("--metrics-out", default=None,
                    help="dump the obs metrics registry at exit "
                         "(.json -> JSON, else Prometheus text)")
    args = ap.parse_args()

    from repro import obs

    if args.trace_out:
        obs.enable_trace(args.trace_out, level=args.trace_level)

    def _export() -> None:
        if args.metrics_out:
            print(f"[train] metrics -> {obs.write_metrics(args.metrics_out)}")
        if args.trace_out:
            obs.disable_trace()
            print(f"[train] trace -> {args.trace_out}")

    if args.train_smoke:
        try:
            train_smoke(args)
        finally:
            _export()
        return

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    mesh = _mesh_from_arg(args.mesh)
    recorder = StepTimeRecorder(
        tokens_per_step=_tokens_per_step(cfg, args),
        config={"arch": args.arch, "smoke": bool(args.smoke),
                "steps": args.steps, "batch": args.batch,
                "mesh": args.mesh, "seed": args.seed})
    _warm_plans(cfg, mesh, recorder, args.plan_store)
    harness = _build_harness(cfg, args, mesh, recorder,
                             faults=_parse_faults(args))
    t0 = time.time()
    out = harness.run()
    dt_s = time.time() - t0
    for rec in out["recovery_log"]:
        print(f"[train] recovered: {rec['kind']} at step {rec['failed_step']} "
              f"-> resumed from {rec['resumed_from']}"
              + (f" (skipped corrupt ckpts {rec['ckpt_skipped']})"
                 if rec["ckpt_skipped"] else ""))
    losses = out["losses"]
    if losses:
        first, last = min(losses), max(losses)
        if first > 0:
            print(f"[train] restored step {first}")
        print(f"[train] loss {losses[first]:.4f} -> {losses[last]:.4f} "
              f"over {out['final_step']} steps "
              f"({out['restarts']} restarts, {dt_s:.1f}s)")
    print(f"[train] done: {out['final_step']} steps")
    if args.bench_out:
        path = recorder.write(args.bench_out)
        print(f"[train] wrote telemetry -> {path}")
    _export()


# --------------------------------------------------------------------------
# CI train-smoke: kill-and-resume + elastic re-race, self-asserting
# --------------------------------------------------------------------------


def train_smoke(args) -> None:
    """Short DETR run proving the whole recovery story on 4 CPU devices.

    Legs (each asserts; any failure exits non-zero for CI):
      1. reference run — uninterrupted, records the loss trajectory;
      2. faulted run — one injected mid-step preemption; must recover
         and reproduce the reference losses BITWISE;
      3. kill-and-resume — the loop is stopped at step k and a fresh
         harness (fresh process, simulated) resumes from the checkpoint;
         continuation losses must equal the reference bitwise;
      4. elastic re-race — an autotuned plan store built on a 2x2 mesh
         restores onto 1x4: only the mesh-keyed axes re-race
         (raced_local == 0), new winners persist, and a second 1x4
         restore does ZERO timing runs.
    Writes the faulted run's ``BENCH_train.json`` trajectory.
    """
    cfg = reduced(get_config("deformable-detr"))
    args.steps, args.batch, args.ckpt_every = 10, 4, 3
    args.keep_last, args.max_restarts, args.microbatches = 10, 4, 1
    args.lr, args.seed = 1e-3, 0
    work = tempfile.mkdtemp(prefix="train_smoke_")
    bench_out = args.bench_out or "BENCH_train.json"

    def run(ckpt_dir, faults=None, recorder=None, total=None):
        rec = recorder or StepTimeRecorder()
        h = _build_harness(cfg, args, None, rec, faults=faults,
                           ckpt_dir=ckpt_dir, total_steps=None)
        if total is not None:
            h.config.total_steps = total
        return h.run(), rec

    # leg 1: reference trajectory
    ref, _ = run(os.path.join(work, "ref"))
    assert ref["final_step"] == args.steps and ref["restarts"] == 0
    assert len(ref["losses"]) == args.steps
    print(f"[train-smoke] reference: {args.steps} steps, "
          f"loss {ref['losses'][0]:.4f} -> {ref['losses'][args.steps - 1]:.4f}")

    # leg 2: injected mid-step preemption -> recovery + bitwise continuity
    recorder = StepTimeRecorder(
        tokens_per_step=_tokens_per_step(cfg, args),
        config={"arch": "deformable-detr", "smoke": True,
                "steps": args.steps, "batch": args.batch,
                "faults": "preempt@7"})
    faults = FaultSchedule.from_spec("preempt@7")
    faulted, recorder = run(os.path.join(work, "faulted"), faults=faults,
                            recorder=recorder)
    assert faulted["restarts"] == 1, faulted["restarts"]
    assert faulted["recovery_log"][0]["kind"] == "preempt"
    assert faulted["recovery_log"][0]["resumed_from"] == 6  # ckpt_every=3
    for s, l in ref["losses"].items():
        assert faulted["losses"][s] == l, (
            f"loss diverged at step {s}: {faulted['losses'][s]} != {l}")
    print("[train-smoke] preemption recovered; losses bitwise-identical")

    # leg 3: kill the loop at step 5, resume in a fresh harness
    kill_dir = os.path.join(work, "killed")
    half, _ = run(kill_dir, total=5)
    assert half["final_step"] == 5
    resumed, _ = run(kill_dir)  # fresh harness object = simulated restart
    assert resumed["final_step"] == args.steps
    assert min(resumed["losses"]) == 5, "resume must start at the checkpoint"
    for s in range(5, args.steps):
        assert resumed["losses"][s] == ref["losses"][s], f"diverged at {s}"
    print("[train-smoke] kill-and-resume continued bitwise from step 5")

    # leg 4: elastic plan re-race (needs the 4-device CI host)
    if len(jax.devices()) >= 4:
        from repro.kernels import plan as plan_mod
        from repro.serving.persistence import PlanStore

        os.environ.setdefault(
            "REPRO_MSDA_AUTOTUNE_CACHE", os.path.join(work, "autotune.json"))
        store_path = os.path.join(work, "plans.json")
        spec = plan_mod.MsdaSpec(
            spatial_shapes=tuple(cfg.msda.levels), num_heads=cfg.msda.num_heads,
            head_dim=cfg.d_model // cfg.msda.num_heads,
            num_points=cfg.msda.num_points,
            num_queries=sum(h * w for h, w in cfg.msda.levels),
            dtype="float32", train=True, slab_dtype="auto")
        m22, m14 = make_mesh_2d(2, 2), make_mesh_2d(1, 4)
        plan = plan_mod.msda_plan(spec, backend="cpu", tune="autotune",
                                  mesh=m22, query_parallel=True)
        PlanStore(store_path).save_plans([plan], meta={"mesh": "data2xmodel2"})
        plan_mod.clear_plans()
        plan_mod.reset_autotune_stats()
        rep = recover_plans(store_path, mesh=m14)
        assert rep.replan_count == 1 and rep.persisted, (rep.replan_count,
                                                         rep.persisted)
        assert rep.raced_local == 0, f"local axes re-raced: {rep.raced_local}"
        recorder.record_event("replan", step=0, latency_s=rep.recovery_s,
                              detail=rep.reraced[0])
        plan_mod.clear_plans()
        plan_mod.reset_autotune_stats()
        rep2 = recover_plans(store_path, mesh=m14)
        assert rep2.replan_count == 0 and rep2.raced == 0, (
            rep2.replan_count, rep2.raced)
        print(f"[train-smoke] elastic: 2x2 -> 1x4 re-raced mesh axes only "
              f"({rep.raced_mesh} races), second restore zero races")
    else:
        print("[train-smoke] <4 devices: skipping the elastic leg")

    path = recorder.write(bench_out)
    print(f"[train-smoke] OK; wrote {path}")


if __name__ == "__main__":
    main()
