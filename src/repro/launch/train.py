"""Training driver: mesh setup, sharded state, checkpoint/restart loop.

CPU-scale usage (reduced config, real optimization):
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

On a real slice the same driver runs the full config against the
production mesh (the dry-run proves those cells compile); fault
tolerance comes from the restart wrapper + deterministic data.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.runtime import fault_tolerance as ft
from repro.sharding import rules
from repro.train import loop as train_loop
from repro.train import state as train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    if cfg.msda is not None:
        # MSDA archs: commit backend + block planning before the first
        # step and surface the plan report (block_q / slabs / VMEM).
        from repro.core import deformable_transformer as dt

        for name, plan in dt.msda_plans(cfg, dtype=cfg.dtype, train=True).items():
            print(f"[train] msda plan ({name}):\n{plan.describe()}")
    dcfg = DataConfig(
        global_batch=args.batch, seq_len=args.seq, vocab_size=cfg.vocab_size,
        seed=args.seed, source=args.data, path=args.data_path,
    )
    pipe = Pipeline(dcfg)
    step_fn = jax.jit(
        train_loop.make_train_step(
            cfg, num_microbatches=args.microbatches, peak_lr=args.lr,
            warmup_steps=max(args.steps // 10, 1), total_steps=args.steps,
        ),
        donate_argnums=(0,),
    )

    state = train_state.init_state(jax.random.PRNGKey(args.seed), cfg)
    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state = ckpt.restore(args.ckpt_dir, state)
        start = int(state.step)
        print(f"[train] restored step {start} from {args.ckpt_dir}")

    t0 = time.time()
    pending_save = None
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        state, metrics = step_fn(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            print(f"[train] step {step:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                  f"({(time.time()-t0)/(step-start+1):.2f}s/step)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            pending_save = ckpt.save_async(state, args.ckpt_dir, step + 1)
    if pending_save is not None:
        pending_save.join()  # daemon writer: commit the last ckpt before exit
    print(f"[train] done: {args.steps - start} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
