import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the real
``train_step`` / ``prefill`` / ``serve_step`` with ShapeDtypeStruct
inputs (no allocation), compiles through the GSPMD partitioner, and
extracts:

* ``memory_analysis()``   — per-device bytes (proves it fits 16 GB HBM);
* ``cost_analysis()``     — HLO FLOPs / bytes for the roofline terms;
* collective bytes        — parsed from the post-SPMD HLO text
  (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute operand sizes + wire-byte estimates).

Results are merged into ``experiments/dryrun_results.json`` so the
sweep is resumable cell by cell:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ShapeConfig, get_config, list_configs, supports_shape
from repro.launch import hlo_analysis, mesh as mesh_lib
from repro.optim import adamw
from repro.sharding import rules
from repro.train import loop as train_loop
from repro.train import state as train_state

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun_results.json")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one cell as ShapeDtypeStructs (+ logical specs)."""
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    if shape.kind == "train":
        if cfg.family == "audio":
            return {
                "frames": (_sds((B, cfg.encoder.num_frames, cfg.d_model), dt), P("dp", None, None)),
                "tokens": (_sds((B, S), "int32"), P("dp", None)),
                "targets": (_sds((B, S), "int32"), P("dp", None)),
            }
        if cfg.family == "vlm":
            from repro.models import vlm as vlm_mod

            sv = vlm_mod.pyramid_len(cfg.vision)
            return {
                "pyramid": (_sds((B, sv, cfg.vision.vision_dim), dt), P("dp", None, None)),
                "tokens": (_sds((B, S), "int32"), P("dp", None)),
                "targets": (_sds((B, S), "int32"), P("dp", None)),
            }
        if cfg.family == "vision":
            sp = sum(h * w for h, w in cfg.msda.levels)
            return {
                "pyramid": (_sds((B, sp, cfg.d_model), dt), P("dp", None, None)),
                "labels": (_sds((B, 20), "int32"), P("dp", None)),
                "boxes": (_sds((B, 20, 4), "float32"), P("dp", None, None)),
            }
        return {
            "tokens": (_sds((B, S), "int32"), P("dp", None)),
            "targets": (_sds((B, S), "int32"), P("dp", None)),
        }
    if shape.kind == "prefill":
        out = {"tokens": (_sds((B, S), "int32"), P("dp", None))}
        if cfg.family == "audio":
            out["frames"] = (_sds((B, cfg.encoder.num_frames, cfg.d_model), dt), P("dp", None, None))
        if cfg.family == "vlm":
            from repro.models import vlm as vlm_mod

            sv = vlm_mod.pyramid_len(cfg.vision)
            out["pyramid"] = (_sds((B, sv, cfg.vision.vision_dim), dt), P("dp", None, None))
        return out
    if shape.kind == "decode":
        return {"token": (_sds((B,), "int32"), P("dp"))}
    raise ValueError(shape.kind)


def _resolve(mesh, logical_spec: P, shape=None) -> NamedSharding:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def nshard(ax):
        t = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            t *= sizes[a]
        return t

    axes = []
    for i, a in enumerate(logical_spec):
        phys = rules.resolve_axis(a, mesh) if isinstance(a, str) else a
        if phys is not None and shape is not None and shape[i] % nshard(phys) != 0:
            phys = None  # degrade to replicated (e.g. batch=1 long_500k)
        axes.append(phys)
    return NamedSharding(mesh, P(*axes))


# --------------------------------------------------------------------------
# cache sharding (decode/prefill cells)
# --------------------------------------------------------------------------


def cache_specs(cache_shapes, mesh, batch: int, capacity: int):
    """Generic cache sharding: batch axis -> dp, capacity axis -> model (SP).

    Works uniformly across KV caches (incl. MQA kv=1, where head-sharding
    would idle the model axis — sequence-sharding the cache is the
    scalable choice), ring buffers, recurrent states.
    """
    dp = rules.resolve_axis("dp", mesh)
    tp = rules.resolve_axis("tp", mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def nshard(ax):
        if ax is None:
            return 1
        t = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            t *= sizes[a]
        return t

    def one(leaf):
        spec = [None] * leaf.ndim
        used_b = used_c = False
        for i, dim in enumerate(leaf.shape):
            if not used_b and dim == batch and dim % nshard(dp) == 0:
                spec[i] = dp
                used_b = True
            elif not used_c and dim == capacity and dim % nshard(tp) == 0:
                spec[i] = tp
                used_c = True
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_shapes)


# --------------------------------------------------------------------------
# per-cell build: (fn, args, in_shardings, donate)
# --------------------------------------------------------------------------


def _microbatches(cfg, shape: ShapeConfig, mesh) -> int:
    """Grad-accumulation factor: bound per-device microbatch activations."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    per_dev = max(shape.global_batch // dp, 1)
    # 1 sequence per device per microbatch for wide models (remat-saved
    # per-layer inputs scale with d_model x layers) and for enc-dec
    # (whisper re-encodes 1500 frames per microbatch), 2 for narrow LMs
    target = 2 if (shape.seq_len <= 4096 and cfg.d_model < 5120
                   and cfg.family != "audio") else 1
    n = max(1, per_dev // target)
    while shape.global_batch % n:
        n -= 1
    return n


def build_cell(cfg, shape: ShapeConfig, mesh):
    """Returns (jitted_fn, args, meta) ready to .lower()."""
    specs = input_specs(cfg, shape)
    args_sds = {k: v[0] for k, v in specs.items()}
    args_sharding = {k: _resolve(mesh, v[1], v[0].shape) for k, v in specs.items()}

    params_shape = jax.eval_shape(lambda: train_state.init_model(jax.random.PRNGKey(0), cfg))
    moe_e = cfg.moe.num_experts if cfg.moe else 0
    pspecs = rules.param_specs(params_shape, mesh, moe_experts=moe_e)
    psharding = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    if shape.kind == "train":
        nm = _microbatches(cfg, shape, mesh)
        step = train_loop.make_train_step(
            cfg, num_microbatches=nm, param_specs=psharding
        )
        # >100B params: bf16 optimizer states (fp32 AdamW state alone is
        # 14.7 GB/chip for grok-1 at 256 chips) — standard at this scale
        n_params = sum(l.size for l in jax.tree.leaves(params_shape))
        opt_dtype = jnp.bfloat16 if n_params > 100e9 else jnp.float32
        state_shape = jax.eval_shape(
            lambda: train_state.TrainState(
                params=params_shape,
                opt=adamw.init_adamw(params_shape, state_dtype=opt_dtype),
                step=jnp.zeros((), jnp.int32),
            )
        )
        opt_sharding = train_state.TrainState(
            params=psharding,
            opt=type(state_shape.opt)(
                m=psharding, v=psharding, count=NamedSharding(mesh, P())
            ),
            step=NamedSharding(mesh, P()),
        )
        fn = jax.jit(
            step,
            in_shardings=(opt_sharding, args_sharding),
            donate_argnums=(0,),
        )
        return fn, (state_shape, args_sds), {"microbatches": nm}

    from repro.serving.engine import make_serve_fns

    # serving deployments load bf16 weights; declare the served params so
    # (fp32 masters are a training artifact — grok decode: 4.9 GB/chip
    # of fp32 params for no benefit)
    params_shape = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
        if l.dtype == jnp.float32 and l.ndim >= 2 else l,
        params_shape,
    )
    prefill, decode = make_serve_fns(cfg)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "prefill":
        cap = S + (cfg.vision.num_visual_tokens if cfg.family == "vlm" else 0)
        fn = jax.jit(
            lambda params, inputs: prefill(params, **inputs, capacity=cap),
            in_shardings=(psharding, args_sharding),
        )
        return fn, (params_shape, args_sds), {}

    # decode: auto-enable the int8 KV cache when the bf16 cache alone
    # would crowd the chips (qwen1.5-32B MHA: 21.5 GB/chip at bf16)
    meta_kv = {}
    if cfg.family in ("dense", "moe", "hybrid", "vlm") and shape.kind == "decode":
        slots = sum(
            S if k == "attn" else min(cfg.window, S) if k == "local" else 0
            for k in cfg.layer_kinds()
        )
        cache_gb = 2 * B * slots * cfg.num_kv_heads * cfg.head_dim * 2 \
            / mesh_lib.chips(mesh) / 1e9
        if cache_gb > 6.0:
            import dataclasses

            cfg = dataclasses.replace(cfg, kv_quant=True)
            meta_kv = {"kv_quant": True, "bf16_cache_gb_per_chip": round(cache_gb, 1)}
    if cfg.family == "audio":
        from repro.models import whisper as wh

        cache_shape = jax.eval_shape(
            lambda p, f, t: wh.whisper_prefill(p, cfg, f, t, S),
            params_shape,
            _sds((B, cfg.encoder.num_frames, cfg.d_model), cfg.dtype),
            _sds((B, 8), "int32"),
        )[1]
    elif cfg.family == "vlm":
        from repro.models import lm as lm_mod

        cache_shape = jax.eval_shape(
            lambda: lm_mod.init_cache(cfg, B, S, jnp.dtype(cfg.dtype))
        )
    else:
        from repro.models import lm as lm_mod

        cache_shape = jax.eval_shape(
            lambda: lm_mod.init_cache(cfg, B, S, jnp.dtype(cfg.dtype))
        )
    csharding = cache_specs(cache_shape, mesh, B, S)
    fn = jax.jit(
        lambda params, cache, token: decode(params, cache, token),
        in_shardings=(psharding, csharding, args_sharding["token"]),
        donate_argnums=(1,),
    )
    return fn, (params_shape, cache_shape, args_sds["token"]), meta_kv


# --------------------------------------------------------------------------
# analytic model FLOPs (the roofline's "useful compute" reference)
# --------------------------------------------------------------------------


def model_flops(cfg, shape: ShapeConfig) -> float:
    """6 * N_active * tokens (x1 for inference kinds, fwd only => 2*N*D)."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    per_tok = 6.0 * n if shape.kind == "train" else 2.0 * n
    return per_tok * tokens


# --------------------------------------------------------------------------
# run one cell
# --------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = supports_shape(cfg, shape)
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        cell.update(status="skip", reason=reason)
        return cell

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        with rules.use_mesh(mesh):
            fn, args, meta = build_cell(cfg, shape, mesh)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t1
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
    except Exception as e:  # noqa: BLE001 — any failure here is a finding
        cell.update(status="error", error=f"{type(e).__name__}: {e}"[:2000],
                    t=time.time() - t0)
        return cell

    n_chips = mesh_lib.chips(mesh)
    ana = hlo_analysis.analyze(hlo)
    flops_nominal = float(cost.get("flops", -1.0)) if cost else -1.0
    memd = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes",
                 "alias_size_in_bytes", "generated_code_size_in_bytes"):
        memd[attr] = getattr(mem, attr, None)

    # roofline terms (per-chip HLO numbers vs per-chip peaks)
    t_compute = ana["flops"] / mesh_lib.PEAK_FLOPS_BF16
    t_memory = ana["mem_bytes"] / mesh_lib.HBM_BW
    t_coll = ana["collectives"]["wire_bytes"] / mesh_lib.ICI_BW
    mflops = model_flops(cfg, shape)
    cell.update(
        status="ok",
        meta=meta,
        t_lower=round(t_lower, 2),
        t_compile=round(t_compile, 2),
        flops_per_device=ana["flops"],
        flops_nominal_costanalysis=flops_nominal,
        mem_bytes_per_device=ana["mem_bytes"],
        collectives=ana["collectives"],
        model_flops_global=mflops,
        useful_flops_ratio=mflops / max(ana["flops"] * n_chips, 1.0),
        roofline={
            "compute_s": t_compute,
            "memory_s": t_memory,
            "collective_s": t_coll,
            "bottleneck": max(
                ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
                key=lambda kv: kv[1],
            )[0],
        },
        memory=memd,
        n_chips=n_chips,
    )
    if verbose:
        print(json.dumps(cell, indent=None, default=str)[:600])
    return cell


def load_results() -> Dict[str, Any]:
    path = os.path.abspath(RESULTS_PATH)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_result(cell: Dict[str, Any]) -> None:
    path = os.path.abspath(RESULTS_PATH)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    results = load_results()
    key = f"{cell['arch']}|{cell['shape']}|{cell['mesh']}"
    results[key] = cell
    with open(path, "w") as f:
        json.dump(results, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true", help="re-run cached cells")
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    archs = list_configs() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    done = load_results()
    for arch in archs:
        for shape_name in shapes:
            for mk in meshes:
                key = f"{arch}|{shape_name}|{mk}"
                if not args.force and done.get(key, {}).get("status") == "ok":
                    print(f"[cached] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                cell = run_cell(arch, shape_name, mk)
                save_result(cell)
                done[key] = cell
                print(f"  -> {cell['status']} "
                      f"(lower {cell.get('t_lower', '-')}s compile {cell.get('t_compile', '-')}s)",
                      flush=True)


if __name__ == "__main__":
    main()
