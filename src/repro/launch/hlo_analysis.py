"""Post-SPMD HLO analysis: true FLOPs / bytes / collective traffic.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports scanned-layer models by orders of magnitude.  This module
walks the optimized HLO text instead:

* computations are parsed into a call graph (``body=`` / ``condition=``
  for whiles — with ``known_trip_count`` from backend_config —,
  ``calls=`` for fusions, ``to_apply=`` for reduces,
  ``branch_computations=`` for conditionals) and every computation gets
  an **execution multiplier** (product of enclosing trip counts);
* operand shapes are resolved through a per-computation symbol table
  (optimized HLO does not print operand types inline);
* dot FLOPs: ``2 * numel(result) * contracted_size`` per ``dot``,
  times multiplier (vector/elementwise FLOPs are not counted — matmul
  noise on these workloads; gather/interp costs show up in bytes);
* HBM-traffic model: for *control* computations (entry / while bodies /
  branches — NOT fusion interiors) sum result+operand bytes of
  buffer-level ops, times multiplier;
* collectives: per-type operand bytes + ring-algorithm per-chip wire
  estimates using the replica-group size.

All numbers are per-device (the HLO is the per-device SPMD module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s*([a-z][\w\-]*)\(([^)]*)\)")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*([a-z][a-z0-9]*\[[0-9,]*\])")
_CALLEE_RE = re.compile(r"(body|condition|calls|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_MEM_OPS = {
    "fusion", "dot", "convolution", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "sort", "pad",
    "concatenate", "slice", "transpose", "broadcast", "iota", "select",
    "add", "multiply", "subtract", "divide", "exponential", "tanh", "compare",
    "reduce-window", "select-and-scatter", "convert", "rng", "bitcast-convert",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "cholesky", "triangular-solve",
}

_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "while", "conditional",
             "call", "custom-call", "opt-barrier"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _type_numel(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class OpLine:
    name: str
    rtype: str
    op: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    ops: List[OpLine] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)
    edges: List[Tuple[str, str, int]] = field(default_factory=list)


def _parse_operands(comp: Computation, operand_str: str) -> List[str]:
    """Operand names from an op's argument list.

    Handles both HLO spellings: bare names (``%a, %b``) and inline-typed
    operands (``f32[32,48]{1,0} %a, ...`` — commas inside the shape must
    not split).  Inline types are harvested into the symbol table.
    """
    pieces, cur, depth = [], "", 0
    for ch in operand_str:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            pieces.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        pieces.append(cur)
    names = []
    for piece in pieces:
        toks = piece.split()
        if not toks:
            continue
        name = toks[-1].lstrip("%")
        if len(toks) > 1 and _SHAPE_RE.search(toks[0]) and name not in comp.symbols:
            comp.symbols[name] = toks[0]
        names.append(name)
    return names


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in text.splitlines():
        if not raw.strip():
            continue
        if not raw.startswith(" ") and ("->" in raw) and raw.rstrip().endswith("{"):
            m = _COMP_HDR.match(raw)
            if m:
                cur = Computation(name=m.group(1), is_entry=raw.startswith("ENTRY"))
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
                for pname, ptype in _PARAM_RE.findall(m.group(2)):
                    cur.symbols[pname] = ptype
                continue
        if raw.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(raw)
        if dm:
            name, rtype, op, operand_str = dm.groups()
            operands = _parse_operands(cur, operand_str)
            cur.symbols[name] = rtype
            cur.ops.append(OpLine(name=name, rtype=rtype, op=op, operands=operands, line=raw))
        # parameters defined inline: %p = f32[..] parameter(0)
        pm = re.match(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s*parameter\(", raw)
        if pm:
            cur.symbols[pm.group(1)] = pm.group(2)
        trip = 1
        tm = _TRIP_RE.search(raw)
        if tm:
            trip = int(tm.group(1))
        line_op = dm.group(3) if dm else ""
        for kind, callee in _CALLEE_RE.findall(raw):
            if kind == "to_apply" and line_op == "call":
                # control-flow call (CPU backend wraps fusions this way):
                # the callee is NOT a fusion interior — its memory counts
                kind = "call"
            cur.edges.append((callee, kind, trip if kind in ("body", "condition") else 1))
        bm = _BRANCHES_RE.search(raw)
        if bm:
            for b in bm.group(1).split(","):
                cur.edges.append((b.strip().lstrip("%"), "branch", 1))
    return comps, entry


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def analyze(text: str, *, default_group: int = 2) -> Dict[str, object]:
    comps, entry_name = parse_hlo(text)
    if not entry_name:
        raise ValueError("no ENTRY computation found")

    fusion_interiors: set = set()
    for c in comps.values():
        for callee, kind, _ in c.edges:
            if kind in ("calls", "to_apply"):
                fusion_interiors.add(callee)

    # execution multipliers (iterative worklist; HLO call graphs are DAGs)
    mult: Dict[str, float] = {}
    stack = [(entry_name, 1.0)]
    while stack:
        name, m = stack.pop()
        if name not in comps:
            continue
        mult[name] = mult.get(name, 0.0) + m
        for callee, kind, trip in comps[name].edges:
            stack.append((callee, m * trip))

    def operand_bytes(c: Computation, opnd: str) -> int:
        t = c.symbols.get(opnd)
        return _type_bytes(t) if t else 0

    flops = 0.0
    mem = 0.0
    coll: Dict[str, float] = {}
    wire = 0.0
    count = 0.0
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for ol in c.ops:
            if ol.op == "dot":
                km = re.search(r"rhs_contracting_dims=\{([0-9,]*)\}", ol.line)
                k = 1
                if km and len(ol.operands) >= 2:
                    rhs_t = c.symbols.get(ol.operands[1], "")
                    dims = _shape_dims(rhs_t)
                    for dd in km.group(1).split(","):
                        if dd and int(dd) < len(dims):
                            k *= dims[int(dd)]
                flops += m * 2.0 * _type_numel(ol.rtype) * k
            if ol.op == "convolution":
                # rough: 2 * numel(out) * prod(kernel spatial+channel)
                rhs_t = c.symbols.get(ol.operands[1], "") if len(ol.operands) > 1 else ""
                kdims = _shape_dims(rhs_t)
                kk = 1
                for d in kdims[:-1]:
                    kk *= d
                flops += m * 2.0 * _type_numel(ol.rtype) * kk
            if ol.op in COLLECTIVES:
                b = sum(operand_bytes(c, o) for o in ol.operands)
                if b == 0:
                    b = _type_bytes(ol.rtype)
                g = _group_size(ol.line, default_group)
                coll[ol.op] = coll.get(ol.op, 0.0) + m * b
                count += m
                frac = (g - 1.0) / max(g, 1)
                if ol.op == "all-reduce":
                    wire += m * 2.0 * b * frac
                elif ol.op == "all-gather":
                    wire += m * _type_bytes(ol.rtype) * frac
                elif ol.op in ("reduce-scatter", "all-to-all"):
                    wire += m * b * frac
                else:  # collective-permute
                    wire += m * b
            if ol.op in _MEM_OPS and name not in fusion_interiors:
                if ol.op == "dynamic-update-slice":
                    # in-place in optimized HLO: traffic = the update window
                    b = 2 * (operand_bytes(c, ol.operands[1]) if len(ol.operands) > 1 else 0)
                elif ol.op == "dynamic-slice":
                    b = 2 * _type_bytes(ol.rtype)  # read window + write result
                elif ol.op in ("broadcast", "iota"):
                    b = _type_bytes(ol.rtype)  # write-only
                else:
                    b = _type_bytes(ol.rtype) + sum(operand_bytes(c, o) for o in ol.operands)
                mem += m * b
    return {
        "flops": flops,
        "mem_bytes": mem,
        "collectives": {"per_type": coll, "wire_bytes": wire, "count": int(count)},
        "n_computations": len(comps),
    }
