"""Production meshes.

Mesh construction is a FUNCTION (importing this module never touches
jax device state).  Axes:

* single-pod: ``(data=16, model=16)`` — one v5e-256 pod;
* multi-pod:  ``(pod=2, data=16, model=16)`` — 512 chips; 'pod' extends
  the data-parallel dimension across the DCN boundary (gradient
  reduction is hierarchical: reduce-scatter intra-pod over ICI, then
  all-reduce inter-pod over the slow links, where int8 error-feedback
  compression is available — see optim/grad_compression.py).
"""
from __future__ import annotations

import jax

# TPU v5e-class hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh():
    """1-device mesh with production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
