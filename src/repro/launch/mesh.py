"""Production meshes.

Mesh construction is a FUNCTION (importing this module never touches
jax device state).  Axes:

* single-pod: ``(data=16, model=16)`` — one v5e-256 pod;
* multi-pod:  ``(pod=2, data=16, model=16)`` — 512 chips; 'pod' extends
  the data-parallel dimension across the DCN boundary (gradient
  reduction is hierarchical: reduce-scatter intra-pod over ICI, then
  all-reduce inter-pod over the slow links, where int8 error-feedback
  compression is available — see optim/grad_compression.py).
"""
from __future__ import annotations

import jax

# TPU v5e-class hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh():
    """1-device mesh with production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def parse_mesh_shape(token: str):
    """'1'/'none'/'local' -> None (no mesh); 'DPxTP' (e.g. '2x2') -> (dp, tp).

    The one parser for mesh-shape CLI tokens (``launch/serve.py
    --mesh``, ``benchmarks/sweep.py --mesh-shapes``) — raises ValueError
    naming the offending token so callers can report-and-continue.
    """
    if token in ("1", "none", "local"):
        return None
    parts = str(token).lower().split("x")
    if len(parts) != 2 or not all(p.isdigit() and int(p) > 0 for p in parts):
        raise ValueError(
            f"bad mesh shape {token!r}: use '1' (no mesh) or 'DPxTP' like 2x2")
    return int(parts[0]), int(parts[1])


def make_mesh_2d(dp: int, tp: int):
    """(data=dp, model=tp) mesh over the first dp*tp local devices.

    The small-mesh constructor behind the 2D (dp x tp) MSDA sharding
    tests and the benchmark sweep's mesh axis: on a host split into N
    virtual CPU devices it yields a real multi-device mesh whose
    collectives (ring ppermute, psum) actually execute, and on TPU it is
    just a sub-slice mesh.  Raises if fewer than dp*tp devices exist.
    """
    n = dp * tp
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"mesh {dp}x{tp} needs {n} devices, have {len(devs)}")
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(dp, tp), ("data", "model"))


def chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
