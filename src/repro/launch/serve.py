"""Serving driver: continuous-batching engine on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --prompts "hello world" "the quick brown fox"

Serving-runtime extras:

    # persistent warm boot: plan store + XLA compilation cache; AOT
    # warm-up for the prompt lengths the fleet expects
    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --store /tmp/plans.json --compile-cache /tmp/xla-cache --warm-lengths 4 8

    # CI smoke: boot the (vlm) engine against one store path; run twice
    # with the same paths and the SECOND boot must perform zero autotune
    # timing runs, zero request-time retraces and zero new XLA cache
    # entries — the process exits non-zero otherwise.
    PYTHONPATH=src python -m repro.launch.serve --serving-smoke \
        --store /tmp/store/plans.json --compile-cache /tmp/store/xla-cache

    # CI chaos smoke: clean boot proves zero resilience overhead, then a
    # second boot under a SEEDED fault schedule (executor raises,
    # straggler ticks, boot-time store corruption) must give every
    # admitted request a typed response and drive the circuit breaker
    # through a full demote -> half-open -> close cycle.  --bench-out
    # writes the event counts BENCH_resilience.json gates.
    PYTHONPATH=src python -m repro.launch.serve --chaos-smoke \
        --store /tmp/chaos/plans.json --fault-seed 7 \
        --bench-out /tmp/chaos/BENCH_resilience.json
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.data import tokenizer
from repro.serving.engine import Request, ServeEngine
from repro.train import state as train_state


def serving_smoke(arch: str, store_path: str, compile_cache_dir: str,
                  *, slots: int = 2, capacity: int = 64) -> dict:
    """One serving boot against a persistent store; self-asserting.

    Cold boot (no store yet): warms + autotunes the bucket plans, saves
    the store, AOT-compiles the executors (all persisted to the XLA
    compilation cache), serves a few pyramid requests.  Warm boot (store
    exists): restores the plan set — the assertions then REQUIRE zero
    autotune timing runs, zero describe drift, zero request-time
    retraces, and zero new XLA cache entries (every boot compile was a
    disk hit).  The CI serving-smoke job runs this twice.
    """
    from repro.kernels import plan as plan_mod
    from repro.serving import aot, persistence

    # enable the compilation cache BEFORE any compile (params init
    # included) so both boots persist/hit the same entry set
    cache_on = persistence.enable_jax_compilation_cache(compile_cache_dir)
    assert cache_on, "persistent compilation cache failed to enable"
    warm = persistence.PlanStore(store_path).exists()
    cache0 = persistence.compilation_cache_entries(compile_cache_dir)
    plan_mod.reset_autotune_stats()
    aot.reset_stats()

    cfg = reduced(get_config(arch))
    params = train_state.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity,
                      store_path=store_path, compile_cache_dir=compile_cache_dir,
                      dtype_policy="auto", tune="autotune")
    eng.warmup(prompt_lengths=(4,))
    boot_tune = plan_mod.autotune_stats()

    vc = cfg.vision
    half = tuple((max(1, h // 2), max(1, w // 2)) for h, w in vc.levels)
    odd = tuple((max(1, h - 2), max(1, w - 3)) for h, w in vc.levels)
    rng = np.random.default_rng(0)
    reqs = []
    for i, lv in enumerate((vc.levels, odd, half, half)):
        S = sum(h * w for h, w in lv)
        reqs.append(Request(
            rid=i, prompt=np.arange(4, dtype=np.int32) + i, max_new=4,
            pyramid=rng.standard_normal((S, vc.vision_dim)).astype(np.float32),
            levels=lv))
    with aot.probe() as probe:
        for r in reqs:
            eng.submit(r)
        eng.run()

    rr = eng.restore_report
    summary = {
        "arch": cfg.name,
        "warm_boot": warm,
        "plans": len(eng.plans),
        "restored_plans": len(rr.plans) if rr else 0,
        "seeded_winners": rr.seeded_winners if rr else 0,
        "describe_mismatches": rr.describe_mismatches if rr else [],
        "boot_autotune": boot_tune,
        "request_traces": probe.traces,
        "request_compiles": probe.compiles,
        "new_xla_cache_entries":
            persistence.compilation_cache_entries(compile_cache_dir) - cache0,
        "completed": [len(r.out) for r in reqs],
        # aot probe counters ride inside the metrics dict so zero-retrace
        # is auditable from the uploaded artifact, not just the asserts
        "metrics": {**eng.metrics.snapshot(),
                    "aot": {"traces": probe.traces,
                            "compiles": probe.compiles,
                            "aot_calls": probe.aot_calls,
                            "boot": aot.stats()}},
    }
    print(json.dumps(summary, indent=1))
    assert all(len(r.out) == r.max_new for r in reqs), "requests incomplete"
    assert probe.traces == 0 and probe.compiles == 0, (
        f"request-time retraces: {probe}")
    if warm:
        assert boot_tune["raced"] == 0, (
            f"warm boot ran autotune timing: {boot_tune}")
        assert summary["restored_plans"] > 0, "warm boot restored no plans"
        assert not summary["describe_mismatches"], summary["describe_mismatches"]
        assert summary["new_xla_cache_entries"] == 0, (
            f"warm boot recompiled {summary['new_xla_cache_entries']} executables")
    else:
        # the no-recompilation assertion above is only meaningful if the
        # cold boot actually persisted executables — a silently-disabled
        # cache would make the warm-boot check pass vacuously
        assert summary["new_xla_cache_entries"] > 0, (
            "cold boot persisted no executables: compilation cache inert")
    eng.shutdown()
    return summary


def resilience_smoke(arch: str, store_path: str, *, fault_seed: int = 7,
                     bench_out: str = None, slots: int = 1,
                     capacity: int = 64) -> dict:
    """Chaos smoke: seeded faults, typed responses, breaker cycle.

    Three self-asserting phases (``docs/serving.md`` §Resilience):

    1. **Clean boot** — no injector: traffic must show zero sheds, zero
       transitions, zero retries, NO fallback rungs built, and zero
       request-time traces (the resilience layer is free on the healthy
       path).  This boot also persists the plan store phase 2 corrupts.
    2. **Chaos boot** — a seeded :class:`FaultSchedule` with all three
       serving kinds: ``corrupt_store`` damages the store at boot (the
       engine must cold-warm + re-persist), ``exec_raise`` arms enough
       decode failures to open the breaker and demote to the jit rung,
       ``straggler`` stalls one tick.  Admission (``max_queue=3``) sheds
       the over-submitted burst; one request carries a short deadline
       and times out.  EVERY submitted request must end with a typed
       ``ServeResponse`` — no silent drops.
    3. **Plan-breaker incident** — a second seeded schedule drives a
       :func:`repro.serving.resilience.guard_plan` breaker over the
       warmed MSDA plan through demote -> half-open probe -> close,
       TWICE with fresh guards from the same seed: both runs must make
       identical decisions (the reproducibility contract).
    """
    from repro.kernels import plan as plan_mod
    from repro.runtime.faults import (
        SERVING_FAULT_KINDS, FaultInjector, FaultSchedule)
    from repro.serving import aot, persistence, resilience

    cfg = reduced(get_config(arch))
    params = train_state.init_model(jax.random.PRNGKey(0), cfg)
    vc = cfg.vision
    rng = np.random.default_rng(0)
    policy = resilience.ResilienceConfig(
        max_queue=3, max_retries=1, breaker_threshold=2, probe_interval=2)

    def _requests(n, deadline_rid=None):
        S = sum(h * w for h, w in vc.levels)
        out = []
        for i in range(n):
            out.append(Request(
                rid=i, prompt=np.arange(4, dtype=np.int32) + i, max_new=3,
                pyramid=rng.standard_normal((S, vc.vision_dim)).astype(np.float32),
                deadline_ticks=2 if i == deadline_rid else None))
        return out

    # -- phase 1: clean boot — resilience must be free ---------------------
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity,
                      store_path=store_path, resilience=policy)
    eng.warmup(prompt_lengths=(4,))
    clean_reqs = _requests(2)
    exec0 = plan_mod.execution_telemetry()
    with aot.probe() as probe:
        for r in clean_reqs:
            eng.submit(r)
        eng.run()
    clean_state = eng.resilience_state()
    clean = {
        "request_traces": probe.traces, "request_compiles": probe.compiles,
        "sheds": clean_state["sheds"],
        "transitions": sum(len(e["transitions"])
                           for e in clean_state["executors"].values()),
        "retries": sum(e["retries"] for e in clean_state["executors"].values()),
        "rungs_built": max(len(e["rungs_built"])
                           for e in clean_state["executors"].values()),
        "new_plan_builds": (plan_mod.execution_telemetry()["plan_cache"]["misses"]
                            - exec0["plan_cache"]["misses"]),
    }
    assert all(r.response is not None and r.response.ok for r in clean_reqs), \
        "clean run: non-ok response"
    assert clean["request_traces"] == 0 and clean["request_compiles"] == 0, clean
    assert clean["sheds"] == 0 and clean["transitions"] == 0 \
        and clean["retries"] == 0 and clean["new_plan_builds"] == 0, clean
    assert clean["rungs_built"] == 1, (
        f"clean run materialised fallback rungs: {clean}")
    eng.shutdown()
    del eng

    # -- phase 2: chaos boot on the now-corruptible store ------------------
    # seeded schedule; n_faults == len(kinds) guarantees every serving
    # kind fires exactly once (kinds cycle a seeded permutation)
    sched = FaultSchedule.generate(fault_seed, 8, n_faults=3,
                                   kinds=SERVING_FAULT_KINDS)
    sched2 = FaultSchedule.generate(fault_seed, 8, n_faults=3,
                                    kinds=SERVING_FAULT_KINDS)
    assert sched.describe() == sched2.describe(), "seeded schedule drifted"
    kinds_fired = sorted(e.kind for e in sched.events.values())
    assert kinds_fired == sorted(SERVING_FAULT_KINDS), kinds_fired
    # 4 armed raises = breaker_threshold * (max_retries + 1): enough to
    # exhaust two consecutive decode calls and open the breaker
    inj = FaultInjector(sched, raise_target="decode", raise_attempts=4,
                        straggler_s=0.01)
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity,
                      store_path=store_path, resilience=policy, faults=inj)
    assert eng.boot_faults, "corrupt_store fault did not fire at boot"
    assert eng.restore_report is None, "engine restored from a corrupt store"
    assert persistence.PlanStore(store_path).load() is not None, \
        "chaos boot did not re-persist the store"
    eng.warmup(prompt_lengths=(4,))
    chaos_reqs = _requests(5, deadline_rid=2)
    for r in chaos_reqs:
        eng.submit(r)
    eng.run(max_ticks=64)
    # the burst may finish before the later scheduled ticks: keep
    # follow-up traffic flowing until every seeded fault has fired and
    # every armed raise is consumed (deterministic — the loop is a pure
    # function of the seeded schedule)
    # function of the seeded schedule).  Traffic also continues until
    # the demoted decode breaker has probed its primary and re-closed —
    # the full demote -> half-open -> close cycle on the live engine.
    extra = []
    while (inj.pending_raises or inj.schedule.events
           or eng._decode_guard.rung > 0) and len(extra) < 8:
        r = _requests(1)[0]
        r.rid = 100 + len(extra)
        extra.append(r)
        eng.submit(r)
        eng.run(max_ticks=32)
    chaos_state = eng.resilience_state()
    statuses = sorted(r.response.status if r.response else "MISSING"
                      for r in chaos_reqs)
    by_status = {s: statuses.count(s) for s in set(statuses)}
    assert "MISSING" not in by_status, (
        f"request dropped without a typed response: {by_status}")
    assert all(r.response is not None for r in extra), \
        "follow-up request dropped without a typed response"
    assert by_status.get("shed", 0) == 2, by_status  # rids 3, 4: queue at 3
    assert by_status.get("timeout", 0) >= 1, by_status  # rid 2's deadline
    decode_t = [t[0] for t in chaos_state["executors"]["decode"]["transitions"]]
    assert decode_t and decode_t[0] == "open" and decode_t[-1] == "closed" \
        and "half_open" in decode_t, (
        f"decode breaker cycle incomplete: {decode_t}")
    assert inj.pending_raises == 0, "armed executor raises left unconsumed"
    assert not inj.schedule.events, f"unfired faults: {inj.schedule.describe()}"
    m = eng.metrics.snapshot()
    assert m["stragglers"] == 1, m["stragglers"]
    eng.shutdown()
    del eng

    # -- phase 3: plan-breaker incident, twice, same seed ------------------
    from repro.serving.engine import warmup_msda_plans

    def plan_incident():
        plan_mod.clear_plans()
        plans = warmup_msda_plans(cfg)
        # pick a plan with at least one fallback rung (heuristic-built,
        # never persisted); the bottom-of-ladder ref plan has none
        plan = next(p for p in plans if p.fallback() is not None)
        s = FaultSchedule.generate(fault_seed + 1, 4, n_faults=1,
                                   kinds=("exec_raise",))
        pinj = FaultInjector(s, raise_target="plan", raise_attempts=4)
        g = resilience.guard_plan(plan, policy, injector=pinj, name="plan",
                                  engine="chaos")
        structs = aot.plan_arg_structs(plan.spec, 1)
        prng = np.random.default_rng(3)
        args = tuple(prng.standard_normal(st.shape).astype(st.dtype)
                     for st in structs)
        outcomes = []
        [pinj.begin_tick(t) for t in range(4)]  # arm the scheduled raises
        for _ in range(8):
            try:
                g.call(*args)
                outcomes.append("ok")
            except resilience.ExecutorFailure:
                outcomes.append("fail")
        return outcomes, list(g.transitions), g.rung_labels(), list(pinj.log)

    out1, trans1, rungs1, log1 = plan_incident()
    out2, trans2, rungs2, log2 = plan_incident()
    assert (out1, trans1, rungs1, log1) == (out2, trans2, rungs2, log2), (
        "plan incident is not reproducible under the same seed")
    t_kinds = [t[0] for t in trans1]
    assert t_kinds[0] == "open" and "half_open" in t_kinds \
        and t_kinds[-1] == "closed" and trans1[-1][1] == 0, trans1
    assert len(rungs1) >= 2, f"ladder never materialised: {rungs1}"

    summary = {
        "arch": cfg.name,
        "clean": clean,
        "chaos": {
            "fault_schedule": sched.describe(),
            "responses": by_status,
            "untyped_requests": statuses.count("MISSING"),
            "sheds": chaos_state["sheds"],
            "deadline_misses": chaos_state["deadline_misses"],
            "exec_errors": chaos_state["exec_errors"],
            "stragglers": chaos_state["stragglers"],
            "boot_corruptions": len(chaos_state["boot_faults"]),
            "decode_transitions": decode_t,
        },
        "plan_breaker": {
            "transitions": trans1,
            "rungs": rungs1,
            "outcomes": out1,
            "reproducible": True,
        },
    }
    print(json.dumps(summary, indent=1))
    if bench_out:
        from repro.obs import bench as obs_bench

        results = {
            "untyped_requests": 0,
            "clean_request_traces": clean["request_traces"],
            "clean_sheds": clean["sheds"],
            "clean_transitions": clean["transitions"],
            "clean_rungs_built": clean["rungs_built"],
            "responses_ok": by_status.get("ok", 0),
            "responses_shed": by_status.get("shed", 0),
            "responses_timeout": by_status.get("timeout", 0),
            "responses_error": by_status.get("error", 0),
            "boot_corruptions": len(chaos_state["boot_faults"]),
            "stragglers": chaos_state["stragglers"],
            "decode_breaker_opens": decode_t.count("open"),
            "decode_breaker_closes": decode_t.count("closed"),
            "breaker_opens": t_kinds.count("open"),
            "breaker_closes": t_kinds.count("closed"),
            "plan_rungs_exercised": len(rungs1),
        }
        gate = [
            # structural: chaos event counts are seeded + deterministic,
            # they must not grow (a drop is a structural win)
            obs_bench.gate_rule("untyped_requests", "lower", 0.0),
            obs_bench.gate_rule("clean_*", "lower", 0.0),
            obs_bench.gate_rule("responses_error", "lower", 0.0),
            obs_bench.gate_rule("responses_timeout", "lower", 0.0),
            # the recovery machinery must keep firing under the seed
            obs_bench.gate_rule("responses_ok", "higher", 0.0),
            obs_bench.gate_rule("boot_corruptions", "higher", 0.0),
            obs_bench.gate_rule("breaker_closes", "higher", 0.0),
            obs_bench.gate_rule("decode_breaker_closes", "higher", 0.0),
            obs_bench.gate_rule("plan_rungs_exercised", "higher", 0.0),
        ]
        import dataclasses as _dc

        path = obs_bench.write_bench(
            bench_out, bench="serving_resilience", results=results,
            config={"arch": cfg.name, "fault_seed": fault_seed,
                    "slots": slots, "policy": _dc.asdict(policy)},
            note="seeded chaos smoke: typed responses, breaker cycle, "
                 "boot store corruption (repro.launch.serve --chaos-smoke)",
            events=summary["chaos"]["fault_schedule"], gate=gate)
        print(f"[serve] resilience bench -> {path}")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", nargs="+", default=["hello world"])
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=None,
                    help="default: 4 (2 for --serving-smoke)")
    ap.add_argument("--capacity", type=int, default=None,
                    help="default: 128 (64 for --serving-smoke)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--store", default=None,
                    help="plan-store path: warm boots restore every plan "
                         "with zero autotune races")
    ap.add_argument("--compile-cache", default=None,
                    help="JAX persistent compilation cache directory")
    ap.add_argument("--dtype-policy", default=None,
                    choices=("follow", "float32", "bfloat16", "auto"))
    ap.add_argument("--tune", default=None, choices=("heuristic", "autotune"))
    ap.add_argument("--warm-lengths", type=int, nargs="*", default=None,
                    help="prompt lengths to AOT-compile prefill for at boot")
    ap.add_argument("--mesh", default=None, metavar="DPxTP",
                    help="build a (data=DP, model=TP) mesh and warm "
                         "DISTRIBUTED plans (e.g. 2x2; needs DP*TP local "
                         "devices); the plan store then records/restores "
                         "the sharding modes — see docs/sharding.md")
    ap.add_argument("--serving-smoke", action="store_true",
                    help="self-asserting double-boot CI smoke (see docstring)")
    ap.add_argument("--chaos-smoke", action="store_true",
                    help="self-asserting resilience smoke under a seeded "
                         "fault schedule (see docstring)")
    ap.add_argument("--fault-seed", type=int, default=7,
                    help="seed for the chaos smoke's FaultSchedule")
    ap.add_argument("--bench-out", default=None,
                    help="write the chaos smoke's BENCH_resilience payload "
                         "here (gated by tools/bench_gate.py)")
    ap.add_argument("--metrics-out", default=None,
                    help="dump the obs metrics registry at exit "
                         "(.json -> JSON, else Prometheus text)")
    ap.add_argument("--trace-out", default=None,
                    help="stream obs spans to this JSONL file")
    ap.add_argument("--trace-level", type=int, default=3,
                    help="span verbosity exported to --trace-out (1-4)")
    args = ap.parse_args()

    from repro import obs

    if args.trace_out:
        obs.enable_trace(args.trace_out, level=args.trace_level)

    def _export() -> None:
        if args.metrics_out:
            print(f"[serve] metrics -> {obs.write_metrics(args.metrics_out)}")
        if args.trace_out:
            obs.disable_trace()
            print(f"[serve] trace -> {args.trace_out}")

    if args.serving_smoke:
        if not (args.store and args.compile_cache):
            ap.error("--serving-smoke needs --store and --compile-cache")
        try:
            serving_smoke(args.arch or "phi-3-vision-4.2b", args.store,
                          args.compile_cache,
                          slots=args.slots or 2, capacity=args.capacity or 64)
        finally:
            _export()
        return

    if args.chaos_smoke:
        if not args.store:
            ap.error("--chaos-smoke needs --store")
        try:
            resilience_smoke(args.arch or "phi-3-vision-4.2b", args.store,
                             fault_seed=args.fault_seed,
                             bench_out=args.bench_out,
                             slots=args.slots or 1,
                             capacity=args.capacity or 64)
        finally:
            _export()
        return

    if not args.arch:
        ap.error("--arch is required")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    mesh = None
    if args.mesh:
        from repro.launch import mesh as mesh_lib

        try:
            shape = mesh_lib.parse_mesh_shape(args.mesh)
        except ValueError as e:
            ap.error(str(e))
        if shape is not None:
            mesh = mesh_lib.make_mesh_2d(*shape)
    params = train_state.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, slots=args.slots or 4,
                      capacity=args.capacity or 128,
                      temperature=args.temperature, store_path=args.store,
                      compile_cache_dir=args.compile_cache,
                      dtype_policy=args.dtype_policy, tune=args.tune,
                      mesh=mesh)
    rng = np.random.default_rng(0)
    reqs = []
    for i, p in enumerate(args.prompts):
        ids = np.asarray(tokenizer.encode(p), np.int32) % cfg.vocab_size
        req = Request(rid=i, prompt=ids, max_new=args.max_new)
        if cfg.family == "vlm":
            # driver demo: synthetic pyramid at the config geometry (a
            # real frontend would pass per-image levels + features)
            vc = cfg.vision
            S = sum(h * w for h, w in vc.levels)
            req.pyramid = rng.standard_normal((S, vc.vision_dim)).astype(np.float32)
        reqs.append(req)
    warm = args.warm_lengths
    if warm is None:
        warm = sorted({len(r.prompt) for r in reqs})
    eng.warmup(prompt_lengths=tuple(warm))
    for req in reqs:
        eng.submit(req)
    eng.run()
    for req in reqs:
        print(f"[serve] request {req.rid}: {len(req.out)} tokens -> {req.out}")
    print(eng.metrics.format())
    _export()


if __name__ == "__main__":
    main()
