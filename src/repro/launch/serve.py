"""Serving driver: continuous-batching engine on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --prompts "hello world" "the quick brown fox"

Serving-runtime extras:

    # persistent warm boot: plan store + XLA compilation cache; AOT
    # warm-up for the prompt lengths the fleet expects
    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --store /tmp/plans.json --compile-cache /tmp/xla-cache --warm-lengths 4 8

    # CI smoke: boot the (vlm) engine against one store path; run twice
    # with the same paths and the SECOND boot must perform zero autotune
    # timing runs, zero request-time retraces and zero new XLA cache
    # entries — the process exits non-zero otherwise.
    PYTHONPATH=src python -m repro.launch.serve --serving-smoke \
        --store /tmp/store/plans.json --compile-cache /tmp/store/xla-cache
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.data import tokenizer
from repro.serving.engine import Request, ServeEngine
from repro.train import state as train_state


def serving_smoke(arch: str, store_path: str, compile_cache_dir: str,
                  *, slots: int = 2, capacity: int = 64) -> dict:
    """One serving boot against a persistent store; self-asserting.

    Cold boot (no store yet): warms + autotunes the bucket plans, saves
    the store, AOT-compiles the executors (all persisted to the XLA
    compilation cache), serves a few pyramid requests.  Warm boot (store
    exists): restores the plan set — the assertions then REQUIRE zero
    autotune timing runs, zero describe drift, zero request-time
    retraces, and zero new XLA cache entries (every boot compile was a
    disk hit).  The CI serving-smoke job runs this twice.
    """
    from repro.kernels import plan as plan_mod
    from repro.serving import aot, persistence

    # enable the compilation cache BEFORE any compile (params init
    # included) so both boots persist/hit the same entry set
    cache_on = persistence.enable_jax_compilation_cache(compile_cache_dir)
    assert cache_on, "persistent compilation cache failed to enable"
    warm = persistence.PlanStore(store_path).exists()
    cache0 = persistence.compilation_cache_entries(compile_cache_dir)
    plan_mod.reset_autotune_stats()
    aot.reset_stats()

    cfg = reduced(get_config(arch))
    params = train_state.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity,
                      store_path=store_path, compile_cache_dir=compile_cache_dir,
                      dtype_policy="auto", tune="autotune")
    eng.warmup(prompt_lengths=(4,))
    boot_tune = plan_mod.autotune_stats()

    vc = cfg.vision
    half = tuple((max(1, h // 2), max(1, w // 2)) for h, w in vc.levels)
    odd = tuple((max(1, h - 2), max(1, w - 3)) for h, w in vc.levels)
    rng = np.random.default_rng(0)
    reqs = []
    for i, lv in enumerate((vc.levels, odd, half, half)):
        S = sum(h * w for h, w in lv)
        reqs.append(Request(
            rid=i, prompt=np.arange(4, dtype=np.int32) + i, max_new=4,
            pyramid=rng.standard_normal((S, vc.vision_dim)).astype(np.float32),
            levels=lv))
    with aot.probe() as probe:
        for r in reqs:
            eng.submit(r)
        eng.run()

    rr = eng.restore_report
    summary = {
        "arch": cfg.name,
        "warm_boot": warm,
        "plans": len(eng.plans),
        "restored_plans": len(rr.plans) if rr else 0,
        "seeded_winners": rr.seeded_winners if rr else 0,
        "describe_mismatches": rr.describe_mismatches if rr else [],
        "boot_autotune": boot_tune,
        "request_traces": probe.traces,
        "request_compiles": probe.compiles,
        "new_xla_cache_entries":
            persistence.compilation_cache_entries(compile_cache_dir) - cache0,
        "completed": [len(r.out) for r in reqs],
        # aot probe counters ride inside the metrics dict so zero-retrace
        # is auditable from the uploaded artifact, not just the asserts
        "metrics": {**eng.metrics.snapshot(),
                    "aot": {"traces": probe.traces,
                            "compiles": probe.compiles,
                            "aot_calls": probe.aot_calls,
                            "boot": aot.stats()}},
    }
    print(json.dumps(summary, indent=1))
    assert all(len(r.out) == r.max_new for r in reqs), "requests incomplete"
    assert probe.traces == 0 and probe.compiles == 0, (
        f"request-time retraces: {probe}")
    if warm:
        assert boot_tune["raced"] == 0, (
            f"warm boot ran autotune timing: {boot_tune}")
        assert summary["restored_plans"] > 0, "warm boot restored no plans"
        assert not summary["describe_mismatches"], summary["describe_mismatches"]
        assert summary["new_xla_cache_entries"] == 0, (
            f"warm boot recompiled {summary['new_xla_cache_entries']} executables")
    else:
        # the no-recompilation assertion above is only meaningful if the
        # cold boot actually persisted executables — a silently-disabled
        # cache would make the warm-boot check pass vacuously
        assert summary["new_xla_cache_entries"] > 0, (
            "cold boot persisted no executables: compilation cache inert")
    eng.shutdown()
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", nargs="+", default=["hello world"])
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=None,
                    help="default: 4 (2 for --serving-smoke)")
    ap.add_argument("--capacity", type=int, default=None,
                    help="default: 128 (64 for --serving-smoke)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--store", default=None,
                    help="plan-store path: warm boots restore every plan "
                         "with zero autotune races")
    ap.add_argument("--compile-cache", default=None,
                    help="JAX persistent compilation cache directory")
    ap.add_argument("--dtype-policy", default=None,
                    choices=("follow", "float32", "bfloat16", "auto"))
    ap.add_argument("--tune", default=None, choices=("heuristic", "autotune"))
    ap.add_argument("--warm-lengths", type=int, nargs="*", default=None,
                    help="prompt lengths to AOT-compile prefill for at boot")
    ap.add_argument("--mesh", default=None, metavar="DPxTP",
                    help="build a (data=DP, model=TP) mesh and warm "
                         "DISTRIBUTED plans (e.g. 2x2; needs DP*TP local "
                         "devices); the plan store then records/restores "
                         "the sharding modes — see docs/sharding.md")
    ap.add_argument("--serving-smoke", action="store_true",
                    help="self-asserting double-boot CI smoke (see docstring)")
    ap.add_argument("--metrics-out", default=None,
                    help="dump the obs metrics registry at exit "
                         "(.json -> JSON, else Prometheus text)")
    ap.add_argument("--trace-out", default=None,
                    help="stream obs spans to this JSONL file")
    ap.add_argument("--trace-level", type=int, default=3,
                    help="span verbosity exported to --trace-out (1-4)")
    args = ap.parse_args()

    from repro import obs

    if args.trace_out:
        obs.enable_trace(args.trace_out, level=args.trace_level)

    def _export() -> None:
        if args.metrics_out:
            print(f"[serve] metrics -> {obs.write_metrics(args.metrics_out)}")
        if args.trace_out:
            obs.disable_trace()
            print(f"[serve] trace -> {args.trace_out}")

    if args.serving_smoke:
        if not (args.store and args.compile_cache):
            ap.error("--serving-smoke needs --store and --compile-cache")
        try:
            serving_smoke(args.arch or "phi-3-vision-4.2b", args.store,
                          args.compile_cache,
                          slots=args.slots or 2, capacity=args.capacity or 64)
        finally:
            _export()
        return

    if not args.arch:
        ap.error("--arch is required")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    mesh = None
    if args.mesh:
        from repro.launch import mesh as mesh_lib

        try:
            shape = mesh_lib.parse_mesh_shape(args.mesh)
        except ValueError as e:
            ap.error(str(e))
        if shape is not None:
            mesh = mesh_lib.make_mesh_2d(*shape)
    params = train_state.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, slots=args.slots or 4,
                      capacity=args.capacity or 128,
                      temperature=args.temperature, store_path=args.store,
                      compile_cache_dir=args.compile_cache,
                      dtype_policy=args.dtype_policy, tune=args.tune,
                      mesh=mesh)
    rng = np.random.default_rng(0)
    reqs = []
    for i, p in enumerate(args.prompts):
        ids = np.asarray(tokenizer.encode(p), np.int32) % cfg.vocab_size
        req = Request(rid=i, prompt=ids, max_new=args.max_new)
        if cfg.family == "vlm":
            # driver demo: synthetic pyramid at the config geometry (a
            # real frontend would pass per-image levels + features)
            vc = cfg.vision
            S = sum(h * w for h, w in vc.levels)
            req.pyramid = rng.standard_normal((S, vc.vision_dim)).astype(np.float32)
        reqs.append(req)
    warm = args.warm_lengths
    if warm is None:
        warm = sorted({len(r.prompt) for r in reqs})
    eng.warmup(prompt_lengths=tuple(warm))
    for req in reqs:
        eng.submit(req)
    eng.run()
    for req in reqs:
        print(f"[serve] request {req.rid}: {len(req.out)} tokens -> {req.out}")
    print(eng.metrics.format())
    _export()


if __name__ == "__main__":
    main()
