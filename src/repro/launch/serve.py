"""Serving driver: continuous-batching engine on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --prompts "hello world" "the quick brown fox"
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.data import tokenizer
from repro.serving.engine import Request, ServeEngine
from repro.train import state as train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", nargs="+", default=["hello world"])
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    params = train_state.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, slots=args.slots, capacity=args.capacity,
                      temperature=args.temperature)
    reqs = []
    for i, p in enumerate(args.prompts):
        ids = np.asarray(tokenizer.encode(p), np.int32) % cfg.vocab_size
        req = Request(rid=i, prompt=ids, max_new=args.max_new)
        reqs.append(req)
        eng.submit(req)
    eng.run()
    for req in reqs:
        print(f"[serve] request {req.rid}: {len(req.out)} tokens -> {req.out}")


if __name__ == "__main__":
    main()
