"""Training step factory: microbatched grad accumulation + AdamW.

``make_train_step(cfg, ...)`` returns a pure jittable
``(state, batch) -> (state, metrics)``:

* the global batch is split into ``num_microbatches`` slices scanned
  with accumulated grads (bounds activation memory; with scanned layers
  + remat this is what makes the 32B-130B train cells fit);
* **mixed precision**: fp32 master params are cast to the config's
  compute dtype ONCE before the microbatch loop, so FSDP all-gathers
  move bf16, not fp32 (§Perf iteration 2: halves gather wire bytes);
* **sharded accumulation**: the fp32 grad accumulator carries the
  parameter PartitionSpecs, so per-microbatch grads are reduce-scattered
  into shards instead of living as full all-reduced tensors (§Perf
  iteration 2: ~2x collective-term win on MoE cells);
* AdamW with warmup-cosine LR, global-norm clip, decoupled decay — all
  operating on the sharded fp32 master state.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adamw, schedule
from repro.train.state import TrainState, loss_fn


def _split_micro(batch: Dict[str, jax.Array], n: int):
    def f(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])

    return jax.tree.map(f, batch)


def make_train_step(
    cfg,
    *,
    num_microbatches: int = 1,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10000,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    remat: bool = True,
    param_specs: Optional[Any] = None,  # PartitionSpec tree (sharded accum)
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict[str, jax.Array]]]:
    lf = loss_fn(cfg)
    compute_dtype = jnp.dtype(cfg.dtype)

    def cast_param(p):
        if p.ndim >= 2 and p.dtype == jnp.float32 and compute_dtype != jnp.float32:
            return p.astype(compute_dtype)
        return p

    def constrain(tree):
        if param_specs is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, param_specs
        )

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params_c = constrain(jax.tree.map(cast_param, state.params))

        def micro_loss(p, mb):
            return lf(p, mb, remat=remat)

        if num_microbatches > 1:
            micro = _split_micro(batch, num_microbatches)

            def one_micro(carry, mb):
                gacc, lacc = carry
                loss, grads = jax.value_and_grad(micro_loss)(params_c, mb)
                # grads arrive in compute dtype, already reduce-scattered by
                # the FSDP backward; accumulate into the sharded fp32 buffer
                gacc = constrain(
                    jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                )
                return (gacc, lacc + loss), None

            zeros = constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            )
            (gsum, lsum), _ = jax.lax.scan(one_micro, (zeros, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
            loss = lsum / num_microbatches
        else:
            loss, grads = jax.value_and_grad(micro_loss)(params_c, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        lr = schedule.warmup_cosine(
            state.step, peak_lr=peak_lr, warmup_steps=warmup_steps, total_steps=total_steps
        )
        new_params, new_opt, gnorm = adamw.adamw_update(
            grads, state.opt, state.params,
            lr=lr, weight_decay=weight_decay, clip_norm=clip_norm,
        )
        new_state = TrainState(params=new_params, opt=new_opt, step=state.step + 1)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step
