"""TrainState pytree + model-family dispatch (init / loss)."""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jax.Array


def init_model(key, cfg):
    """Family dispatch for parameter init."""
    if cfg.family in ("dense", "moe", "hybrid", "ssm"):
        from repro.models import lm

        return lm.init_lm(key, cfg)
    if cfg.family == "audio":
        from repro.models import whisper

        return whisper.init_whisper(key, cfg)
    if cfg.family == "vlm":
        from repro.models import vlm

        return vlm.init_vlm(key, cfg)
    if cfg.family == "vision":
        from repro.core import deformable_transformer as dt

        return dt.init_detr(key, cfg)
    raise ValueError(cfg.family)


def loss_fn(cfg) -> Callable[[Any, Dict[str, jax.Array]], jax.Array]:
    """Family dispatch for the training loss: f(params, batch) -> scalar."""
    if cfg.family in ("dense", "moe", "hybrid", "ssm"):
        from repro.models import lm

        def f(params, batch, remat=True):
            return lm.lm_loss(params, cfg, batch["tokens"], batch["targets"], remat=remat)

        return f
    if cfg.family == "audio":
        from repro.models import whisper

        def f(params, batch, remat=True):
            return whisper.whisper_loss(
                params, cfg, batch["frames"], batch["tokens"], batch["targets"], remat=remat
            )

        return f
    if cfg.family == "vlm":
        from repro.models import vlm

        def f(params, batch, remat=True):
            return vlm.vlm_loss(
                params, cfg, batch["pyramid"], batch["tokens"], batch["targets"], remat=remat
            )

        return f
    if cfg.family == "vision":
        from repro.core import deformable_transformer as dt

        def f(params, batch, remat=True):
            return dt.detr_loss(params, cfg, batch, remat=remat)

        return f
    raise ValueError(cfg.family)


def init_state(key, cfg) -> TrainState:
    params = init_model(key, cfg)
    return TrainState(params=params, opt=adamw.init_adamw(params), step=jnp.zeros((), jnp.int32))
