"""Fault tolerance: heartbeats, straggler detection, restart driver.

On a real multi-pod job these hooks bind to the cluster manager; here
the control-plane logic is implemented and unit-tested against a
simulated cluster (the container has one host), which is exactly the
part a framework owns — detection thresholds, restart policy, elastic
re-meshing — while transport is the environment's.

* :class:`HeartbeatMonitor` — per-worker liveness with wall-clock
  timeouts; ``dead_workers`` drives elastic restart.
* :class:`StragglerDetector` — per-worker step-time EMA + z-score; slow
  workers are flagged for replacement/exclusion (at scale, a straggling
  host silently halves fleet throughput — detection must be cheap and
  continuous).
* :func:`run_with_restarts` — the driver loop: run -> on failure,
  restore newest checkpoint onto the surviving mesh (see
  ``runtime.elastic``) -> continue.  Deterministic data (pipeline is a
  pure function of step) makes the restart bit-exact.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

import numpy as np


class HeartbeatMonitor:
    def __init__(self, workers: List[str], timeout_s: float = 60.0):
        self.timeout = timeout_s
        self.last_seen: Dict[str, float] = {w: time.monotonic() for w in workers}

    def beat(self, worker: str, now: Optional[float] = None) -> None:
        self.last_seen[worker] = time.monotonic() if now is None else now

    def dead_workers(self, now: Optional[float] = None) -> Set[str]:
        now = time.monotonic() if now is None else now
        return {w for w, t in self.last_seen.items() if now - t > self.timeout}


class StragglerDetector:
    """Step-time EMA + cross-worker z-score straggler flagging."""

    def __init__(self, workers: List[str], alpha: float = 0.2, z_thresh: float = 3.0,
                 min_steps: int = 5):
        self.alpha, self.z, self.min_steps = alpha, z_thresh, min_steps
        self.ema: Dict[str, float] = {w: 0.0 for w in workers}
        self.count: Dict[str, int] = {w: 0 for w in workers}

    def record(self, worker: str, step_time_s: float) -> None:
        c = self.count[worker]
        self.ema[worker] = step_time_s if c == 0 else (
            self.alpha * step_time_s + (1 - self.alpha) * self.ema[worker]
        )
        self.count[worker] = c + 1

    def stragglers(self) -> Set[str]:
        ready = [w for w, c in self.count.items() if c >= self.min_steps]
        if len(ready) < 3:
            return set()
        vals = np.asarray([self.ema[w] for w in ready])
        med = np.median(vals)
        mad = np.median(np.abs(vals - med)) + 1e-9
        return {w for w, v in zip(ready, vals) if (v - med) / (1.4826 * mad) > self.z}


@dataclass
class FailureEvent:
    step: int
    kind: str  # 'crash' | 'straggler'
    workers: Set[str] = field(default_factory=set)


def run_with_restarts(
    *,
    train_some_steps: Callable[[int, int], int],
    save_ckpt: Callable[[int], None],
    restore_ckpt: Callable[[], int],
    total_steps: int,
    ckpt_every: int,
    failure_at: Optional[Dict[int, FailureEvent]] = None,
    max_restarts: int = 10,
) -> Dict[str, object]:
    """Restart driver (used by launch/train.py and the FT tests).

    ``train_some_steps(start, n)`` runs n steps, may raise RuntimeError
    (simulated via ``failure_at`` in tests); returns the reached step.
    """
    failure_at = failure_at or {}
    restarts = 0
    step = 0
    log: List[str] = []
    while step < total_steps:
        try:
            nxt = min(step + ckpt_every, total_steps)
            if step in failure_at:
                ev = failure_at.pop(step)
                raise RuntimeError(f"simulated {ev.kind} at step {ev.step}: {ev.workers}")
            step = train_some_steps(step, nxt - step)
            save_ckpt(step)
            log.append(f"ckpt@{step}")
        except RuntimeError as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            log.append(f"restart#{restarts}: {e}")
            step = restore_ckpt()
    return {"final_step": step, "restarts": restarts, "log": log}
