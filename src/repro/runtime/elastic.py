"""Elastic re-meshing: choose a new mesh for the surviving device set.

After losing a pod/host, the job restarts on fewer chips.  The policy:
keep the 'model' axis intact if possible (TP degree is baked into layer
divisibility) and shrink the data axes; fall back to shrinking 'model'
through the config's divisors.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def propose_mesh_shape(
    n_devices: int,
    *,
    preferred_model: int = 16,
    want_pod_axis: bool = False,
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest usable (data, model) [or (pod, data, model)] <= n_devices."""
    model = preferred_model
    while model > 1 and n_devices % model:
        model //= 2
    rest = n_devices // model
    if want_pod_axis and rest % 2 == 0 and rest >= 4:
        return (2, rest // 2, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


def make_elastic_mesh(devices: Optional[Sequence] = None, *, preferred_model: int = 16,
                      want_pod_axis: bool = False):
    devices = list(devices if devices is not None else jax.devices())
    shape, axes = propose_mesh_shape(
        len(devices), preferred_model=preferred_model, want_pod_axis=want_pod_axis
    )
    n = 1
    for s in shape:
        n *= s
    import numpy as np

    dev_array = np.asarray(devices[:n]).reshape(shape)
    from jax.sharding import Mesh

    return Mesh(dev_array, axes)
