"""Shared deterministic fault injection: training steps AND serving ticks.

Grown out of ``training/faults.py`` (which now re-exports this module):
every recovery path — the training harness's checkpointed restarts and
the serving engine's resilience layer — must be testable under the
4-virtual-device conftest, so faults are *data*, not monkeypatches: a
:class:`FaultSchedule` is an explicit (or seeded) list of
:class:`FaultEvent`, each fired exactly once when the runtime reaches
its step (training: optimizer step; serving: engine tick).  Because the
schedule and the runtime around it are deterministic, two runs with the
same schedule make IDENTICAL recovery decisions — which
``tests/test_checkpoint_ft.py`` and ``tests/test_serving_resilience.py``
assert literally.

Training kinds (fired by ``training.harness.TrainingHarness``):

* ``"host_loss"`` — raised BEFORE the step runs: the process "dies" and
  the harness restores the newest checkpoint (losing any steps since).
* ``"preempt"`` — raised AFTER the step computed but BEFORE it commits:
  the classic mid-step preemption; the finished step's work is lost.
* ``"corrupt_ckpt"`` — truncates the newest on-disk checkpoint, then
  dies like ``host_loss``; recovery must fall back to the PREVIOUS
  step (``checkpoint.manager.restore_latest_valid``).

Serving kinds (fired by :class:`FaultInjector`, consumed by
``serving/resilience.py`` + ``ServeEngine``):

* ``"exec_raise"`` — arms N consecutive primary-executor attempts to
  raise :class:`InjectedExecutorError` (N = the injector's
  ``raise_attempts``): one armed attempt exercises retry-with-backoff,
  enough of them exhaust the retry budget and drive the circuit
  breaker's demote -> half-open -> close cycle.
* ``"straggler"`` — the tick straggles: the engine stalls
  ``straggler_s`` and records a straggler event (deadline sweeps then
  see the lost time).
* ``"corrupt_store"`` — damages the serving ``PlanStore`` file; fired
  at BOOT (before the store is read) regardless of the scheduled step,
  so a seeded schedule can include it without knowing boot timing —
  the engine must degrade to a cold warm-up + re-persist.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.checkpoint import manager as ckpt

TRAINING_FAULT_KINDS = ("host_loss", "preempt", "corrupt_ckpt")
SERVING_FAULT_KINDS = ("exec_raise", "straggler", "corrupt_store")
FAULT_KINDS = TRAINING_FAULT_KINDS + SERVING_FAULT_KINDS


class HostLoss(RuntimeError):
    """Simulated host/process loss (the harness restores and resumes)."""


class Preemption(RuntimeError):
    """Simulated mid-step preemption (the in-flight step is discarded)."""


class InjectedExecutorError(RuntimeError):
    """Simulated executor failure (the resilience layer retries/demotes)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")


class FaultSchedule:
    """An ordered, fire-once schedule of injected faults.

    Each event fires the FIRST time the runtime reaches its step —
    replayed steps after a recovery do NOT re-trigger it (a real host
    doesn't die twice from one failure).  ``describe()`` returns the
    schedule as plain dicts for telemetry.
    """

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: Dict[int, FaultEvent] = {}
        for e in events:
            if e.step in self.events:
                raise ValueError(f"two faults scheduled at step {e.step}")
            self.events[e.step] = e
        self.fired: List[FaultEvent] = []

    @classmethod
    def from_spec(cls, spec: str) -> "FaultSchedule":
        """Parse the CLI format: ``"host_loss@5,corrupt_ckpt@9"``."""
        events = []
        for tok in spec.split(","):
            tok = tok.strip()
            if not tok:
                continue
            kind, _, step = tok.partition("@")
            if not step:
                raise ValueError(f"fault {tok!r} is not kind@step")
            events.append(FaultEvent(step=int(step), kind=kind))
        return cls(events)

    @classmethod
    def generate(cls, seed: int, total_steps: int, *, n_faults: int = 2,
                 kinds: Sequence[str] = TRAINING_FAULT_KINDS) -> "FaultSchedule":
        """Seeded random schedule — same seed, same faults, every run.

        Steps are drawn without replacement from ``[1, total_steps)``
        (step 0 has no checkpoint to recover to yet), kinds cycle
        through a seeded permutation of ``kinds``.  The default kinds
        stay the TRAINING set so historical seeds keep producing the
        schedules they always did; serving callers pass
        ``kinds=SERVING_FAULT_KINDS``.
        """
        kinds = tuple(kinds)
        if not kinds:
            raise ValueError(
                "FaultSchedule.generate needs at least one fault kind; "
                f"pass a non-empty subset of {FAULT_KINDS}")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}; one of {FAULT_KINDS}")
        if int(n_faults) < 0:
            raise ValueError(f"n_faults must be >= 0, got {n_faults}")
        rng = np.random.default_rng(seed)
        hi = max(2, int(total_steps))
        n = min(int(n_faults), hi - 1)
        steps = sorted(rng.choice(np.arange(1, hi), size=n, replace=False))
        order = list(rng.permutation(list(kinds)))
        return cls([FaultEvent(step=int(s), kind=order[i % len(order)])
                    for i, s in enumerate(steps)])

    def take(self, step: int) -> Optional[FaultEvent]:
        """The fault scheduled at ``step``, popped so it fires once."""
        ev = self.events.pop(step, None)
        if ev is not None:
            self.fired.append(ev)
        return ev

    def take_kind(self, kind: str) -> List[FaultEvent]:
        """Pop every pending event of ``kind`` regardless of step.

        Boot-time faults (``corrupt_store``) fire before any tick runs,
        so the injector drains them by kind instead of waiting for a
        step the boot will never reach.
        """
        steps = [s for s, e in self.events.items() if e.kind == kind]
        out = []
        for s in sorted(steps):
            ev = self.events.pop(s)
            self.fired.append(ev)
            out.append(ev)
        return out

    def describe(self) -> List[Dict[str, int]]:
        pending = [dataclasses.asdict(e) for _, e in sorted(self.events.items())]
        return [dict(d, fired=False) for d in pending] + \
               [dict(dataclasses.asdict(e), fired=True) for e in self.fired]


def corrupt_latest_checkpoint(directory: str) -> Optional[str]:
    """Deterministically damage the newest committed checkpoint.

    Truncates its first leaf ``.npy`` to 16 bytes — the manifest stays
    valid, so ``latest_step`` still points at it, but ``restore()``
    raises on the mangled array.  Exactly the shape of a crash that
    tore a write.  Returns the damaged file's path (None when there is
    no checkpoint to damage — an empty, missing, or junk-entry-only
    checkpoint directory is a no-op, never a raise).
    """
    step = ckpt.latest_step(directory)
    if step is None:
        return None
    path = os.path.join(directory, f"step_{step:08d}", "leaf_00000.npy")
    if not os.path.exists(path):
        return None
    with open(path, "r+b") as f:
        f.truncate(16)
    return path


def corrupt_plan_store(path: str) -> Optional[str]:
    """Deterministically damage a serving ``PlanStore`` file.

    Truncates the JSON to 16 bytes — ``PlanStore.load()`` must then
    degrade to ``None`` (cold boot: warm fresh + re-persist), never
    raise.  Returns the damaged path (None when there is no store yet,
    in which case the fault is a no-op: a boot with no store is already
    the cold path the fault forces).
    """
    if not path or not os.path.isfile(path):
        return None
    with open(path, "r+b") as f:
        f.truncate(16)
    return path


class FaultInjector:
    """The shared chaos runtime: drives serving faults off a schedule.

    Deterministic by construction — every decision is a pure function
    of ``(schedule, raise_target, raise_attempts)``, so two engines
    built from equal seeded schedules and equal injector configs make
    identical fault/recovery decisions (``self.log`` records each one
    for the reproducibility asserts).

    * ``begin_tick(tick)`` — called at the top of each engine tick;
      pops the tick's event.  ``exec_raise`` arms ``raise_attempts``
      consecutive rung-0 attempts of the ``raise_target`` executor;
      ``straggler`` is returned for the engine to stall + meter;
      ``corrupt_store`` (scheduled mid-run) damages the store file on
      disk — the running engine keeps its in-memory plans, the NEXT
      boot sees the corruption.
    * ``should_raise(name, rung)`` — consulted by the resilience layer
      before each executor attempt; consumes one armed raise when
      ``name`` matches the target and the attempt is on the primary
      rung (fallback rungs never raise: the injected fault models the
      PRIMARY being broken, which is what a demotion must survive).
    * ``apply_boot_faults(store_path)`` — drains every pending
      ``corrupt_store`` event before the store is read.
    """

    def __init__(self, schedule: FaultSchedule, *,
                 raise_target: str = "decode", raise_attempts: int = 1,
                 straggler_s: float = 0.0,
                 store_corruptor: Callable[[str], Optional[str]] = corrupt_plan_store):
        self.schedule = schedule
        self.raise_target = str(raise_target)
        self.raise_attempts = int(raise_attempts)
        self.straggler_s = float(straggler_s)
        self._store_corruptor = store_corruptor
        self._store_path: Optional[str] = None
        self._armed = 0
        self.log: List[Dict[str, Any]] = []

    def apply_boot_faults(self, store_path: Optional[str]) -> List[str]:
        """Fire every pending ``corrupt_store`` event; returns damaged paths."""
        self._store_path = store_path
        damaged = []
        for ev in self.schedule.take_kind("corrupt_store"):
            path = self._store_corruptor(store_path) if store_path else None
            self.log.append({"at": "boot", "kind": ev.kind, "step": ev.step,
                             "damaged": path})
            if path:
                damaged.append(path)
        return damaged

    def begin_tick(self, tick: int) -> Optional[FaultEvent]:
        ev = self.schedule.take(tick)
        if ev is None:
            return None
        if ev.kind == "exec_raise":
            self._armed += self.raise_attempts
            self.log.append({"at": tick, "kind": ev.kind,
                             "armed": self.raise_attempts,
                             "target": self.raise_target})
        elif ev.kind == "straggler":
            self.log.append({"at": tick, "kind": ev.kind,
                             "stall_s": self.straggler_s})
        elif ev.kind == "corrupt_store":
            path = (self._store_corruptor(self._store_path)
                    if self._store_path else None)
            self.log.append({"at": tick, "kind": ev.kind, "damaged": path})
        else:  # a training kind in a serving schedule: surface, don't fire
            self.log.append({"at": tick, "kind": ev.kind, "ignored": True})
        return ev

    def should_raise(self, name: str, rung: int) -> bool:
        if rung == 0 and self._armed > 0 and name == self.raise_target:
            self._armed -= 1
            self.log.append({"kind": "raise", "target": name})
            return True
        return False

    @property
    def pending_raises(self) -> int:
        return self._armed
