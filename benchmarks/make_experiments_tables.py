"""Generate the §Dry-run and §Roofline markdown tables from the sweep JSON.

    PYTHONPATH=src:. python -m benchmarks.make_experiments_tables > experiments/tables.md
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "../experiments/dryrun_results.json")
ARCHS = [
    "granite-20b", "stablelm-1.6b", "qwen1.5-32b", "llama3-8b",
    "recurrentgemma-2b", "dbrx-132b", "grok-1-314b", "whisper-large-v3",
    "xlstm-350m", "phi-3-vision-4.2b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
EXTRA = [("deformable-detr", "detr_1k")]
HBM_PER_CHIP = 16e9


def gb(x):
    return f"{x/1e9:.2f}" if x is not None else "-"


def main() -> None:
    with open(os.path.abspath(RESULTS)) as f:
        r = json.load(f)

    print("### Dry-run (both meshes)\n")
    print("| arch | shape | mesh | status | compile_s | bytes/dev (arg+temp) GB | fits 16GB | collectives (count) | wire GB/chip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            for m in ("single", "multi"):
                c = r.get(f"{a}|{s}|{m}")
                if c is None:
                    continue
                if c["status"] == "skip":
                    if m == "single":
                        print(f"| {a} | {s} | both | skip — {c['reason']} | | | | | |")
                    continue
                mem = c["memory"]
                args_b = mem.get("argument_size_in_bytes") or 0
                temp_b = mem.get("temp_size_in_bytes") or 0
                alias = mem.get("alias_size_in_bytes") or 0
                per_dev = args_b + temp_b - alias
                fits = "YES" if per_dev <= HBM_PER_CHIP else f"**NO ({per_dev/1e9:.1f}GB)**"
                coll = c["collectives"]
                per_t = ", ".join(f"{k.split('-')[-1][:6]}:{gb(v)}G" for k, v in
                                  sorted(coll["per_type"].items()))
                print(f"| {a} | {s} | {m} | ok | {c['t_compile']:.1f} | "
                      f"{per_dev/1e9:.2f} | {fits} | {coll['count']} | "
                      f"{coll['wire_bytes']/1e9:.2f} |")
    for a, sh in EXTRA:
        for m in ("single", "multi"):
            c = r.get(f"{a}|{sh}|{m}")
            if not c or c["status"] != "ok":
                continue
            mem = c["memory"]
            per_dev = (mem.get("argument_size_in_bytes") or 0) + (mem.get("temp_size_in_bytes") or 0) - (mem.get("alias_size_in_bytes") or 0)
            fits = "YES" if per_dev <= HBM_PER_CHIP else f"**NO ({per_dev/1e9:.1f}GB)**"
            coll = c["collectives"]
            print(f"| {a} | {sh} | {m} | ok | {c['t_compile']:.1f} | "
                  f"{per_dev/1e9:.2f} | {fits} | {coll['count']} | "
                  f"{coll['wire_bytes']/1e9:.2f} |")
    print()
    print("### Roofline (single-pod, 256 chips; per-chip terms, seconds)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | bottleneck | MODEL_FLOPS | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS + ["deformable-detr"]:
        shp = SHAPES if a != "deformable-detr" else ["detr_1k"]
        for s in shp:
            c = r.get(f"{a}|{s}|single")
            if c is None:
                continue
            if c["status"] == "skip":
                print(f"| {a} | {s} | — | — | — | skip ({c['reason'].split('—')[0].strip()}) | | | |")
                continue
            ro = c["roofline"]
            tmax = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
            frac = ro["compute_s"] / tmax if tmax else 0
            print(f"| {a} | {s} | {ro['compute_s']:.3e} | {ro['memory_s']:.3e} | "
                  f"{ro['collective_s']:.3e} | {ro['bottleneck']} | "
                  f"{c['model_flops_global']:.2e} | {c['useful_flops_ratio']:.2f} | {frac:.3f} |")


if __name__ == "__main__":
    main()
