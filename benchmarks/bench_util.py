"""Timing utilities for the benchmark harness (CPU wall-clock)."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (jit'd fn, blocked)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
