"""One benchmark per paper table/figure (xMSDA, Tables 2-4, Figs 4-5).

This container is CPU-only, so absolute microseconds are CPU numbers;
what reproduces the paper is the *structure* of each comparison:

* Table 2/3 — "Baseline" (un-fused grid-sample composition, MMCV
  fallback) vs "fused" (single-pass vectorised op = the vendor-library
  analogue) vs the xMSDA kernel path, for forward / backward / train.
  The Pallas kernel is timed in interpret mode only at a reduced size
  (interpret executes the kernel body in Python per grid step — its
  wall-time is NOT a TPU prediction; its structural counters are what
  transfer, and the TPU-side roofline lives in EXPERIMENTS.md §Roofline).
* Table 4 — ablations: adaptive vec-len, gather fusion, scatter fusion,
  staggered/two-phase scatter — reported as kernel-structure counters
  (gathers issued / average gather vector length / scatter conflicts)
  plus interpret-mode wall time.
* Fig 4/5 — gather/scatter micro-benchmarks vs granularity: the paper's
  "merging adjacent pixels doubles effective bandwidth" claim, measured
  with jnp gathers of (N, D) vs (N/2, 2D) layouts.

Workload: the paper's 5-level pyramid scaled by 1/4 per side (CPU
budget): levels 64..4, sum HW = 5456 queries, 8 heads x 32 dim,
4 points — same shape *ratios* as the paper's 1024x1024 eval.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import row, time_fn
from repro.kernels import plan as plan_mod
from repro.kernels.ref import msda_grid_sample_baseline, msda_ref

LEVELS = ((64, 64), (32, 32), (16, 16), (8, 8), (4, 4))
B, H, D, P = 1, 8, 32, 4
Q = sum(h * w for h, w in LEVELS)  # 5456, per-pixel queries like the paper
PAPER = {  # reported kernel times (µs) from the paper, for reference
    "fwd_baseline": 52662.7, "fwd_cann": 16573.6, "fwd_ours_inf": 8981.6,
    "fwd_ours_train": 15562.5, "bwd_baseline": 335696.8, "bwd_cann": 91056.4,
    "bwd_ours": 37714.1,
}


def _inputs(seed=0, q=None):
    qq = q or Q
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    value = jax.random.normal(ks[0], (B, Q, H, D), jnp.float32)
    loc = jax.random.uniform(ks[1], (B, qq, H, len(LEVELS), P, 2))
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, qq, H, len(LEVELS), P)).reshape(B, qq, H, -1)
    ).reshape(B, qq, H, len(LEVELS), P)
    gout = jax.random.normal(ks[3], (B, qq, H * D))
    return value, loc, attn, gout


# --------------------------------------------------------------------------
# Table 2: forward & backward kernel time
# --------------------------------------------------------------------------


def table2_overall():
    print("# Table 2: forward & backward kernel time (CPU wall-clock)")
    value, loc, attn, gout = _inputs()

    base_f = jax.jit(lambda v, l, a: msda_grid_sample_baseline(v, LEVELS, l, a))
    ref_f = jax.jit(lambda v, l, a: msda_ref(v, LEVELS, l, a))
    t_base = time_fn(base_f, value, loc, attn)
    t_ref = time_fn(ref_f, value, loc, attn)
    row("table2.fwd.baseline_grid_sample", t_base, f"paper_us={PAPER['fwd_baseline']}")
    row("table2.fwd.fused_ref(vendor-analogue)", t_ref, f"paper_us={PAPER['fwd_cann']}")
    row("table2.fwd.fused_speedup_vs_baseline", t_base / t_ref * 0,
        f"x{t_base / t_ref:.2f} (paper x{PAPER['fwd_baseline']/PAPER['fwd_cann']:.2f} CANN, "
        f"x{PAPER['fwd_baseline']/PAPER['fwd_ours_inf']:.2f} ours)")

    base_b = jax.jit(jax.grad(lambda v, l, a: jnp.vdot(msda_grid_sample_baseline(v, LEVELS, l, a), gout), argnums=(0, 1, 2)))
    ref_b = jax.jit(jax.grad(lambda v, l, a: jnp.vdot(msda_ref(v, LEVELS, l, a), gout), argnums=(0, 1, 2)))
    tb_base = time_fn(base_b, value, loc, attn, iters=5)
    tb_ref = time_fn(ref_b, value, loc, attn, iters=5)
    row("table2.bwd.baseline_grid_sample", tb_base, f"paper_us={PAPER['bwd_baseline']}")
    row("table2.bwd.fused_ref(vendor-analogue)", tb_ref, f"paper_us={PAPER['bwd_cann']}")
    row("table2.bwd.fused_speedup_vs_baseline", 0.0,
        f"x{tb_base / tb_ref:.2f} (paper x{PAPER['bwd_baseline']/PAPER['bwd_ours']:.2f} ours)")
    return {"fwd": (t_base, t_ref), "bwd": (tb_base, tb_ref)}


# --------------------------------------------------------------------------
# Table 3: relative speedups (derived)
# --------------------------------------------------------------------------


def table3_speedups(t2):
    print("# Table 3: relative speedup over baseline (train = fwd+bwd)")
    tf_b, tf_r = t2["fwd"]
    tb_b, tb_r = t2["bwd"]
    row("table3.inference", tf_r, f"x{tf_b/tf_r:.2f}_vs_baseline (paper x5.86)")
    row("table3.backward", tb_r, f"x{tb_b/tb_r:.2f}_vs_baseline (paper x8.90)")
    row("table3.train_fwd_bwd", tf_r + tb_r,
        f"x{(tf_b+tb_b)/(tf_r+tb_r):.2f}_vs_baseline (paper x7.29)")


# --------------------------------------------------------------------------
# Table 4: ablations (kernel structure + interpret wall time, small size)
# --------------------------------------------------------------------------


def _kernel_stats(levels, q, block_q, fuse_gather):
    """Structural counters: gathers issued per grid step x steps, and the
    average gather vector length (the quantity Fig. 4 says drives
    throughput on the vector core)."""
    gathers = 0
    rows_total = 0
    for l, (hh, ww) in enumerate(levels):
        bq = block_q[l]
        steps = -(-q // bq)
        per_step = 1 if fuse_gather else 4
        gathers += B * H * steps * per_step
        rows_total += B * H * steps * (4 * bq * P)
    return gathers, rows_total / max(gathers, 1)


def table4_ablation():
    print("# Table 4: ablations (interpret-mode wall time + structure)")
    levels = ((16, 16), (8, 8))
    q = 128
    S = sum(h * w for h, w in levels)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    value = jax.random.normal(ks[0], (B, S, H, D))
    loc = jax.random.uniform(ks[1], (B, q, H, len(levels), P, 2))
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (B, q, H, len(levels), P)).reshape(B, q, H, -1)
    ).reshape(B, q, H, len(levels), P)
    gout = jax.random.normal(ks[3], (B, q, H * D))

    # each ablation is one committed MsdaSpec -> MsdaPlan: tuning lives on
    # the spec, the plan is built once, and timing loops just execute it
    variants = {
        "default": dict(fuse_gather=True, adaptive_block=True),
        "-adaptive_veclen": dict(fuse_gather=True, adaptive_block=False),
        "-gather_fusion": dict(fuse_gather=False, adaptive_block=True),
        "-all": dict(fuse_gather=False, adaptive_block=False),
    }
    for name, kw in variants.items():
        spec = plan_mod.MsdaSpec(
            spatial_shapes=levels, num_heads=H, head_dim=D, num_points=P,
            num_queries=q, dtype="float32", **kw)
        p = plan_mod.msda_plan(spec, backend="pallas")
        f = jax.jit(lambda v, l, a, p=p: p(v, l, a))
        t = time_fn(lambda: f(value, loc, attn), warmup=1, iters=3)
        g, veclen = _kernel_stats(levels, q, p.block_q, kw["fuse_gather"])
        row(f"table4.fwd.{name}", t,
            f"gathers={g};avg_vec_rows={veclen:.0f};block_q={p.block_q}")

    # backward: scatter fusion ablation
    for name, fuse in (("default", True), ("-scatter_fusion", False)):
        spec = plan_mod.MsdaSpec(
            spatial_shapes=levels, num_heads=H, head_dim=D, num_points=P,
            num_queries=q, dtype="float32", fuse_scatter=fuse)
        p = plan_mod.msda_plan(spec, backend="pallas")
        f = jax.jit(jax.grad(lambda v, p=p: jnp.vdot(p(v, loc, attn), gout)))
        t = time_fn(lambda: f(value), warmup=1, iters=3)
        scatters = 1 if fuse else 4
        row(f"table4.bwd.{name}", t, f"scatters_per_step={scatters}")
    row("table4.bwd.two_phase_note", 0.0,
        "staggered-write == per-shard partial grad slabs + psum (see "
        "tests/test_sharding_dist.py::test_distributed_msda_grad_value_reduction)")


# --------------------------------------------------------------------------
# Fig 4/5: gather & scatter micro-benchmarks vs granularity
# --------------------------------------------------------------------------


def fig4_gather_microbench():
    print("# Fig 4: gather throughput vs granularity (pixel-pair merging)")
    HW, reps = 256 * 256, 5
    for dd, tag in ((D, "1px_rows(D)"), (2 * D, "2px_merged(2D)"), (4 * D, "4px_merged(4D)")):
        n = 87296 * P * 4 // (dd // D)
        table = jax.random.normal(jax.random.PRNGKey(0), (HW, dd), jnp.float32)
        idx = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, HW)
        f = jax.jit(lambda t, i: jnp.take(t, i, axis=0))
        us = time_fn(f, table, idx, iters=reps)
        gb = n * dd * 4 / (us * 1e-6) / 1e9
        row(f"fig4.gather.{tag}", us, f"GB/s={gb:.2f};rows={n}")


def fig5_scatter_microbench():
    print("# Fig 5: scatter-add throughput vs granularity")
    HW, reps = 256 * 256, 5
    for dd, tag in ((D, "1px_rows(D)"), (2 * D, "2px_merged(2D)")):
        n = 87296 * P * 4 // (dd // D)
        upd = jax.random.normal(jax.random.PRNGKey(0), (n, dd), jnp.float32)
        idx = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, HW)
        f = jax.jit(lambda u, i: jnp.zeros((HW, dd), jnp.float32).at[i].add(u))
        us = time_fn(f, upd, idx, iters=reps)
        gb = n * dd * 4 / (us * 1e-6) / 1e9
        row(f"fig5.scatter.{tag}", us, f"GB/s={gb:.2f};rows={n}")


# --------------------------------------------------------------------------
# backend x dtype-policy matrix (the planned precision axis, PR 2)
# --------------------------------------------------------------------------


def _time_interleaved(fns, args, iters=9):
    """Median us per call, measuring the competitors ALTERNATELY.

    Sequential timing (A fully, then B) lets machine-load drift masquerade
    as a backend delta — on shared CPU runners the same jit'd fn varies
    2-3x between back-to-back blocks.  Interleaving puts every competitor
    under the same load profile; the medians stay comparable.
    """
    for f in fns.values():
        jax.block_until_ready(f(*args))  # compile + warm
    times = {k: [] for k in fns}
    for _ in range(iters):
        for k, f in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f(*args))
            times[k].append(time.perf_counter() - t0)
    return {k: sorted(ts)[len(ts) // 2] * 1e6 for k, ts in times.items()}


def backend_dtype_matrix():
    """cpu-vs-ref backend delta and fp32-vs-bf16 plan-variant delta.

    Two comparisons at the paper-scaled workload:

    * ``"cpu"`` (padded-slab batched per-corner gathers, head-major
      layout) vs ``"ref"`` (masked gathers + per-corner transposes) —
      the off-TPU ``"auto"`` default must beat the oracle it replaced on
      forward; train lands at parity (backward is scatter-bound for
      both backends — the ~0.7 s scatter floor dominates either way).
    * fp32-slab vs bf16-slab plan variants of the cpu backend — what the
      ``dtype_policy`` knob / autotune dtype race trades: bf16 halves
      slab bytes (and on TPU, VMEM residency) against cast overhead.
      On CPU fp32 wins (casts cost, residency doesn't) — which is the
      point: the winner is backend-dependent, so it's raced, not assumed.
    """
    print("# Backend/dtype matrix: cpu-vs-ref and fp32-vs-bf16 plan variants")
    import dataclasses

    value, loc, attn, gout = _inputs()
    spec = plan_mod.MsdaSpec(
        spatial_shapes=LEVELS, num_heads=H, head_dim=D, num_points=P,
        num_queries=Q, dtype="float32")

    plans = {b: plan_mod.msda_plan(spec, backend=b) for b in ("ref", "cpu")}
    fwd = _time_interleaved(
        {b: jax.jit(lambda v, l, a, p=p: p(v, l, a)) for b, p in plans.items()},
        (value, loc, attn))
    bwd = _time_interleaved(
        {b: jax.jit(jax.grad(lambda v, l, a, p=p: jnp.vdot(p(v, l, a), gout),
                             argnums=(0, 1, 2))) for b, p in plans.items()},
        (value, loc, attn), iters=5)
    for b in plans:
        row(f"matrix.fwd.{b}", fwd[b], "")
        row(f"matrix.bwd.{b}", bwd[b], "")
    row("matrix.fwd.cpu_speedup_vs_ref", 0.0, f"x{fwd['ref'] / fwd['cpu']:.2f}")
    row("matrix.train.cpu_speedup_vs_ref", 0.0,
        f"x{(fwd['ref'] + bwd['ref']) / (fwd['cpu'] + bwd['cpu']):.2f}")

    dplans = {pol: plan_mod.msda_plan(dataclasses.replace(spec, slab_dtype=pol),
                                      backend="cpu")
              for pol in ("float32", "bfloat16")}
    dt = _time_interleaved(
        {pol: jax.jit(lambda v, l, a, p=p: p(v, l, a)) for pol, p in dplans.items()},
        (value, loc, attn))
    for pol, p in dplans.items():
        row(f"matrix.fwd.cpu.{pol}_slab", dt[pol],
            f"slab_dtypes={p.tuning.slab_dtypes}")
    row("matrix.fwd.cpu.bf16_vs_fp32_slab", 0.0,
        f"x{dt['float32'] / dt['bfloat16']:.2f}")
    return {"fwd": fwd, "bwd": bwd, "dtype": dt}


# --------------------------------------------------------------------------
# fused whole-pyramid vs per-level launches (PR 5 tentpole ablation)
# --------------------------------------------------------------------------


def fused_vs_per_level(out_path=None):
    """Fused single-launch pyramid vs per-level launches, fwd and train.

    Interpret-mode wall time is NOT a TPU prediction (the kernel body
    runs in Python per grid step); what transfers is the STRUCTURE this
    row reports: launches per direction (1 vs L), gout streams in the
    backward (1 vs L), and HBM round-trips of fp32 partial outputs
    (0 vs L-1).  Writes the ``BENCH_kernels.json`` trajectory file at
    the repo root (CI uploads it per commit) and prints the CSV rows.
    """
    import dataclasses

    from repro.obs import bench as obs_bench

    levels = ((16, 16), (8, 8), (4, 4))
    q, b, h = 64, 1, 2
    S = sum(hh * ww for hh, ww in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    value = jax.random.normal(ks[0], (b, S, h, D))
    loc = jax.random.uniform(ks[1], (b, q, h, L, P, 2))
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (b, q, h, L, P)).reshape(b, q, h, -1)
    ).reshape(b, q, h, L, P)
    gout = jax.random.normal(ks[3], (b, q, h * D))

    print("# Fused whole-pyramid vs per-level launches (interpret mode)")
    results = {}
    for train in (False, True):
        spec = plan_mod.MsdaSpec(
            spatial_shapes=levels, num_heads=h, head_dim=D, num_points=P,
            num_queries=q, dtype="float32", train=train)
        plans = {fuse: plan_mod.msda_plan(
            dataclasses.replace(spec, fuse_levels=fuse), backend="pallas")
            for fuse in ("on", "off")}
        if train:
            fns = {fuse: jax.jit(jax.grad(
                lambda v, l, a, p=p: jnp.vdot(p(v, l, a), gout),
                argnums=(0, 1, 2))) for fuse, p in plans.items()}
        else:
            fns = {fuse: jax.jit(lambda v, l, a, p=p: p(v, l, a))
                   for fuse, p in plans.items()}
        t = _time_interleaved(fns, (value, loc, attn), iters=3)
        tag = "train" if train else "fwd"
        for fuse, us in t.items():
            mode = "fused" if fuse == "on" else "per_level"
            launches = (2 if fuse == "on" else 2 * L) if train else (
                1 if fuse == "on" else L)
            results[f"{tag}.{mode}"] = {"us": us, "launches_per_call": launches}
            row(f"kernels.{tag}.{mode}", us, f"launches={launches}")
        row(f"kernels.{tag}.fused_speedup", 0.0,
            f"x{t['off'] / t['on']:.2f}_vs_per_level")
        results[f"{tag}.fused_speedup_x"] = t["off"] / t["on"]

    if out_path is None:
        out_path = obs_bench.bench_path("kernels")
    obs_bench.write_bench(
        out_path,
        bench="fused_vs_per_level",
        config={"levels": [list(hw) for hw in levels], "Q": q, "B": b,
                "H": h, "D": D, "P": P},
        note="interpret-mode wall time; structural counters transfer",
        results=results,
        gate=[
            # launch counts are geometry-determined: any increase regresses
            obs_bench.gate_rule("*.launches_per_call", "lower", 0.0),
            # speedup ratios are same-machine relative -> moderately stable
            obs_bench.gate_rule("*.fused_speedup_x", "higher", 0.5),
            # raw interpret-mode timings vary across runner hardware
            obs_bench.gate_rule("*.us", "lower", 4.0),
        ])
    print(f"# wrote {out_path}")
    return results


def fusion_tiers(out_path=None):
    """Per-level vs strict-prefix vs whole-pyramid fusion tiers.

    The partial-fusion tier is the middle rung ``fused_vs_per_level``
    cannot see: one fused launch over the prefix [0:k) plus per-level
    tail launches, ``L - k + 1`` per direction.  As above, interpret-
    mode wall time is trend only; the launch schedule (read from
    ``plan.launches_per_call()``, the same method the observability
    gauge bills from) is the structural fact that transfers.  Writes
    the ``BENCH_fusion_tiers.json`` trajectory file at the repo root.
    """
    import dataclasses

    from repro.obs import bench as obs_bench

    levels = ((16, 16), (8, 8), (4, 4))
    q, b, h = 64, 1, 2
    S = sum(hh * ww for hh, ww in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    value = jax.random.normal(ks[0], (b, S, h, D))
    loc = jax.random.uniform(ks[1], (b, q, h, L, P, 2))
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (b, q, h, L, P)).reshape(b, q, h, -1)
    ).reshape(b, q, h, L, P)
    gout = jax.random.normal(ks[3], (b, q, h * D))

    tiers = {"per_level": "off", "prefix2": "prefix:2", "full": "on"}
    print("# Fusion tiers: per-level vs prefix [0:2) vs whole pyramid (interpret mode)")
    results = {}
    for train in (False, True):
        spec = plan_mod.MsdaSpec(
            spatial_shapes=levels, num_heads=h, head_dim=D, num_points=P,
            num_queries=q, dtype="float32", train=train)
        plans = {name: plan_mod.msda_plan(
            dataclasses.replace(spec, fuse_levels=fuse), backend="pallas")
            for name, fuse in tiers.items()}
        if train:
            fns = {name: jax.jit(jax.grad(
                lambda v, l, a, p=p: jnp.vdot(p(v, l, a), gout),
                argnums=(0, 1, 2))) for name, p in plans.items()}
        else:
            fns = {name: jax.jit(lambda v, l, a, p=p: p(v, l, a))
                   for name, p in plans.items()}
        t = _time_interleaved(fns, (value, loc, attn), iters=3)
        tag = "train" if train else "fwd"
        for name, us in t.items():
            lp = plans[name].launches_per_call()
            launches = lp["fwd"] + (lp["bwd"] if train else 0)
            results[f"{tag}.{name}"] = {"us": us, "launches_per_call": launches}
            row(f"fusion_tiers.{tag}.{name}", us, f"launches={launches}")

    if out_path is None:
        out_path = obs_bench.bench_path("fusion_tiers")
    obs_bench.write_bench(
        out_path,
        bench="fusion_tiers",
        config={"levels": [list(hw) for hw in levels], "Q": q, "B": b,
                "H": h, "D": D, "P": P, "prefix_k": 2},
        note="interpret-mode wall time is trend only; launch schedule transfers",
        results=results,
        gate=[
            # the launch schedule is geometry-determined: any increase
            # means a tier stopped fusing what it promised to fuse
            obs_bench.gate_rule("*.launches_per_call", "lower", 0.0),
            # raw interpret-mode timings vary across runner hardware
            obs_bench.gate_rule("*.us", "lower", 4.0),
        ])
    print(f"# wrote {out_path}")
    return results


# --------------------------------------------------------------------------
# pruned top-k vs dense plans (PR 7 sparsity ablation)
# --------------------------------------------------------------------------


def sparsity_ablation(out_path=None):
    """Pruned top-k plans vs the dense path, fwd and train.

    The transferable number is the GATHER-COUNT reduction — the pruned
    executor touches ``4k`` corners per query/head instead of ``4*L*P``
    — plus the renormalised-weight overhead it buys that with; the
    interpret/CPU wall time is reported for trend only.  Writes the
    ``BENCH_sparsity.json`` trajectory file at the repo root (CI uploads
    it per commit) and prints the CSV rows.
    """
    import dataclasses

    from repro.kernels import msda_sparse
    from repro.obs import bench as obs_bench

    levels = ((16, 16), (8, 8), (4, 4))
    q, b, h = 64, 1, 2
    S = sum(hh * ww for hh, ww in levels)
    L = len(levels)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    value = jax.random.normal(ks[0], (b, S, h, D))
    loc = jax.random.uniform(ks[1], (b, q, h, L, P, 2))
    attn = jax.nn.softmax(
        jax.random.normal(ks[2], (b, q, h, L, P)).reshape(b, q, h, -1)
    ).reshape(b, q, h, L, P)
    gout = jax.random.normal(ks[3], (b, q, h * D))

    print("# Pruned top-k vs dense plans (gather counts transfer; walltime is trend)")
    results = {}
    spec0 = plan_mod.MsdaSpec(
        spatial_shapes=levels, num_heads=h, head_dim=D, num_points=P,
        num_queries=q, dtype="float32")
    cells = L * P
    for k in (cells // 2, cells // 4):
        counts = msda_sparse.gather_counts(
            dataclasses.replace(spec0, sparsity="topk", sparsity_k=k))
        for train in (False, True):
            spec = dataclasses.replace(spec0, train=train)
            plans = {
                "dense": plan_mod.msda_plan(spec, backend="cpu"),
                "topk": plan_mod.msda_plan(
                    dataclasses.replace(spec, sparsity="topk", sparsity_k=k),
                    backend="cpu"),
            }
            if train:
                fns = {m: jax.jit(jax.grad(
                    lambda v, l, a, p=p: jnp.vdot(p(v, l, a), gout),
                    argnums=(0, 1, 2))) for m, p in plans.items()}
            else:
                fns = {m: jax.jit(lambda v, l, a, p=p: p(v, l, a))
                       for m, p in plans.items()}
            t = _time_interleaved(fns, (value, loc, attn), iters=3)
            tag = "train" if train else "fwd"
            for mode, us in t.items():
                gathers = (counts["topk_corner_gathers"] if mode == "topk"
                           else counts["dense_corner_gathers"])
                results[f"k{k}.{tag}.{mode}"] = {
                    "us": us, "corner_gathers_per_query": gathers}
                row(f"sparsity.k{k}.{tag}.{mode}", us, f"gathers={gathers}")
            row(f"sparsity.k{k}.{tag}.topk_speedup", 0.0,
                f"x{t['dense'] / t['topk']:.2f}_vs_dense")
            results[f"k{k}.{tag}.topk_speedup_x"] = t["dense"] / t["topk"]
        results[f"k{k}.gather_reduction"] = counts["gather_reduction"]
        row(f"sparsity.k{k}.gather_reduction", 0.0,
            f"{counts['gather_reduction']:.2%}_fewer_corner_gathers")

    if out_path is None:
        out_path = obs_bench.bench_path("sparsity")
    obs_bench.write_bench(
        out_path,
        bench="sparsity_ablation",
        config={"levels": [list(hw) for hw in levels], "Q": q, "B": b,
                "H": h, "D": D, "P": P, "cells": cells},
        note="CPU wall time is trend only; gather-count reduction transfers",
        results=results,
        gate=[
            # gather counts / reduction are geometry-determined facts
            obs_bench.gate_rule("*.corner_gathers_per_query", "lower", 0.0),
            obs_bench.gate_rule("*.gather_reduction", "higher", 0.0),
            obs_bench.gate_rule("*.topk_speedup_x", "higher", 0.5),
            obs_bench.gate_rule("*.us", "lower", 4.0),
        ])
    print(f"# wrote {out_path}")
    return results


# --------------------------------------------------------------------------
# end-to-end: paper host model (reduced) train step
# --------------------------------------------------------------------------


def bench_detr_train():
    print("# E2E: deformable-DETR (reduced) train step, ref vs pallas msda")
    from dataclasses import replace

    from repro.configs.base import get_config, reduced
    from repro.core import deformable_transformer as dt
    from repro.train import loop as train_loop, state as train_state

    cfg = reduced(get_config("deformable-detr"))
    sp = sum(h * w for h, w in cfg.msda.levels)
    batch = {
        "pyramid": jax.random.normal(jax.random.PRNGKey(1), (2, sp, cfg.d_model)) * 0.1,
        "labels": jnp.array([[1, 5, -1], [2, -1, -1]], jnp.int32),
        "boxes": jax.random.uniform(jax.random.PRNGKey(2), (2, 3, 4)),
    }
    for backend in ("ref",):
        c = replace(cfg, msda=replace(cfg.msda, backend=backend))
        state = train_state.init_state(jax.random.PRNGKey(0), c)
        step = jax.jit(train_loop.make_train_step(c, remat=False))
        t = time_fn(lambda s=state: step(s, batch)[0].step, warmup=1, iters=3)
        row(f"e2e.detr_train_step.{backend}", t, "reduced_cfg")
